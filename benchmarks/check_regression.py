"""Benchmark regression gate: diff fresh BENCH_*.json walls against a
committed baseline and fail on >threshold regression.

Walks both JSON reports for every ``"wall_s"`` leaf (wherever it sits —
``device.stages.*.wall_s`` in BENCH_index_build.json, ``batch.*.wall_s``
in BENCH_serve_latency.json) and compares the fresh wall against the
baseline at the same path:

  PYTHONPATH=src python benchmarks/check_regression.py \
      --fresh BENCH_index_build.json \
      --baseline benchmarks/baselines/index_build.json

Exit 1 iff any stage regressed by more than ``--threshold`` (default 25%)
*and* slowed down by at least ``--min-wall`` seconds in absolute terms —
shared CI runners jitter sub-second walls by tens of percent, so a
regression must be both relatively and absolutely significant to gate
(pathological regressions — a host sync per row, a per-batch recompile —
clear both bars instantly). A path present in the baseline but missing
from the fresh report fails too (a silently dropped stage is how a gate
goes blind). Refreshing a baseline is one command: rerun the benchmark
with ``--json`` onto the baseline path.

Quality scores gate in the opposite direction. Any numeric leaf whose key
ends in ``_score`` (e.g. ``scores.stability_score`` in
BENCH_partial_fit.json) is a **floor**: the fresh value must reach at
least ``baseline - --floor-drop`` (absolute slack, default 0.05) — higher
is always fine, and a score leaf missing from the fresh report fails just
like a missing wall. Walls answer "did it get slower?", floors answer
"did the map get worse?"; one gate run checks both.
"""

from __future__ import annotations

import argparse
import json
import sys


def wall_leaves(obj, path="") -> dict:
    """{json-path → seconds} for every ``wall_s`` leaf in the report."""
    out = {}
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            p = f"{path}/{k}" if path else str(k)
            if k == "wall_s" and isinstance(v, (int, float)):
                out[path or "/"] = float(v)
            else:
                out.update(wall_leaves(v, p))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(wall_leaves(v, f"{path}/{i}"))
    return out


def score_leaves(obj, path="") -> dict:
    """{json-path → value} for every numeric ``*_score`` leaf."""
    out = {}
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            p = f"{path}/{k}" if path else str(k)
            if k.endswith("_score") and isinstance(v, (int, float)):
                out[p] = float(v)
            else:
                out.update(score_leaves(v, p))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(score_leaves(v, f"{path}/{i}"))
    return out


def compare(fresh: dict, baseline: dict, threshold: float, min_wall: float):
    """Returns (rows, regressions, missing) — rows for the report table."""
    fw, bw = wall_leaves(fresh), wall_leaves(baseline)
    rows, regressions = [], []
    missing = sorted(set(bw) - set(fw))
    for path in sorted(bw):
        if path not in fw:
            continue
        base, cur = bw[path], fw[path]
        ratio = cur / base if base > 0 else float("inf")
        over = ratio > 1.0 + threshold
        significant = (cur - base) >= min_wall
        regressed = over and significant
        rows.append((path, base, cur, ratio, over, regressed))
        if regressed:
            regressions.append(path)
    return rows, regressions, missing


def compare_scores(fresh: dict, baseline: dict, floor_drop: float):
    """Floor gate: (rows, regressions, missing) over ``*_score`` leaves.

    A fresh score below ``baseline - floor_drop`` regresses; a score path
    in the baseline but absent from the fresh report is missing (and
    fails) — a gate that stops measuring quality must not pass green.
    """
    fs, bs = score_leaves(fresh), score_leaves(baseline)
    rows, regressions = [], []
    missing = sorted(set(bs) - set(fs))
    for path in sorted(bs):
        if path not in fs:
            continue
        base, cur = bs[path], fs[path]
        regressed = cur < base - floor_drop
        rows.append((path, base, cur, regressed))
        if regressed:
            regressions.append(path)
    return rows, regressions, missing


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True, help="freshly produced BENCH_*.json")
    ap.add_argument("--baseline", required=True, help="committed baseline json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="max tolerated slowdown fraction (0.25 = +25%%)",
    )
    ap.add_argument(
        "--min-wall",
        type=float,
        default=0.05,
        help="minimum absolute slowdown (s) before a relative regression gates",
    )
    ap.add_argument(
        "--floor-drop",
        type=float,
        default=0.05,
        help="max tolerated absolute drop below baseline for *_score leaves",
    )
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    rows, regressions, missing = compare(
        fresh, baseline, args.threshold, args.min_wall
    )
    print(
        f"# {args.fresh} vs {args.baseline} "
        f"(threshold +{args.threshold:.0%} AND ≥{args.min_wall}s absolute)"
    )
    print("stage,baseline_s,fresh_s,ratio,verdict")
    for path, base, cur, ratio, over, regressed in rows:
        verdict = "REGRESSED" if regressed else (
            "ok (over threshold, sub-floor delta)" if over else "ok"
        )
        print(f"{path},{base:.4f},{cur:.4f},{ratio:.2f}x,{verdict}")
    for path in missing:
        print(f"{path},?,MISSING,-,-,MISSING", file=sys.stderr)

    srows, sregressions, smissing = compare_scores(
        fresh, baseline, args.floor_drop
    )
    if srows or smissing:
        print(f"# score floors (fresh ≥ baseline - {args.floor_drop})")
        print("score,baseline,fresh,verdict")
        for path, base, cur, regressed in srows:
            verdict = "BELOW FLOOR" if regressed else "ok"
            print(f"{path},{base:.4f},{cur:.4f},{verdict}")
        for path in smissing:
            print(f"{path},?,MISSING,MISSING", file=sys.stderr)
    regressions += sregressions
    missing += smissing

    if regressions or missing:
        print(
            f"# FAIL: {len(regressions)} regression(s) {regressions}, "
            f"{len(missing)} missing stage(s) {missing}",
            file=sys.stderr,
        )
        return 1
    print("# OK: no wall regressed beyond the threshold, no score below floor")
    return 0


if __name__ == "__main__":
    sys.exit(main())

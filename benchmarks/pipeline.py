"""End-to-end pipeline benchmark: embed→store→fit→inverse→explore per family.

Runs every registered :data:`repro.configs.PIPELINE_WORKLOADS` entry —
one per architecture family (dense attention / SSM / MoE) — through
``repro.pipeline.run_pipeline`` plus an ``/explore`` round trip on a
checkpoint-loaded :class:`MapService`, and emits the two things CI gates:

* **stage walls** (``stages.<family>.<stage>.wall_s``): embed (streaming
  model forward → sharded store), fit (store-backed NOMAD fit),
  inverse_train (the jitted 2D→embedding head), explore (decode + frozen
  kNN through the service) — a regression in any stage of any family
  gates via ``benchmarks/check_regression.py``.
* **round-trip scores** (``scores.<family>_roundtrip_score``): the
  inverse head's R² over the map's own rows, gated as a *floor* — the
  2D→embedding direction must keep recovering the corpus.

  PYTHONPATH=src python benchmarks/pipeline.py --quick --json BENCH_pipeline.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import shutil
import sys
import tempfile
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--docs", type=int, default=2_048, help="corpus documents")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--epochs", type=int, default=15, help="map fit epochs")
    ap.add_argument("--inverse-steps", type=int, default=600)
    ap.add_argument("--explore-queries", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true", help="CI size")
    ap.add_argument("--json", default="", help="write BENCH_pipeline.json here")
    return ap.parse_args(argv)


def _short(name: str) -> str:
    return name.removeprefix("pipeline_")


def build_report(args) -> dict:
    from repro.configs import PIPELINE_WORKLOADS
    from repro.pipeline import run_pipeline
    from repro.service import MapService

    if args.quick:
        args.docs = min(args.docs, 768)
        args.seq_len = min(args.seq_len, 32)
        args.epochs = min(args.epochs, 6)
        args.inverse_steps = min(args.inverse_steps, 300)

    stages, scores, families = {}, {}, {}
    for name in sorted(PIPELINE_WORKLOADS):
        w = dataclasses.replace(
            PIPELINE_WORKLOADS[name],
            n_docs=args.docs,
            seq_len=args.seq_len,
            n_epochs=args.epochs,
        )
        workdir = tempfile.mkdtemp(prefix=f"bench-{name}-")
        try:
            r = run_pipeline(
                w, workdir, seed=args.seed, inverse_steps=args.inverse_steps
            )
            # explore round trip: checkpoint-loaded service, decode + kNN
            svc = MapService()
            try:
                svc.registry.load(r.checkpoint_dir)
                coords = r.fit.embedding[: args.explore_queries]
                svc.explore(coords[:1])  # pay the jit compile outside the wall
                t0 = time.perf_counter()
                out = svc.explore(coords)
                explore_s = time.perf_counter() - t0
            finally:
                svc.close()
            short = _short(name)
            st = {k: {"wall_s": round(v, 3)} for k, v in r.stage_s.items()}
            st["explore"] = {"wall_s": round(explore_s, 3)}
            stages[short] = st
            scores[f"{short}_roundtrip_score"] = round(r.roundtrip_score, 4)
            families[short] = {
                "arch": w.arch,
                "family": r.workload.arch_config().family,
                "dim": int(r.store.shape[1]),
                "n_explore_hits": int((out.neighbor_ids >= 0).sum()),
            }
        finally:
            shutil.rmtree(workdir, ignore_errors=True)

    return {
        "benchmark": "pipeline",
        "config": {
            "docs": args.docs,
            "seq_len": args.seq_len,
            "epochs": args.epochs,
            "inverse_steps": args.inverse_steps,
            "explore_queries": args.explore_queries,
        },
        "families": families,
        "stages": stages,
        # *_score leaves are FLOOR-gated by check_regression.py: an inverse
        # head that stops recovering the corpus fails, a faster wall never does
        "scores": scores,
    }


def run(quick: bool = False):
    """benchmarks.run entry: [(name, us_per_call, derived), …]."""
    args = parse_args(["--quick"] if quick else [])
    report = build_report(args)
    rows = []
    for fam, st in report["stages"].items():
        for stage, d in st.items():
            rows.append((f"pipeline.{fam}.{stage}", d["wall_s"] * 1e6, ""))
    for name, v in report["scores"].items():
        rows.append((f"pipeline.{name}", 0.0, f"r2={v:.3f}"))
    return rows


def main(argv=None) -> int:
    args = parse_args(argv)
    report = build_report(args)
    print(f"{'family.stage':>32}  wall_s")
    for fam, st in report["stages"].items():
        for stage, d in st.items():
            print(f"{fam + '.' + stage:>32}  {d['wall_s']:.3f}")
    for name, v in report["scores"].items():
        print(f"{name:>32}  {v:.4f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print("report →", args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Table-1 analogue: the PubMed-scale single-vs-multi-device comparison.

Table 1 claims NOMAD on 8 GPUs matches OpenTSNE's NP@10 (6.2% → 6.1±0.3%)
at 5.4× the speed, while single-GPU methods OOM. Offline we scale the axes
that matter — same index, same per-shard batch — and report:

* wall-time per epoch: 1 shard vs 8 simulated shards (speedup column),
* NP@10 parity between the two (quality column),
* peak *per-shard* working set of θ+index (the vRAM-cap story: it falls
  ~n_shards×, which is why the 8-GPU run completes where 1-GPU OOMs).

Runs the 8-shard fit in a subprocess with 8 host devices, as elsewhere.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture
from repro.metrics import neighborhood_preservation

N, DIM = 12_000, 96
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_WORKER = r"""
import sys, time, json
import numpy as np, jax
from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture
from repro.metrics import neighborhood_preservation
from repro.index.ann import build_index

cfg = NomadConfig(**json.loads(sys.argv[1]))
x, _ = gaussian_mixture(cfg.n_points, cfg.dim, n_components=16, seed=0)
index = build_index(x, cfg)
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
proj = NomadProjection(cfg, strategy="sharded", mesh=mesh,
                       shard_axes=("data", "model"))
t0 = time.time()
emb = proj.fit_transform(x, index=index)
wall = time.time() - t0
np10 = neighborhood_preservation(x, emb, k=10, n_queries=600)
print("RESULT", json.dumps({"wall": wall, "np10": np10}))
"""


def run(quick: bool = False):
    epochs = 6 if quick else 20
    cfg = NomadConfig(
        n_points=N, dim=DIM, n_clusters=32, n_neighbors=15, n_noise=32,
        n_exact_negatives=8, batch_size=1024, n_epochs=epochs,
    )
    rows = []
    x, _ = gaussian_mixture(N, DIM, n_components=16, seed=0)

    from repro.index.ann import build_index

    index = build_index(x, cfg)
    t0 = time.time()
    res = NomadProjection(cfg, strategy="local").fit(x, index=index)
    wall1 = time.time() - t0
    np10_1 = neighborhood_preservation(x, res.embedding, k=10, n_queries=600)

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    import dataclasses

    payload = json.dumps(dataclasses.asdict(cfg))
    r = subprocess.run(
        [sys.executable, "-c", _WORKER, payload],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1800,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1].split("RESULT ")[1])

    theta_bytes = cfg.n_clusters * cfg.cluster_capacity * 2 * 4
    knn_bytes = cfg.n_clusters * cfg.cluster_capacity * cfg.n_neighbors * 8
    shard_bytes_1 = theta_bytes + knn_bytes
    rows.append(
        ("table1/nomad-1shard", wall1 / epochs * 1e6,
         f"np10={np10_1:.4f};shard_mb={shard_bytes_1/2**20:.1f}")
    )
    rows.append(
        ("table1/nomad-8shard", out["wall"] / epochs * 1e6,
         f"np10={out['np10']:.4f};speedup={wall1/out['wall']:.2f}x;"
         f"shard_mb={shard_bytes_1/8/2**20:.1f}")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))

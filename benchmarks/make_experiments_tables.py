"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSONs (current results vs frozen baseline).

  PYTHONPATH=src python benchmarks/make_experiments_tables.py > /tmp/tables.md
"""

from __future__ import annotations

import glob
import json
import os
import sys


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*", "*.json")):
        r = json.load(open(f))
        if r.get("ok"):
            out[(r["mesh"], r["arch"], r["shape"])] = r
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main():
    cur = load("results/dryrun")
    base = load("results/dryrun_baseline")

    print("### §Dry-run (optimized; per-device, from `compiled.memory_analysis()`)\n")
    print("| mesh | arch | shape | kind | GiB/dev | HLO GFLOP/dev | HBM GB/dev | coll GB/dev | coll ops | AG/AR/RS/A2A/CP GB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(cur):
        r = cur[key]
        h = r["hlo_cost"]
        cbt = h["coll_by_type"]
        mix = "/".join(
            f"{cbt.get(t,0)/1e9:.2f}"
            for t in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
        )
        print(
            f"| {key[0]} | {key[1]} | {key[2]} | {r.get('kind','')} "
            f"| {fmt_bytes(r['memory']['per_device_total'])} "
            f"| {h['flops']/1e9:.1f} | {h['bytes']/1e9:.1f} "
            f"| {h['collective_bytes']/1e9:.3f} | {int(h['coll_ops'])} | {mix} |"
        )

    print("\n### §Roofline (optimized; seconds per step; v5e constants)\n")
    print("| mesh | arch | shape | compute s | memory s | collective s | dominant | MODEL_TFLOP | useful | roofline | baseline bound s | speedup |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(cur):
        r = cur[key]
        t = r["terms"]
        b = base.get(key)
        bb = f"{b['terms']['bound_s']:.3f}" if b else "—"
        sp = (
            f"{b['terms']['bound_s']/max(t['bound_s'],1e-12):.2f}×"
            if b
            else "—"
        )
        print(
            f"| {key[0]} | {key[1]} | {key[2]} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | {t['dominant']} | {r['model_flops']/1e12:.1f} "
            f"| {t['useful_ratio']:.3f} | {t['roofline_fraction']:.4f} | {bb} | {sp} |"
        )

    n_ok = len(cur)
    print(f"\n({n_ok} cells compiled OK)")


if __name__ == "__main__":
    main()

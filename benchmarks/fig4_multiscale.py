"""Figure-1/4 analogue: multiscale structure of the map.

The paper's qualitative claim: the Wikipedia map is coherent at global,
mid, and extremely local zoom. Quantified here on a two-level hierarchical
mixture: neighbor label purity at the super-cluster level (global zoom)
and the sub-cluster level (local zoom), plus super-cluster centroid
separation in the 2-D map.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import hierarchical_mixture
from repro.metrics.neighborhood import _topk_neighbors

import jax.numpy as jnp


def run(quick: bool = False):
    n = 6000
    x, sup, sub = hierarchical_mixture(n, 48, n_super=5, n_sub=4, seed=0)
    cfg = NomadConfig(
        n_points=n, dim=48, n_clusters=20, n_neighbors=15, n_noise=32,
        n_exact_negatives=8, batch_size=1024,
        n_epochs=10 if quick else 30,
    )
    res = NomadProjection(cfg).fit(x)
    emb = res.embedding
    q = 600
    nb = np.asarray(_topk_neighbors(jnp.asarray(emb[:q]), jnp.asarray(emb), 10))
    sup_purity = float(np.mean(sup[nb] == sup[:q, None]))
    sub_purity = float(np.mean(sub[nb] == sub[:q, None]))
    # global separation: between/within scatter of super-cluster centroids
    cents = np.stack([emb[sup == s].mean(0) for s in range(5)])
    within = np.mean([emb[sup == s].std(0).mean() for s in range(5)])
    between = np.std(cents, axis=0).mean()
    per_epoch = float(np.mean(res.epoch_times[1:])) * 1e6
    return [(
        "fig4/multiscale", per_epoch,
        f"super_purity={sup_purity:.3f};sub_purity={sub_purity:.3f};"
        f"separation={between/max(within,1e-9):.2f}",
    )]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))

"""Index-build benchmark: wall time + peak RSS per stage, device vs seed host.

("peak RSS" = the process ru_maxrss high-watermark sampled at the end of
each stage — monotone across stages, so attribute a jump to the stage where
it first appears.)

Times the device-resident :class:`repro.index.build.IndexBuilder` pipeline
(kmeans / assign / permute / knn) and, with ``--compare-host``, the seed's
host pipeline — the NumPy bidding loop with its O(N·K) ``banned`` matrix,
the host-synced ``float(shift)`` EM loop, and the ``for c in range(K)``
permutation — then reports the speedup and the neighborhood-edge agreement
between the two indices (the PR-3 acceptance metric).

  PYTHONPATH=src python benchmarks/index_build.py --n 100000 --json BENCH_index_build.json
  PYTHONPATH=src python benchmarks/index_build.py --n 2000 --clusters 8 --compare-host

``--store-dir PATH`` additionally writes the corpus chunk-by-chunk into a
sharded on-disk store at PATH and times the *streamed* out-of-core build
(repro.data.store → IndexBuilder) against the monolithic in-RAM path. The
streamed phase runs first — ``ru_maxrss`` is process-monotone — so
``rss_compare`` cleanly attributes the watermark delta to the monolithic
path's full-size (N, D) copies. tests/test_store.py pins the N=50k bound.

CI smoke-runs this at tiny N on every push (see .github/workflows/ci.yml);
``BENCH_index_build.json`` is the machine-readable artifact.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


# ---------------------------------------------------------------------------
# The seed host pipeline (pre-PR-3), reproduced verbatim as the baseline
# ---------------------------------------------------------------------------


def _seed_capacity_assign(dist2_fn, x, cents, capacity, max_rounds=12):
    """The seed's bidding loop, O(N·K) ``banned`` matrix included."""
    n, K = x.shape[0], cents.shape[0]
    assign = np.full(n, -1, np.int64)
    free = np.full(K, capacity, np.int64)
    banned = np.zeros((n, K), bool)  # the O(N·K) host wall
    for _ in range(max_rounds):
        todo = np.flatnonzero(assign < 0)
        if todo.size == 0:
            return assign
        d2 = dist2_fn(x[todo], cents)
        d2 = np.where(banned[todo] | (free[None, :] <= 0), np.inf, d2)
        pick = np.argmin(d2, 1)
        for c in range(K):
            if free[c] <= 0:
                continue
            bidders = todo[pick == c]
            if bidders.size == 0:
                continue
            if bidders.size > free[c]:
                order = np.argsort(d2[pick == c, c])
                admitted = bidders[order[: free[c]]]
                banned[bidders[order[free[c] :]], c] = True
            else:
                admitted = bidders
            assign[admitted] = c
            free[c] -= admitted.size
    todo = np.flatnonzero(assign < 0)
    if todo.size:
        d2 = dist2_fn(x[todo], cents)
        for t, row in zip(todo, np.argsort(d2, axis=1)):
            for c in row:
                if free[c] > 0:
                    assign[t] = c
                    free[c] -= 1
                    break
    return assign


def seed_host_build(x, cfg):
    """The seed build_index: host kmeans loop (per-iter float(shift) sync),
    host bidding with ``banned``, per-cluster permutation loop, device kNN.
    Returns (AnnIndex, {stage: {"wall_s", "rss_high_watermark_mb"}})."""
    import jax
    import jax.numpy as jnp

    from repro.index.ann import AnnIndex, _np_dist2, data_fingerprint
    from repro.index.build import _rss_mb
    from repro.index.kmeans import assign_jnp, lsh_init_centroids, _m_step
    from repro.index.knn import batched_cluster_knn

    n, d = x.shape
    K, C, k = cfg.n_clusters, cfg.cluster_capacity, cfg.n_neighbors
    stages = {}

    t0 = time.time()
    key = jax.random.key(cfg.seed)
    xd = jnp.asarray(x)
    cents = lsh_init_centroids(key, xd, K)
    for _ in range(cfg.kmeans_iters):
        a, _ = assign_jnp(xd, cents)
        new_cents, _ = _m_step(xd, a, K, cents)
        shift = float(jnp.max(jnp.sum(jnp.square(new_cents - cents), -1)))
        cents = new_cents
        if shift < cfg.kmeans_tol:
            break
    cents = np.asarray(cents)
    stages["kmeans"] = {"wall_s": time.time() - t0, "rss_high_watermark_mb": _rss_mb()}

    t0 = time.time()
    assign = _seed_capacity_assign(_np_dist2, x, cents, C)
    stages["assign"] = {"wall_s": time.time() - t0, "rss_high_watermark_mb": _rss_mb()}

    t0 = time.time()
    order = np.argsort(assign, kind="stable")
    counts = np.bincount(assign, minlength=K).astype(np.int64)
    starts = np.zeros(K, np.int64)
    starts[1:] = np.cumsum(counts)[:-1]
    perm = np.zeros(n, np.int64)
    x_rows = np.zeros((K * C, d), x.dtype)
    for c in range(K):
        members = order[starts[c] : starts[c] + counts[c]]
        rows = c * C + np.arange(counts[c])
        perm[members] = rows
        x_rows[rows] = x[members]
    stages["permute"] = {"wall_s": time.time() - t0, "rss_high_watermark_mb": _rss_mb()}

    t0 = time.time()
    valid = (np.arange(C)[None, :] < counts[:, None]).astype(bool)
    knn_local, knn_w = batched_cluster_knn(
        jnp.asarray(x_rows).reshape(K, C, d), jnp.asarray(valid), k, "jnp"
    )
    knn_local = np.asarray(knn_local)
    knn_w = np.asarray(knn_w).reshape(K * C, k)
    base = (np.arange(K) * C)[:, None, None]
    knn_idx = (knn_local + base).reshape(K * C, k).astype(np.int64)
    self_rows = np.arange(K * C)[:, None]
    knn_idx = np.where(knn_w > 0, knn_idx, self_rows)
    stages["knn"] = {"wall_s": time.time() - t0, "rss_high_watermark_mb": _rss_mb()}

    index = AnnIndex(
        x_rows=x_rows,
        knn_idx=knn_idx,
        knn_w=knn_w.astype(np.float32),
        counts=counts,
        centroids=cents,
        perm=perm,
        capacity=C,
        n_points=n,
        fingerprint=data_fingerprint(x),
    )
    return index, stages


# ---------------------------------------------------------------------------
# Comparison metric
# ---------------------------------------------------------------------------


def edge_agreement(a, b) -> float:
    """Neighborhood-edge IoU between two indices, in original point ids."""

    def edges(idx):
        rows = idx.n_clusters * idx.capacity
        inv = np.full(rows, -1, np.int64)
        inv[idx.perm] = np.arange(idx.n_points)
        k = idx.knn_idx.shape[1]
        heads = inv[np.repeat(np.arange(rows), k)]
        tails = inv[idx.knn_idx.reshape(-1)]
        live = idx.knn_w.reshape(-1) > 0
        return np.unique(heads[live] * np.int64(rows) + tails[live])

    ea, eb = edges(a), edges(b)
    inter = np.intersect1d(ea, eb, assume_unique=True).size
    union = ea.size + eb.size - inter
    return float(inter) / max(1, union)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def bench(
    n=100_000,
    dim=64,
    clusters=256,
    neighbors=15,
    strategy="auto",
    seed=0,
    compare_host=False,
    repeat=1,
    store_dir="",
):
    from repro.configs.base import NomadConfig
    from repro.data.synthetic import gaussian_mixture
    from repro.index.build import IndexBuilder, _rss_mb

    cfg = NomadConfig(
        n_points=n,
        dim=dim,
        n_clusters=clusters,
        n_neighbors=neighbors,
        seed=seed,
        build_strategy=strategy,
    )

    # ---- streamed (out-of-core) build, FIRST: ru_maxrss is a process-
    # monotone high watermark, so the low-RSS path must run before the
    # monolithic path allocates its full-size copies ---------------------------
    streamed = None
    if store_dir:
        from repro.data.synthetic import gaussian_mixture_store

        # the corpus is generated chunk-by-chunk straight onto disk (same
        # rows gaussian_mixture() would produce) — no O(N·D) host buffer
        store, _ = gaussian_mixture_store(
            store_dir, n, dim, n_components=min(32, clusters), seed=seed
        )
        sb = IndexBuilder(cfg)
        sruns = []
        for _ in range(max(1, repeat)):
            streamed_index = sb.build(store)
            sruns.append(sb.report)
        srep = min(sruns, key=lambda r: r.total_s)
        streamed = {
            "total_s_per_run": [r.total_s for r in sruns],
            "total_s": srep.total_s,
            "stages": {
                s: {
                    "wall_s": srep.stage_s[s],
                    "rss_high_watermark_mb": srep.stage_rss_mb[s],
                }
                for s in srep.stage_s
            },
        }
        streamed_peak_mb = _rss_mb()

    x, _ = gaussian_mixture(n, dim, n_components=min(32, clusters), seed=seed)

    # repeat > 1 reports the best (jit-warm) run — one deployment compiles
    # once and builds many indices, so steady-state is the honest number;
    # run 0's times include compilation
    builder = IndexBuilder(cfg)
    runs = []
    for _ in range(max(1, repeat)):
        index = builder.build(x)
        runs.append(builder.report)
    rep = min(runs, key=lambda r: r.total_s)
    out = {
        "n": n,
        "dim": dim,
        "clusters": clusters,
        "neighbors": neighbors,
        "capacity": cfg.cluster_capacity,
        "strategy": rep.strategy,
        "n_shards": rep.n_shards,
        "stragglers": rep.stragglers,
        "device": {
            "total_s_per_run": [r.total_s for r in runs],
            "total_s": rep.total_s,
            "stages": {
                s: {
                    "wall_s": rep.stage_s[s],
                    "rss_high_watermark_mb": rep.stage_rss_mb[s],
                }
                for s in rep.stage_s
            },
        },
    }
    if streamed is not None:
        out["streamed"] = streamed
        out["rss_compare"] = {
            "streamed_peak_mb": streamed_peak_mb,
            "monolithic_peak_mb": _rss_mb(),
            # both watermarks include the interpreter/jax baseline; the
            # streamed phase ran first, so a monolithic peak above the
            # streamed one is attributable to the monolithic allocations
            "note": (
                "process-monotone ru_maxrss: streamed build sampled before "
                "the monolithic path ran; monolithic includes everything "
                "resident up to its own peak"
            ),
        }
        # the two pipelines accumulate f32 in different orders (chunked vs
        # resident), so centroids differ at fp level — report the graph IoU
        out["streamed"]["edge_agreement_vs_monolithic"] = edge_agreement(
            streamed_index, index
        )
    if compare_host:
        from repro.index.ann import _np_dist2

        t0 = time.time()
        host_index, host_stages = seed_host_build(x, cfg)
        out["host_seed"] = {"total_s": time.time() - t0, "stages": host_stages}
        # end-to-end agreement: includes the (tol-sized) kmeans difference —
        # the scan EM freezes pre-update centroids on convergence where the
        # seed loop kept the post-update ones
        out["edge_agreement"] = edge_agreement(index, host_index)
        out["edge_agreement_note"] = (
            "end-to-end IoU; both builds are converged k-means solutions but "
            "the scan EM returns pre-update centroids at the tol stop where "
            "the seed loop returned post-update ones, so cell boundaries "
            "differ by O(sqrt(tol)) — assign_agreement_same_centroids "
            "isolates the refactored capacity assignment itself"
        )
        # isolated capacity-assignment agreement: host bidding rounds on the
        # *device* centroids vs the device rounds — same round semantics,
        # so this is 1.0 up to fp argmin ties
        a_host = _seed_capacity_assign(
            _np_dist2, x, index.centroids, cfg.cluster_capacity
        )
        a_dev = index.perm // cfg.cluster_capacity
        out["assign_agreement_same_centroids"] = float(np.mean(a_host == a_dev))
        out["speedup_vs_host"] = out["host_seed"]["total_s"] / max(
            rep.total_s, 1e-9
        )
    return out


def run(quick: bool = False):
    """benchmarks/run.py contract: [(name, us_per_call, derived), …]."""
    res = bench(
        n=4000 if quick else 50_000,
        dim=16 if quick else 64,
        clusters=8 if quick else 128,
        neighbors=5 if quick else 15,
        compare_host=True,
        repeat=2,  # best-of-2: run 0 pays the jit compiles
    )
    rows = [
        (
            f"index_build/{s}_n{res['n']}",
            res["device"]["stages"][s]["wall_s"] * 1e6,
            f"rss={res['device']['stages'][s]['rss_high_watermark_mb']:.0f}MB",
        )
        for s in ("kmeans", "assign", "permute", "knn")
    ]
    rows.append(
        (
            f"index_build/total_n{res['n']}",
            res["device"]["total_s"] * 1e6,
            f"speedup_vs_host={res['speedup_vs_host']:.2f}x "
            f"edge_agreement={res['edge_agreement']:.4f}",
        )
    )
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=256)
    ap.add_argument("--neighbors", type=int, default=15)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", default="auto", choices=["auto", "local", "sharded"])
    ap.add_argument("--compare-host", action="store_true")
    ap.add_argument("--repeat", type=int, default=2, help="build runs; best wins")
    ap.add_argument(
        "--store-dir",
        default="",
        help="also run the streamed out-of-core build from a sharded store "
        "written (chunk-by-chunk) at this path; reports peak-RSS + wall for "
        "monolithic vs streamed",
    )
    ap.add_argument("--json", default="", help="write the report to this path")
    args = ap.parse_args()

    res = bench(
        n=args.n,
        dim=args.dim,
        clusters=args.clusters,
        neighbors=args.neighbors,
        strategy=args.strategy,
        seed=args.seed,
        compare_host=args.compare_host,
        repeat=args.repeat,
        store_dir=args.store_dir,
    )
    print(json.dumps(res, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())

"""Roofline table (assignment §Roofline): reads the dry-run result JSONs and
emits one row per (arch × shape × mesh) with the three terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and per-device memory.

Run ``python -m repro.launch.dryrun`` first (results/dryrun). If a frozen
baseline exists (results/dryrun_baseline), a before/after delta column is
added for cells whose terms changed — the §Perf audit trail.
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")
BASELINE = "results/dryrun_baseline"


def _load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*", "*.json")):
        r = json.load(open(f))
        if r.get("ok"):
            out[(r["mesh"], r["arch"], r["shape"])] = r
    return out


def run(quick: bool = False):
    cur = _load(RESULTS)
    base = _load(BASELINE) if os.path.isdir(BASELINE) else {}
    rows = []
    for key in sorted(cur):
        r = cur[key]
        t = r["terms"]
        bound_ms = t["bound_s"] * 1e3
        derived = (
            f"compute_ms={t['compute_s']*1e3:.2f};memory_ms={t['memory_s']*1e3:.2f};"
            f"collective_ms={t['collective_s']*1e3:.2f};dominant={t['dominant']};"
            f"useful={t['useful_ratio']:.3f};roofline={t['roofline_fraction']:.4f};"
            f"gib_per_dev={r['memory']['per_device_total']/2**30:.2f}"
        )
        b = base.get(key)
        if b and abs(b["terms"]["bound_s"] - t["bound_s"]) / max(b["terms"]["bound_s"], 1e-12) > 0.02:
            derived += f";baseline_bound_ms={b['terms']['bound_s']*1e3:.2f}"
            derived += f";speedup={b['terms']['bound_s']/max(t['bound_s'],1e-12):.2f}x"
        rows.append((f"roofline/{key[0]}/{key[1]}/{key[2]}", bound_ms * 1e3, derived))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))

"""Incremental-maps benchmark: grow a fitted map and prove it didn't jump.

Exercises the whole ``partial_fit`` pipeline at benchmark size — base fit
→ place/admit/patch/refine/version — and emits the two things CI gates:

* **stage walls** (``stages.*.wall_s``): the incremental path must stay
  incremental — a regression to refit-scale cost gates like any other
  wall via ``benchmarks/check_regression.py``.
* **map-quality scores** (``scores.*_score``): gated as *floors* —
  ``stability_score`` (k-neighborhood overlap of the old rows between the
  previous and grown map, :func:`repro.metrics.map_stability`) and
  ``np_old_score`` (neighborhood preservation of the old rows' original
  vectors in the grown map). ``np_joint_score`` — the same metric for a
  full refit of X ∥ Y — is reported beside them so the committed baseline
  records how close incremental comes to the refit yardstick.

  PYTHONPATH=src python benchmarks/partial_fit.py --quick --json BENCH_partial_fit.json
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import time

import numpy as np


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=20_000, help="base corpus rows")
    ap.add_argument("--append", type=int, default=2_000, help="rows to grow by")
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--clusters", type=int, default=32)
    ap.add_argument("--neighbors", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=12, help="base-fit epochs")
    ap.add_argument("--refine-epochs", type=int, default=3)
    ap.add_argument("--components", type=int, default=16, help="mixture modes")
    ap.add_argument("--k", type=int, default=10, help="metric neighborhood size")
    ap.add_argument("--queries", type=int, default=1_000, help="metric queries")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true", help="CI size")
    ap.add_argument("--json", default="", help="write BENCH_partial_fit.json here")
    return ap.parse_args(argv)


def _mixture(n, dim, components, seed):
    rng = np.random.default_rng(seed)
    centers = np.random.default_rng(9).normal(0, 5, (components, dim))
    labels = rng.integers(0, components, n)
    return (centers[labels] + rng.normal(0, 1, (n, dim))).astype(np.float32)


def build_report(args) -> dict:
    from repro.configs.base import NomadConfig
    from repro.core.nomad import NomadProjection
    from repro.metrics import map_stability, neighborhood_preservation

    if args.quick:
        args.n, args.append = min(args.n, 2_000), min(args.append, 300)
        args.dim, args.clusters = min(args.dim, 16), min(args.clusters, 16)
        args.neighbors, args.epochs = min(args.neighbors, 8), min(args.epochs, 8)
        args.queries = min(args.queries, 800)

    x = _mixture(args.n, args.dim, args.components, args.seed + 1)
    y = _mixture(args.append, args.dim, args.components, args.seed + 2)

    def cfg_for(n, ckdir=""):
        return NomadConfig(
            n_points=n,
            dim=args.dim,
            n_clusters=args.clusters,
            n_neighbors=args.neighbors,
            n_epochs=args.epochs,
            partial_refine_epochs=args.refine_epochs,
            strategy="local",
            build_strategy="local",
            seed=args.seed,
            checkpoint_dir=ckdir,
        )

    ckdir = tempfile.mkdtemp(prefix="bench-partial-fit-")
    try:
        t0 = time.time()
        est = NomadProjection(cfg_for(args.n, ckdir))
        base = est.fit(x)
        fit_base_s = time.time() - t0

        pf = est.partial_fit(y)

        t1 = time.time()
        joint = NomadProjection(cfg_for(args.n + args.append)).fit(
            np.concatenate([x, y])
        )
        fit_joint_s = time.time() - t1

        mk = dict(k=args.k, n_queries=args.queries, seed=args.seed)
        stability = map_stability(base.embedding, pf.embedding[: args.n], **mk)
        np_old = neighborhood_preservation(x, pf.embedding[: args.n], **mk)
        np_joint = neighborhood_preservation(x, joint.embedding[: args.n], **mk)
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)

    stages = {"fit_base": {"wall_s": round(fit_base_s, 3)}}
    for name in ("place", "admit", "patch_knn", "patch_rows", "refine", "version"):
        if name in pf.stage_s:
            stages[name] = {"wall_s": round(pf.stage_s[name], 3)}
    stages["partial_fit_total"] = {"wall_s": round(pf.wall_time_s, 3)}
    stages["fit_joint"] = {"wall_s": round(fit_joint_s, 3)}

    return {
        "benchmark": "partial_fit",
        "config": {
            "n": args.n,
            "append": args.append,
            "dim": args.dim,
            "clusters": args.clusters,
            "neighbors": args.neighbors,
            "epochs": args.epochs,
            "refine_epochs": args.refine_epochs,
            "metric_k": args.k,
            "metric_queries": args.queries,
        },
        "admission": {
            "n_split_cells": pf.n_split_cells,
            "n_new_cells": pf.n_new_cells,
            "n_affected_cells": int(pf.affected_cells.size),
            "version": pf.version,
        },
        "stages": stages,
        # *_score leaves are FLOOR-gated by check_regression.py: a fresh
        # score below baseline - slack fails, a faster wall never does
        "scores": {
            "stability_score": round(stability, 4),
            "np_old_score": round(np_old, 4),
            "np_joint_score": round(np_joint, 4),
        },
    }


def run(quick: bool = False):
    """benchmarks.run entry: [(name, us_per_call, derived), …]."""
    args = parse_args(["--quick"] if quick else [])
    report = build_report(args)
    rows = [
        (f"partial_fit.{name}", d["wall_s"] * 1e6, "")
        for name, d in report["stages"].items()
    ]
    sc = report["scores"]
    rows.append(
        (
            "partial_fit.scores",
            0.0,
            f"stability={sc['stability_score']:.3f} "
            f"np_old={sc['np_old_score']:.3f} "
            f"np_joint={sc['np_joint_score']:.3f}",
        )
    )
    return rows


def main(argv=None) -> int:
    args = parse_args(argv)
    report = build_report(args)
    print(f"{'stage':>18}  wall_s")
    for name, d in report["stages"].items():
        print(f"{name:>18}  {d['wall_s']:.3f}")
    for name, v in report["scores"].items():
        print(f"{name:>18}  {v:.4f}")
    a = report["admission"]
    print(
        f"admission: {a['n_split_cells']} split(s), {a['n_new_cells']} new "
        f"cell(s), {a['n_affected_cells']} affected"
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print("report →", args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Flagship-scale benchmark: an end-to-end synthetic map across processes.

The paper's flagship artifact (a map of Multilingual Wikipedia) is the
scale this repo has been growing toward: a corpus too big for one host's
RAM, indexed and fit across processes. This driver reproduces that shape
synthetically, end to end:

1. **generate** — ``gaussian_mixture_store`` streams an (N, D) corpus
   chunk-by-chunk into a sharded on-disk store; no (N, D) array ever
   exists in any process.
2. **distributed map** — spawns P worker processes of
   ``python -m repro.launch.distributed`` against a local coordinator.
   Each worker reads only its own devices' row ranges of the store (the
   ``"distributed"`` index build), and the fit's collectives cross
   process boundaries on one global mesh.
3. **collect** — merges every worker's ``--stats`` JSON (per-stage walls
   + peak RSS per process) into one machine-readable report.

  # CI smoke (2 processes, N=200k):
  PYTHONPATH=src python benchmarks/flagship.py --n 200000 --processes 2 \
      --epochs 3 --json BENCH_flagship.json

  # flagship runbook (N >= 10M): see README "Scaling across hosts".
  PYTHONPATH=src python benchmarks/flagship.py --n 10000000 --dim 64 \
      --processes 4 --clusters 512 --epochs 20 \
      --store-dir /data/flagship-store --keep-store --json BENCH_flagship.json

Report layout: gated stage walls (max over processes — the straggler
defines the wall) live under ``stages.*.wall_s`` so
``benchmarks/check_regression.py`` picks them up; the per-process detail
(``peak_rss_mb``, ``stage_seconds``) deliberately avoids the ``wall_s``
key so per-process jitter never trips the gate.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# stages a worker reports, in pipeline order (fit/total appended last)
BUILD_STAGES = ("place", "kmeans", "assign", "permute", "knn")


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument(
        "--host-devices", type=int, default=1,
        help="CPU devices per process (XLA host-platform simulation)",
    )
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--clusters", type=int, default=0, help="0 = workload default")
    ap.add_argument("--neighbors", type=int, default=0)
    ap.add_argument("--workload", default="nomad_quickstart")
    ap.add_argument("--components", type=int, default=32, help="mixture modes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gen-chunk-rows", type=int, default=65_536)
    ap.add_argument(
        "--store-dir", default="",
        help="corpus store location (default: a fresh temp dir)",
    )
    ap.add_argument(
        "--keep-store", action="store_true",
        help="leave the generated store on disk (reuse across runs)",
    )
    ap.add_argument("--work-dir", default="", help="stats/scratch dir")
    ap.add_argument("--json", default="", help="write BENCH_flagship.json here")
    ap.add_argument("--timeout", type=int, default=3600, help="worker wall cap (s)")
    return ap.parse_args(argv)


def _generate(args) -> tuple:
    """Chunk-streamed corpus → sharded store; returns (store_dir, wall_s)."""
    from repro.data.store import ShardedStore
    from repro.data.synthetic import gaussian_mixture_store

    store_dir = args.store_dir or os.path.join(args.work_dir, "corpus")
    meta = os.path.join(store_dir, "meta.json")
    t0 = time.time()
    if os.path.exists(meta):
        st = ShardedStore(store_dir)
        if st.shape == (args.n, args.dim):
            print(f"generate: reusing {store_dir} {st.shape}", flush=True)
            return store_dir, 0.0
        raise SystemExit(
            f"--store-dir {store_dir} holds a {st.shape} store, "
            f"want ({args.n}, {args.dim}) — point at a fresh dir"
        )
    gaussian_mixture_store(
        store_dir,
        args.n,
        args.dim,
        n_components=args.components,
        seed=args.seed,
        chunk_rows=args.gen_chunk_rows,
    )
    wall = time.time() - t0
    print(f"generate: ({args.n}, {args.dim}) → {store_dir} in {wall:.1f}s", flush=True)
    return store_dir, wall


def _spawn(args, store_dir: str) -> tuple:
    """Run the P-process map; returns (per-process stats list, wall_s)."""
    stats_base = os.path.join(args.work_dir, "stats.json")
    cmd = [
        sys.executable, "-m", "repro.launch.distributed",
        "--spawn", str(args.processes),
        "--host-devices", str(args.host_devices),
        "--store", store_dir,
        "--epochs", str(args.epochs),
        "--stats", stats_base,
    ]
    if args.clusters:
        cmd += ["--clusters", str(args.clusters)]
    if args.neighbors:
        cmd += ["--neighbors", str(args.neighbors)]
    if args.workload != "nomad_quickstart":
        cmd += ["--workload", args.workload]
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    print("spawn:", " ".join(cmd), flush=True)
    t0 = time.time()
    proc = subprocess.run(cmd, env=env, timeout=args.timeout)
    wall = time.time() - t0
    if proc.returncode != 0:
        raise SystemExit(f"distributed map failed (rc {proc.returncode})")
    root, ext = os.path.splitext(stats_base)
    paths = (
        [stats_base]
        if args.processes == 1
        else [f"{root}.p{i}{ext}" for i in range(args.processes)]
    )
    stats = []
    for p in paths:
        with open(p) as f:
            stats.append(json.load(f))
    return stats, wall


def build_report(args, gen_wall: float, map_wall: float, stats: list) -> dict:
    """Gated ``stages.*.wall_s`` (max over processes) + per-process detail."""
    stages = {"generate": {"wall_s": round(gen_wall, 3)}}
    for name in (*BUILD_STAGES, "fit", "total"):
        walls = [s["stage_seconds"].get(name) for s in stats]
        walls = [w for w in walls if w is not None]
        if walls:
            stages[name] = {"wall_s": round(max(walls), 3)}
    stages["map_end_to_end"] = {"wall_s": round(map_wall, 3)}
    return {
        "benchmark": "flagship",
        "config": {
            "n": args.n,
            "dim": args.dim,
            "processes": args.processes,
            "host_devices": args.host_devices,
            "epochs": args.epochs,
            "workload": args.workload,
        },
        "stages": stages,
        "per_process": [
            {
                "process": s["process"],
                "local_devices": s["local_devices"],
                "peak_rss_mb": round(float(s["peak_rss_mb"]), 1),
                "stage_seconds": {
                    k: round(float(v), 3) for k, v in s["stage_seconds"].items()
                },
            }
            for s in sorted(stats, key=lambda s: s["process"])
        ],
    }


def _run_report(args) -> dict:
    """generate → spawn → collect, returning the report dict."""
    if not args.work_dir:
        import tempfile

        args.work_dir = tempfile.mkdtemp(prefix="flagship-")
    os.makedirs(args.work_dir, exist_ok=True)

    store_dir, gen_wall = _generate(args)
    try:
        stats, map_wall = _spawn(args, store_dir)
    finally:
        if not (args.keep_store or args.store_dir):
            import shutil

            shutil.rmtree(store_dir, ignore_errors=True)
    return build_report(args, gen_wall, map_wall, stats)


def run(quick: bool = False):
    """benchmarks.run entry: [(name, us_per_call, derived), …].

    Spawns worker subprocesses; in an environment where that is not
    possible (no free ports, sandboxed exec) the failure surfaces as
    :class:`benchmarks.run.SuiteSkipped` so the harness reports *why*
    the suite produced no rows instead of failing the whole run.
    """
    from benchmarks.run import SuiteSkipped

    argv = ["--processes", "2", "--timeout", "1200"]
    argv += (
        ["--n", "20000", "--dim", "16", "--clusters", "16", "--epochs", "2"]
        if quick
        else ["--n", "200000", "--epochs", "3"]
    )
    try:
        report = _run_report(parse_args(argv))
    except (SystemExit, OSError, subprocess.SubprocessError) as e:
        raise SuiteSkipped(f"multi-process spawn unavailable: {e}") from e
    rows = [
        (f"flagship.{name}", d["wall_s"] * 1e6, "")
        for name, d in report["stages"].items()
    ]
    for p in report["per_process"]:
        rows.append(
            (
                f"flagship.p{p['process']}",
                0.0,
                f"peak_rss_mb={p['peak_rss_mb']:.0f}",
            )
        )
    return rows


def main(argv=None) -> int:
    args = parse_args(argv)
    report = _run_report(args)
    print(f"{'stage':>14}  wall_s")
    for name, d in report["stages"].items():
        print(f"{name:>14}  {d['wall_s']:.3f}")
    for p in report["per_process"]:
        print(
            f"process {p['process']}: peak RSS {p['peak_rss_mb']:.0f} MB, "
            f"{p['local_devices']} local device(s)"
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print("report →", args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Service load test: N concurrent clients vs the in-process service.

The serving-layer acceptance benchmark: fits a small map once, stands up
the full service stack (registry → cache → batching engine → MapServer),
then drives it with concurrent client threads issuing ragged ``/project``
requests. Per client-count scenario it reports request p50/p99 wall,
throughput (rows/s), the batching engine's batch-fill ratio, and cache
hits:

  PYTHONPATH=src python benchmarks/service_load.py --json BENCH_service_load.json
  PYTHONPATH=src python benchmarks/service_load.py --n-fit 1500 --clusters 8 \
      --epochs 3 --clients 1,8 --requests 20 --rows 24

Two transports:

* ``core`` (default) — clients call ``MapService.project`` directly; the
  dependency-free path every install can run, and the one the committed
  baseline (``benchmarks/baselines/service_load.json``) gates via
  ``benchmarks/check_regression.py``;
* ``http`` — the same requests through the FastAPI app over httpx's
  in-process ASGI transport (needs the ``[service]`` extra); measures the
  marshalling overhead on top of the core numbers.

CI's ``service`` job smoke-runs both at tiny N on every push and gates
the core walls against the baseline (>25% AND ≥0.25s regression fails).
"""

from __future__ import annotations

import argparse
import json
import threading
import time


def _client_requests(n_requests, rows, dim, seed, cache_frac):
    """One client's request schedule: mostly unique queries, a
    ``cache_frac`` fraction repeating the first one (cache exercise)."""
    from repro.data.synthetic import gaussian_mixture

    reqs = []
    for i in range(n_requests):
        if i > 0 and cache_frac > 0 and (i % max(1, round(1 / cache_frac))) == 0:
            reqs.append(reqs[0])  # identical (query, seed) → service cache hit
        else:
            q, _ = gaussian_mixture(
                max(1, rows + (i % 5) - 2), dim, n_components=4, seed=seed + i
            )
            reqs.append((q, seed + i))
    return reqs


def _drive(project, clients, n_requests, rows, dim, cache_frac, timeout=120.0):
    """Run the client storm; returns (per-request walls, total wall)."""
    walls = [[] for _ in range(clients)]
    errs = []
    start = threading.Barrier(clients + 1)

    def run(c):
        try:
            reqs = _client_requests(n_requests, rows, dim, 10_000 * (c + 1), cache_frac)
            start.wait()
            for q, seed in reqs:
                t0 = time.time()
                project(q, seed)
                walls[c].append(time.time() - t0)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=run, args=(c,)) for c in range(clients)]
    for t in threads:
        t.start()
    start.wait()
    t0 = time.time()
    for t in threads:
        t.join(timeout)
    total = time.time() - t0
    if errs:
        raise errs[0]
    flat = [w for ws in walls for w in ws]
    if len(flat) != clients * n_requests:
        raise RuntimeError(f"dropped requests: {len(flat)}/{clients * n_requests}")
    return flat, total


def bench(
    n_fit=20_000,
    dim=64,
    clusters=16,
    neighbors=15,
    epochs=10,
    steps=24,
    microbatch=256,
    max_delay_s=0.002,
    clients_list=(1, 8, 32),
    n_requests=30,
    rows=64,
    cache_frac=0.25,
    transport="core",
    seed=0,
):
    from repro.configs.base import NomadConfig
    from repro.core.nomad import NomadProjection
    from repro.data.synthetic import gaussian_mixture
    from repro.serve import FrozenMap, TransformResult
    from repro.service import MapService

    cfg = NomadConfig(
        n_points=n_fit,
        dim=dim,
        n_clusters=clusters,
        n_neighbors=neighbors,
        n_epochs=epochs,
        batch_size=min(1024, n_fit),
        transform_steps=steps,
        serve_microbatch=microbatch,
        service_max_delay_s=max_delay_s,
        seed=seed,
    )
    x, _ = gaussian_mixture(n_fit, dim, n_components=min(12, clusters), seed=seed)
    est = NomadProjection(cfg)
    t0 = time.time()
    est.fit(x)
    fit_s = time.time() - t0
    frozen = FrozenMap.from_fit(est._fit_result, cfg)

    out = {
        "n_fit": n_fit,
        "dim": dim,
        "clusters": clusters,
        "transform_steps": steps,
        "microbatch": microbatch,
        "max_delay_s": max_delay_s,
        "requests_per_client": n_requests,
        "rows_per_request": rows,
        "cache_frac": cache_frac,
        "transport": transport,
        "fit_s": fit_s,
        "clients": {},
    }
    for clients in clients_list:
        # a fresh stack per scenario: counters and cache start cold
        svc = MapService()
        handle = svc.registry.add(frozen)  # warm: compile paid before timing

        if transport == "core":
            def project(q, s, _svc=svc):
                _svc.project(q, seed=s)
        elif transport == "http":
            from fastapi.testclient import TestClient

            from repro.service.app import create_app

            client = TestClient(create_app(svc))

            def project(q, s, _c=client):
                r = _c.post("/project", json={"rows": q.tolist(), "seed": int(s)})
                r.raise_for_status()
        else:
            raise ValueError(f"unknown transport {transport!r}")

        walls, total = _drive(project, clients, n_requests, rows, dim, cache_frac)
        stats = handle.batcher.stats
        p50 = TransformResult.percentile(walls, 50)
        p99 = TransformResult.percentile(walls, 99)
        out["clients"][f"c{clients}"] = {
            # "wall_s" is the stage-wall key check_regression.py gates on
            "wall_s": p50,
            "p50_s": p50,
            "p99_s": p99,
            "requests_per_s": float(len(walls) / total),
            "device_rows_per_s": float(stats.n_rows / total),
            "batch_fill": stats.batch_fill,
            "n_batches": stats.n_batches,
            "n_requests": stats.n_requests,
            "cache_hits": svc.cache.stats()["hits"],
            "scenario_wall_s": total,
        }
        svc.close()
    return out


def run(quick: bool = False):
    """benchmarks/run.py contract: [(name, us_per_call, derived), …]."""
    res = bench(
        n_fit=1500 if quick else 20_000,
        dim=16 if quick else 64,
        clusters=8 if quick else 16,
        neighbors=5 if quick else 15,
        epochs=3 if quick else 10,
        steps=8 if quick else 24,
        microbatch=64 if quick else 256,
        clients_list=(1, 8) if quick else (1, 8, 32),
        n_requests=10 if quick else 30,
        rows=24 if quick else 64,
    )
    return [
        (
            f"service/load_{name}",
            r["p50_s"] * 1e6,
            f"p99={r['p99_s'] * 1e3:.1f}ms {r['requests_per_s']:.0f}req/s "
            f"fill={r['batch_fill']:.2f} hits={r['cache_hits']}",
        )
        for name, r in res["clients"].items()
    ]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-fit", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=16)
    ap.add_argument("--neighbors", type=int, default=15)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--microbatch", type=int, default=256)
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="batching engine coalescing deadline")
    ap.add_argument("--clients", default="1,8,32", help="comma-separated client counts")
    ap.add_argument("--requests", type=int, default=30, help="requests per client")
    ap.add_argument("--rows", type=int, default=64, help="rows per request (±2 jitter)")
    ap.add_argument("--cache-frac", type=float, default=0.25,
                    help="fraction of repeated (cache-hitting) requests")
    ap.add_argument("--transport", default="core", choices=["core", "http"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="", help="write the report to this path")
    args = ap.parse_args()

    res = bench(
        n_fit=args.n_fit,
        dim=args.dim,
        clusters=args.clusters,
        neighbors=args.neighbors,
        epochs=args.epochs,
        steps=args.steps,
        microbatch=args.microbatch,
        max_delay_s=args.max_delay_ms / 1e3,
        clients_list=tuple(int(c) for c in args.clients.split(",")),
        n_requests=args.requests,
        rows=args.rows,
        cache_frac=args.cache_frac,
        transport=args.transport,
        seed=args.seed,
    )
    print(json.dumps(res, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())

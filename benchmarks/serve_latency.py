"""Serve-path latency benchmark: batch size vs p50/p99 placement latency.

The first inference-side hot path: fits a small map once, freezes it, then
times ``MapServer.transform`` across microbatch sizes — per-batch wall
clocks give p50/p99 placement latency and throughput (points/s).

  PYTHONPATH=src python benchmarks/serve_latency.py --json BENCH_serve_latency.json
  PYTHONPATH=src python benchmarks/serve_latency.py --n-fit 1500 --clusters 8 \
      --epochs 3 --batches 64,256 --repeat 3

CI smoke-runs this at tiny N on every push and gates the recorded walls
against ``benchmarks/baselines/serve_latency.json`` via
``benchmarks/check_regression.py`` (>25% regression fails the job).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def bench(
    n_fit=20_000,
    dim=64,
    clusters=16,
    neighbors=15,
    epochs=10,
    batch_sizes=(64, 256, 1024),
    repeat=5,
    steps=24,
    strategy="auto",
    seed=0,
):
    from repro.configs.base import NomadConfig
    from repro.core.nomad import NomadProjection
    from repro.data.synthetic import gaussian_mixture

    cfg = NomadConfig(
        n_points=n_fit,
        dim=dim,
        n_clusters=clusters,
        n_neighbors=neighbors,
        n_epochs=epochs,
        batch_size=min(1024, n_fit),
        transform_steps=steps,
        serve_strategy=strategy,
        seed=seed,
    )
    x, _ = gaussian_mixture(n_fit, dim, n_components=min(12, clusters), seed=seed)
    est = NomadProjection(cfg)
    t0 = time.time()
    est.fit(x)
    fit_s = time.time() - t0

    out = {
        "n_fit": n_fit,
        "dim": dim,
        "clusters": clusters,
        "neighbors": neighbors,
        "transform_steps": steps,
        "fit_s": fit_s,
        "batch": {},
    }
    for bs in batch_sizes:
        server = est.map_server(microbatch=bs)
        q, _ = gaussian_mixture(
            bs * server.n_shards, dim, n_components=min(12, clusters), seed=seed + 1
        )
        from repro.serve import TransformResult

        server.transform(q, seed=seed)  # warm-up: pays the jit compile
        lats = []
        for r in range(max(1, repeat)):
            res = server.transform(q, seed=seed + r)
            lats.extend(res.batch_latency_s)
        # pooled across repeats through the shared TransformResult helper —
        # the same percentile math res.p50_latency_s uses per call
        p50 = TransformResult.percentile(lats, 50)
        p99 = TransformResult.percentile(lats, 99)
        out["batch"][str(bs)] = {
            # "wall_s" is the stage-wall key check_regression.py gates on
            "wall_s": p50,
            "p50_s": p50,
            "p99_s": p99,
            "points_per_s": float(len(q) / p50),
            "n_runs": len(lats),
            "strategy": server.strategy,
            "n_shards": server.n_shards,
        }
    return out


def run(quick: bool = False):
    """benchmarks/run.py contract: [(name, us_per_call, derived), …]."""
    res = bench(
        n_fit=1500 if quick else 20_000,
        dim=16 if quick else 64,
        clusters=8 if quick else 16,
        neighbors=5 if quick else 15,
        epochs=3 if quick else 10,
        batch_sizes=(64, 256) if quick else (64, 256, 1024),
        repeat=3 if quick else 5,
        steps=8 if quick else 24,
    )
    return [
        (
            f"serve/transform_b{bs}",
            r["p50_s"] * 1e6,
            f"p99={r['p99_s'] * 1e3:.1f}ms tput={r['points_per_s']:.0f}pts/s "
            f"({r['strategy']})",
        )
        for bs, r in res["batch"].items()
    ]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-fit", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=16)
    ap.add_argument("--neighbors", type=int, default=15)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--batches", default="64,256,1024", help="comma-separated")
    ap.add_argument("--repeat", type=int, default=5, help="timed transforms per batch size")
    ap.add_argument("--steps", type=int, default=24, help="frozen NOMAD steps per query")
    ap.add_argument("--strategy", default="auto", choices=["auto", "local", "sharded"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="", help="write the report to this path")
    args = ap.parse_args()

    res = bench(
        n_fit=args.n_fit,
        dim=args.dim,
        clusters=args.clusters,
        neighbors=args.neighbors,
        epochs=args.epochs,
        batch_sizes=tuple(int(b) for b in args.batches.split(",")),
        repeat=args.repeat,
        steps=args.steps,
        strategy=args.strategy,
        seed=args.seed,
    )
    print(json.dumps(res, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())

"""Benchmark harness entry point (assignment deliverable d).

One module per paper artifact; each exposes ``run(quick) -> [(name,
us_per_call, derived), …]`` and this driver prints the combined CSV.

  PYTHONPATH=src python -m benchmarks.run            # full
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized
  PYTHONPATH=src python -m benchmarks.run --only fig3,kernels
"""

from __future__ import annotations

import argparse
import sys
import traceback

SUITES = {
    "fig3": "benchmarks.fig3_speed_quality",  # paper Figure 3
    "table1": "benchmarks.table1_pubmed",  # paper Table 1
    "fig4": "benchmarks.fig4_multiscale",  # paper Figures 1 & 4
    "roofline": "benchmarks.roofline_table",  # assignment §Roofline
    "kernels": "benchmarks.kernel_micro",  # Pallas kernels
    "index_build": "benchmarks.index_build",  # §3.2 device build vs seed host
    "serve": "benchmarks.serve_latency",  # out-of-sample transform latency
    "service_load": "benchmarks.service_load",  # HTTP-service concurrency gate
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="", help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    import importlib

    print("name,us_per_call,derived")
    failed = []
    for key, mod_name in SUITES.items():
        if key not in only:
            continue
        try:
            mod = importlib.import_module(mod_name)
            for name, us, derived in mod.run(quick=args.quick):
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001 — report and continue the suite
            failed.append(key)
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness entry point (assignment deliverable d).

One module per paper artifact; each exposes ``run(quick) -> [(name,
us_per_call, derived), …]`` and this driver prints the combined CSV.

  PYTHONPATH=src python -m benchmarks.run            # full
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized
  PYTHONPATH=src python -m benchmarks.run --only fig3,kernels
  PYTHONPATH=src python -m benchmarks.run --quick --all --json BENCH_run.json

``--json`` additionally writes one consolidated machine-readable report:
per-suite wall seconds, the row tuples, and the traceback tail of any
suite that failed (``--all`` is an explicit alias for the every-suite
default, so CI invocations read as intent rather than omission).

Every known suite appears in the output exactly once: suites excluded by
``--only`` and suites that raise :class:`SuiteSkipped` (e.g. ``flagship``
where multi-process spawn is unavailable) are listed with their skip
reason rather than silently omitted — a missing line in a benchmark
report should always say why.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

SUITES = {
    "fig3": "benchmarks.fig3_speed_quality",  # paper Figure 3
    "table1": "benchmarks.table1_pubmed",  # paper Table 1
    "fig4": "benchmarks.fig4_multiscale",  # paper Figures 1 & 4
    "roofline": "benchmarks.roofline_table",  # assignment §Roofline
    "kernels": "benchmarks.kernel_micro",  # Pallas kernels
    "index_build": "benchmarks.index_build",  # §3.2 device build vs seed host
    "serve": "benchmarks.serve_latency",  # out-of-sample transform latency
    "service_load": "benchmarks.service_load",  # HTTP-service concurrency gate
    "flagship": "benchmarks.flagship",  # multi-process end-to-end map
    "partial_fit": "benchmarks.partial_fit",  # incremental growth + stability
    "pipeline": "benchmarks.pipeline",  # embed→store→fit→inverse→explore
}


class SuiteSkipped(RuntimeError):
    """Raised by a suite's ``run()`` when its prerequisites are absent.

    Distinct from failure: the harness records the reason, prints it, and
    exits 0 — but never drops the suite from the report.
    """


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="", help="comma-separated suite names")
    ap.add_argument(
        "--all", action="store_true",
        help="run every suite (the default; mutually exclusive with --only)",
    )
    ap.add_argument(
        "--json", default="",
        help="write a consolidated per-suite report (BENCH_run.json)",
    )
    args = ap.parse_args()
    if args.all and args.only:
        ap.error("--all and --only are mutually exclusive")
    only = set(args.only.split(",")) if args.only else set(SUITES)
    unknown = sorted(only - set(SUITES))
    if unknown:
        ap.error(f"unknown suite(s) {unknown} — have {sorted(SUITES)}")

    import importlib

    print("name,us_per_call,derived")
    failed = []
    report: dict = {"benchmark": "run", "quick": bool(args.quick), "suites": {}}
    for key, mod_name in SUITES.items():
        entry: dict = {"module": mod_name}
        if key not in only:
            entry["skipped"] = f"not selected (--only {args.only})"
            print(f"# skip {key}: {entry['skipped']}", flush=True)
            report["suites"][key] = entry
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            rows = []
            for name, us, derived in mod.run(quick=args.quick):
                print(f"{name},{us:.1f},{derived}", flush=True)
                rows.append({"name": name, "us_per_call": float(us), "derived": derived})
            entry["rows"] = rows
        except SuiteSkipped as e:
            entry["skipped"] = str(e)
            print(f"# skip {key}: {e}", flush=True)
        except Exception:  # noqa: BLE001 — report and continue the suite
            failed.append(key)
            entry["error"] = traceback.format_exc(limit=8)
            traceback.print_exc(file=sys.stderr)
        entry["wall_s"] = round(time.time() - t0, 3)
        report["suites"][key] = entry
    report["failed"] = failed
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# report → {args.json}", file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Registry-driven Pallas kernel micro-benchmarks.

Enumerates :mod:`repro.kernels.registry` — every registered kernel is timed
on its declared ``bench_shapes`` working point, Pallas path vs jnp oracle
at equal shapes. On CPU the Pallas path runs in interpret mode, so these
wall-times track correctness-path overhead, not TPU performance — the TPU
story is the dry-run roofline; this harness exists to catch algorithmic
regressions and so that *new* kernels get timed the moment they register.

  PYTHONPATH=src python benchmarks/kernel_micro.py            # run + CSV
  PYTHONPATH=src python benchmarks/kernel_micro.py --list     # enumerate
  PYTHONPATH=src python benchmarks/kernel_micro.py --autotune # sweep grids
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

from repro.kernels import autotune, registry


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def _shape_label(sig) -> str:
    """Lossless: one dims-group per argument — "1024x256-1024x256"."""
    return "-".join("x".join(str(d) for d in shape) for shape, _dt in sig)


def run(quick: bool = False):
    """[(name, us_per_call, derived), …] — one pallas + one oracle row per
    registered kernel (benchmarks/run.py contract)."""
    del quick  # bench_shapes are already CI-sized
    rows = []
    for name in registry.names():
        spec = registry.get(name)
        args = spec.make_inputs(jax.random.key(0), spec.bench_shapes)
        label = _shape_label(spec.bench_shapes)
        if spec.pallas is not None:
            tiles = spec.tiles_for_backend(registry.backend())
            mode = "interpret" if registry.interpret_default() else "compiled"
            pallas_fn = lambda *a: spec.pallas(*a, tiles=tiles, interpret=registry.interpret_default())
            rows.append((f"kernel/{name}_{label}", _time(pallas_fn, *args), mode))
        rows.append((f"kernel/{name}_ref", _time(jax.jit(spec.ref), *args), "oracle"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true", help="enumerate registry kernels")
    ap.add_argument("--autotune", action="store_true", help="sweep each kernel's tile grid")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    if args.list:
        for name in registry.names():
            spec = registry.get(name)
            print(
                f"{name}: bench={_shape_label(spec.bench_shapes)} "
                f"candidates={len(spec.tile_candidates)} "
                f"default_tiles={dict(spec.tiles_for_backend(registry.backend()))}"
            )
        return 0

    if args.autotune:
        # same policy as autotune.tiles_for: interpret-mode wall-times say
        # nothing about Mosaic, so don't poison the shippable cache with
        # them unless the user forces REPRO_AUTOTUNE=1.
        cache = autotune.autotune_enabled()
        for name in registry.names():
            spec = registry.get(name)
            entry = autotune.sweep(spec, spec.bench_shapes)
            if cache and entry.get("us") is not None:
                autotune.record(spec, spec.bench_shapes, entry)
            print(f"{name}: winner={entry['tiles']} us={entry.get('us')}")
        if cache:
            print(f"# winners cached at {autotune.cache_path()}")
        else:
            print("# interpret mode: winners NOT cached (REPRO_AUTOTUNE=1 forces)")
        return 0

    for r in run(quick=args.quick):
        print(",".join(str(c) for c in r))
    return 0


if __name__ == "__main__":
    sys.exit(main())

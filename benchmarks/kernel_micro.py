"""Pallas-kernel micro-benchmarks (interpret mode on CPU: these wall-times
track correctness-path overhead, not TPU performance — the TPU story is the
dry-run roofline; this harness exists to catch algorithmic regressions and
to compare kernel vs oracle at equal shapes)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cauchy_mean.ops import cauchy_weighted_sum
from repro.kernels.cauchy_mean.ref import cauchy_weighted_sum_ref
from repro.kernels.kmeans_assign.ops import assign_nearest
from repro.kernels.kmeans_assign.ref import assign_nearest_ref
from repro.kernels.pairwise.ops import pairwise_dist2
from repro.kernels.pairwise.ref import pairwise_dist2_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run(quick: bool = False):
    rows = []
    k1, k2 = jax.random.split(jax.random.key(0))

    x = jax.random.normal(k1, (1024, 256))
    y = jax.random.normal(k2, (1024, 256))
    rows.append(("kernel/pairwise_1024x1024x256", _time(pairwise_dist2, x, y), "interpret"))
    rows.append(("kernel/pairwise_ref", _time(jax.jit(pairwise_dist2_ref), x, y), "oracle"))

    B, K = 2048, 2048
    th = jax.random.normal(k1, (B, 2))
    mu = jax.random.normal(k2, (K, 2))
    w = jnp.ones((K,))
    own = jnp.zeros((B,), jnp.int32)
    rows.append(("kernel/cauchy_mean_2048x2048", _time(cauchy_weighted_sum, th, mu, w, own), "interpret"))
    rows.append(
        ("kernel/cauchy_mean_ref", _time(jax.jit(cauchy_weighted_sum_ref), th, mu, w, own), "oracle")
    )

    xs = jax.random.normal(k1, (4096, 128))
    cs = jax.random.normal(k2, (256, 128))
    rows.append(("kernel/kmeans_assign_4096x256", _time(assign_nearest, xs, cs), "interpret"))
    rows.append(("kernel/kmeans_assign_ref", _time(jax.jit(assign_nearest_ref), xs, cs), "oracle"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))

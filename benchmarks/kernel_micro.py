"""Registry-driven Pallas kernel micro-benchmarks + fused-step comparison.

Enumerates :mod:`repro.kernels.registry` — every registered kernel is timed
on its declared ``bench_shapes`` working point, Pallas path vs jnp oracle
at equal shapes. Kernels with a ``cost_model`` get achieved-vs-roofline
columns (GFLOP/s, GB/s, fraction of the v5e roofline bound — on CPU these
fractions are tiny by construction; the TPU peaks are the fixed reference
frame, so the numbers stay comparable across machines).

``step_compare`` times the production question behind the fusion: ONE
jitted SGD step through the fused ``nomad_step`` dispatch vs the same
mathematics as SEPARATE jitted registry passes (gather | mean term |
contrastive grad | mean-term VJP | scatter) with a host sync — an HBM
round-trip on device — between each. That layout is what a non-fused
registry forces, and the fused step must beat it on any backend.

  PYTHONPATH=src python benchmarks/kernel_micro.py             # run + CSV
  PYTHONPATH=src python benchmarks/kernel_micro.py --list      # enumerate
  PYTHONPATH=src python benchmarks/kernel_micro.py --autotune  # sweep grids
  PYTHONPATH=src python benchmarks/kernel_micro.py --report    # per-candidate roofline
  PYTHONPATH=src python benchmarks/kernel_micro.py --json out.json  # regression gate
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.kernels import autotune, registry
from repro.roofline.analysis import kernel_roofline


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def _shape_label(sig) -> str:
    """Lossless: one dims-group per argument — "1024x256-1024x256"."""
    return "-".join("x".join(str(d) for d in shape) for shape, _dt in sig)


def _roofline_cols(spec, us):
    """" gflops=… gbs=… bound=… roofline_frac=…" or "" without a cost model."""
    if spec.cost_model is None or us is None:
        return "", None
    cost = spec.cost_model(spec.bench_shapes)
    rl = kernel_roofline(cost["flops"], cost["bytes"], us)
    txt = (
        f" gflops={rl['gflops']:.2f} gbs={rl['gbs']:.2f}"
        f" bound={rl['bound']} roofline_frac={rl['roofline_frac']:.2e}"
    )
    return txt, rl


def run(quick: bool = False):
    """[(name, us_per_call, derived), …] — one pallas + one oracle row per
    registered kernel (benchmarks/run.py contract), then the step compare."""
    rows = []
    for name in registry.names():
        spec = registry.get(name)
        args = spec.make_inputs(jax.random.key(0), spec.bench_shapes)
        label = _shape_label(spec.bench_shapes)
        if spec.pallas is not None:
            tiles = spec.tiles_for_backend(registry.backend())
            mode = "interpret" if registry.interpret_default() else "compiled"
            pallas_fn = lambda *a: spec.pallas(*a, tiles=tiles, interpret=registry.interpret_default())
            us = _time(pallas_fn, *args)
            cols, _ = _roofline_cols(spec, us)
            rows.append((f"kernel/{name}_{label}", us, mode + cols))
        rows.append((f"kernel/{name}_ref", _time(jax.jit(spec.ref), *args), "oracle"))
    rows.extend(step_compare(quick=quick))
    return rows


# ---------------------------------------------------------------------------
# Fused step vs multi-pass step
# ---------------------------------------------------------------------------


def step_compare(
    n_points: int = 50_000,
    batch: int = 4096,
    k: int = 15,
    s_neg: int = 16,
    n_cells: int = 64,
    d: int = 2,
    reps: int = 5,
    quick: bool = False,
):
    """Time one NOMAD SGD step, fused vs staged, at N ≥ 50k.

    Both variants run the backend's production implementation (registry
    ``impl=None`` → auto), so the measured gap is pure *structure*: one
    compiled computation vs five dispatches with a host sync (device: an
    HBM round-trip) between every pair.
    """
    if quick:
        n_points, batch = 50_000, 2048
    keys = jax.random.split(jax.random.key(0), 8)
    theta = jax.random.normal(keys[0], (n_points, d), jnp.float32)
    rows_i = jax.random.randint(keys[1], (batch,), 0, n_points)
    pos_rows = jax.random.randint(keys[2], (batch, k), 0, n_points)
    neg_rows = jax.random.randint(keys[3], (batch, s_neg), 0, n_points)
    pos_w = jax.random.uniform(keys[4], (batch, k), jnp.float32)
    means = jax.random.normal(keys[5], (n_cells, d), jnp.float32)
    cell_w = jax.random.uniform(keys[6], (n_cells,), jnp.float32)
    own = jax.random.randint(keys[7], (batch,), 0, n_cells)
    neg_w = jnp.full((batch, s_neg), 1.0 / s_neg, jnp.float32)
    lr = 0.05
    impl = None  # auto: jnp on CPU, pallas on TPU/GPU — same for both variants

    @jax.jit
    def fused_step(theta):
        th_i, th_pos, th_neg = theta[rows_i], theta[pos_rows], theta[neg_rows]

        def loss_fn(ti, tp, tn):
            return jnp.mean(
                losses.nomad_step_term(ti, tp, pos_w, tn, neg_w, means, cell_w, own, impl)
            )

        loss, (g_i, g_pos, g_neg) = jax.value_and_grad(loss_fn, (0, 1, 2))(
            th_i, th_pos, th_neg
        )
        theta = theta.at[rows_i].add(-lr * g_i)
        theta = theta.at[pos_rows.reshape(-1)].add(-lr * g_pos.reshape(-1, d))
        theta = theta.at[neg_rows.reshape(-1)].add(-lr * g_neg.reshape(-1, d))
        return theta, loss

    # --- the same math as separate jitted registry passes -----------------
    gather = jax.jit(lambda th: (th[rows_i], th[pos_rows], th[neg_rows]))
    mean_fwd = jax.jit(lambda ti: losses.nomad_mean_term(ti, means, cell_w, own, impl))

    def _contrastive(ti, tp, tn, mt):
        return losses.contrastive_loss(ti, tp, pos_w, mt, tn, neg_w)

    contrastive_vg = jax.jit(jax.value_and_grad(_contrastive, (0, 1, 2, 3)))

    def _mean_vjp(ti, g_mt):
        _, vjp = jax.vjp(lambda t: losses.nomad_mean_term(t, means, cell_w, own, impl), ti)
        return vjp(g_mt)[0]

    mean_vjp = jax.jit(_mean_vjp)

    @jax.jit
    def scatter(theta, g_i, g_pos, g_neg):
        theta = theta.at[rows_i].add(-lr * g_i)
        theta = theta.at[pos_rows.reshape(-1)].add(-lr * g_pos.reshape(-1, d))
        theta = theta.at[neg_rows.reshape(-1)].add(-lr * g_neg.reshape(-1, d))
        return theta

    def multipass_step(theta):
        th_i, th_pos, th_neg = jax.block_until_ready(gather(theta))
        m_tilde = jax.block_until_ready(mean_fwd(th_i))
        loss, (g_i, g_pos, g_neg, g_mt) = jax.block_until_ready(
            contrastive_vg(th_i, th_pos, th_neg, m_tilde)
        )
        g_i = g_i + jax.block_until_ready(mean_vjp(th_i, g_mt))
        theta = jax.block_until_ready(scatter(theta, g_i, g_pos, g_neg))
        return theta, loss

    us_fused = _time(fused_step, theta, reps=reps)
    us_multi = _time(lambda th: multipass_step(th), theta, reps=reps)
    speedup = us_multi / us_fused if us_fused > 0 else float("inf")
    label = f"N{n_points}_B{batch}"
    return [
        (f"step/nomad_fused_{label}", us_fused, "one jitted step (fused dispatch)"),
        (f"step/nomad_multipass_{label}", us_multi, "5 jitted stages + host sync"),
        (f"step/nomad_fused_speedup_{label}", speedup, "multipass_us / fused_us (x)"),
    ]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _report():
    """Per-candidate sweep with achieved-vs-roofline columns."""
    for name in registry.names():
        spec = registry.get(name)
        if spec.pallas is None:
            print(f"{name}: jnp-only (no tile grid)")
            continue
        entry = autotune.sweep(spec, spec.bench_shapes, report=True)
        cost = spec.cost_model(spec.bench_shapes) if spec.cost_model else None
        print(f"{name} @ {_shape_label(spec.bench_shapes)} (winner {entry['tiles']}):")
        for cand in entry.get("candidates", []):
            line = f"  tiles={cand['tiles']} us={cand['us']:.1f}"
            if cost:
                rl = kernel_roofline(cost["flops"], cost["bytes"], cand["us"])
                line += (
                    f" gflops={rl['gflops']:.2f} gbs={rl['gbs']:.2f}"
                    f" bound={rl['bound']} roofline_us={rl['roofline_us']:.3f}"
                    f" roofline_frac={rl['roofline_frac']:.2e}"
                )
            print(line)


def _json_report(rows) -> dict:
    """wall_s-leaved layout for benchmarks/check_regression.py."""
    out = {"kernels": {}, "step": {}}
    for name, us, derived in rows:
        group, _, leaf = name.partition("/")
        if "speedup" in leaf:
            out["step"][leaf] = {"x": us, "note": derived}
            continue
        bucket = out["kernels"] if group == "kernel" else out["step"]
        bucket[leaf] = {"wall_s": us * 1e-6, "us": us, "note": derived}
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true", help="enumerate registry kernels")
    ap.add_argument("--autotune", action="store_true", help="sweep each kernel's tile grid")
    ap.add_argument(
        "--report", action="store_true", help="sweep + achieved-vs-roofline per candidate"
    )
    ap.add_argument("--json", metavar="PATH", help="write wall_s report for the CI gate")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    if args.list:
        for name in registry.names():
            spec = registry.get(name)
            print(
                f"{name}: bench={_shape_label(spec.bench_shapes)} "
                f"candidates={len(spec.tile_candidates)} "
                f"default_tiles={dict(spec.tiles_for_backend(registry.backend()))} "
                f"cost_model={'yes' if spec.cost_model else 'no'}"
            )
        return 0

    if args.report:
        _report()
        return 0

    if args.autotune:
        # same policy as autotune.tiles_for: interpret-mode wall-times say
        # nothing about Mosaic, so don't poison the shippable cache with
        # them unless the user forces REPRO_AUTOTUNE=1.
        cache = autotune.autotune_enabled()
        for name in registry.names():
            spec = registry.get(name)
            if spec.pallas is None:
                continue
            entry = autotune.sweep(spec, spec.bench_shapes)
            if cache and entry.get("us") is not None:
                autotune.record(spec, spec.bench_shapes, entry)
            print(f"{name}: winner={entry['tiles']} us={entry.get('us')}")
        if cache:
            print(f"# winners cached at {autotune.cache_path()}")
        else:
            print("# interpret mode: winners NOT cached (REPRO_AUTOTUNE=1 forces)")
        return 0

    rows = run(quick=args.quick)
    for r in rows:
        print(",".join(str(c) for c in r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(_json_report(rows), f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Figure-3 analogue: speed vs quality across methods and epoch budgets.

The paper sweeps training epochs for NOMAD vs GPU t-SNE/UMAP on ArXiv and
ImageNet embeddings, reporting NP@10 and random-triplet accuracy. Offline we
use the synthetic embedding-like corpus and compare:

* ``nomad``        — the paper's method (single device),
* ``nomad-8shard`` — 8 simulated devices (the multi-GPU trade-off claim:
  similar/better NP, slight RTA cost from partition approximation),
* ``infonc``       — the exact InfoNC-t-SNE loss (what t-SNE-CUDA-class
  methods optimise; no mean approximation).

Emits CSV rows ``name,us_per_call,derived`` where us_per_call is wall-time
per epoch and ``derived`` packs NP@10 / RTA at the final epoch.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture
from repro.metrics import neighborhood_preservation, random_triplet_accuracy

N, DIM = 8000, 64


def _cfg(**kw):
    base = dict(
        n_points=N, dim=DIM, n_clusters=16, n_neighbors=15, n_noise=32,
        n_exact_negatives=8, batch_size=1024, n_epochs=30,
        strategy="local",  # both methods on one device — apples to apples
    )
    base.update(kw)
    return NomadConfig(**base)


def run(quick: bool = False):
    rows = []
    x, _ = gaussian_mixture(N, DIM, n_components=12, seed=0)
    sweep = (10, 40) if quick else (10, 40, 160, 400)

    from repro.index.ann import build_index

    index = build_index(x, _cfg())

    from repro.kernels import registry

    for method in ("nomad", "infonc"):
        for epochs in sweep:
            cfg = _cfg(n_epochs=epochs, n_noise=64, method=method)
            # which path the fused step took (jnp on CPU, pallas on TPU/GPU)
            impl = registry.resolve("nomad_step", cfg.resolved_kernel_impl())
            res = NomadProjection(cfg).fit(x, index=index)
            per_epoch = (
                float(np.mean(res.epoch_times[1:]))
                if len(res.epoch_times) > 1
                else res.epoch_times[0]
            )
            np10 = neighborhood_preservation(x, res.embedding, k=10, n_queries=500)
            rta = random_triplet_accuracy(x, res.embedding, 10_000)
            rows.append(
                (f"fig3/{method}@{epochs}ep", per_epoch * 1e6,
                 f"np10={np10:.4f};rta={rta:.4f};epochs={epochs};impl={impl}")
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))

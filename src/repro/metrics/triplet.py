"""Random triplet accuracy (paper §4, following Wang et al. [27]):
probability that a random triplet keeps its pairwise-distance ordering
between the high- and low-dimensional spaces."""

from __future__ import annotations

import numpy as np


def random_triplet_accuracy(
    x_high: np.ndarray, x_low: np.ndarray, n_triplets: int = 20_000, seed: int = 0
) -> float:
    n = x_high.shape[0]
    rng = np.random.default_rng(seed)
    i = rng.integers(0, n, n_triplets)
    j = rng.integers(0, n, n_triplets)
    k = rng.integers(0, n, n_triplets)
    ok = (i != j) & (j != k) & (i != k)
    i, j, k = i[ok], j[ok], k[ok]

    def d2(x, a, b):
        diff = x[a].astype(np.float32) - x[b].astype(np.float32)
        return np.sum(diff * diff, axis=-1)

    hi = d2(x_high, i, j) < d2(x_high, i, k)
    lo = d2(x_low, i, j) < d2(x_low, i, k)
    return float(np.mean(hi == lo))

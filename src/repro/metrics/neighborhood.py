"""Neighborhood preservation @ k (paper §4): mean overlap of k-neighborhoods
between the high- and low-dimensional spaces.

For large N the metric is evaluated on a uniform subsample of query points,
with neighbors searched over the full dataset in blocks (exact, not ANN —
the metric must not inherit the index's approximation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _topk_neighbors(queries: jnp.ndarray, data: jnp.ndarray, k: int, block: int = 8192):
    """Exact k nearest neighbors of each query (excluding identical index).

    queries: (Q, d) rows drawn from data at indices ``q_idx`` handled by the
    caller masking; here we exclude self-matches by distance==0 demotion.
    """
    q2 = jnp.sum(jnp.square(queries), -1)[:, None]

    best_d = jnp.full((queries.shape[0], k), jnp.inf, jnp.float32)
    best_i = jnp.full((queries.shape[0], k), -1, jnp.int32)
    n = data.shape[0]
    for start in range(0, n, block):
        db = data[start : start + block]
        d2 = q2 + jnp.sum(jnp.square(db), -1)[None, :] - 2.0 * queries @ db.T
        d2 = jnp.maximum(d2, 0.0)
        cat_d = jnp.concatenate([best_d, d2], axis=1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(jnp.arange(start, start + db.shape[0], dtype=jnp.int32)[None, :], d2.shape)],
            axis=1,
        )
        neg_d, idx = jax.lax.top_k(-cat_d, k)
        best_d = -neg_d
        best_i = jnp.take_along_axis(cat_i, idx, axis=1)
    return best_i


def neighborhood_preservation(
    x_high: np.ndarray,
    x_low: np.ndarray,
    k: int = 10,
    n_queries: int = 2000,
    seed: int = 0,
) -> float:
    """NP@k in [0, 1]. Self-neighbors are excluded (k+1 then drop self)."""
    n = x_high.shape[0]
    rng = np.random.default_rng(seed)
    q_idx = rng.choice(n, size=min(n_queries, n), replace=False)
    xh = jnp.asarray(x_high, jnp.float32)
    xl = jnp.asarray(x_low, jnp.float32)

    def knn_no_self(data, qi):
        nbrs = _topk_neighbors(data[qi], data, k + 1)
        out = np.asarray(nbrs)
        cleaned = np.empty((len(qi), k), np.int64)
        for r, (row, self_i) in enumerate(zip(out, qi)):
            row = row[row != self_i][:k]
            cleaned[r, : len(row)] = row
            if len(row) < k:  # duplicate points: pad with -2 (never matches)
                cleaned[r, len(row) :] = -2
        return cleaned

    hi = knn_no_self(xh, q_idx)
    lo = knn_no_self(xl, q_idx)
    overlap = [
        len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(hi, lo)
    ]
    return float(np.mean(overlap))


def map_stability(
    emb_prev: np.ndarray,
    emb_new: np.ndarray,
    k: int = 10,
    n_queries: int = 2000,
    seed: int = 0,
) -> float:
    """Map-stability score in [0, 1]: how much a map *moved* under an update.

    Both arguments are embeddings of the **same rows in the same order** —
    the previous map version and the new one restricted to the rows both
    contain (after ``partial_fit`` of M appended rows, pass
    ``new_embedding[:N_old]``). The score is the k-neighborhood overlap
    between the two low-dimensional spaces: 1.0 means every old row kept
    exactly its old neighbors (the map did not jump), 0.0 means no
    neighborhood survived. It is the same exact blocked kNN machinery as
    :func:`neighborhood_preservation` with the previous embedding standing
    in for the high-dimensional space.

    Applying one row permutation to *both* embeddings leaves the score
    unchanged whenever every row is queried (``n_queries >= n``); with a
    query subsample the sampled row *ids* differ under permutation, so
    exact invariance holds only at full coverage (tested that way).
    """
    emb_prev = np.asarray(emb_prev)
    emb_new = np.asarray(emb_new)
    if emb_prev.shape[0] != emb_new.shape[0]:
        raise ValueError(
            f"map_stability compares the same rows across versions: got "
            f"{emb_prev.shape[0]} previous vs {emb_new.shape[0]} new rows — "
            "slice the grown embedding to the shared prefix first"
        )
    return neighborhood_preservation(
        emb_prev, emb_new, k=k, n_queries=n_queries, seed=seed
    )

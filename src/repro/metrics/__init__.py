from repro.metrics.neighborhood import map_stability, neighborhood_preservation
from repro.metrics.triplet import random_triplet_accuracy

__all__ = ["map_stability", "neighborhood_preservation", "random_triplet_accuracy"]

from repro.metrics.neighborhood import neighborhood_preservation
from repro.metrics.triplet import random_triplet_accuracy

__all__ = ["neighborhood_preservation", "random_triplet_accuracy"]

"""Step functions: train (fwd+bwd+update, microbatched), prefill, decode.

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every input of the step selected by the shape cell — the dry-run lowers
against these, so nothing is allocated.

Batch conventions (labels are pre-shifted targets):
  LM / MoE / SSM / hybrid: {"tokens": (B,S) i32, "labels": (B,S) i32}
  audio (HuBERT):          {"embeds": (B,S,D), "labels": (B,S) i32}
  VLM (InternVL2):         {"tokens": (B,S−P) i32, "patches": (B,P,D),
                            "labels": (B,S−P) i32}   (P = n_vision_patches)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm
from repro.models.layers import dtype_of


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE; logits fp32 (B, S, V), labels (B, S) int32."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def make_loss_fn(cfg: ArchConfig):
    def loss_fn(params, batch):
        logits, aux, _ = lm.forward(
            params,
            cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            patches=batch.get("patches"),
        )
        if cfg.family == "vlm":  # loss on text positions only
            logits = logits[:, cfg.n_vision_patches :, :]
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + MOE_AUX_COEF * aux if cfg.n_experts else ce
        return loss, {"ce": ce, "moe_aux": aux}

    return loss_fn


MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Train step (with gradient accumulation)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, optimizer, *, microbatched: bool = False):
    """``microbatched=True``: the batch arrives pre-split (accum, micro, …) —
    the production path (reshaping a dp-sharded batch dim would make XLA
    insert all-gathers; the host loader emits the split layout directly)."""
    loss_fn = make_loss_fn(cfg)
    accum = max(cfg.accum_steps, 1)
    acc_dt = dtype_of(cfg.grad_accum_dtype)

    def train_step(params, opt_state, batch):
        if accum == 1:
            if microbatched:
                batch = jax.tree.map(lambda x: x[0], batch)
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            if microbatched:
                micro = batch
            else:
                micro = jax.tree.map(
                    lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                    batch,
                )

            def micro_step(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, lsum), _ = jax.lax.scan(micro_step, (gz, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / accum, gsum)
            loss = lsum / accum
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        logits, _, cache = lm.forward(
            params,
            cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            patches=batch.get("patches"),
            with_cache=not cfg.encoder_only,
        )
        # serving wants the last-position logits + the cache for decode
        return logits[:, -1:, :], cache

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, token):
        return lm.decode_step(params, cfg, cache, token)

    return decode_step


# ---------------------------------------------------------------------------
# Input specs (dry-run)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(
    cfg: ArchConfig, shape: ShapeConfig, *, with_labels: bool, microbatched: bool = False
) -> dict:
    B, S = shape.global_batch, shape.seq_len
    cd = dtype_of(cfg.compute_dtype)
    lead: tuple = ()
    if microbatched and shape.kind == "train" and cfg.accum_steps > 1:
        assert B % cfg.accum_steps == 0, (B, cfg.accum_steps)
        lead = (cfg.accum_steps,)
        B = B // cfg.accum_steps
    spec: dict[str, Any] = {}
    if cfg.family == "audio":
        spec["embeds"] = _sds(lead + (B, S, cfg.d_model), cd)
    elif cfg.family == "vlm":
        P = cfg.n_vision_patches
        spec["tokens"] = _sds(lead + (B, S - P), jnp.int32)
        spec["patches"] = _sds(lead + (B, P, cfg.d_model), cd)
    else:
        spec["tokens"] = _sds(lead + (B, S), jnp.int32)
    if with_labels:
        lab_len = S - cfg.n_vision_patches if cfg.family == "vlm" else S
        spec["labels"] = _sds(lead + (B, lab_len), jnp.int32)
    return spec


def cache_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def input_specs(cfg: ArchConfig, shape: ShapeConfig, optimizer=None) -> tuple:
    """Positional ShapeDtypeStruct args for the step of this shape cell."""
    params = lm.abstract_params(cfg)
    if shape.kind == "train":
        assert optimizer is not None
        opt_state = jax.eval_shape(optimizer.init, params)
        return (
            params,
            opt_state,
            batch_specs(cfg, shape, with_labels=True, microbatched=True),
        )
    if shape.kind == "prefill":
        return (params, batch_specs(cfg, shape, with_labels=False))
    # decode
    token = _sds((shape.global_batch, 1), jnp.int32)
    return (params, cache_specs(cfg, shape), token)

"""Model composition for all assigned architectures.

One code path per *family topology*:

* homogeneous decoder (dense / moe / ssm): ``lax.scan`` over L identical
  layers with stacked parameters;
* hybrid (Jamba): ``lax.scan`` over M = L/8 meta-blocks, each an unrolled
  [attention, mamba×7] stack with MoE on odd positions (1:7 interleave,
  MoE every second layer);
* encoder (HuBERT): bidirectional homogeneous stack over stub frame
  embeddings, untied classification head;
* VLM (InternVL2): stub patch embeddings prepended to text embeddings,
  causal LM over the combined sequence.

All entry points are pure functions; ``init_params`` composes with
``jax.eval_shape`` for the allocation-free dry-run.

Cache layout (decode):
  ``{"k": (L,B,Sc,kv,hd), "v": …, "pos": (Sc,), "idx": scalar,
     "ssm_h": (L,B,H,P,N), "ssm_conv": (L,B,w-1,cd)}``
with the unused members absent per family. For SWA archs (mixtral) the cache
is a ring buffer of ``min(seq_len, window)`` slots; ``pos`` stores absolute
positions so masking works across wraps.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import dtype_of, init_embedding, init_linear, init_swiglu, rms_norm, swiglu

MOE_AUX_COEF = 0.01

# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------
# XLA's sharding propagation solves a global assignment; without anchors it
# sometimes replicates the batch to simplify an embedding gather (measured:
# 16× activation blow-up on phi4 train_4k). The launcher pins activations to
# (batch axes, None, None) here; tests/CPU runs leave it unset (no-op).

_ACT_BATCH_AXES: "tuple | None" = None


def set_activation_sharding(batch_axes) -> None:
    """batch_axes: mesh axis (or tuple) for the batch dim, or None to clear."""
    global _ACT_BATCH_AXES
    _ACT_BATCH_AXES = batch_axes


def _shard_act(x):
    if _ACT_BATCH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(_ACT_BATCH_AXES, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def _init_homogeneous_layer(key, cfg: ArchConfig, is_moe: bool, is_attn: bool) -> dict:
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg.param_dtype)
    layer: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if is_attn:
        layer["attn"] = attn_lib.init_attention(ks[0], cfg)
    else:
        layer["ssm"] = ssm_lib.init_ssm(ks[0], cfg)
    if cfg.d_ff:
        layer["ln2"] = jnp.ones((cfg.d_model,), dt)
        if is_moe:
            layer["moe"] = moe_lib.init_moe(ks[1], cfg)
        else:
            layer["mlp"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt)
    return layer


def _init_meta_block(key, cfg: ArchConfig) -> dict:
    """One Jamba meta-block: pos 0 = attention, pos 1..7 = mamba.

    MLP at every position; MoE on odd positions (1,3,5,7), dense on even.
    """
    P = cfg.attn_period  # 8
    dt = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    n_mamba = P - 1
    n_moe = sum(1 for i in range(P) if i % cfg.moe_period == cfg.moe_offset)
    n_dense = P - n_moe
    mamba_keys = jax.random.split(keys[0], n_mamba)
    moe_keys = jax.random.split(keys[1], n_moe)
    dense_keys = jax.random.split(keys[2], n_dense)
    D = cfg.d_model
    return {
        "attn_ln": jnp.ones((D,), dt),
        "attn": attn_lib.init_attention(keys[3], cfg),
        "mamba_ln": jnp.ones((n_mamba, D), dt),
        "mamba": jax.vmap(lambda k: ssm_lib.init_ssm(k, cfg))(mamba_keys),
        "moe_ln": jnp.ones((n_moe, D), dt),
        "moe": jax.vmap(lambda k: moe_lib.init_moe(k, cfg))(moe_keys),
        "dense_ln": jnp.ones((n_dense, D), dt),
        "dense": jax.vmap(lambda k: init_swiglu(k, D, cfg.d_ff, dt))(dense_keys),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    dt = dtype_of(cfg.param_dtype)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    params: dict[str, Any] = {}
    if cfg.family == "audio":
        # stub frontend supplies frame embeddings; no token embedding table
        params["in_ln"] = jnp.ones((cfg.d_model,), dt)
        params["head"] = init_linear(k_head, cfg.d_model, cfg.vocab_padded, dt)
    else:
        params["embed"] = init_embedding(k_emb, cfg.vocab_padded, cfg.d_model, dt)
    if cfg.family == "hybrid":
        M = cfg.n_layers // cfg.attn_period
        keys = jax.random.split(k_layers, M)
        params["blocks"] = jax.vmap(lambda k: _init_meta_block(k, cfg))(keys)
    else:
        is_moe = cfg.layer_is_moe(0)
        is_attn = cfg.layer_is_attention(0)
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_homogeneous_layer(k, cfg, is_moe, is_attn)
        )(keys)
    params["final_ln"] = jnp.ones((cfg.d_model,), dt)
    return params


def abstract_params(cfg: ArchConfig) -> dict:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# Embedding in / logits out
# ---------------------------------------------------------------------------


def embed_in(params, cfg: ArchConfig, tokens=None, embeds=None, patches=None):
    cd = dtype_of(cfg.compute_dtype)
    if cfg.family == "audio":
        return rms_norm(embeds.astype(cd), params["in_ln"])
    x = params["embed"][tokens].astype(cd)
    if cfg.family == "vlm":
        x = jnp.concatenate([patches.astype(cd), x], axis=1)
    return _shard_act(x)


def logits_out(params, cfg: ArchConfig, x):
    if cfg.family == "audio":
        logits = (x @ params["head"]).astype(jnp.float32)
    else:
        logits = (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:  # inert pad columns
        neg = jnp.asarray(-1e30, jnp.float32)
        pad_ok = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(pad_ok, logits, neg)
    return logits


# ---------------------------------------------------------------------------
# Sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _homogeneous_body(cfg: ArchConfig, positions, causal, with_cache):
    def body(carry, lp):
        x, aux = carry
        h = rms_norm(x, lp["ln1"])
        cache_out = ()
        if "attn" in lp:
            a, (k, v) = attn_lib.attention_block(lp["attn"], h, positions, cfg, causal=causal)
            x = x + a
            if with_cache:
                cache_out = (k, v)
        else:
            a, st = ssm_lib.ssm_block(lp["ssm"], h, cfg)
            x = x + a
            if with_cache:
                cache_out = (st.h, st.tail_x, st.tail_b, st.tail_c)
        if cfg.d_ff:
            h = rms_norm(x, lp["ln2"])
            if "moe" in lp:
                y, moe_aux = moe_lib.moe_block(lp["moe"], h, cfg)
                aux = aux + moe_aux
            else:
                y = swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
            x = x + y
        return (_shard_act(x), aux), cache_out

    return body


def _meta_block_body(cfg: ArchConfig, positions, causal, with_cache):
    P = cfg.attn_period

    def mlp_at(x, bp, pos, counters, aux):
        moe_i, dense_i = counters
        if pos % cfg.moe_period == cfg.moe_offset:
            h = rms_norm(x, bp["moe_ln"][moe_i])
            mp = jax.tree.map(lambda a: a[moe_i], bp["moe"])
            y, moe_aux = moe_lib.moe_block(mp, h, cfg)
            return x + y, (moe_i + 1, dense_i), aux + moe_aux
        h = rms_norm(x, bp["dense_ln"][dense_i])
        dp = jax.tree.map(lambda a: a[dense_i], bp["dense"])
        return x + swiglu(h, dp["w_gate"], dp["w_up"], dp["w_down"]), (moe_i, dense_i + 1), aux

    def body(carry, bp):
        x, aux = carry
        # position 0: attention
        h = rms_norm(x, bp["attn_ln"])
        a, (k, v) = attn_lib.attention_block(bp["attn"], h, positions, cfg, causal=causal)
        x = x + a
        counters = (0, 0)
        x, counters, aux = mlp_at(x, bp, 0, counters, aux)
        sts = []
        for pos in range(1, P):
            h = rms_norm(x, bp["mamba_ln"][pos - 1])
            mp = jax.tree.map(lambda a: a[pos - 1], bp["mamba"])
            m, st = ssm_lib.ssm_block(mp, h, cfg)
            x = x + m
            if with_cache:
                sts.append(st)
            x, counters, aux = mlp_at(x, bp, pos, counters, aux)
        cache_out = ()
        if with_cache:
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *sts)
            cache_out = (k, v, stacked.h, stacked.tail_x, stacked.tail_b, stacked.tail_c)
        return (_shard_act(x), aux), cache_out

    return body


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def forward(
    params,
    cfg: ArchConfig,
    tokens=None,
    embeds=None,
    patches=None,
    *,
    with_cache: bool = False,
):
    """Sequence forward. Returns (logits fp32, moe_aux, cache_stacked|None)."""
    x = embed_in(params, cfg, tokens=tokens, embeds=embeds, patches=patches)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    causal = not cfg.encoder_only
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid":
        body = _meta_block_body(cfg, positions, causal, with_cache)
        (x, aux), caches = jax.lax.scan(_maybe_remat(body, cfg), (x, aux0), params["blocks"])
    else:
        body = _homogeneous_body(cfg, positions, causal, with_cache)
        (x, aux), caches = jax.lax.scan(_maybe_remat(body, cfg), (x, aux0), params["layers"])
    x = rms_norm(x, params["final_ln"])
    logits = logits_out(params, cfg, x)
    return logits, aux, (caches if with_cache else None)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _decode_mlp(cfg: ArchConfig, x, bp, pos: int, counters, aux):
    """MLP at meta-block position ``pos`` during decode (mirrors mlp_at)."""
    moe_i, dense_i = counters
    if pos % cfg.moe_period == cfg.moe_offset:
        h = rms_norm(x, bp["moe_ln"][moe_i])
        mp = jax.tree.map(lambda a: a[moe_i], bp["moe"])
        y, moe_aux = moe_lib.moe_block(mp, h, cfg)
        return x + y, (moe_i + 1, dense_i), aux + moe_aux
    h = rms_norm(x, bp["dense_ln"][dense_i])
    dp = jax.tree.map(lambda a: a[dense_i], bp["dense"])
    return x + swiglu(h, dp["w_gate"], dp["w_up"], dp["w_down"]), (moe_i, dense_i + 1), aux


def cache_capacity(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, filled: Optional[int] = None) -> dict:
    """Zero cache with ``filled`` tokens marked valid (default: seq_len − 1)."""
    cd = dtype_of(cfg.compute_dtype)
    Sc = cache_capacity(cfg, seq_len)
    filled = seq_len - 1 if filled is None else filled
    cache: dict[str, Any] = {"idx": jnp.asarray(filled, jnp.int32)}
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.family == "hybrid":
        M = cfg.n_layers // cfg.attn_period
        n_mamba = cfg.attn_period - 1
        cache["k"] = jnp.zeros((M, batch, Sc, kv, hd), cd)
        cache["v"] = jnp.zeros((M, batch, Sc, kv, hd), cd)
        st = ssm_lib.init_ssm_state(cfg, batch)
        for nm, leaf in zip(("ssm_h", "ssm_tx", "ssm_tb", "ssm_tc"), st):
            cache[nm] = jnp.zeros((M, n_mamba) + leaf.shape, leaf.dtype)
    elif cfg.family == "ssm":
        st = ssm_lib.init_ssm_state(cfg, batch)
        for nm, leaf in zip(("ssm_h", "ssm_tx", "ssm_tb", "ssm_tc"), st):
            cache[nm] = jnp.zeros((cfg.n_layers,) + leaf.shape, leaf.dtype)
    else:
        cache["k"] = jnp.zeros((cfg.n_layers, batch, Sc, kv, hd), cd)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, Sc, kv, hd), cd)
    if "k" in cache:
        # absolute position of each slot (ring-aware); −big ⇒ never written
        s = jnp.arange(Sc, dtype=jnp.int32)
        if filled >= Sc:  # ring has wrapped: slot s holds the latest p≡s (mod Sc), p<filled
            pos0 = filled - 1 - ((filled - 1 - s) % Sc)
            valid = jnp.ones((Sc,), bool)
        else:
            pos0 = s
            valid = s < filled
        cache["pos"] = jnp.where(valid, pos0, -(2**30)).astype(jnp.int32)
    return cache


def load_cache_from_prefill(cfg: ArchConfig, cache: dict, stacked, n_tokens: int) -> dict:
    """Copy prefill outputs (scan-stacked per layer) into a decode cache.

    ``stacked`` is the cache tuple ``forward(..., with_cache=True)`` returns;
    ``n_tokens`` is the prefill length. Handles the SWA ring buffer (only
    the last ``Sc`` positions land, at their ring slots).
    """
    import numpy as np

    if cfg.family == "hybrid":
        k, v, hs, txs, tbs, tcs = stacked
        cache = dict(cache)
        cache["k"] = cache["k"].at[:, :, :n_tokens].set(k.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, :n_tokens].set(v.astype(cache["v"].dtype))
        cache.update(ssm_h=hs, ssm_tx=txs, ssm_tb=tbs, ssm_tc=tcs)
    elif cfg.family == "ssm":
        hs, txs, tbs, tcs = stacked
        cache = dict(cache, ssm_h=hs, ssm_tx=txs, ssm_tb=tbs, ssm_tc=tcs)
    else:
        k, v = stacked
        Sc = cache["k"].shape[2]
        cache = dict(cache)
        if n_tokens > Sc:  # ring (SWA): keep the last Sc positions
            sl = np.arange(n_tokens - Sc, n_tokens)
            cache["k"] = cache["k"].at[:, :, sl % Sc].set(k[:, :, sl].astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[:, :, sl % Sc].set(v[:, :, sl].astype(cache["v"].dtype))
        else:
            cache["k"] = cache["k"].at[:, :, :n_tokens].set(k.astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[:, :, :n_tokens].set(v.astype(cache["v"].dtype))
    return cache


def decode_step(params, cfg: ArchConfig, cache: dict, token):
    """One token: token (B, 1) int32 (or (B,1,D) embeds is not supported —
    decode is LM-only). Returns (logits (B,1,V) fp32, new cache)."""
    cd = dtype_of(cfg.compute_dtype)
    B = token.shape[0]
    x = params["embed"][token].astype(cd)
    idx = cache["idx"]
    pos = jnp.broadcast_to(idx, (B, 1)).astype(jnp.int32)

    has_attn_cache = "k" in cache
    if has_attn_cache:
        Sc = cache["k"].shape[2]
        slot = idx % Sc
        new_pos = cache["pos"].at[slot].set(idx)
        valid = jnp.broadcast_to(new_pos >= 0, (B, Sc))

    def attn_step(ap, h, kc, vc):
        q, k_new, v_new = attn_lib.qkv_project(ap, h, pos, cfg)
        kc = kc.at[:, slot, :, :].set(k_new[:, 0])
        vc = vc.at[:, slot, :, :].set(v_new[:, 0])
        out = attn_lib.dispatch_attend_decode(q, kc, vc, pos, jnp.broadcast_to(new_pos, (B, Sc)), valid, window=cfg.sliding_window)
        hm = attn_lib.head_mask(cfg)
        if hm is not None:
            out = out * hm[None, None, :, None].astype(out.dtype)
        return jnp.einsum("bqhe,hed->bqd", out, ap.wo), kc, vc

    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        P = cfg.attn_period

        def body(carry, xs):
            x, aux = carry
            bp, kc, vc, st_stack = xs
            h = rms_norm(x, bp["attn_ln"])
            a, kc, vc = attn_step(bp["attn"], h, kc, vc)
            x = x + a
            counters = (0, 0)
            x, counters, aux = _decode_mlp(cfg, x, bp, 0, counters, aux)
            new_sts = []
            for p_i in range(1, P):
                h = rms_norm(x, bp["mamba_ln"][p_i - 1])
                mp = jax.tree.map(lambda a: a[p_i - 1], bp["mamba"])
                st = jax.tree.map(lambda a: a[p_i - 1], st_stack)
                m, st2 = ssm_lib.ssm_decode_block(mp, h, cfg, st)
                x = x + m
                new_sts.append(st2)
                x, counters, aux = _decode_mlp(cfg, x, bp, p_i, counters, aux)
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_sts)
            return (x, aux), (kc, vc, stacked)

        st_in = ssm_lib.SSMState(
            h=cache["ssm_h"], tail_x=cache["ssm_tx"], tail_b=cache["ssm_tb"], tail_c=cache["ssm_tc"]
        )
        (x, aux), (ks, vs, sts) = jax.lax.scan(
            body, (x, aux0), (params["blocks"], cache["k"], cache["v"], st_in)
        )
        new_cache = dict(
            cache, k=ks, v=vs, ssm_h=sts.h, ssm_tx=sts.tail_x, ssm_tb=sts.tail_b,
            ssm_tc=sts.tail_c, idx=idx + 1, pos=new_pos,
        )
    elif cfg.family == "ssm":

        def body(carry, xs):
            x, aux = carry
            lp, st = xs
            h = rms_norm(x, lp["ln1"])
            m, st2 = ssm_lib.ssm_decode_block(lp["ssm"], h, cfg, st)
            x = x + m
            return (x, aux), st2

        st_in = ssm_lib.SSMState(
            h=cache["ssm_h"], tail_x=cache["ssm_tx"], tail_b=cache["ssm_tb"], tail_c=cache["ssm_tc"]
        )
        (x, aux), sts = jax.lax.scan(body, (x, aux0), (params["layers"], st_in))
        new_cache = dict(
            cache, ssm_h=sts.h, ssm_tx=sts.tail_x, ssm_tb=sts.tail_b, ssm_tc=sts.tail_c,
            idx=idx + 1,
        )
    else:

        def body(carry, xs):
            x, aux = carry
            lp, kc, vc = xs
            h = rms_norm(x, lp["ln1"])
            a, kc, vc = attn_step(lp["attn"], h, kc, vc)
            x = x + a
            if cfg.d_ff:
                h = rms_norm(x, lp["ln2"])
                if "moe" in lp:
                    y, moe_aux = moe_lib.moe_block(lp["moe"], h, cfg)
                    aux = aux + moe_aux
                else:
                    y = swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
                x = x + y
            return (x, aux), (kc, vc)

        (x, aux), (ks, vs) = jax.lax.scan(
            body, (x, aux0), (params["layers"], cache["k"], cache["v"])
        )
        new_cache = dict(cache, k=ks, v=vs, idx=idx + 1, pos=new_pos)

    x = rms_norm(x, params["final_ln"])
    logits = logits_out(params, cfg, x)
    return logits, new_cache

"""Shared model layers: norms, RoPE, SwiGLU, initialisers.

Everything is a pure function over explicit parameter pytrees (no module
framework): ``init_*`` builds parameters from a PRNG key, ``apply``-style
functions consume them. This keeps ``jax.eval_shape`` usable for
allocation-free abstract initialisation in the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32, cast back to the input dtype."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def gated_rms_norm(x: jax.Array, gate: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Mamba-2's gated RMSNorm: ``rmsnorm(x * silu(gate)) * w``."""
    dt = x.dtype
    x = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` (..., S, ..., head_dim) by position-dependent angles.

    ``positions`` has shape broadcastable to x.shape[:-1] minus head axes —
    we pass (B, S) and rely on broadcasting over the head axes between.
    """
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    # insert singleton head axes between S and hd: (B, S, 1, ..., 1, hd/2)
    shape = ang.shape[:-1] + (1,) * (x.ndim - ang.ndim) + ang.shape[-1:]
    ang = ang.reshape(shape)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: ``(silu(x @ w_gate) * (x @ w_up)) @ w_down``."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_swiglu(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def init_linear(key, d_in: int, d_out: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (d_in, d_out)) / np.sqrt(d_in)).astype(dtype)


def init_embedding(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)

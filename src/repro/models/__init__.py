from repro.models import attention, layers, lm, moe, ssm, steps

__all__ = ["attention", "layers", "lm", "moe", "ssm", "steps"]

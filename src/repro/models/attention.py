"""Attention: GQA with RoPE, optional qk-norm and sliding windows.

Weight layout is TP-native: projections are stored head-major —
``wq (D, H, hd)``, ``wk/wv (D, KV, hd)``, ``wo (H, hd, D)`` — so the tensor
axis shards the explicit H dimension and **no sharded dimension is ever
reshaped** (sharded reshapes are where XLA SPMD inserts surprise
collectives). GQA repeats k/v to H heads at use (replicated KV → local
slice; no communication). ``wo`` is row-parallel: the output contraction
over (H, hd) produces the one expected psum per attention block.

Three execution paths, numerically equivalent (tested against each other):

* ``attend_full``     — materialises the (Sq, Sk) score matrix; the oracle.
* ``attend_chunked``  — online-softmax over (q-chunk, kv-chunk) tiles via a
  double ``lax.scan`` (FlashAttention recurrence at the jnp level, so the
  dry-run HLO stays compact and live memory is O(Sq·chunk)).
* ``attend_decode``   — single query against a cache whose length axis may
  be sharded (distributed flash-decode: softmax max/sum lower to small
  all-reduces under pjit).

Layouts: q (B, S, H, hd); k/v (B, S, KV, hd); caches (B, Sc, KV, hd).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, rms_norm

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


class AttnParams(NamedTuple):
    wq: jax.Array  # (D, H, hd)
    wk: jax.Array  # (D, KV, hd)
    wv: jax.Array  # (D, KV, hd)
    wo: jax.Array  # (H, hd, D)
    q_norm: Optional[jax.Array] = None  # (hd,) — qwen3-style qk-norm
    k_norm: Optional[jax.Array] = None  # (hd,)


def init_attention(key, cfg) -> AttnParams:
    from repro.models.layers import dtype_of

    dt = dtype_of(cfg.param_dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    D, hd, KV = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
    Hp = cfg.n_heads_padded  # pad heads live but masked (head_mask)
    s_in = 1.0 / np.sqrt(D)
    s_out = 1.0 / np.sqrt(cfg.n_heads * hd)
    return AttnParams(
        wq=(jax.random.normal(kq, (D, Hp, hd)) * s_in).astype(dt),
        wk=(jax.random.normal(kk, (D, KV, hd)) * s_in).astype(dt),
        wv=(jax.random.normal(kv, (D, KV, hd)) * s_in).astype(dt),
        wo=(jax.random.normal(ko, (Hp, hd, D)) * s_out).astype(dt),
        q_norm=jnp.ones((hd,), dt) if cfg.qk_norm else None,
        k_norm=jnp.ones((hd,), dt) if cfg.qk_norm else None,
    )


def head_mask(cfg) -> Optional[jax.Array]:
    """(Hp,) 1/0 mask: within each kv group of g_pad padded q slots, the
    first g are real. Masking attention outputs keeps pad heads inert
    (zero forward contribution AND zero wo gradients)."""
    Hp, H, KV = cfg.n_heads_padded, cfg.n_heads, max(cfg.n_kv_heads, 1)
    if Hp == H:
        return None
    g, g_pad = H // KV, Hp // KV
    return (jnp.arange(Hp) % g_pad < g).astype(jnp.float32)


def qkv_project(p: AttnParams, x: jax.Array, positions: jax.Array, cfg):
    """x (B, S, D) → q (B,S,H,hd), k/v (B,S,KV,hd), RoPE'd and normed."""
    q = jnp.einsum("bsd,dhe->bshe", x, p.wq)
    k = jnp.einsum("bsd,dhe->bshe", x, p.wk)
    v = jnp.einsum("bsd,dhe->bshe", x, p.wv)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm)
        k = rms_norm(k, p.k_norm)
    if not cfg.encoder_only:  # the audio encoder is position-free (stub CNN)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, hd) → (B, S, H, hd) by repeating each kv head H/KV times."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


# ---------------------------------------------------------------------------
# Full (oracle) attention
# ---------------------------------------------------------------------------


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int) -> jax.Array:
    """(…, Sq, Sk) additive bias: 0 where visible, NEG_INF elsewhere."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window > 0:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF)


def attend_full(q, k, v, q_pos, k_pos, *, causal: bool, window: int = 0) -> jax.Array:
    H, hd = q.shape[-2], q.shape[-1]
    k, v = repeat_kv(k, H), repeat_kv(v, H)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhe,bkhe->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    bias = _mask_bias(q_pos, k_pos, causal, window)  # (B, Sq, Sk)
    probs = jax.nn.softmax(scores + bias[:, None, :, :], axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhe->bqhe", probs, v)


# ---------------------------------------------------------------------------
# Chunked (memory-efficient) attention — training / prefill hot path
# ---------------------------------------------------------------------------


def attend_chunked(q, k, v, q_pos, k_pos, *, causal: bool, window: int = 0, chunk: int = 1024):
    """Online-softmax attention; O(Sq·chunk) live memory instead of O(Sq·Sk).

    Outer scan over q chunks, inner scan over kv chunks with the running
    (max, sum, acc) recurrence. Fully-masked tiles still execute (static
    schedule); the roofline carries this ~2× score-FLOP overhead and §Perf
    attacks it.
    """
    B, Sq, H, hd = q.shape
    k, v = repeat_kv(k, H), repeat_kv(v, H)
    Sk = k.shape[1]
    cq, ck = min(chunk, Sq), min(chunk, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, Sk, chunk)
    nq, nk = Sq // cq, Sk // ck
    scale = 1.0 / np.sqrt(hd)

    q_r = q.reshape(B, nq, cq, H, hd).transpose(1, 0, 2, 3, 4)
    qp_r = q_pos.reshape(B, nq, cq).transpose(1, 0, 2)
    k_r = k.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
    v_r = v.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
    kp_r = k_pos.reshape(B, nk, ck).transpose(1, 0, 2)

    def q_step(_, qc):
        qi, qpi = qc

        def kv_step(carry, kc):
            m, l, acc = carry
            ki, vi, kpi = kc
            s = jnp.einsum("bqhe,bkhe->bhqk", qi, ki, preferred_element_type=jnp.float32)
            s = s * scale + _mask_bias(qpi, kpi, causal, window)[:, None, :, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhe->bhqe", p.astype(vi.dtype), vi, preferred_element_type=jnp.float32
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k_r, v_r, kp_r))
        out = acc / jnp.maximum(l, 1e-37)[..., None]  # (B, H, cq, hd)
        return None, out.transpose(0, 2, 1, 3)  # (B, cq, H, hd)

    _, outs = jax.lax.scan(q_step, None, (q_r, qp_r))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# Flash attention (custom VJP): §Perf iteration 2
# ---------------------------------------------------------------------------
# The plain chunked path is memory-optimal FORWARD, but jax AD of the double
# scan stores every (cq, ck) probability tile for the backward — measured
# ~0.9 GiB/layer and the dominant HBM term fleet-wide. This custom VJP stores
# only (out, L = m + log l) per row (FlashAttention-2's residuals) and
# recomputes tiles in the backward, which is also how the TPU kernel would
# behave. Inputs are MHA-shaped (k/v already repeated to H heads); the GQA
# head-sum in the k/v gradient falls out of jax's transpose of repeat_kv.


def _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, chunk):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    cq, ck = min(chunk, Sq), min(chunk, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, Sk, chunk)
    nq, nk = Sq // cq, Sk // ck
    scale = 1.0 / np.sqrt(hd)

    q_r = q.reshape(B, nq, cq, H, hd).transpose(1, 0, 2, 3, 4)
    qp_r = q_pos.reshape(B, nq, cq).transpose(1, 0, 2)
    k_r = k.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
    v_r = v.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
    kp_r = k_pos.reshape(B, nk, ck).transpose(1, 0, 2)

    def q_step(_, qc):
        qi, qpi = qc

        def kv_step(carry, kc):
            m, l, acc = carry
            ki, vi, kpi = kc
            s = jnp.einsum("bqhe,bkhe->bhqk", qi, ki, preferred_element_type=jnp.float32)
            s = s * scale + _mask_bias(qpi, kpi, causal, window)[:, None, :, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhe->bhqe", p.astype(vi.dtype), vi, preferred_element_type=jnp.float32
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k_r, v_r, kp_r))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        L = m + jnp.log(jnp.maximum(l, 1e-37))  # (B, H, cq)
        return None, (out.transpose(0, 2, 1, 3), L)

    _, (outs, Ls) = jax.lax.scan(q_step, None, (q_r, qp_r))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd).astype(v.dtype)
    L = Ls.transpose(1, 2, 0, 3).reshape(B, H, Sq)  # (nq,B,H,cq) → (B,H,Sq)
    return out, L


def _flash_bwd_impl(q, k, v, q_pos, k_pos, out, L, dout, causal, window, chunk):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    cq, ck = min(chunk, Sq), min(chunk, Sk)
    nq, nk = Sq // cq, Sk // ck
    scale = 1.0 / np.sqrt(hd)

    D = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # (B,Sq,H)
    D = D.transpose(0, 2, 1)  # (B, H, Sq)

    q_r = q.reshape(B, nq, cq, H, hd).transpose(1, 0, 2, 3, 4)
    do_r = dout.reshape(B, nq, cq, H, hd).transpose(1, 0, 2, 3, 4)
    qp_r = q_pos.reshape(B, nq, cq).transpose(1, 0, 2)
    L_r = L.reshape(B, H, nq, cq).transpose(2, 0, 1, 3)  # (nq, B, H, cq)
    D_r = D.reshape(B, H, nq, cq).transpose(2, 0, 1, 3)
    k_r = k.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
    v_r = v.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
    kp_r = k_pos.reshape(B, nk, ck).transpose(1, 0, 2)

    def q_step(carry, qc):
        dk, dv = carry  # (nk, B, ck, H, hd) fp32
        qi, doi, qpi, Li, Di = qc

        def kv_step(carry2, kc):
            dq_i = carry2
            j, ki, vi, kpi = kc
            s = jnp.einsum("bqhe,bkhe->bhqk", qi, ki, preferred_element_type=jnp.float32)
            s = s * scale + _mask_bias(qpi, kpi, causal, window)[:, None, :, :]
            p = jnp.exp(s - Li[..., None])  # (B,H,cq,ck)
            dv_j = jnp.einsum("bhqk,bqhe->bkhe", p, doi.astype(jnp.float32))
            dp = jnp.einsum("bqhe,bkhe->bhqk", doi.astype(jnp.float32), vi.astype(jnp.float32))
            ds = p * (dp - Di[..., None]) * scale  # (B,H,cq,ck)
            dq_i = dq_i + jnp.einsum("bhqk,bkhe->bqhe", ds, ki.astype(jnp.float32))
            dk_j = jnp.einsum("bhqk,bqhe->bkhe", ds, qi.astype(jnp.float32))
            return dq_i, (j, dk_j, dv_j)

        dq0 = jnp.zeros((B, cq, H, hd), jnp.float32)
        dq_i, (js, dk_js, dv_js) = jax.lax.scan(
            kv_step, dq0, (jnp.arange(nk), k_r, v_r, kp_r)
        )
        dk = dk + dk_js
        dv = dv + dv_js
        return (dk, dv), dq_i

    dk0 = jnp.zeros((nk, B, ck, H, hd), jnp.float32)
    dv0 = jnp.zeros((nk, B, ck, H, hd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(q_step, (dk0, dv0), (q_r, do_r, qp_r, L_r, D_r))
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd).astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Sk, H, hd).astype(k.dtype)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Sk, H, hd).astype(v.dtype)
    return dq, dk, dv


import functools as _ft


@_ft.lru_cache(maxsize=None)
def _flash_fn(causal: bool, window: int, chunk: int):
    @jax.custom_vjp
    def fa(q, k, v, q_pos, k_pos):
        return _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, chunk)[0]

    def fwd(q, k, v, q_pos, k_pos):
        out, L = _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, chunk)
        return out, (q, k, v, q_pos, k_pos, out, L)

    def bwd(res, dout):
        q, k, v, q_pos, k_pos, out, L = res
        dq, dk, dv = _flash_bwd_impl(
            q, k, v, q_pos, k_pos, out, L, dout, causal, window, chunk
        )
        return dq, dk, dv, None, None

    fa.defvjp(fwd, bwd)
    return fa


def attend_flash(q, k, v, q_pos, k_pos, *, causal: bool, window: int = 0, chunk: int = 1024):
    """Memory-optimal fwd+bwd attention (k/v repeated to H by the caller)."""
    H = q.shape[2]
    k, v = repeat_kv(k, H), repeat_kv(v, H)
    return _flash_fn(causal, window, chunk)(q, k, v, q_pos, k_pos)


# ---------------------------------------------------------------------------
# Decode attention (single query vs. a possibly-sharded cache)
# ---------------------------------------------------------------------------


def _decode_local(q, k_cache, v_cache, q_pos, k_pos, valid, window):
    """Single-shard decode attention → unnormalised (o_partial, m, l)."""
    H, hd = q.shape[-2], q.shape[-1]
    k_cache, v_cache = repeat_kv(k_cache, H), repeat_kv(v_cache, H)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhe,bkhe->bhqk", q, k_cache, preferred_element_type=jnp.float32) * scale
    d = q_pos[:, :, None] - k_pos[:, None, :]  # (B, 1, S)
    ok = (d >= 0) & valid[:, None, :]
    if window > 0:
        ok &= d < window
    s = jnp.where(ok[:, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B, H, 1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bkhe->bqhe", p.astype(v_cache.dtype), v_cache)
    return o, m, l


def attend_decode(q, k_cache, v_cache, q_pos, k_pos, valid, *, window: int = 0):
    """q: (B, 1, H, hd); caches: (B, S, KV, hd); valid: (B, S) bool."""
    o, m, l = _decode_local(q, k_cache, v_cache, q_pos, k_pos, valid, window)
    return o / jnp.maximum(l, 1e-37).transpose(0, 2, 1)[..., None]


# --- distributed flash-decode (§Perf iteration 7) ----------------------------
# Left to global-view pjit, repeat_kv + masking around the sharded cache made
# XLA all-gather the whole KV cache per layer (measured 4 GB of wire per
# layer per token on yi-34b). This shard_map version keeps the cache's
# length shards local and combines (o, m, l) softmax stats — a few KB of
# psum per layer, the textbook flash-decode reduction.

_DECODE_CTX: "tuple | None" = None  # (mesh, batch_axes, s_axes)


def set_decode_context(mesh, batch_axes, s_axes) -> None:
    global _DECODE_CTX
    _DECODE_CTX = None if mesh is None else (mesh, batch_axes, tuple(s_axes))


def attend_decode_sharded(q, k_cache, v_cache, q_pos, k_pos, valid, *, window: int = 0):
    import functools

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, baxes, saxes = _DECODE_CTX

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(
            P(baxes, None, None, None),  # q replicated over the S shards
            P(baxes, saxes, None, None),
            P(baxes, saxes, None, None),
            P(baxes, None),
            P(baxes, saxes),
            P(baxes, saxes),
        ),
        out_specs=P(baxes, None, None, None),
        check_rep=False,
    )
    def block(q, kc, vc, qp, kp, vd):
        o, m, l = _decode_local(q, kc, vc, qp, kp, vd, window)
        g_m = jax.lax.pmax(m, saxes)  # (B, H, 1)
        corr = jnp.exp(m - g_m)
        l = jax.lax.psum(l * corr, saxes)
        o = jax.lax.psum(o * corr.transpose(0, 2, 1)[..., None].astype(o.dtype), saxes)
        return o / jnp.maximum(l, 1e-37).transpose(0, 2, 1)[..., None].astype(o.dtype)

    return block(q, k_cache, v_cache, q_pos, k_pos, valid)


def dispatch_attend_decode(q, k_cache, v_cache, q_pos, k_pos, valid, *, window: int = 0):
    if _DECODE_CTX is not None:
        return attend_decode_sharded(q, k_cache, v_cache, q_pos, k_pos, valid, window=window)
    return attend_decode(q, k_cache, v_cache, q_pos, k_pos, valid, window=window)


def attention_block(p: AttnParams, x, positions, cfg, *, causal: bool):
    """Projection → attention → output projection, for train/prefill."""
    q, k, v = qkv_project(p, x, positions, cfg)
    window = cfg.sliding_window
    if x.shape[1] > cfg.attn_chunk:
        impl = attend_flash if getattr(cfg, "attn_impl", "flash") == "flash" else attend_chunked
        out = impl(q, k, v, positions, positions, causal=causal, window=window, chunk=cfg.attn_chunk)
    else:
        out = attend_full(q, k, v, positions, positions, causal=causal, window=window)
    hm = head_mask(cfg)
    if hm is not None:
        out = out * hm[None, None, :, None].astype(out.dtype)
    y = jnp.einsum("bqhe,hed->bqd", out, p.wo)  # row-parallel: one psum
    return y, (k, v)


def attention_decode_block(p: AttnParams, x, pos, k_cache, v_cache, k_pos, valid, cfg):
    """One decode step. x: (B, 1, D); returns (y, (k_new, v_new))."""
    q, k_new, v_new = qkv_project(p, x, pos, cfg)
    out = dispatch_attend_decode(q, k_cache, v_cache, pos, k_pos, valid, window=cfg.sliding_window)
    hm = head_mask(cfg)
    if hm is not None:
        out = out * hm[None, None, :, None].astype(out.dtype)
    y = jnp.einsum("bqhe,hed->bqd", out, p.wo)
    return y, (k_new, v_new)

"""Mamba-2 (SSD — state-space duality) block.

The sequence path uses the chunked SSD algorithm [arXiv:2405.21060]: within a
chunk the recurrence is computed as a (Q×Q) masked, decay-weighted
"attention" (MXU-friendly batched matmuls); across chunks a ``lax.scan``
carries the (H, P, N) state. One scan iterates per chunk and computes both
the intra-chunk quadratic term and the inter-chunk contribution, so live
memory is O(B·H·Q·Q) and the HLO stays compact for the dry-run.

Decode is the O(1) recurrence ``h ← exp(Δ·A)·h + Δ·B⊗x``.

Sharding note (why the projections are split): the reference Mamba fuses
z/x/B/C/Δ into one ``in_proj`` and slices the output. Slicing a
tensor-sharded dimension at non-shard-aligned offsets makes XLA reshuffle,
so each component has its own projection (mathematically identical), and
the depthwise conv runs per component. The conv tails in the decode state
stay per-component for the same reason (x tail sharded over heads via
d_inner; B/C tails replicated — they are N=128 wide).

Layout: x_heads (B, S, H, P), B/C (B, S, N) (single group), state (B, H, P, N).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import gated_rms_norm


class SSMParams(NamedTuple):
    w_z: jax.Array  # (D, di)
    w_x: jax.Array  # (D, di)
    w_b: jax.Array  # (D, N)
    w_c: jax.Array  # (D, N)
    w_dt: jax.Array  # (D, H)
    conv_x: jax.Array  # (w, di)
    conv_b: jax.Array  # (w, N)
    conv_c: jax.Array  # (w, N)
    conv_bias_x: jax.Array  # (di,)
    conv_bias_b: jax.Array  # (N,)
    conv_bias_c: jax.Array  # (N,)
    A_log: jax.Array  # (H,) fp32
    D: jax.Array  # (H,) fp32
    dt_bias: jax.Array  # (H,) fp32
    norm_w: jax.Array  # (di,)
    w_out: jax.Array  # (di, D)


class SSMState(NamedTuple):
    h: jax.Array  # (B, H, P, N) fp32
    tail_x: jax.Array  # (B, w-1, di)
    tail_b: jax.Array  # (B, w-1, N)
    tail_c: jax.Array  # (B, w-1, N)


def init_ssm(key, cfg) -> SSMParams:
    from repro.models.layers import dtype_of

    dt_ = dtype_of(cfg.param_dtype)
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv
    ks = jax.random.split(key, 9)
    s = 1.0 / np.sqrt(D)
    sw = 1.0 / np.sqrt(w)
    return SSMParams(
        w_z=(jax.random.normal(ks[0], (D, di)) * s).astype(dt_),
        w_x=(jax.random.normal(ks[1], (D, di)) * s).astype(dt_),
        w_b=(jax.random.normal(ks[2], (D, N)) * s).astype(dt_),
        w_c=(jax.random.normal(ks[3], (D, N)) * s).astype(dt_),
        w_dt=(jax.random.normal(ks[4], (D, H)) * s).astype(dt_),
        conv_x=(jax.random.normal(ks[5], (w, di)) * sw).astype(dt_),
        conv_b=(jax.random.normal(ks[6], (w, N)) * sw).astype(dt_),
        conv_c=(jax.random.normal(ks[7], (w, N)) * sw).astype(dt_),
        conv_bias_x=jnp.zeros((di,), dt_),
        conv_bias_b=jnp.zeros((N,), dt_),
        conv_bias_c=jnp.zeros((N,), dt_),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        D=jnp.ones((H,), jnp.float32),
        dt_bias=jnp.log(jnp.expm1(jnp.full((H,), 1e-2))).astype(jnp.float32),  # softplus⁻¹
        norm_w=jnp.ones((di,), dt_),
        w_out=(jax.random.normal(ks[8], (di, D)) / np.sqrt(di)).astype(dt_),
    )


def init_ssm_state(cfg, batch: int) -> SSMState:
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    w = cfg.ssm_conv
    return SSMState(
        h=jnp.zeros((batch, H, P, N), jnp.float32),
        tail_x=jnp.zeros((batch, w - 1, di), jnp.float32),
        tail_b=jnp.zeros((batch, w - 1, N), jnp.float32),
        tail_c=jnp.zeros((batch, w - 1, N), jnp.float32),
    )


def _causal_conv(u: jax.Array, w: jax.Array, bias: jax.Array, tail):
    """Depthwise causal conv width w over (B, S, C) with optional state tail.

    Returns (silu(conv(u)), new tail (B, w-1, C))."""
    width = w.shape[0]
    B, S, C = u.shape
    if tail is None:
        tail = jnp.zeros((B, width - 1, C), u.dtype)
    full = jnp.concatenate([tail.astype(u.dtype), u], axis=1)  # (B, S+w-1, C)
    out = sum(full[:, i : i + S, :] * w[i] for i in range(width)) + bias
    return jax.nn.silu(out), full[:, -(width - 1) :, :]


def ssd_scan(x_h, B_mat, C_mat, dt, A, h0, chunk: int):
    """Chunked SSD. x_h (B,S,H,P); B/C (B,S,N); dt (B,S,H) fp32; A (H,) fp32.

    Returns (y (B,S,H,P) fp32, h_final (B,H,P,N) fp32).
    """
    Bsz, S, H, P = x_h.shape
    N = B_mat.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, chunk)
    nc = S // Q

    xr = x_h.reshape(Bsz, nc, Q, H, P).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    Br = B_mat.reshape(Bsz, nc, Q, N).astype(jnp.float32).transpose(1, 0, 2, 3)
    Cr = C_mat.reshape(Bsz, nc, Q, N).astype(jnp.float32).transpose(1, 0, 2, 3)
    dtr = dt.reshape(Bsz, nc, Q, H).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(h, inp):
        xc, Bc, Cc, dtc = inp  # (B,Q,H,P), (B,Q,N), (B,Q,N), (B,Q,H)
        dA = dtc * A  # (B, Q, H), ≤ 0
        cum = jnp.cumsum(dA, axis=1)  # inclusive cumsum over the chunk
        # intra-chunk: scores[b,i,j,h] = (C_i·B_j)·exp(cum_i−cum_j)·dt_j, j≤i
        CB = jnp.einsum("bin,bjn->bij", Cc, Bc)
        decay = jnp.exp(jnp.clip(cum[:, :, None, :] - cum[:, None, :, :], -60.0, 0.0))
        scores = CB[:, :, :, None] * decay * dtc[:, None, :, :]
        scores = jnp.where(tri[None, :, :, None], scores, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xc)
        # inter-chunk: contribution of the carried state
        in_decay = jnp.exp(jnp.clip(cum, -60.0, 0.0))  # exp(cum_i) (B,Q,H)
        y_inter = jnp.einsum("bin,bhpn->bihp", Cc, h) * in_decay[:, :, :, None]
        # chunk state: S_c = Σ_j exp(cum_Q − cum_j)·dt_j·(x_j ⊗ B_j)
        out_decay = jnp.exp(jnp.clip(cum[:, -1:, :] - cum, -60.0, 0.0))  # (B,Q,H)
        wdt = (out_decay * dtc)[..., None]  # (B,Q,H,1)
        S_c = jnp.einsum("bjhp,bjn->bhpn", xc * wdt, Bc)
        total = jnp.exp(jnp.clip(cum[:, -1, :], -60.0, 0.0))  # (B,H)
        h_new = h * total[:, :, None, None] + S_c
        return h_new, y_intra + y_inter

    h_final, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32), (xr, Br, Cr, dtr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_final


def _project(p: SSMParams, x: jax.Array, cfg, state: SSMState | None):
    """x (B,S,D) → (z, xs, B_mat, C_mat, dt, new tails) — conv'd/activated."""
    z = x @ p.w_z
    dt = jax.nn.softplus((x @ p.w_dt).astype(jnp.float32) + p.dt_bias)  # (B,S,H)
    xs, tx = _causal_conv(x @ p.w_x, p.conv_x, p.conv_bias_x, state.tail_x if state else None)
    Bm, tb = _causal_conv(x @ p.w_b, p.conv_b, p.conv_bias_b, state.tail_b if state else None)
    Cm, tc = _causal_conv(x @ p.w_c, p.conv_c, p.conv_bias_c, state.tail_c if state else None)
    return z, xs, Bm, Cm, dt, (tx, tb, tc)


def ssm_block(p: SSMParams, x: jax.Array, cfg, state: SSMState | None = None):
    """Full-sequence Mamba-2 block. Returns (y (B,S,D), final SSMState)."""
    B, S, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, Bm, Cm, dt, (tx, tb, tc) = _project(p, x, cfg, state)
    xs = xs.reshape(B, S, H, P)
    A = -jnp.exp(p.A_log)
    h0 = state.h if state is not None else jnp.zeros((B, H, P, N), jnp.float32)
    y, h_final = ssd_scan(xs, Bm, Cm, dt, A, h0, cfg.ssm_chunk)
    y = y + p.D[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = gated_rms_norm(y, z, p.norm_w)
    out = y @ p.w_out
    new_state = SSMState(
        h=h_final,
        tail_x=tx.astype(jnp.float32),
        tail_b=tb.astype(jnp.float32),
        tail_c=tc.astype(jnp.float32),
    )
    return out, new_state


def ssm_decode_block(p: SSMParams, x: jax.Array, cfg, state: SSMState):
    """Single-token step. x: (B, 1, D) → (y (B,1,D), new state)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, Bm, Cm, dt, (tx, tb, tc) = _project(p, x, cfg, state)
    xs = xs[:, 0].reshape(B, H, P).astype(jnp.float32)
    B_vec = Bm[:, 0].astype(jnp.float32)
    C_vec = Cm[:, 0].astype(jnp.float32)
    dt0 = dt[:, 0, :]  # (B, H)
    A = -jnp.exp(p.A_log)
    decay = jnp.exp(dt0 * A)  # (B, H)
    h = state.h * decay[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xs * dt0[..., None], B_vec
    )
    y = jnp.einsum("bhpn,bn->bhp", h, C_vec) + p.D[None, :, None] * xs
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = gated_rms_norm(y, z, p.norm_w)
    new_state = SSMState(
        h=h,
        tail_x=tx.astype(jnp.float32),
        tail_b=tb.astype(jnp.float32),
        tail_c=tc.astype(jnp.float32),
    )
    return y @ p.w_out, new_state

"""Mixture-of-Experts FFN: top-k routing with capacity, GShard-style.

The default dispatch is the einsum/one-hot ("dense dispatch") formulation:
it is the canonical pjit-shardable pattern — with tokens sharded over the
``data`` axis and experts over ``model``, the SPMD partitioner emits the
dispatch all-reduce automatically.  A sort-based (gather/scatter) dispatch is
also provided (``dispatch="sort"``); it trades the one-hot memory for
data-dependent gathers and is one of the §Perf hillclimb levers.

Routing follows GShard/Switch: softmax router in fp32, top-k experts per
token, per-expert position via cumulative sum, tokens beyond capacity are
dropped (their combine weight is zero — the residual path carries them).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_linear, init_swiglu


class MoEParams(NamedTuple):
    router: jax.Array  # (D, E) — kept fp32
    w_gate: jax.Array  # (E, D, F)
    w_up: jax.Array  # (E, D, F)
    w_down: jax.Array  # (E, F, D)
    shared: dict | None  # SwiGLU params of the shared expert(s), or None


def init_moe(key, cfg) -> MoEParams:
    from repro.models.layers import dtype_of

    dt = dtype_of(cfg.param_dtype)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(D), 1.0 / np.sqrt(F)
    shared = None
    if cfg.n_shared_experts:
        shared = init_swiglu(ks, D, F * cfg.n_shared_experts, dt)
    return MoEParams(
        router=(jax.random.normal(kr, (D, E)) * s_in).astype(jnp.float32),
        w_gate=(jax.random.normal(kg, (E, D, F)) * s_in).astype(dt),
        w_up=(jax.random.normal(ku, (E, D, F)) * s_in).astype(dt),
        w_down=(jax.random.normal(kd, (E, F, D)) * s_out).astype(dt),
        shared=shared,
    )


def expert_capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    cap = int(np.ceil(n_tokens * top_k * factor / n_experts))
    return max(cap, 4)


def _route(x_flat: jax.Array, p: MoEParams, top_k: int):
    """Return (probs (T,E) fp32, topk gate weights (T,k), topk expert ids (T,k))."""
    logits = x_flat.astype(jnp.float32) @ p.router
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renormalise over chosen
    return probs, gate, idx


def moe_einsum(p: MoEParams, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """GShard dense-dispatch MoE. x: (B, S, D) → (B, S, D), aux loss.

    The (T, E, C) dispatch/combine one-hots are the communication-friendly
    form: einsum ``tec,td->ecd`` with t sharded over data and e over model.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = expert_capacity(T, E, k, cfg.capacity_factor)
    x_flat = x.reshape(T, D)

    probs, gate, idx = _route(x_flat, p, k)

    # position of each (token, choice) within its expert, computed choice-major
    # so earlier choices win capacity slots (Switch/GShard convention).
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # (T, k, E)
    # cumulative count over the flattened (k, T) order:
    flat = onehot.transpose(1, 0, 2).reshape(k * T, E)
    pos = jnp.cumsum(flat, axis=0) - flat  # (k*T, E) position if dispatched
    pos_tok = (pos * flat).sum(-1).reshape(k, T).transpose(1, 0)  # (T, k)
    expert_of = idx  # (T, k)
    keep = pos_tok < C

    gate = gate * keep.astype(gate.dtype)

    # dispatch (T, E, C) and combine (T, E, C) tensors
    disp = (
        jax.nn.one_hot(expert_of, E, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos_tok, C), C, dtype=x.dtype)[:, :, None, :]
    ).sum(1)  # (T, E, C)
    comb = (
        jax.nn.one_hot(expert_of, E, dtype=jnp.float32)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos_tok, C), C, dtype=jnp.float32)[:, :, None, :]
        * gate[..., None, None].astype(jnp.float32)
    ).sum(1)

    xe = jnp.einsum("tec,td->ecd", disp, x_flat)  # (E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p.w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xe, p.w_up
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p.w_down)  # (E, C, D)
    y = jnp.einsum("tec,ecd->td", comb.astype(ye.dtype), ye)

    if p.shared is not None:
        from repro.models.layers import swiglu

        y = y + swiglu(x_flat, p.shared["w_gate"], p.shared["w_up"], p.shared["w_down"])

    aux = load_balance_loss(probs, expert_of, E)
    return y.reshape(B, S, D), aux


def moe_sort(p: MoEParams, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Sort-based dispatch: gather tokens into (E, C) slots via argsort.

    Same routing decisions as ``moe_einsum`` (identical keep/drop set);
    avoids the (T, E, C) one-hots at the price of data-dependent gathers.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = expert_capacity(T, E, k, cfg.capacity_factor)
    x_flat = x.reshape(T, D)

    probs, gate, idx = _route(x_flat, p, k)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)
    flat = onehot.transpose(1, 0, 2).reshape(k * T, E)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos_tok = (pos * flat).sum(-1).reshape(k, T).transpose(1, 0)  # (T, k)
    keep = pos_tok < C
    gate = gate * keep.astype(gate.dtype)

    # flatten (token, choice) assignments and scatter token ids into slots
    slot = idx * C + jnp.where(keep, pos_tok, E * C)  # (T, k); dropped → OOB
    slot_flat = slot.reshape(T * k)
    tok_ids = jnp.tile(jnp.arange(T)[:, None], (1, k)).reshape(T * k)
    slot_to_tok = jnp.zeros((E * C + 1,), jnp.int32).at[slot_flat].set(tok_ids, mode="drop")
    slot_filled = jnp.zeros((E * C + 1,), bool).at[slot_flat].set(True, mode="drop")
    slot_to_tok = slot_to_tok[: E * C].reshape(E, C)
    slot_filled = slot_filled[: E * C].reshape(E, C)

    xe = x_flat[slot_to_tok] * slot_filled[..., None].astype(x.dtype)  # (E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p.w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xe, p.w_up
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p.w_down)

    # combine: scatter-add expert outputs back to tokens, weighted by gate
    ye_flat = ye.reshape(E * C, D)
    contrib = ye_flat[slot_flat.clip(0, E * C - 1)] * gate.reshape(T * k, 1).astype(ye.dtype)
    contrib = jnp.where((slot_flat < E * C)[:, None], contrib, 0)
    y = jnp.zeros((T, D), ye.dtype).at[tok_ids].add(contrib)

    if p.shared is not None:
        from repro.models.layers import swiglu

        y = y + swiglu(x_flat, p.shared["w_gate"], p.shared["w_up"], p.shared["w_down"])

    aux = load_balance_loss(probs, idx, E)
    return y.reshape(B, S, D), aux


def load_balance_loss(probs: jax.Array, expert_of: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E * Σ_e f_e · P_e."""
    T = probs.shape[0]
    f = jnp.zeros((n_experts,)).at[expert_of.reshape(-1)].add(1.0) / max(
        expert_of.size, 1
    )
    P = probs.mean(0)
    return n_experts * jnp.sum(jax.lax.stop_gradient(f) * P)


# ---------------------------------------------------------------------------
# Expert-parallel shard_map dispatch (§Perf iteration 4)
# ---------------------------------------------------------------------------
# Why: under pjit, both dense-dispatch (one-hot einsums: 2·T·E·C·D FLOPs) and
# sort-dispatch (data-dependent gathers XLA refuses to shard: measured an
# unsharded (T·k, D) fp32 combine tensor) leave huge artifacts. But our
# activations are already REPLICATED over the model axis (batch shards over
# data only), so each (data i, model j) shard can dispatch **locally**: it
# selects, from its own token block, the tokens routed to the experts living
# on model-shard j, runs them, scatters back, and one psum over `model`
# completes the combine — the same single collective a Megatron MLP pays.
#
# E % model == 0  → true EP (E/model experts per shard, full F);
# model % E == 0  → experts column-split over F (exact: SwiGLU is
#                   elementwise in F; the psum sums the column partials).

_EP_MESH: "tuple | None" = None  # (mesh, dp_axes, token_axes, model_axis, stationary)


def set_ep_mesh(
    mesh, dp_axes, token_axes=..., model_axis: str = "model", stationary: bool = False
) -> None:
    """``token_axes``: mesh axes of the batch dim (None ⇒ tokens replicated,
    e.g. batch=1 decode); defaults to ``dp_axes``. ``dp_axes`` names the
    FSDP axis the expert weights' d_model dim is sharded over.

    ``stationary`` (§Perf iteration 8 — serving 100B+ MoE): weights never
    move. Experts shard E over model and F over data; the (tiny) decode
    token batch is all-gathered to every shard instead (128 tokens × D ≈
    2 MB vs 43 GB of expert weights per jamba decode step), each shard
    computes its (expert, F-slice) partials, and one psum over
    (model, data) combines."""
    global _EP_MESH
    if mesh is None:
        _EP_MESH = None
        return
    if token_axes is ...:
        token_axes = tuple(dp_axes)
    _EP_MESH = (mesh, tuple(dp_axes), token_axes, model_axis, stationary)


def _ep_weight_specs(cfg, msize: int, fsdp):
    from jax.sharding import PartitionSpec as P

    if cfg.n_experts % msize == 0:
        return P("model", fsdp, None), P("model", None, fsdp), True
    assert msize % cfg.n_experts == 0, (cfg.n_experts, msize)
    return P(None, fsdp, "model"), P(None, "model", fsdp), False


def moe_ep(p: MoEParams, x: jax.Array, cfg):
    import functools

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, dp_axes, token_axes, maxis, stationary = _EP_MESH
    msize = mesh.shape[maxis]
    E, k, D = cfg.n_experts, cfg.top_k, cfg.d_model
    fsdp = dp_axes[-1] if dp_axes else None
    if stationary:
        # weights-stationary serving: E over model, F over data, no gathers;
        # the (tiny) token batch is replicated instead
        assert fsdp is not None and E % msize == 0, (E, msize)
        gu_spec, d_spec, true_ep = P("model", None, fsdp), P("model", fsdp, None), True
        fsdp_gather = None
        x_spec = P(None, None, None)
        psum_axes = (maxis, fsdp)
    else:
        gu_spec, d_spec, true_ep = _ep_weight_specs(cfg, msize, fsdp)
        fsdp_gather = fsdp
        x_spec = P(token_axes, None, None) if token_axes else P(None, None, None)
        psum_axes = (maxis,)
    E_loc = E // msize if true_ep else E

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(x_spec, P(), gu_spec, gu_spec, d_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    def block(x_loc, router, wg, wu, wd):
        B_loc, S, _ = x_loc.shape
        T = B_loc * S
        C = expert_capacity(T, E, k, cfg.capacity_factor)
        x_flat = x_loc.reshape(T, D)

        if fsdp_gather is not None:
            # weights arrive FSDP-sharded on D; gather them (zero-3's weight AG)
            wg = jax.lax.all_gather(wg, fsdp_gather, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_gather, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_gather, axis=2, tiled=True)

        probs, gate, idx = _route(x_flat, MoEParams(router, None, None, None, None), k)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # (T, k, E)
        flat = onehot.transpose(1, 0, 2).reshape(k * T, E)
        pos = jnp.cumsum(flat, axis=0) - flat
        pos_tok = (pos * flat).sum(-1).reshape(k, T).transpose(1, 0)  # (T, k)
        keep = pos_tok < C
        gate = gate * keep.astype(gate.dtype)

        if true_ep:  # keep only this shard's experts
            e0 = jax.lax.axis_index(maxis) * E_loc
            mine = (idx >= e0) & (idx < e0 + E_loc)
            slot = jnp.where(keep & mine, (idx - e0) * C + pos_tok, E_loc * C)
        else:  # every shard runs all experts on its F column slice
            slot = jnp.where(keep, idx * C + pos_tok, E_loc * C)
        slot_flat = slot.reshape(T * k)
        tok_ids = jnp.tile(jnp.arange(T)[:, None], (1, k)).reshape(T * k)
        slot_to_tok = jnp.zeros((E_loc * C + 1,), jnp.int32).at[slot_flat].set(
            tok_ids, mode="drop"
        )
        filled = jnp.zeros((E_loc * C + 1,), bool).at[slot_flat].set(True, mode="drop")
        slot_to_tok = slot_to_tok[:-1].reshape(E_loc, C)
        filled = filled[:-1].reshape(E_loc, C)

        xe = x_flat[slot_to_tok] * filled[..., None].astype(x_loc.dtype)  # (E_loc, C, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
            "ecd,edf->ecf", xe, wu
        )
        ye = jnp.einsum("ecf,efd->ecd", h, wd)  # (E_loc, C, D) (partial if !true_ep)

        ye_flat = ye.reshape(E_loc * C, D)
        contrib = ye_flat[jnp.clip(slot_flat, 0, E_loc * C - 1)]
        contrib = contrib * gate.reshape(T * k, 1).astype(ye.dtype)
        contrib = jnp.where((slot_flat < E_loc * C)[:, None], contrib, 0)
        y = jnp.zeros((T, D), ye.dtype).at[tok_ids].add(contrib)
        y = jax.lax.psum(y, psum_axes)  # combine across expert shards / F slices

        aux = load_balance_loss(probs, idx, E)
        if token_axes:
            aux = jax.lax.pmean(aux, token_axes)
        return y.reshape(B_loc, S, D), aux

    y, aux = block(x, p.router, p.w_gate, p.w_up, p.w_down)
    if p.shared is not None:
        from repro.models.layers import swiglu

        B, S, _ = x.shape
        y = y + swiglu(
            x.reshape(B * S, D), p.shared["w_gate"], p.shared["w_up"], p.shared["w_down"]
        ).reshape(B, S, D)
    return y, aux


def moe_block(p: MoEParams, x: jax.Array, cfg, dispatch: str | None = None):
    dispatch = dispatch or getattr(cfg, "moe_dispatch", "sort")
    if _EP_MESH is not None and dispatch in ("ep", "sort"):
        return moe_ep(p, x, cfg)
    if dispatch == "sort":
        return moe_sort(p, x, cfg)
    return moe_einsum(p, x, cfg)

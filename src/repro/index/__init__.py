from repro.index.ann import AnnIndex, build_index
from repro.index.kmeans import kmeans_fit, lsh_init_centroids

__all__ = ["AnnIndex", "build_index", "kmeans_fit", "lsh_init_centroids"]

from repro.index.ann import AnnIndex, build_index, data_fingerprint
from repro.index.build import BuildReport, IndexBuilder, capacity_assign_device
from repro.index.incremental import PartialUpdate, admit_and_patch
from repro.index.kmeans import kmeans_centroids, kmeans_fit, lsh_init_centroids

__all__ = [
    "AnnIndex",
    "BuildReport",
    "IndexBuilder",
    "PartialUpdate",
    "admit_and_patch",
    "build_index",
    "capacity_assign_device",
    "data_fingerprint",
    "kmeans_centroids",
    "kmeans_fit",
    "lsh_init_centroids",
]

"""Device-resident, sharded index-build subsystem (paper §3.2 at scale).

PR 2 made *training* device-resident and sharded; this module does the same
for the **index build** — the LSH-init k-means → capacity-bounded clusters
→ in-cluster exact kNN pipeline that used to run through host NumPy with an
O(N·K) ``banned`` matrix (~40 GB at N=10M, K=4K) inside a Python bidding
loop.

:class:`IndexBuilder` mirrors the training strategy layer:
``build_strategy="auto"|"local"|"sharded"`` resolves from ``jax.devices()``
(or the mesh the estimator trains on), and every stage runs on device:

* **kmeans**  — the ``lax.scan`` EM of :mod:`repro.index.kmeans` with
  on-device convergence, its E-step the row-blocked ``"kmeans_assign"``
  registry kernel (``"sharded"`` routes through ``kmeans_fit_sharded``:
  rows sharded, one (K, D+1) psum per iteration);
* **assign**  — capacity-bounded assignment as a jitted ``while_loop`` of
  bidding rounds: ONE row-blocked pass through the ``"pairwise"`` registry
  kernel caches each row's top-R nearest centroids (R =
  ``cfg.build_candidates``), then every round is O(N·R): each unassigned
  row bids for its nearest centroid with free capacity, and the
  ``"capacity_admit"`` registry kernel (stable segmented rank) admits each
  centroid's ``free`` closest bidders — exactly the host reference's round
  semantics. Carried state is ``assign (N,) + free (K,)``; no (N, K)
  allocation exists on host or device;
* **permute** — the cluster-major permutation as one vectorised
  argsort/scatter jit (the seed looped ``for c in range(K)`` on host);
* **knn**     — ``batched_cluster_knn``; under ``"sharded"`` each device
  computes the kNN of its own contiguous cluster blocks via ``shard_map``.

``"sharded"`` never places the full (N, D) on one device, and on a
1-device mesh it reproduces ``"local"`` bit-for-bit (asserted in
tests/test_index_build.py). Stragglers — rows whose whole candidate list
filled up, a fraction of a percent at normal slack — are force-placed on
host from O(T·K) distances, T = number of stragglers.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from contextlib import contextmanager
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import NomadConfig
from repro.index import kmeans as km
from repro.index.ann import AnnIndex, _np_dist2, data_fingerprint
from repro.index.knn import batched_cluster_knn, cluster_knn_batch_sharded

BUILD_AXIS = "build"


# ---------------------------------------------------------------------------
# Capacity-bounded assignment: device bidding rounds over cached candidates
# ---------------------------------------------------------------------------


def _candidate_pass(x, cents, n_cand: int, impl: str, block: int):
    """One row-blocked pass: each row's ``R = min(n_cand, K)`` nearest
    centroids, distance-sorted. The (block, K) distance tile comes from the
    ``"pairwise"`` registry kernel; only the (N, R) top-k survives — the
    single O(N·K) *compute* pass of the whole assignment, with O(N·R)
    *memory*."""
    from repro.kernels import registry

    n, d = x.shape
    r = min(n_cand, cents.shape[0])
    block = max(1, min(block, n))
    nb = -(-n // block)
    pad = nb * block - n
    xp = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)]) if pad else x

    def one(xb):
        d2 = registry.dispatch("pairwise", xb, cents, impl=impl)
        neg, idx = jax.lax.top_k(-d2, r)
        return idx.astype(jnp.int32), -neg

    idx, d2 = jax.lax.map(one, xp.reshape(nb, block, d))
    return idx.reshape(nb * block, r)[:n], d2.reshape(nb * block, r)[:n]


def _bid_from_candidates(cand_idx, cand_d2, free):
    """Each row's nearest centroid with free capacity — candidates are
    distance-sorted, so that is the first free one. Rows whose whole
    candidate list is full (``has=False``) sit the round out (and fall to
    the host straggler pass if the loop ends)."""
    ok = free[cand_idx] > 0  # (N, R)
    has = jnp.any(ok, axis=1)
    j = jnp.argmax(ok, axis=1)  # first free candidate
    rows = jnp.arange(cand_idx.shape[0])
    return cand_idx[rows, j], cand_d2[rows, j], has


def _round_cond_body(estep_fn, n: int, n_real: int, K: int, max_rounds: int):
    """The shared bidding-round while_loop pieces (local and sharded).

    Every round with a non-empty bidder pool admits at least one point
    (``capacity_admit`` admits min(bidders, free) per centroid), so the
    loop provably progresses; ``progressed`` stops it early once the only
    unassigned rows are candidate-exhausted stragglers."""
    from repro.kernels import registry

    real = jnp.arange(n) < n_real

    def cond(carry):
        assign, _free, r, progressed = carry
        return (r < max_rounds) & progressed & jnp.any((assign < 0) & real)

    def body(carry):
        assign, free, r, _progressed = carry
        pick, d2, has = estep_fn(free)
        bidding = (assign < 0) & real & has
        admitted = registry.dispatch("capacity_admit", pick, d2, bidding, free)
        assign = jnp.where(admitted, pick, assign)
        taken = jnp.zeros_like(free).at[jnp.where(admitted, pick, K)].add(
            1, mode="drop"
        )
        return assign, free - taken, r + 1, jnp.any(bidding)

    init = (
        jnp.full((n,), -1, jnp.int32),
        None,  # free filled in by the caller
        jnp.zeros((), jnp.int32),
        jnp.ones((), bool),
    )
    return cond, body, init


@functools.partial(
    jax.jit, static_argnames=("capacity", "impl", "block", "max_rounds", "n_cand")
)
def _capacity_rounds_local(x, cents, capacity, impl, block, max_rounds, n_cand):
    n = x.shape[0]
    K = cents.shape[0]
    cand_idx, cand_d2 = _candidate_pass(x, cents, n_cand, impl, block)
    cond, body, init = _round_cond_body(
        lambda free: _bid_from_candidates(cand_idx, cand_d2, free),
        n,
        n,
        K,
        max_rounds,
    )
    init = (init[0], jnp.full((K,), capacity, jnp.int32), init[2], init[3])
    assign, free, _, _ = jax.lax.while_loop(cond, body, init)
    return assign, free


def _capacity_rounds_sharded(
    mesh, x_sharded, cents, capacity, impl, block, max_rounds, n_cand, n_real
):
    """Rows (and their candidate cache) sharded over the build axis; the
    per-round exchange is one all_gather of the (N,) bids (admission is
    replicated — O(N + K) state, never (N, K) nor (N, D) on one device)."""
    n = x_sharded.shape[0]
    K = cents.shape[0]
    blk = max(1, min(block, n // mesh.shape[BUILD_AXIS]))

    @jax.jit
    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(BUILD_AXIS, None), P(None, None)),
        out_specs=(P(None), P(None)),
        check_rep=False,
    )
    def run(x_local, cents):
        cand_idx, cand_d2 = _candidate_pass(x_local, cents, n_cand, impl, blk)

        def estep(free):
            p_l, d_l, h_l = _bid_from_candidates(cand_idx, cand_d2, free)
            return (
                jax.lax.all_gather(p_l, BUILD_AXIS, axis=0, tiled=True),
                jax.lax.all_gather(d_l, BUILD_AXIS, axis=0, tiled=True),
                jax.lax.all_gather(h_l, BUILD_AXIS, axis=0, tiled=True),
            )

        cond, body, init = _round_cond_body(estep, n, n_real, K, max_rounds)
        init = (init[0], jnp.full((K,), capacity, jnp.int32), init[2], init[3])
        assign, free, _, _ = jax.lax.while_loop(cond, body, init)
        return assign, free

    return run(x_sharded, cents)


def _force_place_host(x, cents, assign, free, chunk: int = 8192):
    """Place stragglers (rows unassigned after ``max_rounds``) into their
    nearest centroid with space — O(T·K) host *compute*, chunked so the
    live distance block never exceeds (chunk, K) even if contention drives
    T toward N."""
    todo = np.flatnonzero(assign < 0)
    if todo.size == 0:
        return assign, 0
    for s in range(0, todo.size, chunk):
        block = todo[s : s + chunk]
        d2 = _np_dist2(x[block], cents)
        for t, row in zip(block, np.argsort(d2, axis=1)):
            for c in row:
                if free[c] > 0:
                    assign[t] = c
                    free[c] -= 1
                    break
    if (assign < 0).any():
        raise RuntimeError("capacity assignment: total capacity < N")
    return assign, int(todo.size)


def capacity_assign_device(
    x: np.ndarray,
    cents: np.ndarray,
    capacity: int,
    *,
    impl="auto",
    block: int = 16384,
    max_rounds: int = 16,
    n_cand: int = 32,
) -> np.ndarray:
    """Device-resident capacity-bounded assignment (single-device form).

    The round semantics match :func:`repro.index.kmeans.capacity_assign`
    (the host NumPy oracle): unassigned points bid for their nearest
    centroid with free capacity; each centroid admits its ``free`` closest
    bidders, ties broken by original index. (A point whose ``n_cand``
    nearest centroids all fill is force-placed by the straggler pass —
    the one place the two can differ, and only under extreme contention.)
    Returns ``assign`` (N,) int64.
    """
    from repro.kernels import registry

    resolved = registry.resolve("pairwise", impl)
    assign, free = _capacity_rounds_local(
        jnp.asarray(x),
        jnp.asarray(cents, jnp.float32),
        capacity,
        resolved,
        max(1, min(block, x.shape[0])),
        max_rounds,
        n_cand,
    )
    assign = np.asarray(assign).astype(np.int64)
    assign, _ = _force_place_host(
        np.asarray(x), np.asarray(cents), assign, np.asarray(free).copy()
    )
    return assign


# ---------------------------------------------------------------------------
# Cluster-major permutation: one argsort/scatter jit
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_clusters", "capacity"))
def _permutation_from_assign(assign, n_clusters, capacity):
    """assign (N,) → (perm (N,), counts (K,)) on device.

    row = cluster · capacity + slot, slots in stable original-index order —
    identical layout to the seed's per-cluster host loop, vectorised. Only
    O(N + K) integer state; the (K·C, D) row buffer itself is one host
    memcpy of the (host-resident) input, done per consumer: whole for the
    local kNN stage, shard-by-shard for the sharded one.
    """
    n = assign.shape[0]
    order = jnp.argsort(assign, stable=True)
    counts = jnp.zeros((n_clusters,), jnp.int32).at[assign].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )
    a_sorted = assign[order]
    slot = jnp.arange(n, dtype=jnp.int32) - starts[a_sorted]
    rows = a_sorted * capacity + slot
    perm = jnp.zeros((n,), jnp.int32).at[order].set(rows)
    return perm, counts


def _scatter_rows_host(x, perm, n_clusters, capacity):
    """x_rows (K·C, D) in the caller's dtype — one vectorised host scatter."""
    x_rows = np.zeros((n_clusters * capacity, x.shape[1]), x.dtype)
    x_rows[perm] = x
    return x_rows


def _finalize_knn(knn_local, knn_w, K: int, C: int):
    """(K, C, k) in-cluster slots → (K·C, k) global rows; dead edges → self."""
    knn_local = np.asarray(knn_local)
    knn_w = np.asarray(knn_w).reshape(K * C, -1)
    base = (np.arange(K) * C)[:, None, None]
    knn_idx = (knn_local + base).reshape(K * C, -1).astype(np.int64)
    self_rows = np.arange(K * C)[:, None]
    knn_idx = np.where(knn_w > 0, knn_idx, self_rows)
    return knn_idx, knn_w.astype(np.float32)


# ---------------------------------------------------------------------------
# The builder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BuildReport:
    """Provenance of one index build (feeds FitResult + benchmarks)."""

    strategy: str
    n_shards: int
    total_s: float
    stage_s: dict  # {"kmeans" | "assign" | "permute" | "knn": seconds}
    stage_rss_mb: dict  # high-watermark host RSS at the end of each stage
    stragglers: int = 0


def _rss_mb() -> float:
    try:
        import resource
        import sys

        rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        # ru_maxrss is kilobytes on Linux, bytes on macOS
        return rss / (1024.0 * 1024.0) if sys.platform == "darwin" else rss / 1024.0
    except Exception:  # non-POSIX platform
        return 0.0


def resolve_build_strategy(
    spec: str, cfg: NomadConfig, mesh: Optional[Mesh] = None
):
    """``"auto"|"local"|"sharded"`` → ("local", None) | ("sharded", Mesh).

    The build mesh is one flat axis over the largest cluster-divisible
    prefix of the available devices (the training mesh's devices when the
    estimator passes one in, else ``jax.devices()``); ``"auto"`` picks
    sharded exactly when that mesh is wider than one device.
    """
    from repro.core.strategy import flat_mesh, largest_divisor_leq

    spec = spec or "auto"
    if spec not in ("auto", "local", "sharded"):
        raise ValueError(
            f"unknown build_strategy {spec!r} (want 'auto'|'local'|'sharded')"
        )
    if spec == "local":
        return "local", None
    devs = list(mesh.devices.reshape(-1)) if mesh is not None else jax.devices()
    width = largest_divisor_leq(cfg.n_clusters, len(devs))
    if spec == "auto" and width == 1:
        return "local", None
    return "sharded", flat_mesh(devs[:width], BUILD_AXIS)


class IndexBuilder:
    """Builds the §3.2 :class:`AnnIndex` on device, locally or sharded.

    Mirrors the training strategy layer: ``strategy`` (default
    ``cfg.build_strategy``) is ``"auto"|"local"|"sharded"``; ``mesh`` (the
    estimator's training mesh, if any) supplies the device pool. After
    ``build`` the per-stage wall times and peak host RSS sit in
    :attr:`report` (a :class:`BuildReport`).
    """

    def __init__(
        self,
        cfg: NomadConfig,
        *,
        strategy: Optional[str] = None,
        mesh: Optional[Mesh] = None,
        impl=None,
    ):
        self.cfg = cfg
        self.spec = strategy if strategy is not None else cfg.build_strategy
        self.mesh = mesh
        self.impl = impl if impl is not None else cfg.resolved_kernel_impl()
        self.report: Optional[BuildReport] = None

    # -- the one build -------------------------------------------------------

    def build(self, x: np.ndarray) -> AnnIndex:
        cfg = self.cfg
        n, d = x.shape
        K, C = cfg.n_clusters, cfg.cluster_capacity
        if K * C < n:
            raise ValueError(f"capacity {C}×{K} < N={n}; raise capacity_slack")
        name, mesh = resolve_build_strategy(self.spec, cfg, self.mesh)

        stage_s: dict = {}
        stage_rss: dict = {}

        @contextmanager
        def stage(label):
            t0 = time.time()
            yield
            # accumulate: the straggler force-place re-enters "assign"
            stage_s[label] = stage_s.get(label, 0.0) + (time.time() - t0)
            stage_rss[label] = _rss_mb()

        t0 = time.time()
        if name == "local":
            index, stragglers = self._build_local(x, stage)
            n_shards = 1
        else:
            index, stragglers = self._build_sharded(x, mesh, stage)
            n_shards = mesh.shape[BUILD_AXIS]
        self.report = BuildReport(
            strategy=name,
            n_shards=n_shards,
            total_s=time.time() - t0,
            stage_s=stage_s,
            stage_rss_mb=stage_rss,
            stragglers=stragglers,
        )
        return index

    # -- stages ----------------------------------------------------------------

    def _assemble(self, x, cents, x_rows, perm, counts, knn_local, knn_w):
        cfg = self.cfg
        K, C = cfg.n_clusters, cfg.cluster_capacity
        knn_idx, knn_w = _finalize_knn(knn_local, knn_w, K, C)
        return AnnIndex(
            x_rows=x_rows,
            knn_idx=knn_idx,
            knn_w=knn_w,
            counts=np.asarray(counts).astype(np.int64),
            centroids=np.asarray(cents),
            perm=perm,
            capacity=C,
            n_points=x.shape[0],
            fingerprint=data_fingerprint(x),
        )

    def _finish(self, x, cents, assign_d, free_d, stage, knn_fn):
        """The strategy-independent tail: straggler force-place → permute →
        kNN (``knn_fn`` is the one per-strategy piece) → assemble. One body
        for both paths keeps sharded ≡ local by construction."""
        cfg = self.cfg
        n, d = x.shape
        K, C = cfg.n_clusters, cfg.cluster_capacity

        with stage("assign"):  # stragglers are assign work (times accumulate)
            assign = np.asarray(assign_d)[:n].astype(np.int64)
            assign, stragglers = _force_place_host(
                x, np.asarray(cents), assign, np.asarray(free_d).copy()
            )

        with stage("permute"):
            perm_d, counts = _permutation_from_assign(
                jnp.asarray(assign, jnp.int32), K, C
            )
            perm = np.asarray(perm_d).astype(np.int64)
            x_rows = _scatter_rows_host(x, perm, K, C)

        with stage("knn"):
            knn_local, knn_w = knn_fn(
                np.asarray(x_rows, np.float32).reshape(K, C, d), counts
            )
            jax.block_until_ready(knn_w)

        return (
            self._assemble(x, cents, x_rows, perm, counts, knn_local, knn_w),
            stragglers,
        )

    def _build_local(self, x, stage):
        from repro.kernels import registry

        cfg = self.cfg
        n = x.shape[0]
        K, C, k = cfg.n_clusters, cfg.cluster_capacity, cfg.n_neighbors
        block = cfg.build_block_rows
        key = jax.random.key(cfg.seed)
        xd = jnp.asarray(x)

        with stage("kmeans"):
            cents = km.kmeans_centroids(
                key,
                xd,
                K,
                n_iters=cfg.kmeans_iters,
                tol=cfg.kmeans_tol,
                impl=self.impl,
                block=block,
            )
            jax.block_until_ready(cents)

        with stage("assign"):
            assign_d, free_d = _capacity_rounds_local(
                xd,
                cents,
                C,
                registry.resolve("pairwise", self.impl),
                max(1, min(block, n)),
                cfg.build_max_rounds,
                cfg.build_candidates,
            )

        def knn_fn(x_blocks_host, counts):
            valid = jnp.arange(C)[None, :] < counts[:, None]
            return batched_cluster_knn(
                jnp.asarray(x_blocks_host), valid, k, self.impl
            )

        return self._finish(x, cents, assign_d, free_d, stage, knn_fn)

    def _build_sharded(self, x, mesh, stage):
        from repro.kernels import registry

        cfg = self.cfg
        n, d = x.shape
        K, C, k = cfg.n_clusters, cfg.cluster_capacity, cfg.n_neighbors
        block = cfg.build_block_rows
        n_dev = mesh.shape[BUILD_AXIS]
        key = jax.random.key(cfg.seed)

        # pad rows up to the device count; padding never enters any statistic
        n_pad = -(-n // n_dev) * n_dev
        xp = x if n_pad == n else np.concatenate(
            [x, np.zeros((n_pad - n, d), x.dtype)]
        )
        row_sh = NamedSharding(mesh, P(BUILD_AXIS, None))
        xd = jax.device_put(jnp.asarray(xp), row_sh)

        with stage("kmeans"):
            cents = km.kmeans_fit_sharded(
                key,
                xd,
                K,
                mesh,
                BUILD_AXIS,
                n_iters=cfg.kmeans_iters,
                tol=cfg.kmeans_tol,
                impl=self.impl,
                block=block,
                n_real=n if n_pad != n else None,
            )
            jax.block_until_ready(cents)

        with stage("assign"):
            assign_d, free_d = _capacity_rounds_sharded(
                mesh,
                xd,
                cents,
                C,
                registry.resolve("pairwise", self.impl),
                block,
                cfg.build_max_rounds,
                cfg.build_candidates,
                n,
            )

        def knn_fn(x_blocks_host, counts):
            # device_put from host inside cluster_knn_batch_sharded moves
            # each device only its own cluster blocks — the full (K·C, D)
            # never lands on one device
            return cluster_knn_batch_sharded(
                mesh, BUILD_AXIS, x_blocks_host, counts, k, self.impl
            )

        return self._finish(x, cents, assign_d, free_d, stage, knn_fn)

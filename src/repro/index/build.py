"""Device-resident, sharded index-build subsystem (paper §3.2 at scale).

PR 2 made *training* device-resident and sharded; this module does the same
for the **index build** — the LSH-init k-means → capacity-bounded clusters
→ in-cluster exact kNN pipeline that used to run through host NumPy with an
O(N·K) ``banned`` matrix (~40 GB at N=10M, K=4K) inside a Python bidding
loop.

:class:`IndexBuilder` mirrors the training strategy layer:
``build_strategy="auto"|"local"|"sharded"`` resolves from ``jax.devices()``
(or the mesh the estimator trains on), and every stage runs on device:

* **kmeans**  — the ``lax.scan`` EM of :mod:`repro.index.kmeans` with
  on-device convergence, its E-step the row-blocked ``"kmeans_assign"``
  registry kernel (``"sharded"`` routes through ``kmeans_fit_sharded``:
  rows sharded, one (K, D+1) psum per iteration);
* **assign**  — capacity-bounded assignment as a jitted ``while_loop`` of
  bidding rounds: ONE row-blocked pass through the ``"pairwise"`` registry
  kernel caches each row's top-R nearest centroids (R =
  ``cfg.build_candidates``), then every round is O(N·R): each unassigned
  row bids for its nearest centroid with free capacity, and the
  ``"capacity_admit"`` registry kernel (stable segmented rank) admits each
  centroid's ``free`` closest bidders — exactly the host reference's round
  semantics. Carried state is ``assign (N,) + free (K,)``; no (N, K)
  allocation exists on host or device;
* **permute** — the cluster-major permutation as one vectorised
  argsort/scatter jit (the seed looped ``for c in range(K)`` on host);
* **knn**     — ``batched_cluster_knn``; under ``"sharded"`` each device
  computes the kNN of its own contiguous cluster blocks via ``shard_map``.

``"sharded"`` never places the full (N, D) on one device, and on a
1-device mesh it reproduces ``"local"`` bit-for-bit (asserted in
tests/test_index_build.py). Stragglers — rows whose whole candidate list
filled up, a fraction of a percent at normal slack — are force-placed on
host from O(T·K) distances, T = number of stragglers.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
from contextlib import contextmanager
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import NomadConfig
from repro.index import kmeans as km
from repro.index.ann import AnnIndex, _np_dist2, data_fingerprint
from repro.index.knn import batched_cluster_knn, cluster_knn_batch_sharded

BUILD_AXIS = "build"


# ---------------------------------------------------------------------------
# Capacity-bounded assignment: device bidding rounds over cached candidates
# ---------------------------------------------------------------------------


def _candidate_pass(x, cents, n_cand: int, impl: str, block: int):
    """One row-blocked pass: each row's ``R = min(n_cand, K)`` nearest
    centroids, distance-sorted. The (block, K) distance tile comes from the
    ``"pairwise"`` registry kernel; only the (N, R) top-k survives — the
    single O(N·K) *compute* pass of the whole assignment, with O(N·R)
    *memory*."""
    from repro.kernels import registry

    n, d = x.shape
    r = min(n_cand, cents.shape[0])
    block = max(1, min(block, n))
    nb = -(-n // block)
    pad = nb * block - n
    xp = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)]) if pad else x

    def one(xb):
        d2 = registry.dispatch("pairwise", xb, cents, impl=impl)
        neg, idx = jax.lax.top_k(-d2, r)
        return idx.astype(jnp.int32), -neg

    idx, d2 = jax.lax.map(one, xp.reshape(nb, block, d))
    return idx.reshape(nb * block, r)[:n], d2.reshape(nb * block, r)[:n]


def _bid_from_candidates(cand_idx, cand_d2, free):
    """Each row's nearest centroid with free capacity — candidates are
    distance-sorted, so that is the first free one. Rows whose whole
    candidate list is full (``has=False``) sit the round out (and fall to
    the host straggler pass if the loop ends)."""
    ok = free[cand_idx] > 0  # (N, R)
    has = jnp.any(ok, axis=1)
    j = jnp.argmax(ok, axis=1)  # first free candidate
    rows = jnp.arange(cand_idx.shape[0])
    return cand_idx[rows, j], cand_d2[rows, j], has


def _round_cond_body(estep_fn, n: int, n_real: int, K: int, max_rounds: int):
    """The shared bidding-round while_loop pieces (local and sharded).

    Every round with a non-empty bidder pool admits at least one point
    (``capacity_admit`` admits min(bidders, free) per centroid), so the
    loop provably progresses; ``progressed`` stops it early once the only
    unassigned rows are candidate-exhausted stragglers."""
    from repro.kernels import registry

    real = jnp.arange(n) < n_real

    def cond(carry):
        assign, _free, r, progressed = carry
        return (r < max_rounds) & progressed & jnp.any((assign < 0) & real)

    def body(carry):
        assign, free, r, _progressed = carry
        pick, d2, has = estep_fn(free)
        bidding = (assign < 0) & real & has
        admitted = registry.dispatch("capacity_admit", pick, d2, bidding, free)
        assign = jnp.where(admitted, pick, assign)
        taken = jnp.zeros_like(free).at[jnp.where(admitted, pick, K)].add(
            1, mode="drop"
        )
        return assign, free - taken, r + 1, jnp.any(bidding)

    init = (
        jnp.full((n,), -1, jnp.int32),
        None,  # free filled in by the caller
        jnp.zeros((), jnp.int32),
        jnp.ones((), bool),
    )
    return cond, body, init


@functools.partial(
    jax.jit, static_argnames=("capacity", "impl", "block", "max_rounds", "n_cand")
)
def _capacity_rounds_local(x, cents, capacity, impl, block, max_rounds, n_cand):
    n = x.shape[0]
    K = cents.shape[0]
    cand_idx, cand_d2 = _candidate_pass(x, cents, n_cand, impl, block)
    cond, body, init = _round_cond_body(
        lambda free: _bid_from_candidates(cand_idx, cand_d2, free),
        n,
        n,
        K,
        max_rounds,
    )
    init = (init[0], jnp.full((K,), capacity, jnp.int32), init[2], init[3])
    assign, free, _, _ = jax.lax.while_loop(cond, body, init)
    return assign, free


def _capacity_rounds_sharded(
    mesh, x_sharded, cents, capacity, impl, block, max_rounds, n_cand, n_real
):
    """Rows (and their candidate cache) sharded over the build axis; the
    per-round exchange is one all_gather of the (N,) bids (admission is
    replicated — O(N + K) state, never (N, K) nor (N, D) on one device)."""
    n = x_sharded.shape[0]
    K = cents.shape[0]
    blk = max(1, min(block, n // mesh.shape[BUILD_AXIS]))

    @jax.jit
    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(BUILD_AXIS, None), P(None, None)),
        out_specs=(P(None), P(None)),
        check_rep=False,
    )
    def run(x_local, cents):
        cand_idx, cand_d2 = _candidate_pass(x_local, cents, n_cand, impl, blk)

        def estep(free):
            p_l, d_l, h_l = _bid_from_candidates(cand_idx, cand_d2, free)
            return (
                jax.lax.all_gather(p_l, BUILD_AXIS, axis=0, tiled=True),
                jax.lax.all_gather(d_l, BUILD_AXIS, axis=0, tiled=True),
                jax.lax.all_gather(h_l, BUILD_AXIS, axis=0, tiled=True),
            )

        cond, body, init = _round_cond_body(estep, n, n_real, K, max_rounds)
        init = (init[0], jnp.full((K,), capacity, jnp.int32), init[2], init[3])
        assign, free, _, _ = jax.lax.while_loop(cond, body, init)
        return assign, free

    return run(x_sharded, cents)


def _row_gather(x, rows: np.ndarray) -> np.ndarray:
    """Rows of ``x`` whether it is an ndarray or an EmbeddingStore."""
    from repro.data.store import is_store

    return x.read_rows(rows) if is_store(x) else x[rows]


def _force_place_host(x, cents, assign, free, chunk: int = 8192):
    """Place stragglers (rows unassigned after ``max_rounds``) into their
    nearest centroid with space — O(T·K) host *compute*, chunked so the
    live distance block never exceeds (chunk, K) even if contention drives
    T toward N. ``x`` may be an array or a disk-backed store."""
    todo = np.flatnonzero(assign < 0)
    if todo.size == 0:
        return assign, 0
    for s in range(0, todo.size, chunk):
        block = todo[s : s + chunk]
        d2 = _np_dist2(_row_gather(x, block), cents)
        for t, row in zip(block, np.argsort(d2, axis=1)):
            for c in row:
                if free[c] > 0:
                    assign[t] = c
                    free[c] -= 1
                    break
    if (assign < 0).any():
        raise RuntimeError("capacity assignment: total capacity < N")
    return assign, int(todo.size)


def capacity_assign_device(
    x: np.ndarray,
    cents: np.ndarray,
    capacity: int,
    *,
    impl="auto",
    block: int = 16384,
    max_rounds: int = 16,
    n_cand: int = 32,
) -> np.ndarray:
    """Device-resident capacity-bounded assignment (single-device form).

    The round semantics match :func:`repro.index.kmeans.capacity_assign`
    (the host NumPy oracle): unassigned points bid for their nearest
    centroid with free capacity; each centroid admits its ``free`` closest
    bidders, ties broken by original index. (A point whose ``n_cand``
    nearest centroids all fill is force-placed by the straggler pass —
    the one place the two can differ, and only under extreme contention.)
    Returns ``assign`` (N,) int64.
    """
    from repro.kernels import registry

    resolved = registry.resolve("pairwise", impl)
    assign, free = _capacity_rounds_local(
        jnp.asarray(x),
        jnp.asarray(cents, jnp.float32),
        capacity,
        resolved,
        max(1, min(block, x.shape[0])),
        max_rounds,
        n_cand,
    )
    assign = np.asarray(assign).astype(np.int64)
    assign, _ = _force_place_host(
        np.asarray(x), np.asarray(cents), assign, np.asarray(free).copy()
    )
    return assign


# ---------------------------------------------------------------------------
# Cluster-major permutation: one argsort/scatter jit
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_clusters", "capacity"))
def _permutation_from_assign(assign, n_clusters, capacity):
    """assign (N,) → (perm (N,), counts (K,)) on device.

    row = cluster · capacity + slot, slots in stable original-index order —
    identical layout to the seed's per-cluster host loop, vectorised. Only
    O(N + K) integer state; the (K·C, D) row buffer itself is one host
    memcpy of the (host-resident) input, done per consumer: whole for the
    local kNN stage, shard-by-shard for the sharded one.
    """
    n = assign.shape[0]
    order = jnp.argsort(assign, stable=True)
    counts = jnp.zeros((n_clusters,), jnp.int32).at[assign].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )
    a_sorted = assign[order]
    slot = jnp.arange(n, dtype=jnp.int32) - starts[a_sorted]
    rows = a_sorted * capacity + slot
    perm = jnp.zeros((n,), jnp.int32).at[order].set(rows)
    return perm, counts


def _scatter_rows_host(x, perm, n_clusters, capacity):
    """x_rows (K·C, D) in the caller's dtype — one vectorised host scatter."""
    x_rows = np.zeros((n_clusters * capacity, x.shape[1]), x.dtype)
    x_rows[perm] = x
    return x_rows


def _finalize_knn(knn_local, knn_w, K: int, C: int):
    """(K, C, k) in-cluster slots → (K·C, k) global rows; dead edges → self."""
    knn_local = np.asarray(knn_local)
    knn_w = np.asarray(knn_w).reshape(K * C, -1)
    base = (np.arange(K) * C)[:, None, None]
    knn_idx = (knn_local + base).reshape(K * C, -1).astype(np.int64)
    self_rows = np.arange(K * C)[:, None]
    knn_idx = np.where(knn_w > 0, knn_idx, self_rows)
    return knn_idx, knn_w.astype(np.float32)


# ---------------------------------------------------------------------------
# Streamed (out-of-core) stages: disk-backed stores, O(chunk) host RSS
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, donate_argnums=(0, 1), static_argnames=("n_cand", "impl", "block")
)
def _cand_write_chunk(cand_idx, cand_d2, xb, start, cents, n_cand, impl, block):
    """One streamed chunk of the candidate pass: top-R centroids of the
    chunk's rows written into the device-resident (N_pad, R) cache. The
    cache is donated, so the update is in-place where the backend allows."""
    idx, d2 = _candidate_pass(xb, cents, n_cand, impl, block)
    cand_idx = jax.lax.dynamic_update_slice(cand_idx, idx, (start, 0))
    cand_d2 = jax.lax.dynamic_update_slice(cand_d2, d2, (start, 0))
    return cand_idx, cand_d2


@functools.partial(
    jax.jit, static_argnames=("n_clusters", "capacity", "max_rounds", "n_real")
)
def _capacity_rounds_cached(
    cand_idx, cand_d2, n_clusters, capacity, max_rounds, n_real
):
    """The bidding rounds of ``_capacity_rounds_local`` over a candidate
    cache built elsewhere (the streamed pass) — same round semantics, same
    carried O(N + K) state. Rows beyond ``n_real`` are chunk padding and
    never bid."""
    n = cand_idx.shape[0]
    cond, body, init = _round_cond_body(
        lambda free: _bid_from_candidates(cand_idx, cand_d2, free),
        n,
        n_real,
        n_clusters,
        max_rounds,
    )
    init = (init[0], jnp.full((n_clusters,), capacity, jnp.int32), init[2], init[3])
    assign, free, _, _ = jax.lax.while_loop(cond, body, init)
    return assign, free


def _resolve_spill_dir(cfg: NomadConfig, store) -> str:
    """Where a streamed build spills the cluster-major ``x_rows`` store.

    Deterministic locations first: ``cfg.checkpoint_dir/x_rows_spill-<tag>``
    when the fit owns a checkpoint directory, else a sibling of the input
    store (``<path>.x_rows-<tag>``). The tag hashes the full config + the
    store path, so a refit with the *same* config overwrites its own spill
    (whose bytes it reproduces) while a different config — a sweep over
    seeds, cluster counts, dtypes — gets its own directory and can never
    corrupt the ``x_rows`` a still-live ``AnnIndex`` references. Only when
    neither location is writable does it fall back to a fresh system temp
    dir (beware: /tmp is often RAM-backed tmpfs — point checkpoint_dir at
    real disk for truly big corpora).
    """
    import hashlib
    import tempfile

    tag = hashlib.sha256(
        (repr(sorted(dataclasses.asdict(cfg).items())) + str(store.path)).encode()
    ).hexdigest()[:8]
    candidates = []
    if cfg.checkpoint_dir:
        candidates.append(
            os.path.join(cfg.checkpoint_dir, "x_rows_spill-" + tag)
        )
    if store.path:
        candidates.append(str(store.path).rstrip("/\\") + ".x_rows-" + tag)
    for cand in candidates:
        try:
            os.makedirs(cand, exist_ok=True)
            # per-process probe name: concurrent jax.distributed processes
            # probe the same candidate dir and must not race each other
            probe = os.path.join(cand, f".write-probe-{os.getpid()}")
            with open(probe, "w"):
                pass
            os.remove(probe)
            return cand
        except OSError:
            continue
    return tempfile.mkdtemp(prefix="repro-x-rows-")


def _spill_sharded_scatter(
    store, perm: np.ndarray, n_rows: int, dim: int, out_dir: str, dtype: str,
    chunk_rows: int, rows_per_shard: int = 65536, max_shards: int = 256,
):
    """Stream the input store once and scatter ``row i → perm[i]`` into a
    sharded on-disk store of ``n_rows`` rows — the cluster-major ``x_rows``
    layout without ever holding it (or the input) in host RAM. Shards are
    pre-created as writable memmaps; each chunk's rows are grouped by
    destination shard and written in one fancy-indexed slice per shard.
    The scatter touches every shard per chunk, so all shard memmaps stay
    open — ``max_shards`` caps the fd count (shards grow instead) to stay
    far under default ulimits at any N.
    """
    from repro.data.store import (
        SHARD_PATTERN,
        ShardedStore,
        _commit_meta,
        _disk_dtype,
        _encode,
        stream_chunks,
    )

    os.makedirs(out_dir, exist_ok=True)
    rows_per_shard = max(rows_per_shard, -(-n_rows // max_shards))
    rows_per_shard = max(1, min(rows_per_shard, n_rows))
    n_shards = -(-n_rows // rows_per_shard)
    shard_rows = [
        min(rows_per_shard, n_rows - j * rows_per_shard) for j in range(n_shards)
    ]
    starts = np.concatenate([[0], np.cumsum(shard_rows)])
    files, mms = [], []
    for j in range(n_shards):
        name = SHARD_PATTERN.format(j)
        files.append(name)
        mms.append(
            np.lib.format.open_memmap(
                os.path.join(out_dir, name),
                mode="w+",
                dtype=_disk_dtype(dtype),
                shape=(shard_rows[j], dim),
            )
        )
    for s, chunk in stream_chunks(store, chunk_rows):
        targets = perm[s : s + chunk.shape[0]]
        order = np.argsort(targets, kind="stable")
        t_sorted = targets[order]
        enc = _encode(chunk, dtype)[order]
        bounds = np.searchsorted(t_sorted, starts)
        for j in range(n_shards):
            lo, hi = bounds[j], bounds[j + 1]
            if lo == hi:
                continue
            mms[j][t_sorted[lo:hi] - starts[j]] = enc[lo:hi]
    for mm in mms:
        mm.flush()
    del mms
    _commit_meta(out_dir, n_rows, dim, dtype, files, shard_rows)
    return ShardedStore(out_dir)


# ---------------------------------------------------------------------------
# The builder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BuildReport:
    """Provenance of one index build (feeds FitResult + benchmarks)."""

    strategy: str
    n_shards: int
    total_s: float
    stage_s: dict  # {"kmeans" | "assign" | "permute" | "knn": seconds}
    stage_rss_mb: dict  # high-watermark host RSS at the end of each stage
    stragglers: int = 0


def _rss_mb() -> float:
    try:
        import resource
        import sys

        rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        # ru_maxrss is kilobytes on Linux, bytes on macOS
        return rss / (1024.0 * 1024.0) if sys.platform == "darwin" else rss / 1024.0
    except Exception:  # non-POSIX platform
        return 0.0


def resolve_build_strategy(
    spec: str, cfg: NomadConfig, mesh: Optional[Mesh] = None
):
    """``"auto"|"local"|"sharded"|"distributed"`` →
    ("local", None) | ("sharded"|"distributed", Mesh).

    The build mesh is one flat axis over the largest cluster-divisible
    prefix of the available devices (the training mesh's devices when the
    estimator passes one in, else ``jax.devices()`` — the **global** pool
    under ``jax.distributed``); ``"auto"`` picks sharded exactly when that
    mesh is wider than one device. ``"distributed"`` runs the same
    collective program but reads/places rows per process; under multiple
    processes every global device must participate, so ``n_clusters`` must
    divide evenly (a truncated mesh would orphan some process's devices).
    """
    from repro.core.strategy import flat_mesh, largest_divisor_leq

    spec = spec or "auto"
    if spec not in ("auto", "local", "sharded", "distributed"):
        raise ValueError(
            f"unknown build_strategy {spec!r} "
            "(want 'auto'|'local'|'sharded'|'distributed')"
        )
    if spec == "local":
        return "local", None
    devs = list(mesh.devices.reshape(-1)) if mesh is not None else jax.devices()
    width = largest_divisor_leq(cfg.n_clusters, len(devs))
    if spec == "distributed":
        if jax.process_count() > 1 and width != len(devs):
            raise ValueError(
                f"build_strategy='distributed': n_clusters={cfg.n_clusters} "
                f"must be divisible by the global device count {len(devs)} "
                f"({jax.process_count()} processes) — every process's "
                "devices must join the build mesh"
            )
        return "distributed", flat_mesh(devs[:width], BUILD_AXIS)
    if spec == "auto" and width == 1:
        return "local", None
    return "sharded", flat_mesh(devs[:width], BUILD_AXIS)


class IndexBuilder:
    """Builds the §3.2 :class:`AnnIndex` on device, locally or sharded.

    Mirrors the training strategy layer: ``strategy`` (default
    ``cfg.build_strategy``) is ``"auto"|"local"|"sharded"``; ``mesh`` (the
    estimator's training mesh, if any) supplies the device pool. After
    ``build`` the per-stage wall times and peak host RSS sit in
    :attr:`report` (a :class:`BuildReport`).
    """

    def __init__(
        self,
        cfg: NomadConfig,
        *,
        strategy: Optional[str] = None,
        mesh: Optional[Mesh] = None,
        impl=None,
    ):
        self.cfg = cfg
        self.spec = strategy if strategy is not None else cfg.build_strategy
        self.mesh = mesh
        self.impl = impl if impl is not None else cfg.resolved_kernel_impl()
        self.report: Optional[BuildReport] = None

    # -- the one build -------------------------------------------------------

    def build(self, x) -> AnnIndex:
        from repro.data.store import as_store, is_store

        cfg = self.cfg
        n, d = x.shape  # ndarray and EmbeddingStore both expose .shape
        K, C = cfg.n_clusters, cfg.cluster_capacity
        if K * C < n:
            raise ValueError(f"capacity {C}×{K} < N={n}; raise capacity_slack")
        # multi-process jax (or an explicit "distributed" spec) takes the
        # cross-process path first: the streamed pipeline's sequential
        # chunk accumulation cannot be split across processes bit-equally,
        # so distributed builds reuse the sharded collective program over
        # the global mesh with per-process row reads instead
        if self.spec == "distributed" or jax.process_count() > 1:
            name, mesh = resolve_build_strategy("distributed", cfg, self.mesh)
        else:
            # a store input — or an explicit cfg.chunk_rows — selects the
            # out-of-core streamed pipeline; chunking fixes the accumulation
            # order, so the two containers produce bit-identical indices
            streamed = is_store(x) or cfg.chunk_rows > 0
            name, mesh = (
                ("streamed", None)
                if streamed
                else resolve_build_strategy(self.spec, cfg, self.mesh)
            )

        stage_s: dict = {}
        stage_rss: dict = {}

        @contextmanager
        def stage(label):
            t0 = time.time()
            yield
            # accumulate: the straggler force-place re-enters "assign"
            stage_s[label] = stage_s.get(label, 0.0) + (time.time() - t0)
            stage_rss[label] = _rss_mb()

        t0 = time.time()
        if name == "streamed":
            index, stragglers = self._build_streamed(as_store(x), stage)
            n_shards = 1
        elif name == "local":
            index, stragglers = self._build_local(x, stage)
            n_shards = 1
        elif name == "distributed":
            index, stragglers = self._build_distributed(as_store(x), mesh, stage)
            n_shards = mesh.shape[BUILD_AXIS]
        else:
            index, stragglers = self._build_sharded(x, mesh, stage)
            n_shards = mesh.shape[BUILD_AXIS]
        self.report = BuildReport(
            strategy=name,
            n_shards=n_shards,
            total_s=time.time() - t0,
            stage_s=stage_s,
            stage_rss_mb=stage_rss,
            stragglers=stragglers,
        )
        return index

    # -- stages ----------------------------------------------------------------

    def _assemble(self, x, cents, x_rows, perm, counts, knn_local, knn_w):
        cfg = self.cfg
        K, C = cfg.n_clusters, cfg.cluster_capacity
        knn_idx, knn_w = _finalize_knn(knn_local, knn_w, K, C)
        return AnnIndex(
            x_rows=x_rows,
            knn_idx=knn_idx,
            knn_w=knn_w,
            counts=np.asarray(counts).astype(np.int64),
            centroids=np.asarray(cents),
            perm=perm,
            capacity=C,
            n_points=x.shape[0],
            fingerprint=data_fingerprint(x),
        )

    def _finish(self, x, cents, assign_d, free_d, stage, knn_fn):
        """The strategy-independent tail: straggler force-place → permute →
        kNN (``knn_fn`` is the one per-strategy piece) → assemble. One body
        for both paths keeps sharded ≡ local by construction."""
        cfg = self.cfg
        n, d = x.shape
        K, C = cfg.n_clusters, cfg.cluster_capacity

        with stage("assign"):  # stragglers are assign work (times accumulate)
            assign = np.asarray(assign_d)[:n].astype(np.int64)
            assign, stragglers = _force_place_host(
                x, np.asarray(cents), assign, np.asarray(free_d).copy()
            )

        with stage("permute"):
            perm_d, counts = _permutation_from_assign(
                jnp.asarray(assign, jnp.int32), K, C
            )
            perm = np.asarray(perm_d).astype(np.int64)
            x_rows = _scatter_rows_host(x, perm, K, C)

        with stage("knn"):
            knn_local, knn_w = knn_fn(
                np.asarray(x_rows, np.float32).reshape(K, C, d), counts
            )
            jax.block_until_ready(knn_w)

        return (
            self._assemble(x, cents, x_rows, perm, counts, knn_local, knn_w),
            stragglers,
        )

    def _build_local(self, x, stage):
        from repro.kernels import registry

        cfg = self.cfg
        n = x.shape[0]
        K, C, k = cfg.n_clusters, cfg.cluster_capacity, cfg.n_neighbors
        block = cfg.build_block_rows
        key = jax.random.key(cfg.seed)
        xd = jnp.asarray(x)

        with stage("kmeans"):
            cents = km.kmeans_centroids(
                key,
                xd,
                K,
                n_iters=cfg.kmeans_iters,
                tol=cfg.kmeans_tol,
                impl=self.impl,
                block=block,
            )
            jax.block_until_ready(cents)

        with stage("assign"):
            assign_d, free_d = _capacity_rounds_local(
                xd,
                cents,
                C,
                registry.resolve("pairwise", self.impl),
                max(1, min(block, n)),
                cfg.build_max_rounds,
                cfg.build_candidates,
            )

        def knn_fn(x_blocks_host, counts):
            valid = jnp.arange(C)[None, :] < counts[:, None]
            return batched_cluster_knn(
                jnp.asarray(x_blocks_host), valid, k, self.impl
            )

        return self._finish(x, cents, assign_d, free_d, stage, knn_fn)

    def _build_streamed(self, store, stage):
        """The out-of-core build: every §3.2 stage consumes the corpus as a
        double-buffered stream of ``cfg.resolved_chunk_rows()``-row chunks
        (``repro.data.store.stream_chunks`` → ``data/loader.py``'s
        ``Prefetcher``), so peak host RSS is O(chunk + K·D) — plus the
        O(N·k) kNN graph that *is* the product — instead of O(N·D).
        Device state adds the O(N·R) candidate cache of the capacity
        assignment (R = ``cfg.build_candidates``; the full (N, D) never
        lands anywhere). When the input store is disk-backed the permuted
        cluster-major ``x_rows`` is scattered straight into a disk-backed
        sharded store (dtype ``cfg.store_dtype``) as the stream passes.

        Chunk boundaries depend only on (N, chunk_rows), never on the
        store's native shard layout, so a sharded/memmap store and an
        in-memory array holding the same rows build bit-identical indices.
        """
        from repro.data.store import ArrayStore, stream_chunks
        from repro.index.kmeans import _pad_chunk
        from repro.kernels import registry

        cfg = self.cfg
        n, d = store.shape
        K, C, k = cfg.n_clusters, cfg.cluster_capacity, cfg.n_neighbors
        chunk = max(1, min(cfg.resolved_chunk_rows(), n))
        blk = max(1, min(cfg.build_block_rows, chunk))
        impl = registry.resolve("pairwise", self.impl)
        key = jax.random.key(cfg.seed)

        with stage("kmeans"):
            cents = km.kmeans_centroids_streamed(
                key,
                store,
                K,
                chunk_rows=chunk,
                n_iters=cfg.kmeans_iters,
                tol=cfg.kmeans_tol,
                impl=self.impl,
                block=cfg.build_block_rows,
            )
            jax.block_until_ready(cents)

        with stage("assign"):
            r = min(cfg.build_candidates, K)
            n_pad = -(-n // chunk) * chunk
            cand_idx = jnp.zeros((n_pad, r), jnp.int32)
            cand_d2 = jnp.full((n_pad, r), jnp.inf, jnp.float32)
            for s, ch in stream_chunks(store, chunk):
                xb, _w = _pad_chunk(ch, chunk)
                cand_idx, cand_d2 = _cand_write_chunk(
                    cand_idx,
                    cand_d2,
                    jnp.asarray(xb),
                    jnp.int32(s),
                    cents,
                    cfg.build_candidates,
                    impl,
                    blk,
                )
            assign_d, free_d = _capacity_rounds_cached(
                cand_idx, cand_d2, K, C, cfg.build_max_rounds, n
            )
            assign = np.asarray(assign_d)[:n].astype(np.int64)
            assign, stragglers = _force_place_host(
                store, np.asarray(cents), assign, np.asarray(free_d).copy()
            )

        with stage("permute"):
            perm_d, counts = _permutation_from_assign(
                jnp.asarray(assign, jnp.int32), K, C
            )
            perm = np.asarray(perm_d).astype(np.int64)
            if store.path is not None:  # disk in → disk out
                x_rows = _spill_sharded_scatter(
                    store, perm, K * C, d,
                    _resolve_spill_dir(cfg, store), cfg.store_dtype, chunk,
                    max_shards=cfg.store_max_shards,
                )
            else:  # in-memory store: scatter per chunk into one host buffer
                buf = np.zeros((K * C, d), np.float32)
                for s, ch in store.iter_chunks(chunk):
                    buf[perm[s : s + ch.shape[0]]] = ch
                x_rows = buf

        with stage("knn"):
            counts_h = np.asarray(counts)
            kc = max(1, chunk // C)
            knn_local = np.empty((K, C, k), np.int32)
            knn_w = np.empty((K, C, k), np.float32)
            x_rows_store = x_rows if store.path is not None else ArrayStore(x_rows)
            for s, blk_rows in stream_chunks(x_rows_store, kc * C):
                c0, nb = s // C, blk_rows.shape[0] // C
                valid = (
                    np.arange(C)[None, :] < counts_h[c0 : c0 + nb, None]
                )
                idxb, wb = batched_cluster_knn(
                    jnp.asarray(blk_rows.reshape(nb, C, d)),
                    jnp.asarray(valid),
                    k,
                    self.impl,
                )
                knn_local[c0 : c0 + nb] = np.asarray(idxb)
                knn_w[c0 : c0 + nb] = np.asarray(wb)

        return (
            self._assemble(store, cents, x_rows, perm, counts, knn_local, knn_w),
            stragglers,
        )

    def _build_sharded(self, x, mesh, stage):
        from repro.kernels import registry

        cfg = self.cfg
        n, d = x.shape
        K, C, k = cfg.n_clusters, cfg.cluster_capacity, cfg.n_neighbors
        block = cfg.build_block_rows
        n_dev = mesh.shape[BUILD_AXIS]
        key = jax.random.key(cfg.seed)

        # pad rows up to the device count; padding never enters any statistic
        n_pad = -(-n // n_dev) * n_dev
        xp = x if n_pad == n else np.concatenate(
            [x, np.zeros((n_pad - n, d), x.dtype)]
        )
        row_sh = NamedSharding(mesh, P(BUILD_AXIS, None))
        xd = jax.device_put(jnp.asarray(xp), row_sh)

        with stage("kmeans"):
            cents = km.kmeans_fit_sharded(
                key,
                xd,
                K,
                mesh,
                BUILD_AXIS,
                n_iters=cfg.kmeans_iters,
                tol=cfg.kmeans_tol,
                impl=self.impl,
                block=block,
                n_real=n if n_pad != n else None,
            )
            jax.block_until_ready(cents)

        with stage("assign"):
            assign_d, free_d = _capacity_rounds_sharded(
                mesh,
                xd,
                cents,
                C,
                registry.resolve("pairwise", self.impl),
                block,
                cfg.build_max_rounds,
                cfg.build_candidates,
                n,
            )

        def knn_fn(x_blocks_host, counts):
            # device_put from host inside cluster_knn_batch_sharded moves
            # each device only its own cluster blocks — the full (K·C, D)
            # never lands on one device
            return cluster_knn_batch_sharded(
                mesh, BUILD_AXIS, x_blocks_host, counts, k, self.impl
            )

        return self._finish(x, cents, assign_d, free_d, stage, knn_fn)

    def _build_distributed(self, store, mesh, stage):
        """``_build_sharded``'s collective program with per-process data
        movement: each process reads only the contiguous row ranges its own
        devices shard (never all N rows), assembles the global (N_pad, D)
        via ``jax.make_array_from_single_device_arrays``, and the kmeans /
        assign / kNN collectives (one psum, one all_gather per round) span
        the whole ``jax.distributed`` mesh. On a single process this is the
        sharded build bit-for-bit (same jitted programs, same shardings) —
        which is exactly what makes a P-process run verifiable against a
        1-process P-device run.

        The cluster-major ``x_rows`` is spilled cooperatively: every
        process writes the shard files of its own devices' cluster blocks
        (``write_sharded(..., commit=False)`` at its row offset), then
        process 0 commits the metadata after a barrier. Requires a spill
        location all processes resolve identically (``cfg.checkpoint_dir``
        or a disk-backed input store); the kNN stage reuses the in-RAM
        per-device blocks, so the spill is never read back during the
        build.
        """
        from repro.core.strategy import fetch_global, sync_processes
        from repro.data.store import (
            ShardedStore,
            commit_sharded_meta,
            write_sharded,
        )
        from repro.kernels import registry

        cfg = self.cfg
        n, d = store.shape
        K, C, k = cfg.n_clusters, cfg.cluster_capacity, cfg.n_neighbors
        block = cfg.build_block_rows
        n_dev = mesh.shape[BUILD_AXIS]
        devs = list(mesh.devices.reshape(-1))
        pid = jax.process_index()
        n_proc = jax.process_count()
        key = jax.random.key(cfg.seed)

        n_pad = -(-n // n_dev) * n_dev
        rows_per = n_pad // n_dev
        row_sh = NamedSharding(mesh, P(BUILD_AXIS, None))

        with stage("place"):
            pieces = []
            for di, dev in enumerate(devs):
                if dev.process_index != pid:
                    continue
                lo = min(di * rows_per, n)
                hi = min(lo + rows_per, n)
                blk_rows = store.read(lo, hi)
                if blk_rows.shape[0] < rows_per:  # tail padding, one device
                    blk_rows = np.concatenate(
                        [blk_rows,
                         np.zeros((rows_per - blk_rows.shape[0], d), np.float32)]
                    )
                pieces.append(jax.device_put(jnp.asarray(blk_rows), dev))
            xd = jax.make_array_from_single_device_arrays(
                (n_pad, d), row_sh, pieces
            )

        with stage("kmeans"):
            cents = km.kmeans_fit_sharded(
                key,
                xd,
                K,
                mesh,
                BUILD_AXIS,
                n_iters=cfg.kmeans_iters,
                tol=cfg.kmeans_tol,
                impl=self.impl,
                block=block,
                n_real=n if n_pad != n else None,
            )
            jax.block_until_ready(cents)
        cents_h = fetch_global(cents)  # replicated → local copy everywhere

        with stage("assign"):
            assign_d, free_d = _capacity_rounds_sharded(
                mesh,
                xd,
                cents,
                C,
                registry.resolve("pairwise", self.impl),
                block,
                cfg.build_max_rounds,
                cfg.build_candidates,
                n,
            )
            # replicated outputs; the straggler pass runs identically on
            # every process (deterministic host math over shared inputs)
            assign = fetch_global(assign_d)[:n].astype(np.int64)
            assign, stragglers = _force_place_host(
                store, cents_h, assign, fetch_global(free_d).copy()
            )

        with stage("permute"):
            perm_d, counts = _permutation_from_assign(
                jnp.asarray(assign, jnp.int32), K, C
            )
            perm = np.asarray(perm_d).astype(np.int64)
            counts_h = np.asarray(counts)
            # gather each local device's cluster blocks from the store:
            # device di owns clusters [di·K/n_dev, (di+1)·K/n_dev), i.e.
            # x_rows rows [di·rps, (di+1)·rps) with rps = (K/n_dev)·C
            Kl = K // n_dev
            rps = Kl * C
            local = [di for di, dev in enumerate(devs)
                     if dev.process_index == pid]
            blocks = []
            for di in local:
                lo = di * rps
                sel = (perm >= lo) & (perm < lo + rps)
                src = np.flatnonzero(sel)
                xloc = np.zeros((rps, d), np.float32)
                xloc[perm[src] - lo] = store.read_rows(src)
                blocks.append(xloc)
            if n_proc > 1:
                if not (cfg.checkpoint_dir or store.path):
                    raise ValueError(
                        "distributed build: the x_rows spill needs a "
                        "location every process resolves identically — "
                        "set cfg.checkpoint_dir or build from a "
                        "disk-backed store (the temp-dir fallback differs "
                        "per process)"
                    )
                spill_dir = _resolve_spill_dir(cfg, store)
                for di, xloc in zip(local, blocks):
                    write_sharded(
                        [xloc],
                        spill_dir,
                        rows_per_shard=rps,
                        dtype=cfg.store_dtype,
                        row_offset=di * rps,
                        total_rows=K * C,
                        commit=False,
                    )
                sync_processes("x-rows-spill")
                if pid == 0:
                    commit_sharded_meta(
                        spill_dir, K * C, d,
                        rows_per_shard=rps, dtype=cfg.store_dtype,
                    )
                sync_processes("x-rows-commit")
                x_rows = ShardedStore(spill_dir)
            else:
                x_rows = np.concatenate(blocks)  # (K·C, D) host, like sharded

        with stage("knn"):
            blk_sh = NamedSharding(mesh, P(BUILD_AXIS, None, None))
            xb = jax.make_array_from_single_device_arrays(
                (K, C, d),
                blk_sh,
                [jax.device_put(jnp.asarray(xloc.reshape(Kl, C, d)), devs[di])
                 for di, xloc in zip(local, blocks)],
            )
            knn_local_d, knn_w_d = cluster_knn_batch_sharded(
                mesh, BUILD_AXIS, xb, counts_h, k, self.impl
            )
            knn_local = fetch_global(knn_local_d)
            knn_w = fetch_global(knn_w_d)

        return (
            self._assemble(
                store, cents_h, x_rows, perm, counts_h, knn_local, knn_w
            ),
            stragglers,
        )

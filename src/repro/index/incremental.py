"""Incremental admission of appended rows into a fitted §3.2 index.

The ``partial_fit`` subsystem's index half: given the previous
:class:`~repro.index.ann.AnnIndex` + cluster-major θ buffer and a batch of
new rows (already *placed* on the frozen map by the serve path), produce
the grown index without rebuilding the world:

1. **admit** — each new row targets its placement cell (nearest frozen
   centroid). Cells whose ``counts + incoming`` stay within capacity take
   the rows into their padding slots — the existing members, their rows,
   their kNN entries and the cell centroid are all bit-untouched.
2. **split / re-seed** — an overflowing cell is re-seeded: its members
   (old + incoming) run a small LSH-init k-means into enough sub-cells to
   restore the build's average fill, then the same capacity-bounded
   bidding (:func:`~repro.index.build.capacity_assign_device`) the full
   build uses. The first sub-cell keeps the original cell id (so every
   *other* cell's global rows stay put); the rest append new cell blocks
   at the end of the layout — K grows, capacity C never changes.
3. **patch** — the in-cluster kNN graph is recomputed **only** for the
   affected cells (one :func:`~repro.index.knn.batched_cluster_knn` over
   their blocks, identical math to the full build); ``x_rows`` is patched
   by block copy (ndarray) or rewritten shard-aligned into a fresh
   sharded store (``write_sharded(row_offset=…)`` regions + one
   ``commit_sharded_meta`` publish — the store path, unchanged shards
   streamed straight from the previous version's store).

The returned layout keeps the invariant every consumer relies on:
``row = cell * capacity + slot``; rows of *unaffected* cells are
bit-identical to the previous version, which is what makes the cheap
refinement epochs (restricted to ``affected_cells``) safe.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NomadConfig
from repro.index.ann import AnnIndex, data_fingerprint


@dataclasses.dataclass
class PartialUpdate:
    """What one admission pass produced (the index half of partial_fit)."""

    index: AnnIndex  # grown index (K' ≥ K cells, same capacity)
    theta_rows: np.ndarray  # (K'·C, out_dim) patched cluster-major θ
    affected_cells: np.ndarray  # (A,) sorted global ids of cells that changed
    n_split_cells: int  # overflowing cells that were re-seeded
    n_new_cells: int  # cells appended to the layout (K' - K)
    stage_s: Dict[str, float]  # {"admit": s, "patch_knn": s, "patch_rows": s}


def chained_fingerprint(parent_fp: str, new_x: np.ndarray) -> str:
    """Version fingerprint of an append: hash(parent fp ∥ fp(new rows)).

    Content-derived and order-sensitive — the same base map growing by the
    same batches hashes identically, any divergence doesn't — without ever
    re-reading the full corpus (the original rows live only in ``x_rows``).
    """
    h = hashlib.sha256()
    h.update(parent_fp.encode())
    h.update(data_fingerprint(new_x).encode())
    return h.hexdigest()[:16]


def _split_fill_target(cfg: NomadConfig, capacity: int) -> int:
    # the average fill the original build aims for (C = slack·N/K ⇒ fill
    # N/K = C/slack): re-seeded sub-cells keep the same headroom for the
    # *next* append instead of being born full
    return max(1, min(capacity, int(capacity / cfg.capacity_slack)))


def _read_rows(x_rows, lo: int, hi: int) -> np.ndarray:
    from repro.data.store import is_store

    if is_store(x_rows):
        return np.asarray(x_rows.read(lo, hi), np.float32)
    return np.asarray(x_rows[lo:hi], np.float32)


def _patch_store_x_rows(
    old_store,
    changed: Dict[int, np.ndarray],
    K: int,
    K2: int,
    C: int,
    dim: int,
    out_dir: str,
    cfg: NomadConfig,
):
    """Rewrite a store-backed ``x_rows`` into ``out_dir`` with the patch.

    Shards are ``g·C`` rows with ``g`` a divisor of K, so the appended
    region starts on a shard boundary: region ``[0, K·C)`` (unchanged
    blocks streamed from the old store, changed blocks from RAM) and
    region ``[K·C, K'·C)`` (the new cells) are written as two
    ``write_sharded(commit=False)`` ranges, then published by one
    ``commit_sharded_meta`` — the same two-writer protocol a multi-process
    spill uses, here separating "previous layout" from "appended cells".
    """
    from repro.core.strategy import largest_divisor_leq
    from repro.data.store import commit_sharded_meta, write_sharded

    g = largest_divisor_leq(K, max(1, 65536 // C))
    divisors = [d for d in range(g, K + 1) if K % d == 0]
    for d in divisors:  # fd ceiling: coarsen shards until the count fits
        g = d
        if -(-K2 // g) <= max(1, cfg.store_max_shards):
            break
    rps = g * C

    def old_region():
        c = 0
        while c < K:
            if c in changed:
                yield changed[c]
                c += 1
            else:
                end = c + 1
                while end < K and end not in changed and (end - c) < g:
                    end += 1
                yield _read_rows(old_store, c * C, end * C)
                c = end

    write_sharded(
        old_region(),
        out_dir,
        rows_per_shard=rps,
        dtype=cfg.store_dtype,
        row_offset=0,
        total_rows=K2 * C,
        commit=False,
    )
    if K2 > K:
        write_sharded(
            (changed[c] for c in range(K, K2)),
            out_dir,
            rows_per_shard=rps,
            dtype=cfg.store_dtype,
            row_offset=K * C,
            total_rows=K2 * C,
            commit=False,
        )
    return commit_sharded_meta(
        out_dir, K2 * C, dim, rows_per_shard=rps, dtype=cfg.store_dtype
    )


def admit_and_patch(
    index: AnnIndex,
    theta_rows: np.ndarray,
    new_x: np.ndarray,
    new_cells: np.ndarray,
    new_theta: np.ndarray,
    cfg: NomadConfig,
    *,
    impl="auto",
    spill_dir: Optional[str] = None,
) -> PartialUpdate:
    """Admit ``new_x`` (placed at ``new_cells`` with initial positions
    ``new_theta``) into ``index``, patching kNN/x_rows/θ incrementally.

    ``spill_dir`` is where a store-backed ``x_rows`` patch is written
    (required exactly when ``index.x_rows`` is a store). Rows of cells the
    append never touches are bit-identical in every output artifact.
    """
    from repro.data.store import is_store
    from repro.index.build import capacity_assign_device
    from repro.index.kmeans import kmeans_centroids
    from repro.index.knn import batched_cluster_knn

    t0 = time.time()
    K, C = index.n_clusters, index.capacity
    dim = int(index.x_rows.shape[1])
    N, M = index.n_points, int(new_x.shape[0])
    k = int(index.knn_idx.shape[1])
    out_dim = int(theta_rows.shape[1])
    counts = np.asarray(index.counts).astype(np.int64)
    new_cells = np.asarray(new_cells).astype(np.int64)
    new_x = np.ascontiguousarray(new_x, np.float32)
    new_theta = np.asarray(new_theta, np.float32)
    theta_full = np.asarray(theta_rows, np.float32)

    if new_cells.shape != (M,):
        raise ValueError(f"new_cells {new_cells.shape} must be ({M},)")
    if new_cells.size and (new_cells.min() < 0 or new_cells.max() >= K):
        raise ValueError("new_cells must index the previous layout's cells")

    inc = np.bincount(new_cells, minlength=K)
    split_cells = np.flatnonzero(counts + inc > C)
    split_set = set(int(c) for c in split_cells)

    # original point id per row of the OLD layout (for re-permuting splits)
    row_owner = np.full(K * C, -1, np.int64)
    row_owner[np.asarray(index.perm)] = np.arange(N)

    # ---- plan: appends into free slots vs full cell re-seeds ---------------
    appends: Dict[int, np.ndarray] = {}
    for c in np.unique(new_cells):
        if int(c) not in split_set:
            appends[int(c)] = np.flatnonzero(new_cells == c)

    # per-cell rewrite plan: cell -> (orig ids slot-ordered, x block, θ block)
    rewrites: Dict[int, tuple] = {}
    new_centroids: Dict[int, np.ndarray] = {}
    next_cell = K
    fill = _split_fill_target(cfg, C)
    key_base = jax.random.fold_in(jax.random.key(cfg.seed + 7), N)
    for c in split_cells:
        c = int(c)
        cnt = int(counts[c])
        old_x = _read_rows(index.x_rows, c * C, c * C + cnt)
        old_ids = row_owner[c * C : c * C + cnt]
        j_new = np.flatnonzero(new_cells == c)
        mem_x = np.concatenate([old_x, new_x[j_new]], axis=0)
        mem_ids = np.concatenate([old_ids, N + j_new])
        mem_th = np.concatenate(
            [theta_full[c * C : c * C + cnt], new_theta[j_new]], axis=0
        )
        total = mem_x.shape[0]
        n_sub = max(2, -(-total // fill))
        key_c = jax.random.fold_in(key_base, c)
        cents = np.asarray(
            kmeans_centroids(
                key_c,
                jnp.asarray(mem_x),
                n_sub,
                n_iters=cfg.kmeans_iters,
                tol=cfg.kmeans_tol,
                impl=impl,
            )
        )
        sub = capacity_assign_device(
            mem_x,
            cents,
            C,
            impl=impl,
            max_rounds=cfg.build_max_rounds,
            n_cand=min(cfg.build_candidates, n_sub),
        )
        # non-empty sub-cells only; the first keeps the original cell id so
        # every other cell's global row numbering survives the split
        members = [np.flatnonzero(sub == s) for s in range(n_sub)]
        members = [m for m in members if m.size]
        for s_i, m in enumerate(members):
            cell_id = c if s_i == 0 else next_cell
            if s_i > 0:
                next_cell += 1
            xb = np.zeros((C, dim), np.float32)
            xb[: m.size] = mem_x[m]
            tb = np.zeros((C, out_dim), np.float32)
            tb[: m.size] = mem_th[m]
            rewrites[cell_id] = (mem_ids[m], xb, tb)
            new_centroids[cell_id] = (
                mem_x[m].mean(axis=0, dtype=np.float64).astype(np.float32)
            )

    K2 = next_cell
    n_new_cells = K2 - K

    # ---- assemble the grown layout ----------------------------------------
    counts2 = np.zeros((K2,), counts.dtype)
    counts2[:K] = counts
    centroids2 = np.zeros((K2, dim), np.float32)
    centroids2[:K] = np.asarray(index.centroids, np.float32)
    perm2 = np.zeros((N + M,), np.int64)
    perm2[:N] = np.asarray(index.perm)
    theta2 = np.zeros((K2 * C, out_dim), np.float32)
    theta2[: K * C] = theta_full

    # blocks whose content changes (re-used by both x_rows paths + the kNN
    # re-pass — affected cells are exactly the changed blocks)
    changed: Dict[int, np.ndarray] = {}
    for c, (ids, xb, tb) in rewrites.items():
        counts2[c] = ids.size
        centroids2[c] = new_centroids[c]
        perm2[ids] = c * C + np.arange(ids.size)
        theta2[c * C : (c + 1) * C] = tb
        changed[c] = xb
    for c, j_list in appends.items():
        base = int(counts[c])
        xb = np.zeros((C, dim), np.float32)
        xb[: base + j_list.size] = np.concatenate(
            [_read_rows(index.x_rows, c * C, c * C + base), new_x[j_list]]
        )
        changed[c] = xb
        rows = c * C + base + np.arange(j_list.size)
        perm2[N + j_list] = rows
        theta2[rows] = new_theta[j_list]
        counts2[c] = base + j_list.size
        # centroid deliberately frozen: admission must not move the
        # geometry other cells' placements were computed against

    stage_admit = time.time() - t0

    # ---- kNN patch: recompute only the affected cells' blocks --------------
    t1 = time.time()
    affected = np.array(sorted(changed), np.int64)
    knn_idx2 = np.zeros((K2 * C, k), index.knn_idx.dtype)
    knn_idx2[: K * C] = index.knn_idx
    knn_idx2[K * C :] = np.arange(K * C, K2 * C)[:, None]  # self = dead edge
    knn_w2 = np.zeros((K2 * C, k), np.float32)
    knn_w2[: K * C] = index.knn_w
    if affected.size:
        x_blocks = np.stack([changed[int(c)] for c in affected])
        valid = np.arange(C)[None, :] < counts2[affected][:, None]
        knn_local, knn_w_aff = batched_cluster_knn(
            jnp.asarray(x_blocks), jnp.asarray(valid), k, impl
        )
        knn_local = np.asarray(knn_local).astype(np.int64)
        knn_w_aff = np.asarray(knn_w_aff, np.float32)
        base_rows = (affected * C)[:, None, None]
        knn_glob = knn_local + base_rows
        self_rows = base_rows + np.arange(C)[None, :, None]
        knn_glob = np.where(knn_w_aff > 0, knn_glob, self_rows)
        flat_rows = (affected[:, None] * C + np.arange(C)[None, :]).reshape(-1)
        knn_idx2[flat_rows] = knn_glob.reshape(-1, k)
        knn_w2[flat_rows] = knn_w_aff.reshape(-1, k)
    stage_knn = time.time() - t1

    # ---- x_rows patch ------------------------------------------------------
    t2 = time.time()
    if is_store(index.x_rows):
        if not spill_dir:
            raise ValueError(
                "admit_and_patch: index.x_rows is store-backed — pass "
                "spill_dir= for the patched store"
            )
        x_rows2 = _patch_store_x_rows(
            index.x_rows, changed, K, K2, C, dim, spill_dir, cfg
        )
    else:
        x_rows2 = np.zeros((K2 * C, dim), np.asarray(index.x_rows).dtype)
        x_rows2[: K * C] = index.x_rows
        for c, xb in changed.items():
            x_rows2[c * C : (c + 1) * C] = xb
    stage_rows = time.time() - t2

    grown = AnnIndex(
        x_rows=x_rows2,
        knn_idx=knn_idx2,
        knn_w=knn_w2,
        counts=counts2,
        centroids=centroids2,
        perm=perm2,
        capacity=C,
        n_points=N + M,
        fingerprint=chained_fingerprint(index.fingerprint, new_x),
    )
    return PartialUpdate(
        index=grown,
        theta_rows=theta2,
        affected_cells=affected,
        n_split_cells=int(split_cells.size),
        n_new_cells=n_new_cells,
        stage_s={
            "admit": stage_admit,
            "patch_knn": stage_knn,
            "patch_rows": stage_rows,
        },
    )

"""Exact within-cluster kNN + the inverse-rank edge weights (paper §3.2/Eq 6).

Because neighbor candidates are confined to the point's own (padded) cluster
block, every cluster is a connected component of the ANN graph — the paper's
device-locality property for positive forces.

The pairwise-distance matrix dispatches through the kernel registry
(kernel ``"pairwise"``, MXU form ‖x‖²+‖y‖²−2x·yᵀ). Top-k and the rank
matrix stay in jnp (sort-heavy, VPU-bound either way).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.rank_model import edge_weights

BIG = jnp.float32(1e30)


def _pairwise_dist2_jnp(xb: jax.Array) -> jax.Array:
    x2 = jnp.sum(jnp.square(xb), -1)
    d2 = x2[:, None] + x2[None, :] - 2.0 * (xb @ xb.T)
    return jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def _cluster_knn_jit(
    x_block: jax.Array,  # (C, D) one padded cluster
    valid: jax.Array,  # (C,) real-point mask
    k: int,
    impl: str,  # pre-resolved: "pallas" | "jnp"
):
    from repro.kernels import registry

    C = x_block.shape[0]
    xb = x_block.astype(jnp.float32)
    if impl == "pallas":
        d2 = registry.dispatch("pairwise", xb, xb, impl="pallas")
    else:
        d2 = _pairwise_dist2_jnp(xb)
    # mask padding and self for neighbor search
    pad_mask = ~(valid[:, None] & valid[None, :])
    search = d2 + pad_mask * BIG + jnp.eye(C, dtype=jnp.float32) * BIG
    _, knn_idx = jax.lax.top_k(-search, k)  # (C, k) ascending distance
    # ranks use the true distance matrix with padding pushed to the end
    d2_ranked = d2 + pad_mask * BIG
    w = edge_weights(d2_ranked, knn_idx, k, valid)
    return knn_idx.astype(jnp.int32), w


def cluster_knn(x_block, valid, k: int, impl=None, *, use_pallas=None):
    """Returns (knn_idx (C, k) in-cluster slots, weights (C, k) fp32).

    ``impl`` is a registry impl ("auto"|"pallas"|"jnp", legacy bools
    accepted; the ``use_pallas=`` keyword is a deprecated alias); it is
    resolved *outside* the jit so env overrides apply per call, never baked
    into a cached trace.
    """
    from repro.index.kmeans import deprecate_use_pallas
    from repro.kernels import registry

    impl = deprecate_use_pallas(impl, use_pallas, "cluster_knn")
    return _cluster_knn_jit(x_block, valid, k, registry.resolve("pairwise", impl))


def batched_cluster_knn(
    x_blocks: jax.Array, valid: jax.Array, k: int, impl=None, *, use_pallas=None
):
    """vmap over clusters: x_blocks (Kc, C, D), valid (Kc, C)."""
    from repro.index.kmeans import deprecate_use_pallas
    from repro.kernels import registry

    impl = deprecate_use_pallas(impl, use_pallas, "batched_cluster_knn")
    resolved = registry.resolve("pairwise", impl)
    return jax.vmap(lambda xb, vb: _cluster_knn_jit(xb, vb, k, resolved))(
        x_blocks, valid
    )


def query_cluster_knn(
    q: jax.Array,  # (B, D) query vectors
    own: jax.Array,  # (B,) assigned cluster per query
    x_blocks: jax.Array,  # (K, C, D) frozen cluster-major vectors
    counts: jax.Array,  # (K,) real points per cluster
    k: int,
    *,
    block: int = 256,
):
    """Query-only kNN against a *frozen* index: each query searches its own
    assigned (padded) cluster block — the same §3.2 locality the training
    graph uses, so a served point attaches exactly where a refit would put
    its positives.

    Runs in ``block``-row chunks via ``lax.map`` so the gathered
    (block, C, D) tile bounds peak memory regardless of the query count.
    Returns (slot (B, k) in-cluster slots, d2 (B, k) ascending,
    valid (B, k) real-neighbor mask) — per-row math only, so results are
    independent of batching/sharding.
    """
    B, d = q.shape
    C = x_blocks.shape[1]
    block = max(1, min(block, B))
    nb = -(-B // block)
    pad = nb * block - B
    qp = jnp.concatenate([q, jnp.zeros((pad, d), q.dtype)]) if pad else q
    ownp = jnp.concatenate([own, jnp.zeros((pad,), own.dtype)]) if pad else own
    x2 = jnp.sum(jnp.square(x_blocks.astype(jnp.float32)), -1)  # (K, C)

    def one(args):
        qb, ob = args  # (block, D), (block,)
        xb = x_blocks[ob].astype(jnp.float32)  # (block, C, D)
        qf = qb.astype(jnp.float32)
        d2 = (
            jnp.sum(jnp.square(qf), -1)[:, None]
            + x2[ob]
            - 2.0 * jnp.einsum("bd,bcd->bc", qf, xb)
        )
        d2 = jnp.maximum(d2, 0.0)
        invalid = jnp.arange(C)[None, :] >= counts[ob][:, None]
        neg, slot = jax.lax.top_k(-(d2 + invalid * BIG), k)
        return slot.astype(jnp.int32), -neg

    slot, d2 = jax.lax.map(
        one, (qp.reshape(nb, block, d), ownp.reshape(nb, block))
    )
    slot = slot.reshape(nb * block, k)[:B]
    d2 = d2.reshape(nb * block, k)[:B]
    valid = slot < counts[own][:, None]
    valid &= d2 < BIG / 2  # padded-out candidates (cluster smaller than k)
    return slot, jnp.where(valid, d2, 0.0), valid


def cluster_knn_batch_sharded(mesh, axis: str, x_blocks, counts, k: int, impl=None):
    """``batched_cluster_knn`` with the cluster axis sharded over ``axis``.

    Each device runs the kNN of its own contiguous cluster blocks — the
    cluster-component property (§3.2) makes the stage embarrassingly
    parallel, so the only data movement is placing ``x_blocks`` row-sharded.
    On a 1-device mesh this is the local vmap, bit-for-bit.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels import registry

    import numpy as np

    resolved = registry.resolve("pairwise", impl)
    Kc, C, _d = x_blocks.shape
    if Kc % mesh.shape[axis]:
        raise ValueError(
            f"n_clusters={Kc} not divisible by the {mesh.shape[axis]}-device "
            f"build mesh"
        )
    # valid stays a host array: device_put from host works under a
    # multi-process mesh (x_blocks may already be a global jax.Array with
    # this exact sharding — device_put is then the identity)
    valid = np.arange(C)[None, :] < np.asarray(counts)[:, None]
    xb = jax.device_put(x_blocks, NamedSharding(mesh, P(axis, None, None)))
    vb = jax.device_put(valid, NamedSharding(mesh, P(axis, None)))

    @jax.jit
    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None)),
        out_specs=(P(axis, None, None), P(axis, None, None)),
        check_rep=False,
    )
    def run(xb_l, vb_l):
        return jax.vmap(lambda a, b: _cluster_knn_jit(a, b, k, resolved))(xb_l, vb_l)

    return run(xb, vb)

"""LSH-initialised K-means (paper §3.2).

The paper: "We initialize our K-Means clustering using a locally sensitive
hash, run expectation maximization until convergence, and compute exact
nearest neighbors for each point within its cluster."

Every E-step (local, sharded, and the capacity-bidding rounds in
:mod:`repro.index.build`) runs through one row-blocked helper,
:func:`blocked_assign`, which dispatches the distance+argmin inner loop
through the kernel registry (kernel ``"kmeans_assign"``): the fused Pallas
path when resolved, else the jnp oracle per block. Peak live memory is one
``(block, K)`` tile — never ``(N, K)``.

EM itself is a ``lax.scan`` with **on-device convergence**: a ``done`` flag
freezes the carry once the centroid shift drops under ``tol``, so a build
never host-syncs a ``float(shift)`` per iteration. The ``shard_map``
variant (:func:`kmeans_fit_sharded`) runs the same scan body with points
sharded across devices — per-iteration communication is one psum of
(K, D+1) partial statistics, the classic distributed-EM factorisation —
and on a 1-device mesh it is bit-identical to the local scan.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def deprecate_use_pallas(impl, use_pallas, fn_name: str):
    """Shared shim: ``use_pallas=`` keyword → ``impl=`` with a warning."""
    if use_pallas is None:
        return impl
    warnings.warn(
        f"{fn_name}(use_pallas=...) is deprecated; pass "
        "impl='auto'|'pallas'|'jnp' instead",
        DeprecationWarning,
        stacklevel=3,
    )
    return use_pallas if impl is None else impl


def lsh_init_centroids(
    key, x: jax.Array, n_clusters: int, valid=None, n_valid: Optional[int] = None
) -> jax.Array:
    """Random-hyperplane LSH buckets → bucket means as initial centroids.

    b = ceil(log2 K) hyperplanes give 2^b ≥ K buckets; the K most populated
    buckets seed the centroids; empty seats fall back to random points.
    ``valid`` (N,) bool excludes padding rows from the bucket statistics and
    ``n_valid`` bounds the random fallback draw (the sharded build pads N up
    to the device count; padding must enter neither).
    """
    n, d = x.shape
    b = max(1, int(np.ceil(np.log2(n_clusters))))
    kh, kf = jax.random.split(key)
    planes = jax.random.normal(kh, (d, b), jnp.float32)
    bits = (x.astype(jnp.float32) @ planes) > 0  # (n, b)
    codes = jnp.sum(bits * (2 ** jnp.arange(b, dtype=jnp.int32))[None, :], axis=1)
    n_buckets = 2**b
    if valid is None:
        sums = jnp.zeros((n_buckets, d), jnp.float32).at[codes].add(x.astype(jnp.float32))
        cnts = jnp.zeros((n_buckets,), jnp.float32).at[codes].add(1.0)
    else:
        w = valid.astype(jnp.float32)
        sums = jnp.zeros((n_buckets, d), jnp.float32).at[codes].add(
            x.astype(jnp.float32) * w[:, None]
        )
        cnts = jnp.zeros((n_buckets,), jnp.float32).at[codes].add(w)
    order = jnp.argsort(-cnts)  # most populated first
    top = order[:n_clusters]
    cents = sums[top] / jnp.maximum(cnts[top], 1.0)[:, None]
    # empty buckets → random data points (never padding rows)
    fallback = x[
        jax.random.randint(kf, (n_clusters,), 0, n if n_valid is None else n_valid)
    ].astype(jnp.float32)
    return jnp.where((cnts[top] > 0)[:, None], cents, fallback)


def assign_jnp(x: jax.Array, cents: jax.Array, block: int = 16384):
    """Nearest-centroid assignment; returns (assign (n,), min_dist2 (n,))."""
    c2 = jnp.sum(jnp.square(cents), -1)

    def one_block(xb):
        d2 = (
            jnp.sum(jnp.square(xb), -1)[:, None]
            + c2[None, :]
            - 2.0 * xb @ cents.T
        )
        return jnp.argmin(d2, -1).astype(jnp.int32), jnp.min(d2, -1)

    n = x.shape[0]
    if n <= block:
        return one_block(x.astype(jnp.float32))
    outs = [one_block(x[s : s + block].astype(jnp.float32)) for s in range(0, n, block)]
    return jnp.concatenate([o[0] for o in outs]), jnp.concatenate([o[1] for o in outs])


def blocked_assign(x: jax.Array, cents: jax.Array, impl: str, block: int):
    """Row-blocked E-step through the kernel registry.

    ``impl`` must be pre-resolved ("pallas" | "jnp") so the choice is
    static inside any enclosing trace. ``lax.map`` keeps one block live at
    a time: peak memory is (block, K) on the jnp path, the kernel's own
    tiles on the Pallas path — never (N, K).
    """
    from repro.kernels import registry

    n, d = x.shape
    block = max(1, min(block, n))
    nb = -(-n // block)
    pad = nb * block - n
    xp = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)]) if pad else x

    def one(xb):
        return registry.dispatch("kmeans_assign", xb, cents, impl=impl)

    a, d2 = jax.lax.map(one, xp.reshape(nb, block, d))
    return a.reshape(-1)[:n], d2.reshape(-1)[:n]


def _m_step(x, assign, n_clusters, old_cents):
    """Unweighted M-step (the weighted/psum variant lives in ``_em_scan``,
    where the padding mask and the collective seam belong)."""
    sums = jnp.zeros((n_clusters, x.shape[1]), jnp.float32).at[assign].add(
        x.astype(jnp.float32)
    )
    cnts = jnp.zeros((n_clusters,), jnp.float32).at[assign].add(1.0)
    cents = sums / jnp.maximum(cnts, 1.0)[:, None]
    return jnp.where((cnts > 0)[:, None], cents, old_cents), cnts


def _em_scan(x, cents0, n_clusters, n_iters, tol, impl, block, w=None, psum_axis=None):
    """The one EM body: scan with a ``done``-frozen carry (no host syncs).

    On convergence the carry keeps the *pre-update* centroids, so the
    carried ``(assign, cnts)`` stay consistent with the returned centroids
    and no post-loop E-step is needed. ``psum_axis`` turns the M-step's
    (K, D+1) statistics into psums — the distributed-EM factorisation.

    Returns ``(cents, assign, cnts, done)``.
    """
    n = x.shape[0]

    def partial_stats(a):
        xf = x.astype(jnp.float32)
        ww = jnp.ones((n,), jnp.float32) if w is None else w
        sums = jnp.zeros((n_clusters, x.shape[1]), jnp.float32).at[a].add(
            xf * ww[:, None]
        )
        cnts = jnp.zeros((n_clusters,), jnp.float32).at[a].add(ww)
        return sums, cnts

    def e_then_m(cents):
        a, _ = blocked_assign(x, cents, impl, block)
        sums, cnts = partial_stats(a)
        if psum_axis is not None:
            sums = jax.lax.psum(sums, psum_axis)  # the one collective
            cnts = jax.lax.psum(cnts, psum_axis)
        new = sums / jnp.maximum(cnts, 1.0)[:, None]
        new = jnp.where((cnts > 0)[:, None], new, cents)
        return a, new, cnts

    def live(carry):
        cents, _assign, _cnts, done = carry
        a, new, cnts = e_then_m(cents)
        shift = jnp.max(jnp.sum(jnp.square(new - cents), -1))
        conv = shift < tol
        # freeze centroids on convergence: (cents, a, cnts) stay consistent
        return jnp.where(conv, cents, new), a, cnts, conv

    def body(carry, _):
        carry = jax.lax.cond(carry[3], lambda c: c, live, carry)
        return carry, None

    init = (
        cents0,
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n_clusters,), jnp.float32),
        jnp.zeros((), bool),
    )
    carry, _ = jax.lax.scan(body, init, None, length=n_iters)
    return carry


@functools.partial(
    jax.jit, static_argnames=("n_clusters", "n_iters", "impl", "block")
)
def _kmeans_fit_jit(x, cents0, tol, n_clusters, n_iters, impl, block):
    cents, assign, cnts, done = _em_scan(
        x, cents0, n_clusters, n_iters, tol, impl, block
    )

    def align(args):
        # ran out of iterations before converging: the carried assignment is
        # one E-step stale w.r.t. the final centroids — align once
        cents, _a, _c = args
        a, _ = blocked_assign(x, cents, impl, block)
        _, cnts = _m_step(x, a, n_clusters, cents)
        return cents, a, cnts

    return jax.lax.cond(
        done, lambda args: args, align, (cents, assign, cnts)
    )


@functools.partial(
    jax.jit, static_argnames=("n_clusters", "n_iters", "impl", "block")
)
def _kmeans_cents_jit(x, cents0, tol, n_clusters, n_iters, impl, block):
    cents, _a, _c, _done = _em_scan(
        x, cents0, n_clusters, n_iters, tol, impl, block
    )
    return cents


def kmeans_centroids(
    key,
    x: jax.Array,
    n_clusters: int,
    n_iters: int = 25,
    tol: float = 1e-4,
    impl=None,
    *,
    block: int = 16384,
):
    """Centroids-only EM — the index build's kmeans stage.

    Same scan body as :func:`kmeans_fit` minus the assignment outputs (the
    build derives its assignment from the capacity-bounded bidding rounds,
    not the unconstrained E-step), and the same body
    :func:`kmeans_fit_sharded` runs under ``shard_map`` — which is what
    makes a 1-device sharded build bit-identical to the local one.
    """
    from repro.kernels import registry

    x = jnp.asarray(x)
    cents0 = lsh_init_centroids(key, x, n_clusters)
    return _kmeans_cents_jit(
        x,
        cents0,
        jnp.float32(tol),
        n_clusters,
        n_iters,
        registry.resolve("kmeans_assign", impl),
        min(block, x.shape[0]),
    )


def kmeans_fit(
    key,
    x: jax.Array,
    n_clusters: int,
    n_iters: int = 25,
    tol: float = 1e-4,
    impl=None,
    *,
    block: int = 16384,
    use_pallas=None,
):
    """Lloyd's EM from LSH init. Returns (centroids, assignments, counts).

    ``impl`` is a registry impl: "auto" | "pallas" | "jnp" (legacy bools
    accepted; the ``use_pallas=`` keyword is a deprecated alias). The whole
    EM loop is one jitted ``lax.scan`` with on-device convergence — no
    per-iteration host sync — and the returned assignment is always the
    nearest-centroid assignment of the returned centroids: on convergence
    the loop's own final E-step already is (no recompute), otherwise one
    alignment E-step runs inside the same jit.
    """
    from repro.kernels import registry

    impl = deprecate_use_pallas(impl, use_pallas, "kmeans_fit")
    x = jnp.asarray(x)
    cents0 = lsh_init_centroids(key, x, n_clusters)
    return _kmeans_fit_jit(
        x,
        cents0,
        jnp.float32(tol),
        n_clusters,
        n_iters,
        registry.resolve("kmeans_assign", impl),
        min(block, x.shape[0]),
    )


def _pad_chunk(chunk: np.ndarray, chunk_rows: int):
    """Pad a (possibly ragged) host chunk to exactly ``chunk_rows`` rows and
    return its validity weights — every streamed chunk then hits one fixed
    (chunk_rows, D) jit trace, and padding never enters a statistic."""
    c = chunk.shape[0]
    w = np.zeros((chunk_rows,), np.float32)
    w[:c] = 1.0
    if c < chunk_rows:
        chunk = np.concatenate(
            [chunk, np.zeros((chunk_rows - c, chunk.shape[1]), chunk.dtype)]
        )
    return chunk, w


def kmeans_centroids_streamed(
    key,
    store,
    n_clusters: int,
    *,
    chunk_rows: int,
    n_iters: int = 25,
    tol: float = 1e-4,
    impl=None,
    block: int = 16384,
):
    """Centroids-only EM over a disk-backed :class:`repro.data.store.
    EmbeddingStore` — the streamed twin of :func:`kmeans_centroids`.

    Each pass streams the corpus in ``chunk_rows``-row chunks through a
    double-buffered :func:`repro.data.store.stream_chunks` feed; per chunk
    one jitted call runs the registry E-step and accumulates the (K, D+1)
    partial statistics on device, so peak host RSS is O(chunk_rows · D) and
    device state is O(chunk + K·D). Same LSH init key schedule as the
    resident scan, and convergence freezes the *pre-update* centroids
    (matching ``_em_scan``); the one host sync is a ``float(shift)`` per EM
    pass, amortised over a full pass of the data. Chunk boundaries depend
    only on (N, chunk_rows), so results are identical for any two stores
    holding the same rows.
    """
    import functools as _ft

    from repro.data.store import stream_chunks
    from repro.kernels import registry

    resolved = registry.resolve("kmeans_assign", impl)
    n, d = store.shape
    chunk_rows = max(1, min(chunk_rows, n))
    blk = max(1, min(block, chunk_rows))

    b = max(1, int(np.ceil(np.log2(n_clusters))))
    kh, kf = jax.random.split(key)
    planes = jax.random.normal(kh, (d, b), jnp.float32)
    pow2 = (2 ** jnp.arange(b, dtype=jnp.int32))[None, :]
    n_buckets = 2**b

    @_ft.partial(jax.jit, donate_argnums=(0, 1))
    def lsh_partial(sums, cnts, xb, w):
        bits = (xb @ planes) > 0
        codes = jnp.sum(bits * pow2, axis=1)
        sums = sums.at[codes].add(xb * w[:, None])
        cnts = cnts.at[codes].add(w)
        return sums, cnts

    sums = jnp.zeros((n_buckets, d), jnp.float32)
    cnts = jnp.zeros((n_buckets,), jnp.float32)
    for _s, chunk in stream_chunks(store, chunk_rows):
        xb, w = _pad_chunk(chunk, chunk_rows)
        sums, cnts = lsh_partial(sums, cnts, jnp.asarray(xb), jnp.asarray(w))
    order = jnp.argsort(-cnts)
    top = order[:n_clusters]
    bucket_cents = sums[top] / jnp.maximum(cnts[top], 1.0)[:, None]
    fb_rows = np.asarray(jax.random.randint(kf, (n_clusters,), 0, n))
    fallback = jnp.asarray(store.read_rows(fb_rows), jnp.float32)
    cents = jnp.where((cnts[top] > 0)[:, None], bucket_cents, fallback)

    @_ft.partial(jax.jit, donate_argnums=(0, 1))
    def em_partial(sums, cnts, xb, w, cents):
        a, _ = blocked_assign(xb, cents, resolved, blk)
        sums = sums.at[a].add(xb * w[:, None])
        cnts = cnts.at[a].add(w)
        return sums, cnts

    for _it in range(n_iters):
        sums = jnp.zeros((n_clusters, d), jnp.float32)
        cnts = jnp.zeros((n_clusters,), jnp.float32)
        for _s, chunk in stream_chunks(store, chunk_rows):
            xb, w = _pad_chunk(chunk, chunk_rows)
            sums, cnts = em_partial(
                sums, cnts, jnp.asarray(xb), jnp.asarray(w), cents
            )
        new = sums / jnp.maximum(cnts, 1.0)[:, None]
        new = jnp.where((cnts > 0)[:, None], new, cents)
        shift = float(jnp.max(jnp.sum(jnp.square(new - cents), -1)))
        if shift < tol:
            break  # freeze-on-converge: keep the pre-update centroids
        cents = new
    return cents


def kmeans_fit_sharded(
    key,
    x_sharded,
    n_clusters,
    mesh,
    axis: str,
    n_iters: int = 25,
    tol: float = 0.0,
    impl=None,
    *,
    block: int = 16384,
    n_real: Optional[int] = None,
):
    """Distributed EM: X rows sharded over ``axis``; psum of (K, D+1) stats.

    x_sharded: global-view array already placed with rows sharded. Returns
    replicated centroids. (Per-iteration collective: K×(D+1) fp32.)
    ``n_real`` masks trailing padding rows (rows padded so the row count
    divides the mesh axis). ``tol=0`` keeps the historical fixed-iteration
    behaviour; with the same ``tol``/``block``/``impl`` as
    :func:`kmeans_fit`, a 1-device mesh reproduces the local scan
    bit-for-bit.

    Under multi-process ``jax.distributed`` the same body runs unchanged:
    ``mesh`` spans the global device pool, ``x_sharded`` is a global view
    assembled from per-process pieces, and the one (K, D+1) psum per
    iteration crosses processes. A P-process run is bit-equal to a
    1-process run over the same P devices — the psum sums the same
    per-device partials in the same mesh order either way.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.kernels import registry

    resolved = registry.resolve("kmeans_assign", impl)
    n = x_sharded.shape[0]
    valid = None if n_real is None else (jnp.arange(n) < n_real)
    cents0 = lsh_init_centroids(
        key, x_sharded, n_clusters, valid=valid, n_valid=n_real
    )
    if valid is None:
        valid = jnp.ones((n,), jnp.float32)
    else:
        valid = valid.astype(jnp.float32)
    blk = min(block, n // mesh.shape[axis])

    @jax.jit
    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(None, None)),
        out_specs=P(None, None),
        check_rep=False,
    )
    def em_iters(x_local, w_local, cents):
        cents, _a, _c, _done = _em_scan(
            x_local,
            cents,
            n_clusters,
            n_iters,
            jnp.float32(tol),
            resolved,
            blk,
            w=w_local,
            psum_axis=axis,
        )
        return cents

    return em_iters(x_sharded, valid, cents0)


def capacity_assign(
    dist2_fn,
    x: np.ndarray,
    cents: np.ndarray,
    capacity: int,
    max_rounds: int = 12,
) -> np.ndarray:
    """Capacity-bounded nearest-centroid assignment (host-side reference).

    TPU adaptation (DESIGN.md §2): static shapes need bounded clusters.
    Greedy rounds: each unassigned point bids for its nearest centroid with
    free capacity; each centroid admits its ``capacity`` closest bidders.
    Terminates because every round either fills a centroid or assigns all.

    State is O(N + K): a rejected bidder's centroid is, by construction,
    full from that round on (rejection only happens when bidders exceed the
    remaining capacity), so the ``free <= 0`` mask already covers every
    cluster the seed implementation tracked in its (N, K) ``banned``
    matrix. The production build runs the device equivalent
    (:func:`repro.index.build.capacity_assign_device`); this NumPy loop is
    the oracle it is tested against and the benchmark baseline.
    """
    n = x.shape[0]
    K = cents.shape[0]
    assign = np.full(n, -1, np.int64)
    free = np.full(K, capacity, np.int64)

    for _ in range(max_rounds):
        todo = np.flatnonzero(assign < 0)
        if todo.size == 0:
            return assign
        d2 = dist2_fn(x[todo], cents)  # (T, K)
        d2 = np.where(free[None, :] <= 0, np.inf, d2)
        pick = np.argmin(d2, 1)
        for c in range(K):
            if free[c] <= 0:
                continue
            bidders = todo[pick == c]
            if bidders.size == 0:
                continue
            if bidders.size > free[c]:
                order = np.argsort(d2[pick == c, c], kind="stable")
                admitted = bidders[order[: free[c]]]
            else:
                admitted = bidders
            assign[admitted] = c
            free[c] -= admitted.size
    # force-place any stragglers into the nearest centroid with space
    todo = np.flatnonzero(assign < 0)
    if todo.size:
        d2 = dist2_fn(x[todo], cents)
        order = np.argsort(d2, axis=1)
        for t, row in zip(todo, order):
            for c in row:
                if free[c] > 0:
                    assign[t] = c
                    free[c] -= 1
                    break
    if (assign < 0).any():
        raise RuntimeError("capacity_assign: total capacity < N")
    return assign

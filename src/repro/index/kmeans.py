"""LSH-initialised K-means (paper §3.2).

The paper: "We initialize our K-Means clustering using a locally sensitive
hash, run expectation maximization until convergence, and compute exact
nearest neighbors for each point within its cluster."

The E-step distance+argmin dispatches through the kernel registry
(kernel ``"kmeans_assign"``): the fused Pallas path when resolved, else
the blocked jnp path (which doubles as the oracle).
A ``shard_map`` variant (`kmeans_fit_sharded`) runs EM with points sharded
across devices — per-iteration communication is one psum of (K, D+1)
partial statistics, the classic distributed-EM factorisation.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def lsh_init_centroids(key, x: jax.Array, n_clusters: int) -> jax.Array:
    """Random-hyperplane LSH buckets → bucket means as initial centroids.

    b = ceil(log2 K) hyperplanes give 2^b ≥ K buckets; the K most populated
    buckets seed the centroids; empty seats fall back to random points.
    """
    n, d = x.shape
    b = max(1, int(np.ceil(np.log2(n_clusters))))
    kh, kf = jax.random.split(key)
    planes = jax.random.normal(kh, (d, b), jnp.float32)
    bits = (x.astype(jnp.float32) @ planes) > 0  # (n, b)
    codes = jnp.sum(bits * (2 ** jnp.arange(b, dtype=jnp.int32))[None, :], axis=1)
    n_buckets = 2**b
    sums = jnp.zeros((n_buckets, d), jnp.float32).at[codes].add(x.astype(jnp.float32))
    cnts = jnp.zeros((n_buckets,), jnp.float32).at[codes].add(1.0)
    order = jnp.argsort(-cnts)  # most populated first
    top = order[:n_clusters]
    cents = sums[top] / jnp.maximum(cnts[top], 1.0)[:, None]
    # empty buckets → random data points
    fallback = x[jax.random.randint(kf, (n_clusters,), 0, n)].astype(jnp.float32)
    return jnp.where((cnts[top] > 0)[:, None], cents, fallback)


def assign_jnp(x: jax.Array, cents: jax.Array, block: int = 16384):
    """Nearest-centroid assignment; returns (assign (n,), min_dist2 (n,))."""
    c2 = jnp.sum(jnp.square(cents), -1)

    def one_block(xb):
        d2 = (
            jnp.sum(jnp.square(xb), -1)[:, None]
            + c2[None, :]
            - 2.0 * xb @ cents.T
        )
        return jnp.argmin(d2, -1).astype(jnp.int32), jnp.min(d2, -1)

    n = x.shape[0]
    if n <= block:
        return one_block(x.astype(jnp.float32))
    outs = [one_block(x[s : s + block].astype(jnp.float32)) for s in range(0, n, block)]
    return jnp.concatenate([o[0] for o in outs]), jnp.concatenate([o[1] for o in outs])


def _m_step(x, assign, n_clusters, old_cents):
    sums = jnp.zeros((n_clusters, x.shape[1]), jnp.float32).at[assign].add(
        x.astype(jnp.float32)
    )
    cnts = jnp.zeros((n_clusters,), jnp.float32).at[assign].add(1.0)
    cents = sums / jnp.maximum(cnts, 1.0)[:, None]
    return jnp.where((cnts > 0)[:, None], cents, old_cents), cnts


def kmeans_fit(
    key,
    x: jax.Array,
    n_clusters: int,
    n_iters: int = 25,
    tol: float = 1e-4,
    use_pallas=False,
):
    """Lloyd's EM from LSH init. Returns (centroids, assignments, counts).

    ``use_pallas`` is a registry impl: "auto" | "pallas" | "jnp" (legacy
    bools accepted). The jnp path keeps the row-blocked ``assign_jnp`` so
    huge N never materialises an (N, K) matrix.
    """
    from repro.kernels import registry

    cents = lsh_init_centroids(key, x, n_clusters)

    if registry.resolve("kmeans_assign", use_pallas) == "pallas":
        assign_fn: Callable = lambda xx, cc: registry.dispatch(
            "kmeans_assign", xx, cc, impl="pallas"
        )
    else:
        assign_fn = assign_jnp

    assign = None
    for _ in range(n_iters):
        assign, _ = assign_fn(x, cents)
        new_cents, cnts = _m_step(x, assign, n_clusters, cents)
        shift = float(jnp.max(jnp.sum(jnp.square(new_cents - cents), -1)))
        cents = new_cents
        if shift < tol:
            break
    assign, _ = assign_fn(x, cents)
    _, cnts = _m_step(x, assign, n_clusters, cents)
    return cents, assign, cnts


def kmeans_fit_sharded(key, x_sharded, n_clusters, mesh, axis: str, n_iters: int = 25):
    """Distributed EM: X rows sharded over ``axis``; psum of (K, D+1) stats.

    x_sharded: global-view array already placed with rows sharded. Returns
    replicated centroids. (Per-iteration collective: K×(D+1) fp32.)
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    d = x_sharded.shape[1]

    cents0 = lsh_init_centroids(key, x_sharded, n_clusters)  # cheap, replicated

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None),
        check_rep=False,
    )
    def em_iters(x_local, cents):
        def body(cents, _):
            a, _d = assign_jnp(x_local, cents)
            sums = jnp.zeros((n_clusters, d), jnp.float32).at[a].add(
                x_local.astype(jnp.float32)
            )
            cnts = jnp.zeros((n_clusters,), jnp.float32).at[a].add(1.0)
            sums = jax.lax.psum(sums, axis)  # the one collective
            cnts = jax.lax.psum(cnts, axis)
            new = sums / jnp.maximum(cnts, 1.0)[:, None]
            return jnp.where((cnts > 0)[:, None], new, cents), None

        cents, _ = jax.lax.scan(body, cents, None, length=n_iters)
        return cents

    return em_iters(x_sharded, cents0)


def capacity_assign(
    dist2_fn,
    x: np.ndarray,
    cents: np.ndarray,
    capacity: int,
    max_rounds: int = 12,
) -> np.ndarray:
    """Capacity-bounded nearest-centroid assignment (host-side, NumPy).

    TPU adaptation (DESIGN.md §2): static shapes need bounded clusters.
    Greedy rounds: each unassigned point bids for its nearest centroid with
    free capacity; each centroid admits its ``capacity`` closest bidders.
    Terminates because every round either fills a centroid or assigns all.
    """
    n = x.shape[0]
    K = cents.shape[0]
    assign = np.full(n, -1, np.int64)
    free = np.full(K, capacity, np.int64)
    banned = np.zeros((n, K), bool)  # clusters already full when we bid

    for _ in range(max_rounds):
        todo = np.flatnonzero(assign < 0)
        if todo.size == 0:
            return assign
        d2 = dist2_fn(x[todo], cents)  # (T, K)
        d2 = np.where(banned[todo] | (free[None, :] <= 0), np.inf, d2)
        pick = np.argmin(d2, 1)
        for c in range(K):
            if free[c] <= 0:
                continue
            bidders = todo[pick == c]
            if bidders.size == 0:
                continue
            if bidders.size > free[c]:
                order = np.argsort(d2[pick == c, c])
                admitted = bidders[order[: free[c]]]
                rejected = bidders[order[free[c] :]]
                banned[rejected, c] = True
            else:
                admitted = bidders
            assign[admitted] = c
            free[c] -= admitted.size
    # force-place any stragglers into the nearest centroid with space
    todo = np.flatnonzero(assign < 0)
    if todo.size:
        d2 = dist2_fn(x[todo], cents)
        order = np.argsort(d2, axis=1)
        for t, row in zip(todo, order):
            for c in row:
                if free[c] > 0:
                    assign[t] = c
                    free[c] -= 1
                    break
    if (assign < 0).any():
        raise RuntimeError("capacity_assign: total capacity < N")
    return assign

"""ANN index builder: the end-to-end §3.2 pipeline.

Produces the *cluster-major* layout every downstream consumer shares
(single-device reference, shard_map distributed step, checkpointing):

  row r = cluster * capacity + slot,  slot < counts[cluster] ⇒ real point

Fields
------
x_rows     (K·C, D)   permuted input vectors (padding rows = 0)
knn_idx    (K·C, k)   row indices of kNN tails (self-loop ⇒ masked edge)
knn_w      (K·C, k)   p(j|i) weights (0 ⇒ edge absent)
counts     (K,)       real points per cluster
centroids  (K, D)
perm       (N,)       original index → row (for un-permuting outputs)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NomadConfig
from repro.index import kmeans as km
from repro.index.knn import batched_cluster_knn


@dataclasses.dataclass
class AnnIndex:
    x_rows: np.ndarray
    knn_idx: np.ndarray
    knn_w: np.ndarray
    counts: np.ndarray
    centroids: np.ndarray
    perm: np.ndarray
    capacity: int
    n_points: int

    @property
    def n_clusters(self) -> int:
        return self.counts.shape[0]

    @property
    def valid_mask(self) -> np.ndarray:
        K, C = self.n_clusters, self.capacity
        return (np.arange(C)[None, :] < self.counts[:, None]).reshape(K * C)

    def unpermute(self, rows: np.ndarray) -> np.ndarray:
        """Map row-major data (K·C, …) back to original point order (N, …)."""
        return rows[self.perm]


def index_cache_path(checkpoint_dir: str) -> str:
    """Where a fit caches its index beside the checkpoints (one convention)."""
    import os

    return os.path.join(checkpoint_dir, "index.npz")


def save_index(index: AnnIndex, path: str) -> None:
    """Persist an index as one .npz (used as the fit/resume on-disk cache)."""
    np.savez(
        path,
        x_rows=index.x_rows,
        knn_idx=index.knn_idx,
        knn_w=index.knn_w,
        counts=index.counts,
        centroids=index.centroids,
        perm=index.perm,
        capacity=index.capacity,
        n_points=index.n_points,
    )


def load_index(path: str) -> AnnIndex:
    z = np.load(path)
    return AnnIndex(
        x_rows=z["x_rows"],
        knn_idx=z["knn_idx"],
        knn_w=z["knn_w"],
        counts=z["counts"],
        centroids=z["centroids"],
        perm=z["perm"],
        capacity=int(z["capacity"]),
        n_points=int(z["n_points"]),
    )


def _np_dist2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return (
        np.sum(a.astype(np.float32) ** 2, -1)[:, None]
        + np.sum(b.astype(np.float32) ** 2, -1)[None, :]
        - 2.0 * a.astype(np.float32) @ b.astype(np.float32).T
    )


def build_index(x: np.ndarray, cfg: NomadConfig, use_pallas=None) -> AnnIndex:
    """K-means (LSH init) → capacity-bounded clusters → in-cluster exact kNN.

    ``use_pallas`` is a registry impl override ("auto"|"pallas"|"jnp", legacy
    bools accepted); None defers to ``cfg.resolved_kernel_impl()``.
    """
    if use_pallas is None:
        use_pallas = cfg.resolved_kernel_impl()
    n, d = x.shape
    K, C, k = cfg.n_clusters, cfg.cluster_capacity, cfg.n_neighbors
    if K * C < n:
        raise ValueError(f"capacity {C}×{K} < N={n}; raise capacity_slack")
    key = jax.random.key(cfg.seed)

    cents, _, _ = km.kmeans_fit(
        key, jnp.asarray(x), K, n_iters=cfg.kmeans_iters, tol=cfg.kmeans_tol, use_pallas=use_pallas
    )
    cents = np.asarray(cents)

    assign = km.capacity_assign(_np_dist2, np.asarray(x), cents, C)

    # build the cluster-major permutation
    order = np.argsort(assign, kind="stable")
    counts = np.bincount(assign, minlength=K).astype(np.int64)
    starts = np.zeros(K, np.int64)
    starts[1:] = np.cumsum(counts)[:-1]
    perm = np.zeros(n, np.int64)  # original → row
    x_rows = np.zeros((K * C, d), x.dtype)
    for c in range(K):
        members = order[starts[c] : starts[c] + counts[c]]
        rows = c * C + np.arange(counts[c])
        perm[members] = rows
        x_rows[rows] = x[members]

    valid = (np.arange(C)[None, :] < counts[:, None]).astype(bool)  # (K, C)
    knn_local, knn_w = batched_cluster_knn(
        jnp.asarray(x_rows).reshape(K, C, d), jnp.asarray(valid), k, use_pallas
    )
    knn_local = np.asarray(knn_local)  # (K, C, k) slot within cluster
    knn_w = np.asarray(knn_w).reshape(K * C, k)
    base = (np.arange(K) * C)[:, None, None]
    knn_idx = (knn_local + base).reshape(K * C, k).astype(np.int64)
    # dead edges (w == 0) point at self so gathers stay in-bounds & local
    self_rows = np.arange(K * C)[:, None]
    knn_idx = np.where(knn_w > 0, knn_idx, self_rows)

    return AnnIndex(
        x_rows=x_rows,
        knn_idx=knn_idx,
        knn_w=knn_w.astype(np.float32),
        counts=counts,
        centroids=cents,
        perm=perm,
        capacity=C,
        n_points=n,
    )

"""ANN index: the end-to-end §3.2 pipeline's data structure + front door.

Produces the *cluster-major* layout every downstream consumer shares
(single-device reference, shard_map distributed step, checkpointing):

  row r = cluster * capacity + slot,  slot < counts[cluster] ⇒ real point

Fields
------
x_rows     (K·C, D)   permuted input vectors (padding rows = 0)
knn_idx    (K·C, k)   row indices of kNN tails (self-loop ⇒ masked edge)
knn_w      (K·C, k)   p(j|i) weights (0 ⇒ edge absent)
counts     (K,)       real points per cluster
centroids  (K, D)
perm       (N,)       original index → row (for un-permuting outputs)
fingerprint           content hash of a deterministic row sample of the
                      data the index was built from — lets a cached index
                      refuse a *different* same-shape dataset

The pipeline itself lives in :mod:`repro.index.build` (the
:class:`~repro.index.build.IndexBuilder` execution subsystem —
device-resident, optionally sharded); :func:`build_index` here is the
stable one-call front door.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os

import numpy as np

from repro.configs.base import NomadConfig


@dataclasses.dataclass
class AnnIndex:
    # (K·C, D) permuted vectors — an ndarray, or (out-of-core builds) a
    # disk-backed repro.data.store.EmbeddingStore; training never reads it,
    # so a streamed fit keeps host RSS free of the O(N·D) buffer. Serving
    # (FrozenMap) materialises it to device explicitly.
    x_rows: np.ndarray
    knn_idx: np.ndarray
    knn_w: np.ndarray
    counts: np.ndarray
    centroids: np.ndarray
    perm: np.ndarray
    capacity: int
    n_points: int
    fingerprint: str = ""

    @property
    def n_clusters(self) -> int:
        return self.counts.shape[0]

    @property
    def valid_mask(self) -> np.ndarray:
        K, C = self.n_clusters, self.capacity
        return (np.arange(C)[None, :] < self.counts[:, None]).reshape(K * C)

    def unpermute(self, rows: np.ndarray) -> np.ndarray:
        """Map row-major data (K·C, …) back to original point order (N, …)."""
        return rows[self.perm]


def data_fingerprint(x, n_sample: int = 64, block_rows: int = 65536) -> str:
    """Content hash of ``x``: shape + a deterministic row sample + a full
    float64 column-sum checksum.

    The row sample alone would miss a change confined to non-sampled rows;
    the column sums make any perturbation visible unless it exactly cancels
    per column in float64 — good enough for the checkpoint index-cache
    staleness check at one full O(N·D) streaming pass, no O(N·D) hashing.

    ``x`` may be an array or an :class:`repro.data.store.EmbeddingStore`.
    The column sums accumulate over fixed ``block_rows`` blocks regardless
    of the container (never the store's chunk_rows), so the same rows hash
    the same whether they arrive in RAM, as a memmap, or sharded on disk.
    (For N > block_rows this grouping differs from the pre-store whole-array
    sum, so caches written by earlier versions at that size re-fingerprint
    once — a one-time rebuild, warned about as a mismatch.)
    """
    from repro.data.store import as_store, is_store

    st = x if is_store(x) else as_store(np.asarray(x))
    n, d = st.shape
    idx = np.unique(np.linspace(0, max(n - 1, 0), min(n_sample, n)).astype(np.int64))
    h = hashlib.sha256()
    h.update(repr((n, d)).encode())
    h.update(np.ascontiguousarray(st.read_rows(idx), dtype=np.float32).tobytes())
    colsum = np.zeros((d,), np.float64)
    for s in range(0, n, block_rows):
        colsum += st.read(s, min(s + block_rows, n)).sum(axis=0, dtype=np.float64)
    h.update(np.ascontiguousarray(colsum).tobytes())
    return h.hexdigest()[:16]


def index_cache_path(checkpoint_dir: str) -> str:
    """Where a fit caches its index beside the checkpoints (one convention)."""
    import os

    return os.path.join(checkpoint_dir, "index.npz")


def save_index(index: AnnIndex, path: str) -> None:
    """Persist an index as one .npz (used as the fit/resume on-disk cache).

    A store-backed ``x_rows`` (out-of-core build) is spilled *chunked* into
    a float32 ``.npy`` sidecar beside the npz — the O(N·D) buffer never
    materialises in host RAM — and the npz records the sidecar's name.
    This deliberately duplicates the build's own x_rows spill on disk: the
    cache directory must stay **self-contained** (``from_checkpoint``
    serving ships only the checkpoint dir, and a later refit may overwrite
    a build spill it pointed into), so disk is traded for that guarantee.
    """
    from repro.data.store import copy_to_npy, is_store

    fields = dict(
        knn_idx=index.knn_idx,
        knn_w=index.knn_w,
        counts=index.counts,
        centroids=index.centroids,
        perm=index.perm,
        capacity=index.capacity,
        n_points=index.n_points,
        fingerprint=np.asarray(index.fingerprint),
    )
    if is_store(index.x_rows):
        sidecar = os.path.basename(path) + ".x_rows.npy"
        copy_to_npy(index.x_rows, os.path.join(os.path.dirname(path) or ".", sidecar))
        fields["x_rows_file"] = np.asarray(sidecar)
    else:
        fields["x_rows"] = index.x_rows
    np.savez(path, **fields)


def load_index(path: str) -> AnnIndex:
    from repro.data.store import MemmapStore

    z = np.load(path)
    if "x_rows_file" in z.files:  # store-backed cache: memmap the sidecar
        x_rows = MemmapStore(
            os.path.join(os.path.dirname(path) or ".", str(z["x_rows_file"]))
        )
    else:
        x_rows = z["x_rows"]
    return AnnIndex(
        x_rows=x_rows,
        knn_idx=z["knn_idx"],
        knn_w=z["knn_w"],
        counts=z["counts"],
        centroids=z["centroids"],
        perm=z["perm"],
        capacity=int(z["capacity"]),
        n_points=int(z["n_points"]),
        # caches written before fingerprints existed load as "" (never stale)
        fingerprint=str(z["fingerprint"]) if "fingerprint" in z.files else "",
    )


def _np_dist2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return (
        np.sum(a.astype(np.float32) ** 2, -1)[:, None]
        + np.sum(b.astype(np.float32) ** 2, -1)[None, :]
        - 2.0 * a.astype(np.float32) @ b.astype(np.float32).T
    )


def build_index(
    x: np.ndarray,
    cfg: NomadConfig,
    impl=None,
    *,
    strategy=None,
    mesh=None,
    use_pallas=None,
) -> AnnIndex:
    """K-means (LSH init) → capacity-bounded clusters → in-cluster exact kNN.

    Thin front door over :class:`repro.index.build.IndexBuilder` — every
    stage runs on device; ``strategy`` (default ``cfg.build_strategy``)
    selects ``"auto"|"local"|"sharded"`` execution. ``impl`` is a registry
    impl override ("auto"|"pallas"|"jnp", legacy bools accepted); None
    defers to ``cfg.resolved_kernel_impl()``. The ``use_pallas=`` keyword
    is a deprecated alias for ``impl``.
    """
    from repro.index.build import IndexBuilder
    from repro.index.kmeans import deprecate_use_pallas

    impl = deprecate_use_pallas(impl, use_pallas, "build_index")
    return IndexBuilder(cfg, strategy=strategy, mesh=mesh, impl=impl).build(x)

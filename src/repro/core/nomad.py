"""NOMAD Projection driver (paper §3 end-to-end).

``make_step_fn`` builds the jitted SGD step over a *local* cluster-major
block of positions — the same function body serves the single-device
reference (local = everything) and the ``shard_map`` distributed path
(local = this shard's clusters, means/counts global). All index structures
come from :mod:`repro.index.ann`.

Method selection:
* ``"nomad"``  — Eq. 3: remote cells via means (M̃), own cell sampled (M).
* ``"infonc"`` — Eq. 2: the InfoNC-t-SNE baseline; all negatives drawn
  uniformly from the full support (single-device only — this is exactly the
  non-factorising loss the paper is working around).

Sampling conventions (paper §3.3): heads i uniform over points (uniform
marginal P_i); noise tails uniform over points (uniform ξ); |M| = n_noise.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from typing import TYPE_CHECKING

from repro.configs.base import NomadConfig
from repro.core import losses
from repro.core.pca import pca_init

if TYPE_CHECKING:  # runtime import is lazy (repro.index imports repro.core)
    from repro.index.ann import AnnIndex


# ---------------------------------------------------------------------------
# Sampling helpers (cluster-major layout)
# ---------------------------------------------------------------------------


def sample_points(key, n: int, cum_counts: jax.Array, capacity: int):
    """n uniform valid points. Returns (rows, cluster_ids) — both (n,)."""
    total = cum_counts[-1]
    u = jax.random.randint(key, (n,), 0, total)
    cluster = jnp.searchsorted(cum_counts, u, side="right").astype(jnp.int32)
    start = jnp.where(cluster > 0, cum_counts[cluster - 1], 0)
    slot = u - start
    return cluster * capacity + slot, cluster


def sample_in_cluster(key, cluster_ids: jax.Array, counts: jax.Array, capacity: int, s: int):
    """(B,) cluster ids → (B, s) uniform valid rows within each cluster."""
    B = cluster_ids.shape[0]
    c = counts[cluster_ids]  # (B,)
    u = jax.random.uniform(key, (B, s))
    slot = jnp.floor(u * c[:, None]).astype(jnp.int32)
    slot = jnp.minimum(slot, (c - 1)[:, None].astype(jnp.int32))
    return cluster_ids[:, None] * capacity + slot


def local_means(theta_rows: jax.Array, counts: jax.Array, capacity: int):
    """Masked per-cluster means of positions: (K·C, d) → (K, d)."""
    K = counts.shape[0]
    th = theta_rows.reshape(K, capacity, -1).astype(jnp.float32)
    valid = (jnp.arange(capacity)[None, :] < counts[:, None]).astype(jnp.float32)
    sums = jnp.sum(th * valid[:, :, None], axis=1)
    return sums / jnp.maximum(counts.astype(jnp.float32), 1.0)[:, None]


# ---------------------------------------------------------------------------
# The SGD step
# ---------------------------------------------------------------------------


def make_step_fn(
    cfg: NomadConfig,
    *,
    method: str = "nomad",
    cluster_offset: int = 0,
    n_total: Optional[int] = None,
):
    """Build ``step(theta, idx, state) -> (theta, loss)``.

    ``idx`` is a dict of local index arrays; ``state`` carries (means,
    global_counts, lr, key). ``cluster_offset`` maps local cluster ids into
    the global cell numbering (shard s owns cells [off, off + K_local)).
    """
    n_total = n_total or cfg.n_points
    B, S, Mn = cfg.batch_size, cfg.n_exact_negatives, cfg.n_noise
    C = cfg.cluster_capacity

    def step(theta, idx, means, global_counts, lr, key):
        k_head, k_neg = jax.random.split(key)
        rows, cl_local = sample_points(k_head, B, idx["cum_counts"], C)
        pos_rows = idx["knn_idx"][rows]  # (B, k)
        pos_w = idx["knn_w"][rows]  # (B, k)
        th_i = theta[rows]
        th_pos = theta[pos_rows]

        if method == "infonc":
            # Eq. 2 baseline: |M| noise tails uniform over the full support
            neg_rows, _ = sample_points(k_neg, B * Mn, idx["cum_counts"], C)
            neg_rows = neg_rows.reshape(B, Mn)
            th_neg = theta[neg_rows]

            def loss_fn(ti, tp, tn):
                return losses.infonc_tsne_loss(ti, tp, pos_w, tn)

        else:
            neg_rows = sample_in_cluster(k_neg, cl_local, idx["counts"], C, S)
            th_neg = theta[neg_rows]
            cell_global = cl_local + cluster_offset

            def loss_fn(ti, tp, tn):
                return losses.nomad_loss(
                    ti,
                    tp,
                    pos_w,
                    means,
                    global_counts,
                    cell_global,
                    tn,
                    n_noise=Mn,
                    n_total=n_total,
                    impl=cfg.resolved_kernel_impl(),
                )

        loss, (g_i, g_pos, g_neg) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
            th_i, th_pos, th_neg
        )
        # sparse SGD: only touched rows are updated (reaction forces included)
        theta = theta.at[rows].add(-lr * g_i)
        theta = theta.at[pos_rows.reshape(-1)].add(-lr * g_pos.reshape(-1, theta.shape[1]))
        theta = theta.at[neg_rows.reshape(-1)].add(-lr * g_neg.reshape(-1, theta.shape[1]))
        return theta, loss

    return step


def make_epoch_fn(cfg: NomadConfig, step_fn, steps_per_epoch: int):
    """jit-compiled epoch: refresh means once, then scan the SGD steps.

    Mirrors Fig. 2: means are computed (and, in the distributed version,
    all-gathered) once per epoch and held fixed (stop-gradient) within it.
    ``mean_refresh_steps > 0`` refreshes more often (beyond-paper knob).
    """
    C = cfg.cluster_capacity
    refresh = cfg.mean_refresh_steps or steps_per_epoch

    @jax.jit
    def epoch(theta, idx, lr0, lr1, epoch_key):
        counts_f = idx["counts"].astype(jnp.float32)

        def body(carry, t):
            theta, means = carry
            means = jax.lax.cond(
                t % refresh == 0,
                lambda th: local_means(th, idx["counts"], C),
                lambda th: means,
                theta,
            )
            lr = lr0 + (lr1 - lr0) * (t / steps_per_epoch)
            key = jax.random.fold_in(epoch_key, t)
            theta, loss = step_fn(theta, idx, means, counts_f, lr, key)
            return (theta, means), loss

        means0 = local_means(theta, idx["counts"], C)
        (theta, _), losses_ = jax.lax.scan(
            body, (theta, means0), jnp.arange(steps_per_epoch)
        )
        return theta, jnp.mean(losses_)

    return epoch


# ---------------------------------------------------------------------------
# Fit driver (single-device reference; distributed lives in core/distributed)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitResult:
    embedding: np.ndarray  # (N, out_dim) in the original point order
    index: "AnnIndex"
    losses: list
    wall_time_s: float
    epoch_times: list


class NomadProjection:
    """scikit-style front end: ``NomadProjection(cfg).fit(x)``."""

    def __init__(self, cfg: NomadConfig, method: str = "nomad"):
        self.cfg = cfg
        self.method = method

    def fit(
        self,
        x: np.ndarray,
        index: "Optional[AnnIndex]" = None,
        callback: Optional[Callable] = None,
    ) -> FitResult:
        from repro.index.ann import build_index

        cfg = self.cfg
        t0 = time.time()
        if index is None:
            index = build_index(x, cfg)
        theta = self._init_theta(x, index)

        idx = {
            "knn_idx": jnp.asarray(index.knn_idx, jnp.int32),
            "knn_w": jnp.asarray(index.knn_w, jnp.float32),
            "counts": jnp.asarray(index.counts, jnp.int32),
            "cum_counts": jnp.asarray(np.cumsum(index.counts), jnp.int32),
        }
        steps = cfg.resolved_steps_per_epoch()
        step_fn = make_step_fn(cfg, method=self.method)
        epoch_fn = make_epoch_fn(cfg, step_fn, steps)

        lr0 = cfg.resolved_lr0()
        key = jax.random.key(cfg.seed + 1)
        losses_, epoch_times = [], []
        for e in range(cfg.n_epochs):
            te = time.time()
            frac0 = 1.0 - e / cfg.n_epochs
            frac1 = 1.0 - (e + 1) / cfg.n_epochs
            theta, mloss = epoch_fn(
                theta, idx, lr0 * frac0, lr0 * frac1, jax.random.fold_in(key, e)
            )
            mloss = float(mloss)
            losses_.append(mloss)
            epoch_times.append(time.time() - te)
            if callback is not None:
                callback(e, np.asarray(theta), mloss)
        emb = index.unpermute(np.asarray(theta))
        return FitResult(
            embedding=emb,
            index=index,
            losses=losses_,
            wall_time_s=time.time() - t0,
            epoch_times=epoch_times,
        )

    def _init_theta(self, x: np.ndarray, index: "AnnIndex") -> jax.Array:
        cfg = self.cfg
        if cfg.init == "pca":
            th0 = np.asarray(pca_init(jnp.asarray(x), cfg.out_dim, cfg.init_scale))
        else:
            rng = np.random.default_rng(cfg.seed)
            th0 = rng.normal(0, cfg.init_scale, (x.shape[0], cfg.out_dim)).astype(
                np.float32
            )
        rows = np.zeros((index.n_clusters * index.capacity, cfg.out_dim), np.float32)
        rows[index.perm] = th0
        return jnp.asarray(rows)

"""NOMAD Projection driver (paper §3 end-to-end).

``make_step_fn`` builds the jitted SGD step over a *local* cluster-major
block of positions — the same function body serves the single-device
reference (local = everything) and the ``shard_map`` distributed path
(local = this shard's clusters, means/counts global). All index structures
come from :mod:`repro.index.ann`.

Method selection:
* ``"nomad"``  — Eq. 3: remote cells via means (M̃), own cell sampled (M).
* ``"infonc"`` — Eq. 2: the InfoNC-t-SNE baseline; all negatives drawn
  uniformly from the full support (single-device only — this is exactly the
  non-factorising loss the paper is working around).

Sampling conventions (paper §3.3): heads i uniform over points (uniform
marginal P_i); noise tails uniform over points (uniform ξ); |M| = n_noise.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from typing import TYPE_CHECKING

from repro.configs.base import NomadConfig
from repro.core import losses
from repro.core.pca import pca_init

if TYPE_CHECKING:  # runtime import is lazy (repro.index imports repro.core)
    from repro.index.ann import AnnIndex


# ---------------------------------------------------------------------------
# Sampling helpers (cluster-major layout)
# ---------------------------------------------------------------------------


def sample_points(key, n: int, cum_counts: jax.Array, capacity: int):
    """n uniform valid points. Returns (rows, cluster_ids) — both (n,)."""
    total = cum_counts[-1]
    u = jax.random.randint(key, (n,), 0, total)
    cluster = jnp.searchsorted(cum_counts, u, side="right").astype(jnp.int32)
    start = jnp.where(cluster > 0, cum_counts[cluster - 1], 0)
    slot = u - start
    return cluster * capacity + slot, cluster


def sample_in_cluster(key, cluster_ids: jax.Array, counts: jax.Array, capacity: int, s: int):
    """(B,) cluster ids → (B, s) uniform valid rows within each cluster."""
    B = cluster_ids.shape[0]
    c = counts[cluster_ids]  # (B,)
    u = jax.random.uniform(key, (B, s))
    slot = jnp.floor(u * c[:, None]).astype(jnp.int32)
    slot = jnp.minimum(slot, (c - 1)[:, None].astype(jnp.int32))
    return cluster_ids[:, None] * capacity + slot


def local_means(theta_rows: jax.Array, counts: jax.Array, capacity: int):
    """Masked per-cluster means of positions: (K·C, d) → (K, d)."""
    K = counts.shape[0]
    th = theta_rows.reshape(K, capacity, -1).astype(jnp.float32)
    valid = (jnp.arange(capacity)[None, :] < counts[:, None]).astype(jnp.float32)
    sums = jnp.sum(th * valid[:, :, None], axis=1)
    return sums / jnp.maximum(counts.astype(jnp.float32), 1.0)[:, None]


# ---------------------------------------------------------------------------
# The SGD step
# ---------------------------------------------------------------------------


def make_step_fn(
    cfg: NomadConfig,
    *,
    method: str = "nomad",
    cluster_offset: int = 0,
    n_total: Optional[int] = None,
):
    """Build ``step(theta, idx, state) -> (theta, loss)``.

    ``idx`` is a dict of local index arrays; ``state`` carries (means,
    global_counts, lr, key). ``cluster_offset`` maps local cluster ids into
    the global cell numbering (shard s owns cells [off, off + K_local)).

    The NOMAD branch runs the whole per-step loss through the fused
    ``"nomad_step"`` registry kernel (via :func:`losses.nomad_loss`):
    distances, Cauchy weights, attraction and the online-accumulated
    repulsive mass are one tiled pass with a custom VJP on TPU/GPU, and
    the bit-equal legacy multi-pass composition on CPU (``impl="jnp"``).
    ``cfg.kernel_impl`` / ``REPRO_KERNELS`` select per run.
    """
    n_total = n_total or cfg.n_points
    B, S, Mn = cfg.batch_size, cfg.n_exact_negatives, cfg.n_noise
    C = cfg.cluster_capacity

    def step(theta, idx, means, global_counts, lr, key):
        k_head, k_neg = jax.random.split(key)
        rows, cl_local = sample_points(k_head, B, idx["cum_counts"], C)
        pos_rows = idx["knn_idx"][rows]  # (B, k)
        pos_w = idx["knn_w"][rows]  # (B, k)
        th_i = theta[rows]
        th_pos = theta[pos_rows]

        if method == "infonc":
            # Eq. 2 baseline: |M| noise tails uniform over the full support
            neg_rows, _ = sample_points(k_neg, B * Mn, idx["cum_counts"], C)
            neg_rows = neg_rows.reshape(B, Mn)
            th_neg = theta[neg_rows]

            def loss_fn(ti, tp, tn):
                return losses.infonc_tsne_loss(ti, tp, pos_w, tn)

        else:
            neg_rows = sample_in_cluster(k_neg, cl_local, idx["counts"], C, S)
            th_neg = theta[neg_rows]
            cell_global = cl_local + cluster_offset

            def loss_fn(ti, tp, tn):
                return losses.nomad_loss(
                    ti,
                    tp,
                    pos_w,
                    means,
                    global_counts,
                    cell_global,
                    tn,
                    n_noise=Mn,
                    n_total=n_total,
                    impl=cfg.resolved_kernel_impl(),
                )

        loss, (g_i, g_pos, g_neg) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
            th_i, th_pos, th_neg
        )
        # sparse SGD: only touched rows are updated (reaction forces included)
        theta = theta.at[rows].add(-lr * g_i)
        theta = theta.at[pos_rows.reshape(-1)].add(-lr * g_pos.reshape(-1, theta.shape[1]))
        theta = theta.at[neg_rows.reshape(-1)].add(-lr * g_neg.reshape(-1, theta.shape[1]))
        return theta, loss

    return step


def make_epoch_fn(cfg: NomadConfig, step_fn, steps_per_epoch: int):
    """jit-compiled epoch: refresh means once, then scan the SGD steps.

    Mirrors Fig. 2: means are computed (and, in the distributed version,
    all-gathered) once per epoch and held fixed (stop-gradient) within it.
    ``mean_refresh_steps > 0`` refreshes more often (beyond-paper knob).
    """
    C = cfg.cluster_capacity
    refresh = cfg.mean_refresh_steps or steps_per_epoch

    @jax.jit
    def epoch(theta, idx, lr0, lr1, epoch_key):
        counts_f = idx["counts"].astype(jnp.float32)

        def body(carry, t):
            theta, means = carry
            means = jax.lax.cond(
                t % refresh == 0,
                lambda th: local_means(th, idx["counts"], C),
                lambda th: means,
                theta,
            )
            lr = lr0 + (lr1 - lr0) * (t / steps_per_epoch)
            key = jax.random.fold_in(epoch_key, t)
            theta, loss = step_fn(theta, idx, means, counts_f, lr, key)
            return (theta, means), loss

        means0 = local_means(theta, idx["counts"], C)
        (theta, _), losses_ = jax.lax.scan(
            body, (theta, means0), jnp.arange(steps_per_epoch)
        )
        return theta, jnp.mean(losses_)

    return epoch


# ---------------------------------------------------------------------------
# Fit driver — one estimator, every scale (execution lives in core/strategy)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitResult:
    embedding: np.ndarray  # (N, out_dim) in the original point order
    index: "AnnIndex"
    losses: list
    wall_time_s: float
    epoch_times: list
    # execution provenance
    strategy: str = "local"
    n_shards: int = 1
    mesh_shape: Optional[tuple] = None
    mesh_axes: Optional[tuple] = None
    # index-build provenance: "local" | "sharded" (IndexBuilder ran),
    # "cache" (checkpoint_dir/index.npz reused), "provided" (index= argument)
    index_build_strategy: str = ""
    index_build_s: float = 0.0
    # checkpoint/resume provenance
    start_epoch: int = 0
    resumed: bool = False
    checkpoint_dir: str = ""
    checkpoint_epochs: list = dataclasses.field(default_factory=list)
    # multi-process provenance (jax.distributed; 1/0 single-process)
    process_count: int = 1
    process_index: int = 0


def _config_digest(cfg: NomadConfig) -> dict:
    """The config fields a checkpoint must agree on to resume bit-exactly."""
    d = dataclasses.asdict(cfg)
    for transient in (
        "checkpoint_dir",
        "checkpoint_every_epochs",
        "use_pallas",
        "kernel_impl",
        # serve-side knobs never change what a fit computes
        "serve_strategy",
        "serve_microbatch",
        "serve_knn_block",
        "transform_steps",
        "transform_lr",
    ):
        d.pop(transient, None)
    return d


def prepare_inputs(
    x, dim: Optional[int] = None, caller: str = "fit", chunk_rows: int = 0
):
    """The one validation/dtype-coercion gate for ``fit`` AND ``transform``.

    Integer and half-precision inputs are upcast to float32 (the pipeline's
    native dtype); float64 is *rejected* rather than silently halved so the
    precision loss stays a caller decision; NaN/Inf fail with the same
    actionable error everywhere.

    Out-of-core inputs — an :class:`repro.data.store.EmbeddingStore`, an
    ``np.memmap``, or a path to a ``.npy``/sharded-store directory — are
    validated **per chunk** (``chunk_rows`` rows at a time, default 8192)
    and returned as a store the caller streams from: neither the float32
    cast nor the NaN scan ever allocates a full-size temporary. In-memory
    arrays keep the resident behaviour and return an ``np.ndarray``.
    """
    import os as _os

    from repro.data.store import DEFAULT_CHUNK_ROWS, as_store, is_store

    if (
        is_store(x)
        or isinstance(x, np.memmap)
        or isinstance(x, (str, _os.PathLike))
    ):
        st = as_store(x)
        if st.dtype_name == "float64":
            raise ValueError(
                f"{caller}: x is float64 — the whole pipeline (index build, "
                "kernels, serving) runs float32; pass x.astype(np.float32) "
                "explicitly so the precision cut is your call, not a silent one"
            )
        if dim is not None and st.dim != dim:
            raise ValueError(
                f"{caller}: x has dim {st.dim} but the fitted map expects "
                f"dim {dim} — queries must live in the training feature space"
            )
        n_bad = 0
        for _s, chunk in st.iter_chunks(
            chunk_rows if chunk_rows > 0 else DEFAULT_CHUNK_ROWS
        ):
            finite = np.isfinite(chunk)
            if not finite.all():
                n_bad += int(chunk.size - finite.sum())
        if n_bad:
            raise ValueError(
                f"{caller}: x contains {n_bad} non-finite values (NaN/Inf) — "
                "clean or impute before projecting; a single NaN poisons the "
                "k-means statistics and every distance downstream"
            )
        return st

    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(
            f"{caller}: expected a 2-D (n_points, dim) array, got shape {x.shape}"
        )
    if x.dtype == np.float64:
        raise ValueError(
            f"{caller}: x is float64 — the whole pipeline (index build, "
            "kernels, serving) runs float32; pass x.astype(np.float32) "
            "explicitly so the precision cut is your call, not a silent one"
        )
    if x.dtype != np.float32:
        x = x.astype(np.float32)
    if not np.isfinite(x).all():
        n_bad = int(np.size(x) - np.isfinite(x).sum())
        raise ValueError(
            f"{caller}: x contains {n_bad} non-finite values (NaN/Inf) — "
            "clean or impute before projecting; a single NaN poisons the "
            "k-means statistics and every distance downstream"
        )
    if dim is not None and x.shape[1] != dim:
        raise ValueError(
            f"{caller}: x has dim {x.shape[1]} but the fitted map expects "
            f"dim {dim} — queries must live in the training feature space"
        )
    return x


class NomadProjection:
    """The unified scikit-style front end: ``NomadProjection(cfg).fit(x)``.

    One estimator covers every scale. ``strategy`` (ctor arg, default
    ``cfg.strategy``) selects how epochs execute — ``"auto"`` resolves from
    ``jax.devices()``; ``"local"`` / ``"sharded"`` / ``"hierarchical"`` force
    a mode; an :class:`repro.core.strategy.ExecutionStrategy` instance plugs
    in a custom one. All paths return the same enriched :class:`FitResult`.
    The ANN index is built the same way: ``cfg.build_strategy`` resolves an
    :class:`repro.index.build.IndexBuilder` over the training mesh's device
    pool, so the §3.2 pipeline is device-resident (and sharded) before the
    first epoch runs; ``FitResult.index_build_strategy`` /
    ``index_build_s`` record what happened.

    Progress streams through the structured event API
    (:class:`repro.core.strategy.FitCallbacks`): ``on_epoch_start``,
    ``on_epoch_end`` (with the *unpermuted* ``(N, out_dim)`` embedding),
    ``on_means_refresh``, ``on_checkpoint``.

    With ``cfg.checkpoint_dir`` set, θ is checkpointed every
    ``cfg.checkpoint_every_epochs`` epochs (atomic commit; the ANN index is
    cached beside it), and a killed run continues with
    ``NomadProjection.from_checkpoint(dir).fit(x)`` — same fold_in schedule,
    so the result matches an uninterrupted run.

    A fitted (or checkpoint-loaded) estimator also serves: ``transform(q)``
    places unseen rows on the frozen map (``repro.serve``) without touching
    a single fitted coordinate — ``from_checkpoint(dir).transform(q)``
    needs no access to the training array at all.
    """

    def __init__(
        self,
        cfg: NomadConfig,
        method: Optional[str] = None,
        *,
        strategy=None,
        mesh=None,
        shard_axes=None,
        pod_axis=None,
    ):
        self.cfg = cfg
        self.method = method or cfg.method
        self.strategy = strategy if strategy is not None else cfg.strategy
        self.mesh = mesh
        self.shard_axes = shard_axes
        self.pod_axis = pod_axis
        self._resume_default = False
        self._fit_result: Optional[FitResult] = None
        self._frozen = None
        self._server = None

    # -- constructors ----------------------------------------------------------

    @classmethod
    def from_checkpoint(
        cls, checkpoint_dir: str, cfg: Optional[NomadConfig] = None, **overrides
    ) -> "NomadProjection":
        """Rebuild the estimator a checkpoint directory was written by.

        The returned estimator resumes by default: ``.fit(x)`` restores the
        latest θ + epoch and continues to ``cfg.n_epochs``. Pass field
        ``overrides`` (or a full ``cfg``) to alter the continuation.
        """
        from repro.checkpoint.checkpointer import load_metadata

        meta = load_metadata(checkpoint_dir)
        if cfg is None:
            if "config" not in meta:
                raise ValueError(
                    f"checkpoint under {checkpoint_dir} has no stored config "
                    "(written by a pre-unified-API launcher?) — pass cfg= "
                    "explicitly to resume it"
                )
            stored = dict(meta["config"])
            stored.update(checkpoint_dir=checkpoint_dir, **overrides)
            cfg = NomadConfig(**stored)
        est = cls(cfg, method=meta.get("method"))
        est._resume_default = True
        return est

    # -- the one fit ----------------------------------------------------------

    def fit(
        self,
        x: np.ndarray,
        index: "Optional[AnnIndex]" = None,
        callback: Optional[Callable] = None,
        *,
        callbacks=None,
        resume: Optional[bool] = None,
        theta0=None,
    ) -> FitResult:
        """Fit the map. ``resume=True`` continues from ``cfg.checkpoint_dir``.

        ``x`` may be an in-memory array **or** a disk-backed corpus — an
        :class:`repro.data.store.EmbeddingStore`, an ``np.memmap``, or a
        path to a ``.npy`` / sharded-store directory. Store inputs stream
        through the whole pipeline (per-chunk validation, streamed §3.2
        index build, streamed PCA init); the epoch loop itself touches only
        θ and the O(N·k) index arrays, never the corpus, so a fit from disk
        keeps host RSS at O(chunk + K·D + N·k). With the same
        ``cfg.chunk_rows`` set, fit(store) and fit(ndarray) of identical
        rows are bit-equal (chunking pins the f32 accumulation order).

        ``callback`` is the deprecated bare ``fn(epoch, embedding, loss)``
        form; prefer ``callbacks=`` with a
        :class:`repro.core.strategy.FitCallbacks`.
        """
        import os
        import warnings

        from repro.core.strategy import (
            CheckpointEvent,
            EpochEndEvent,
            EpochStartEvent,
            MeansRefreshEvent,
            as_callbacks,
            resolve_strategy,
            sync_processes,
        )
        from repro.index.ann import (
            data_fingerprint,
            index_cache_path,
            load_index,
            save_index,
        )
        from repro.index.build import IndexBuilder

        cfg = self.cfg
        x = prepare_inputs(x, caller="fit", chunk_rows=cfg.chunk_rows)
        t0 = time.time()
        events = as_callbacks(callbacks, callback)
        resume = self._resume_default if resume is None else resume
        ckdir = cfg.checkpoint_dir
        if resume and not ckdir:
            raise ValueError("resume=True needs cfg.checkpoint_dir to be set")

        # ---- index: argument > on-disk cache > fresh build --------------------
        index_cache = index_cache_path(ckdir) if ckdir else ""
        cache_stale = False
        build_strategy, build_s = "provided", 0.0
        if index is None and index_cache and os.path.exists(index_cache):
            cached = load_index(index_cache)
            # a stale cache (checkpoint_dir reused across datasets) must not
            # silently replace the data the caller passed in — neither by
            # shape nor, for same-shape datasets, by content (fingerprint of
            # a deterministic row sample)
            if cached.n_points != x.shape[0] or cached.x_rows.shape[1] != x.shape[1]:
                cache_stale = True
                warnings.warn(
                    f"ignoring index cache {index_cache}: built for "
                    f"({cached.n_points}, {cached.x_rows.shape[1]}) data, "
                    f"got {x.shape} — rebuilding"
                )
            elif cached.fingerprint and cached.fingerprint != data_fingerprint(x):
                cache_stale = True
                warnings.warn(
                    f"ignoring index cache {index_cache}: same shape but "
                    f"different data content (fingerprint mismatch) — rebuilding"
                )
            else:
                index = cached
                build_strategy = "cache"
        if index is None:
            builder = IndexBuilder(cfg, mesh=self.mesh)
            index = builder.build(x)
            build_strategy = builder.report.strategy
            build_s = builder.report.total_s
        if index_cache and (cache_stale or not os.path.exists(index_cache)):
            # multi-process: every process built the identical index via the
            # cross-process collectives — one writer, everyone waits for it
            if jax.process_index() == 0:
                os.makedirs(ckdir, exist_ok=True)
                save_index(index, index_cache)
            sync_processes("index-cache")

        # ---- θ: resume from checkpoint > warm start > fresh init --------------
        start_epoch, resumed = 0, False
        if resume:
            from repro.checkpoint import Checkpointer, latest_step

            if latest_step(ckdir) is not None:
                shape = (index.n_clusters * index.capacity, cfg.out_dim)
                skeleton = {"theta": np.zeros(shape, np.float32)}
                tree, meta = Checkpointer(ckdir).restore(skeleton)
                theta0 = tree["theta"]
                start_epoch = int(meta["epoch"]) + 1
                resumed = True
                stored = meta.get("config")
                if stored is not None and {
                    k: v for k, v in stored.items()
                    if k in _config_digest(cfg)
                } != _config_digest(cfg):
                    warnings.warn(
                        "resuming with a config that differs from the one the "
                        "checkpoint was written with — the continued run will "
                        "not match an uninterrupted one"
                    )
        if theta0 is None:
            theta0 = self._init_theta(x, index)

        # ---- strategy ------------------------------------------------------------
        strategy = resolve_strategy(
            self.strategy,
            cfg,
            method=self.method,
            mesh=self.mesh,
            shard_axes=self.shard_axes,
            pod_axis=self.pod_axis,
        )
        theta = strategy.prepare(cfg, self.method, index, theta0)

        ckpt = None
        multiprocess = jax.process_count() > 1
        if ckdir:
            from repro.checkpoint import Checkpointer

            # multi-process: process 0 writes synchronously and everyone
            # barriers on the commit — the async writer thread would race
            # the barrier's collectives
            ckpt = Checkpointer(
                ckdir,
                n_shards=strategy.n_shards,
                keep=3,
                async_save=not multiprocess,
                primary=jax.process_index() == 0,
            )
        every = max(1, cfg.checkpoint_every_epochs)

        # ---- the one epoch loop ---------------------------------------------------
        lr0 = cfg.resolved_lr0()
        key = jax.random.key(cfg.seed + 1)
        losses_, epoch_times, checkpoint_epochs = [], [], []
        try:
            for e in range(start_epoch, cfg.n_epochs):
                te = time.time()
                f0 = 1.0 - e / cfg.n_epochs
                f1 = 1.0 - (e + 1) / cfg.n_epochs
                if events is not None:
                    events.on_epoch_start(
                        EpochStartEvent(e, cfg.n_epochs, lr0 * f0, lr0 * f1, strategy.name)
                    )
                theta, mloss = strategy.run_epoch(
                    theta, e, lr0 * f0, lr0 * f1, jax.random.fold_in(key, e)
                )
                losses_.append(mloss)
                epoch_times.append(time.time() - te)

                if ckpt is not None and ((e + 1) % every == 0 or e == cfg.n_epochs - 1):
                    # strategy.fetch is collective: every process gathers the
                    # full θ even though only the primary writes it
                    ckpt.save(
                        e,
                        {"theta": strategy.fetch(theta)},
                        sharded_keys=("theta",),
                        metadata={
                            "epoch": e,
                            "config": dataclasses.asdict(cfg),
                            "method": self.method,
                            "strategy": strategy.name,
                            # snapshot: the async writer must not see later appends
                            "losses": list(losses_),
                        },
                    )
                    if multiprocess:
                        # no process races past a commit its peers rely on
                        sync_processes(f"ckpt-{e}")
                    checkpoint_epochs.append(e)
                    if events is not None:
                        events.on_checkpoint(
                            CheckpointEvent(e, e, ckdir, strategy.n_shards)
                        )
                if events is not None:
                    events.on_means_refresh(
                        MeansRefreshEvent(e, strategy.refreshes_per_epoch(), strategy.name)
                    )
                    emb_e = (
                        index.unpermute(strategy.fetch(theta))
                        if events.wants_embedding
                        else None
                    )
                    events.on_epoch_end(
                        EpochEndEvent(
                            e, cfg.n_epochs, mloss, epoch_times[-1], strategy.name, emb_e
                        )
                    )
        finally:
            if ckpt is not None:
                ckpt.wait()  # commit the in-flight save even on interruption

        emb = index.unpermute(strategy.fetch(theta))
        meta = strategy.describe()
        result = FitResult(
            embedding=emb,
            index=index,
            losses=losses_,
            wall_time_s=time.time() - t0,
            epoch_times=epoch_times,
            strategy=meta["strategy"],
            n_shards=meta["n_shards"],
            mesh_shape=meta["mesh_shape"],
            mesh_axes=meta["mesh_axes"],
            index_build_strategy=build_strategy,
            index_build_s=build_s,
            start_epoch=start_epoch,
            resumed=resumed,
            checkpoint_dir=ckdir,
            checkpoint_epochs=checkpoint_epochs,
            process_count=meta["process_count"],
            process_index=meta["process_index"],
        )
        self._fit_result = result
        self._frozen = None  # a refit invalidates any cached frozen state
        self._server = None
        return result

    def fit_transform(self, x: np.ndarray, **kwargs) -> np.ndarray:
        """``fit(...)`` and return just the ``(N, out_dim)`` embedding.

        Forwards through ``fit`` and therefore through the same
        :func:`prepare_inputs` validation gate ``transform`` uses —
        float64/NaN inputs fail with the same actionable error everywhere.
        """
        return self.fit(x, **kwargs).embedding

    # -- out-of-sample serving (repro.serve) -----------------------------------

    def map_server(self, **overrides):
        """The :class:`repro.serve.MapServer` this estimator serves from.

        Frozen state comes from the last ``fit`` when one ran in this
        process, else straight from ``cfg.checkpoint_dir`` (θ + cached
        index — **no training data needed**, the ``from_checkpoint``
        serving path). The config-default server is cached; passing
        ``overrides`` (``strategy=``, ``microbatch=``, ``mesh=``,
        ``steps=``, ``lr=``) returns a fresh *uncached* server, so a
        one-off override can never change what ``transform()`` later does.
        """
        from repro.checkpoint import latest_step
        from repro.serve import FrozenMap, MapServer

        if self._server is not None and not overrides:
            return self._server
        if self._frozen is None:
            if self._fit_result is not None:
                self._frozen = FrozenMap.from_fit(self._fit_result, self.cfg)
            elif self.cfg.checkpoint_dir and latest_step(self.cfg.checkpoint_dir) is not None:
                self._frozen = FrozenMap.from_checkpoint(self.cfg.checkpoint_dir, self.cfg)
            else:
                raise RuntimeError(
                    "transform needs a fitted map: call fit(x) first, or load "
                    "one with NomadProjection.from_checkpoint(dir)"
                )
        if overrides:
            return MapServer(self._frozen, **overrides)
        self._server = MapServer(self._frozen)
        return self._server

    def transform(self, x: np.ndarray, *, seed: int = 0) -> np.ndarray:
        """Place unseen rows on the frozen fitted map (out-of-sample
        extension). Returns the ``(n_queries, out_dim)`` placements;
        ``map_server().transform(x)`` returns the full
        :class:`repro.serve.TransformResult` (cells, neighbor ids/distances,
        per-batch latency). Never moves fitted positions — the serve
        kernels' gradients stop at the query rows.
        """
        return self.map_server().transform(x, seed=seed).embedding

    def _init_theta(self, x, index: "AnnIndex") -> jax.Array:
        from repro.data.store import as_store, is_store

        cfg = self.cfg
        if cfg.init == "pca":
            if is_store(x) or cfg.chunk_rows > 0:
                # the streamed init: same chunk schedule as the streamed
                # build, so fit(store) ≡ fit(ndarray) stays bit-exact
                from repro.core.pca import pca_init_streamed

                th0 = pca_init_streamed(
                    as_store(x),
                    cfg.out_dim,
                    cfg.init_scale,
                    chunk_rows=cfg.resolved_chunk_rows(),
                )
            else:
                th0 = np.asarray(
                    pca_init(jnp.asarray(x), cfg.out_dim, cfg.init_scale)
                )
        else:
            rng = np.random.default_rng(cfg.seed)
            th0 = rng.normal(0, cfg.init_scale, (x.shape[0], cfg.out_dim)).astype(
                np.float32
            )
        rows = np.zeros((index.n_clusters * index.capacity, cfg.out_dim), np.float32)
        rows[index.perm] = th0
        return jnp.asarray(rows)

"""NOMAD Projection driver (paper §3 end-to-end).

``make_step_fn`` builds the jitted SGD step over a *local* cluster-major
block of positions — the same function body serves the single-device
reference (local = everything) and the ``shard_map`` distributed path
(local = this shard's clusters, means/counts global). All index structures
come from :mod:`repro.index.ann`.

Method selection:
* ``"nomad"``  — Eq. 3: remote cells via means (M̃), own cell sampled (M).
* ``"infonc"`` — Eq. 2: the InfoNC-t-SNE baseline; all negatives drawn
  uniformly from the full support (single-device only — this is exactly the
  non-factorising loss the paper is working around).

Sampling conventions (paper §3.3): heads i uniform over points (uniform
marginal P_i); noise tails uniform over points (uniform ξ); |M| = n_noise.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from typing import TYPE_CHECKING

from repro.configs.base import NomadConfig
from repro.core import losses
from repro.core.pca import pca_init

if TYPE_CHECKING:  # runtime import is lazy (repro.index imports repro.core)
    from repro.index.ann import AnnIndex


# ---------------------------------------------------------------------------
# Sampling helpers (cluster-major layout)
# ---------------------------------------------------------------------------


def sample_points(key, n: int, cum_counts: jax.Array, capacity: int):
    """n uniform valid points. Returns (rows, cluster_ids) — both (n,)."""
    total = cum_counts[-1]
    u = jax.random.randint(key, (n,), 0, total)
    cluster = jnp.searchsorted(cum_counts, u, side="right").astype(jnp.int32)
    start = jnp.where(cluster > 0, cum_counts[cluster - 1], 0)
    slot = u - start
    return cluster * capacity + slot, cluster


def sample_in_cluster(key, cluster_ids: jax.Array, counts: jax.Array, capacity: int, s: int):
    """(B,) cluster ids → (B, s) uniform valid rows within each cluster."""
    B = cluster_ids.shape[0]
    c = counts[cluster_ids]  # (B,)
    u = jax.random.uniform(key, (B, s))
    slot = jnp.floor(u * c[:, None]).astype(jnp.int32)
    slot = jnp.minimum(slot, (c - 1)[:, None].astype(jnp.int32))
    return cluster_ids[:, None] * capacity + slot


def local_means(theta_rows: jax.Array, counts: jax.Array, capacity: int):
    """Masked per-cluster means of positions: (K·C, d) → (K, d)."""
    K = counts.shape[0]
    th = theta_rows.reshape(K, capacity, -1).astype(jnp.float32)
    valid = (jnp.arange(capacity)[None, :] < counts[:, None]).astype(jnp.float32)
    sums = jnp.sum(th * valid[:, :, None], axis=1)
    return sums / jnp.maximum(counts.astype(jnp.float32), 1.0)[:, None]


# ---------------------------------------------------------------------------
# The SGD step
# ---------------------------------------------------------------------------


def make_step_fn(
    cfg: NomadConfig,
    *,
    method: str = "nomad",
    cluster_offset: int = 0,
    n_total: Optional[int] = None,
):
    """Build ``step(theta, idx, state) -> (theta, loss)``.

    ``idx`` is a dict of local index arrays; ``state`` carries (means,
    global_counts, lr, key). ``cluster_offset`` maps local cluster ids into
    the global cell numbering (shard s owns cells [off, off + K_local)).

    The NOMAD branch runs the whole per-step loss through the fused
    ``"nomad_step"`` registry kernel (via :func:`losses.nomad_loss`):
    distances, Cauchy weights, attraction and the online-accumulated
    repulsive mass are one tiled pass with a custom VJP on TPU/GPU, and
    the bit-equal legacy multi-pass composition on CPU (``impl="jnp"``).
    ``cfg.kernel_impl`` / ``REPRO_KERNELS`` select per run.
    """
    n_total = n_total or cfg.n_points
    B, S, Mn = cfg.batch_size, cfg.n_exact_negatives, cfg.n_noise
    C = cfg.cluster_capacity

    def step(theta, idx, means, global_counts, lr, key):
        k_head, k_neg = jax.random.split(key)
        rows, cl_local = sample_points(k_head, B, idx["cum_counts"], C)
        pos_rows = idx["knn_idx"][rows]  # (B, k)
        pos_w = idx["knn_w"][rows]  # (B, k)
        th_i = theta[rows]
        th_pos = theta[pos_rows]

        if method == "infonc":
            # Eq. 2 baseline: |M| noise tails uniform over the full support
            neg_rows, _ = sample_points(k_neg, B * Mn, idx["cum_counts"], C)
            neg_rows = neg_rows.reshape(B, Mn)
            th_neg = theta[neg_rows]

            def loss_fn(ti, tp, tn):
                return losses.infonc_tsne_loss(ti, tp, pos_w, tn)

        else:
            neg_rows = sample_in_cluster(k_neg, cl_local, idx["counts"], C, S)
            th_neg = theta[neg_rows]
            cell_global = cl_local + cluster_offset

            def loss_fn(ti, tp, tn):
                return losses.nomad_loss(
                    ti,
                    tp,
                    pos_w,
                    means,
                    global_counts,
                    cell_global,
                    tn,
                    n_noise=Mn,
                    n_total=n_total,
                    impl=cfg.resolved_kernel_impl(),
                )

        loss, (g_i, g_pos, g_neg) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
            th_i, th_pos, th_neg
        )
        # sparse SGD: only touched rows are updated (reaction forces included)
        theta = theta.at[rows].add(-lr * g_i)
        theta = theta.at[pos_rows.reshape(-1)].add(-lr * g_pos.reshape(-1, theta.shape[1]))
        theta = theta.at[neg_rows.reshape(-1)].add(-lr * g_neg.reshape(-1, theta.shape[1]))
        return theta, loss

    return step


def make_partial_step_fn(
    cfg: NomadConfig,
    *,
    method: str = "nomad",
    n_total: Optional[int] = None,
):
    """The :func:`make_step_fn` body with heads restricted to a cell subset.

    ``idx`` additionally carries ``aff_cells`` (A,) global ids of the cells
    a partial_fit touched and ``aff_cum_counts`` (A,) their cumulative real
    counts: heads sample uniformly over the *affected* points only, mapped
    to global rows through the affected→global cell indirection. Means,
    global counts and the repulsive mass still span the full layout, so
    the refined cells equilibrate against the whole map — but gradients
    only ever land on rows of affected cells (positives are in-cluster,
    negatives in-cell), leaving the rest of θ bit-identical.
    """
    n_total = n_total or cfg.n_points
    B, S, Mn = cfg.batch_size, cfg.n_exact_negatives, cfg.n_noise
    C = cfg.cluster_capacity

    def step(theta, idx, means, global_counts, lr, key):
        k_head, k_neg = jax.random.split(key)
        acum = idx["aff_cum_counts"]
        u = jax.random.randint(k_head, (B,), 0, acum[-1])
        a = jnp.searchsorted(acum, u, side="right").astype(jnp.int32)
        start = jnp.where(a > 0, acum[a - 1], 0)
        cell = idx["aff_cells"][a]  # global cell ids
        rows = cell * C + (u - start)
        pos_rows = idx["knn_idx"][rows]
        pos_w = idx["knn_w"][rows]
        th_i = theta[rows]
        th_pos = theta[pos_rows]

        if method == "infonc":
            neg_rows, _ = sample_points(k_neg, B * Mn, idx["cum_counts"], C)
            neg_rows = neg_rows.reshape(B, Mn)
            th_neg = theta[neg_rows]

            def loss_fn(ti, tp, tn):
                return losses.infonc_tsne_loss(ti, tp, pos_w, tn)

        else:
            neg_rows = sample_in_cluster(k_neg, cell, idx["counts"], C, S)
            th_neg = theta[neg_rows]

            def loss_fn(ti, tp, tn):
                return losses.nomad_loss(
                    ti,
                    tp,
                    pos_w,
                    means,
                    global_counts,
                    cell,
                    tn,
                    n_noise=Mn,
                    n_total=n_total,
                    impl=cfg.resolved_kernel_impl(),
                )

        loss, (g_i, g_pos, g_neg) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
            th_i, th_pos, th_neg
        )
        theta = theta.at[rows].add(-lr * g_i)
        theta = theta.at[pos_rows.reshape(-1)].add(-lr * g_pos.reshape(-1, theta.shape[1]))
        theta = theta.at[neg_rows.reshape(-1)].add(-lr * g_neg.reshape(-1, theta.shape[1]))
        return theta, loss

    return step


def make_epoch_fn(cfg: NomadConfig, step_fn, steps_per_epoch: int):
    """jit-compiled epoch: refresh means once, then scan the SGD steps.

    Mirrors Fig. 2: means are computed (and, in the distributed version,
    all-gathered) once per epoch and held fixed (stop-gradient) within it.
    ``mean_refresh_steps > 0`` refreshes more often (beyond-paper knob).
    """
    C = cfg.cluster_capacity
    refresh = cfg.mean_refresh_steps or steps_per_epoch

    @jax.jit
    def epoch(theta, idx, lr0, lr1, epoch_key):
        counts_f = idx["counts"].astype(jnp.float32)

        def body(carry, t):
            theta, means = carry
            means = jax.lax.cond(
                t % refresh == 0,
                lambda th: local_means(th, idx["counts"], C),
                lambda th: means,
                theta,
            )
            lr = lr0 + (lr1 - lr0) * (t / steps_per_epoch)
            key = jax.random.fold_in(epoch_key, t)
            theta, loss = step_fn(theta, idx, means, counts_f, lr, key)
            return (theta, means), loss

        means0 = local_means(theta, idx["counts"], C)
        (theta, _), losses_ = jax.lax.scan(
            body, (theta, means0), jnp.arange(steps_per_epoch)
        )
        return theta, jnp.mean(losses_)

    return epoch


# ---------------------------------------------------------------------------
# Fit driver — one estimator, every scale (execution lives in core/strategy)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitResult:
    embedding: np.ndarray  # (N, out_dim) in the original point order
    index: "AnnIndex"
    losses: list
    wall_time_s: float
    epoch_times: list
    # execution provenance
    strategy: str = "local"
    n_shards: int = 1
    mesh_shape: Optional[tuple] = None
    mesh_axes: Optional[tuple] = None
    # index-build provenance: "local" | "sharded" (IndexBuilder ran),
    # "cache" (checkpoint_dir/index.npz reused), "provided" (index= argument)
    index_build_strategy: str = ""
    index_build_s: float = 0.0
    # checkpoint/resume provenance
    start_epoch: int = 0
    resumed: bool = False
    checkpoint_dir: str = ""
    checkpoint_epochs: list = dataclasses.field(default_factory=list)
    # multi-process provenance (jax.distributed; 1/0 single-process)
    process_count: int = 1
    process_index: int = 0


@dataclasses.dataclass
class PartialFitResult:
    """What one :meth:`NomadProjection.partial_fit` call produced."""

    embedding: np.ndarray  # (N_old + M, out_dim) in original ∥ appended order
    index: "AnnIndex"  # grown index (K' cells, capacity unchanged)
    n_new: int  # appended rows admitted this call
    n_points: int  # total rows after the append
    losses: list  # refinement epoch mean losses
    wall_time_s: float = 0.0
    epoch_times: list = dataclasses.field(default_factory=list)
    refine_epochs: int = 0
    # admission provenance
    affected_cells: np.ndarray = None  # (A,) cells placed into / re-seeded
    n_split_cells: int = 0  # cells that overflowed and were re-seeded
    n_new_cells: int = 0  # layout growth (K' - K)
    stage_s: dict = dataclasses.field(default_factory=dict)
    # lineage provenance (empty when cfg.checkpoint_dir is unset)
    version: str = ""
    parent_version: str = ""
    checkpoint_dir: str = ""  # the self-contained version directory


def _config_digest(cfg: NomadConfig) -> dict:
    """The config fields a checkpoint must agree on to resume bit-exactly."""
    d = dataclasses.asdict(cfg)
    for transient in (
        "checkpoint_dir",
        "checkpoint_every_epochs",
        "use_pallas",
        "kernel_impl",
        # serve-side knobs never change what a fit computes
        "serve_strategy",
        "serve_microbatch",
        "serve_knn_block",
        "transform_steps",
        "transform_lr",
        # incremental-growth knob: changing it never alters the base fit
        "partial_refine_epochs",
    ):
        d.pop(transient, None)
    return d


def prepare_inputs(
    x, dim: Optional[int] = None, caller: str = "fit", chunk_rows: int = 0
):
    """The one validation/dtype-coercion gate for ``fit`` AND ``transform``.

    Integer and half-precision inputs are upcast to float32 (the pipeline's
    native dtype); float64 is *rejected* rather than silently halved so the
    precision loss stays a caller decision; NaN/Inf fail with the same
    actionable error everywhere.

    Out-of-core inputs — an :class:`repro.data.store.EmbeddingStore`, an
    ``np.memmap``, or a path to a ``.npy``/sharded-store directory — are
    validated **per chunk** (``chunk_rows`` rows at a time, default 8192)
    and returned as a store the caller streams from: neither the float32
    cast nor the NaN scan ever allocates a full-size temporary. In-memory
    arrays keep the resident behaviour and return an ``np.ndarray``.
    """
    import os as _os

    from repro.data.store import DEFAULT_CHUNK_ROWS, as_store, is_store

    if (
        is_store(x)
        or isinstance(x, np.memmap)
        or isinstance(x, (str, _os.PathLike))
    ):
        st = as_store(x)
        if st.dtype_name == "float64":
            raise ValueError(
                f"{caller}: x is float64 — the whole pipeline (index build, "
                "kernels, serving) runs float32; pass x.astype(np.float32) "
                "explicitly so the precision cut is your call, not a silent one"
            )
        if dim is not None and st.dim != dim:
            raise ValueError(
                f"{caller}: x has dim {st.dim} but the fitted map expects "
                f"dim {dim} — queries must live in the training feature space"
            )
        n_bad = 0
        for _s, chunk in st.iter_chunks(
            chunk_rows if chunk_rows > 0 else DEFAULT_CHUNK_ROWS
        ):
            finite = np.isfinite(chunk)
            if not finite.all():
                n_bad += int(chunk.size - finite.sum())
        if n_bad:
            raise ValueError(
                f"{caller}: x contains {n_bad} non-finite values (NaN/Inf) — "
                "clean or impute before projecting; a single NaN poisons the "
                "k-means statistics and every distance downstream"
            )
        return st

    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(
            f"{caller}: expected a 2-D (n_points, dim) array, got shape {x.shape}"
        )
    if x.dtype == np.float64:
        raise ValueError(
            f"{caller}: x is float64 — the whole pipeline (index build, "
            "kernels, serving) runs float32; pass x.astype(np.float32) "
            "explicitly so the precision cut is your call, not a silent one"
        )
    if x.dtype != np.float32:
        x = x.astype(np.float32)
    if not np.isfinite(x).all():
        n_bad = int(np.size(x) - np.isfinite(x).sum())
        raise ValueError(
            f"{caller}: x contains {n_bad} non-finite values (NaN/Inf) — "
            "clean or impute before projecting; a single NaN poisons the "
            "k-means statistics and every distance downstream"
        )
    if dim is not None and x.shape[1] != dim:
        raise ValueError(
            f"{caller}: x has dim {x.shape[1]} but the fitted map expects "
            f"dim {dim} — queries must live in the training feature space"
        )
    return x


class NomadProjection:
    """The unified scikit-style front end: ``NomadProjection(cfg).fit(x)``.

    One estimator covers every scale. ``strategy`` (ctor arg, default
    ``cfg.strategy``) selects how epochs execute — ``"auto"`` resolves from
    ``jax.devices()``; ``"local"`` / ``"sharded"`` / ``"hierarchical"`` force
    a mode; an :class:`repro.core.strategy.ExecutionStrategy` instance plugs
    in a custom one. All paths return the same enriched :class:`FitResult`.
    The ANN index is built the same way: ``cfg.build_strategy`` resolves an
    :class:`repro.index.build.IndexBuilder` over the training mesh's device
    pool, so the §3.2 pipeline is device-resident (and sharded) before the
    first epoch runs; ``FitResult.index_build_strategy`` /
    ``index_build_s`` record what happened.

    Progress streams through the structured event API
    (:class:`repro.core.strategy.FitCallbacks`): ``on_epoch_start``,
    ``on_epoch_end`` (with the *unpermuted* ``(N, out_dim)`` embedding),
    ``on_means_refresh``, ``on_checkpoint``.

    With ``cfg.checkpoint_dir`` set, θ is checkpointed every
    ``cfg.checkpoint_every_epochs`` epochs (atomic commit; the ANN index is
    cached beside it), and a killed run continues with
    ``NomadProjection.from_checkpoint(dir).fit(x)`` — same fold_in schedule,
    so the result matches an uninterrupted run.

    A fitted (or checkpoint-loaded) estimator also serves: ``transform(q)``
    places unseen rows on the frozen map (``repro.serve``) without touching
    a single fitted coordinate — ``from_checkpoint(dir).transform(q)``
    needs no access to the training array at all.
    """

    def __init__(
        self,
        cfg: NomadConfig,
        method: Optional[str] = None,
        *,
        strategy=None,
        mesh=None,
        shard_axes=None,
        pod_axis=None,
    ):
        self.cfg = cfg
        self.method = method or cfg.method
        self.strategy = strategy if strategy is not None else cfg.strategy
        self.mesh = mesh
        self.shard_axes = shard_axes
        self.pod_axis = pod_axis
        self._resume_default = False
        self._fit_result: Optional[FitResult] = None
        self._frozen = None
        self._server = None

    # -- constructors ----------------------------------------------------------

    @classmethod
    def from_checkpoint(
        cls, checkpoint_dir: str, cfg: Optional[NomadConfig] = None, **overrides
    ) -> "NomadProjection":
        """Rebuild the estimator a checkpoint directory was written by.

        The returned estimator resumes by default: ``.fit(x)`` restores the
        latest θ + epoch and continues to ``cfg.n_epochs``. Pass field
        ``overrides`` (or a full ``cfg``) to alter the continuation.
        """
        from repro.checkpoint.checkpointer import load_metadata

        meta = load_metadata(checkpoint_dir)
        if cfg is None:
            if "config" not in meta:
                raise ValueError(
                    f"checkpoint under {checkpoint_dir} has no stored config "
                    "(written by a pre-unified-API launcher?) — pass cfg= "
                    "explicitly to resume it"
                )
            stored = dict(meta["config"])
            stored.update(checkpoint_dir=checkpoint_dir, **overrides)
            cfg = NomadConfig(**stored)
        est = cls(cfg, method=meta.get("method"))
        est._resume_default = True
        return est

    # -- the one fit ----------------------------------------------------------

    def fit(
        self,
        x: np.ndarray,
        index: "Optional[AnnIndex]" = None,
        callback: Optional[Callable] = None,
        *,
        callbacks=None,
        resume: Optional[bool] = None,
        theta0=None,
    ) -> FitResult:
        """Fit the map. ``resume=True`` continues from ``cfg.checkpoint_dir``.

        ``x`` may be an in-memory array **or** a disk-backed corpus — an
        :class:`repro.data.store.EmbeddingStore`, an ``np.memmap``, or a
        path to a ``.npy`` / sharded-store directory. Store inputs stream
        through the whole pipeline (per-chunk validation, streamed §3.2
        index build, streamed PCA init); the epoch loop itself touches only
        θ and the O(N·k) index arrays, never the corpus, so a fit from disk
        keeps host RSS at O(chunk + K·D + N·k). With the same
        ``cfg.chunk_rows`` set, fit(store) and fit(ndarray) of identical
        rows are bit-equal (chunking pins the f32 accumulation order).

        ``callback`` is the deprecated bare ``fn(epoch, embedding, loss)``
        form; prefer ``callbacks=`` with a
        :class:`repro.core.strategy.FitCallbacks`.
        """
        import os
        import warnings

        from repro.core.strategy import (
            CheckpointEvent,
            EpochEndEvent,
            EpochStartEvent,
            MeansRefreshEvent,
            as_callbacks,
            resolve_strategy,
            sync_processes,
        )
        from repro.index.ann import (
            data_fingerprint,
            index_cache_path,
            load_index,
            save_index,
        )
        from repro.index.build import IndexBuilder

        cfg = self.cfg
        x = prepare_inputs(x, caller="fit", chunk_rows=cfg.chunk_rows)
        t0 = time.time()
        events = as_callbacks(callbacks, callback)
        resume = self._resume_default if resume is None else resume
        ckdir = cfg.checkpoint_dir
        if resume and not ckdir:
            raise ValueError("resume=True needs cfg.checkpoint_dir to be set")

        # ---- index: argument > on-disk cache > fresh build --------------------
        index_cache = index_cache_path(ckdir) if ckdir else ""
        cache_stale = False
        build_strategy, build_s = "provided", 0.0
        if index is None and index_cache and os.path.exists(index_cache):
            cached = load_index(index_cache)
            # a stale cache (checkpoint_dir reused across datasets) must not
            # silently replace the data the caller passed in — neither by
            # shape nor, for same-shape datasets, by content (fingerprint of
            # a deterministic row sample)
            if cached.n_points != x.shape[0] or cached.x_rows.shape[1] != x.shape[1]:
                cache_stale = True
                warnings.warn(
                    f"ignoring index cache {index_cache}: built for "
                    f"({cached.n_points}, {cached.x_rows.shape[1]}) data, "
                    f"got {x.shape} — rebuilding"
                )
            elif cached.fingerprint and cached.fingerprint != data_fingerprint(x):
                cache_stale = True
                warnings.warn(
                    f"ignoring index cache {index_cache}: same shape but "
                    f"different data content (fingerprint mismatch) — rebuilding"
                )
            else:
                index = cached
                build_strategy = "cache"
        if index is None:
            builder = IndexBuilder(cfg, mesh=self.mesh)
            index = builder.build(x)
            build_strategy = builder.report.strategy
            build_s = builder.report.total_s
        if index_cache and (cache_stale or not os.path.exists(index_cache)):
            # multi-process: every process built the identical index via the
            # cross-process collectives — one writer, everyone waits for it
            if jax.process_index() == 0:
                os.makedirs(ckdir, exist_ok=True)
                save_index(index, index_cache)
            sync_processes("index-cache")

        # ---- θ: resume from checkpoint > warm start > fresh init --------------
        start_epoch, resumed = 0, False
        if resume:
            from repro.checkpoint import Checkpointer, latest_step

            if latest_step(ckdir) is not None:
                shape = (index.n_clusters * index.capacity, cfg.out_dim)
                skeleton = {"theta": np.zeros(shape, np.float32)}
                tree, meta = Checkpointer(ckdir).restore(skeleton)
                theta0 = tree["theta"]
                start_epoch = int(meta["epoch"]) + 1
                resumed = True
                stored = meta.get("config")
                if stored is not None and {
                    k: v for k, v in stored.items()
                    if k in _config_digest(cfg)
                } != _config_digest(cfg):
                    warnings.warn(
                        "resuming with a config that differs from the one the "
                        "checkpoint was written with — the continued run will "
                        "not match an uninterrupted one"
                    )
        if theta0 is None:
            theta0 = self._init_theta(x, index)

        # ---- strategy ------------------------------------------------------------
        strategy = resolve_strategy(
            self.strategy,
            cfg,
            method=self.method,
            mesh=self.mesh,
            shard_axes=self.shard_axes,
            pod_axis=self.pod_axis,
        )
        theta = strategy.prepare(cfg, self.method, index, theta0)

        ckpt = None
        multiprocess = jax.process_count() > 1
        if ckdir:
            from repro.checkpoint import Checkpointer

            # multi-process: process 0 writes synchronously and everyone
            # barriers on the commit — the async writer thread would race
            # the barrier's collectives
            ckpt = Checkpointer(
                ckdir,
                n_shards=strategy.n_shards,
                keep=3,
                async_save=not multiprocess,
                primary=jax.process_index() == 0,
            )
        every = max(1, cfg.checkpoint_every_epochs)

        # ---- the one epoch loop ---------------------------------------------------
        lr0 = cfg.resolved_lr0()
        key = jax.random.key(cfg.seed + 1)
        losses_, epoch_times, checkpoint_epochs = [], [], []
        try:
            for e in range(start_epoch, cfg.n_epochs):
                te = time.time()
                f0 = 1.0 - e / cfg.n_epochs
                f1 = 1.0 - (e + 1) / cfg.n_epochs
                if events is not None:
                    events.on_epoch_start(
                        EpochStartEvent(e, cfg.n_epochs, lr0 * f0, lr0 * f1, strategy.name)
                    )
                theta, mloss = strategy.run_epoch(
                    theta, e, lr0 * f0, lr0 * f1, jax.random.fold_in(key, e)
                )
                losses_.append(mloss)
                epoch_times.append(time.time() - te)

                if ckpt is not None and ((e + 1) % every == 0 or e == cfg.n_epochs - 1):
                    # strategy.fetch is collective: every process gathers the
                    # full θ even though only the primary writes it
                    ckpt.save(
                        e,
                        {"theta": strategy.fetch(theta)},
                        sharded_keys=("theta",),
                        metadata={
                            "epoch": e,
                            "config": dataclasses.asdict(cfg),
                            "method": self.method,
                            "strategy": strategy.name,
                            # snapshot: the async writer must not see later appends
                            "losses": list(losses_),
                        },
                    )
                    if multiprocess:
                        # no process races past a commit its peers rely on
                        sync_processes(f"ckpt-{e}")
                    checkpoint_epochs.append(e)
                    if events is not None:
                        events.on_checkpoint(
                            CheckpointEvent(e, e, ckdir, strategy.n_shards)
                        )
                if events is not None:
                    events.on_means_refresh(
                        MeansRefreshEvent(e, strategy.refreshes_per_epoch(), strategy.name)
                    )
                    emb_e = (
                        index.unpermute(strategy.fetch(theta))
                        if events.wants_embedding
                        else None
                    )
                    events.on_epoch_end(
                        EpochEndEvent(
                            e, cfg.n_epochs, mloss, epoch_times[-1], strategy.name, emb_e
                        )
                    )
        finally:
            if ckpt is not None:
                ckpt.wait()  # commit the in-flight save even on interruption

        emb = index.unpermute(strategy.fetch(theta))
        meta = strategy.describe()
        result = FitResult(
            embedding=emb,
            index=index,
            losses=losses_,
            wall_time_s=time.time() - t0,
            epoch_times=epoch_times,
            strategy=meta["strategy"],
            n_shards=meta["n_shards"],
            mesh_shape=meta["mesh_shape"],
            mesh_axes=meta["mesh_axes"],
            index_build_strategy=build_strategy,
            index_build_s=build_s,
            start_epoch=start_epoch,
            resumed=resumed,
            checkpoint_dir=ckdir,
            checkpoint_epochs=checkpoint_epochs,
            process_count=meta["process_count"],
            process_index=meta["process_index"],
        )
        self._fit_result = result
        self._frozen = None  # a refit invalidates any cached frozen state
        self._server = None
        return result

    # -- incremental growth (append-only corpora) ------------------------------

    def _previous_state(self):
        """(index, theta_rows, parent_dir) of the map being grown.

        In-process fit state wins; otherwise the newest lineage version
        under ``cfg.checkpoint_dir`` (falling back to the root itself for
        pre-lineage checkpoints) — so ``from_checkpoint(root).partial_fit``
        needs **no access to the original corpus**: the previous rows come
        from the cached index's ``x_rows``.
        """
        from repro.checkpoint import MapLineage, latest_step, load_theta
        from repro.index.ann import index_cache_path, load_index

        cfg = self.cfg
        if self._fit_result is not None:
            index = self._fit_result.index
            theta_rows = np.zeros(
                (index.n_clusters * index.capacity, cfg.out_dim), np.float32
            )
            theta_rows[index.perm] = self._fit_result.embedding
            return index, theta_rows, ""
        if not cfg.checkpoint_dir:
            raise RuntimeError(
                "partial_fit needs a fitted map: call fit(x) first, or load "
                "one with NomadProjection.from_checkpoint(dir)"
            )
        lineage = MapLineage(cfg.checkpoint_dir)
        base = lineage.latest()
        base_dir = base.path if base is not None else cfg.checkpoint_dir
        import os

        cache = index_cache_path(base_dir)
        if not os.path.exists(cache) or latest_step(base_dir) is None:
            raise RuntimeError(
                f"partial_fit: {base_dir} holds no fitted map (need both "
                "index.npz and a step_*/ checkpoint) — run fit(x) with "
                "cfg.checkpoint_dir set first"
            )
        index = load_index(cache)
        theta_rows, _meta = load_theta(base_dir)
        return index, theta_rows, base_dir

    def partial_fit(
        self,
        new_x,
        *,
        callbacks=None,
        refine_epochs: Optional[int] = None,
        seed: Optional[int] = None,
    ) -> PartialFitResult:
        """Grow the fitted map in place with appended rows (no refit).

        Pipeline: **place** ``new_x`` on the frozen map via the serve path
        (initial positions + nearest-centroid target cells) → **admit**
        into capacity-bounded cells, re-seeding only cells that overflow
        (:mod:`repro.index.incremental`) → **patch** the in-cluster kNN
        graph and ``x_rows`` for affected cells only → **refine** with a
        few cheap epochs whose heads are restricted to the affected cells
        (:class:`repro.core.strategy.PartialRefineStrategy`) → **version**
        the artifacts: with ``cfg.checkpoint_dir`` set, a self-contained
        ``vN/`` directory (θ checkpoint + index cache) is written and
        recorded in the ``versions.json`` lineage, ready for
        ``MapRegistry.swap`` / ``FrozenMap.from_checkpoint``.

        Rows in cells the append never touches keep **bit-identical**
        positions; appending 0 rows is a true no-op (no artifact changes,
        no version written). Multi-process runs are not supported — grow
        on one process, serve the version anywhere.
        """
        import os

        from repro.core.strategy import (
            EpochEndEvent,
            EpochStartEvent,
            PartialRefineStrategy,
            as_callbacks,
        )

        if jax.process_count() > 1:
            raise NotImplementedError(
                "partial_fit is single-process: grow the map on one process "
                "and point peers/servers at the new lineage version"
            )
        cfg = self.cfg
        t0 = time.time()
        events = as_callbacks(callbacks, None)
        index, theta_rows, _base_dir = self._previous_state()
        if index.capacity != cfg.cluster_capacity:
            raise ValueError(
                f"partial_fit: index capacity {index.capacity} != "
                f"cfg.cluster_capacity {cfg.cluster_capacity} — partial_fit "
                "must run with the config the map was fitted with (capacity "
                "is a static layout property; it never changes on append)"
            )

        from repro.data.store import is_store

        new_x = prepare_inputs(
            new_x, dim=int(index.x_rows.shape[1]), caller="partial_fit"
        )
        if is_store(new_x):
            new_x = new_x.materialize()  # appends are batch-sized, not corpus-sized
        M = int(new_x.shape[0])
        n_old = index.n_points

        ckdir = cfg.checkpoint_dir
        lineage = None
        if ckdir:
            from repro.checkpoint import MapLineage

            lineage = MapLineage(ckdir)

        if M == 0:  # the no-op invariant: nothing changes, nothing is written
            latest = lineage.latest() if lineage is not None else None
            return PartialFitResult(
                embedding=index.unpermute(np.asarray(theta_rows)),
                index=index,
                n_new=0,
                n_points=n_old,
                losses=[],
                wall_time_s=time.time() - t0,
                refine_epochs=0,
                affected_cells=np.zeros((0,), np.int64),
                stage_s={},
                version=latest.name if latest is not None else "",
                parent_version=latest.name if latest is not None else "",
                checkpoint_dir="",
            )

        # ---- place: the frozen-transform serve path ---------------------------
        from repro.serve import FrozenMap, MapServer

        t_place = time.time()
        frozen = FrozenMap.from_index_theta(index, theta_rows, cfg)
        placed = MapServer(frozen).transform(
            np.asarray(new_x), seed=cfg.seed if seed is None else seed,
            return_neighbors=False,
        )
        stage_s = {"place": time.time() - t_place}

        # ---- version bookkeeping (dir must exist before a store spill) --------
        version_name, parent_name, version_dir = "", "", ""
        if lineage is not None:
            if not lineage.exists():
                # upgrade a pre-lineage checkpoint in place: the base fit
                # becomes v0 at the root
                lineage.record(
                    name="v0",
                    dirname=".",
                    parent="",
                    fingerprint=index.fingerprint,
                    n_points=n_old,
                    kind="fit",
                )
            parent_name = lineage.latest().name
            version_name = lineage.next_name()
            version_dir = os.path.join(ckdir, version_name)
            os.makedirs(version_dir, exist_ok=True)

        # ---- admit + patch (repro.index.incremental) --------------------------
        from repro.index.incremental import admit_and_patch

        spill_dir = None
        if is_store(index.x_rows):
            if version_dir:
                spill_dir = os.path.join(version_dir, "x_rows_store")
            else:
                import tempfile

                spill_dir = tempfile.mkdtemp(prefix="nomad-partial-spill-")
        upd = admit_and_patch(
            index,
            theta_rows,
            np.asarray(new_x),
            np.asarray(placed.cells),
            np.asarray(placed.embedding, np.float32),
            cfg,
            impl=cfg.resolved_kernel_impl(),
            spill_dir=spill_dir,
        )
        stage_s.update(upd.stage_s)

        # ---- refine: cheap epochs over affected cells only --------------------
        t_refine = time.time()
        refine_epochs = (
            cfg.partial_refine_epochs if refine_epochs is None else refine_epochs
        )
        losses_, epoch_times = [], []
        if refine_epochs > 0 and upd.affected_cells.size:
            strategy = PartialRefineStrategy(upd.affected_cells)
            theta = strategy.prepare(cfg, self.method, upd.index, upd.theta_rows)
            # start from the final fit epoch's lr scale — the equilibrium
            # regime the frozen rows were left in — annealed to 0 again
            lr_r = cfg.resolved_lr0() / max(cfg.n_epochs, 1)
            key = jax.random.fold_in(
                jax.random.key(cfg.seed + 11), upd.index.n_points
            )
            for e in range(refine_epochs):
                te = time.time()
                f0 = 1.0 - e / refine_epochs
                f1 = 1.0 - (e + 1) / refine_epochs
                if events is not None:
                    events.on_epoch_start(
                        EpochStartEvent(
                            e, refine_epochs, lr_r * f0, lr_r * f1, strategy.name
                        )
                    )
                theta, mloss = strategy.run_epoch(
                    theta, e, lr_r * f0, lr_r * f1, jax.random.fold_in(key, e)
                )
                losses_.append(mloss)
                epoch_times.append(time.time() - te)
                if events is not None:
                    emb_e = (
                        upd.index.unpermute(strategy.fetch(theta))
                        if events.wants_embedding
                        else None
                    )
                    events.on_epoch_end(
                        EpochEndEvent(
                            e, refine_epochs, mloss, epoch_times[-1],
                            strategy.name, emb_e,
                        )
                    )
            theta_new = strategy.fetch(theta)
        else:
            theta_new = np.asarray(upd.theta_rows)
        stage_s["refine"] = time.time() - t_refine

        # ---- version: self-contained dir + lineage entry ----------------------
        t_version = time.time()
        if lineage is not None:
            from repro.checkpoint import Checkpointer
            from repro.index.ann import index_cache_path, save_index

            ckpt = Checkpointer(version_dir, keep=2, async_save=False)
            ckpt.save(
                max(refine_epochs - 1, 0),
                {"theta": theta_new},
                metadata={
                    "epoch": max(refine_epochs - 1, 0),
                    "config": dataclasses.asdict(cfg),
                    "method": self.method,
                    "strategy": "partial",
                    "losses": list(losses_),
                    "parent_version": parent_name,
                },
            )
            ckpt.wait()
            save_index(upd.index, index_cache_path(version_dir))
            lineage.record(
                name=version_name,
                dirname=version_name,
                parent=parent_name,
                fingerprint=upd.index.fingerprint,
                n_points=upd.index.n_points,
                kind="partial_fit",
            )
            stage_s["version"] = time.time() - t_version

        emb = upd.index.unpermute(theta_new)
        result = PartialFitResult(
            embedding=emb,
            index=upd.index,
            n_new=M,
            n_points=upd.index.n_points,
            losses=losses_,
            wall_time_s=time.time() - t0,
            epoch_times=epoch_times,
            refine_epochs=refine_epochs,
            affected_cells=upd.affected_cells,
            n_split_cells=upd.n_split_cells,
            n_new_cells=upd.n_new_cells,
            stage_s=stage_s,
            version=version_name,
            parent_version=parent_name,
            checkpoint_dir=version_dir,
        )
        # the estimator now serves (and grows) the new version
        self._fit_result = FitResult(
            embedding=emb,
            index=upd.index,
            losses=losses_,
            wall_time_s=result.wall_time_s,
            epoch_times=epoch_times,
            strategy="partial",
            index_build_strategy="incremental",
            checkpoint_dir=version_dir,
        )
        self._frozen = None
        self._server = None
        return result

    def fit_transform(self, x: np.ndarray, **kwargs) -> np.ndarray:
        """``fit(...)`` and return just the ``(N, out_dim)`` embedding.

        Forwards through ``fit`` and therefore through the same
        :func:`prepare_inputs` validation gate ``transform`` uses —
        float64/NaN inputs fail with the same actionable error everywhere.
        """
        return self.fit(x, **kwargs).embedding

    # -- out-of-sample serving (repro.serve) -----------------------------------

    def map_server(self, **overrides):
        """The :class:`repro.serve.MapServer` this estimator serves from.

        Frozen state comes from the last ``fit`` when one ran in this
        process, else straight from ``cfg.checkpoint_dir`` (θ + cached
        index — **no training data needed**, the ``from_checkpoint``
        serving path). The config-default server is cached; passing
        ``overrides`` (``strategy=``, ``microbatch=``, ``mesh=``,
        ``steps=``, ``lr=``) returns a fresh *uncached* server, so a
        one-off override can never change what ``transform()`` later does.
        """
        from repro.checkpoint import latest_step
        from repro.serve import FrozenMap, MapServer

        if self._server is not None and not overrides:
            return self._server
        if self._frozen is None:
            if self._fit_result is not None:
                self._frozen = FrozenMap.from_fit(self._fit_result, self.cfg)
            elif self.cfg.checkpoint_dir and latest_step(self.cfg.checkpoint_dir) is not None:
                self._frozen = FrozenMap.from_checkpoint(self.cfg.checkpoint_dir, self.cfg)
            else:
                raise RuntimeError(
                    "transform needs a fitted map: call fit(x) first, or load "
                    "one with NomadProjection.from_checkpoint(dir)"
                )
        if overrides:
            return MapServer(self._frozen, **overrides)
        self._server = MapServer(self._frozen)
        return self._server

    def transform(self, x: np.ndarray, *, seed: int = 0) -> np.ndarray:
        """Place unseen rows on the frozen fitted map (out-of-sample
        extension). Returns the ``(n_queries, out_dim)`` placements;
        ``map_server().transform(x)`` returns the full
        :class:`repro.serve.TransformResult` (cells, neighbor ids/distances,
        per-batch latency). Never moves fitted positions — the serve
        kernels' gradients stop at the query rows.
        """
        return self.map_server().transform(x, seed=seed).embedding

    def _init_theta(self, x, index: "AnnIndex") -> jax.Array:
        from repro.data.store import as_store, is_store

        cfg = self.cfg
        if cfg.init == "pca":
            if is_store(x) or cfg.chunk_rows > 0:
                # the streamed init: same chunk schedule as the streamed
                # build, so fit(store) ≡ fit(ndarray) stays bit-exact
                from repro.core.pca import pca_init_streamed

                th0 = pca_init_streamed(
                    as_store(x),
                    cfg.out_dim,
                    cfg.init_scale,
                    chunk_rows=cfg.resolved_chunk_rows(),
                )
            else:
                th0 = np.asarray(
                    pca_init(jnp.asarray(x), cfg.out_dim, cfg.init_scale)
                )
        else:
            rng = np.random.default_rng(cfg.seed)
            th0 = rng.normal(0, cfg.init_scale, (x.shape[0], cfg.out_dim)).astype(
                np.float32
            )
        rows = np.zeros((index.n_clusters * index.capacity, cfg.out_dim), np.float32)
        rows[index.perm] = th0
        return jnp.asarray(rows)

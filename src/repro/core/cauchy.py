"""The Cauchy affinity kernel (paper Eq. 1): q(θi, θj) = 1 / (1 + ‖θi−θj‖²).

All affinity math is fp32: near q→1 the gradient is dominated by the tiny
‖θi−θj‖² term and bf16 rounding destroys the spring forces.
"""

from __future__ import annotations

import jax.numpy as jnp


def cauchy(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise-broadcast Cauchy affinity over the last axis."""
    d2 = jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)), axis=-1)
    return 1.0 / (1.0 + d2)


def cauchy_pairwise(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(Na, d) × (Nb, d) → (Na, Nb) Cauchy affinities (MXU-friendly form)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    d2 = (
        jnp.sum(jnp.square(a), -1)[:, None]
        + jnp.sum(jnp.square(b), -1)[None, :]
        - 2.0 * (a @ b.T)
    )
    return 1.0 / (1.0 + jnp.maximum(d2, 0.0))

"""PCA initialisation (paper §3.4, following Wang et al. [27]).

Exact eigendecomposition of the D×D covariance for D ≤ 2048; randomized
range-finder beyond that (the paper's corpora are 768–1024-d, so exact).
The projection is rescaled so each output dim has std ``scale`` — the
t-SNE convention from Belkina et al. [2] / Kobak & Berens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pca_init(x: jax.Array, out_dim: int = 2, scale: float = 1e-4, max_exact_dim: int = 2048):
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=0, keepdims=True)
    xc = x - mu
    D = x.shape[1]
    if D <= max_exact_dim:
        cov = (xc.T @ xc) / x.shape[0]
        evals, evecs = jnp.linalg.eigh(cov)
        comps = evecs[:, ::-1][:, :out_dim]  # eigh is ascending
    else:  # randomized power iteration
        key = jax.random.key(17)
        q = jax.random.normal(key, (D, out_dim + 8), jnp.float32)
        for _ in range(4):
            q = xc.T @ (xc @ q)
            q, _ = jnp.linalg.qr(q)
        b = xc @ q
        _, _, vt = jnp.linalg.svd(b, full_matrices=False)
        comps = (q @ vt.T)[:, :out_dim]
    proj = xc @ comps
    std = jnp.std(proj, axis=0, keepdims=True)
    return proj / jnp.maximum(std, 1e-12) * scale


def pca_init_streamed(
    store,
    out_dim: int = 2,
    scale: float = 1e-4,
    chunk_rows: int = 0,
    max_exact_dim: int = 2048,
):
    """:func:`pca_init` over a :class:`repro.data.store.EmbeddingStore`.

    Never materialises the corpus: the mean and the D×D covariance are
    accumulated over ``chunk_rows``-row chunks (double-buffered disk
    reads), and only the (N, out_dim) projection — the *output* of the
    init — lives in host memory. Beyond ``max_exact_dim`` the randomized
    range-finder runs the same way, one streamed pass per power iteration.
    Chunk boundaries depend only on (N, chunk_rows), so two stores holding
    the same rows produce bit-identical inits.
    """
    from repro.data.store import DEFAULT_CHUNK_ROWS, stream_chunks
    from repro.index.kmeans import _pad_chunk

    n, D = store.shape
    chunk_rows = max(1, min(chunk_rows or DEFAULT_CHUNK_ROWS, n))

    @jax.jit
    def sum_partial(acc, xb, w):
        return acc + jnp.sum(xb * w[:, None], axis=0)

    acc = jnp.zeros((D,), jnp.float32)
    for _s, chunk in stream_chunks(store, chunk_rows):
        xb, w = _pad_chunk(chunk, chunk_rows)
        acc = sum_partial(acc, jnp.asarray(xb), jnp.asarray(w))
    mu = acc[None, :] / n

    @jax.jit
    def cov_partial(acc, xb, w, mu):
        xc = (xb - mu) * w[:, None]
        return acc + xc.T @ xc

    if D <= max_exact_dim:
        cov = jnp.zeros((D, D), jnp.float32)
        for _s, chunk in stream_chunks(store, chunk_rows):
            xb, w = _pad_chunk(chunk, chunk_rows)
            cov = cov_partial(cov, jnp.asarray(xb), jnp.asarray(w), mu)
        _evals, evecs = jnp.linalg.eigh(cov / n)
        comps = evecs[:, ::-1][:, :out_dim]
    else:  # randomized power iteration, one streamed pass per iteration
        key = jax.random.key(17)
        q = jax.random.normal(key, (D, out_dim + 8), jnp.float32)

        @jax.jit
        def power_partial(acc, xb, w, mu, q):
            xc = (xb - mu) * w[:, None]
            return acc + xc.T @ (xc @ q)

        for _ in range(4):
            acc_q = jnp.zeros_like(q)
            for _s, chunk in stream_chunks(store, chunk_rows):
                xb, w = _pad_chunk(chunk, chunk_rows)
                acc_q = power_partial(acc_q, jnp.asarray(xb), jnp.asarray(w), mu, q)
            q, _ = jnp.linalg.qr(acc_q)
        b_rows = []
        for _s, chunk in stream_chunks(store, chunk_rows):
            b_rows.append(np.asarray((jnp.asarray(chunk) - mu) @ q))
        _, _, vt = jnp.linalg.svd(jnp.asarray(np.concatenate(b_rows)), full_matrices=False)
        comps = (q @ vt.T)[:, :out_dim]

    proj = np.empty((n, out_dim), np.float32)

    @jax.jit
    def project(xb):
        return (xb - mu) @ comps

    for s, chunk in stream_chunks(store, chunk_rows):
        proj[s : s + chunk.shape[0]] = np.asarray(project(jnp.asarray(chunk)))
    pj = jnp.asarray(proj)
    std = jnp.std(pj, axis=0, keepdims=True)
    return np.asarray(pj / jnp.maximum(std, 1e-12) * scale)

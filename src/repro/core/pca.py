"""PCA initialisation (paper §3.4, following Wang et al. [27]).

Exact eigendecomposition of the D×D covariance for D ≤ 2048; randomized
range-finder beyond that (the paper's corpora are 768–1024-d, so exact).
The projection is rescaled so each output dim has std ``scale`` — the
t-SNE convention from Belkina et al. [2] / Kobak & Berens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pca_init(x: jax.Array, out_dim: int = 2, scale: float = 1e-4, max_exact_dim: int = 2048):
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=0, keepdims=True)
    xc = x - mu
    D = x.shape[1]
    if D <= max_exact_dim:
        cov = (xc.T @ xc) / x.shape[0]
        evals, evecs = jnp.linalg.eigh(cov)
        comps = evecs[:, ::-1][:, :out_dim]  # eigh is ascending
    else:  # randomized power iteration
        key = jax.random.key(17)
        q = jax.random.normal(key, (D, out_dim + 8), jnp.float32)
        for _ in range(4):
            q = xc.T @ (xc @ q)
            q, _ = jnp.linalg.qr(q)
        b = xc @ q
        _, _, vt = jnp.linalg.svd(b, full_matrices=False)
        comps = (q @ vt.T)[:, :out_dim]
    proj = xc @ comps
    std = jnp.std(proj, axis=0, keepdims=True)
    return proj / jnp.maximum(std, 1e-12) * scale

"""Pluggable execution strategies for the unified ``NomadProjection`` front end.

One estimator, every scale: the estimator owns the epoch loop, callbacks and
checkpointing; a strategy owns *where and how one epoch runs*:

* :class:`LocalStrategy`        — single device, ``make_epoch_fn`` (the
  paper's single-GPU reference; the only strategy that supports the
  non-factorising ``"infonc"`` baseline).
* :class:`ShardedStrategy`      — the paper's Fig. 2 multi-device mode:
  cluster-sharded ``shard_map`` epochs with a flat per-refresh all-gather of
  cell means (``core/distributed.py:make_sharded_epoch_fn``).
* :class:`HierarchicalStrategy` — the multi-pod extension: full means
  circulate intra-pod, remote pods are summarised by one super-mean each.

``resolve_strategy("auto", cfg, ...)`` picks for you from ``jax.devices()``
and the config: one device → local; several devices → sharded over the
largest cluster-divisible device count (hierarchical when
``cfg.hierarchical`` and a 2-pod mesh fits). Every strategy consumes the
same global cluster-major ``theta`` view and returns per-epoch
``(theta, loss)``, so checkpoints written under one strategy restore under
any other (elastic resume).

The *index build* has a twin of this layer —
:class:`repro.index.build.IndexBuilder`, resolved from
``cfg.build_strategy`` over the same device pool — so ``fit`` is
device-resident end to end: build strategies produce the index the
execution strategies then train on.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import NomadConfig


# ---------------------------------------------------------------------------
# Event API
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EpochStartEvent:
    epoch: int
    n_epochs: int
    lr0: float  # lr at the first step of this epoch
    lr1: float  # lr at the last step of this epoch
    strategy: str


@dataclasses.dataclass
class EpochEndEvent:
    epoch: int
    n_epochs: int
    loss: float
    time_s: float
    strategy: str
    # (N, out_dim) in the ORIGINAL point order — never the raw cluster-major
    # capacity-padded buffer. None when no consumer asked for embeddings.
    embedding: Optional[np.ndarray] = None


@dataclasses.dataclass
class MeansRefreshEvent:
    epoch: int
    n_refreshes: int  # mean refreshes performed inside this epoch
    strategy: str


@dataclasses.dataclass
class CheckpointEvent:
    epoch: int
    step: int  # checkpoint step id (== epoch)
    directory: str
    n_shards: int


class FitCallbacks:
    """Structured fit events. Subclass and override what you need.

    ``wants_embedding`` controls whether :attr:`EpochEndEvent.embedding` is
    materialised (an O(N·d) device→host copy + unpermute per epoch); set it
    to False for cheap loss/time-only observers on big runs.
    """

    wants_embedding: bool = True

    def on_epoch_start(self, event: EpochStartEvent) -> None: ...

    def on_epoch_end(self, event: EpochEndEvent) -> None: ...

    def on_means_refresh(self, event: MeansRefreshEvent) -> None: ...

    def on_checkpoint(self, event: CheckpointEvent) -> None: ...


class CallbackList(FitCallbacks):
    """Fan one event stream out to several callback objects."""

    def __init__(self, callbacks: Sequence[FitCallbacks]):
        self.callbacks = list(callbacks)

    @property
    def wants_embedding(self) -> bool:  # type: ignore[override]
        return any(cb.wants_embedding for cb in self.callbacks)

    def on_epoch_start(self, event):
        for cb in self.callbacks:
            cb.on_epoch_start(event)

    def on_epoch_end(self, event):
        for cb in self.callbacks:
            cb.on_epoch_end(event)

    def on_means_refresh(self, event):
        for cb in self.callbacks:
            cb.on_means_refresh(event)

    def on_checkpoint(self, event):
        for cb in self.callbacks:
            cb.on_checkpoint(event)


class LegacyCallback(FitCallbacks):
    """Adapter for the old bare ``callback(epoch, embedding, loss)``.

    Unlike the pre-redesign behaviour (which leaked the raw cluster-major,
    capacity-padded ``theta`` buffer), the adapter hands the *unpermuted*
    ``(N, out_dim)`` embedding — the same array ``FitResult.embedding`` ends
    up with.
    """

    def __init__(self, fn: Callable):
        self.fn = fn

    def on_epoch_end(self, event: EpochEndEvent) -> None:
        self.fn(event.epoch, event.embedding, event.loss)


def as_callbacks(
    callbacks=None, legacy_callback: Optional[Callable] = None
) -> Optional[FitCallbacks]:
    """Normalise fit()'s callback arguments into one FitCallbacks (or None)."""
    out = []
    if callbacks is not None:
        if isinstance(callbacks, FitCallbacks):
            out.append(callbacks)
        else:  # sequence of FitCallbacks
            out.extend(callbacks)
    if legacy_callback is not None:
        warnings.warn(
            "fit(callback=...) is deprecated; pass callbacks=FitCallbacks() "
            "(see repro.core.strategy.FitCallbacks). The legacy callback now "
            "receives the unpermuted (N, out_dim) embedding.",
            DeprecationWarning,
            stacklevel=3,
        )
        out.append(LegacyCallback(legacy_callback))
    if not out:
        return None
    return out[0] if len(out) == 1 else CallbackList(out)


# ---------------------------------------------------------------------------
# Multi-process helpers
# ---------------------------------------------------------------------------


def fetch_global(arr) -> np.ndarray:
    """Device array → host np.ndarray, multi-process safe.

    Single-process (and anything fully addressable) is a plain
    ``np.asarray``. Under ``jax.distributed`` a sharded array is *not*
    fully addressable — ``np.asarray`` raises — so the missing shards are
    gathered from peer processes first (every process gets the full
    array). Collective: every process must call this together.
    """
    if getattr(arr, "is_fully_addressable", True) or getattr(
        arr, "is_fully_replicated", False
    ):
        # fully replicated arrays (e.g. psum outputs) have a complete local
        # copy on every process — np.asarray reads it without communication
        return np.asarray(arr)
    from jax.experimental import multihost_utils

    return np.asarray(multihost_utils.process_allgather(arr, tiled=True))


def sync_processes(tag: str = "sync") -> None:
    """Cross-process barrier; no-op in a single-process runtime."""
    if jax.process_count() > 1:
        from jax.experimental import multihost_utils

        multihost_utils.sync_global_devices(tag)


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


class ExecutionStrategy:
    """Where/how one NOMAD epoch runs. Stateful: ``prepare`` then ``run_epoch``."""

    name: str = "?"

    def __init__(self) -> None:
        self.n_shards: int = 1
        self.mesh: Optional[Mesh] = None

    # -- lifecycle -----------------------------------------------------------

    def prepare(self, cfg: NomadConfig, method: str, index, theta0) -> jax.Array:
        """Place ``theta0``/index on device(s), build the epoch fn; return theta."""
        raise NotImplementedError

    def run_epoch(self, theta, epoch: int, lr0: float, lr1: float, key):
        """One epoch: ``(theta, lr schedule, rng) -> (theta, mean_loss)``."""
        raise NotImplementedError

    # -- introspection ---------------------------------------------------------

    def refreshes_per_epoch(self) -> int:
        steps = self._steps
        refresh = self._refresh
        return max(1, -(-steps // refresh))

    def fetch(self, theta) -> np.ndarray:
        """θ → host array; gathers remote shards under multi-process jax."""
        return fetch_global(theta)

    def describe(self) -> dict:
        return {
            "strategy": self.name,
            "n_shards": self.n_shards,
            "mesh_shape": tuple(self.mesh.shape.values()) if self.mesh else None,
            "mesh_axes": tuple(self.mesh.axis_names) if self.mesh else None,
            "process_count": jax.process_count(),
            "process_index": jax.process_index(),
        }


class LocalStrategy(ExecutionStrategy):
    """Single-device reference loop (``core/nomad.py:make_epoch_fn``)."""

    name = "local"

    def prepare(self, cfg, method, index, theta0):
        from repro.core.nomad import make_epoch_fn, make_step_fn

        self._steps = cfg.resolved_steps_per_epoch()
        self._refresh = cfg.mean_refresh_steps or self._steps
        self._idx = {
            "knn_idx": jnp.asarray(index.knn_idx, jnp.int32),
            "knn_w": jnp.asarray(index.knn_w, jnp.float32),
            "counts": jnp.asarray(index.counts, jnp.int32),
            "cum_counts": jnp.asarray(np.cumsum(index.counts), jnp.int32),
        }
        step_fn = make_step_fn(cfg, method=method)
        self._epoch_fn = make_epoch_fn(cfg, step_fn, self._steps)
        return jnp.asarray(theta0)

    def run_epoch(self, theta, epoch, lr0, lr1, key):
        theta, loss = self._epoch_fn(theta, self._idx, lr0, lr1, key)
        return theta, float(loss)


class PartialRefineStrategy(ExecutionStrategy):
    """Refinement epochs restricted to the cells a ``partial_fit`` touched.

    Same epoch contract as :class:`LocalStrategy` — means refreshed over
    the **full** layout (repulsion still sees every cell), the usual
    ``make_epoch_fn`` scan — but heads are sampled only from
    ``affected_cells`` (:func:`repro.core.nomad.make_partial_step_fn`).
    Positives come from the patched in-cluster kNN and negatives from the
    head's own cell, so gradients never reach a row outside the affected
    cells: everything the append didn't touch stays bit-identical, which
    is the property the map-stability gate leans on.

    Steps per epoch scale with the *affected* point count, not N — the
    "cheap" in cheap refinement.
    """

    name = "partial"

    def __init__(self, affected_cells):
        super().__init__()
        self.affected_cells = np.asarray(affected_cells, np.int32)

    def prepare(self, cfg, method, index, theta0):
        from repro.core.nomad import make_epoch_fn, make_partial_step_fn

        if self.affected_cells.size == 0:
            raise ValueError("PartialRefineStrategy needs >=1 affected cell")
        counts = np.asarray(index.counts)
        aff = self.affected_cells
        n_aff = int(counts[aff].sum())
        self._steps = max(1, -(-n_aff // cfg.batch_size))
        self._refresh = cfg.mean_refresh_steps or self._steps
        self._idx = {
            "knn_idx": jnp.asarray(index.knn_idx, jnp.int32),
            "knn_w": jnp.asarray(index.knn_w, jnp.float32),
            "counts": jnp.asarray(counts, jnp.int32),
            "cum_counts": jnp.asarray(np.cumsum(counts), jnp.int32),
            "aff_cells": jnp.asarray(aff, jnp.int32),
            "aff_cum_counts": jnp.asarray(np.cumsum(counts[aff]), jnp.int32),
        }
        step_fn = make_partial_step_fn(cfg, method=method, n_total=index.n_points)
        self._epoch_fn = make_epoch_fn(cfg, step_fn, self._steps)
        return jnp.asarray(theta0)

    def run_epoch(self, theta, epoch, lr0, lr1, key):
        theta, loss = self._epoch_fn(theta, self._idx, lr0, lr1, key)
        return theta, float(loss)


class ShardedStrategy(ExecutionStrategy):
    """Fig. 2 cluster-sharded ``shard_map`` epochs, flat mean exchange.

    ``mesh=None`` builds a default 1-axis mesh over the largest device count
    that divides ``cfg.n_clusters``. With a mesh given, ``shard_axes``
    defaults to every axis except ``pod_axis``.
    """

    name = "sharded"
    _hierarchical = False

    def __init__(
        self,
        mesh: Optional[Mesh] = None,
        shard_axes: Optional[Sequence[str]] = None,
        pod_axis: Optional[str] = None,
    ):
        super().__init__()
        self.mesh = mesh
        self.shard_axes = tuple(shard_axes) if shard_axes is not None else None
        self.pod_axis = pod_axis

    def _resolve_mesh(self, cfg: NomadConfig) -> None:
        if self.mesh is None:
            self.mesh = default_mesh(cfg, hierarchical=self._hierarchical)
            self.shard_axes = ("data",)
            self.pod_axis = "pod" if "pod" in self.mesh.axis_names else None
        if self.pod_axis is None and "pod" in self.mesh.axis_names and (
            self.shard_axes is None or "pod" not in self.shard_axes
        ):
            self.pod_axis = "pod"
        if self.shard_axes is None:
            self.shard_axes = tuple(
                a for a in self.mesh.axis_names if a != self.pod_axis
            )
        uncovered = [
            a
            for a in self.mesh.axis_names
            if a not in self.shard_axes and a != self.pod_axis
            and self.mesh.shape[a] > 1
        ]
        if uncovered:
            raise ValueError(
                f"mesh axes {uncovered} are covered by neither shard_axes="
                f"{self.shard_axes} nor pod_axis={self.pod_axis!r}; θ would be "
                "silently replicated across them"
            )
        n_shards = int(np.prod([self.mesh.shape[a] for a in self.shard_axes]))
        if self.pod_axis:
            n_shards *= self.mesh.shape[self.pod_axis]
        if cfg.n_clusters % n_shards:
            raise ValueError(
                f"strategy={self.name!r}: n_clusters={cfg.n_clusters} is not "
                f"divisible by the {n_shards}-shard mesh "
                f"{dict(self.mesh.shape)}; pick a compatible mesh or "
                "strategy='local'"
            )
        self.n_shards = n_shards

    def prepare(self, cfg, method, index, theta0):
        from repro.core.distributed import make_sharded_epoch_fn, shard_index_arrays

        if method != "nomad":
            raise ValueError(
                f"method={method!r} only runs with strategy='local' — its loss "
                "does not factorise over the cluster partition (paper Eq. 2)"
            )
        if self._hierarchical:
            cfg = cfg.replace(hierarchical=True)
        self._resolve_mesh(cfg)
        if self._hierarchical and self.pod_axis is None:
            raise ValueError(
                "strategy='hierarchical' needs a mesh with a pod axis "
                "(e.g. axes ('pod', 'data'))"
            )

        # shards work in parallel, so each runs 1/n_shards of the
        # single-device step count — per-epoch sample volume stays ≈ N.
        self._steps = max(1, -(-cfg.resolved_steps_per_epoch() // self.n_shards))
        self._refresh = cfg.mean_refresh_steps or self._steps

        axes = ((self.pod_axis,) if self.pod_axis else ()) + self.shard_axes
        row_sh = NamedSharding(self.mesh, P(axes, None))
        vec_sh = NamedSharding(self.mesh, P(axes))
        idx = shard_index_arrays(index, self.n_shards)
        self._idx = {
            "knn_idx": jax.device_put(idx["knn_idx"], row_sh),
            "knn_w": jax.device_put(idx["knn_w"], row_sh),
            "counts": jax.device_put(idx["counts"], vec_sh),
            "cum_counts": jax.device_put(idx["cum_counts"], vec_sh),
        }
        self._counts_global = jnp.asarray(index.counts, jnp.float32)
        self._epoch_fn = jax.jit(
            make_sharded_epoch_fn(
                cfg,
                self.mesh,
                shard_axes=self.shard_axes,
                pod_axis=self.pod_axis,
                steps_per_epoch=self._steps,
                n_shards=self.n_shards,
            )
        )
        return jax.device_put(jnp.asarray(theta0), row_sh)

    def run_epoch(self, theta, epoch, lr0, lr1, key):
        theta, loss = self._epoch_fn(
            theta, self._idx, self._counts_global, lr0, lr1, key
        )
        return theta, float(loss)


class HierarchicalStrategy(ShardedStrategy):
    """Multi-pod mode: intra-pod full means, inter-pod super-means."""

    name = "hierarchical"
    _hierarchical = True


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


def largest_divisor_leq(k: int, n: int) -> int:
    """Largest divisor of ``k`` that is ≤ ``n`` — the widest device count a
    K-cluster workload can shard over. Shared by training-strategy and
    index-build (:func:`repro.index.build.resolve_build_strategy`)
    resolution so ``"auto"`` picks the same device pool for both."""
    for d in range(min(k, n), 0, -1):
        if k % d == 0:
            return d
    return 1


_largest_divisor_leq = largest_divisor_leq  # pre-PR-3 private name


def flat_mesh(devs, axis: str) -> Mesh:
    """One flat mesh axis over ``devs`` — the shape shared by the training
    default mesh, the index-build mesh
    (:func:`repro.index.build.resolve_build_strategy`) and the serve mesh
    (:func:`repro.serve.server.resolve_serve_strategy`).

    ``devs`` must come from the GLOBAL pool (``jax.devices()``), never
    ``jax.local_devices()`` — under ``jax.distributed`` a mesh built from
    local devices would silently compute a per-process answer with no
    cross-process collectives. ``launch/mesh.py:flat_mesh`` wraps this
    with the global pool filled in."""
    return Mesh(np.asarray(devs).reshape(-1), (axis,))


def default_mesh(cfg: NomadConfig, *, hierarchical: bool = False) -> Mesh:
    """A mesh over (a prefix of) ``jax.devices()`` compatible with K clusters.

    ``jax.devices()`` is the global pool: under ``jax.distributed`` it
    spans every process, so the default mesh (and the shard_map
    collectives over it) crosses process boundaries automatically.
    """
    devs = jax.devices()
    K = cfg.n_clusters
    if hierarchical:
        # 2 pods × the largest per-pod width that keeps K divisible
        pods = 2
        per_pod = _largest_divisor_leq(K // pods if K % pods == 0 else 1, len(devs) // pods)
        if K % pods == 0 and per_pod >= 1 and pods * per_pod <= len(devs):
            arr = np.asarray(devs[: pods * per_pod]).reshape(pods, per_pod)
            return Mesh(arr, ("pod", "data"))
        # fall through to a flat mesh when a 2-pod layout doesn't fit
    d = _largest_divisor_leq(K, len(devs))
    return flat_mesh(devs[:d], "data")


def resolve_strategy(
    spec,
    cfg: NomadConfig,
    *,
    method: Optional[str] = None,
    mesh: Optional[Mesh] = None,
    shard_axes: Optional[Sequence[str]] = None,
    pod_axis: Optional[str] = None,
) -> ExecutionStrategy:
    """Turn ``"auto"|"local"|"sharded"|"hierarchical"`` (or an instance) into
    a ready-to-prepare strategy."""
    if isinstance(spec, ExecutionStrategy):
        return spec
    spec = spec or "auto"
    method = method or cfg.method

    if spec == "auto":
        # GLOBAL device count — under jax.distributed this spans every
        # process (jax.local_device_count() would wedge each process into
        # its own single-host strategy with no cross-process collectives)
        n_dev = jax.device_count()
        if mesh is not None:
            if cfg.hierarchical and "pod" in mesh.axis_names:
                spec = "hierarchical"
            else:
                spec = "sharded"
        elif method == "infonc" or n_dev == 1:
            spec = "local"
        elif _largest_divisor_leq(cfg.n_clusters, n_dev) == 1:
            warnings.warn(
                f"strategy='auto': {n_dev} devices share no divisor with "
                f"n_clusters={cfg.n_clusters}; falling back to strategy='local'"
            )
            spec = "local"
        elif cfg.hierarchical and n_dev >= 4 and cfg.n_clusters % 2 == 0:
            spec = "hierarchical"
        else:
            spec = "sharded"

    if spec == "local":
        if jax.process_count() > 1:
            raise ValueError(
                f"strategy='local' (method={method!r}) cannot run under "
                f"multi-process jax.distributed ({jax.process_count()} "
                "processes): the local loop would compute one independent "
                "answer per process. Use strategy='sharded' with "
                "n_clusters divisible by the global device count."
            )
        return LocalStrategy()
    if spec == "sharded":
        return ShardedStrategy(mesh=mesh, shard_axes=shard_axes, pod_axis=pod_axis)
    if spec == "hierarchical":
        return HierarchicalStrategy(
            mesh=mesh, shard_axes=shard_axes, pod_axis=pod_axis
        )
    raise ValueError(
        f"unknown strategy {spec!r} (want 'auto'|'local'|'sharded'|'hierarchical')"
    )

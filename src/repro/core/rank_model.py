"""The inverse-rank edge model (paper Eq. 6).

    p(j|i) = e^{1/rank_j(i)} / Z   if rank_j(i) ≤ k, else 0
    Z      = Σ_{j=0}^{k} e^{1/(j+1)}

``rank_j(i)`` is the paper's (slightly unusual) definition: the index of the
*head* i in the list of points sorted by ascending distance **to the tail
j** — i.e. how close i looks from j's perspective. Index 0 is j itself, so
ranks of other points start at 1. The normaliser Z has k+1 terms exactly as
written in Eq. 6 (it includes the r = k+1 term); we keep it verbatim for
faithfulness — it is a constant, so it only scales the loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normalizer(k: int) -> float:
    return float(np.exp(1.0 / np.arange(1, k + 2)).sum())


def rank_matrix(dist2: jnp.ndarray) -> jnp.ndarray:
    """R[i, j] = rank of i in j's ascending-distance order (0 = j itself).

    dist2: (C, C) squared distances with dist2[j, j] = 0.
    """
    # rank along each column: double argsort
    order = jnp.argsort(dist2, axis=0)  # (C, C): order[r, j] = point at rank r w.r.t. j
    C = dist2.shape[0]
    ranks = jnp.zeros((C, C), jnp.int32)
    ranks = ranks.at[order, jnp.arange(C)[None, :]].set(jnp.arange(C, dtype=jnp.int32)[:, None])
    return ranks


def edge_weights(dist2: jnp.ndarray, knn_idx: jnp.ndarray, k: int, valid: jnp.ndarray) -> jnp.ndarray:
    """Weights p(j|i) for each kNN edge i→j (Eq. 6).

    dist2:   (C, C) in-cluster squared distances (padding rows masked +inf)
    knn_idx: (C, k) neighbor slots per point
    valid:   (C,) real-point mask
    Returns (C, k) fp32 weights; invalid edges get 0.
    """
    R = rank_matrix(dist2)
    C = dist2.shape[0]
    rows = jnp.arange(C)[:, None]
    r_ji = R[rows, knn_idx]  # rank of i from j's perspective → R[i, j]
    w = jnp.exp(1.0 / jnp.maximum(r_ji.astype(jnp.float32), 1.0)) / normalizer(k)
    w = jnp.where((r_ji >= 1) & (r_ji <= k), w, 0.0)
    w = jnp.where(valid[:, None] & valid[knn_idx], w, 0.0)
    return w

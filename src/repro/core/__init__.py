from repro.core.cauchy import cauchy, cauchy_pairwise
from repro.core.losses import contrastive_loss, infonc_tsne_loss, nomad_loss
from repro.core.nomad import FitResult, NomadProjection, make_epoch_fn, make_step_fn
from repro.core.strategy import (
    CallbackList,
    CheckpointEvent,
    EpochEndEvent,
    EpochStartEvent,
    ExecutionStrategy,
    FitCallbacks,
    HierarchicalStrategy,
    LocalStrategy,
    MeansRefreshEvent,
    ShardedStrategy,
    resolve_strategy,
)
from repro.core.pca import pca_init

__all__ = [
    "cauchy",
    "cauchy_pairwise",
    "contrastive_loss",
    "infonc_tsne_loss",
    "nomad_loss",
    "NomadProjection",
    "FitResult",
    "make_step_fn",
    "make_epoch_fn",
    "pca_init",
    # execution strategies + event API
    "ExecutionStrategy",
    "LocalStrategy",
    "ShardedStrategy",
    "HierarchicalStrategy",
    "resolve_strategy",
    "FitCallbacks",
    "CallbackList",
    "EpochStartEvent",
    "EpochEndEvent",
    "MeansRefreshEvent",
    "CheckpointEvent",
]

"""InfoNC-t-SNE loss (Eq. 2) and the NOMAD surrogate (Eq. 3–5).

Both are implemented through one batched primitive so their equivalence when
R̃ = ∅ (the paper's reduction property) is structural, not coincidental:

    L = −(1/B) Σ_b Σ_s w_pos[b,s] · [log q(b,s) − log(q(b,s) + M̃_b + M_b)]

    M̃_b = Σ_r mean_w[b,r] · q(θ_b, μ_r)          (approximated cells)
    M_b  = Σ_s neg_w[b,s] · q(θ_b, θ_neg[b,s])    (exactly-sampled cells)

with ``mean_w[b,r] = |M| · p(m∈r)`` for approximated cells r (0 for the
head's own cell and non-approximated cells) and ``neg_w`` the importance
weight of each drawn sample (``|M| · p(m∈r) / n_samples_r``).

The training step no longer composes these passes separately: the WHOLE
per-head loss (attraction + M̃ + M) dispatches through the kernel registry
as one fused kernel (``"nomad_step"``, :func:`nomad_step_term`) whose
Pallas path accumulates the repulsive mass online across K-tiles — the
(B, k+S) affinity block and the (B, K) mean-term block never materialise
in HBM. The jnp path is the legacy multi-pass composition, preserved
bit-equal as the oracle. ``"cauchy_mean"`` (:func:`nomad_mean_term`)
remains the standalone M̃ kernel for the serve path and the oracle tests.
``impl`` selects per call ("auto" picks per backend; legacy bools work).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cauchy import cauchy


def mean_term_jnp(theta_i: jax.Array, means: jax.Array, mean_w: jax.Array) -> jax.Array:
    """Generic M̃: (B,d) × (K,d) × (B,K) → (B,). Oracle/test path."""
    q_im = cauchy(theta_i[:, None, :], means[None, :, :])  # (B, K)
    return jnp.sum(mean_w * q_im, axis=-1)


def nomad_mean_term(
    theta_i: jax.Array,
    means: jax.Array,
    cell_w: jax.Array,  # (K,) = |M| · p(m∈r)
    own_cell: jax.Array,  # (B,) global cell id of each head (excluded from M̃)
    impl=None,  # registry impl: None/"auto" | "pallas" | "jnp" (bools legacy)
) -> jax.Array:
    from repro.kernels import registry

    return registry.dispatch("cauchy_mean", theta_i, means, cell_w, own_cell, impl=impl)


def nomad_step_term(
    theta_i: jax.Array,  # (B, d) head positions
    theta_pos: jax.Array,  # (B, k, d) positive (kNN) tail positions
    pos_w: jax.Array,  # (B, k) p(j|i) weights
    theta_neg: jax.Array,  # (B, S) exact in-cell samples
    neg_w: jax.Array,  # (B, S) importance weights
    means: jax.Array,  # (K, d) cell means (stop-gradded by the kernel)
    cell_w: jax.Array,  # (K,) |M|·p(m∈r) weights
    own_cell: jax.Array,  # (B,) global cell id per head (excluded from M̃)
    impl=None,  # registry impl: None/"auto" | "pallas" | "jnp" (bools legacy)
) -> jax.Array:
    """The fused per-head step loss (B,) through the registry.

    Pallas = one online-accumulating pass (custom VJP, gradients to θ_i,
    θ_pos, θ_neg only); jnp = the legacy multi-pass oracle, bit-equal to
    the pre-fusion ``nomad_mean_term`` + ``contrastive_loss`` composition.
    """
    from repro.kernels import registry

    return registry.dispatch(
        "nomad_step",
        theta_i,
        theta_pos,
        pos_w,
        theta_neg,
        neg_w,
        means,
        cell_w,
        own_cell,
        impl=impl,
    )


def contrastive_loss(
    theta_i: jax.Array,  # (B, d) head positions
    theta_pos: jax.Array,  # (B, k, d) positive (kNN) tail positions
    pos_w: jax.Array,  # (B, k) p(j|i) weights (0 ⇒ edge absent)
    m_tilde: jax.Array,  # (B,) mean-approximated negative mass (M̃)
    theta_neg: Optional[jax.Array] = None,  # (B, S, d) sampled negatives
    neg_w: Optional[jax.Array] = None,  # (B, S) importance weights
) -> jax.Array:
    """The shared primitive above. Returns a scalar (mean over the batch)."""
    q_pos = cauchy(theta_i[:, None, :], theta_pos)  # (B, k)
    if theta_neg is not None:
        q_neg = cauchy(theta_i[:, None, :], theta_neg)  # (B, S)
        m_exact = jnp.sum(neg_w * q_neg, axis=-1)  # (B,)
    else:
        m_exact = jnp.zeros(theta_i.shape[:1], jnp.float32)
    denom = q_pos + (m_tilde + m_exact)[:, None]
    per_edge = jnp.log(q_pos) - jnp.log(denom)
    loss = -jnp.sum(pos_w * per_edge, axis=-1)  # (B,)
    return jnp.mean(loss)


def infonc_tsne_loss(theta_i, theta_pos, pos_w, theta_noise):
    """Eq. 2 estimator: denominators from |M| uniformly-drawn noise tails.

    theta_noise: (B, M, d). Mirrors Damrich et al.'s InfoNC-t-SNE with the
    explicit p(j|i) weights of Eq. 6 (NOMAD models p(j|i) explicitly). This
    is the R̃ = ∅ corner of the NOMAD loss: M̃ ≡ 0 and every noise draw is
    an exact sample with unit weight.
    """
    B, M, _ = theta_noise.shape
    m_tilde = jnp.zeros((B,), jnp.float32)
    neg_w = jnp.ones((B, M), jnp.float32)  # Σ_m q(im), unweighted as in Eq. 2
    return contrastive_loss(theta_i, theta_pos, pos_w, m_tilde, theta_noise, neg_w)


def nomad_loss(
    theta_i,
    theta_pos,
    pos_w,
    means,
    counts,  # (K,) cell sizes (fp32 ok)
    cell_of_i,  # (B,) own-cell id of each head (global numbering)
    theta_neg,  # (B, S, d) samples drawn uniformly from the head's own cell
    n_noise: int,  # |M|
    n_total: int,  # N (support size of ξ per head; self-edges negligible at scale)
    impl=None,  # registry impl for the fused step kernel (None/"auto"|"pallas"|"jnp")
):
    """Eq. 3 with R̃ = all cells except the head's own (the paper's default).

    M̃  = |M| Σ_{r≠c(i)} (|r|/N) q(i, μ_r)      — means, stop-gradded
    M   = |M| (|c(i)|/N) mean_s q(i, m_s)      — exact in-cell samples

    The whole per-head term dispatches as ONE fused registry kernel
    (``"nomad_step"``); its jnp path is the legacy mean-term +
    contrastive composition, bit-for-bit.
    """
    B, S, _ = theta_neg.shape
    p_cell = counts.astype(jnp.float32) / float(n_total)  # (K,)
    cell_w = float(n_noise) * p_cell  # (K,)
    means = jax.lax.stop_gradient(means)
    p_own = p_cell[cell_of_i]  # (B,)
    neg_w = jnp.broadcast_to((float(n_noise) * p_own / S)[:, None], (B, S))
    per_head = nomad_step_term(
        theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, cell_of_i, impl
    )
    return jnp.mean(per_head)

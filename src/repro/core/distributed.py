"""Distributed NOMAD Projection (paper Fig. 2, on a TPU mesh).

Clusters are sharded contiguously across devices: shard ``s`` of ``n``
owns clusters ``[s·K/n, (s+1)·K/n)`` — each cluster is a component of the
ANN graph (paper §3.2), so positive forces and exact in-cell negatives
never leave the device. The only collective in the optimisation loop is
the per-refresh all-gather of cluster means and (static) counts.

The epoch body is process-agnostic: built over a mesh that spans the
**global** device pool (``jax.devices()``), its all-gathers/psums cross
process boundaries under multi-process ``jax.distributed`` with no code
change — gather/sum over the same per-device shards in the same mesh
order makes a P-process fit bit-equal to a 1-process fit on the same
device count (asserted in tests/test_multiprocess.py).

Two exchange modes:

* ``flat``         — the paper: all-gather all K means over every device.
* ``hierarchical`` — our multi-pod extension (the paper's stated future
  work): full means circulate only within a pod; remote pods are
  summarised by one size-weighted *super-mean* each. The same
  Jensen+Taylor argument (paper §7) applied to the pod-level partition
  justifies the approximation; DCN bytes drop from K·d to pods·d.

The SGD step body is ``repro.core.nomad.make_step_fn`` — identical math to
the single-device reference, which is what the equivalence test checks.

Host-side orchestration lives in the unified estimator now
(:class:`repro.core.nomad.NomadProjection` + ``repro.core.strategy``); this
module provides the ``shard_map`` epoch function those strategies wrap, and
keeps :func:`fit_distributed` as a deprecation shim.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import NomadConfig
from repro.core import losses
from repro.core.nomad import local_means, sample_in_cluster, sample_points


def shard_index_and_count(mesh: Mesh, axes) -> tuple:
    """(flat shard index, total shards) for possibly-multiple mesh axes."""
    sizes = [mesh.shape[a] for a in axes]
    idx = jnp.zeros((), jnp.int32)
    for a, s in zip(axes, sizes):
        idx = idx * s + jax.lax.axis_index(a)
    total = int(np.prod(sizes))
    return idx, total


def make_sharded_epoch_fn(
    cfg: NomadConfig,
    mesh: Mesh,
    *,
    shard_axes=("data", "model"),
    pod_axis: Optional[str] = None,
    steps_per_epoch: int,
    n_shards: int,
):
    """Build ``epoch(theta, idx, lr0, lr1, key) -> (theta, mean_loss)``.

    ``theta``: (K·C, d) global view, rows sharded over ``shard_axes``
    (+ ``pod_axis`` outermost if given). ``idx`` dict likewise row-sharded
    except the replicated ``counts_global``.
    """
    C = cfg.cluster_capacity
    K = cfg.n_clusters
    Kl = K // n_shards
    B, S, Mn = cfg.batch_size, cfg.n_exact_negatives, cfg.n_noise
    # batch_size is PER SHARD (paper: per-GPU); one epoch still touches ~N
    # heads because steps_per_epoch is divided by n_shards in fit_distributed.
    B_local = B
    refresh = cfg.mean_refresh_steps or steps_per_epoch
    n_chunks = max(steps_per_epoch // refresh, 1)
    all_axes = ((pod_axis,) if pod_axis else ()) + tuple(shard_axes)
    hierarchical = cfg.hierarchical and pod_axis is not None
    n_total = cfg.n_points

    def gather_cells(theta_l, counts_l, counts_global, shard_off):
        """Per-refresh exchange → (cell_means, cell_w, own-exclusion base).

        Returns the means matrix the loss sees, its |M|·p weights, and the
        global id offset of this shard's own cells within that matrix.
        """
        means_l = local_means(theta_l, counts_l, C)  # (Kl, d)
        if not hierarchical:
            means_g = jax.lax.all_gather(means_l, all_axes, axis=0, tiled=True)
            cell_w = float(Mn) * counts_global.astype(jnp.float32) / n_total
            return means_g, cell_w, shard_off
        # ---- hierarchical: full means intra-pod, super-means inter-pod ----
        means_pod = jax.lax.all_gather(means_l, tuple(shard_axes), axis=0, tiled=True)
        n_pods = mesh.shape[pod_axis]
        Kp = K // n_pods  # clusters per pod
        pod_idx = jax.lax.axis_index(pod_axis)
        pod_counts = jax.lax.dynamic_slice_in_dim(
            counts_global.astype(jnp.float32), pod_idx * Kp, Kp
        )
        w_sum = jnp.maximum(jnp.sum(pod_counts), 1.0)
        super_mean = jnp.sum(means_pod * pod_counts[:, None], 0, keepdims=True) / w_sum
        super_means = jax.lax.all_gather(super_mean[0], pod_axis, axis=0, tiled=False)
        super_counts = jax.lax.all_gather(jnp.sum(pod_counts), pod_axis, tiled=False)
        # own pod's super-mean is excluded (its cells are already exact/full)
        own_pod = jax.lax.axis_index(pod_axis)
        super_w = float(Mn) * super_counts / n_total
        super_w = jnp.where(jnp.arange(n_pods) == own_pod, 0.0, super_w)
        cell_means = jnp.concatenate([means_pod, super_means], axis=0)  # (Kp+P, d)
        pod_cell_w = float(Mn) * pod_counts / n_total
        cell_w = jnp.concatenate([pod_cell_w, super_w])
        own_base = shard_off - pod_idx * Kp  # own cells indexed within the pod block
        return cell_means, cell_w, own_base

    def sgd_step(theta_l, idx_l, cell_means, cell_w, own_base, counts_l, lr, key):
        k_head, k_neg = jax.random.split(key)
        rows, cl_local = sample_points(k_head, B_local, idx_l["cum_counts"], C)
        pos_rows = idx_l["knn_idx"][rows]
        pos_w = idx_l["knn_w"][rows]
        th_i = theta_l[rows]
        th_pos = theta_l[pos_rows]
        neg_rows = sample_in_cluster(k_neg, cl_local, counts_l, C, S)
        th_neg = theta_l[neg_rows]
        own_cell = cl_local + own_base
        p_own = counts_l.astype(jnp.float32)[cl_local] / n_total
        neg_w = jnp.broadcast_to((float(Mn) * p_own / S)[:, None], (B_local, S))
        cell_means = jax.lax.stop_gradient(cell_means)

        def loss_fn(ti, tp, tn):
            # one fused registry kernel per step (jnp path ≡ the legacy
            # mean-term + contrastive composition, bit-for-bit)
            per_head = losses.nomad_step_term(
                ti, tp, pos_w, tn, neg_w, cell_means, cell_w, own_cell,
                cfg.resolved_kernel_impl(),
            )
            return jnp.mean(per_head)

        loss, (g_i, g_pos, g_neg) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
            th_i, th_pos, th_neg
        )
        d = theta_l.shape[1]
        theta_l = theta_l.at[rows].add(-lr * g_i)
        theta_l = theta_l.at[pos_rows.reshape(-1)].add(-lr * g_pos.reshape(-1, d))
        theta_l = theta_l.at[neg_rows.reshape(-1)].add(-lr * g_neg.reshape(-1, d))
        return theta_l, loss

    row_spec = P((pod_axis,) + tuple(shard_axes) if pod_axis else shard_axes)
    specs_in = (
        P(*row_spec, None),  # theta (K·C, d)
        {
            "knn_idx": P(*row_spec, None),
            "knn_w": P(*row_spec, None),
            "counts": P(*row_spec),
            "cum_counts": P(*row_spec),
        },
        P(),  # counts_global (K,) replicated
        P(),  # lr0
        P(),  # lr1
        P(),  # key
    )

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=specs_in,
        out_specs=(P(*row_spec, None), P()),
        check_rep=False,
    )
    def epoch(theta_l, idx_l, counts_global, lr0, lr1, key):
        shard_idx, _ = shard_index_and_count(mesh, all_axes)
        shard_off = shard_idx * Kl
        if n_shards > 1:  # decorrelate shards; 1 shard matches the local stream
            key = jax.random.fold_in(key, shard_idx)
        counts_l = idx_l["counts"]

        def chunk_body(carry, c):
            theta_l, t0 = carry
            cell_means, cell_w, own_base = gather_cells(
                theta_l, counts_l, counts_global, shard_off
            )

            def step_body(carry, t):
                theta_l = carry
                lr = lr0 + (lr1 - lr0) * (t / steps_per_epoch)
                theta_l, loss = sgd_step(
                    theta_l,
                    idx_l,
                    cell_means,
                    cell_w,
                    own_base,
                    counts_l,
                    lr,
                    jax.random.fold_in(key, t),
                )
                return theta_l, loss

            theta_l, losses_ = jax.lax.scan(
                step_body, theta_l, t0 + jnp.arange(refresh)
            )
            return (theta_l, t0 + refresh), jnp.mean(losses_)

        (theta_l, _), chunk_losses = jax.lax.scan(
            chunk_body, (theta_l, jnp.zeros((), jnp.int32)), jnp.arange(n_chunks)
        )
        loss = jax.lax.pmean(jnp.mean(chunk_losses), all_axes)
        return theta_l, loss

    return epoch


# ---------------------------------------------------------------------------
# Host-side orchestration
# ---------------------------------------------------------------------------


def shard_index_arrays(index, n_shards: int):
    """Split an AnnIndex into the global-view arrays the epoch fn expects.

    kNN row ids are rebased to be shard-local (subtracting the shard's row
    offset) — positives never cross shards by construction, this just
    asserts it numerically.
    """
    K, C = index.n_clusters, index.capacity
    if K % n_shards:
        raise ValueError(f"n_clusters={K} not divisible by n_shards={n_shards}")
    Kl = K // n_shards
    rows_per = Kl * C
    knn_local = index.knn_idx.copy()
    for s in range(n_shards):
        lo, hi = s * rows_per, (s + 1) * rows_per
        blk = knn_local[lo:hi]
        if blk.size and ((blk < lo) | (blk >= hi)).any():
            raise AssertionError("kNN edge crosses shard boundary")
        knn_local[lo:hi] = blk - lo
    cum = np.concatenate(
        [np.cumsum(index.counts[s * Kl : (s + 1) * Kl]) for s in range(n_shards)]
    )
    return {
        "knn_idx": jnp.asarray(knn_local, jnp.int32),
        "knn_w": jnp.asarray(index.knn_w, jnp.float32),
        "counts": jnp.asarray(index.counts, jnp.int32),
        "cum_counts": jnp.asarray(cum, jnp.int32),
    }


def fit_distributed(
    cfg: NomadConfig,
    x: np.ndarray,
    mesh: Mesh,
    *,
    shard_axes=("data", "model"),
    pod_axis: Optional[str] = None,
    index=None,
    theta0=None,
    callback=None,
):
    """DEPRECATED shim — use the unified estimator instead:

        NomadProjection(cfg, strategy="sharded", mesh=mesh).fit(x)

    Delegates to :class:`repro.core.nomad.NomadProjection` and returns the
    legacy ``(embedding, index, losses)`` tuple. Note the legacy ``callback``
    now receives the *unpermuted* ``(N, out_dim)`` embedding, not the raw
    sharded θ buffer.
    """
    import warnings

    warnings.warn(
        "fit_distributed is deprecated; use "
        "NomadProjection(cfg, strategy='sharded'|'hierarchical', mesh=mesh).fit(x)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.core.nomad import NomadProjection

    strategy = "hierarchical" if (cfg.hierarchical and pod_axis) else "sharded"
    est = NomadProjection(
        cfg, strategy=strategy, mesh=mesh, shard_axes=shard_axes, pod_axis=pod_axis
    )
    res = est.fit(x, index=index, callback=callback, theta0=theta0)
    return res.embedding, res.index, res.losses

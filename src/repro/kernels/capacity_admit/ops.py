"""Registry spec for the capacity-bounded admission step (jnp-only).

The index build's bidding loop dispatches ``"capacity_admit"`` by name so
its inner loop is one uniform registry seam with ``"kmeans_assign"`` (the
distance+argmin half of a round). Admission is sort-bound — a fused Pallas
path would still be two device sorts — so the spec registers ``pallas=None``
and always serves the jnp reference.
"""

from __future__ import annotations

import jax

from repro.kernels import registry
from repro.kernels.capacity_admit.ref import capacity_admit_ref


def _make_inputs(key, sig):
    (ps, _pdt), (ds, _ddt), (bs, _bdt), (fs, _fdt) = sig
    kp, kd, kb, kf = jax.random.split(key, 4)
    K = fs[0]
    pick = jax.random.randint(kp, ps, 0, K, "int32")
    d2 = jax.random.uniform(kd, ds, "float32")
    bidding = jax.random.bernoulli(kb, 0.7, bs)
    free = jax.random.randint(kf, fs, 0, max(2, ps[0] // K), "int32")
    return pick, d2, bidding, free


def _sig(n, k):
    return (((n,), "int32"), ((n,), "float32"), ((n,), "bool"), ((k,), "int32"))


SPEC = registry.register(
    registry.KernelSpec(
        name="capacity_admit",
        ref=capacity_admit_ref,
        pallas=None,  # jnp-only: sort-bound on every backend
        tile_candidates=(),
        default_tiles={"": {}},
        make_inputs=_make_inputs,
        check_shapes=(_sig(512, 16), _sig(1000, 7)),
        bench_shapes=_sig(100_000, 256),
    )
)

"""jnp reference for one capacity-bounded bidding round's admission.

Given every point's bid (nearest centroid with free capacity) this decides,
per centroid, which bidders get in: the ``free[c]`` *closest* ones, with a
stable original-index tie-break — exactly the host reference
(`repro.index.kmeans.capacity_assign`) admits per round.

The whole step is sort-bound (two stable argsorts + a searchsorted), so the
jnp path IS the production path on every backend; it is registered jnp-only
(``pallas=None``) to claim the dispatch seam for the index build.
"""

from __future__ import annotations

import jax.numpy as jnp


def capacity_admit_ref(pick, d2, bidding, free):
    """One bidding round's admission mask.

    pick    (N,) int32  — each point's bid (a centroid id in [0, K))
    d2      (N,) f32    — the bid's distance (ranks bidders per centroid)
    bidding (N,) bool   — False ⇒ the row does not participate this round
                          (already assigned, or a padding row)
    free    (K,) int32  — remaining capacity per centroid

    Returns ``admitted`` (N,) bool. Carries only O(N + K) state — never an
    (N, K) matrix: admission rank within a centroid's bidder pool comes
    from a stable two-key sort (centroid, distance, original index).
    """
    n = pick.shape[0]
    k = free.shape[0]
    # non-bidders sort into a sentinel segment k past every real centroid
    pick_eff = jnp.where(bidding, pick, k).astype(jnp.int32)
    d2_eff = jnp.where(bidding, d2.astype(jnp.float32), jnp.inf)
    # stable two-pass sort == lexicographic (centroid, distance, index)
    order = jnp.argsort(d2_eff, stable=True)
    order = order[jnp.argsort(pick_eff[order], stable=True)]
    p_sorted = pick_eff[order]
    # rank of each bidder within its centroid's segment
    seg_start = jnp.searchsorted(p_sorted, p_sorted, side="left")
    rank = jnp.arange(n, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    free_ext = jnp.concatenate([free.astype(jnp.int32), jnp.zeros((1,), jnp.int32)])
    admitted_sorted = bidding[order] & (rank < free_ext[p_sorted])
    return jnp.zeros((n,), bool).at[order].set(admitted_sorted)

"""Fused-kernel package: Pallas hot-spot kernels behind one registry.

Each kernel lives in its own sub-package as

  <name>/<name>.py   the Pallas kernel bodies (tile-parameterized)
  <name>/ops.py      the public padding-safe op + its ``KernelSpec``
  <name>/ref.py      the pure-jnp oracle

and registers itself with :mod:`repro.kernels.registry`. Consumers call
``registry.dispatch("<name>", *args, impl=...)``; tile sizes come from
:mod:`repro.kernels.autotune` (per-backend grid sweep, on-disk cache).
Adding a kernel = write the three files + ``registry.register(spec)`` —
see docs/ARCHITECTURE.md for a worked example.

A hot spot may also register **jnp-only** (``pallas=None``, no kernel
body file) to claim the dispatch seam before a fused path lands — e.g.
``capacity_admit``, the sort-bound admission step of the index build.
"""

from repro.kernels import registry  # noqa: F401

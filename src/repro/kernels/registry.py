"""Kernel registry + dispatcher: one seam for every fused kernel.

Each compute hot-spot registers a :class:`KernelSpec` declaring

* ``ref``     — the pure-jnp oracle (differentiable via ordinary AD),
* ``pallas``  — the fused Pallas implementation, parameterized by a
  ``tiles`` mapping of block/tile sizes (and ``interpret``),
* ``tile_candidates`` / ``default_tiles`` — the autotune search grid and
  the per-backend fallback winners,
* ``make_inputs`` + ``check_shapes`` + ``oracle_check`` — a correctness
  oracle: synthesize inputs for any shape signature and validate the
  Pallas path against ``ref`` (used by tests, benchmarks and the tuner).

Callers go through :func:`dispatch`, which resolves pallas-vs-jnp *per
backend* with overrides, then asks the autotuner for tile sizes:

    resolution order (first match wins)
      1. explicit ``impl=`` argument ("pallas" | "jnp"; "auto"/None falls
         through; legacy bools are accepted: True→"pallas", False→"jnp")
      2. env ``REPRO_KERNEL_<NAME>``   (per-kernel override)
      3. env ``REPRO_KERNELS``         (global override)
      4. backend policy: tpu/gpu → "pallas" (compiled); cpu → "jnp"
         (Pallas on CPU means interpret mode — an oracle-checking tool,
         not a fast path)

``REPRO_PALLAS_INTERPRET`` ("0"/"1") forces interpret mode off/on; unset
⇒ interpret on CPU, compiled on TPU/GPU. (This changes the pre-registry
default, which interpreted on *every* backend until the env var was set
to "0" — TPU runs now compile out of the box.) CPU CI thus exercises the
same kernel bodies that Mosaic compiles on a real TPU.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

import jax

# (shape, dtype-name) per public argument — the unit the autotune cache is
# keyed on and ``make_inputs`` synthesizes from.
ShapeSig = Tuple[Tuple[Tuple[int, ...], str], ...]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Everything the dispatcher/autotuner/benchmarks need about one kernel.

    ``pallas=None`` registers a **jnp-only** kernel: a hot spot that wants
    the registry seam today (named dispatch, env/config overrides, a place
    for tests and benchmarks to find it) before a fused implementation has
    landed. Such kernels always resolve to the ref path; ``validate``
    raises, and the autotuner never sees them. The capacity-bounded
    admission step of the index build (``"capacity_admit"``) is the first:
    sort-bound, VPU-bound either way, but its dispatch seam keeps the
    build's inner loops uniform.
    """

    name: str
    ref: Callable[..., Any]
    pallas: Optional[Callable[..., Any]]  # pallas(*args, tiles=Mapping, interpret=bool)
    tile_candidates: Tuple[Mapping[str, int], ...]
    default_tiles: Mapping[str, Mapping[str, int]]  # backend → tiles ("" = fallback)
    make_inputs: Callable[[jax.Array, ShapeSig], tuple]  # (key, sig) → args
    check_shapes: Tuple[ShapeSig, ...]  # correctness grid for tests
    bench_shapes: ShapeSig  # the micro-benchmark working point
    tol: Tuple[float, float] = (1e-5, 1e-5)  # (rtol, atol) vs the oracle
    # optional custom comparison (e.g. argmin ties); signature
    # oracle_check(args, got, want) -> None, raising on mismatch
    oracle_check: Optional[Callable[[tuple, Any, Any], None]] = None
    # optional analytic cost of ONE forward call at a signature:
    # cost_model(sig) -> {"flops": float, "bytes": float} — feeds the
    # roofline columns of benchmarks/kernel_micro.py and the autotuner's
    # per-candidate achieved-vs-roofline report
    cost_model: Optional[Callable[[ShapeSig], dict]] = None
    # dtype grid the parity harness (tests/test_kernel_parity.py) sweeps:
    # every floating dtype in check_shapes is rewritten to each entry
    dtype_grid: Tuple[str, ...] = ("float32", "bfloat16")

    def tiles_for_backend(self, backend: str) -> Mapping[str, int]:
        return self.default_tiles.get(backend, self.default_tiles[""])


_REGISTRY: dict[str, KernelSpec] = {}
_BUILTINS_LOADED = False


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def _load_builtins() -> None:
    """Import the kernel packages (each registers its spec at import)."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    import repro.kernels.capacity_admit.ops  # noqa: F401
    import repro.kernels.cauchy_mean.ops  # noqa: F401
    import repro.kernels.frozen_attract.ops  # noqa: F401
    import repro.kernels.kmeans_assign.ops  # noqa: F401
    import repro.kernels.nomad_step.ops  # noqa: F401
    import repro.kernels.pairwise.ops  # noqa: F401


def get(name: str) -> KernelSpec:
    _load_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: {names()}") from None


def names() -> list[str]:
    _load_builtins()
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Implementation resolution
# ---------------------------------------------------------------------------

_VALID_IMPLS = ("pallas", "jnp")


def normalize_impl(impl) -> str:
    """Map legacy bools / None / strings onto {"auto", "pallas", "jnp"}."""
    if impl is None:
        return "auto"
    if isinstance(impl, bool):
        return "pallas" if impl else "jnp"
    impl = str(impl).lower()
    if impl in ("", "auto"):
        return "auto"
    if impl == "ref":
        return "jnp"
    if impl not in _VALID_IMPLS:
        raise ValueError(f"impl must be auto|pallas|jnp, got {impl!r}")
    return impl


def backend() -> str:
    return jax.default_backend()


def interpret_default() -> bool:
    """Env wins; unset ⇒ interpret iff running on CPU (TPU/GPU compile)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return backend() == "cpu"


def has_pallas(name: str) -> bool:
    """False for jnp-only kernels (registered with ``pallas=None``)."""
    return get(name).pallas is not None


def resolve(name: str, impl=None) -> str:
    """Resolve one kernel's implementation to "pallas" or "jnp".

    jnp-only kernels resolve to "jnp" under every override — the seam is
    registered, the fused path hasn't landed yet. Invalid ``impl`` strings
    still raise for them, same as for every other kernel.
    """
    choice = normalize_impl(impl)
    if not has_pallas(name):
        return "jnp"
    if choice == "auto":
        env_kernel = os.environ.get("REPRO_KERNEL_" + name.upper().replace("-", "_"))
        env_global = os.environ.get("REPRO_KERNELS")
        choice = normalize_impl(env_kernel if env_kernel else env_global)
    if choice == "auto":
        choice = "jnp" if backend() == "cpu" else "pallas"
    return choice


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def shape_sig(args: Sequence[Any]) -> ShapeSig:
    """Static (shape, dtype) signature — works on tracers too."""
    return tuple((tuple(a.shape), str(a.dtype)) for a in args)


def dispatch(name: str, *args, impl=None, tiles: Optional[Mapping[str, int]] = None):
    """Run kernel ``name`` on ``args`` through the resolved implementation.

    Safe to call under ``jit``/``grad``: resolution happens at trace time
    (implementation choice and tile sizes are static w.r.t. the trace).
    """
    spec = get(name)
    if resolve(name, impl) == "jnp":
        return spec.ref(*args)
    if tiles is None:
        from repro.kernels import autotune

        tiles = autotune.tiles_for(spec, shape_sig(args))
    return spec.pallas(*args, tiles=tiles, interpret=interpret_default())


# ---------------------------------------------------------------------------
# Correctness oracle
# ---------------------------------------------------------------------------


def validate(
    name: str,
    args: tuple,
    *,
    tiles: Optional[Mapping[str, int]] = None,
    interpret: Optional[bool] = None,
):
    """Run the Pallas path against the jnp oracle on ``args``; raise on
    mismatch. The spec's ``oracle_check`` (if any) arbitrates ties;
    otherwise every output leaf must be allclose within ``spec.tol``."""
    import numpy as np

    spec = get(name)
    if spec.pallas is None:
        raise ValueError(
            f"kernel {name!r} is jnp-only (pallas=None) — nothing to validate "
            "against the oracle"
        )
    if tiles is None:
        tiles = spec.tiles_for_backend(backend())
    if interpret is None:
        interpret = interpret_default()
    got = spec.pallas(*args, tiles=tiles, interpret=interpret)
    want = spec.ref(*args)
    if spec.oracle_check is not None:
        spec.oracle_check(args, got, want)
        return got, want
    rtol, atol = spec.tol
    got_leaves = jax.tree_util.tree_leaves(got)
    want_leaves = jax.tree_util.tree_leaves(want)
    assert len(got_leaves) == len(want_leaves), (len(got_leaves), len(want_leaves))
    for g, w in zip(got_leaves, want_leaves):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32), rtol=rtol, atol=atol
        )
    return got, want

"""Pure-jnp oracle for the fused K-means E-step (distance + argmin)."""

from __future__ import annotations

import jax.numpy as jnp


def assign_nearest_ref(x, cents):
    """x (N, D), cents (K, D) → (assign (N,) int32, min_d2 (N,) fp32)."""
    x = x.astype(jnp.float32)
    c = cents.astype(jnp.float32)
    d2 = (
        jnp.sum(jnp.square(x), -1)[:, None]
        + jnp.sum(jnp.square(c), -1)[None, :]
        - 2.0 * x @ c.T
    )
    d2 = jnp.maximum(d2, 0.0)
    return jnp.argmin(d2, -1).astype(jnp.int32), jnp.min(d2, -1)

"""Public op + registry spec for the fused K-means E-step
(padding-safe jit wrapper around the distance+argmin Pallas kernel)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.kernels.kmeans_assign.kmeans_assign import assign_nearest_pallas
from repro.kernels.kmeans_assign.ref import assign_nearest_ref


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def _assign_nearest_padded(x, cents, block_n, block_k, interpret):
    n, k = x.shape[0], cents.shape[0]
    bn, bk = min(block_n, max(n, 8)), min(block_k, max(k, 8))
    pad_n = (-n) % bn
    pad_k = (-k) % bk
    xp = jnp.concatenate([x, jnp.zeros((pad_n, x.shape[1]), x.dtype)]) if pad_n else x
    # padded centroids sit at +BIG distance so they are never selected
    if pad_k:
        far = jnp.full((pad_k, cents.shape[1]), 1e18, cents.dtype)
        cp = jnp.concatenate([cents, far])
    else:
        cp = cents
    arg, mind = assign_nearest_pallas(
        xp.astype(jnp.float32), cp.astype(jnp.float32), block_n=bn, block_k=bk, interpret=interpret
    )
    return arg[0, :n], mind[0, :n]


def assign_nearest(
    x,
    cents,
    block_n: int = 512,
    block_k: int = 256,
    interpret: bool | None = None,
):
    """x (N, D), cents (K, D) → (assign (N,) int32, min_d2 (N,) fp32)."""
    if interpret is None:
        interpret = registry.interpret_default()
    return _assign_nearest_padded(x, cents, block_n, block_k, interpret)


# ---------------------------------------------------------------------------
# Registry spec
# ---------------------------------------------------------------------------


def _pallas_adapter(x, cents, *, tiles, interpret):
    return assign_nearest(
        x,
        cents,
        block_n=tiles.get("block_n", 512),
        block_k=tiles.get("block_k", 256),
        interpret=interpret,
    )


def _make_inputs(key, sig):
    (xs, xdt), (cs, cdt) = sig
    kx, kc = jax.random.split(key)
    return jax.random.normal(kx, xs, xdt), jax.random.normal(kc, cs, cdt)


def _oracle_check(args, got, want):
    """Argmin ties may break differently between tilings: assert the min
    distances agree and the chosen centroid is distance-equivalent."""
    x, cents = args
    a_got, d_got = (np.asarray(got[0]), np.asarray(got[1]))
    a_want, d_want = (np.asarray(want[0]), np.asarray(want[1]))
    np.testing.assert_allclose(d_got, d_want, rtol=1e-4, atol=1e-4)
    xf = np.asarray(x, np.float32)
    cf = np.asarray(cents, np.float32)
    d_of_got = np.sum(np.square(xf - cf[a_got]), axis=-1)
    d_of_want = np.sum(np.square(xf - cf[a_want]), axis=-1)
    np.testing.assert_allclose(d_of_got, d_of_want, rtol=1e-4, atol=1e-4)


def _sig(n, k, d, dt="float32"):
    return (((n, d), dt), ((k, d), dt))


def _cost_model(sig):
    (n, d) = sig[0][0]
    k = sig[1][0][0]
    flops = 2.0 * n * k * d + 2.0 * n * k  # dist² + running argmin
    bytes_ = 4.0 * (n * d + k * d + 2 * n)
    return {"flops": flops, "bytes": bytes_}


SPEC = registry.register(
    registry.KernelSpec(
        name="kmeans_assign",
        ref=assign_nearest_ref,
        pallas=_pallas_adapter,
        tile_candidates=(
            {"block_n": 256, "block_k": 128},
            {"block_n": 512, "block_k": 256},
            {"block_n": 512, "block_k": 512},
            {"block_n": 1024, "block_k": 256},
        ),
        default_tiles={
            "": {"block_n": 512, "block_k": 256},
            "tpu": {"block_n": 512, "block_k": 256},
        },
        make_inputs=_make_inputs,
        check_shapes=(
            _sig(512, 256, 64),
            _sig(1000, 17, 32),
            _sig(64, 512, 128),
            _sig(513, 255, 48),
        ),
        bench_shapes=_sig(4096, 256, 128),
        tol=(1e-4, 1e-4),
        oracle_check=_oracle_check,
        cost_model=_cost_model,
    )
)

"""Public op for the fused K-means E-step (padding-safe jit wrapper)."""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_assign.kmeans_assign import assign_nearest_pallas

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("block_n", "block_k"))
def assign_nearest(x, cents, block_n: int = 512, block_k: int = 256):
    """x (N, D), cents (K, D) → (assign (N,) int32, min_d2 (N,) fp32)."""
    n, k = x.shape[0], cents.shape[0]
    bn, bk = min(block_n, max(n, 8)), min(block_k, max(k, 8))
    pad_n = (-n) % bn
    pad_k = (-k) % bk
    xp = jnp.concatenate([x, jnp.zeros((pad_n, x.shape[1]), x.dtype)]) if pad_n else x
    # padded centroids sit at +BIG distance so they are never selected
    if pad_k:
        far = jnp.full((pad_k, cents.shape[1]), 1e18, cents.dtype)
        cp = jnp.concatenate([cents, far])
    else:
        cp = cents
    arg, mind = assign_nearest_pallas(
        xp.astype(jnp.float32), cp.astype(jnp.float32), block_n=bn, block_k=bk, interpret=INTERPRET
    )
    return arg[0, :n], mind[0, :n]

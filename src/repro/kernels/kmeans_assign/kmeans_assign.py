"""Fused K-means E-step Pallas kernel: distance tile (MXU) + running argmin.

Grid (N/bn, K/bk); the running (min, argmin) lives in the output blocks
(VMEM-resident, re-read each K step) — no (N, K) distance matrix ever
reaches HBM. Epilogue clamps distances at 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38  # python float: jnp scalars would be captured as consts


def _kernel(x_ref, c_ref, arg_ref, min_ref, *, bk):
    kstep = pl.program_id(1)

    @pl.when(kstep == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, BIG)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    x = x_ref[...]  # (bn, D)
    c = c_ref[...]  # (bk, D)
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = (
        jnp.sum(jnp.square(x), axis=1, keepdims=True)
        + jnp.sum(jnp.square(c), axis=1, keepdims=True).T
        - 2.0 * cross
    )
    d2 = jnp.maximum(d2, 0.0)  # (bn, bk)
    tile_min = jnp.min(d2, axis=1)  # (bn,)
    tile_arg = (kstep * bk + jnp.argmin(d2, axis=1)).astype(jnp.int32)
    cur = min_ref[0, :]
    better = tile_min < cur
    min_ref[0, :] = jnp.where(better, tile_min, cur)
    arg_ref[0, :] = jnp.where(better, tile_arg, arg_ref[0, :])


def assign_nearest_pallas(x, cents, *, block_n=512, block_k=256, interpret=True):
    """x (N, D), cents (K, D), D block-resident → ((1,N) int32, (1,N) fp32)."""
    n, d = x.shape
    k = cents.shape[0]
    bn, bk = min(block_n, n), min(block_k, k)
    assert n % bn == 0 and k % bk == 0, (n, k, bn, bk)
    grid = (n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, kk: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, kk: (kk, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, bn), lambda i, kk: (0, i)),
            pl.BlockSpec((1, bn), lambda i, kk: (0, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ),
        interpret=interpret,
    )(x.astype(jnp.float32), cents.astype(jnp.float32))

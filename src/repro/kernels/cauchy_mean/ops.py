"""Public op: ``cauchy_weighted_sum`` with a custom VJP (both directions are
Pallas kernels; means and weights are non-differentiable by the paper's
design — means are refreshed by all-gather, not by gradient flow)."""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.cauchy_mean.cauchy_mean import (
    cauchy_mean_bwd_pallas,
    cauchy_mean_fwd_pallas,
)

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"
BB, BK = 512, 1024


def _pad_minor(a: jax.Array, mult: int, fill=0):
    pad = (-a.shape[-1]) % mult
    if pad:
        filler = jnp.full(a.shape[:-1] + (pad,), fill, a.dtype)
        a = jnp.concatenate([a, filler], axis=-1)
    return a


def _prep(theta_i, means, cell_w, own_cell):
    B, d = theta_i.shape
    bb, bk = min(BB, max(B, 8)), min(BK, max(means.shape[0], 128))
    th = _pad_minor(theta_i.astype(jnp.float32).T, bb)  # (d, B')
    mu = _pad_minor(means.astype(jnp.float32).T, bk)  # (d, K')
    w = _pad_minor(cell_w.astype(jnp.float32)[None, :], bk)  # (1, K') pad w=0
    own = _pad_minor(own_cell.astype(jnp.int32)[None, :], bb, fill=-1)
    return th, mu, w, own, bb, bk, B


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def cauchy_weighted_sum(theta_i, means, cell_w, own_cell):
    s, _ = _fwd(theta_i, means, cell_w, own_cell)
    return s


def _fwd(theta_i, means, cell_w, own_cell):
    th, mu, w, own, bb, bk, B = _prep(theta_i, means, cell_w, own_cell)
    s = cauchy_mean_fwd_pallas(th, mu, w, own, bb=bb, bk=bk, interpret=INTERPRET)
    return s[0, :B], (theta_i, means, cell_w, own_cell)


def _bwd(res, gbar):
    theta_i, means, cell_w, own_cell = res
    th, mu, w, own, bb, bk, B = _prep(theta_i, means, cell_w, own_cell)
    gb = _pad_minor(gbar.astype(jnp.float32)[None, :], bb)
    g = cauchy_mean_bwd_pallas(th, mu, w, own, gb, bb=bb, bk=bk, interpret=INTERPRET)
    g_theta = g[:, :B].T.astype(theta_i.dtype)  # (B, d)
    return (g_theta, None, None, None)


cauchy_weighted_sum.defvjp(_fwd, _bwd)

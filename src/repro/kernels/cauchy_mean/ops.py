"""Public op + registry spec: ``cauchy_weighted_sum`` with a custom VJP
(both directions are Pallas kernels; means and weights are
non-differentiable by the paper's design — means are refreshed by
all-gather, not by gradient flow).

Tile sizes (``bb`` over the batch, ``bk`` over the cells) are arguments
now: each distinct (bb, bk, interpret) triple gets its own cached
``custom_vjp`` instance so the pair stays consistent between forward and
backward under autodiff.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.cauchy_mean.cauchy_mean import (
    cauchy_mean_bwd_pallas,
    cauchy_mean_fwd_pallas,
)
from repro.kernels.cauchy_mean.ref import cauchy_weighted_sum_ref
from repro.kernels.padding import pad_minor as _pad_minor

DEFAULT_BB, DEFAULT_BK = 512, 1024


@functools.lru_cache(maxsize=None)
def _build_op(bb_max: int, bk_max: int, interpret: bool):
    """One custom-vjp op per static (bb, bk, interpret) configuration."""

    def _prep(theta_i, means, cell_w, own_cell):
        B = theta_i.shape[0]
        bb, bk = min(bb_max, max(B, 8)), min(bk_max, max(means.shape[0], 128))
        th = _pad_minor(theta_i.astype(jnp.float32).T, bb)  # (d, B')
        mu = _pad_minor(means.astype(jnp.float32).T, bk)  # (d, K')
        w = _pad_minor(cell_w.astype(jnp.float32)[None, :], bk)  # (1, K') pad w=0
        own = _pad_minor(own_cell.astype(jnp.int32)[None, :], bb, fill=-1)
        return th, mu, w, own, bb, bk, B

    @jax.custom_vjp
    def op(theta_i, means, cell_w, own_cell):
        s, _ = _fwd(theta_i, means, cell_w, own_cell)
        return s

    def _fwd(theta_i, means, cell_w, own_cell):
        th, mu, w, own, bb, bk, B = _prep(theta_i, means, cell_w, own_cell)
        s = cauchy_mean_fwd_pallas(th, mu, w, own, bb=bb, bk=bk, interpret=interpret)
        return s[0, :B], (theta_i, means, cell_w, own_cell)

    def _bwd(res, gbar):
        theta_i, means, cell_w, own_cell = res
        th, mu, w, own, bb, bk, B = _prep(theta_i, means, cell_w, own_cell)
        gb = _pad_minor(gbar.astype(jnp.float32)[None, :], bb)
        g = cauchy_mean_bwd_pallas(th, mu, w, own, gb, bb=bb, bk=bk, interpret=interpret)
        g_theta = g[:, :B].T.astype(theta_i.dtype)  # (B, d)
        return (g_theta, None, None, None)

    op.defvjp(_fwd, _bwd)
    return op


def cauchy_weighted_sum(
    theta_i,
    means,
    cell_w,
    own_cell,
    *,
    bb: int = DEFAULT_BB,
    bk: int = DEFAULT_BK,
    interpret: bool | None = None,
):
    """s_b = Σ_r cell_w[r] · [own_cell[b] ≠ r] · q(θ_b, μ_r). Differentiable
    in ``theta_i`` only (custom VJP); fused over (B, K) tiles of (bb, bk)."""
    if interpret is None:
        interpret = registry.interpret_default()
    return _build_op(bb, bk, interpret)(theta_i, means, cell_w, own_cell)


# ---------------------------------------------------------------------------
# Registry spec
# ---------------------------------------------------------------------------


def _pallas_adapter(theta_i, means, cell_w, own_cell, *, tiles, interpret):
    return cauchy_weighted_sum(
        theta_i,
        means,
        cell_w,
        own_cell,
        bb=tiles.get("bb", DEFAULT_BB),
        bk=tiles.get("bk", DEFAULT_BK),
        interpret=interpret,
    )


def _make_inputs(key, sig):
    (ts, tdt), (ms, mdt), (ws, wdt), (os_, odt) = sig
    K = ms[0]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, ts, tdt) * 3.0
    means = jax.random.normal(k2, ms, mdt) * 3.0
    w = jax.random.uniform(k3, ws, wdt)
    own = jax.random.randint(k4, os_, 0, K, odt)
    return theta, means, w, own


def _sig(B, K, d, dt="float32"):
    return (((B, d), dt), ((K, d), dt), ((K,), dt), ((B,), "int32"))


def _cost_model(sig):
    (B, d) = sig[0][0]
    K = sig[1][0][0]
    flops = float(B) * K * (3 * d + 4)  # dist² + Cauchy + weighted sum
    bytes_ = 4.0 * (B * d + K * d + K + 2 * B)
    return {"flops": flops, "bytes": bytes_}


SPEC = registry.register(
    registry.KernelSpec(
        name="cauchy_mean",
        ref=cauchy_weighted_sum_ref,
        pallas=_pallas_adapter,
        tile_candidates=(
            {"bb": 256, "bk": 512},
            {"bb": 512, "bk": 1024},
            {"bb": 512, "bk": 2048},
            {"bb": 1024, "bk": 1024},
        ),
        default_tiles={
            "": {"bb": DEFAULT_BB, "bk": DEFAULT_BK},
            "tpu": {"bb": DEFAULT_BB, "bk": DEFAULT_BK},
        },
        make_inputs=_make_inputs,
        check_shapes=(
            _sig(512, 1024, 2),
            _sig(100, 64, 2),
            _sig(64, 100, 3),
            _sig(777, 333, 2),
        ),
        bench_shapes=_sig(2048, 2048, 2),
        tol=(1e-5, 1e-6),
        cost_model=_cost_model,
    )
)

"""Fused Cauchy-vs-means Pallas TPU kernels (forward + backward).

This is NOMAD's negative-force hot spot: every sampled head is repelled by
all K cluster means (Eq. 4), a B×K Cauchy contraction executed every step.
Fusing the weight construction (`|M|·p(m∈r)·[r ≠ own]`), the affinity and
the reduction means the (B, K) intermediate never touches HBM — only
θ (d×B), μ (d×K), w (K) stream in and s (B) streams out; arithmetic
intensity is ~K/2 flops/byte, comfortably compute-bound on the VPU.

Layout note (TPU adaptation): positions are passed transposed, (d, B) and
(d, K) with d = 2, so the minor (lane) axis is the large one; the tiny d
axis sits on sublanes. The (bb, bk) working tile lives in VMEM/VREGs only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist2_tile(th, mu, d):
    """th (d, bb), mu (d, bk) → (bb, bk) squared distances (d unrolled)."""
    acc = None
    for dd in range(d):
        diff = th[dd, :, None] - mu[dd, None, :]
        acc = diff * diff if acc is None else acc + diff * diff
    return acc


def _fwd_kernel(theta_ref, means_ref, w_ref, own_ref, out_ref, *, d, bk):
    kstep = pl.program_id(1)

    @pl.when(kstep == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    th = theta_ref[...]  # (d, bb)
    mu = means_ref[...]  # (d, bk)
    q = 1.0 / (1.0 + _dist2_tile(th, mu, d))  # (bb, bk)
    bb = th.shape[1]
    r_ids = kstep * bk + jax.lax.broadcasted_iota(jnp.int32, (bb, bk), 1)
    own = own_ref[...]  # (1, bb)
    mask = (own[0, :, None] != r_ids).astype(jnp.float32)
    w = w_ref[...][0, None, :]  # (1, bk)
    out_ref[0, :] += jnp.sum(q * w * mask, axis=1)


def _bwd_kernel(theta_ref, means_ref, w_ref, own_ref, gbar_ref, gout_ref, *, d, bk):
    kstep = pl.program_id(1)

    @pl.when(kstep == 0)
    def _init():
        gout_ref[...] = jnp.zeros_like(gout_ref)

    th = theta_ref[...]
    mu = means_ref[...]
    q = 1.0 / (1.0 + _dist2_tile(th, mu, d))
    bb = th.shape[1]
    r_ids = kstep * bk + jax.lax.broadcasted_iota(jnp.int32, (bb, bk), 1)
    own = own_ref[...]  # (1, bb)
    mask = (own[0, :, None] != r_ids).astype(jnp.float32)
    factor = w_ref[...][0, None, :] * mask * q * q  # (bb, bk)
    gbar = gbar_ref[...][0, :]  # (bb,)
    for dd in range(d):
        diff = th[dd, :, None] - mu[dd, None, :]
        gout_ref[dd, :] += -2.0 * gbar * jnp.sum(factor * diff, axis=1)


def _grids(B, K, bb, bk):
    assert B % bb == 0 and K % bk == 0, (B, K, bb, bk)
    return (B // bb, K // bk)


def cauchy_mean_fwd_pallas(theta_t, means_t, w, own, *, bb=512, bk=1024, interpret=True):
    """theta_t (d, B), means_t (d, K), w (1, K), own (1, B) → s (1, B)."""
    d, B = theta_t.shape
    K = means_t.shape[1]
    bb, bk = min(bb, B), min(bk, K)
    grid = _grids(B, K, bb, bk)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, d=d, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((d, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((1, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.float32),
        interpret=interpret,
    )(theta_t, means_t, w, own)


def cauchy_mean_bwd_pallas(theta_t, means_t, w, own, gbar, *, bb=512, bk=1024, interpret=True):
    """Adds gbar: returns gθ (d, B)."""
    d, B = theta_t.shape
    K = means_t.shape[1]
    bb, bk = min(bb, B), min(bk, K)
    grid = _grids(B, K, bb, bk)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, d=d, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((d, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((1, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
        ],
        out_specs=pl.BlockSpec((d, bb), lambda i, kk: (0, i)),
        out_shape=jax.ShapeDtypeStruct((d, B), jnp.float32),
        interpret=interpret,
    )(theta_t, means_t, w, own, gbar)

"""Pure-jnp oracle for the fused Cauchy-vs-means reduction (fwd + vjp)."""

from __future__ import annotations

import jax.numpy as jnp


def cauchy_weighted_sum_ref(theta_i, means, cell_w, own_cell):
    """s_b = Σ_r cell_w[r] · [own_cell[b] ≠ r] · q(θ_b, μ_r).

    theta_i (B, d) fp32; means (K, d); cell_w (K,); own_cell (B,) int32.
    """
    th = theta_i.astype(jnp.float32)
    mu = means.astype(jnp.float32)
    d2 = jnp.sum(jnp.square(th[:, None, :] - mu[None, :, :]), axis=-1)  # (B, K)
    q = 1.0 / (1.0 + d2)
    K = means.shape[0]
    mask = own_cell[:, None] != jnp.arange(K, dtype=own_cell.dtype)[None, :]
    return jnp.sum(q * cell_w[None, :].astype(jnp.float32) * mask, axis=-1)


def cauchy_weighted_sum_vjp_ref(theta_i, means, cell_w, own_cell, gbar):
    """∂(gbar·s)/∂θ_b = gbar_b Σ_r w·mask·(−2)(θ_b−μ_r)·q²."""
    th = theta_i.astype(jnp.float32)
    mu = means.astype(jnp.float32)
    diff = th[:, None, :] - mu[None, :, :]  # (B, K, d)
    d2 = jnp.sum(jnp.square(diff), axis=-1)
    q = 1.0 / (1.0 + d2)
    K = means.shape[0]
    mask = own_cell[:, None] != jnp.arange(K, dtype=own_cell.dtype)[None, :]
    factor = cell_w[None, :].astype(jnp.float32) * mask * q * q  # (B, K)
    return gbar[:, None].astype(jnp.float32) * (-2.0) * jnp.einsum("bk,bkd->bd", factor, diff)

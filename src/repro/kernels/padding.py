"""Shared padding helpers for the transposed-layout kernels.

The TPU-adapted kernels (``cauchy_mean``, ``frozen_attract``) stream their
large axis on lanes, so public ops pad the minor axis up to the tile
multiple before ``pallas_call`` and slice the result back.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_minor(a: jax.Array, mult: int, fill=0) -> jax.Array:
    """Pad the last axis of ``a`` up to a multiple of ``mult`` with ``fill``."""
    pad = (-a.shape[-1]) % mult
    if pad:
        filler = jnp.full(a.shape[:-1] + (pad,), fill, a.dtype)
        a = jnp.concatenate([a, filler], axis=-1)
    return a

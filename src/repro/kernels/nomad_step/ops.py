"""Public op + registry spec: ``nomad_step_fused`` with a custom VJP.

The whole per-step NOMAD loss (attraction + mean repulsion + exact in-cell
negatives) as ONE registry kernel. Differentiable in (θ_i, θ_pos, θ_neg)
only — by the paper's design the edge weights are data, the cell weights
are statistics, and the means refresh by all-gather, never by gradient
flow; the VJP returns ``None`` for all of them.

The forward saves the online-accumulated repulsive mass m (1, B') as a
residual so the backward never replays the K sweep before its gradient
tiles; both directions are Pallas kernels over the same (bb, bk) tiling
(one cached ``custom_vjp`` instance per static (bb, bk, interpret) triple,
so the pair stays consistent under autodiff).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.nomad_step.nomad_step import (
    nomad_step_bwd_pallas,
    nomad_step_fwd_pallas,
)
from repro.kernels.nomad_step.ref import nomad_step_ref
from repro.kernels.padding import pad_minor as _pad_minor

DEFAULT_BB, DEFAULT_BK = 512, 1024


@functools.lru_cache(maxsize=None)
def _build_op(bb_max: int, bk_max: int, interpret: bool):
    """One custom-vjp op per static (bb, bk, interpret) configuration."""

    def _prep(theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell):
        B, d = theta_i.shape
        k, S = pos_w.shape[1], neg_w.shape[1]
        bb = min(bb_max, max(B, 8))
        bk = min(bk_max, max(means.shape[0], 128))
        th = _pad_minor(theta_i.astype(jnp.float32).T, bb)  # (d, B')
        # (B, k, d) → (k, d, B) → (k·d, B'): row j·d + dd = component dd of tail j
        pos = _pad_minor(
            jnp.transpose(theta_pos.astype(jnp.float32), (1, 2, 0)).reshape(k * d, B), bb
        )
        pw = _pad_minor(pos_w.astype(jnp.float32).T, bb)  # (k, B') pad w=0
        neg = _pad_minor(
            jnp.transpose(theta_neg.astype(jnp.float32), (1, 2, 0)).reshape(S * d, B), bb
        )
        nw = _pad_minor(neg_w.astype(jnp.float32).T, bb)  # (S, B') pad w=0
        mu = _pad_minor(means.astype(jnp.float32).T, bk)  # (d, K')
        cw = _pad_minor(cell_w.astype(jnp.float32)[None, :], bk)  # (1, K') pad w=0
        own = _pad_minor(own_cell.astype(jnp.int32)[None, :], bb, fill=-1)
        return th, pos, pw, neg, nw, mu, cw, own, bb, bk, B

    @jax.custom_vjp
    def op(theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell):
        loss, _ = _fwd(theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell)
        return loss

    def _fwd(theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell):
        th, pos, pw, neg, nw, mu, cw, own, bb, bk, B = _prep(
            theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell
        )
        loss, m = nomad_step_fwd_pallas(
            th, pos, pw, neg, nw, mu, cw, own, bb=bb, bk=bk, interpret=interpret
        )
        res = (theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell, m)
        return loss[0, :B], res

    def _bwd(res, gbar):
        theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell, m = res
        th, pos, pw, neg, nw, mu, cw, own, bb, bk, B = _prep(
            theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell
        )
        gb = _pad_minor(gbar.astype(jnp.float32)[None, :], bb)
        gi, gpos, gneg = nomad_step_bwd_pallas(
            th, pos, pw, neg, nw, mu, cw, own, m, gb, bb=bb, bk=bk, interpret=interpret
        )
        d, k, S = theta_i.shape[1], pos_w.shape[1], neg_w.shape[1]
        g_i = gi[:, :B].T.astype(theta_i.dtype)  # (B, d)
        g_pos = gpos[:, :B].reshape(k, d, B).transpose(2, 0, 1).astype(theta_pos.dtype)
        g_neg = gneg[:, :B].reshape(S, d, B).transpose(2, 0, 1).astype(theta_neg.dtype)
        return (g_i, g_pos, None, g_neg, None, None, None, None)

    op.defvjp(_fwd, _bwd)
    return op


def nomad_step_fused(
    theta_i,
    theta_pos,
    pos_w,
    theta_neg,
    neg_w,
    means,
    cell_w,
    own_cell,
    *,
    bb: int = DEFAULT_BB,
    bk: int = DEFAULT_BK,
    interpret: bool | None = None,
):
    """Per-head NOMAD step loss (B,), one tiled pass. Differentiable in
    (θ_i, θ_pos, θ_neg) only (custom VJP); online accumulation over
    (bb, bk) tiles — no (B, k+S) or (B, K) HBM intermediate."""
    if interpret is None:
        interpret = registry.interpret_default()
    return _build_op(bb, bk, interpret)(
        theta_i, theta_pos, pos_w, theta_neg, neg_w, means, cell_w, own_cell
    )


# ---------------------------------------------------------------------------
# Registry spec
# ---------------------------------------------------------------------------


def _pallas_adapter(*args, tiles, interpret):
    return nomad_step_fused(
        *args,
        bb=tiles.get("bb", DEFAULT_BB),
        bk=tiles.get("bk", DEFAULT_BK),
        interpret=interpret,
    )


def _make_inputs(key, sig):
    (ts, tdt), (ps, pdt), (ws, wdt), (ns, ndt), (nws, nwdt), (ms, mdt), (cs, cdt), (os_, odt) = sig
    K = ms[0]
    ks = jax.random.split(key, 8)
    theta = jax.random.normal(ks[0], ts, tdt) * 3.0
    pos = jax.random.normal(ks[1], ps, pdt) * 3.0
    pw = jax.random.uniform(ks[2], ws, wdt)
    neg = jax.random.normal(ks[3], ns, ndt) * 3.0
    nw = jax.random.uniform(ks[4], nws, nwdt)
    means = jax.random.normal(ks[5], ms, mdt) * 3.0
    cw = jax.random.uniform(ks[6], cs, cdt)
    own = jax.random.randint(ks[7], os_, 0, K, odt)
    return theta, pos, pw, neg, nw, means, cw, own


def _sig(B, k, S, K, d, dt="float32"):
    return (
        ((B, d), dt),
        ((B, k, d), dt),
        ((B, k), dt),
        ((B, S, d), dt),
        ((B, S), dt),
        ((K, d), dt),
        ((K,), dt),
        ((B,), "int32"),
    )


def _cost_model(sig):
    """Forward-pass cost: FLOPs of the three affinity families + streamed
    bytes (loss + m out; everything else in once)."""
    (B, d) = sig[0][0]
    k = sig[2][0][1]
    S = sig[4][0][1]
    K = sig[5][0][0]
    flops = float(B) * (K * (3 * d + 4) + (k + S) * (3 * d + 12))
    bytes_ = 4.0 * (
        B * d + B * k * d + B * k + B * S * d + B * S + K * d + K + B + 2 * B
    )
    return {"flops": flops, "bytes": bytes_}


SPEC = registry.register(
    registry.KernelSpec(
        name="nomad_step",
        ref=nomad_step_ref,
        pallas=_pallas_adapter,
        tile_candidates=(
            {"bb": 256, "bk": 512},
            {"bb": 512, "bk": 512},
            {"bb": 512, "bk": 1024},
            {"bb": 1024, "bk": 512},
        ),
        default_tiles={
            "": {"bb": DEFAULT_BB, "bk": DEFAULT_BK},
            "tpu": {"bb": DEFAULT_BB, "bk": DEFAULT_BK},
        },
        make_inputs=_make_inputs,
        check_shapes=(
            _sig(512, 15, 16, 64, 2),
            _sig(100, 5, 4, 33, 2),  # ragged B and K exercise pad_minor
            _sig(64, 3, 8, 100, 3),
            _sig(777, 15, 16, 130, 2),
        ),
        bench_shapes=_sig(2048, 15, 16, 1024, 2),
        tol=(2e-5, 2e-5),
        cost_model=_cost_model,
    )
)

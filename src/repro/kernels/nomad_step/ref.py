"""Pure-jnp oracle for the fused NOMAD SGD-step loss (Eq. 3–5, per head).

This is the *legacy multi-pass path*, preserved verbatim as the jnp impl
and the differential oracle: the mean term is the ``cauchy_mean`` oracle,
the contrastive reduction is the same expression ``losses.contrastive_loss``
used before the fusion — so ``impl="jnp"`` through the registry is
bit-equal to the pre-fusion epoch step, and ordinary AD through this
function is the gradient oracle the fused custom VJP is tested against.

    loss_b = Σ_s pos_w[b,s] · (log(q_pos + m_b) − log q_pos)
    m_b    = M̃_b + M_b
    M̃_b   = Σ_r cell_w[r] · [r ≠ own(b)] · q(θ_b, μ_r)   (means stop-gradded)
    M_b    = Σ_s neg_w[b,s] · q(θ_b, θ_neg[b,s])

Returns the per-head loss (B,); callers take ``jnp.mean``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cauchy_mean.ref import cauchy_weighted_sum_ref


def nomad_step_ref(
    theta_i,  # (B, d) head positions
    theta_pos,  # (B, k, d) positive (kNN) tail positions
    pos_w,  # (B, k) p(j|i) weights (0 ⇒ edge absent)
    theta_neg,  # (B, S, d) exact in-cell negative samples
    neg_w,  # (B, S) importance weights
    means,  # (K, d) cell means (stop-gradded here — refreshed by epoch, not AD)
    cell_w,  # (K,) |M|·p(m∈r) weights (0 at padded / excluded cells)
    own_cell,  # (B,) global cell id of each head (its mean is excluded from M̃)
):
    th = theta_i.astype(jnp.float32)
    mu = jax.lax.stop_gradient(means.astype(jnp.float32))
    m_tilde = cauchy_weighted_sum_ref(th, mu, cell_w, own_cell)  # (B,)
    # identical op sequence to core.cauchy.cauchy + losses.contrastive_loss
    d2_pos = jnp.sum(jnp.square(th[:, None, :] - theta_pos.astype(jnp.float32)), axis=-1)
    q_pos = 1.0 / (1.0 + d2_pos)  # (B, k)
    d2_neg = jnp.sum(jnp.square(th[:, None, :] - theta_neg.astype(jnp.float32)), axis=-1)
    q_neg = 1.0 / (1.0 + d2_neg)  # (B, S)
    m_exact = jnp.sum(neg_w.astype(jnp.float32) * q_neg, axis=-1)  # (B,)
    denom = q_pos + (m_tilde + m_exact)[:, None]
    per_edge = jnp.log(q_pos) - jnp.log(denom)
    return -jnp.sum(pos_w.astype(jnp.float32) * per_edge, axis=-1)

"""Fused NOMAD SGD-step Pallas TPU kernels (forward + backward).

One tiled pass per step computes everything the θ update needs: pairwise
distances to the k positives and S exact negatives, Cauchy weights, the
B×K mean-repulsion term, and the per-head loss — the flash-attention
trick applied to Eq. 3: the repulsive mass m_b = M̃_b + M_b is accumulated
*online* across K-tiles (grid dim 1), so the (B, k+S) affinity block and
the (B, K) mean-term block never materialise in HBM. Only θ (d×B), the
positive/negative blocks (k·d×B / S·d×B), their weights, μ (d×K) and the
cell weights stream in; loss (1×B) and m (1×B, the backward's residual)
stream out.

Layout (same TPU adaptation as ``cauchy_mean``/``frozen_attract``):
everything crosses the kernel transposed with the large B (and K) axis on
lanes; the tiny static k, S and d axes are flattened as (k·d, B) rows
s·d + dd and fully unrolled.

Schedule (grid = (B//bb, K//bk), kstep = program_id(1) iterates fastest):

  kstep 0        zero-init m; (+ backward: write attraction & exact-neg
                 gradient parts, which don't depend on the K tile)
  every kstep    m += Σ_r cell_w·[r≠own]·q(θ, μ_r) over this bk tile
                 (+ backward: g_i += mean-term gradient of this tile)
  last kstep     m += Σ_s neg_w·q(θ, θ_neg)  (exact in-cell negatives),
                 then loss = Σ_s pos_w·(log(q_pos + m) + log1p(d2_pos))

The backward takes m as a residual (saved by the forward), so the online
accumulation never has to be replayed before the gradient tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist2_tile(th, mu, d):
    """th (d, bb), mu (d, bk) → (bb, bk) squared distances (d unrolled)."""
    acc = None
    for dd in range(d):
        diff = th[dd, :, None] - mu[dd, None, :]
        acc = diff * diff if acc is None else acc + diff * diff
    return acc


def _flat_dist2(th, flat_ref, j, d):
    """th (d, bb) vs row-block j of a (n·d, bb) flattened tensor → (diffs, d2)."""
    diffs, d2 = [], None
    for dd in range(d):
        diff = th[dd, :] - flat_ref[j * d + dd, :]
        diffs.append(diff)
        d2 = diff * diff if d2 is None else d2 + diff * diff
    return diffs, d2


def _fwd_kernel(
    th_ref, pos_ref, pw_ref, neg_ref, nw_ref, mu_ref, cw_ref, own_ref,
    loss_ref, m_ref, *, d, k, s, bk, nk,
):
    kstep = pl.program_id(1)

    @pl.when(kstep == 0)
    def _init():
        m_ref[...] = jnp.zeros_like(m_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    th = th_ref[...]  # (d, bb)
    mu = mu_ref[...]  # (d, bk)
    q = 1.0 / (1.0 + _dist2_tile(th, mu, d))  # (bb, bk)
    bb = th.shape[1]
    r_ids = kstep * bk + jax.lax.broadcasted_iota(jnp.int32, (bb, bk), 1)
    own = own_ref[...]  # (1, bb)
    mask = (own[0, :, None] != r_ids).astype(jnp.float32)
    w = cw_ref[...][0, None, :]  # (1, bk)
    m_ref[0, :] += jnp.sum(q * w * mask, axis=1)  # online M̃ accumulation

    @pl.when(kstep == nk - 1)
    def _finish():
        m = m_ref[0, :]
        for j in range(s):  # exact in-cell negatives: M
            _, d2 = _flat_dist2(th, neg_ref, j, d)
            m += nw_ref[...][j, :] * (1.0 / (1.0 + d2))
        m_ref[0, :] = m
        acc = jnp.zeros_like(m)
        for j in range(k):  # attraction + shared log-denominator
            _, d2 = _flat_dist2(th, pos_ref, j, d)
            qp = 1.0 / (1.0 + d2)
            acc += pw_ref[...][j, :] * (jnp.log(qp + m) + jnp.log1p(d2))
        loss_ref[0, :] = acc


def _bwd_kernel(
    th_ref, pos_ref, pw_ref, neg_ref, nw_ref, mu_ref, cw_ref, own_ref,
    m_ref, gbar_ref, gi_ref, gpos_ref, gneg_ref, *, d, k, s, bk,
):
    kstep = pl.program_id(1)
    th = th_ref[...]  # (d, bb)
    m = m_ref[...][0, :]  # (bb,) — the forward's residual (full M̃ + M)
    gbar = gbar_ref[...][0, :]

    # G_b = ∂loss_b/∂m_b = Σ_j pw_j/(q_pj + m) — k is tiny and unrolled, so
    # recomputing it per K-tile is cheaper than a cross-tile carry.
    pw = pw_ref[...]
    pos_terms = []
    G = None
    for j in range(k):
        diffs, d2 = _flat_dist2(th, pos_ref, j, d)
        qp = 1.0 / (1.0 + d2)
        qpm = qp + m
        pos_terms.append((diffs, qp, qpm))
        contrib = pw[j, :] / qpm
        G = contrib if G is None else G + contrib

    @pl.when(kstep == 0)
    def _first():
        # attraction (∂ via q_pos) + exact negatives (∂ via m): K-independent
        gi = [jnp.zeros_like(m) for _ in range(d)]
        for j in range(k):
            diffs, qp, qpm = pos_terms[j]
            factor = pw[j, :] * (qp - qp * qp / qpm)
            for dd in range(d):
                gi[dd] += factor * diffs[dd]
                gpos_ref[j * d + dd, :] = -2.0 * gbar * factor * diffs[dd]
        nw = nw_ref[...]
        for j in range(s):
            diffs, d2 = _flat_dist2(th, neg_ref, j, d)
            qn = 1.0 / (1.0 + d2)
            coef = G * nw[j, :] * qn * qn
            for dd in range(d):
                gneg_ref[j * d + dd, :] = 2.0 * gbar * coef * diffs[dd]
                gi[dd] -= coef * diffs[dd]
        for dd in range(d):
            gi_ref[dd, :] = 2.0 * gbar * gi[dd]

    # mean-term gradient of this K tile, accumulated online into g_i
    mu = mu_ref[...]
    q = 1.0 / (1.0 + _dist2_tile(th, mu, d))
    bb = th.shape[1]
    r_ids = kstep * bk + jax.lax.broadcasted_iota(jnp.int32, (bb, bk), 1)
    own = own_ref[...]
    mask = (own[0, :, None] != r_ids).astype(jnp.float32)
    factor = cw_ref[...][0, None, :] * mask * q * q  # (bb, bk)
    for dd in range(d):
        diff = th[dd, :, None] - mu[dd, None, :]
        gi_ref[dd, :] += -2.0 * gbar * G * jnp.sum(factor * diff, axis=1)


def _grids(B, K, bb, bk):
    assert B % bb == 0 and K % bk == 0, (B, K, bb, bk)
    return (B // bb, K // bk)


def nomad_step_fwd_pallas(
    th, pos, pw, neg, nw, mu, cw, own, *, bb=512, bk=1024, interpret=True
):
    """th (d,B), pos (k·d,B), pw (k,B), neg (S·d,B), nw (S,B), mu (d,K),
    cw (1,K), own (1,B) → (loss (1,B), m (1,B))."""
    d, B = th.shape
    k, s = pw.shape[0], nw.shape[0]
    K = mu.shape[1]
    bb, bk = min(bb, B), min(bk, K)
    grid = _grids(B, K, bb, bk)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, d=d, k=k, s=s, bk=bk, nk=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((k * d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((k, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((s * d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((s, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((d, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((1, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, B), jnp.float32),
            jax.ShapeDtypeStruct((1, B), jnp.float32),
        ],
        interpret=interpret,
    )(th, pos, pw, neg, nw, mu, cw, own)


def nomad_step_bwd_pallas(
    th, pos, pw, neg, nw, mu, cw, own, m, gbar, *, bb=512, bk=1024, interpret=True
):
    """Adds m (1,B) residual + gbar (1,B): returns (g_i (d,B),
    g_pos (k·d,B), g_neg (S·d,B))."""
    d, B = th.shape
    k, s = pw.shape[0], nw.shape[0]
    K = mu.shape[1]
    bb, bk = min(bb, B), min(bk, K)
    grid = _grids(B, K, bb, bk)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, d=d, k=k, s=s, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((k * d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((k, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((s * d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((s, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((d, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((1, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((1, bb), lambda i, kk: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((k * d, bb), lambda i, kk: (0, i)),
            pl.BlockSpec((s * d, bb), lambda i, kk: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, B), jnp.float32),
            jax.ShapeDtypeStruct((k * d, B), jnp.float32),
            jax.ShapeDtypeStruct((s * d, B), jnp.float32),
        ],
        interpret=interpret,
    )(th, pos, pw, neg, nw, mu, cw, own, m, gbar)

"""Blocked pairwise squared-distance Pallas TPU kernel.

``dist²(x, y) = ‖x‖² + ‖y‖² − 2·x·yᵀ`` — the cross term is a matmul, so the
kernel rides the MXU; the norms are cheap VPU epilogues. This is the
hot inner loop of both the within-cluster exact kNN (paper §3.2) and the
K-means E-step.

Grid: (N/bn, M/bm, D/bd) with accumulation over the D axis; the norm
epilogue fires on the last D step. Block sizes default to MXU-aligned
(128×…) tiles; the D tile keeps x/y slabs within a VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, out_ref, *, n_d_steps: int):
    d_step = pl.program_id(2)

    @pl.when(d_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # (bn, bd)
    y = y_ref[...]  # (bm, bd)
    # accumulate ‖x‖² + ‖y‖² − 2 x yᵀ piecewise over D
    cross = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    x2 = jnp.sum(jnp.square(x), axis=1, keepdims=True)  # (bn, 1)
    y2 = jnp.sum(jnp.square(y), axis=1, keepdims=True).T  # (1, bm)
    out_ref[...] += x2 + y2 - 2.0 * cross

    @pl.when(d_step == n_d_steps - 1)
    def _clamp():
        out_ref[...] = jnp.maximum(out_ref[...], 0.0)


def pairwise_dist2_pallas(
    x: jax.Array,
    y: jax.Array,
    *,
    block_n: int = 256,
    block_m: int = 256,
    block_d: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """x (N, D), y (M, D) fp32 → (N, M) fp32. Caller pads to block multiples."""
    n, d = x.shape
    m, _ = y.shape
    bn, bm, bd = min(block_n, n), min(block_m, m), min(block_d, d)
    assert n % bn == 0 and m % bm == 0 and d % bd == 0, (x.shape, y.shape, (bn, bm, bd))
    grid = (n // bn, m // bm, d // bd)
    return pl.pallas_call(
        functools.partial(_kernel, n_d_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((bm, bd), lambda i, j, kd: (j, kd)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, kd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), y.astype(jnp.float32))

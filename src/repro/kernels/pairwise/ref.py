"""Pure-jnp oracle for the pairwise squared-distance kernel."""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_dist2_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(N, D) × (M, D) → (N, M) squared euclidean distances, fp32."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = (
        jnp.sum(jnp.square(x), -1)[:, None]
        + jnp.sum(jnp.square(y), -1)[None, :]
        - 2.0 * (x @ y.T)
    )
    return jnp.maximum(d2, 0.0)

"""jit'd public wrapper: pads to block multiples, dispatches, slices back.

``interpret=True`` on CPU (this container); on a real TPU the same call
compiles the Mosaic kernel (set ``REPRO_PALLAS_INTERPRET=0``).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.pairwise.pairwise import pairwise_dist2_pallas

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _pad_rows(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[0]) % mult
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
    return a


def _pad_cols(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[1]) % mult
    if pad:
        a = jnp.concatenate([a, jnp.zeros((a.shape[0], pad), a.dtype)], axis=1)
    return a


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "block_d"))
def pairwise_dist2(
    x: jax.Array,
    y: jax.Array,
    block_n: int = 256,
    block_m: int = 256,
    block_d: int = 512,
) -> jax.Array:
    """(N, D) × (M, D) → (N, M) fp32 squared distances (padding-safe)."""
    n, m = x.shape[0], y.shape[0]
    bn, bm = min(block_n, max(n, 8)), min(block_m, max(m, 128))
    xp = _pad_cols(_pad_rows(x.astype(jnp.float32), bn), block_d)
    yp = _pad_cols(_pad_rows(y.astype(jnp.float32), bm), block_d)
    bd = min(block_d, xp.shape[1])
    out = pairwise_dist2_pallas(
        xp, yp, block_n=bn, block_m=bm, block_d=bd, interpret=INTERPRET
    )
    return out[:n, :m]

"""Public op + registry spec for the blocked pairwise-distance kernel.

The jit'd wrapper pads to block multiples, dispatches the Pallas kernel,
and slices back. ``interpret=None`` resolves via the registry policy
(interpret on CPU, compiled on real hardware, ``REPRO_PALLAS_INTERPRET``
overrides).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.pairwise.pairwise import pairwise_dist2_pallas
from repro.kernels.pairwise.ref import pairwise_dist2_ref


def _pad_rows(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[0]) % mult
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
    return a


def _pad_cols(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[1]) % mult
    if pad:
        a = jnp.concatenate([a, jnp.zeros((a.shape[0], pad), a.dtype)], axis=1)
    return a


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "block_d", "interpret")
)
def _pairwise_dist2_padded(x, y, block_n, block_m, block_d, interpret):
    n, m = x.shape[0], y.shape[0]
    bn, bm = min(block_n, max(n, 8)), min(block_m, max(m, 128))
    xp = _pad_cols(_pad_rows(x.astype(jnp.float32), bn), block_d)
    yp = _pad_cols(_pad_rows(y.astype(jnp.float32), bm), block_d)
    bd = min(block_d, xp.shape[1])
    out = pairwise_dist2_pallas(
        xp, yp, block_n=bn, block_m=bm, block_d=bd, interpret=interpret
    )
    return out[:n, :m]


def pairwise_dist2(
    x: jax.Array,
    y: jax.Array,
    block_n: int = 256,
    block_m: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """(N, D) × (M, D) → (N, M) fp32 squared distances (padding-safe)."""
    if interpret is None:
        interpret = registry.interpret_default()
    return _pairwise_dist2_padded(x, y, block_n, block_m, block_d, interpret)


# ---------------------------------------------------------------------------
# Registry spec
# ---------------------------------------------------------------------------


def _pallas_adapter(x, y, *, tiles, interpret):
    return pairwise_dist2(
        x,
        y,
        block_n=tiles.get("block_n", 256),
        block_m=tiles.get("block_m", 256),
        block_d=tiles.get("block_d", 512),
        interpret=interpret,
    )


def _make_inputs(key, sig):
    (xs, xdt), (ys, ydt) = sig
    kx, ky = jax.random.split(key)
    return jax.random.normal(kx, xs, xdt), jax.random.normal(ky, ys, ydt)


def _sig(n, m, d, dt="float32"):
    return (((n, d), dt), ((m, d), dt))


def _cost_model(sig):
    (n, d) = sig[0][0]
    m = sig[1][0][0]
    flops = 2.0 * n * m * d + 4.0 * n * m  # ‖x‖²+‖y‖²−2xy expansion
    bytes_ = 4.0 * (n * d + m * d + n * m)
    return {"flops": flops, "bytes": bytes_}


SPEC = registry.register(
    registry.KernelSpec(
        name="pairwise",
        ref=pairwise_dist2_ref,
        pallas=_pallas_adapter,
        tile_candidates=(
            {"block_n": 128, "block_m": 128, "block_d": 256},
            {"block_n": 256, "block_m": 256, "block_d": 256},
            {"block_n": 256, "block_m": 256, "block_d": 512},
            {"block_n": 512, "block_m": 256, "block_d": 512},
        ),
        default_tiles={
            "": {"block_n": 256, "block_m": 256, "block_d": 512},
            "tpu": {"block_n": 256, "block_m": 256, "block_d": 512},
        },
        make_inputs=_make_inputs,
        check_shapes=(
            _sig(96, 128, 64),
            _sig(100, 60, 33),
            _sig(8, 257, 128),
            _sig(64, 64, 16, "bfloat16"),
        ),
        bench_shapes=_sig(1024, 1024, 256),
        tol=(2e-5, 2e-5),
        cost_model=_cost_model,
    )
)

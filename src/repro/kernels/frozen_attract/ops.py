"""Public op + registry spec: ``frozen_attract`` with a custom VJP.

The one-sided serve update: both directions are Pallas kernels, and the
cotangents stop at (θ_q, m) — neighbor positions and edge weights are
frozen by design, so the VJP returns nothing for them and the map can
never be perturbed by a query. ``m`` keeps its gradient because the
repulsive mass is itself a function of θ_q (via ``cauchy_mean``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.frozen_attract.frozen_attract import (
    frozen_attract_bwd_pallas,
    frozen_attract_fwd_pallas,
)
from repro.kernels.frozen_attract.ref import frozen_attract_ref
from repro.kernels.padding import pad_minor as _pad_minor

DEFAULT_BB = 512


@functools.lru_cache(maxsize=None)
def _build_op(bb_max: int, interpret: bool):
    """One custom-vjp op per static (bb, interpret) configuration."""

    def _prep(theta_q, nbrs, w, m):
        B, d = theta_q.shape
        k = w.shape[1]
        bb = min(bb_max, max(B, 8))
        th = _pad_minor(theta_q.astype(jnp.float32).T, bb)  # (d, B')
        # (B, k, d) → (k, d, B) → (k·d, B'): row s·d + dd = component dd of nbr s
        nb = _pad_minor(
            jnp.transpose(nbrs.astype(jnp.float32), (1, 2, 0)).reshape(k * d, B), bb
        )
        wt = _pad_minor(w.astype(jnp.float32).T, bb)  # (k, B') pad w=0
        mt = _pad_minor(m.astype(jnp.float32)[None, :], bb)  # (1, B')
        return th, nb, wt, mt, bb, B

    @jax.custom_vjp
    def op(theta_q, nbrs, w, m):
        loss, _ = _fwd(theta_q, nbrs, w, m)
        return loss

    def _fwd(theta_q, nbrs, w, m):
        th, nb, wt, mt, bb, B = _prep(theta_q, nbrs, w, m)
        s = frozen_attract_fwd_pallas(th, nb, wt, mt, bb=bb, interpret=interpret)
        return s[0, :B], (theta_q, nbrs, w, m)

    def _bwd(res, gbar):
        theta_q, nbrs, w, m = res
        th, nb, wt, mt, bb, B = _prep(theta_q, nbrs, w, m)
        gb = _pad_minor(gbar.astype(jnp.float32)[None, :], bb)
        gth, gm = frozen_attract_bwd_pallas(
            th, nb, wt, mt, gb, bb=bb, interpret=interpret
        )
        g_theta = gth[:, :B].T.astype(theta_q.dtype)  # (B, d)
        g_m = gm[0, :B].astype(m.dtype)
        return (g_theta, None, None, g_m)

    op.defvjp(_fwd, _bwd)
    return op


def frozen_attract(
    theta_q,
    nbrs,
    w,
    m,
    *,
    bb: int = DEFAULT_BB,
    interpret: bool | None = None,
):
    """loss_b = Σ_s w[b,s]·(log(q_bs + m_b) − log q_bs) over frozen kNN.

    Differentiable in ``theta_q`` and ``m`` only (custom VJP); fused over
    (bb,) query tiles with the k·d neighbor block unrolled in-register.
    """
    if interpret is None:
        interpret = registry.interpret_default()
    return _build_op(bb, interpret)(theta_q, nbrs, w, m)


# ---------------------------------------------------------------------------
# Registry spec
# ---------------------------------------------------------------------------


def _pallas_adapter(theta_q, nbrs, w, m, *, tiles, interpret):
    return frozen_attract(
        theta_q, nbrs, w, m, bb=tiles.get("bb", DEFAULT_BB), interpret=interpret
    )


def _make_inputs(key, sig):
    (ts, tdt), (ns, ndt), (ws, wdt), (ms, mdt) = sig
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, ts, tdt) * 3.0
    nbrs = jax.random.normal(k2, ns, ndt) * 3.0
    w = jax.random.uniform(k3, ws, wdt)
    m = jax.random.uniform(k4, ms, mdt) * 5.0
    return theta, nbrs, w, m


def _sig(B, k, d, dt="float32"):
    return (((B, d), dt), ((B, k, d), dt), ((B, k), dt), ((B,), dt))


def _cost_model(sig):
    (B, d) = sig[0][0]
    k = sig[2][0][1]
    flops = float(B) * k * (3 * d + 12)  # dist² + Cauchy + log terms
    bytes_ = 4.0 * (B * d + B * k * d + B * k + 2 * B)
    return {"flops": flops, "bytes": bytes_}


SPEC = registry.register(
    registry.KernelSpec(
        name="frozen_attract",
        ref=frozen_attract_ref,
        pallas=_pallas_adapter,
        tile_candidates=({"bb": 256}, {"bb": 512}, {"bb": 1024}),
        default_tiles={"": {"bb": DEFAULT_BB}, "tpu": {"bb": DEFAULT_BB}},
        make_inputs=_make_inputs,
        check_shapes=(
            _sig(512, 15, 2),
            _sig(64, 8, 2),
            _sig(100, 5, 3),
            _sig(777, 15, 2),
        ),
        bench_shapes=_sig(2048, 15, 2),
        tol=(1e-5, 1e-6),
        cost_model=_cost_model,
    )
)

"""Fused frozen-neighbor attraction Pallas TPU kernels (forward + backward).

This is the serve path's hot spot: every transform step evaluates each
query against its k frozen kNN positions — a (B, k) Cauchy contraction plus
the log-denominator coupling to the repulsive mass m. Fusing the affinity,
the logs and the reduction keeps the (B, k) intermediates in VREGs; only
θ (d×B), the neighbor block (k·d×B), w (k×B) and m (1×B) stream in and the
per-query loss (1×B) streams out.

Layout note (same TPU adaptation as ``cauchy_mean``): everything crosses
the kernel transposed so the large B axis is the minor (lane) axis. The
neighbor tensor is flattened to 2-D as (k·d, B) — row s·d + dd holds
component dd of neighbor s — because k and d are tiny static constants the
kernel fully unrolls over.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(theta_ref, nbrs_ref, w_ref, m_ref, out_ref, *, d, k):
    th = theta_ref[...]  # (d, bb)
    m = m_ref[...][0, :]  # (bb,)
    acc = jnp.zeros_like(m)
    for s in range(k):
        d2 = jnp.zeros_like(m)
        for dd in range(d):
            diff = th[dd, :] - nbrs_ref[s * d + dd, :]
            d2 += diff * diff
        q = 1.0 / (1.0 + d2)
        acc += w_ref[...][s, :] * (jnp.log(q + m) + jnp.log1p(d2))
    out_ref[0, :] = acc


def _bwd_kernel(theta_ref, nbrs_ref, w_ref, m_ref, gbar_ref, gth_ref, gm_ref, *, d, k):
    th = theta_ref[...]
    m = m_ref[...][0, :]
    gbar = gbar_ref[...][0, :]
    gth = [jnp.zeros_like(m) for _ in range(d)]
    gm = jnp.zeros_like(m)
    for s in range(k):
        diffs = []
        d2 = jnp.zeros_like(m)
        for dd in range(d):
            diff = th[dd, :] - nbrs_ref[s * d + dd, :]
            diffs.append(diff)
            d2 += diff * diff
        q = 1.0 / (1.0 + d2)
        qm = q + m
        w = w_ref[...][s, :]
        factor = w * (q - q * q / qm)
        for dd in range(d):
            gth[dd] += factor * diffs[dd]
        gm += w / qm
    for dd in range(d):
        gth_ref[dd, :] = 2.0 * gbar * gth[dd]
    gm_ref[0, :] = gbar * gm


def frozen_attract_fwd_pallas(theta_t, nbrs_t, w_t, m, *, bb=512, interpret=True):
    """theta_t (d, B), nbrs_t (k·d, B), w_t (k, B), m (1, B) → loss (1, B)."""
    d, B = theta_t.shape
    k = w_t.shape[0]
    bb = min(bb, B)
    assert B % bb == 0, (B, bb)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, d=d, k=k),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((d, bb), lambda i: (0, i)),
            pl.BlockSpec((k * d, bb), lambda i: (0, i)),
            pl.BlockSpec((k, bb), lambda i: (0, i)),
            pl.BlockSpec((1, bb), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, bb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.float32),
        interpret=interpret,
    )(theta_t, nbrs_t, w_t, m)


def frozen_attract_bwd_pallas(theta_t, nbrs_t, w_t, m, gbar, *, bb=512, interpret=True):
    """Adds gbar (1, B): returns (gθ (d, B), gm (1, B))."""
    d, B = theta_t.shape
    k = w_t.shape[0]
    bb = min(bb, B)
    assert B % bb == 0, (B, bb)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, d=d, k=k),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((d, bb), lambda i: (0, i)),
            pl.BlockSpec((k * d, bb), lambda i: (0, i)),
            pl.BlockSpec((k, bb), lambda i: (0, i)),
            pl.BlockSpec((1, bb), lambda i: (0, i)),
            pl.BlockSpec((1, bb), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((d, bb), lambda i: (0, i)),
            pl.BlockSpec((1, bb), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, B), jnp.float32),
            jax.ShapeDtypeStruct((1, B), jnp.float32),
        ],
        interpret=interpret,
    )(theta_t, nbrs_t, w_t, m, gbar)

"""Pure-jnp oracle for the fused frozen-neighbor attraction (fwd + vjp).

The serve-side loss of one query b against its *frozen* kNN:

    loss_b = Σ_s w[b,s] · (log(q_bs + m_b) − log q_bs),
    q_bs   = 1 / (1 + ‖θ_b − nb_bs‖²)

— the attractive half of the NOMAD objective with the repulsive mass m_b
(M̃ + M, already reduced) entering only through the shared denominator.
Gradients flow to θ_b and m_b; the neighbor positions and weights are
frozen by construction (out-of-sample extension never moves the map).
"""

from __future__ import annotations

import jax.numpy as jnp


def frozen_attract_ref(theta_q, nbrs, w, m):
    """theta_q (B, d), nbrs (B, k, d), w (B, k), m (B,) → loss (B,) fp32.

    Uses log q = −log1p(‖θ−nb‖²) so q never underflows the log.
    """
    th = theta_q.astype(jnp.float32)
    nb = nbrs.astype(jnp.float32)
    d2 = jnp.sum(jnp.square(th[:, None, :] - nb), axis=-1)  # (B, k)
    q = 1.0 / (1.0 + d2)
    per_edge = jnp.log(q + m.astype(jnp.float32)[:, None]) + jnp.log1p(d2)
    return jnp.sum(w.astype(jnp.float32) * per_edge, axis=-1)


def frozen_attract_vjp_ref(theta_q, nbrs, w, m, gbar):
    """Hand-written cotangents (the Pallas backward's oracle).

    ∂loss_b/∂θ_b = 2·Σ_s w·(θ_b − nb_bs)·(q − q²/(q+m))
    ∂loss_b/∂m_b = Σ_s w / (q_bs + m_b)
    Returns (g_theta (B, d), g_m (B,)).
    """
    th = theta_q.astype(jnp.float32)
    nb = nbrs.astype(jnp.float32)
    diff = th[:, None, :] - nb  # (B, k, d)
    d2 = jnp.sum(jnp.square(diff), axis=-1)
    q = 1.0 / (1.0 + d2)
    qm = q + m.astype(jnp.float32)[:, None]
    wf = w.astype(jnp.float32)
    factor = wf * (q - q * q / qm)  # (B, k)
    g_theta = 2.0 * gbar[:, None].astype(jnp.float32) * jnp.einsum(
        "bk,bkd->bd", factor, diff
    )
    g_m = gbar.astype(jnp.float32) * jnp.sum(wf / qm, axis=-1)
    return g_theta, g_m

"""First-call tile-size autotuner (v2) with a bucketed, source-keyed cache.

For each (kernel, backend, shape-*bucket*) the tuner times every candidate
in the spec's small tile grid on synthesized inputs and records the winner:

* in-process  — a dict, so a jitted trace asks at most once per bucket;
* on disk     — JSON at ``$REPRO_TUNE_CACHE`` (default
  ``~/.cache/repro/kernel_tune.json``), so winners survive across runs and
  can be shipped with a deployment.

v2 cache semantics:

* **Shape buckets.** Dimensions ≤ 128 key exactly; larger dimensions round
  up to the next power of two. N = 49k and N = 50k land in the same bucket
  (65536) and share one sweep — tile winners are a function of tiling
  regime, not of the exact row count, and per-exact-shape entries made the
  cache grow without bound on ragged workloads. Sweeps run at the bucketed
  shape (``pad_minor`` in every kernel makes any shape legal).
* **Source-hash invalidation.** Each entry records a hash of the kernel
  package's ``.py`` sources; entries whose hash no longer matches are
  ignored at load (a kernel edit re-tunes instead of serving stale tiles).
* **Versioned envelope** ``{"version": 2, "entries": {...}}``. Corrupt,
  truncated or legacy-v1 files are ignored wholesale and rewritten on the
  next store; stores are read-modify-write with an atomic replace, so two
  racing processes each leave a valid file (last writer wins).

The sweep runs *eagerly* on freshly synthesized concrete inputs (from
``spec.make_inputs``), which makes it legal to trigger from inside a jit
trace: tracers only contribute their static shape signature, never data.
``sweep(..., report=True)`` additionally returns every candidate's wall
time for the achieved-vs-roofline report in ``benchmarks/kernel_micro.py``.

Enablement policy (``REPRO_AUTOTUNE``): "1" forces tuning on, "0" forces it
off; unset ⇒ tune only when the Pallas path actually compiles (i.e. not in
interpret mode) — interpret-mode wall-times say nothing about Mosaic, so
CPU CI silently falls back to the spec's per-backend default tiles.
"""

from __future__ import annotations

import functools
import hashlib
import json
import os
import sys
import tempfile
import time
from typing import Mapping, Optional

import jax

from repro.kernels.registry import KernelSpec, ShapeSig, backend, interpret_default

CACHE_VERSION = 2

_memory_cache: dict[str, dict] = {}
_disk_loaded_from: Optional[str] = None

_SWEEP_REPS = 3  # timed reps per candidate (after one compile/warmup call)


def cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "kernel_tune.json"),
    )


# ---------------------------------------------------------------------------
# Cache keys: shape buckets + kernel-source hash
# ---------------------------------------------------------------------------


def bucket_dim(n: int) -> int:
    """≤ 128 exact; above, the next power of two (49k and 50k → 65536)."""
    n = int(n)
    if n <= 128:
        return n
    p = 128
    while p < n:
        p *= 2
    return p


def bucket_sig(sig: ShapeSig) -> ShapeSig:
    """Bucket every dimension of every argument (dtypes key exactly)."""
    return tuple((tuple(bucket_dim(d) for d in shape), dt) for shape, dt in sig)


def cache_key(name: str, back: str, sig: ShapeSig) -> str:
    return f"{name}|{back}|{bucket_sig(sig)!r}"


@functools.lru_cache(maxsize=None)
def _dir_source_hash(pkg_dir: str) -> str:
    h = hashlib.sha256()
    try:
        for fn in sorted(os.listdir(pkg_dir)):
            if fn.endswith(".py"):
                with open(os.path.join(pkg_dir, fn), "rb") as f:
                    h.update(fn.encode())
                    h.update(f.read())
    except OSError:
        return "unknown"
    return h.hexdigest()[:16]


def source_hash(spec: KernelSpec) -> str:
    """Hash of the kernel package's ``.py`` sources (cache-entry validity)."""
    if spec.pallas is None:
        return "jnp-only"
    mod = sys.modules.get(spec.pallas.__module__)
    mod_file = getattr(mod, "__file__", None)
    if not mod_file:
        return "unknown"
    return _dir_source_hash(os.path.dirname(os.path.abspath(mod_file)))


def autotune_enabled() -> bool:
    env = os.environ.get("REPRO_AUTOTUNE")
    if env is not None:
        return env != "0"
    return not interpret_default()


# ---------------------------------------------------------------------------
# Disk cache
# ---------------------------------------------------------------------------


def _load_disk() -> None:
    """Merge valid on-disk entries into memory (once per path).

    Anything unusable — unreadable/corrupt JSON, a legacy v1 flat dict, a
    foreign version, entries for unregistered kernels, entries whose
    recorded source hash no longer matches the kernel package — is simply
    skipped; the next winner store rewrites the file in v2 form.
    """
    global _disk_loaded_from
    path = cache_path()
    if _disk_loaded_from == path:
        return
    _disk_loaded_from = path
    try:
        with open(path) as f:
            on_disk = json.load(f)
    except (OSError, ValueError):
        return
    if not isinstance(on_disk, dict) or on_disk.get("version") != CACHE_VERSION:
        return
    entries = on_disk.get("entries")
    if not isinstance(entries, dict):
        return
    from repro.kernels import registry

    for k, v in entries.items():
        if not isinstance(v, dict) or "tiles" not in v:
            continue
        name = str(k).split("|", 1)[0]
        try:
            spec = registry.get(name)
        except KeyError:
            continue
        if v.get("src") != source_hash(spec):
            continue
        _memory_cache.setdefault(k, v)


def _store_disk(key: str, entry: dict) -> None:
    """Read-modify-write with an atomic replace (best-effort on failure).

    The per-candidate ``"candidates"`` report never goes to disk — only
    the winner. A damaged or legacy file is replaced with a fresh v2
    envelope rather than propagated.
    """
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        try:
            with open(path) as f:
                on_disk = json.load(f)
        except (OSError, ValueError):
            on_disk = None
        if (
            not isinstance(on_disk, dict)
            or on_disk.get("version") != CACHE_VERSION
            or not isinstance(on_disk.get("entries"), dict)
        ):
            on_disk = {"version": CACHE_VERSION, "entries": {}}
        on_disk["entries"][key] = {k: v for k, v in entry.items() if k != "candidates"}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(on_disk, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS etc. — the in-memory winner still applies


def clear_memory_cache() -> None:
    """Testing hook: forget in-process winners (disk is untouched)."""
    global _disk_loaded_from
    _memory_cache.clear()
    _disk_loaded_from = None


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------


def _time_candidate(spec: KernelSpec, args: tuple, tiles: Mapping[str, int], interpret: bool) -> float:
    """Median-free min-of-reps wall time (µs) for one tile candidate."""
    run = lambda: jax.block_until_ready(spec.pallas(*args, tiles=tiles, interpret=interpret))
    run()  # compile / warm up
    best = float("inf")
    for _ in range(_SWEEP_REPS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def sweep(
    spec: KernelSpec,
    sig: ShapeSig,
    *,
    interpret: Optional[bool] = None,
    report: bool = False,
) -> dict:
    """Time every tile candidate at ``sig``; return the winning entry.

    Runs eagerly on synthesized inputs — never touches caller data.
    ``report=True`` adds a ``"candidates"`` list (every candidate's tiles
    and wall time) for roofline reporting; it is stripped before disk.
    """
    if interpret is None:
        interpret = interpret_default()
    args = spec.make_inputs(jax.random.key(0), sig)
    results = []
    for tiles in spec.tile_candidates:
        try:
            us = _time_candidate(spec, args, tiles, interpret)
        except Exception:  # noqa: BLE001 — invalid tiling for this shape
            continue
        results.append((us, dict(tiles)))
    if not results:
        entry = {"tiles": dict(spec.tiles_for_backend(backend())), "us": None}
    else:
        us, tiles = min(results, key=lambda r: r[0])
        entry = {"tiles": tiles, "us": us, "n_candidates": len(results)}
    entry["src"] = source_hash(spec)
    if report:
        entry["candidates"] = [{"tiles": t, "us": u} for u, t in results]
    return entry


def record(spec: KernelSpec, sig: ShapeSig, entry: dict) -> None:
    """Store a sweep winner (memory + disk) — e.g. from an explicit
    ``kernel_micro.py --autotune`` run warming the cache for a deployment."""
    entry = dict(entry)
    entry.setdefault("src", source_hash(spec))
    key = cache_key(spec.name, backend(), sig)
    _memory_cache[key] = entry
    _store_disk(key, entry)


def tiles_for(spec: KernelSpec, sig: ShapeSig) -> Mapping[str, int]:
    """The dispatcher's entry point: cached winner, else sweep, else defaults.

    Keys — and sweeps — at the *bucketed* signature, so every shape in a
    bucket shares one entry and one sweep.
    """
    back = backend()
    key = cache_key(spec.name, back, sig)
    _load_disk()
    entry = _memory_cache.get(key)
    if entry is None:
        if autotune_enabled():
            entry = sweep(spec, bucket_sig(sig))
            if entry.get("us") is not None:  # a failed sweep (every candidate
                _store_disk(key, entry)  # errored) must not poison the disk
        else:  # cache — retry next process
            entry = {"tiles": dict(spec.tiles_for_backend(back)), "us": None}
        _memory_cache[key] = entry
    return entry["tiles"]

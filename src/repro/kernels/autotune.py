"""First-call tile-size autotuner with an on-disk winner cache.

For each (kernel, backend, shape-signature) the tuner times every candidate
in the spec's small tile grid on synthesized inputs and records the winner:

* in-process  — a dict, so a jitted trace asks at most once per signature;
* on disk     — JSON at ``$REPRO_TUNE_CACHE`` (default
  ``~/.cache/repro/kernel_tune.json``), so winners survive across runs and
  can be shipped with a deployment.

The sweep runs *eagerly* on freshly synthesized concrete inputs (from
``spec.make_inputs``), which makes it legal to trigger from inside a jit
trace: tracers only contribute their static shape signature, never data.

Enablement policy (``REPRO_AUTOTUNE``): "1" forces tuning on, "0" forces it
off; unset ⇒ tune only when the Pallas path actually compiles (i.e. not in
interpret mode) — interpret-mode wall-times say nothing about Mosaic, so
CPU CI silently falls back to the spec's per-backend default tiles.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Mapping, Optional

import jax

from repro.kernels.registry import KernelSpec, ShapeSig, backend, interpret_default

_memory_cache: dict[str, dict] = {}
_disk_loaded_from: Optional[str] = None

_SWEEP_REPS = 3  # timed reps per candidate (after one compile/warmup call)


def cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "kernel_tune.json"),
    )


def cache_key(name: str, back: str, sig: ShapeSig) -> str:
    return f"{name}|{back}|{sig!r}"


def autotune_enabled() -> bool:
    env = os.environ.get("REPRO_AUTOTUNE")
    if env is not None:
        return env != "0"
    return not interpret_default()


# ---------------------------------------------------------------------------
# Disk cache
# ---------------------------------------------------------------------------


def _load_disk() -> None:
    """Merge the on-disk cache into memory (once per path)."""
    global _disk_loaded_from
    path = cache_path()
    if _disk_loaded_from == path:
        return
    _disk_loaded_from = path
    try:
        with open(path) as f:
            on_disk = json.load(f)
    except (OSError, ValueError):
        return
    for k, v in on_disk.items():
        _memory_cache.setdefault(k, v)


def _store_disk(key: str, entry: dict) -> None:
    """Read-modify-write with an atomic replace (best-effort on failure)."""
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        try:
            with open(path) as f:
                on_disk = json.load(f)
        except (OSError, ValueError):
            on_disk = {}
        on_disk[key] = entry
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(on_disk, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS etc. — the in-memory winner still applies


def clear_memory_cache() -> None:
    """Testing hook: forget in-process winners (disk is untouched)."""
    global _disk_loaded_from
    _memory_cache.clear()
    _disk_loaded_from = None


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------


def _time_candidate(spec: KernelSpec, args: tuple, tiles: Mapping[str, int], interpret: bool) -> float:
    """Median-free min-of-reps wall time (µs) for one tile candidate."""
    run = lambda: jax.block_until_ready(spec.pallas(*args, tiles=tiles, interpret=interpret))
    run()  # compile / warm up
    best = float("inf")
    for _ in range(_SWEEP_REPS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def sweep(spec: KernelSpec, sig: ShapeSig, *, interpret: Optional[bool] = None) -> dict:
    """Time every tile candidate at ``sig``; return the winning entry.

    Runs eagerly on synthesized inputs — never touches caller data.
    """
    if interpret is None:
        interpret = interpret_default()
    args = spec.make_inputs(jax.random.key(0), sig)
    results = []
    for tiles in spec.tile_candidates:
        try:
            us = _time_candidate(spec, args, tiles, interpret)
        except Exception:  # noqa: BLE001 — invalid tiling for this shape
            continue
        results.append((us, dict(tiles)))
    if not results:
        return {"tiles": dict(spec.tiles_for_backend(backend())), "us": None}
    us, tiles = min(results, key=lambda r: r[0])
    return {"tiles": tiles, "us": us, "n_candidates": len(results)}


def record(spec: KernelSpec, sig: ShapeSig, entry: dict) -> None:
    """Store a sweep winner (memory + disk) — e.g. from an explicit
    ``kernel_micro.py --autotune`` run warming the cache for a deployment."""
    key = cache_key(spec.name, backend(), sig)
    _memory_cache[key] = entry
    _store_disk(key, entry)


def tiles_for(spec: KernelSpec, sig: ShapeSig) -> Mapping[str, int]:
    """The dispatcher's entry point: cached winner, else sweep, else defaults."""
    back = backend()
    key = cache_key(spec.name, back, sig)
    _load_disk()
    entry = _memory_cache.get(key)
    if entry is None:
        if autotune_enabled():
            entry = sweep(spec, sig)
            if entry.get("us") is not None:  # a failed sweep (every candidate
                _store_disk(key, entry)  # errored) must not poison the disk
        else:  # cache — retry next process
            entry = {"tiles": dict(spec.tiles_for_backend(back)), "us": None}
        _memory_cache[key] = entry
    return entry["tiles"]

"""Map registry: versioned frozen maps with atomic hot swap.

A production map service outlives any single map: corpora are refit
nightly and the serving fleet must pick the new checkpoint up without
dropping traffic. ``MapRegistry`` owns that lifecycle:

* :meth:`load` — build a :class:`FrozenMap` from a checkpoint dir (or
  :meth:`add` an in-process FrozenMap / MapServer), wrap it in a
  :class:`MapServer` + :class:`Batcher`, and **warm** it (one dummy
  transform pays the jit compile *before* the version can take traffic);
* :meth:`activate` — flip the active pointer. The flip is one reference
  assignment under the registry lock: requests that already resolved the
  old handle keep it and complete on the map they started on, requests
  resolving after the flip get the new one — no request ever sees half a
  swap or rows from two maps;
* :meth:`retire` — drain the old version's batcher (in-flight requests
  finish), close it, and drop the handle;
* :meth:`swap` — load → warm → activate → retire(old), the one-call hot
  swap used by the ``POST /maps`` endpoint.

Each handle carries a content-derived ``fingerprint``
(:func:`map_fingerprint` — ``data_fingerprint`` over the frozen θ rows),
which is what the result cache keys on: a swap to a genuinely different
map invalidates by construction, while reloading identical state under a
new label keeps its warm cache.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Union

import numpy as np

from repro.serve.frozen import FrozenMap
from repro.serve.server import MapServer
from repro.service.batcher import Batcher


def map_fingerprint(frozen: FrozenMap) -> str:
    """Content hash of the served state — ``data_fingerprint`` (shape +
    row sample + column checksums) over the frozen θ rows."""
    from repro.index.ann import data_fingerprint

    return data_fingerprint(np.asarray(frozen.theta_rows))


@dataclasses.dataclass
class MapHandle:
    """One servable map version: frozen state + server + batcher, plus the
    optional inverse head (2D → embedding) when the checkpoint carried an
    ``inverse.npz`` — what the ``/explore`` endpoint decodes with."""

    version: str
    server: MapServer
    batcher: Batcher
    fingerprint: str
    source: str = "in-process"
    created_at: float = dataclasses.field(default_factory=time.time)
    inverse: Optional[object] = None  # pipeline.inverse.InverseProjection

    @property
    def frozen(self) -> FrozenMap:
        return self.server.frozen

    def describe(self) -> dict:
        fz = self.frozen
        return {
            "version": self.version,
            "fingerprint": self.fingerprint,
            "source": self.source,
            "created_at": self.created_at,
            "n_points": fz.n_points,
            "dim": fz.dim,
            "out_dim": fz.out_dim,
            "n_clusters": fz.n_clusters,
            "steps": self.server.steps,
            "strategy": self.server.strategy,
            "n_shards": self.server.n_shards,
            "microbatch": self.server.microbatch,
            "batch_rows": self.server.batch_rows,
            "has_inverse": self.inverse is not None,
        }


class MapRegistry:
    """Versioned :class:`MapHandle` store with an atomic active pointer."""

    def __init__(self):
        self._maps: Dict[str, MapHandle] = {}
        self._active: Optional[str] = None
        self._lock = threading.RLock()
        self._seq = 0

    # -- registration ----------------------------------------------------------

    def add(
        self,
        frozen_or_server: Union[FrozenMap, MapServer],
        *,
        version: Optional[str] = None,
        activate: bool = True,
        warm: bool = True,
        source: str = "in-process",
        max_delay_s: Optional[float] = None,
        inverse=None,
        **server_kw,
    ) -> MapHandle:
        """Register an already-loaded FrozenMap (or a configured MapServer).

        Warming runs one dummy single-row transform through the server so
        the jit compile is paid before :meth:`activate` exposes the
        version to traffic — a hot swap must never stall live requests on
        a cold compile. ``inverse`` optionally attaches a trained
        :class:`repro.pipeline.inverse.InverseProjection` so the version
        can serve ``/explore``.
        """
        if isinstance(frozen_or_server, MapServer):
            if server_kw:
                raise ValueError("pass server options with a FrozenMap, not a MapServer")
            server = frozen_or_server
        else:
            server = MapServer(frozen_or_server, **server_kw)
        if warm:
            server.transform(np.zeros((1, server.frozen.dim), np.float32), seed=0)
        handle = MapHandle(
            version="",
            server=server,
            batcher=Batcher(server, max_delay_s=max_delay_s),
            fingerprint=map_fingerprint(server.frozen),
            source=source,
            inverse=inverse,
        )
        with self._lock:
            if version is None:
                self._seq += 1
                version = f"v{self._seq}"
            if version in self._maps:
                handle.batcher.close(drain=False)
                raise ValueError(f"map version {version!r} already registered")
            handle.version = version
            self._maps[version] = handle
            if activate or self._active is None:
                self._active = version
        return handle

    def load(
        self,
        checkpoint_dir: str,
        *,
        version: Optional[str] = None,
        cfg=None,
        activate: bool = True,
        warm: bool = True,
        max_delay_s: Optional[float] = None,
        **server_kw,
    ) -> MapHandle:
        """Load a checkpoint dir into a servable version (θ + index cache,
        no training data — the ``FrozenMap.from_checkpoint`` path). An
        ``inverse.npz`` beside the checkpoint (the pipeline writes one) is
        picked up automatically, so a hot swap carries the explore head
        with the map."""
        from repro.pipeline.inverse import load_inverse

        frozen = FrozenMap.from_checkpoint(checkpoint_dir, cfg)
        return self.add(
            frozen,
            version=version,
            activate=activate,
            warm=warm,
            source=checkpoint_dir,
            max_delay_s=max_delay_s,
            inverse=load_inverse(checkpoint_dir, missing_ok=True),
            **server_kw,
        )

    def load_lineage(
        self,
        lineage_root: str,
        *,
        map_version: Optional[str] = None,
        version: Optional[str] = None,
        **load_kw,
    ) -> MapHandle:
        """Load a version from a ``versions.json`` lineage (the artifact
        layout ``partial_fit`` grows under one checkpoint root).

        ``map_version`` names the lineage entry (default: the newest —
        "serve the latest map"); ``version`` is the registry label it
        serves under (default: the lineage name, so a hot swap onto a
        grown map reads ``registry.load_lineage(root)`` and the service's
        ``/versions`` listing shows ``v1``, ``v2`` … matching the lineage).
        Every lineage version directory is self-contained, so this is just
        resolution + the ordinary :meth:`load`.
        """
        from repro.checkpoint.lineage import MapLineage

        v = MapLineage(lineage_root).resolve(map_version)
        return self.load(v.path, version=version or v.name, **load_kw)

    # -- resolution ------------------------------------------------------------

    def get(self, version: Optional[str] = None) -> MapHandle:
        """The handle for ``version`` (default: the active map)."""
        with self._lock:
            if version is None:
                if self._active is None:
                    raise RuntimeError(
                        "no active map — register one with load()/add() first"
                    )
                return self._maps[self._active]
            try:
                return self._maps[version]
            except KeyError:
                raise KeyError(
                    f"unknown map version {version!r} "
                    f"(have {sorted(self._maps)})"
                ) from None

    @property
    def active_version(self) -> Optional[str]:
        with self._lock:
            return self._active

    def versions(self) -> List[dict]:
        with self._lock:
            handles = list(self._maps.values())
            active = self._active
        out = [h.describe() for h in sorted(handles, key=lambda h: h.created_at)]
        for d in out:
            d["active"] = d["version"] == active
        return out

    # -- lifecycle -------------------------------------------------------------

    def activate(self, version: str) -> MapHandle:
        with self._lock:
            handle = self.get(version)
            self._active = version
            return handle

    def retire(self, version: str, *, timeout: float = 60.0) -> None:
        """Drain and drop a non-active version. In-flight requests finish
        (the batcher drains before closing); new submissions to the
        retired handle raise ``BatcherClosed``, which the service layer
        retries onto the current active map."""
        with self._lock:
            if version == self._active:
                raise ValueError(
                    f"refusing to retire the active map {version!r} — "
                    "activate a replacement first"
                )
            handle = self.get(version)
            del self._maps[version]
        handle.batcher.close(drain=True, timeout=timeout)

    def swap(
        self,
        checkpoint_dir: str,
        *,
        version: Optional[str] = None,
        retire_old: bool = True,
        timeout: float = 60.0,
        **load_kw,
    ) -> MapHandle:
        """Hot swap: load + warm the new version, flip the pointer, drain
        the old. Requests in flight on the old map complete there; nothing
        is dropped (tested under concurrent load)."""
        with self._lock:
            old = self._active
        handle = self.load(
            checkpoint_dir, version=version, activate=True, warm=True, **load_kw
        )
        if retire_old and old is not None and old != handle.version:
            self.retire(old, timeout=timeout)
        return handle

    def close(self, *, timeout: float = 60.0) -> None:
        """Drain and close every version (service shutdown)."""
        with self._lock:
            handles = list(self._maps.values())
            self._maps.clear()
            self._active = None
        for h in handles:
            h.batcher.close(drain=True, timeout=timeout)

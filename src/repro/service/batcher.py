"""The dynamic batching engine: concurrent requests → device batches.

``Batcher`` sits between the service endpoints and one
:class:`repro.serve.MapServer`. Concurrent ``project()`` calls enqueue
their rows; a single worker thread coalesces whatever is waiting into
fixed ``MapServer.batch_rows``-row device batches — holding a *partial*
batch open for at most ``max_delay_s`` in case more requests arrive —
and fans the rows of each batch back out to the requests they came from.

Correctness rests on one property of the serve layer: the jitted
transform takes **per-row seeds and per-row local row ids**, and every
row's placement depends only on its own ``(x, seed, row)`` and the frozen
state (the batch loss is a sum of per-row terms, so gradients decouple
row by row; pad rows only dilute the *reported* loss). A request is
chunked into items of at most ``batch_rows`` rows, each row keeping the
request's seed and its 0-based offset within the request — exactly the
numbering a dedicated ``MapServer.transform(q, seed=...)`` call uses. Any
interleaving of concurrent requests therefore returns placements
bit-identical to one direct transform per request (tested), with one
deliberate exception: ``TransformResult.batch_loss`` is reported as NaN
for coalesced results, because a shared batch's loss mixes rows of
several requests and cannot be attributed to one of them.

The batcher is framework-agnostic and dependency-free — the FastAPI app
drives it over HTTP, tests and the load benchmark drive it directly.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import List, Optional

import numpy as np

from repro.serve.server import MapServer, TransformResult


class BatcherClosed(RuntimeError):
    """Raised by submissions to a closed (draining or shut down) batcher."""


class _Request:
    """One logical ``project()`` call: output buffers + completion event."""

    __slots__ = (
        "n",
        "seed",
        "return_neighbors",
        "embedding",
        "cells",
        "neighbor_ids",
        "neighbor_dists",
        "remaining_rows",
        "done",
        "error",
        "latencies",
        "t_submit",
    )

    def __init__(self, n: int, seed: int, out_dim: int, k: int, return_neighbors: bool):
        self.n = n
        self.seed = np.uint32(seed & 0xFFFFFFFF)
        self.return_neighbors = return_neighbors
        self.embedding = np.empty((n, out_dim), np.float32)
        self.cells = np.empty((n,), np.int64)
        self.neighbor_ids = np.empty((n, k), np.int64) if return_neighbors else None
        self.neighbor_dists = (
            np.empty((n, k), np.float32) if return_neighbors else None
        )
        self.remaining_rows = n
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.latencies: List[float] = []
        self.t_submit = time.monotonic()


class _Item:
    """A contiguous row range of one request, as queued for coalescing."""

    __slots__ = ("request", "q", "offset")

    def __init__(self, request: _Request, q: np.ndarray, offset: int):
        self.request = request
        self.q = q
        self.offset = offset  # row offset into the request (== local row id base)

    @property
    def n(self) -> int:
        return self.q.shape[0]

    def split(self, m: int) -> "tuple[_Item, _Item]":
        """Head of ``m`` rows (fills the current batch) + requeued tail."""
        return (
            _Item(self.request, self.q[:m], self.offset),
            _Item(self.request, self.q[m:], self.offset + m),
        )


class BatcherStats:
    """Monotonic counters the cache tests and ``/metrics`` read."""

    __slots__ = ("n_batches", "n_rows", "n_pad_rows", "n_requests", "n_errors")

    def __init__(self):
        self.n_batches = 0
        self.n_rows = 0
        self.n_pad_rows = 0
        self.n_requests = 0
        self.n_errors = 0

    @property
    def batch_fill(self) -> float:
        """Fraction of device-batch rows that carried real queries."""
        total = self.n_rows + self.n_pad_rows
        return self.n_rows / total if total else float("nan")

    def as_dict(self) -> dict:
        return {
            "n_batches": self.n_batches,
            "n_rows": self.n_rows,
            "n_pad_rows": self.n_pad_rows,
            "n_requests": self.n_requests,
            "n_errors": self.n_errors,
            "batch_fill": self.batch_fill,
        }


class Batcher:
    """Coalesces concurrent requests into ``server.batch_rows`` batches.

    ``max_delay_s`` bounds the queueing a lone request pays for the chance
    of sharing its device batch: the worker flushes a partial batch the
    moment the *oldest* queued row has waited that long (or immediately,
    once a batch is full or the batcher is draining).

    ``autostart=False`` leaves the worker stopped until :meth:`start` —
    tests use this to enqueue a deterministic backlog and observe exactly
    how it coalesces.
    """

    def __init__(
        self,
        server: MapServer,
        *,
        max_delay_s: Optional[float] = None,
        autostart: bool = True,
    ):
        self.server = server
        self.max_delay_s = (
            server.frozen.cfg.service_max_delay_s if max_delay_s is None else max_delay_s
        )
        if self.max_delay_s < 0:
            raise ValueError("max_delay_s must be >= 0")
        self._dq: "collections.deque[_Item]" = collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        self._inflight_rows = 0  # queued or inside the worker, not yet fanned out
        self.stats = BatcherStats()
        self._recent_batch_lat: "collections.deque[float]" = collections.deque(
            maxlen=512
        )
        self._worker: Optional[threading.Thread] = None
        if autostart:
            self.start()

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> None:
        with self._cv:
            if self._closed:
                raise BatcherClosed("batcher is closed")
            if self._worker is not None:
                return
            self._worker = threading.Thread(
                target=self._run, name="nomad-batcher", daemon=True
            )
            self._worker.start()

    def close(self, *, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop accepting work; with ``drain`` finish everything queued.

        Draining is what makes hot map swap lossless: the registry flips
        the active pointer first, then closes the old version's batcher —
        requests already inside it complete on the map they started on,
        requests arriving after the flip never see it.
        """
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if drain:
            deadline = time.monotonic() + timeout
            with self._cv:
                while self._inflight_rows > 0:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"batcher drain timed out with "
                            f"{self._inflight_rows} rows in flight"
                        )
                    self._cv.wait(min(remaining, 0.1))
        if self._worker is not None:
            self._worker.join(timeout=timeout)

    @property
    def closed(self) -> bool:
        return self._closed

    # -- introspection ---------------------------------------------------------

    def queue_depth(self) -> int:
        """Rows currently waiting to be placed (queued or mid-batch)."""
        with self._cv:
            return self._inflight_rows

    def recent_batch_latency(self) -> List[float]:
        with self._cv:
            return list(self._recent_batch_lat)

    # -- the public call -------------------------------------------------------

    def submit(self, q: np.ndarray, *, seed: int = 0, return_neighbors: bool = True):
        """Enqueue one request; returns its :class:`_Request` handle
        (wait on ``.done``, then read the output buffers). ``q`` must
        already be validated, float32, ``(n, dim)`` with n ≥ 1."""
        q = np.ascontiguousarray(q, np.float32)
        if q.ndim != 2 or q.shape[1] != self.server.frozen.dim or q.shape[0] < 1:
            raise ValueError(
                f"submit wants (n>=1, {self.server.frozen.dim}) float32 rows, "
                f"got {q.shape}"
            )
        req = _Request(
            q.shape[0],
            seed,
            self.server.frozen.out_dim,
            self.server.frozen.cfg.n_neighbors,
            return_neighbors,
        )
        B = self.server.batch_rows
        items = [
            _Item(req, q[s : s + B], s) for s in range(0, q.shape[0], B)
        ]
        with self._cv:
            if self._closed:
                raise BatcherClosed("batcher is closed")
            self.stats.n_requests += 1
            self._inflight_rows += req.n
            self._dq.extend(items)
            self._cv.notify_all()
        return req

    def project(
        self,
        q: np.ndarray,
        *,
        seed: int = 0,
        return_neighbors: bool = True,
        timeout: float = 60.0,
    ) -> TransformResult:
        """Blocking submit + wait; returns the request's TransformResult.

        ``batch_loss`` is NaN per batch touched — a coalesced batch's loss
        mixes requests and is not attributable to this one.
        """
        t0 = time.time()
        req = self.submit(q, seed=seed, return_neighbors=return_neighbors)
        if not req.done.wait(timeout):
            raise TimeoutError(
                f"request of {req.n} rows not served within {timeout}s "
                f"(queue depth {self.queue_depth()})"
            )
        if req.error is not None:
            raise req.error
        return TransformResult(
            embedding=req.embedding,
            cells=req.cells,
            neighbor_ids=req.neighbor_ids,
            neighbor_dists=req.neighbor_dists,
            n_queries=req.n,
            strategy=self.server.strategy,
            n_shards=self.server.n_shards,
            microbatch=self.server.microbatch,
            steps=self.server.steps,
            wall_time_s=time.time() - t0,
            batch_latency_s=list(req.latencies),
            batch_loss=[float("nan")] * len(req.latencies),
        )

    # -- the worker ------------------------------------------------------------

    def _collect(self) -> Optional[List[_Item]]:
        """Block until a batch is ready: full, deadline-expired, or closing.

        Returns None exactly once, when the queue is empty and the batcher
        is closed — the worker's exit signal.
        """
        B = self.server.batch_rows
        with self._cv:
            while not self._dq:
                if self._closed:
                    return None
                self._cv.wait(0.05)
            first = self._dq.popleft()
            deadline = first.request.t_submit + self.max_delay_s
            items, rows = [first], first.n
            while rows < B:
                if self._dq:
                    nxt = self._dq[0]
                    space = B - rows
                    if nxt.n <= space:
                        self._dq.popleft()
                        items.append(nxt)
                        rows += nxt.n
                    else:
                        head, tail = nxt.split(space)
                        self._dq[0] = tail
                        items.append(head)
                        rows += space
                    continue
                now = time.monotonic()
                if self._closed or now >= deadline:
                    break
                self._cv.wait(min(deadline - now, 0.05))
            return items

    def _run(self) -> None:
        while True:
            items = self._collect()
            if items is None:
                return
            self._process(items)

    def _process(self, items: List[_Item]) -> None:
        B = self.server.batch_rows
        dim = self.server.frozen.dim
        qb = np.zeros((B, dim), np.float32)
        rows = np.zeros((B,), np.int32)
        seeds = np.zeros((B,), np.uint32)
        valid = np.zeros((B,), bool)
        o = 0
        for it in items:
            m = it.n
            qb[o : o + m] = it.q
            rows[o : o + m] = np.arange(it.offset, it.offset + m, dtype=np.int32)
            seeds[o : o + m] = it.request.seed
            valid[o : o + m] = True
            o += m
        # the full variant serves a mixed batch too (placements are parity-
        # tested against the fast path); skip neighbors only when every
        # request in the batch asked to
        want_nb = any(it.request.return_neighbors for it in items)
        try:
            out = self.server.transform_batch(
                qb, rows, seeds, valid, return_neighbors=want_nb
            )
        except BaseException as e:  # noqa: BLE001 — fail the requests, keep serving
            with self._cv:
                self.stats.n_errors += 1
                self._inflight_rows -= o
                self._cv.notify_all()
            for it in items:
                req = it.request
                req.error = e
                req.done.set()
            return
        o = 0
        for it in items:
            m = it.n
            req = it.request
            req.embedding[it.offset : it.offset + m] = out.embedding[o : o + m]
            req.cells[it.offset : it.offset + m] = out.cells[o : o + m]
            if req.return_neighbors:
                req.neighbor_ids[it.offset : it.offset + m] = out.neighbor_ids[
                    o : o + m
                ]
                req.neighbor_dists[it.offset : it.offset + m] = out.neighbor_dists[
                    o : o + m
                ]
            req.latencies.append(out.latency_s)
            req.remaining_rows -= m
            if req.remaining_rows == 0:
                req.done.set()
            o += m
        with self._cv:
            self.stats.n_batches += 1
            self.stats.n_rows += o
            self.stats.n_pad_rows += B - o
            self._recent_batch_lat.append(out.latency_s)
            self._inflight_rows -= o
            self._cv.notify_all()

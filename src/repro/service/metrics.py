"""Thread-safe service counters and latency windows.

Deliberately framework-free: the FastAPI app, the batching engine and the
load-test benchmark all report through the same two primitives, so
``/metrics`` works (and is testable) without the ``[service]`` extra
installed. Quantiles go through ``TransformResult.percentile`` — one
percentile implementation across the serve layer, the benchmarks and the
metrics endpoint.
"""

from __future__ import annotations

import collections
import threading
from typing import Dict, Optional

from repro.serve.server import TransformResult


class LatencyWindow:
    """A bounded sliding window of wall clocks with p50/p99 snapshots.

    Keeps the most recent ``maxlen`` observations — a service that has
    been up for a week should report *current* tail latency, not the
    all-time histogram — plus a lifetime count.
    """

    def __init__(self, maxlen: int = 2048):
        self._window = collections.deque(maxlen=maxlen)
        self._count = 0
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._window.append(float(seconds))
            self._count += 1

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            vals = list(self._window)
            count = self._count
        return {
            "count": count,
            "window": len(vals),
            "p50_s": TransformResult.percentile(vals, 50.0),
            "p99_s": TransformResult.percentile(vals, 99.0),
        }


class ServiceMetrics:
    """Named monotonic counters + named latency windows, all thread-safe."""

    def __init__(self):
        self._counters: Dict[str, int] = collections.defaultdict(int)
        self._lock = threading.Lock()
        self._latencies: Dict[str, LatencyWindow] = {}

    def inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            self._counters[name] += by

    def count(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def latency(self, name: str) -> LatencyWindow:
        with self._lock:
            win = self._latencies.get(name)
            if win is None:
                win = self._latencies[name] = LatencyWindow()
            return win

    def record_latency(self, name: str, seconds: float) -> None:
        self.latency(name).record(seconds)

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(sorted(self._counters.items()))
            windows = dict(self._latencies)
        return {
            "counters": counters,
            "latency": {k: w.snapshot() for k, w in sorted(windows.items())},
        }

"""The map-serving service layer: HTTP front end over ``repro.serve``.

Layering (each piece independently testable, all dependency-free except
the optional HTTP skin)::

    app.py (FastAPI, [service] extra)      — the network skin
      └─ core.py   MapService             — validation → cache → batcher
           ├─ cache.py    ResultCache     — LRU keyed on (map, query,
           │                                seed, steps) fingerprints
           ├─ registry.py MapRegistry     — versioned maps, warm + atomic
           │                                hot swap + drain
           │    └─ batcher.py Batcher     — coalesces concurrent requests
           │         └─ repro.serve.MapServer.transform_batch
           └─ metrics.py ServiceMetrics   — counters + latency windows

The batching engine returns, per request, exactly the bits a dedicated
``MapServer.transform`` call would (per-row seeds/rows — see
``batcher.py``); the cache returns them without touching the device; the
registry swaps maps under load without dropping either.
"""

from repro.service.batcher import Batcher, BatcherClosed, BatcherStats
from repro.service.cache import ResultCache, make_key, query_fingerprint
from repro.service.core import ExploreOutcome, MapService, ProjectOutcome
from repro.service.metrics import LatencyWindow, ServiceMetrics
from repro.service.registry import MapHandle, MapRegistry, map_fingerprint

__all__ = [
    "Batcher",
    "BatcherClosed",
    "BatcherStats",
    "ExploreOutcome",
    "LatencyWindow",
    "MapHandle",
    "MapRegistry",
    "MapService",
    "ProjectOutcome",
    "ResultCache",
    "ServiceMetrics",
    "create_app",
    "make_key",
    "map_fingerprint",
    "query_fingerprint",
]


def create_app(*args, **kwargs):
    """Lazy re-export of :func:`repro.service.app.create_app` (keeps the
    fastapi import out of ``import repro.service`` on bare installs)."""
    from repro.service.app import create_app as _create_app

    return _create_app(*args, **kwargs)

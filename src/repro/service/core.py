"""MapService: the framework-free service core behind every endpoint.

One object ties the three service pieces together —

  request → validation gate → result cache → batching engine → MapServer

— and is what the FastAPI app (``repro.service.app``), the load-test
benchmark and the tests all drive. Keeping the whole request path out of
the HTTP layer means the batching/caching/swap semantics are fully
testable on a bare install (the ``[service]`` extra only adds the network
skin).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.serve.server import TransformResult
from repro.service import cache as cache_mod
from repro.service.batcher import BatcherClosed
from repro.service.cache import ResultCache
from repro.service.metrics import ServiceMetrics
from repro.service.registry import MapHandle, MapRegistry

# a request that raced a retire re-resolves the active map this many times
SWAP_RETRIES = 8


@dataclasses.dataclass
class ProjectOutcome:
    """One served ``/project`` request: result + serving provenance."""

    result: TransformResult
    map_version: str
    map_fingerprint: str
    cache_hit: bool
    wall_s: float


@dataclasses.dataclass
class ExploreOutcome:
    """One served ``/explore`` request: "what lives at this 2D spot?".

    ``embedding`` is the inverse head's decoded vector per coordinate;
    ``neighbor_ids``/``neighbor_dists`` are the corpus rows the frozen
    §3.2 index puts nearest to it (-1 / inf padding, as everywhere)."""

    coords: np.ndarray  # (B, 2) the query coordinates
    embedding: np.ndarray  # (B, D) decoded embedding-space vectors
    neighbor_ids: np.ndarray  # (B, k) int32 original corpus ids
    neighbor_dists: np.ndarray  # (B, k) float32 embedding-space distances
    map_version: str
    map_fingerprint: str
    wall_s: float


class MapService:
    """Registry + cache + metrics behind one ``project()`` entry point."""

    def __init__(
        self,
        registry: Optional[MapRegistry] = None,
        *,
        cache_entries: Optional[int] = None,
        metrics: Optional[ServiceMetrics] = None,
    ):
        self.registry = registry if registry is not None else MapRegistry()
        self.cache = ResultCache(1024 if cache_entries is None else cache_entries)
        self.metrics = metrics if metrics is not None else ServiceMetrics()

    # -- the request path ------------------------------------------------------

    def project(
        self,
        q,
        *,
        seed: int = 0,
        steps: Optional[int] = None,
        return_neighbors: bool = True,
        map_version: Optional[str] = None,
        use_cache: bool = True,
        timeout: float = 60.0,
    ) -> ProjectOutcome:
        """Place query rows on a served map.

        The happy path: resolve the map handle, check the result cache
        (keyed on map fingerprint × query fingerprint × seed × steps — a
        hit returns without touching the batcher or the device at all),
        else go through the batching engine. If a hot swap retires the
        resolved handle between resolution and submission, the request
        transparently re-resolves the *current* active map — a swap never
        drops a request (tested).
        """
        from repro.core.nomad import prepare_inputs

        t0 = time.time()
        self.metrics.inc("project.requests")
        handle = self.registry.get(map_version)
        q = prepare_inputs(q, dim=handle.frozen.dim, caller="project")
        q = np.asarray(q)
        for attempt in range(SWAP_RETRIES):
            if steps is not None and steps != handle.server.steps:
                raise ValueError(
                    f"map {handle.version!r} serves transform_steps="
                    f"{handle.server.steps} (compiled in); got steps={steps}. "
                    "Register a version with the steps you want."
                )
            key = cache_mod.make_key(
                handle.fingerprint, q, seed, handle.server.steps, return_neighbors
            )
            if use_cache:
                hit = self.cache.get(key)
                if hit is not None:
                    self.metrics.inc("project.cache_hits")
                    wall = time.time() - t0
                    self.metrics.record_latency("project", wall)
                    return ProjectOutcome(
                        result=hit,
                        map_version=handle.version,
                        map_fingerprint=handle.fingerprint,
                        cache_hit=True,
                        wall_s=wall,
                    )
            try:
                result = handle.batcher.project(
                    q, seed=seed, return_neighbors=return_neighbors, timeout=timeout
                )
            except BatcherClosed:
                # lost the race against a hot swap: the handle we resolved
                # was retired before our rows made it in — re-resolve. An
                # explicitly pinned version does not fail over to a
                # different map behind the caller's back.
                self.metrics.inc("project.swap_retries")
                if map_version is not None:
                    raise
                handle = self.registry.get(None)
                continue
            if use_cache:
                self.cache.put(key, result)
            self.metrics.inc("project.served")
            wall = time.time() - t0
            self.metrics.record_latency("project", wall)
            return ProjectOutcome(
                result=result,
                map_version=handle.version,
                map_fingerprint=handle.fingerprint,
                cache_hit=False,
                wall_s=wall,
            )
        raise RuntimeError(
            f"request lost the swap race {SWAP_RETRIES} times in a row — "
            "is something retiring maps in a tight loop?"
        )

    def explore(
        self,
        coords,
        *,
        k: Optional[int] = None,
        map_version: Optional[str] = None,
    ) -> ExploreOutcome:
        """The inverse of :meth:`project`: given 2D map coordinate(s),
        decode an embedding-space vector with the map's inverse head and
        return the corpus rows the frozen index puts nearest to it — the
        MapExplorer "what lives at this spot?" query.

        Needs a version whose checkpoint carried ``inverse.npz``
        (``describe()['has_inverse']``); a map without one raises with
        the training hint. Explore never touches the batcher: decode +
        kNN is one light jitted call on the handle's own frozen state,
        so a racing hot swap simply means this request answers from the
        map it resolved — exactly the ``project()`` semantics.
        """
        t0 = time.time()
        self.metrics.inc("explore.requests")
        handle = self.registry.get(map_version)
        if handle.inverse is None:
            raise ValueError(
                f"map {handle.version!r} has no inverse head — fit one with "
                "repro.pipeline (run_pipeline or train_inverse + "
                "save_inverse beside the checkpoint) and reload the version"
            )
        q = np.asarray(coords, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        emb = handle.inverse.decode(q)  # validates shape/NaN
        ids, dists = handle.frozen.neighbors(emb, k=k)
        self.metrics.inc("explore.served")
        wall = time.time() - t0
        self.metrics.record_latency("explore", wall)
        return ExploreOutcome(
            coords=q,
            embedding=emb,
            neighbor_ids=ids,
            neighbor_dists=dists,
            map_version=handle.version,
            map_fingerprint=handle.fingerprint,
            wall_s=wall,
        )

    # -- introspection (the /health, /maps, /metrics bodies) -------------------

    def health(self) -> dict:
        active = self.registry.active_version
        return {
            "status": "ok" if active is not None else "empty",
            "active_map": active,
            "n_maps": len(self.registry.versions()),
        }

    def maps(self) -> dict:
        return {
            "active": self.registry.active_version,
            "maps": self.registry.versions(),
        }

    def metrics_snapshot(self) -> dict:
        """Everything ``/metrics`` serves: counters, request-latency
        percentiles, cache stats, and per-version batcher state (queue
        depth, batch-fill ratio, device-batch latency percentiles)."""
        snap = self.metrics.snapshot()
        snap["cache"] = self.cache.stats()
        per_map = {}
        for desc in self.registry.versions():
            handle = self.registry.get(desc["version"])
            lat = handle.batcher.recent_batch_latency()
            per_map[desc["version"]] = {
                "active": desc["active"],
                "queue_depth": handle.batcher.queue_depth(),
                **handle.batcher.stats.as_dict(),
                "batch_p50_s": TransformResult.percentile(lat, 50.0),
                "batch_p99_s": TransformResult.percentile(lat, 99.0),
            }
        snap["maps"] = per_map
        snap["active_map"] = self.registry.active_version
        return snap

    def close(self) -> None:
        self.registry.close()


def handle_for(service: MapService, version: Optional[str] = None) -> MapHandle:
    """Convenience used by the app layer's error mapping."""
    return service.registry.get(version)

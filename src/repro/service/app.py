"""The HTTP skin: a FastAPI app over one :class:`MapService`.

This module is the only place the ``[service]`` optional extra (fastapi /
uvicorn / httpx) is touched, and every import is guarded: a bare install
can import ``repro.service`` — batcher, cache, registry, core are all
dependency-free — and only ``create_app()`` raises, with the install
hint. Endpoints:

* ``GET  /health``  — 200 with the active map, 503 while no map is live
  (what a load balancer should probe);
* ``POST /project`` — place query rows: body ``{"rows": [[...], ...],
  "seed": 0, "return_neighbors": true, "map_version": null}``. Responses
  carry the serving provenance (map version + fingerprint, cache_hit,
  batch count). Neighbor distances use ``-1.0`` where the neighbor id is
  ``-1`` (dead edge): the float payload stays strict-JSON (no
  ``Infinity`` literals);
* ``POST /explore`` — the inverse: body ``{"coords": [[x, y], ...],
  "k": null, "map_version": null}``. Each 2D map coordinate is decoded
  to an embedding-space vector by the map's inverse head (the
  ``inverse.npz`` the pipeline checkpoints beside the map) and answered
  with the nearest corpus rows from the frozen index — "what lives at
  this spot?". 400 when the served map has no inverse head;
* ``GET  /maps``    — every registered version + which one is active;
* ``POST /maps``    — hot swap: load a checkpoint dir, warm, activate,
  optionally retire the old version — all while serving;
* ``POST /maps/{version}/activate`` — flip the active pointer only;
* ``GET  /metrics`` — request counters per endpoint, cache stats, queue
  depth, batch-fill ratio, p50/p99 request and device-batch latency.

Run it with uvicorn, e.g.::

    service = MapService(); service.registry.load("ck/")
    uvicorn.run(create_app(service), host="0.0.0.0", port=8000)

(or see ``examples/serve_http.py`` for the full fit → checkpoint → serve
loop, including programmatic startup/shutdown).
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.service.core import MapService

try:  # the [service] extra — keep the core importable without it
    from fastapi import FastAPI, HTTPException
    from pydantic import BaseModel, Field

    HAVE_FASTAPI = True
except ImportError:  # pragma: no cover - exercised on bare installs
    HAVE_FASTAPI = False
    FastAPI = None  # type: ignore[assignment]

    class BaseModel:  # type: ignore[no-redef]
        pass

    def Field(*a, **k):  # type: ignore[no-redef]
        return None


class ProjectRequest(BaseModel):
    rows: List[List[float]] = Field(..., description="(n, dim) query rows")
    seed: int = 0
    return_neighbors: bool = True
    map_version: Optional[str] = None
    use_cache: bool = True


class ExploreRequest(BaseModel):
    coords: List[List[float]] = Field(..., description="(n, 2) map coordinates")
    k: Optional[int] = None
    map_version: Optional[str] = None


class SwapRequest(BaseModel):
    checkpoint_dir: str
    version: Optional[str] = None
    retire_old: bool = True


def _json_dists(ids: np.ndarray, dists: np.ndarray) -> list:
    """inf (dead edge) → -1.0 so the payload stays strict JSON."""
    return np.where(ids >= 0, dists, -1.0).astype(float).tolist()


def create_app(service: Optional[MapService] = None, **service_kw):
    """Build the FastAPI app over ``service`` (a fresh empty
    :class:`MapService` when omitted — load maps via ``POST /maps``)."""
    if not HAVE_FASTAPI:
        raise RuntimeError(
            "the HTTP service needs the [service] extra: "
            "pip install 'repro-nomad[service]'"
        )
    svc = service if service is not None else MapService(**service_kw)
    app = FastAPI(
        title="NOMAD map service",
        description="Out-of-sample projection over frozen NOMAD maps",
    )
    app.state.service = svc

    @app.get("/health")
    def health():
        svc.metrics.inc("http./health")
        body = svc.health()
        if body["status"] != "ok":
            raise HTTPException(status_code=503, detail=body)
        return body

    @app.post("/project")
    def project(req: ProjectRequest):
        svc.metrics.inc("http./project")
        q = np.asarray(req.rows, np.float32)
        try:
            outcome = svc.project(
                q,
                seed=req.seed,
                return_neighbors=req.return_neighbors,
                map_version=req.map_version,
                use_cache=req.use_cache,
            )
        except (ValueError, KeyError, RuntimeError) as e:
            # validation-gate rejects (dim/NaN/steps), unknown versions,
            # and "no active map" are all caller errors at this layer
            status = 404 if isinstance(e, KeyError) else 400
            raise HTTPException(status_code=status, detail=str(e)) from None
        res = outcome.result
        body = {
            "map_version": outcome.map_version,
            "map_fingerprint": outcome.map_fingerprint,
            "cache_hit": outcome.cache_hit,
            "wall_s": outcome.wall_s,
            "n_queries": res.n_queries,
            "n_batches": len(res.batch_latency_s),
            "embedding": res.embedding.astype(float).tolist(),
            "cells": res.cells.astype(int).tolist(),
        }
        if req.return_neighbors:
            body["neighbor_ids"] = res.neighbor_ids.astype(int).tolist()
            body["neighbor_dists"] = _json_dists(
                res.neighbor_ids, res.neighbor_dists
            )
        return body

    @app.post("/explore")
    def explore(req: ExploreRequest):
        svc.metrics.inc("http./explore")
        try:
            outcome = svc.explore(
                np.asarray(req.coords, np.float32),
                k=req.k,
                map_version=req.map_version,
            )
        except (ValueError, KeyError, RuntimeError) as e:
            status = 404 if isinstance(e, KeyError) else 400
            raise HTTPException(status_code=status, detail=str(e)) from None
        return {
            "map_version": outcome.map_version,
            "map_fingerprint": outcome.map_fingerprint,
            "wall_s": outcome.wall_s,
            "embedding": outcome.embedding.astype(float).tolist(),
            "neighbor_ids": outcome.neighbor_ids.astype(int).tolist(),
            "neighbor_dists": _json_dists(
                outcome.neighbor_ids, outcome.neighbor_dists
            ),
        }

    @app.get("/maps")
    def maps():
        svc.metrics.inc("http./maps")
        return svc.maps()

    @app.post("/maps")
    def swap(req: SwapRequest):
        svc.metrics.inc("http./maps.swap")
        try:
            handle = svc.registry.swap(
                req.checkpoint_dir, version=req.version, retire_old=req.retire_old
            )
        except (FileNotFoundError, ValueError) as e:
            raise HTTPException(status_code=400, detail=str(e)) from None
        return {"activated": handle.version, "map": handle.describe()}

    @app.post("/maps/{version}/activate")
    def activate(version: str):
        svc.metrics.inc("http./maps.activate")
        try:
            handle = svc.registry.activate(version)
        except KeyError as e:
            raise HTTPException(status_code=404, detail=str(e)) from None
        return {"activated": handle.version}

    @app.get("/metrics")
    def metrics():
        svc.metrics.inc("http./metrics")
        return svc.metrics_snapshot()

    return app

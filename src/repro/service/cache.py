"""LRU result cache for ``/project`` responses.

A cache entry is one full :class:`repro.serve.TransformResult`, keyed on
everything that determines it bit-for-bit:

  (map fingerprint, query fingerprint, seed, steps, return_neighbors)

The *map* fingerprint is content-derived (``data_fingerprint`` over the
frozen θ rows — see ``repro.service.registry.map_fingerprint``), so a hot
swap to a genuinely different map can never serve stale placements, while
re-registering the same checkpoint under a new version label keeps its
warm cache. The *query* fingerprint hashes the exact canonical float32
bytes of the query rows: ``data_fingerprint``'s sampled row hash is built
for 10⁸-row training corpora where a full pass is the cost being avoided;
a service query is a handful of rows, and a cache that can confuse two
different queries is worse than no cache — so below
``EXACT_FINGERPRINT_ROWS`` (every realistic request) the fingerprint is
exact, and only beyond it falls back to ``data_fingerprint``'s sampled
scheme.

Hits return the stored result object; results are immutable by the serve
layer's convention (nothing downstream writes to a TransformResult).
"""

from __future__ import annotations

import collections
import hashlib
import threading
from typing import Optional, Tuple

import numpy as np

from repro.serve.server import TransformResult

# full-bytes hashing up to this many query rows; sampled beyond (a 4096×1024
# float32 request is 16 MB — still < 2ms to sha256)
EXACT_FINGERPRINT_ROWS = 65536

CacheKey = Tuple[str, str, int, int, bool]


def query_fingerprint(q: np.ndarray) -> str:
    """Content hash of one canonical (float32, C-contiguous) query array."""
    q = np.ascontiguousarray(q, np.float32)
    if q.shape[0] <= EXACT_FINGERPRINT_ROWS:
        h = hashlib.sha256()
        h.update(repr(q.shape).encode())
        h.update(q.tobytes())
        return h.hexdigest()[:16]
    from repro.index.ann import data_fingerprint

    return data_fingerprint(q)


def make_key(
    map_fingerprint: str,
    q: np.ndarray,
    seed: int,
    steps: int,
    return_neighbors: bool = True,
) -> CacheKey:
    return (
        map_fingerprint,
        query_fingerprint(q),
        int(seed),
        int(steps),
        bool(return_neighbors),
    )


class ResultCache:
    """A plain thread-safe LRU over :class:`TransformResult` entries.

    ``capacity=0`` disables caching (every get misses, puts drop)."""

    def __init__(self, capacity: int = 1024):
        if capacity < 0:
            raise ValueError("cache capacity must be >= 0")
        self.capacity = capacity
        self._entries: "collections.OrderedDict[CacheKey, TransformResult]" = (
            collections.OrderedDict()
        )
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: CacheKey) -> Optional[TransformResult]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return entry

    def put(self, key: CacheKey, result: TransformResult) -> None:
        if self.capacity == 0:
            return
        with self._lock:
            self._entries[key] = result
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "size": len(self._entries),
                "capacity": self.capacity,
            }

from repro.checkpoint.checkpointer import (
    Checkpointer,
    latest_step,
    load_metadata,
    load_theta,
)
from repro.checkpoint.lineage import MapLineage, MapVersion

__all__ = [
    "Checkpointer",
    "MapLineage",
    "MapVersion",
    "latest_step",
    "load_metadata",
    "load_theta",
]

from repro.checkpoint.checkpointer import Checkpointer, latest_step, load_metadata

__all__ = ["Checkpointer", "latest_step", "load_metadata"]

from repro.checkpoint.checkpointer import (
    Checkpointer,
    latest_step,
    load_metadata,
    load_theta,
)

__all__ = ["Checkpointer", "latest_step", "load_metadata", "load_theta"]

"""Map-version lineage: the ``versions.json`` contract under a checkpoint dir.

A ``checkpoint_dir`` that only ever sees full fits holds one map. Once
``partial_fit`` grows the corpus in place, the directory becomes a
*lineage*: each update writes a *self-contained* version subdirectory
(``<root>/v1/``, ``<root>/v2/`` … — its own ``step_*/`` checkpoint plus
``index.npz``) and appends an entry to ``<root>/versions.json``:

.. code-block:: json

    {"versions": [
      {"name": "v0", "dir": ".",  "parent": "",   "fingerprint": "9f…",
       "n_points": 100000, "kind": "fit",         "created_at": 1754…},
      {"name": "v1", "dir": "v1", "parent": "v0", "fingerprint": "3a…",
       "n_points": 101024, "kind": "partial_fit", "created_at": 1754…}
    ]}

Contract:

* ``dir`` is **relative to the lineage root** (``"."`` = the root itself —
  the base fit's artifacts stay exactly where a plain fit wrote them, so
  pre-lineage checkpoints upgrade in place as version ``v0``).
* ``parent`` names the entry the version was grown from (``""`` for a
  base fit). Parents always precede children in the list.
* ``fingerprint`` is the version's index fingerprint. A ``partial_fit``
  version carries a *chained* fingerprint — hash(parent fingerprint +
  fingerprint of the appended rows) — so identical append sequences hash
  identically while any divergence (different parent, different rows)
  is visible without re-reading the corpus.
* Every version directory is self-contained: ``FrozenMap.from_checkpoint``
  / ``MapRegistry.load`` / ``NomadProjection.from_checkpoint`` work on
  ``lineage.resolve(name).path`` directly — hot-swapping a service onto a
  new version is ``registry.swap(lineage.resolve().path)`` (or the
  one-call :meth:`repro.service.registry.MapRegistry.load_lineage`).

The file is written whole via tmp + ``os.replace`` — readers never see a
torn update, exactly like the checkpoint commit itself.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import List, Optional

VERSIONS_FILE = "versions.json"


@dataclasses.dataclass
class MapVersion:
    """One entry of ``versions.json`` (see the module contract above)."""

    name: str
    dirname: str  # relative to the lineage root; "." = the root itself
    parent: str  # "" for a base fit
    fingerprint: str
    n_points: int
    kind: str  # "fit" | "partial_fit"
    created_at: float
    root: str = ""  # absolute-ization context, not serialized

    @property
    def path(self) -> str:
        """The version's self-contained checkpoint directory."""
        return os.path.normpath(os.path.join(self.root, self.dirname))

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "dir": self.dirname,
            "parent": self.parent,
            "fingerprint": self.fingerprint,
            "n_points": int(self.n_points),
            "kind": self.kind,
            "created_at": self.created_at,
        }


class MapLineage:
    """Reader/writer of one checkpoint root's ``versions.json``."""

    def __init__(self, root: str):
        self.root = root
        self._file = os.path.join(root, VERSIONS_FILE)

    def exists(self) -> bool:
        return os.path.exists(self._file)

    def load(self) -> List[MapVersion]:
        if not self.exists():
            return []
        with open(self._file) as f:
            doc = json.load(f)
        return [
            MapVersion(
                name=v["name"],
                dirname=v["dir"],
                parent=v.get("parent", ""),
                fingerprint=v.get("fingerprint", ""),
                n_points=int(v.get("n_points", 0)),
                kind=v.get("kind", "fit"),
                created_at=float(v.get("created_at", 0.0)),
                root=self.root,
            )
            for v in doc.get("versions", [])
        ]

    def latest(self) -> Optional[MapVersion]:
        versions = self.load()
        return versions[-1] if versions else None

    def resolve(self, name: Optional[str] = None) -> MapVersion:
        """The named version (default: the newest). Raises on miss/empty."""
        versions = self.load()
        if not versions:
            raise FileNotFoundError(
                f"{self._file} has no versions — nothing fitted here yet"
            )
        if name is None:
            return versions[-1]
        for v in versions:
            if v.name == name:
                return v
        raise KeyError(
            f"unknown map version {name!r} in {self._file} "
            f"(have {[v.name for v in versions]})"
        )

    def next_name(self) -> str:
        """The next free ``vN`` (monotone even if versions were pruned)."""
        taken = {v.name for v in self.load()}
        i = len(taken)
        while f"v{i}" in taken:
            i += 1
        return f"v{i}"

    def record(
        self,
        *,
        name: str,
        dirname: str,
        parent: str,
        fingerprint: str,
        n_points: int,
        kind: str,
    ) -> MapVersion:
        """Append one version entry (atomic tmp + rename rewrite)."""
        versions = self.load()
        if any(v.name == name for v in versions):
            raise ValueError(f"map version {name!r} already recorded in {self._file}")
        if parent and not any(v.name == parent for v in versions):
            raise ValueError(
                f"parent version {parent!r} is not in {self._file} — "
                "a lineage must stay connected"
            )
        entry = MapVersion(
            name=name,
            dirname=dirname,
            parent=parent,
            fingerprint=fingerprint,
            n_points=int(n_points),
            kind=kind,
            created_at=time.time(),
            root=self.root,
        )
        versions.append(entry)
        os.makedirs(self.root, exist_ok=True)
        tmp = self._file + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"versions": [v.to_json() for v in versions]}, f, indent=1)
        os.replace(tmp, self._file)
        return entry

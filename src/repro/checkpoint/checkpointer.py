"""Fault-tolerant checkpointing.

Design (mirrors what a multi-host deployment needs, executed single-host):

* a checkpoint is a directory ``step_<n>/`` holding one ``shard_<i>.npz``
  per logical shard plus a ``manifest.json`` (tree structure, shard map,
  user metadata such as epoch/rng state/config digest);
* writes go to ``step_<n>.tmp/`` and are committed by a single atomic
  ``rename`` — a crash mid-write can never corrupt the latest checkpoint;
* saves can run on a background thread (``async_save=True``); the next
  save (or ``wait()``) joins the previous one first, bounding dirty state
  to one checkpoint;
* restore supports **elastic resharding**: row-sharded leaves are stored
  with their global shapes, so a checkpoint written from 8 shards restores
  onto 4 or 16 — this is the node-failure / elastic-scaling path, and the
  multi-pod story depends on it (see tests/test_checkpoint.py);
* ``keep`` bounds retained checkpoints (oldest pruned after commit).
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import shutil
from typing import Any, Optional

import numpy as np


def _flatten(tree, prefix=""):
    """Stable depth-first flatten of nested dict/list pytrees of arrays."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _unflatten_into(skeleton, flat: dict, prefix=""):
    if isinstance(skeleton, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}/{k}" if prefix else str(k))
            for k, v in skeleton.items()
        }
    if isinstance(skeleton, (list, tuple)):
        vals = [
            _unflatten_into(v, flat, f"{prefix}/{i}") for i, v in enumerate(skeleton)
        ]
        return type(skeleton)(vals)
    return flat[prefix]


class Checkpointer:
    def __init__(
        self,
        directory: str,
        *,
        n_shards: int = 1,
        keep: int = 3,
        async_save: bool = False,
        primary: bool = True,
    ):
        """``primary=False`` turns ``save`` into a no-op: under multi-process
        ``jax.distributed`` every process holds the full (gathered) state, so
        only process 0 writes — peers construct the Checkpointer with
        ``primary=jax.process_index() == 0`` and still restore from the
        shared directory. The caller owns the cross-process barrier that
        orders the commit before anyone proceeds."""
        self.dir = directory
        self.n_shards = n_shards
        self.keep = keep
        self.primary = primary
        self._pool = cf.ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[cf.Future] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: dict, *, sharded_keys=(), metadata: Optional[dict] = None):
        """``sharded_keys``: names (flat paths) whose leading axis is split
        into ``n_shards`` row blocks — one block per shard file."""
        if not self.primary:
            return
        self.wait()
        arrays = {k: np.asarray(v) for k, v in _flatten(tree)}
        if self._pool is None:
            self._write(step, arrays, tuple(sharded_keys), metadata or {})
        else:
            self._pending = self._pool.submit(
                self._write, step, arrays, tuple(sharded_keys), metadata or {}
            )

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, arrays: dict, sharded_keys, metadata: dict):
        tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "n_shards": self.n_shards,
            "sharded": list(sharded_keys),
            "metadata": metadata,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in arrays.items()
            },
        }
        for s in range(self.n_shards):
            payload = {}
            for k, v in arrays.items():
                if k in sharded_keys:
                    n = v.shape[0]
                    assert n % self.n_shards == 0, (k, n, self.n_shards)
                    blk = n // self.n_shards
                    payload[k] = v[s * blk : (s + 1) * blk]
                elif s == 0:  # replicated leaves live in shard 0 only
                    payload[k] = v
            np.savez(os.path.join(tmp, f"shard_{s:05d}.npz"), **payload)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # the atomic commit point
        self._prune()

    def _prune(self):
        steps = sorted(self._steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def _steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return out

    def restore(self, skeleton: dict, step: Optional[int] = None):
        """Returns (tree, metadata). ``skeleton`` fixes the pytree structure;
        global array shapes come from the files, so the caller may re-shard
        onto any device count afterwards (elastic restore)."""
        steps = self._steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        step = max(steps) if step is None else step
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        sharded = set(manifest["sharded"])
        flat: dict[str, Any] = {}
        parts: dict[str, list] = {k: [] for k in sharded}
        for s in range(manifest["n_shards"]):
            with np.load(os.path.join(path, f"shard_{s:05d}.npz")) as z:
                for k in z.files:
                    if k in sharded:
                        parts[k].append(z[k])
                    else:
                        flat[k] = z[k]
        for k, chunks in parts.items():
            flat[k] = np.concatenate(chunks, axis=0)
        tree = _unflatten_into(skeleton, flat)
        return tree, manifest["metadata"]


def load_theta(directory: str, step: Optional[int] = None):
    """Restore just the θ row block of one checkpoint (latest by default).

    Returns ``(theta (K·C, out_dim) np.float32, metadata)`` with shards
    already concatenated to the global cluster-major buffer — the array the
    serve path freezes. No estimator or config is needed; the global shape
    comes from the manifest.
    """
    tree, meta = Checkpointer(directory).restore({"theta": None}, step)
    return np.asarray(tree["theta"], np.float32), meta


def load_metadata(directory: str, step: Optional[int] = None) -> dict:
    """User metadata of one checkpoint (latest by default) — no array I/O."""
    steps = [
        int(n.split("_")[1])
        for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
    ]
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = max(steps) if step is None else step
    with open(os.path.join(directory, f"step_{step:09d}", "manifest.json")) as f:
        return json.load(f)["metadata"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(n.split("_")[1])
        for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
    ]
    return max(steps) if steps else None

"""Roofline-term computation (assignment §Roofline).

Hardware constants are TPU v5e-class (the stated target):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

The HLO module analysed is the per-partition program, so the parser's
numbers are *per device*; the three terms are per-device times directly:

  compute    = flops_dev / peak            (≡ HLO_FLOPs·chips / (chips·peak))
  memory     = bytes_dev / hbm_bw
  collective = coll_bytes_dev / link_bw

MODEL_FLOPS (the "useful work" yardstick) is 6·N·D for training and 2·N·D
for single forward passes (N = active params, D = tokens), plus the
attention KV term for decode. ``MODEL_FLOPS / (HLO_FLOPs·chips)`` exposes
remat/padding/capacity waste.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig, ShapeConfig
from repro.roofline.hlo_cost import CostReport

HW_V5E = {
    "peak_flops": 197e12,  # bf16 FLOP/s per chip
    "hbm_bw": 819e9,  # B/s per chip
    "ici_bw": 50e9,  # B/s per link
    "name": "tpu-v5e",
}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline lower-bound step time (terms overlap perfectly)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: 1.0 = perfectly compute-bound
        with zero waste. The score §Perf pushes up."""
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops / self.hlo_flops_global * self.compute_s if self.hlo_flops_global else 0.0
        return ideal / self.bound_s


def roofline_terms(
    report: CostReport,
    n_chips: int,
    model_fl: float,
    hw: dict = HW_V5E,
) -> RooflineTerms:
    compute_s = report.flops / hw["peak_flops"]
    memory_s = report.bytes / hw["hbm_bw"]
    collective_s = report.collective_bytes / hw["ici_bw"]
    hlo_global = report.flops * n_chips
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops=model_fl,
        hlo_flops_global=hlo_global,
        useful_ratio=(model_fl / hlo_global) if hlo_global else 0.0,
    )


def kernel_roofline(flops: float, bytes_: float, us: float, hw: dict = HW_V5E) -> dict:
    """Achieved-vs-roofline summary for ONE kernel call.

    Takes an analytic cost (``spec.cost_model(sig)``) and a measured wall
    time (µs) and returns achieved GFLOP/s and GB/s, their fractions of
    the hardware peaks, the roofline-bound wall time at those peaks, the
    achieved fraction of that bound, and which resource bounds the kernel.
    Feeds the roofline columns of ``benchmarks/kernel_micro.py`` and the
    autotuner's per-candidate ``--report``.
    """
    s = us * 1e-6
    compute_s = flops / hw["peak_flops"]
    memory_s = bytes_ / hw["hbm_bw"]
    roofline_s = max(compute_s, memory_s)
    return {
        "gflops": flops / s / 1e9 if s > 0 else 0.0,
        "gbs": bytes_ / s / 1e9 if s > 0 else 0.0,
        "frac_peak_flops": (flops / s) / hw["peak_flops"] if s > 0 else 0.0,
        "frac_peak_bw": (bytes_ / s) / hw["hbm_bw"] if s > 0 else 0.0,
        "roofline_us": roofline_s * 1e6,
        "roofline_frac": roofline_s / s if s > 0 else 0.0,
        "bound": "compute" if compute_s >= memory_s else "memory",
    }


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs for one step of this cell."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * n_active * tokens
        flops += 2.0 * _attention_flops(cfg, B, S) * 3  # fwd + 2×bwd
        return flops
    if shape.kind == "prefill":
        tokens = B * S
        return 2.0 * n_active * tokens + 2.0 * _attention_flops(cfg, B, S)
    # decode: one token per sequence + full-cache attention reads
    flops = 2.0 * n_active * B
    Sc = min(S, cfg.sliding_window) if cfg.sliding_window else S
    if cfg.n_heads:
        n_attn_layers = sum(
            1 for l in range(cfg.n_layers) if cfg.layer_is_attention(l)
        )
        flops += 4.0 * B * cfg.n_heads * cfg.head_dim * Sc * n_attn_layers
    if cfg.ssm_state:
        n_ssm = cfg.n_layers - sum(
            1 for l in range(cfg.n_layers) if cfg.layer_is_attention(l)
        )
        flops += 6.0 * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * n_ssm
    return flops


def _attention_flops(cfg: ArchConfig, B: int, S: int) -> float:
    """Score+PV matmul FLOPs for one forward pass (causal halving applied)."""
    if not cfg.n_heads:
        return 0.0
    n_attn = sum(1 for l in range(cfg.n_layers) if cfg.layer_is_attention(l))
    eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
    per_query = eff if cfg.sliding_window else S / 2  # causal triangle
    return 4.0 * B * S * per_query * cfg.n_heads * cfg.head_dim * n_attn


def nomad_model_flops(n_points, batch, k_nn, n_exact, n_clusters, steps) -> float:
    """Useful FLOPs of one NOMAD epoch: Cauchy affinities of positives,
    exact negatives, and the B×K mean term, fwd+bwd (×3)."""
    per_step = batch * (k_nn + n_exact + n_clusters) * 8.0  # ~8 flops/affinity
    return 3.0 * per_step * steps


def nomad_analytic_terms(cfg, n_chips: int, steps: int, hw: dict = HW_V5E) -> dict:
    """Kernel-true per-device roofline terms for one NOMAD epoch.

    The HLO-parsed memory term is inflated on CPU: the Pallas cauchy_mean
    kernel runs in interpret mode, so its (bb × bk) tiles appear as HLO
    fusion boundaries and get billed as HBM traffic; the Mosaic kernel
    keeps them in VMEM. This computes what the TPU actually streams:
    per step, the gathered/scattered θ rows (heads + kNN tails + exact
    negatives, read+write) plus the kernel's true I/O (θ_i, μ, w in; s,
    dθ out), plus one full pass over local θ per mean refresh.
    """
    d = cfg.out_dim
    B = cfg.batch_size  # per shard
    touched = B * (1 + cfg.n_neighbors + cfg.n_exact_negatives)
    per_step = (
        2 * touched * d * 4  # gather + scatter of positions
        + touched * 4 * 2  # index reads
        + 2 * B * d * 4  # kernel θ_i in, dθ out
        + 2 * cfg.n_clusters * (d + 1) * 4  # μ, w (+ recompute in bwd)
        + 2 * B * 4  # s out / ḡ in
    )
    rows_local = (cfg.n_clusters // n_chips) * cfg.cluster_capacity
    refreshes = max(steps // (cfg.mean_refresh_steps or steps), 1)
    mem_bytes = per_step * steps + refreshes * rows_local * d * 4
    # the paper's point: the only wire traffic is the means exchange
    coll_bytes = refreshes * cfg.n_clusters * (d + 1) * 4
    flops = 3.0 * B * (cfg.n_neighbors + cfg.n_exact_negatives + cfg.n_clusters) * 8.0 * steps
    return {
        "compute_s": flops / hw["peak_flops"],
        "memory_s": mem_bytes / hw["hbm_bw"],
        "collective_s": coll_bytes / hw["ici_bw"],
    }

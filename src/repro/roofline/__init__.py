from repro.roofline.hlo_cost import CostReport, analyze_hlo
from repro.roofline.analysis import HW_V5E, roofline_terms, model_flops

__all__ = ["CostReport", "analyze_hlo", "HW_V5E", "roofline_terms", "model_flops"]

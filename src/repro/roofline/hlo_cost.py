"""Trip-count-aware HLO cost model.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts a
``while`` body **once**, but every model here is scan-over-layers, so its
numbers are off by ~n_layers (verified in tests against both XLA on
loop-free graphs and analytic FLOPs on looped ones). This parser walks the
post-optimisation, post-SPMD-partitioning HLO text and:

* multiplies costs inside while bodies by the ``known_trip_count`` XLA
  records on the while op (nested loops multiply);
* counts dot FLOPs as 2·|out|·K (K from ``lhs_contracting_dims`` and the
  lhs operand's shape, resolved through a module-wide symbol table — the
  post-opt text references operands by name only);
* approximates HBM traffic as operands+results at *fusion boundaries*
  (ops inside a fused computation stay in registers; the fusion op's own
  operands/results are the traffic);
* sums wire bytes of every collective (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute), per type — the module
  is the per-partition program, so these are per-device bytes.

This is a *model*, not a measurement: CPU fusion choices differ from TPU,
which we accept and note in EXPERIMENTS.md (the relative deltas the perf
loop optimises are robust to it; cross-checks live in tests/test_roofline.py).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "and",
    "or", "xor", "not", "compare", "select", "clamp", "convert", "floor",
    "ceil", "round-nearest-afz", "sign", "cosine", "sine", "logistic",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "cbrt", "erf", "is-finite", "stochastic-convert", "tan",
}
_DATA_MOVERS = {
    "copy", "copy-start", "transpose", "reshape", "broadcast", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "reverse", "rng-bit-generator", "reduce", "scatter", "gather", "sort",
}


def _text_elems_bytes(text: str) -> Tuple[float, float]:
    """Sum (elements, bytes) over every shape literal in ``text``."""
    elems = 0.0
    byts = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _dims_of_first_shape(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_type: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_ops: float = 0.0
    dot_flops: float = 0.0
    unknown_trip_whiles: int = 0

    def add(self, other: "CostReport", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.dot_flops += other.dot_flops * mult
        self.coll_ops += other.coll_ops * mult
        self.unknown_trip_whiles += other.unknown_trip_whiles
        for k, v in other.coll_by_type.items():
            self.coll_by_type[k] = self.coll_by_type.get(k, 0.0) + v * mult


@dataclasses.dataclass
class _Op:
    name: str
    result: str  # result type text
    opcode: str
    operands: str  # operand list text (names; shapes resolved via symtab)
    attrs: str


_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_SCALAR_TYPE_RE = re.compile(r"[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"(?:condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _parse_module(hlo: str):
    """→ (computations: name → [ _Op ], symtab: op name → result type text)."""
    comps: Dict[str, List[_Op]] = {}
    symtab: Dict[str, str] = {}
    cur: Optional[str] = None
    ops: List[_Op] = []
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEAD_RE.match(line)
            if m:
                cur = m.group(1)
                ops = []
            continue
        if line.startswith("}"):
            comps[cur] = ops
            cur = None
            continue
        m = _OP_HEAD_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end() :]
        # result type: tuple (paren-matched — may contain /*index=N*/ comments)
        # or a scalar/array type literal
        if rest.startswith("("):
            depth = 0
            end = -1
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            if end < 0:
                continue
            result, rest = rest[:end], rest[end:]
        else:
            m2 = _SCALAR_TYPE_RE.match(rest)
            if not m2:
                continue
            result, rest = m2.group(0), rest[m2.end() :]
        m3 = _OPCODE_RE.match(rest)
        if not m3:
            continue
        opcode = m3.group(1)
        rest = rest[m3.end() :]
        depth = 1
        operands, attrs = rest, ""
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    operands, attrs = rest[:i], rest[i + 1 :]
                    break
        op = _Op(name, result, opcode, operands, attrs)
        ops.append(op)
        symtab[name] = result
    return comps, symtab


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)\s*\(", hlo, re.MULTILINE)
    return m.group(1)


def _trip_count(op: _Op) -> Optional[int]:
    m = re.search(r"known_trip_count[^0-9]*([0-9]+)", op.attrs)
    return int(m.group(1)) if m else None


def analyze_hlo(hlo: str) -> CostReport:
    comps, symtab = _parse_module(hlo)
    entry = _entry_name(hlo)
    memo: Dict[Tuple[str, bool], CostReport] = {}
    adjust_memo: Dict[str, float] = {}

    def fusion_slice_adjustment(name: str) -> float:
        """Bytes to subtract from a fusion's operand bill: a fused
        dynamic-slice of a *parameter* reads only the slice, not the whole
        operand (scan bodies slice one layer out of the (L, …) weight/cache
        stacks — billing the stack per trip overstated traffic ~L×)."""
        if name in adjust_memo:
            return adjust_memo[name]
        local = {op.name: op for op in comps.get(name, ())}

        def is_param_alias(nm: str, depth=0) -> bool:
            op = local.get(nm)
            if op is None or depth > 4:
                return False
            if op.opcode == "parameter":
                return True
            if op.opcode in ("bitcast", "copy", "convert", "transpose", "reshape"):
                inner = _NAME_RE.findall(op.operands)
                return bool(inner) and is_param_alias(inner[0], depth + 1)
            return False

        adj = 0.0
        for op in comps.get(name, ()):
            if op.opcode != "dynamic-slice":
                continue
            inner = _NAME_RE.findall(op.operands)
            if inner and is_param_alias(inner[0]):
                t = local.get(inner[0])
                src = symtab.get(inner[0]) if t is None else t.result
                if src:
                    _, src_b = _text_elems_bytes(src)
                    _, res_b = _text_elems_bytes(op.result)
                    adj += max(src_b - res_b, 0.0)
        adjust_memo[name] = adj
        return adj

    def operand_bytes(op: _Op) -> float:
        total = 0.0
        if _SHAPE_RE.search(op.operands):  # inline shapes (older dumps)
            _, b = _text_elems_bytes(op.operands)
            return b
        for nm in _NAME_RE.findall(op.operands):
            t = symtab.get(nm)
            if t:
                _, b = _text_elems_bytes(t)
                total += b
        return total

    def dot_flops(op: _Op) -> float:
        out_elems, _ = _text_elems_bytes(op.result)
        names = _NAME_RE.findall(op.operands)
        lhs_dims: List[int] = []
        if _SHAPE_RE.search(op.operands):
            lhs_dims = _dims_of_first_shape(op.operands)
        elif names and names[0] in symtab:
            lhs_dims = _dims_of_first_shape(symtab[names[0]])
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        k = 1.0
        if mc and lhs_dims:
            for idx in mc.group(1).split(","):
                if idx:
                    k *= lhs_dims[int(idx)]
        return 2.0 * out_elems * k

    def comp_cost(name: str, in_fusion: bool) -> CostReport:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        memo[key] = CostReport()  # cycle guard
        rep = CostReport()
        for op in comps.get(name, ()):
            oc = op.opcode
            if oc == "fusion":
                called = _CALL_RE.search(op.attrs)
                if called:
                    rep.add(comp_cost(called.group(1), True))
                _, out_b = _text_elems_bytes(op.result)
                if "dynamic-update-slice" in op.name or "dynamic_update_slice" in op.name:
                    # in-place update fusions touch only the update region:
                    # bill 2× the non-aliased operands (the slice being
                    # written), not the whole carried buffer — scan-carried
                    # stacks were otherwise billed n_layers× their size
                    op_bytes = []
                    for nm in _NAME_RE.findall(op.operands):
                        t = symtab.get(nm)
                        if t:
                            _, b = _text_elems_bytes(t)
                            op_bytes.append(b)
                    if op_bytes:
                        rep.bytes += 2.0 * (sum(op_bytes) - max(op_bytes))
                    continue
                bill = operand_bytes(op) + out_b
                if called:
                    bill -= fusion_slice_adjustment(called.group(1))
                rep.bytes += max(bill, out_b)
                continue
            if oc == "while":
                body = _CALL_RE.search(op.attrs)
                cond = _COND_RE.search(op.attrs)
                trips = _trip_count(op)
                if trips is None:
                    trips = 1
                    rep.unknown_trip_whiles += 1
                if body:
                    rep.add(comp_cost(body.group(1), in_fusion), trips)
                if cond:
                    rep.add(comp_cost(cond.group(1), in_fusion), trips)
                continue
            if oc in ("call", "async-start", "custom-call-start"):
                called = _CALL_RE.search(op.attrs)
                if called:
                    rep.add(comp_cost(called.group(1), in_fusion))
                continue
            if oc == "conditional":
                names = _BRANCHES_RE.search(op.attrs)
                if names:
                    branch_reps = [
                        comp_cost(n.strip().lstrip("%"), in_fusion)
                        for n in names.group(1).split(",")
                    ]
                    if branch_reps:  # one branch executes: take the heaviest
                        rep.add(max(branch_reps, key=lambda r: r.flops))
                continue
            if any(oc.startswith(c) for c in _COLLECTIVES):
                if oc.endswith("-done"):
                    continue  # counted at -start
                base = next(c for c in _COLLECTIVES if oc.startswith(c))
                in_b = operand_bytes(op)
                _, out_b = _text_elems_bytes(op.result)
                # wire model: AG counts gathered output, others input
                wire = out_b if base == "all-gather" else in_b
                rep.collective_bytes += wire
                rep.coll_by_type[base] = rep.coll_by_type.get(base, 0.0) + wire
                rep.coll_ops += 1
                if not in_fusion:
                    rep.bytes += in_b + out_b
                continue
            if oc in ("dot", "convolution"):
                f = dot_flops(op)
                rep.flops += f
                rep.dot_flops += f
                if not in_fusion:
                    _, out_b = _text_elems_bytes(op.result)
                    rep.bytes += operand_bytes(op) + out_b
                continue
            if oc == "custom-call":
                if "matmul" in op.attrs or "dot" in op.attrs:
                    f = dot_flops(op)
                    rep.flops += f
                    rep.dot_flops += f
                if not in_fusion:
                    _, out_b = _text_elems_bytes(op.result)
                    rep.bytes += operand_bytes(op) + out_b
                continue
            if oc in _ELEMENTWISE:
                out_e, _ = _text_elems_bytes(op.result)
                rep.flops += out_e
                continue
            if oc in _DATA_MOVERS:
                if oc == "reduce":
                    in_e = 0.0
                    for nm in _NAME_RE.findall(op.operands):
                        t = symtab.get(nm)
                        if t:
                            e, _ = _text_elems_bytes(t)
                            in_e += e
                    rep.flops += in_e
                if not in_fusion:
                    _, out_b = _text_elems_bytes(op.result)
                    if oc in ("slice", "dynamic-slice", "gather"):
                        # reads only the sliced/gathered region, not the operand
                        rep.bytes += 2.0 * out_b
                    elif oc == "dynamic-update-slice":
                        # in-place: touches only the update region (read+write)
                        names = _NAME_RE.findall(op.operands)
                        upd_b = 0.0
                        if len(names) >= 2 and names[1] in symtab:
                            _, upd_b = _text_elems_bytes(symtab[names[1]])
                        rep.bytes += 2.0 * upd_b
                    elif oc == "scatter":
                        names = _NAME_RE.findall(op.operands)
                        upd_b = 0.0
                        if len(names) >= 3 and names[2] in symtab:
                            _, upd_b = _text_elems_bytes(symtab[names[2]])
                        rep.bytes += 2.0 * upd_b
                    else:
                        rep.bytes += operand_bytes(op) + out_b
                continue
            # parameter/constant/tuple/get-tuple-element/bitcast/iota: free
        memo[key] = rep
        return rep

    if entry is None:
        return CostReport()
    total = CostReport()
    total.add(comp_cost(entry, False))
    return total

"""Stage 2 of the pipeline: a parametric inverse projection (2D → embedding).

A served NOMAD map answers "where does this vector live?" —
``MapServer.transform``. The MapExplorer-style interaction needs the
*other* direction: "what lives at this spot?" — click a 2D coordinate,
get back a plausible embedding-space vector, then look up the corpus rows
nearest to it. Deep Learning Multidimensional Projections (PAPERS.md)
shows a small MLP trained on (projection, input) pairs suffices for that
inverse; here the pairs are sampled straight from the trained map — the
fitted positions θ against the frozen input vectors x of the same rows.

The head is deliberately tiny (2 → hidden → … → D): it trains in seconds
on CPU with a fully jitted ``lax.scan`` loop, is deterministic per seed
(fixed-key fold_in schedule — tested), and checkpoints beside the map as
``inverse.npz`` in the same directory as ``index.npz``, so

* ``FrozenMap.from_checkpoint`` serving nodes pick it up with
  :func:`load_inverse` (no training data needed), and
* a service hot swap (``MapRegistry.load``/``load_lineage``) carries it
  onto the new version automatically — every lineage version directory
  stays self-contained.

``checkpoint→reload ≡ in-memory`` is bit-for-bit: the npz round-trip
stores the exact float32 parameters (tested).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INVERSE_FILE = "inverse.npz"


@dataclasses.dataclass
class InverseProjection:
    """A trained 2D → embedding decoder head.

    ``layers`` is a list of ``(w, b)`` float32 pairs; inputs are
    standardised by ``(mu_in, sd_in)`` (stored, so a loaded head is
    self-contained). All state is plain numpy — a head is trivially
    picklable/serialisable and owns its one jitted decode function.
    """

    layers: List[Tuple[np.ndarray, np.ndarray]]
    mu_in: np.ndarray  # (in_dim,) input standardiser
    sd_in: np.ndarray  # (in_dim,)
    seed: int = 0
    train_steps: int = 0
    train_loss: float = float("nan")  # final-step batch MSE

    def __post_init__(self):
        self._decode_jit = None

    @property
    def in_dim(self) -> int:
        return int(self.layers[0][0].shape[0])

    @property
    def out_dim(self) -> int:
        return int(self.layers[-1][0].shape[1])

    @property
    def hidden(self) -> Tuple[int, ...]:
        return tuple(int(w.shape[1]) for w, _ in self.layers[:-1])

    def decode(self, theta) -> np.ndarray:
        """Map 2D coordinates ``(B, in_dim)`` to embedding vectors
        ``(B, out_dim)`` (float32, on host)."""
        q = np.asarray(theta, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2 or q.shape[1] != self.in_dim:
            raise ValueError(
                f"decode: expected (n, {self.in_dim}) coordinates, "
                f"got shape {q.shape}"
            )
        if not np.isfinite(q).all():
            raise ValueError("decode: coordinates contain NaN/Inf")
        if self._decode_jit is None:
            self._decode_jit = jax.jit(_mlp_apply)
        params = _pack(self.layers, self.mu_in, self.sd_in)
        return np.asarray(self._decode_jit(params, jnp.asarray(q)))


# -- the MLP ------------------------------------------------------------------


def _pack(layers, mu_in, sd_in) -> dict:
    return {
        "w": [jnp.asarray(w) for w, _ in layers],
        "b": [jnp.asarray(b) for _, b in layers],
        "mu": jnp.asarray(mu_in),
        "sd": jnp.asarray(sd_in),
    }


def _mlp_apply(params: dict, q: jax.Array) -> jax.Array:
    h = (q - params["mu"]) / params["sd"]
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        h = h @ w + b
        if i < n - 1:
            h = jax.nn.gelu(h)
    return h


def _init_params(key, dims: List[int]) -> dict:
    ws, bs = [], []
    for i in range(len(dims) - 1):
        k = jax.random.fold_in(key, i)
        fan_in = dims[i]
        scale = float(np.sqrt(2.0 / fan_in))
        if i == len(dims) - 2:
            scale *= 0.1  # small final layer: start near the mean target
        ws.append(jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32) * scale)
        bs.append(jnp.zeros((dims[i + 1],), jnp.float32))
    return {"w": ws, "b": bs}


# -- training -----------------------------------------------------------------


def train_inverse(
    theta: np.ndarray,
    x: np.ndarray,
    *,
    hidden: Tuple[int, ...] = (128, 128),
    steps: int = 1_500,
    batch: int = 512,
    lr: float = 3e-3,
    weight_decay: float = 1e-4,
    seed: int = 0,
) -> InverseProjection:
    """Fit the decoder on (θ, x) pairs sampled from a trained map.

    ``theta`` is the fitted ``(N, out_dim)`` embedding, ``x`` the matching
    ``(N, D)`` input vectors. The whole optimisation — minibatch sampling,
    forward, MSE, AdamW — is one jitted ``lax.scan``; the RNG schedule is
    ``fold_in(key(seed), step)``, so a fixed seed reproduces the head
    bit-for-bit (tested).
    """
    from repro.optim import AdamW, warmup_cosine

    th = np.asarray(theta, np.float32)
    xs = np.asarray(x, np.float32)
    if th.ndim != 2 or xs.ndim != 2 or th.shape[0] != xs.shape[0]:
        raise ValueError(
            f"train_inverse: want matched (N, in_dim)/(N, D) pairs, got "
            f"{th.shape} / {xs.shape}"
        )
    if th.shape[0] < 2:
        raise ValueError("train_inverse: need at least 2 (θ, x) pairs")
    n = th.shape[0]
    batch = min(batch, n)
    mu = th.mean(0)
    sd = np.maximum(th.std(0), 1e-6)
    dims = [th.shape[1], *hidden, xs.shape[1]]

    params = _init_params(jax.random.key(seed), dims)
    opt = AdamW(
        schedule=warmup_cosine(lr, min(100, max(1, steps // 10)), steps),
        weight_decay=weight_decay,
        moment_dtype="float32",
    )
    opt_state = opt.init(params)
    thd = jnp.asarray(th)
    xsd = jnp.asarray(xs)
    mud, sdd = jnp.asarray(mu), jnp.asarray(sd)
    base_key = jax.random.key(seed)

    @jax.jit
    def fit(params, opt_state):
        def step(carry, t):
            p, s = carry
            kt = jax.random.fold_in(base_key, t)
            idx = jax.random.randint(kt, (batch,), 0, n)

            def loss_fn(p):
                full = {"w": p["w"], "b": p["b"], "mu": mud, "sd": sdd}
                pred = _mlp_apply(full, thd[idx])
                return jnp.mean(jnp.square(pred - xsd[idx]))

            loss, g = jax.value_and_grad(loss_fn)(p)
            p, s = opt.update(p, g, s)
            return (p, s), loss

        (p, _), losses = jax.lax.scan(step, (params, opt_state), jnp.arange(steps))
        return p, losses

    params, losses = fit(params, opt_state)
    layers = [
        (np.asarray(w, np.float32), np.asarray(b, np.float32))
        for w, b in zip(params["w"], params["b"])
    ]
    return InverseProjection(
        layers=layers,
        mu_in=mu.astype(np.float32),
        sd_in=sd.astype(np.float32),
        seed=seed,
        train_steps=steps,
        train_loss=float(losses[-1]),
    )


def inverse_from_frozen(frozen, **train_kw) -> InverseProjection:
    """Train the head from a :class:`repro.serve.frozen.FrozenMap` — the
    (θ, x) pairs are the map's own valid rows, scattered back to original
    corpus order (layout-independent training data)."""
    inv_perm = np.asarray(frozen.inv_perm)
    valid = inv_perm >= 0
    n = int(valid.sum())
    theta = np.zeros((n, frozen.out_dim), np.float32)
    x = np.zeros((n, frozen.dim), np.float32)
    theta[inv_perm[valid]] = np.asarray(frozen.theta_rows)[valid]
    x[inv_perm[valid]] = np.asarray(frozen.x_rows)[valid]
    return train_inverse(theta, x, **train_kw)


def roundtrip_score(inv: InverseProjection, theta, x) -> float:
    """Fraction of embedding-space variance the inverse recovers:
    ``1 − ‖decode(θ) − x‖² / ‖x − x̄‖²`` (R²; 1 = perfect, ≤0 = no better
    than predicting the mean). This is the ``*_score`` leaf CI floors."""
    xs = np.asarray(x, np.float32)
    pred = inv.decode(theta)
    mse = float(np.mean(np.square(pred - xs)))
    var = float(np.mean(np.square(xs - xs.mean(0))))
    return 1.0 - mse / max(var, 1e-12)


# -- persistence --------------------------------------------------------------


def inverse_path(checkpoint_dir: str) -> str:
    """Where the head lives inside a map's checkpoint directory —
    beside ``index.npz``, so every lineage version dir stays
    self-contained and a hot swap carries the head with the map."""
    return os.path.join(checkpoint_dir, INVERSE_FILE)


def save_inverse(checkpoint_dir: str, inv: InverseProjection) -> str:
    """Atomic (tmp + replace) write of ``inverse.npz``. Returns the path."""
    os.makedirs(checkpoint_dir, exist_ok=True)
    path = inverse_path(checkpoint_dir)
    payload = {"mu_in": inv.mu_in, "sd_in": inv.sd_in}
    for i, (w, b) in enumerate(inv.layers):
        payload[f"w{i}"] = w
        payload[f"b{i}"] = b
    payload["meta"] = np.frombuffer(
        json.dumps(
            {
                "n_layers": len(inv.layers),
                "seed": inv.seed,
                "train_steps": inv.train_steps,
                "train_loss": inv.train_loss,
            }
        ).encode(),
        dtype=np.uint8,
    )
    tmp = path + ".tmp.npz"
    np.savez(tmp, **payload)
    os.replace(tmp, path)
    return path


def load_inverse(
    checkpoint_dir: str, *, missing_ok: bool = False
) -> Optional[InverseProjection]:
    """Load ``inverse.npz`` from a checkpoint dir. With ``missing_ok`` a
    map without a trained head returns ``None`` (the registry's probe);
    otherwise a missing file raises with the training hint."""
    path = inverse_path(checkpoint_dir)
    if not os.path.exists(path):
        if missing_ok:
            return None
        raise FileNotFoundError(
            f"no inverse head at {path} — train one with "
            "repro.pipeline.inverse.train_inverse (or run_pipeline) and "
            "save_inverse() it beside the map's checkpoint"
        )
    with np.load(path) as z:
        meta = json.loads(bytes(z["meta"]).decode())
        layers = [
            (
                np.asarray(z[f"w{i}"], np.float32),
                np.asarray(z[f"b{i}"], np.float32),
            )
            for i in range(int(meta["n_layers"]))
        ]
        return InverseProjection(
            layers=layers,
            mu_in=np.asarray(z["mu_in"], np.float32),
            sd_in=np.asarray(z["sd_in"], np.float32),
            seed=int(meta["seed"]),
            train_steps=int(meta["train_steps"]),
            train_loss=float(meta["train_loss"]),
        )

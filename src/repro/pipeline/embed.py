"""Stage 1 of the embed→map→explore pipeline: streaming model embedding.

The paper's maps are built from vectors a real model produced. This module
drives any zoo architecture (``data/embeddings.py``'s pooled forward) over
token batches and lands the vectors **directly in a sharded on-disk store**
— the pooled ``(N, D)`` matrix never materialises on host. Two overlapped
stages run concurrently:

* a :class:`repro.data.loader.Prefetcher` worker thread runs the jitted
  model forward for batch *i+1* (device compute + the device→host copy of
  the pooled rows), while
* the consumer thread writes batch *i*'s rows into ``write_sharded()``
  chunks (disk I/O).

Chunk contents depend only on (params, token batches, pool) — the worker
calls the *same* jitted function in the same order a materialising loop
would — so ``fit(embed_to_store(...))`` is bit-for-bit
``fit(embed_corpus(...))`` for every architecture family (tested in
tests/test_pipeline.py, the same contract PR 5 pinned for
``fit(store) ≡ fit(ndarray)``).
"""

from __future__ import annotations

import math
from typing import Iterator, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.embeddings import hidden_states


def make_embed_fn(cfg: ArchConfig, pool: str = "mean"):
    """The jitted ``(params, tokens (B, S)) -> pooled (B, D) f32`` forward.

    One function per (cfg, pool) — reuse it across batches so the compile
    is paid once per batch shape.
    """
    if pool not in ("mean", "last"):
        raise ValueError(f"unknown pool {pool!r} (want 'mean'|'last')")

    @jax.jit
    def fwd(params, tokens):
        h = hidden_states(params, cfg, tokens=tokens)
        v = jnp.mean(h, axis=1) if pool == "mean" else h[:, -1, :]
        return v.astype(jnp.float32)

    return fwd


def _batch_slices(tokens: np.ndarray, batch: int) -> Sequence[np.ndarray]:
    return [tokens[s : s + batch] for s in range(0, tokens.shape[0], batch)]


def embed_chunks(
    params,
    cfg: ArchConfig,
    token_batches: Union[np.ndarray, Sequence[np.ndarray]],
    *,
    pool: str = "mean",
    doc_batch: int = 128,
    depth: int = 2,
) -> Iterator[np.ndarray]:
    """Yield pooled ``(B, D)`` float32 chunks, model forward prefetched.

    ``token_batches`` is either a ``(N, S)`` token array (cut into
    ``doc_batch``-row forwards) or an explicit sequence of ``(B, S)``
    batches. The forward for batch *i+1* runs on a Prefetcher worker
    while the consumer (typically ``write_sharded``) handles batch *i* —
    the model-forward / disk-write overlap of the streaming pipeline. A
    forward error re-raises in the consumer (Prefetcher contract), never
    hangs the pipeline.
    """
    if isinstance(token_batches, np.ndarray):
        batches: Sequence[np.ndarray] = _batch_slices(token_batches, doc_batch)
    else:
        batches = list(token_batches)
    if not batches:
        return
    fwd = make_embed_fn(cfg, pool)

    from repro.data.loader import Prefetcher

    def make(step: int):
        # np.asarray blocks on the device result: the worker owns the
        # forward AND the device→host copy, the consumer only writes
        return np.asarray(fwd(params, jnp.asarray(batches[step])))

    pf = Prefetcher(make, depth=depth, max_steps=len(batches))
    try:
        for _ in range(len(batches)):
            _step, chunk = next(pf)
            yield chunk
    finally:
        pf.close()


def embed_to_store(
    params,
    cfg: ArchConfig,
    token_batches: Union[np.ndarray, Sequence[np.ndarray]],
    out_dir: str,
    *,
    pool: str = "mean",
    doc_batch: int = 128,
    rows_per_shard: int = 8192,
    dtype: str = "float32",
    depth: int = 2,
):
    """Embed token batches straight into a sharded store at ``out_dir``.

    Peak host memory is O(doc_batch · D + rows_per_shard · D): the chunk
    iterator feeds ``write_sharded`` which re-blocks rows to shards and
    commits ``meta.json`` last (a crashed embed run never leaves a
    directory that parses as a store). Returns the committed
    :class:`repro.data.store.ShardedStore`.
    """
    from repro.data.store import write_sharded

    return write_sharded(
        embed_chunks(
            params,
            cfg,
            token_batches,
            pool=pool,
            doc_batch=doc_batch,
            depth=depth,
        ),
        out_dir,
        rows_per_shard=rows_per_shard,
        dtype=dtype,
    )


def embed_dim(cfg: ArchConfig) -> int:
    """The pooled-vector dimensionality of an embedder (== d_model)."""
    return cfg.d_model


def n_embed_batches(n_docs: int, doc_batch: int) -> int:
    return math.ceil(n_docs / doc_batch)


def init_embedder(workload, seed: int = 0, **arch_overrides):
    """(params, reduced ArchConfig) for one named pipeline workload."""
    acfg = workload.arch_config(**arch_overrides)
    from repro.models import lm

    params = lm.init_params(jax.random.key(seed), acfg)
    return params, acfg


def corpus_for(workload, seed: Optional[int] = None):
    """The workload's synthetic class-structured token corpus."""
    from repro.data.synthetic import class_token_corpus

    return class_token_corpus(
        workload.n_docs,
        workload.seq_len,
        workload.vocab_size,
        n_classes=workload.n_classes,
        seed=0 if seed is None else seed,
    )

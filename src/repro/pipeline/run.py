"""The end-to-end driver: embed → store → fit → inverse → explore-ready.

``run_pipeline`` strings the stages of one named
:class:`repro.configs.PipelineWorkload` together and leaves behind a
self-contained map directory a service node can pick up cold:

* ``<workdir>/embeddings/`` — the sharded corpus store stage 1 streamed
  (the pooled ``(N, D)`` matrix never existed on host),
* ``<workdir>/map/``        — θ checkpoints + ``index.npz`` from the fit,
  plus ``inverse.npz`` — the stage-2 head — beside them, so
  ``MapRegistry.load(dir)`` serves both ``/project`` and ``/explore``
  from the directory alone.

Stage walls land in ``PipelineResult.stage_s`` (what
``benchmarks/pipeline.py`` reports) and the inverse round-trip R² in
``PipelineResult.roundtrip_score`` (the CI floor).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import numpy as np

from repro.configs.nomad_workloads import PipelineWorkload


@dataclasses.dataclass
class PipelineResult:
    """Everything one pipeline run produced (see module docstring)."""

    workload: PipelineWorkload
    store: object  # ShardedStore — the streamed corpus on disk
    fit: object  # core.nomad.FitResult
    frozen: object  # serve.frozen.FrozenMap
    inverse: object  # pipeline.inverse.InverseProjection
    classes: np.ndarray  # (N,) latent corpus classes (synthetic ground truth)
    checkpoint_dir: str  # the map dir (θ + index.npz + inverse.npz)
    roundtrip_score: float  # inverse R² over the map's own rows
    stage_s: dict  # {"embed": s, "fit": s, "inverse_train": s}


def run_pipeline(
    workload: PipelineWorkload,
    workdir: str,
    *,
    seed: int = 0,
    pool: Optional[str] = None,
    chunk_rows: int = 1_024,
    inverse_steps: int = 600,
    inverse_hidden=(64, 64),
    nomad_overrides: Optional[dict] = None,
) -> PipelineResult:
    """Run embed→store→fit→inverse for one workload under ``workdir``.

    ``chunk_rows`` is pinned (not auto) so the fit is bit-reproducible
    against a materialised run of the same vectors. ``nomad_overrides``
    forwards extra :class:`NomadConfig` fields (tests shrink epochs with
    it).
    """
    from repro.core.nomad import NomadProjection
    from repro.pipeline.embed import corpus_for, embed_to_store, init_embedder
    from repro.pipeline.inverse import (
        inverse_from_frozen,
        roundtrip_score,
        save_inverse,
    )
    from repro.serve.frozen import FrozenMap

    stage_s = {}
    tokens, classes = corpus_for(workload, seed=seed)
    params, acfg = init_embedder(workload, seed=seed)

    t0 = time.perf_counter()
    store = embed_to_store(
        params,
        acfg,
        tokens,
        os.path.join(workdir, "embeddings"),
        pool=workload.pool if pool is None else pool,
        doc_batch=workload.doc_batch,
    )
    stage_s["embed"] = time.perf_counter() - t0

    ckdir = os.path.join(workdir, "map")
    cfg = workload.nomad_config(
        store.shape[0],
        store.shape[1],
        seed=seed,
        chunk_rows=chunk_rows,
        checkpoint_dir=ckdir,
        **(nomad_overrides or {}),
    )
    t0 = time.perf_counter()
    fit = NomadProjection(cfg).fit(store)
    stage_s["fit"] = time.perf_counter() - t0

    frozen = FrozenMap.from_fit(fit, cfg)
    t0 = time.perf_counter()
    inverse = inverse_from_frozen(
        frozen, hidden=tuple(inverse_hidden), steps=inverse_steps, seed=seed
    )
    stage_s["inverse_train"] = time.perf_counter() - t0
    save_inverse(ckdir, inverse)

    score = roundtrip_score(inverse, fit.embedding, store.materialize())
    return PipelineResult(
        workload=workload,
        store=store,
        fit=fit,
        frozen=frozen,
        inverse=inverse,
        classes=classes,
        checkpoint_dir=ckdir,
        roundtrip_score=score,
        stage_s=stage_s,
    )

"""The end-to-end embed→store→fit→serve→explore pipeline.

* :mod:`repro.pipeline.embed`   — stage 1: streaming model embedding
  (pooled forwards land directly in a sharded store; the ``(N, D)``
  matrix never materialises on host).
* :mod:`repro.pipeline.inverse` — stage 2: the parametric inverse
  projection (2D → embedding MLP) checkpointed beside the map.
* :mod:`repro.pipeline.run`     — the driver tying them to a fit; its
  output directory is exactly what ``MapRegistry.load`` serves, giving
  stage 3 (the service's ``/explore``) its data.

Named workloads across the architecture families live in
:data:`repro.configs.PIPELINE_WORKLOADS`.
"""

from repro.pipeline.embed import (
    corpus_for,
    embed_chunks,
    embed_dim,
    embed_to_store,
    init_embedder,
    make_embed_fn,
)
from repro.pipeline.inverse import (
    INVERSE_FILE,
    InverseProjection,
    inverse_from_frozen,
    inverse_path,
    load_inverse,
    roundtrip_score,
    save_inverse,
    train_inverse,
)
from repro.pipeline.run import PipelineResult, run_pipeline

__all__ = [
    "corpus_for",
    "embed_chunks",
    "embed_dim",
    "embed_to_store",
    "init_embedder",
    "make_embed_fn",
    "INVERSE_FILE",
    "InverseProjection",
    "inverse_from_frozen",
    "inverse_path",
    "load_inverse",
    "roundtrip_score",
    "save_inverse",
    "train_inverse",
    "PipelineResult",
    "run_pipeline",
]

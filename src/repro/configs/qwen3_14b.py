"""Qwen3-14B.

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936; qk-norm + GQA.
[hf:Qwen/Qwen3-8B family scaling; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1e6,
    accum_steps=8,
    source="hf:Qwen/Qwen3-14B",
)

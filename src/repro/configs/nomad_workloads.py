"""NOMAD Projection workload configs — the paper's own experiments.

* ``nomad_quickstart`` — CPU-sized synthetic workload used by examples/tests.
* ``nomad_pubmed``     — Table-1-scale workload (PubMed: ~24M abstracts,
  768-d BERT embeddings in the paper; sized for the production mesh here).
* ``nomad_wiki60m``    — the paper's flagship: 60M-point Multilingual
  Wikipedia map (BGE-M3, 1024-d), the largest published data map.

The two production workloads are exercised through the multi-pod dry-run
(`--arch nomad_wiki60m`), proving the distributed epoch step lowers and
compiles on the 256/512-chip meshes.

End-to-end *pipeline* workloads (:data:`PIPELINE_WORKLOADS`) pair a zoo
architecture with a token corpus and a map config: the paper's headline
result maps embeddings produced by a real model, and these are the named
embed→store→fit→serve→explore runs ``repro.pipeline`` drives across the
architecture families (dense attention, SSM, MoE). Sizes here are
CPU-smoke defaults; ``repro.pipeline.run_pipeline(scale=...)`` scales
them up without new registry entries.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import NomadConfig

QUICKSTART = NomadConfig(
    name="nomad_quickstart",
    n_points=20_000,
    dim=64,
    n_clusters=16,
    n_neighbors=15,
    n_noise=64,
    n_exact_negatives=8,
    batch_size=2_048,
    n_epochs=200,  # epochs are cheap; quality scales with them (Fig. 3)
)

PUBMED = NomadConfig(
    name="nomad_pubmed",
    n_points=24_000_000,
    dim=768,
    n_clusters=4_096,
    n_neighbors=15,
    n_noise=128,
    n_exact_negatives=16,
    batch_size=8_192,
    n_epochs=60,
    kmeans_iters=50,
)

WIKI60M = NomadConfig(
    name="nomad_wiki60m",
    n_points=60_000_000,
    dim=1024,
    n_clusters=8_192,
    n_neighbors=15,
    n_noise=128,
    n_exact_negatives=16,
    batch_size=8_192,
    n_epochs=80,
    kmeans_iters=50,
)

NOMAD_WORKLOADS = {c.name: c for c in (QUICKSTART, PUBMED, WIKI60M)}


# ---------------------------------------------------------------------------
# End-to-end embed→map→explore pipeline workloads
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PipelineWorkload:
    """One named embed→store→fit→serve→explore run.

    ``arch`` keys :data:`repro.configs.ARCHS`; the embedder is the
    CPU-reduced form of that architecture (``reduced(...)`` with the
    overrides below), so every family's *real forward pass* — attention,
    SSD scan, MoE routing — produces the vectors, not a stand-in matrix.
    The token corpus is :func:`repro.data.synthetic.class_token_corpus`
    at ``(n_docs, seq_len, n_classes)``; the map config comes from
    :meth:`nomad_config` with ``n_points``/``dim`` filled in by the
    pipeline (``dim`` is only known after the embedder is built).
    """

    name: str
    arch: str  # repro.configs.ARCHS key
    # corpus
    n_docs: int = 2_048
    seq_len: int = 64
    n_classes: int = 8
    doc_batch: int = 128  # token rows per embed forward (divides n_docs)
    pool: str = "mean"  # "mean" | "last"
    # embedder reduction (CPU-sized; family topology is preserved)
    n_layers: int = 2
    d_model: int = 128
    vocab_size: int = 512
    # map
    n_clusters: int = 16
    n_neighbors: int = 15
    n_epochs: int = 15
    batch_size: int = 512

    def arch_config(self, **overrides):
        """The reduced :class:`ArchConfig` of this workload's embedder."""
        from repro.configs import ARCHS, reduced

        kw = dict(
            n_layers=self.n_layers,
            d_model=self.d_model,
            vocab_size=self.vocab_size,
        )
        kw.update(overrides)
        return reduced(ARCHS[self.arch], **kw)

    def nomad_config(self, n_points: int, dim: int, **overrides) -> NomadConfig:
        """The map config for a corpus of ``n_points`` ``dim``-d vectors."""
        kw = dict(
            name=self.name,
            n_points=n_points,
            dim=dim,
            n_clusters=self.n_clusters,
            n_neighbors=self.n_neighbors,
            n_epochs=self.n_epochs,
            batch_size=min(self.batch_size, n_points),
        )
        kw.update(overrides)
        return NomadConfig(**kw)


# ≥3 architecture families: dense attention (phi4), SSM/SSD (mamba2),
# MoE (mixtral). The embed stage is family-agnostic by construction —
# anything ARCHS carries slots in as a fourth entry with one line.
PIPELINE_WORKLOADS = {
    w.name: w
    for w in (
        PipelineWorkload(name="pipeline_phi4_mini", arch="phi4-mini-3.8b"),
        PipelineWorkload(name="pipeline_mamba2_2_7b", arch="mamba2-2.7b"),
        PipelineWorkload(name="pipeline_mixtral_8x7b", arch="mixtral-8x7b"),
    )
}

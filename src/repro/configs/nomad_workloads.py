"""NOMAD Projection workload configs — the paper's own experiments.

* ``nomad_quickstart`` — CPU-sized synthetic workload used by examples/tests.
* ``nomad_pubmed``     — Table-1-scale workload (PubMed: ~24M abstracts,
  768-d BERT embeddings in the paper; sized for the production mesh here).
* ``nomad_wiki60m``    — the paper's flagship: 60M-point Multilingual
  Wikipedia map (BGE-M3, 1024-d), the largest published data map.

The two production workloads are exercised through the multi-pod dry-run
(`--arch nomad_wiki60m`), proving the distributed epoch step lowers and
compiles on the 256/512-chip meshes.
"""

from repro.configs.base import NomadConfig

QUICKSTART = NomadConfig(
    name="nomad_quickstart",
    n_points=20_000,
    dim=64,
    n_clusters=16,
    n_neighbors=15,
    n_noise=64,
    n_exact_negatives=8,
    batch_size=2_048,
    n_epochs=200,  # epochs are cheap; quality scales with them (Fig. 3)
)

PUBMED = NomadConfig(
    name="nomad_pubmed",
    n_points=24_000_000,
    dim=768,
    n_clusters=4_096,
    n_neighbors=15,
    n_noise=128,
    n_exact_negatives=16,
    batch_size=8_192,
    n_epochs=60,
    kmeans_iters=50,
)

WIKI60M = NomadConfig(
    name="nomad_wiki60m",
    n_points=60_000_000,
    dim=1024,
    n_clusters=8_192,
    n_neighbors=15,
    n_noise=128,
    n_exact_negatives=16,
    batch_size=8_192,
    n_epochs=80,
    kmeans_iters=50,
)

NOMAD_WORKLOADS = {c.name: c for c in (QUICKSTART, PUBMED, WIKI60M)}

"""Jamba 1.5 Large (398B total params).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; hybrid Mamba +
attention with a 1:7 interleave (one attention layer per 8-layer meta-block)
and MoE (16 experts, top-2) on every second layer, per the Jamba recipe.
[arXiv:2403.19887; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65_536,
    n_experts=16,
    top_k=2,
    moe_period=2,
    moe_offset=1,
    attn_period=8,  # layer l is attention iff l % 8 == 0  (1 attn : 7 mamba)
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    accum_steps=8,
    grad_accum_dtype="bfloat16",
    source="arXiv:2403.19887",
)

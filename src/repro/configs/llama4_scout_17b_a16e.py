"""Llama-4 Scout 17B-active / 16-expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1
with one shared expert (Llama-4 MoE recipe), early-fusion multimodal family —
we model the text backbone. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    moe_period=1,
    rope_theta=5e5,
    accum_steps=8,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
)

"""Config registry: ``get_arch(name)`` / ``ARCHS`` / ``SHAPES``."""

from repro.configs.base import ArchConfig, NomadConfig, ShapeConfig, SHAPES, reduced
from repro.configs.llama4_scout_17b_a16e import CONFIG as LLAMA4_SCOUT
from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA
from repro.configs.mamba2_2_7b import CONFIG as MAMBA2
from repro.configs.phi4_mini_3_8b import CONFIG as PHI4
from repro.configs.qwen3_14b import CONFIG as QWEN3
from repro.configs.minitron_4b import CONFIG as MINITRON
from repro.configs.yi_34b import CONFIG as YI34B
from repro.configs.hubert_xlarge import CONFIG as HUBERT
from repro.configs.internvl2_76b import CONFIG as INTERNVL2
from repro.configs.nomad_workloads import (
    NOMAD_WORKLOADS,
    PIPELINE_WORKLOADS,
    PipelineWorkload,
    QUICKSTART,
    PUBMED,
    WIKI60M,
)

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        LLAMA4_SCOUT,
        MIXTRAL,
        JAMBA,
        MAMBA2,
        PHI4,
        QWEN3,
        MINITRON,
        YI34B,
        HUBERT,
        INTERNVL2,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_nomad(name: str) -> NomadConfig:
    if name not in NOMAD_WORKLOADS:
        raise KeyError(f"unknown NOMAD workload {name!r}; available: {sorted(NOMAD_WORKLOADS)}")
    return NOMAD_WORKLOADS[name]


__all__ = [
    "ArchConfig",
    "NomadConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCHS",
    "NOMAD_WORKLOADS",
    "PIPELINE_WORKLOADS",
    "PipelineWorkload",
    "get_arch",
    "get_nomad",
    "reduced",
    "QUICKSTART",
    "PUBMED",
    "WIKI60M",
]

"""HuBERT X-Large (encoder-only audio transformer).

48L d_model=1280 16H (kv=16, i.e. MHA) d_ff=5120 vocab=504 (cluster targets).
Encoder-only ⇒ bidirectional attention, no decode step. The conv waveform
frontend is a stub per the assignment: ``input_specs`` provides precomputed
frame embeddings. [arXiv:2106.07447; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    accum_steps=4,
    source="arXiv:2106.07447 (unverified)",
)

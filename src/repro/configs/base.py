"""Configuration dataclasses for the repro framework.

Two families of config live here:

* :class:`ArchConfig` — an assigned LM-family architecture (exact published
  dims; see ``src/repro/configs/<id>.py``). These are the substrate models
  whose train/serve steps are lowered in the multi-pod dry-run.
* :class:`NomadConfig` — a NOMAD Projection workload (the paper's actual
  contribution): dataset size/dim, ANN-index parameters, loss parameters,
  optimization schedule, and distribution strategy.

Shape cells (``train_4k`` …) are defined in :data:`SHAPES` and are shared by
all LM archs; each arch declares which cells it supports via
:meth:`ArchConfig.supported_shapes` (encoder-only archs have no decode;
``long_500k`` requires sub-quadratic attention).
"""

from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Optional


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment.

    ``kind`` selects which step gets lowered in the dry-run:

    * ``train``   → ``train_step``   (fwd + bwd + optimizer update)
    * ``prefill`` → ``prefill_step`` (inference forward, returns KV/SSM state)
    * ``decode``  → ``decode_step``  (one new token against a seq_len cache)
    """

    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    def __post_init__(self) -> None:
        if self.kind not in ("train", "prefill", "decode"):
            raise ValueError(f"unknown shape kind {self.kind!r}")


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# LM architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """An assigned architecture, with exact published dimensions.

    The same dataclass describes dense, MoE, SSM (attention-free), hybrid
    (Mamba + attention interleave), encoder-only audio, and VLM-backbone
    models; unused blocks are disabled with zeros.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int  # 0 => attention-free (pure SSM)
    n_kv_heads: int
    d_ff: int  # 0 => no dense MLP (mamba2's block has none)
    vocab_size: int
    head_dim: int = 128

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_period: int = 1  # a layer is MoE iff (layer_idx % moe_period == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # "sort" (gather/scatter, production default — §Perf iteration 1) or
    # "einsum" (GShard one-hot dense dispatch — the naive baseline; its
    # dispatch einsums cost 2·T·E·C·D FLOPs and dominated the MoE cells)
    moe_dispatch: str = "sort"

    # --- SSM (Mamba-2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256  # SSD chunk length (training/prefill)

    # --- hybrid (Jamba) -------------------------------------------------------
    attn_period: int = 0  # >0: layer l uses attention iff l % attn_period == 0

    # --- attention details ------------------------------------------------------
    sliding_window: int = 0  # >0: Mistral/Mixtral-style SWA
    qk_norm: bool = False  # Qwen3-style per-head RMS norm of q,k
    rope_theta: float = 1e4

    # --- modality ----------------------------------------------------------------
    encoder_only: bool = False  # HuBERT: bidirectional, no decode step
    n_vision_patches: int = 0  # InternVL2: stub patch embeds prepended to text

    # --- TPU sharding padding ----------------------------------------------------
    # pjit requires explicitly-sharded dims to divide the mesh axis. Heads are
    # padded per-kv-group with inert (masked) heads; vocab is padded with
    # -inf-masked logit columns. Both are exact-math-preserving; the waste is
    # visible in the roofline useful_ratio. reduced() disables both.
    head_pad_to: int = 16  # model-axis size the (padded) head count must divide by
    vocab_pad_to: int = 256

    # --- numerics / memory policy ---------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_moment_dtype: str = "int8"  # int8-quantized Adam moments by default
    grad_accum_dtype: str = "float32"  # microbatch gradient accumulator dtype
    remat: str = "full"  # "none" | "full" | "dots"
    accum_steps: int = 8  # gradient-accumulation microbatches for train_4k
    attn_chunk: int = 1024  # KV-chunk for memory-efficient (online-softmax) attn
    # "flash" = custom-VJP recompute backward (§Perf iteration 2);
    # "chunked" = plain online-softmax whose AD saves every tile (baseline)
    attn_impl: str = "flash"

    # --- provenance ------------------------------------------------------------
    source: str = ""

    # -- derived -----------------------------------------------------------------

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1) if self.n_heads else 0

    @property
    def n_heads_padded(self) -> int:
        """Heads incl. per-kv-group padding so TP over ``head_pad_to`` ways
        divides evenly AND every real head keeps its published kv group."""
        if not self.n_heads or self.head_pad_to <= 1:
            return self.n_heads
        import math

        kv = max(self.n_kv_heads, 1)
        g = self.n_heads // kv
        m = self.head_pad_to // math.gcd(kv, self.head_pad_to)
        g_pad = -(-g // m) * m
        return kv * g_pad

    @property
    def vocab_padded(self) -> int:
        if self.vocab_pad_to <= 1:
            return self.vocab_size
        return -(-self.vocab_size // self.vocab_pad_to) * self.vocab_pad_to

    def layer_is_attention(self, layer_idx: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return layer_idx % self.attn_period == 0
        return True

    def layer_is_moe(self, layer_idx: int) -> bool:
        if not self.n_experts:
            return False
        return layer_idx % self.moe_period == self.moe_offset

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can run 512k-token contexts (assignment rule)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def supported_shapes(self) -> list[str]:
        out = []
        for s in SHAPES.values():
            if s.kind == "decode" and self.encoder_only:
                continue  # encoder-only: no autoregressive decode
            if s.name == "long_500k" and not self.sub_quadratic:
                continue  # needs sub-quadratic attention
            out.append(s.name)
        return out

    # -- parameter counts (for roofline MODEL_FLOPS) ------------------------------

    def param_counts(self) -> dict[str, float]:
        """Analytic parameter counts: total and active-per-token."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D  # embedding (tied with the LM head)
        active = V * D
        per_layer_total = 0.0
        per_layer_active = 0.0
        for l in range(self.n_layers):
            lt = la = 0.0
            if self.layer_is_attention(l):
                qdim = self.n_heads * self.head_dim
                kvdim = self.n_kv_heads * self.head_dim
                attn = D * (qdim + 2 * kvdim) + qdim * D
                lt += attn
                la += attn
            elif self.family in ("ssm", "hybrid"):
                di, ds = self.d_inner, self.ssm_state
                ng = 1  # single B/C group
                in_proj = D * (2 * di + 2 * ng * ds + self.ssm_heads)
                out_proj = di * D
                conv = (di + 2 * ng * ds) * self.ssm_conv
                lt += in_proj + out_proj + conv + 2 * self.ssm_heads
                la += in_proj + out_proj + conv + 2 * self.ssm_heads
            if F:
                ffn = 3 * D * F  # SwiGLU
                if self.layer_is_moe(l):
                    lt += ffn * self.n_experts + D * self.n_experts
                    la += ffn * (self.top_k + self.n_shared_experts)
                    lt += ffn * self.n_shared_experts
                else:
                    lt += ffn
                    la += ffn
            lt += 2 * D  # norms
            la += 2 * D
            per_layer_total += lt
            per_layer_active += la
        total += per_layer_total + D  # final norm
        active += per_layer_active + D
        return {"total": float(total), "active": float(active)}


# ---------------------------------------------------------------------------
# NOMAD workload config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NomadConfig:
    """A NOMAD Projection run: data, index, loss, schedule, distribution."""

    name: str = "nomad"
    # data
    n_points: int = 100_000
    dim: int = 256
    out_dim: int = 2

    # estimator (repro.core.nomad.NomadProjection)
    method: str = "nomad"  # "nomad" (Eq. 3) | "infonc" (Eq. 2 baseline, local only)
    strategy: str = "auto"  # "auto" | "local" | "sharded" | "hierarchical"

    # ANN index (paper §3.2): LSH-initialised K-means, exact kNN in-cluster
    n_clusters: int = 64
    kmeans_iters: int = 25
    kmeans_tol: float = 1e-4
    capacity_slack: float = 1.25  # cluster capacity = slack * N / K (TPU static shapes)
    n_neighbors: int = 15  # k of the kNN graph

    # index-build execution (repro.index.build.IndexBuilder): where the §3.2
    # pipeline itself runs. "auto" resolves from jax.devices() like the
    # training strategy; "local" is one device; "sharded" never places the
    # full (N, D) on a single device.
    # "distributed" is the multi-process variant of "sharded": the same
    # collective program over the global mesh, with each process reading
    # only its own row ranges of the store (jax.distributed runs resolve
    # to it automatically).
    build_strategy: str = "auto"  # "auto" | "local" | "sharded" | "distributed"
    build_block_rows: int = 16384  # row block of the E-step / capacity bidding
    build_max_rounds: int = 16  # device bidding rounds before host fallback
    build_candidates: int = 32  # nearest-centroid candidates cached per row

    # out-of-core ingestion (repro.data.store): corpora too big for host RAM
    # stream through an EmbeddingStore in `chunk_rows`-row chunks. 0 keeps
    # the resident path for in-memory arrays (today's behaviour) and picks a
    # default chunk for store inputs; >0 forces the *streamed* build/init
    # path for every input container — chunking fixes the f32 accumulation
    # order, so fit(store) and fit(ndarray) of the same data are then
    # bit-identical (tested; with the default store_dtype — a lossy spill
    # dtype rounds the disk-backed branch's x_rows, so bit-equality holds
    # only at "float32"). `store_dtype` is the on-disk dtype of stores
    # the pipeline itself writes (the permuted x_rows spill): bfloat16
    # halves the disk/PCIe footprint; accumulation stays float32 on device.
    chunk_rows: int = 0
    store_dtype: str = "float32"  # "float32" | "float16" | "bfloat16"
    # ceiling on shard *files* a single spill writes (one open fd each
    # during the scatter pass): spills whose natural layout would exceed
    # it are re-blocked to coarser shards instead of exhausting fds
    store_max_shards: int = 256

    # loss (paper §3.3)
    n_noise: int = 64  # |M| noise samples per head
    n_exact_negatives: int = 16  # samples drawn from non-approximated cells
    approximate_remote_only: bool = True  # R̃ = every cell except the head's own
    batch_size: int = 4_096  # heads sampled per step (E_{i~P_i} estimator)

    # schedule (paper §3.4): lr0 = n/10, linear anneal to 0, PCA init
    n_epochs: int = 40
    steps_per_epoch: int = 0  # 0 => ceil(N / batch_size)
    lr0: float = 0.0  # 0 => n_points / 10 (paper convention)
    init: str = "pca"  # "pca" | "random"
    init_scale: float = 1e-4  # per-dim std of the initial projection
    seed: int = 0

    # distribution (paper Fig. 2 + our multi-pod extension)
    mean_refresh_steps: int = 0  # 0 => once per epoch (paper); else every T steps
    hierarchical: bool = False  # pod-level super-means across the slow axis
    n_cluster_groups: int = 0  # super-mean groups (0 => one per pod shard)

    # out-of-sample serving (repro.serve): place unseen points on a frozen
    # map. "auto" serves sharded exactly when >1 device is visible; queries
    # are processed in fixed `serve_microbatch` slices (one compile each),
    # each optimised by `transform_steps` frozen NOMAD steps. transform_lr=0
    # derives the per-row lr of the *final* fit epoch
    # (resolved_lr0() / batch_size / n_epochs): a served map sits at the
    # equilibrium of the annealed schedule, and re-injecting epoch-0-scale
    # forces provably pushes queries off the frozen map.
    serve_strategy: str = "auto"  # "auto" | "local" | "sharded"
    serve_microbatch: int = 1024  # queries per device per jitted batch
    serve_knn_block: int = 256  # query rows per frozen-kNN gather tile
    transform_steps: int = 24  # frozen NOMAD steps per query batch
    transform_lr: float = 0.0  # 0 => resolved_lr0() / batch_size / n_epochs

    # HTTP service front end (repro.service): the batching engine holds a
    # partial device batch open at most `service_max_delay_s` waiting for
    # concurrent /project requests to coalesce into it; the service-level
    # LRU result cache keeps `service_cache_entries` responses (0 disables
    # caching). Both are service-layer knobs — the library-call
    # MapServer.transform path never reads them.
    service_max_delay_s: float = 0.005
    service_cache_entries: int = 1024

    # kernel dispatch (repro.kernels.registry): "" defers to "auto" — the
    # registry picks per backend (tpu/gpu → pallas, cpu → jnp;
    # REPRO_KERNELS / REPRO_KERNEL_<NAME> env vars override);
    # "pallas"/"jnp" force one path everywhere.
    kernel_impl: str = ""
    # DEPRECATED: setting it emits a DeprecationWarning; use kernel_impl.
    use_pallas: Optional[bool] = None

    # incremental growth (repro.core.nomad.NomadProjection.partial_fit):
    # refinement epochs run over the affected cells after an append. 0
    # admits + patches without moving any position (pure placement).
    partial_refine_epochs: int = 3

    # fault tolerance
    checkpoint_every_epochs: int = 5
    checkpoint_dir: str = ""

    def __post_init__(self) -> None:
        if self.method not in ("nomad", "infonc"):
            raise ValueError(f"unknown method {self.method!r} (want 'nomad'|'infonc')")
        if self.strategy not in ("auto", "local", "sharded", "hierarchical"):
            raise ValueError(
                f"unknown strategy {self.strategy!r} "
                "(want 'auto'|'local'|'sharded'|'hierarchical')"
            )
        if self.build_strategy not in ("auto", "local", "sharded", "distributed"):
            raise ValueError(
                f"unknown build_strategy {self.build_strategy!r} "
                "(want 'auto'|'local'|'sharded'|'distributed')"
            )
        if (
            self.build_block_rows < 1
            or self.build_max_rounds < 1
            or self.build_candidates < 1
        ):
            raise ValueError(
                "build_block_rows, build_max_rounds and build_candidates "
                "must be >= 1"
            )
        if self.chunk_rows < 0:
            raise ValueError("chunk_rows must be >= 0 (0 = auto)")
        if self.store_max_shards < 1:
            raise ValueError("store_max_shards must be >= 1")
        if self.store_dtype not in ("float32", "float16", "bfloat16"):
            raise ValueError(
                f"unknown store_dtype {self.store_dtype!r} "
                "(want 'float32'|'float16'|'bfloat16')"
            )
        if self.serve_strategy not in ("auto", "local", "sharded"):
            raise ValueError(
                f"unknown serve_strategy {self.serve_strategy!r} "
                "(want 'auto'|'local'|'sharded')"
            )
        if self.serve_microbatch < 1 or self.serve_knn_block < 1:
            raise ValueError("serve_microbatch and serve_knn_block must be >= 1")
        if self.transform_steps < 0 or self.transform_lr < 0:
            raise ValueError("transform_steps and transform_lr must be >= 0")
        if self.service_max_delay_s < 0:
            raise ValueError("service_max_delay_s must be >= 0")
        if self.service_cache_entries < 0:
            raise ValueError("service_cache_entries must be >= 0 (0 disables)")
        if self.partial_refine_epochs < 0:
            raise ValueError("partial_refine_epochs must be >= 0 (0 = place only)")
        if self.use_pallas is not None:
            warnings.warn(
                "NomadConfig.use_pallas is deprecated; use "
                "kernel_impl='auto'|'pallas'|'jnp' instead",
                DeprecationWarning,
                stacklevel=3,
            )

    def resolved_lr0(self) -> float:
        return self.lr0 if self.lr0 > 0 else self.n_points / 10.0

    def resolved_kernel_impl(self) -> str:
        """The registry ``impl`` argument this run dispatches kernels with."""
        if self.kernel_impl:
            return self.kernel_impl
        if self.use_pallas is None:
            return "auto"
        return "auto" if self.use_pallas else "jnp"

    def resolved_transform_lr(self) -> float:
        """Per-row serve lr. Fit's mean-of-batch update gives each touched
        row an effective step of lr/batch_size, and by the last epoch the
        linear anneal has scaled lr down by ~1/n_epochs — the regime the
        frozen equilibrium was reached in, so that is where a new point's
        refinement starts (the serve scan anneals it further to 0)."""
        if self.transform_lr > 0:
            return self.transform_lr
        return self.resolved_lr0() / self.batch_size / max(self.n_epochs, 1)

    def resolved_chunk_rows(self) -> int:
        """The row-chunk size streamed pipeline stages read stores with."""
        if self.chunk_rows > 0:
            return self.chunk_rows
        from repro.data.store import DEFAULT_CHUNK_ROWS

        return DEFAULT_CHUNK_ROWS

    def resolved_steps_per_epoch(self) -> int:
        if self.steps_per_epoch:
            return self.steps_per_epoch
        return max(1, -(-self.n_points // self.batch_size))

    @property
    def cluster_capacity(self) -> int:
        cap = int(self.capacity_slack * self.n_points / self.n_clusters)
        return max(cap, self.n_neighbors + 2)

    def replace(self, **kw) -> "NomadConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A smoke-test-sized config of the same family (assignment requirement).

    Keeps the family topology (MoE period, attn interleave, SWA, qk-norm …)
    but shrinks widths/depths/vocab so one train step runs on CPU in <1 s.
    """

    kw: dict = dict(
        name=cfg.name + "-reduced",
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 16),
        d_model=128,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32 if cfg.n_heads else cfg.head_dim,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        # capacity ≥ E/k ⇒ drop-free routing, so tests comparing runs of
        # different lengths (prefill vs full forward) see identical math
        capacity_factor=8.0,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=32,
        sliding_window=min(cfg.sliding_window, 16),
        n_vision_patches=min(cfg.n_vision_patches, 8),
        head_pad_to=1,
        vocab_pad_to=1,
        param_dtype="float32",
        compute_dtype="float32",
        opt_moment_dtype="float32",
        accum_steps=1,
        attn_chunk=64,
        remat="none",
    )
    if cfg.family == "hybrid":
        # keep the 1:7 interleave with two meta-blocks
        kw["attn_period"] = cfg.attn_period
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)

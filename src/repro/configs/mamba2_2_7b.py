"""Mamba-2 2.7B (SSD — state-space duality).

64L d_model=2560, attention-free, no dense MLP block (the Mamba-2 block is
the whole layer), vocab=50280, ssm_state=128. [arXiv:2405.21060; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=0,  # no separate MLP block
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    accum_steps=8,
    source="arXiv:2405.21060 (unverified)",
)

"""Mixtral 8x7B.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, 8 experts top-2,
sliding-window attention (w=4096). SWA makes the arch sub-quadratic, so it
runs the ``long_500k`` cell (the KV cache is a 4096-token ring buffer).
[arXiv:2401.04088; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    n_experts=8,
    top_k=2,
    moe_period=1,
    sliding_window=4096,
    rope_theta=1e6,
    accum_steps=8,
    source="arXiv:2401.04088",
)

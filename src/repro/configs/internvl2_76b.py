"""InternVL2-76B backbone (InternViT + Llama-3-70B-class LM).

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The vision frontend
(InternViT) is a stub per the assignment: ``input_specs`` provides
precomputed patch embeddings which are prepended to the text embeddings
(n_vision_patches of the seq_len budget). [arXiv:2404.16821; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    n_vision_patches=256,
    rope_theta=5e5,
    accum_steps=8,
    source="arXiv:2404.16821 (unverified)",
)

from repro.data.store import (
    ArrayStore,
    EmbeddingStore,
    MemmapStore,
    ShardedStore,
    as_store,
    is_store,
    stream_chunks,
    write_sharded,
)
from repro.data.synthetic import gaussian_mixture, hierarchical_mixture, swiss_roll

__all__ = [
    "ArrayStore",
    "EmbeddingStore",
    "MemmapStore",
    "ShardedStore",
    "as_store",
    "is_store",
    "stream_chunks",
    "write_sharded",
    "gaussian_mixture",
    "hierarchical_mixture",
    "swiss_roll",
]

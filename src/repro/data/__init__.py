from repro.data.synthetic import gaussian_mixture, hierarchical_mixture, swiss_roll

__all__ = ["gaussian_mixture", "hierarchical_mixture", "swiss_roll"]

"""Out-of-core embedding stores: chunked row access over corpora on disk.

The paper's headline demonstration — a map of Multilingual Wikipedia —
needs an ``(N, D)`` float32 matrix that does not fit in host RAM. Every
consumer in this repo (``prepare_inputs``, the streamed
:class:`repro.index.build.IndexBuilder` path, PCA init, ``MapServer``
query batches) therefore reads through ONE interface,
:class:`EmbeddingStore`:

* :class:`ArrayStore`   — an in-memory ``np.ndarray`` (or ``np.memmap``)
  behind the same chunked API; the zero-copy adapter the equivalence
  tests stream through.
* :class:`MemmapStore`  — a single ``.npy`` file opened with
  ``mmap_mode="r"``; pages are file-backed and evictable, so host RSS
  stays bounded by what the OS keeps resident.
* :class:`ShardedStore` — a directory of row-block shards
  (``shard-00000.npy``, …) described by ``meta.json``. Shards are read
  with *eager* ``np.load`` one at a time (anonymous memory, freed after
  the chunk), which keeps the RSS high-watermark at O(shard) — the
  format the larger-than-RAM pipeline is built around.

``read()`` always returns **float32** rows regardless of the storage
dtype — the cast happens per chunk, never as a full-array temporary.
Storage dtypes: ``float32``, ``float16``, and ``bfloat16`` (halves the
disk/PCIe footprint; accumulation stays f32 on device). NumPy cannot
round-trip ``ml_dtypes.bfloat16`` through ``.npy`` (the logical dtype
degrades to raw ``|V2``), so bf16 shards hold the raw ``uint16`` bit
patterns and ``meta.json`` records the logical dtype.

``write_sharded()`` converts any array/store/chunk-iterator into the
sharded layout; the CLI front end is::

    python -m repro.data.store convert corpus.npy corpus_store/ \
        --rows-per-shard 65536 --dtype bfloat16
    python -m repro.data.store info corpus_store/

``stream_chunks()`` is the double-buffered host→device feed every
streamed pipeline stage uses: a background :class:`repro.data.loader.
Prefetcher` reads chunk *i+1* from disk while the device works on *i*.
"""

from __future__ import annotations

import json
import os
from typing import Iterable, Iterator, Optional, Tuple, Union

import numpy as np

META_NAME = "meta.json"
STORE_FORMAT = "repro-embedding-store"
SHARD_PATTERN = "shard-{:05d}.npy"

#: storage dtypes a store may hold on disk (reads always upcast to f32)
STORE_DTYPES = ("float32", "float16", "bfloat16")

#: the chunk size streamed consumers default to when cfg.chunk_rows is 0 —
#: the ONE definition (NomadConfig.resolved_chunk_rows, prepare_inputs and
#: pca_init_streamed all resolve through it; drift would break the
#: "chunk boundaries depend only on (N, chunk_rows)" contract)
DEFAULT_CHUNK_ROWS = 8192


def _bfloat16_dtype():
    """The ml_dtypes bfloat16 dtype, or an actionable error without it."""
    try:
        import ml_dtypes
    except ImportError as e:  # pragma: no cover - env without jax's dep
        raise RuntimeError(
            "bfloat16 stores need the ml_dtypes package (shipped with jax); "
            "install it or use store dtype 'float32'/'float16'"
        ) from e
    return np.dtype(ml_dtypes.bfloat16)


def _check_store_dtype(name: str) -> str:
    if name not in STORE_DTYPES:
        raise ValueError(
            f"unknown store dtype {name!r} (want one of {STORE_DTYPES})"
        )
    return name


def _encode(chunk: np.ndarray, dtype: str) -> np.ndarray:
    """float rows → the on-disk representation of ``dtype``."""
    if dtype == "bfloat16":
        # raw bit patterns: .npy cannot represent the logical bf16 dtype
        return chunk.astype(_bfloat16_dtype()).view(np.uint16)
    return chunk.astype(np.dtype(dtype), copy=False)


def _decode(raw: np.ndarray, dtype: str) -> np.ndarray:
    """On-disk representation → float32 rows (the f32-accumulation side)."""
    if dtype == "bfloat16":
        return raw.view(_bfloat16_dtype()).astype(np.float32)
    return raw.astype(np.float32, copy=False)


def _disk_dtype(dtype: str) -> np.dtype:
    """The numpy dtype shard *files* hold (bf16 → raw uint16 bits)."""
    _check_store_dtype(dtype)
    return np.dtype(np.uint16) if dtype == "bfloat16" else np.dtype(dtype)


def _commit_meta(
    out_dir: str, n_rows: int, dim: int, dtype: str, files, shard_rows
) -> None:
    """Write ``meta.json`` atomically (tmp + rename) — the single place the
    store format is stamped; every writer (``write_sharded``, the index
    build's x_rows spill) commits through it, so a crashed write never
    leaves a directory that parses as a store."""
    meta = {
        "format": STORE_FORMAT,
        "version": 1,
        "n_rows": int(n_rows),
        "dim": int(dim),
        "dtype": dtype,
        "shards": list(files),
        "shard_rows": [int(r) for r in shard_rows],
    }
    tmp = os.path.join(out_dir, META_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1)
    os.replace(tmp, os.path.join(out_dir, META_NAME))


# ---------------------------------------------------------------------------
# The interface
# ---------------------------------------------------------------------------


class EmbeddingStore:
    """Uniform chunked-read interface over an ``(N, D)`` row source.

    Subclasses set :attr:`shape`, :attr:`dtype_name` (the *storage*
    dtype), :attr:`path` (``None`` for in-memory) and implement
    :meth:`_read_raw`. Everything a consumer touches — :meth:`read`,
    :meth:`read_rows`, :meth:`iter_chunks` — returns float32.
    """

    shape: Tuple[int, int]
    dtype_name: str
    path: Optional[str] = None

    # -- to be implemented -----------------------------------------------------

    def _read_raw(self, start: int, stop: int) -> np.ndarray:
        raise NotImplementedError

    # -- the shared surface ----------------------------------------------------

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def dim(self) -> int:
        return self.shape[1]

    def __len__(self) -> int:
        return self.shape[0]

    def read(self, start: int, stop: int) -> np.ndarray:
        """Rows ``[start, stop)`` as a float32 ``(stop-start, D)`` array."""
        n = self.shape[0]
        if not (0 <= start <= stop <= n):
            raise IndexError(f"row range [{start}, {stop}) outside [0, {n})")
        return _decode(self._read_raw(start, stop), self.dtype_name)

    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        """Gather arbitrary rows (float32). Default: range-read per run of
        consecutive indices — subclasses with cheaper gathers override."""
        rows = np.asarray(rows, np.int64)
        out = np.empty((rows.size, self.shape[1]), np.float32)
        order = np.argsort(rows, kind="stable")
        sorted_rows = rows[order]
        s = 0
        while s < sorted_rows.size:
            e = s + 1
            while e < sorted_rows.size and sorted_rows[e] == sorted_rows[e - 1] + 1:
                e += 1
            block = self.read(int(sorted_rows[s]), int(sorted_rows[e - 1]) + 1)
            out[order[s:e]] = block
            s = e
        return out

    def iter_chunks(
        self, chunk_rows: int
    ) -> Iterator[Tuple[int, np.ndarray]]:
        """Yield ``(start, chunk)`` covering all rows in order; the final
        chunk is ragged when ``chunk_rows`` does not divide N."""
        if chunk_rows < 1:
            raise ValueError("chunk_rows must be >= 1")
        n = self.shape[0]
        for s in range(0, n, chunk_rows):
            yield s, self.read(s, min(s + chunk_rows, n))

    def process_row_range(
        self, process_index: int, process_count: int
    ) -> Tuple[int, int]:
        """The contiguous row range process ``process_index`` of
        ``process_count`` owns — the balanced split the multi-process
        pipeline reads through, so no process ever touches all N rows.
        Ranges are contiguous and in process order: concatenating them in
        order reproduces the store exactly."""
        if not (0 <= process_index < process_count):
            raise ValueError(
                f"process_index {process_index} outside [0, {process_count})"
            )
        n = self.shape[0]
        base, extra = divmod(n, process_count)
        start = process_index * base + min(process_index, extra)
        stop = start + base + (1 if process_index < extra else 0)
        return start, stop

    def materialize(self) -> np.ndarray:
        """The full float32 array — an explicit O(N·D) host allocation."""
        out = np.empty(self.shape, np.float32)
        for s, chunk in self.iter_chunks(max(1, min(65536, self.shape[0]))):
            out[s : s + chunk.shape[0]] = chunk
        return out

    def __array__(self, dtype=None, copy=None):
        a = self.materialize()
        return a.astype(dtype) if dtype is not None else a


def is_store(x) -> bool:
    """True iff ``x`` goes through the chunked-read interface."""
    return isinstance(x, EmbeddingStore)


# ---------------------------------------------------------------------------
# Implementations
# ---------------------------------------------------------------------------


class ArrayStore(EmbeddingStore):
    """An in-memory array (or ``np.memmap``) behind the store interface.

    Wrapping costs nothing: reads are slices, cast to float32 per chunk —
    a memmap input therefore never materialises a full-size temporary.
    """

    def __init__(self, x: np.ndarray):
        if x.ndim != 2:
            raise ValueError(f"expected a 2-D (n, dim) array, got {x.shape}")
        self._x = x
        self.shape = (int(x.shape[0]), int(x.shape[1]))
        self.dtype_name = str(x.dtype)
        self.path = getattr(x, "filename", None)

    def _read_raw(self, start, stop):
        return self._x[start:stop]

    def read(self, start, stop):
        chunk = self._x[start:stop]
        return np.asarray(chunk, np.float32)  # per-chunk cast/copy only

    def read_rows(self, rows):
        return np.asarray(self._x[np.asarray(rows, np.int64)], np.float32)


class MemmapStore(EmbeddingStore):
    """A single ``.npy`` file opened with ``mmap_mode="r"``."""

    def __init__(self, path: str):
        self.path = str(path)
        self._mm = np.load(self.path, mmap_mode="r")
        if self._mm.ndim != 2:
            raise ValueError(
                f"{path}: expected a 2-D (n, dim) .npy, got shape {self._mm.shape}"
            )
        if self._mm.dtype.kind == "V":
            raise ValueError(
                f"{path}: raw void dtype — bfloat16 cannot round-trip through "
                "a bare .npy; convert it to a sharded store "
                "(python -m repro.data.store convert) which records the "
                "logical dtype in meta.json"
            )
        self.shape = (int(self._mm.shape[0]), int(self._mm.shape[1]))
        self.dtype_name = str(self._mm.dtype)

    def _read_raw(self, start, stop):
        return self._mm[start:stop]

    def read(self, start, stop):
        return np.asarray(self._mm[start:stop], np.float32)

    def read_rows(self, rows):
        return np.asarray(self._mm[np.asarray(rows, np.int64)], np.float32)


class ShardedStore(EmbeddingStore):
    """A directory of row-block shards + ``meta.json``.

    Shards are loaded *eagerly* (regular ``np.load``, anonymous memory)
    one at a time with a one-shard decoded cache, so a sequential pass
    keeps host RSS at O(shard) — unlike a memmap, whose touched pages
    linger in RSS until the OS needs them back.
    """

    def __init__(self, directory: str):
        self.path = str(directory)
        meta_path = os.path.join(self.path, META_NAME)
        if not os.path.exists(meta_path):
            raise FileNotFoundError(
                f"{self.path}: no {META_NAME} — not an embedding store "
                "(create one with repro.data.store.write_sharded)"
            )
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("format") != STORE_FORMAT:
            raise ValueError(
                f"{meta_path}: format {meta.get('format')!r} is not "
                f"{STORE_FORMAT!r}"
            )
        self.dtype_name = _check_store_dtype(meta["dtype"])
        self.shape = (int(meta["n_rows"]), int(meta["dim"]))
        self._files = list(meta["shards"])
        self._rows = np.asarray(meta["shard_rows"], np.int64)
        if len(self._files) != self._rows.size or self._rows.size == 0:
            raise ValueError(f"{meta_path}: empty or inconsistent shard list")
        if (self._rows <= 0).any():
            bad = int(np.argmax(self._rows <= 0))
            raise ValueError(
                f"{meta_path}: shard {self._files[bad]!r} declares "
                f"{int(self._rows[bad])} rows — every shard must hold at "
                "least one row"
            )
        if int(self._rows.sum()) != self.shape[0]:
            raise ValueError(
                f"{meta_path}: shard rows sum to {int(self._rows.sum())} "
                f"but n_rows is {self.shape[0]}"
            )
        self._starts = np.concatenate([[0], np.cumsum(self._rows)])
        self._cache: Tuple[int, Optional[np.ndarray]] = (-1, None)

    def _shard_f32(self, i: int) -> np.ndarray:
        ci, chunk = self._cache
        if ci == i and chunk is not None:
            return chunk
        raw = np.load(os.path.join(self.path, self._files[i]))
        want = (int(self._rows[i]), self.shape[1])
        if raw.shape != want:
            raise ValueError(
                f"{self._files[i]}: shape {raw.shape} does not match "
                f"meta.json ({want})"
            )
        chunk = _decode(raw, self.dtype_name)
        self._cache = (i, chunk)
        return chunk

    def _read_raw(self, start, stop):  # pragma: no cover - read() overrides
        raise NotImplementedError

    def read(self, start, stop):
        n = self.shape[0]
        if not (0 <= start <= stop <= n):
            raise IndexError(f"row range [{start}, {stop}) outside [0, {n})")
        if start == stop:
            return np.empty((0, self.shape[1]), np.float32)
        i0 = int(np.searchsorted(self._starts, start, side="right")) - 1
        i1 = int(np.searchsorted(self._starts, stop, side="left")) - 1
        parts = []
        for i in range(i0, i1 + 1):
            lo = max(start, int(self._starts[i])) - int(self._starts[i])
            hi = min(stop, int(self._starts[i + 1])) - int(self._starts[i])
            parts.append(self._shard_f32(i)[lo:hi])
        if len(parts) == 1:
            return np.ascontiguousarray(parts[0])
        return np.concatenate(parts, axis=0)

    def assigned_shards(
        self, process_index: int, process_count: int
    ) -> list:
        """Shard-file indices overlapping this process's
        :meth:`process_row_range` — which files a process actually opens
        when it streams its range (boundary shards may be shared with a
        neighbour process)."""
        start, stop = self.process_row_range(process_index, process_count)
        if start == stop:
            return []
        i0 = int(np.searchsorted(self._starts, start, side="right")) - 1
        i1 = int(np.searchsorted(self._starts, stop, side="left")) - 1
        return list(range(i0, i1 + 1))


# ---------------------------------------------------------------------------
# Writing
# ---------------------------------------------------------------------------


def _chunk_source(
    source: Union[np.ndarray, EmbeddingStore, Iterable[np.ndarray]],
    chunk_rows: int,
) -> Iterator[np.ndarray]:
    if isinstance(source, EmbeddingStore):
        for _s, chunk in source.iter_chunks(chunk_rows):
            yield chunk
    elif isinstance(source, np.ndarray):
        for s in range(0, source.shape[0], chunk_rows):
            yield source[s : s + chunk_rows]
    else:  # an iterable of 2-D row chunks (streamed generation)
        for chunk in source:
            yield np.asarray(chunk)


def sharded_grid(n_rows: int, rows_per_shard: int) -> Tuple[list, list]:
    """The canonical ``(files, shard_rows)`` layout of an ``n_rows`` store
    re-blocked at ``rows_per_shard`` — full shards plus one ragged tail.
    Writers that split the row space across processes all agree on this
    grid, so process 0 can commit ``meta.json`` for shards it never wrote."""
    files, shard_rows = [], []
    for i, s in enumerate(range(0, n_rows, rows_per_shard)):
        files.append(SHARD_PATTERN.format(i))
        shard_rows.append(min(rows_per_shard, n_rows - s))
    return files, shard_rows


def commit_sharded_meta(
    out_dir: str, n_rows: int, dim: int, *, rows_per_shard: int, dtype: str = "float32"
) -> ShardedStore:
    """Commit ``meta.json`` for a store whose shards were written by
    :func:`write_sharded` calls with ``commit=False`` (one per process).
    Call on exactly one process (process 0), after a barrier has ordered
    every peer's shard writes before it."""
    _check_store_dtype(dtype)
    files, shard_rows = sharded_grid(n_rows, rows_per_shard)
    missing = [f for f in files if not os.path.exists(os.path.join(out_dir, f))]
    if missing:
        raise FileNotFoundError(
            f"commit_sharded_meta({out_dir}): {len(missing)} shard file(s) "
            f"missing (first: {missing[0]}) — did every writer process "
            "finish before the commit?"
        )
    _commit_meta(out_dir, n_rows, dim, dtype, files, shard_rows)
    return ShardedStore(out_dir)


def write_sharded(
    source: Union[np.ndarray, EmbeddingStore, Iterable[np.ndarray]],
    out_dir: str,
    *,
    rows_per_shard: int = 65536,
    dtype: str = "float32",
    row_offset: int = 0,
    total_rows: Optional[int] = None,
    commit: bool = True,
) -> Optional[ShardedStore]:
    """Stream ``source`` into a sharded store at ``out_dir``.

    ``source`` may be an array, another store, or an iterable of 2-D row
    chunks (for corpora generated on the fly). Rows are re-blocked to
    exactly ``rows_per_shard`` per shard (ragged final shard), encoded to
    ``dtype``, and ``meta.json`` is committed last — a crashed convert
    never leaves a directory that parses as a store.

    Multi-process writes: with ``total_rows`` set, ``source`` covers only
    rows ``[row_offset, row_offset + len(source))`` of a ``total_rows``
    store whose other row ranges peer processes write concurrently.
    ``row_offset`` must land on a shard boundary (``rows_per_shard |
    row_offset``) so no shard file has two writers. Pass ``commit=False``
    on every process (returns ``None``), barrier, then have process 0
    alone call :func:`commit_sharded_meta` — the meta commit is the
    single atomic publish point, exactly as in the single-writer case.
    """
    _check_store_dtype(dtype)
    if rows_per_shard < 1:
        raise ValueError("rows_per_shard must be >= 1")
    if total_rows is None and row_offset:
        raise ValueError("row_offset needs total_rows (a multi-writer store)")
    if row_offset % rows_per_shard:
        raise ValueError(
            f"row_offset {row_offset} is not a multiple of rows_per_shard "
            f"{rows_per_shard} — a shard file would need two writers"
        )
    os.makedirs(out_dir, exist_ok=True)

    shard_base = row_offset // rows_per_shard
    files, shard_rows = [], []
    dim = None
    pending: list = []
    pending_rows = 0

    def flush(buf_rows: int):
        nonlocal pending, pending_rows
        block = pending[0] if len(pending) == 1 else np.concatenate(pending)
        take, rest = block[:buf_rows], block[buf_rows:]
        name = SHARD_PATTERN.format(shard_base + len(files))
        np.save(os.path.join(out_dir, name), _encode(take, dtype))
        files.append(name)
        shard_rows.append(int(take.shape[0]))
        pending = [rest] if rest.shape[0] else []
        pending_rows = int(rest.shape[0])

    written = 0
    for chunk in _chunk_source(source, rows_per_shard):
        if chunk.ndim != 2:
            raise ValueError(f"source chunk has shape {chunk.shape}, want 2-D")
        if dim is None:
            dim = int(chunk.shape[1])
        elif int(chunk.shape[1]) != dim:
            raise ValueError(
                f"source chunk dim {chunk.shape[1]} != first chunk dim {dim}"
            )
        if chunk.dtype == np.float64:
            chunk = chunk.astype(np.float32)  # per-chunk, never full-array
        pending.append(chunk)
        pending_rows += int(chunk.shape[0])
        written += int(chunk.shape[0])
        while pending_rows >= rows_per_shard:
            flush(rows_per_shard)
    if pending_rows:
        flush(pending_rows)
    if not files:
        raise ValueError("write_sharded: source produced no rows")

    if total_rows is not None:
        end = row_offset + written
        if end > total_rows:
            raise ValueError(
                f"write_sharded: rows [{row_offset}, {end}) overflow "
                f"total_rows={total_rows}"
            )
        if end != total_rows and written % rows_per_shard:
            raise ValueError(
                f"write_sharded: range [{row_offset}, {end}) ends mid-shard "
                f"({written} rows, rows_per_shard={rows_per_shard}) but is "
                "not the final range — the next writer's shard would have "
                "two owners"
            )
    if not commit:
        return None
    n_rows = total_rows if total_rows is not None else sum(shard_rows)
    if total_rows is not None and (row_offset or written != total_rows):
        raise ValueError(
            "write_sharded(commit=True) with a partial row range — peers "
            "own the other shards; use commit=False + commit_sharded_meta"
        )
    _commit_meta(out_dir, n_rows, dim, dtype, files, shard_rows)
    return ShardedStore(out_dir)


def copy_to_npy(store: EmbeddingStore, path: str, chunk_rows: int = 65536) -> str:
    """Chunked store → single float32 ``.npy`` (memmap-written, O(chunk)
    host RSS) — used to spill a store-backed index field beside an
    ``index.npz`` cache."""
    mm = np.lib.format.open_memmap(
        path, mode="w+", dtype=np.float32, shape=store.shape
    )
    for s, chunk in store.iter_chunks(chunk_rows):
        mm[s : s + chunk.shape[0]] = chunk
    mm.flush()
    del mm
    return path


# ---------------------------------------------------------------------------
# Resolution + streaming
# ---------------------------------------------------------------------------


def as_store(x) -> EmbeddingStore:
    """Anything row-shaped → an :class:`EmbeddingStore`.

    Accepts a store (returned as-is), an ``np.ndarray``/``np.memmap``
    (wrapped zero-copy), a ``.npy`` path (memmap), or a sharded-store
    directory.
    """
    if is_store(x):
        return x
    if isinstance(x, np.ndarray):
        return ArrayStore(x)
    if isinstance(x, (str, os.PathLike)):
        p = os.fspath(x)
        if os.path.isdir(p):
            return ShardedStore(p)
        if p.endswith(".npy"):
            return MemmapStore(p)
        raise ValueError(
            f"{p}: not a sharded-store directory or a .npy file"
        )
    raise TypeError(
        f"cannot adapt {type(x).__name__} into an EmbeddingStore "
        "(want ndarray, store, .npy path, or store directory)"
    )


def stream_chunks(
    store: EmbeddingStore, chunk_rows: int, *, depth: int = 2
) -> Iterator[Tuple[int, np.ndarray]]:
    """One double-buffered pass over ``store``: a background
    :class:`repro.data.loader.Prefetcher` reads chunk *i+1* from disk
    while the consumer (typically a device step) works on chunk *i*.

    Yields the same ``(start, float32 chunk)`` schedule as
    ``store.iter_chunks(chunk_rows)`` — chunk boundaries depend only on
    ``(N, chunk_rows)``, never on the store's native shard layout, which
    is what makes streamed results identical across containers.
    """
    from repro.data.loader import Prefetcher

    n = store.shape[0]
    n_chunks = max(1, -(-n // chunk_rows))

    def make(step: int):
        s = step * chunk_rows
        return s, store.read(s, min(s + chunk_rows, n))

    # max_steps bounds the worker to exactly one pass; a read error inside
    # the worker re-raises here instead of hanging the consumer
    pf = Prefetcher(make, depth=depth, max_steps=n_chunks)
    try:
        for _ in range(n_chunks):
            _step, (s, chunk) = next(pf)
            yield s, chunk
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# CLI: python -m repro.data.store {convert,info}
# ---------------------------------------------------------------------------


def _main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.data.store",
        description="Convert/inspect on-disk embedding stores.",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    cv = sub.add_parser(
        "convert", help="re-block a .npy / store into a sharded store"
    )
    cv.add_argument("src", help=".npy file or existing store directory")
    cv.add_argument("out_dir", help="output sharded-store directory")
    cv.add_argument("--rows-per-shard", type=int, default=65536)
    cv.add_argument("--dtype", default="float32", choices=list(STORE_DTYPES))

    info = sub.add_parser("info", help="describe a store")
    info.add_argument("src", help=".npy file or store directory")

    args = ap.parse_args(argv)
    if args.cmd == "convert":
        st = write_sharded(
            as_store(args.src),
            args.out_dir,
            rows_per_shard=args.rows_per_shard,
            dtype=args.dtype,
        )
        print(
            f"wrote {st.path}: {st.n_rows} rows x {st.dim} dims, "
            f"dtype {st.dtype_name}, {len(st._files)} shard(s)"
        )
        return 0
    st = as_store(args.src)
    kind = type(st).__name__
    print(f"{kind}: {st.n_rows} rows x {st.dim} dims, dtype {st.dtype_name}")
    if isinstance(st, ShardedStore):
        print(f"shards: {len(st._files)} (rows per shard: {st._rows.tolist()})")
        from repro.configs.base import NomadConfig

        cap = NomadConfig().store_max_shards
        print(
            f"spill fd cap: {cap} shards (NomadConfig.store_max_shards; "
            "index-build spills re-block above it)"
        )
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(_main())

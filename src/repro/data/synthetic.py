"""Synthetic corpora standing in for the paper's datasets.

The paper maps embedding corpora (ArXiv/ImageNet/PubMed/Wikipedia vectors).
Offline we use generators whose ground truth is known, so the quality
metrics (NP@k, triplet accuracy) and multiscale structure checks are
meaningful:

* ``gaussian_mixture``     — ArXiv/ImageNet stand-in: well-separated
  clusters on a hypersphere shell (embedding-like norm concentration).
* ``hierarchical_mixture`` — Wikipedia stand-in: two-level cluster tree for
  the Fig. 4 multiscale analysis (super-clusters of sub-clusters).
* ``swiss_roll``           — classic manifold for local-structure sanity.
"""

from __future__ import annotations

import numpy as np


def gaussian_mixture(
    n: int,
    dim: int,
    n_components: int = 10,
    spread: float = 0.15,
    seed: int = 0,
):
    """Returns (x (n, dim) float32, labels (n,) int64)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (n_components, dim))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    labels = rng.integers(0, n_components, n)
    x = centers[labels] + rng.normal(0, spread / np.sqrt(dim), (n, dim))
    return x.astype(np.float32), labels


def gaussian_mixture_store(
    out_dir: str,
    n: int,
    dim: int,
    n_components: int = 10,
    spread: float = 0.15,
    seed: int = 0,
    *,
    chunk_rows: int = 8192,
    rows_per_shard: int = 65536,
    dtype: str = "float32",
):
    """:func:`gaussian_mixture`, generated chunk-by-chunk straight into a
    sharded on-disk store — the corpus never materialises in host RAM.

    Returns ``(store, labels)``. ``np.random.Generator`` draws samples
    sequentially from its bit stream, so chunked ``normal`` calls produce
    exactly the rows one ``(n, dim)`` call would: the store holds the same
    float32 values as ``gaussian_mixture(n, dim, ...)`` (tested), which is
    what lets the RSS benchmark compare monolithic vs streamed builds of
    the *same* data.
    """
    from repro.data.store import write_sharded

    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (n_components, dim))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    labels = rng.integers(0, n_components, n)

    def chunks():
        for s in range(0, n, chunk_rows):
            lab = labels[s : s + chunk_rows]
            yield (
                centers[lab]
                + rng.normal(0, spread / np.sqrt(dim), (lab.size, dim))
            ).astype(np.float32)

    store = write_sharded(
        chunks(), out_dir, rows_per_shard=rows_per_shard, dtype=dtype
    )
    return store, labels


def class_token_corpus(
    n_docs: int,
    seq_len: int,
    vocab_size: int,
    n_classes: int = 8,
    keep: float = 0.7,
    seed: int = 0,
):
    """A token corpus with latent document classes — the embed→map
    pipeline's stand-in for a real text corpus.

    Each class owns a base token sequence; a document keeps each base
    token with probability ``keep`` and replaces the rest with uniform
    noise, so documents of one class share ~``keep`` of their tokens and
    an embedding model (even an untrained one: mean-pooled token
    embeddings are class-token histograms) separates the classes.

    Returns ``(tokens (n_docs, seq_len) int32, classes (n_docs,) int64)``.
    """
    rng = np.random.default_rng(seed)
    classes = rng.integers(0, n_classes, n_docs)
    base = rng.integers(0, vocab_size, (n_classes, seq_len))
    noise = rng.integers(0, vocab_size, (n_docs, seq_len))
    mask = rng.random((n_docs, seq_len)) < keep
    tokens = np.where(mask, base[classes], noise).astype(np.int32)
    return tokens, classes


def hierarchical_mixture(
    n: int,
    dim: int,
    n_super: int = 6,
    n_sub: int = 5,
    super_spread: float = 0.35,
    sub_spread: float = 0.06,
    seed: int = 0,
):
    """Two-level tree: returns (x, super_labels, sub_labels)."""
    rng = np.random.default_rng(seed)
    supers = rng.normal(0, 1, (n_super, dim))
    supers /= np.linalg.norm(supers, axis=1, keepdims=True)
    subs = supers[:, None, :] + rng.normal(
        0, super_spread / np.sqrt(dim), (n_super, n_sub, dim)
    )
    sup = rng.integers(0, n_super, n)
    sub = rng.integers(0, n_sub, n)
    x = subs[sup, sub] + rng.normal(0, sub_spread / np.sqrt(dim), (n, dim))
    return x.astype(np.float32), sup, sup * n_sub + sub


def swiss_roll(n: int, dim: int = 3, noise: float = 0.02, seed: int = 0):
    """Swiss roll lifted into ``dim`` dimensions by a random rotation."""
    rng = np.random.default_rng(seed)
    t = 1.5 * np.pi * (1 + 2 * rng.random(n))
    h = 21.0 * rng.random(n)
    x3 = np.stack([t * np.cos(t), h, t * np.sin(t)], axis=1)
    x3 = (x3 - x3.mean(0)) / x3.std(0)
    x3 += rng.normal(0, noise, x3.shape)
    if dim > 3:
        q, _ = np.linalg.qr(rng.normal(0, 1, (dim, dim)))
        x = x3 @ q[:3, :]
    else:
        x = x3
    return x.astype(np.float32), t

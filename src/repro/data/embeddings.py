"""Embedding-extraction bridge: model zoo → NOMAD Projection.

The paper maps corpora embedded by external models (Nomic Embed, OpenCLIP,
BGE-M3). Here any zoo architecture plays that role: run the model over
token batches, mean-pool the final hidden states, and the resulting vectors
feed ``NomadProjection`` (see examples/embed_and_map.py). This is the
arch-applicability story of DESIGN.md §5: the assigned architectures are
embedding *producers* for the paper's technique.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.layers import rms_norm


def hidden_states(params, cfg: ArchConfig, tokens=None, embeds=None, patches=None):
    """Forward pass returning the final-norm hidden states (B, S, D)."""
    x = lm.embed_in(params, cfg, tokens=tokens, embeds=embeds, patches=patches)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    causal = not cfg.encoder_only
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid":
        body = lm._meta_block_body(cfg, positions, causal, with_cache=False)
        (x, _), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    else:
        body = lm._homogeneous_body(cfg, positions, causal, with_cache=False)
        (x, _), _ = jax.lax.scan(body, (x, aux0), params["layers"])
    return rms_norm(x, params["final_ln"])


def embed_corpus(
    params,
    cfg: ArchConfig,
    token_batches,
    *,
    pool: str = "mean",
) -> np.ndarray:
    """Iterate token batches (B, S) → pooled vectors (N, D) on host."""
    fwd = jax.jit(lambda p, t: hidden_states(p, cfg, tokens=t))
    outs = []
    for toks in token_batches:
        h = fwd(params, jnp.asarray(toks))
        if pool == "mean":
            v = jnp.mean(h, axis=1)
        elif pool == "last":
            v = h[:, -1, :]
        else:
            raise ValueError(pool)
        outs.append(np.asarray(v, np.float32))
    return np.concatenate(outs, axis=0)

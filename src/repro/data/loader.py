"""Deterministic sharded host data loader with background prefetch.

For the LM substrate: an infinite token stream, seeded per (stream-name,
shard, step) so every host in a multi-host job materialises exactly its own
rows of the global batch without coordination — restart-safe resumption
comes for free (the step counter is in the checkpoint).

On this single-host container the loader produces the *global* batch
(shard = 0 of 1) and jit's input sharding scatters it; on a real multi-host
deployment each process passes its ``(shard, n_shards)`` and the arrays feed
``jax.make_array_from_process_local_data``.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class TokenStream:
    """Synthetic next-token corpus: Zipf-distributed ids with a Markov twist,
    so the loss has learnable structure (tests assert loss decreases)."""

    def __init__(self, vocab_size: int, seq_len: int, name: str = "train"):
        self.vocab = vocab_size
        self.seq = seq_len
        self.name = name

    def batch(self, step: int, batch_size: int, shard: int = 0, n_shards: int = 1):
        rows = batch_size // n_shards
        seed = abs(hash((self.name, step, shard))) % (2**31)
        rng = np.random.default_rng(seed)
        # zipf-ish marginal, clipped to vocab
        z = rng.zipf(1.3, size=(rows, self.seq + 1)) % self.vocab
        # inject determinism: every even position repeats the previous token
        # with p=0.5 (learnable bigram structure)
        rep = rng.random((rows, self.seq)) < 0.5
        z = z.astype(np.int64)
        for t in range(1, self.seq + 1, 2):
            z[:, t] = np.where(rep[:, t - 1], z[:, t - 1], z[:, t])
        return {
            "tokens": z[:, :-1].astype(np.int32),
            "labels": z[:, 1:].astype(np.int32),
        }


class Prefetcher:
    """Runs ``make(step)`` on a worker thread, ``depth`` batches ahead.

    ``max_steps`` bounds the worker to that many items (for one finite pass
    over a chunked store); ``None`` free-runs forever (the LM stream). Each
    item is built **once** and only the queue put retries on back-pressure —
    a slow consumer never triggers a re-read. A ``make`` exception is
    enqueued and re-raised in the consumer, so a failed disk read surfaces
    instead of hanging the pipeline on a dead worker.
    """

    def __init__(
        self, make, start_step: int = 0, depth: int = 2, max_steps=None
    ):
        self._make = make
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._max_steps = max_steps
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Retry-put until accepted or close(); True iff enqueued."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _run(self):
        step = self._step
        made = 0
        while not self._stop.is_set():
            if self._max_steps is not None and made >= self._max_steps:
                return
            try:
                item = (step, self._make(step))
            except BaseException as e:  # surfaces in the consumer
                self._put((step, e))
                return
            if not self._put(item):
                return
            step += 1
            made += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, item = self._q.get()
        if isinstance(item, BaseException):
            raise item
        return step, item

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)

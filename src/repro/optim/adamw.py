"""AdamW with optional int8-quantised moments.

State is a per-leaf pytree ``{"m": …, "v": …}`` plus a step counter. Leaves
smaller than ``QUANT_MIN_SIZE`` keep fp32 moments regardless of policy
(norm scales, per-head vectors — scales matter more than bytes there).
The first moment is symmetric int8; the second moment is stored on a sqrt
scale (strictly positive, dynamic range halves in log space).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.quantized import QTensor, dequantize_int8, maybe_dequantize, quantize_int8

QUANT_MIN_SIZE = 65_536


class AdamW(NamedTuple):
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"  # "float32" | "bfloat16" | "int8"

    # -- API ------------------------------------------------------------------

    def init(self, params) -> dict:
        def one(p):
            z = jnp.zeros(p.shape, jnp.float32)
            if self.moment_dtype == "int8" and p.size >= QUANT_MIN_SIZE:
                return {"m": quantize_int8(z), "v": quantize_int8(z, sqrt_scaled=True)}
            dt = jnp.bfloat16 if self.moment_dtype == "bfloat16" else jnp.float32
            return {"m": z.astype(dt), "v": z.astype(dt)}

        return {"mu": jax.tree.map(one, params), "count": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state, *args):
        count = state["count"] + 1
        lr = self.schedule(count)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        def one(p, g, mv):
            g = g.astype(jnp.float32)
            m = maybe_dequantize(mv["m"])
            v = maybe_dequantize(mv["v"])
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            if isinstance(mv["m"], QTensor):
                new_mv = {"m": quantize_int8(m), "v": quantize_int8(v, sqrt_scaled=True)}
            else:
                new_mv = {"m": m.astype(mv["m"].dtype), "v": v.astype(mv["v"].dtype)}
            return new_p, new_mv

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mv = treedef.flatten_up_to(state["mu"])
        out = [one(p, g, mv) for p, g, mv in zip(flat_p, flat_g, flat_mv)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        return new_params, {"mu": new_mu, "count": count}

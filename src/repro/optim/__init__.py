from repro.optim.schedules import constant, linear_decay, warmup_cosine
from repro.optim.adamw import AdamW
from repro.optim.sgd import SGD
from repro.optim.quantized import quantize_int8, dequantize_int8, QTensor

__all__ = [
    "AdamW",
    "SGD",
    "constant",
    "linear_decay",
    "warmup_cosine",
    "quantize_int8",
    "dequantize_int8",
    "QTensor",
]

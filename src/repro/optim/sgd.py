"""SGD (+ optional momentum) — the paper's optimizer for NOMAD Projection."""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class SGD(NamedTuple):
    schedule: Callable
    momentum: float = 0.0

    def init(self, params) -> dict:
        state = {"count": jnp.zeros((), jnp.int32)}
        if self.momentum:
            state["velocity"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return state

    def update(self, params, grads, state, *args):
        count = state["count"] + 1
        lr = self.schedule(count)
        if self.momentum:
            vel = jax.tree.map(
                lambda v, g: self.momentum * v + g.astype(jnp.float32),
                state["velocity"],
                grads,
            )
            new_params = jax.tree.map(
                lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype), params, vel
            )
            return new_params, {"count": count, "velocity": vel}
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params,
            grads,
        )
        return new_params, {"count": count}

"""Gradient compression for data-parallel all-reduce.

int8 block-quantised ``psum`` with error feedback [1-bit Adam / PowerSGD
lineage]: each shard keeps a residual of its quantisation error and folds it
into the next step's gradient, so the compression bias telescopes away.

This is a ``shard_map``-level tool: inside jit, the DP all-reduce is
implicit and XLA does not expose a quantisation hook; under ``shard_map``
the collective is ours, so we compress around it. Used by the optional
compressed-DP train step (see tests/test_compression.py) and available to
the NOMAD epoch step (where it is pointless by design — the paper's own
point is that only means cross devices — but the hook exists for the LM
substrate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant(x: jax.Array):
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), -1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequant(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(grads, axis_name: str, residuals):
    """all-reduce(mean) of int8-quantised grads with error feedback.

    Returns (reduced fp32 grads, new residuals). ``residuals`` must be a
    pytree of zeros_like(grads) on the first call.
    """

    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale, pad = _quant(g)
        sent = _dequant(q, scale, pad, g.shape)
        new_r = g - sent  # error feedback: what we failed to send
        # int8 payloads all-reduce as int32 partial sums (wire bytes ≈ ¼ of fp32
        # on TPU reductions of int8 inputs; we model the dtype explicitly).
        total = jax.lax.psum(sent, axis_name)
        n = jax.lax.psum(jnp.ones(()), axis_name)
        return total / n, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten([o[1] for o in out])

"""Row-wise int8 quantisation for optimizer moments.

At jamba-398B scale, fp32 Adam moments alone are 3.2 TB; int8 moments cut
that 4×, which is the difference between fitting and not fitting 16 GB/chip.

Layout (deliberately sharding-transparent — §Perf iteration 3): the int8
payload keeps the **parameter's own shape** and scales are per-row over the
last axis, so the moment tensors inherit the parameter's PartitionSpec
unchanged. (The first version blocked the *flattened* tensor, and
``reshape(-1)`` of a sharded dim forced XLA to replicate: measured 3.1 TiB
per device on jamba train — the single worst memory bug of the baseline.)

The second moment is stored on a sqrt scale: strictly positive, halves the
dynamic range in log space, and v's per-row spread is what per-row scaling
struggles with most.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jax.Array  # int8, same shape as the original tensor
    scale: jax.Array  # fp32, original shape minus the last axis
    sqrt_scaled: bool = False  # payload encodes sqrt(x) of an x ≥ 0 tensor


def quantize_int8(x: jax.Array, *, sqrt_scaled: bool = False) -> QTensor:
    x = x.astype(jnp.float32)
    if sqrt_scaled:
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale, sqrt_scaled=sqrt_scaled)


def dequantize_int8(t: QTensor) -> jax.Array:
    x = t.q.astype(jnp.float32) * t.scale[..., None]
    if t.sqrt_scaled:
        x = jnp.square(x)
    return x


def quantize_like(x: jax.Array, proto) -> "QTensor | jax.Array":
    if isinstance(proto, QTensor):
        return quantize_int8(x, sqrt_scaled=proto.sqrt_scaled)
    return x.astype(proto.dtype)


def maybe_dequantize(x) -> jax.Array:
    return dequantize_int8(x) if isinstance(x, QTensor) else x.astype(jnp.float32)


# key-aware registration so sharding rules can recognise ".scale" leaves
jax.tree_util.register_pytree_with_keys(
    QTensor,
    lambda t: (
        ((jax.tree_util.GetAttrKey("q"), t.q), (jax.tree_util.GetAttrKey("scale"), t.scale)),
        (t.sqrt_scaled,),
    ),
    lambda aux, ch: QTensor(q=ch[0], scale=ch[1], sqrt_scaled=aux[0]),
)

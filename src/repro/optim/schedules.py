"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def schedule(step):
        return jnp.asarray(lr, jnp.float32)

    return schedule


def linear_decay(lr0: float, total_steps: int, floor: float = 0.0):
    """The paper's schedule: lr0 annealed linearly to ``floor`` (default 0)."""

    def schedule(step):
        frac = 1.0 - jnp.minimum(step, total_steps) / max(total_steps, 1)
        return jnp.asarray(floor + (lr0 - floor) * frac, jnp.float32)

    return schedule


def warmup_cosine(lr0: float, warmup: int, total_steps: int, floor_frac: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr0 * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = lr0 * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos).astype(jnp.float32)

    return schedule

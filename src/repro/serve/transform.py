"""The jitted out-of-sample transform: frozen-neighbor NOMAD steps.

One batch of unseen rows is placed in four stages, all inside a single jit
(optionally wrapped in ``shard_map`` with the query rows sharded):

1. **assign** — nearest frozen k-means centroid per query, through the
   ``"kmeans_assign"`` registry kernel (the same fused distance+argmin the
   index build uses);
2. **kNN** — exact nearest neighbors inside the assigned frozen cluster
   block (:func:`repro.index.knn.query_cluster_knn`) — the §3.2 locality
   property, applied at query time. Edge weights follow Eq. 6 with the
   *query-side* rank (neighbor s gets e^{1/(s+1)}/Z): the tail-side rank
   of an unseen point would need the full (C, C) in-cell distance matrix
   per query cell, and both sides share the Z normaliser;
3. **init** — each query starts at the Cauchy-weighted mean of its frozen
   neighbors' positions, weights 1/(1+‖x_q − x_nb‖²) from the *high-dim*
   distances (NCVis-style: the noise-contrastive objective stays
   well-posed with one side frozen, so a good init is most of the work);
4. **optimize** — a ``lax.scan`` of ``transform_steps`` NOMAD steps in
   which only the query positions move: attraction through the fused
   ``"frozen_attract"`` kernel, repulsion through the same ``"cauchy_mean"``
   M̃ term training used (remote cells via frozen means, the own cell via
   frozen in-cell samples), lr linearly annealed.

**Every stage is per-row math against replicated frozen state, and the RNG
is folded per global query row** (``fold_in(key, row)``), so placements are
bit-identical across microbatch sizes and across local vs sharded serving
— the property tests/test_serve.py pins down.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses
from repro.core.cauchy import cauchy
from repro.core.rank_model import normalizer
from repro.index.knn import query_cluster_knn
from repro.serve.frozen import FrozenMap


def frozen_arrays(fz: FrozenMap) -> dict:
    """The FrozenMap as the flat dict pytree the jitted fn consumes."""
    K, C = fz.n_clusters, fz.capacity
    return {
        "theta": fz.theta_rows,
        "x_blocks": fz.x_rows.reshape(K, C, fz.dim),
        "centroids": fz.centroids,
        "counts": fz.counts,
        "means": fz.means,
        "inv_perm": fz.inv_perm,
    }


def make_transform_fn(
    fz: FrozenMap,
    *,
    steps: Optional[int] = None,
    lr: Optional[float] = None,
    mesh=None,
    axis: str = "serve",
    with_neighbors: bool = True,
):
    """Build the jitted batch-transform function for one FrozenMap.

    Returns ``fn(fz_arrays, qx (B, D), rows (B,) int32, seeds (B,) uint32,
    valid (B,) bool) -> (theta (B, d), own (B,), nb_ids (B, k),
    nb_dists (B, k), step_losses (steps,))``. With ``mesh`` given, the
    body runs under ``shard_map`` with queries row-sharded over ``axis``
    and the frozen state replicated; B must then divide by the mesh size.

    The RNG stream is ``fold_in(key(seeds[i]), rows[i])`` — folded per
    row from a *per-row* seed, so one batch may mix rows of several
    logical requests (each with its own seed and its own local row
    numbering) and every row still gets exactly the RNG a dedicated
    ``MapServer.transform(q, seed=...)`` call would have given it. This
    is what lets the service-layer batching engine coalesce concurrent
    requests into one device batch bit-identically.

    ``with_neighbors=False`` returns ``(theta, own, step_losses)`` only:
    jit dead-code-eliminates the neighbor-id unpermute + sqrt and skips
    two (B, k) host transfers — the placement-only service fast path.
    """
    cfg = fz.cfg
    C = fz.capacity
    k = cfg.n_neighbors
    S = cfg.n_exact_negatives
    T = cfg.transform_steps if steps is None else steps
    lr0 = cfg.resolved_transform_lr() if lr is None else lr
    impl = cfg.resolved_kernel_impl()
    knn_block = cfg.serve_knn_block
    n_noise = float(cfg.n_noise)
    n_total = float(fz.n_points)
    sharded = mesh is not None
    # Eq. 6 weight table, precomputed on HOST: as a traced jnp constant XLA
    # folds it differently under shard_map vs plain jit (one-ulp exp
    # differences), which would break the local ≡ sharded bit-equality
    w_rank = jnp.asarray(
        np.exp(1.0 / np.arange(1, k + 1, dtype=np.float32)) / normalizer(k),
        jnp.float32,
    )

    def body(fza, qx, rows, seeds, valid):
        from repro.kernels import registry

        # -- 1. assign to a frozen cell -------------------------------------
        own, _ = registry.dispatch(
            "kmeans_assign", qx.astype(jnp.float32), fza["centroids"], impl=impl
        )

        # -- 2. frozen in-cell kNN ------------------------------------------
        slot, nb_d2, nb_valid = query_cluster_knn(
            qx, own, fza["x_blocks"], fza["counts"], k, block=knn_block
        )
        nb_row = own[:, None] * C + slot  # (B, k) rows into theta/inv_perm
        nb_theta = jax.lax.stop_gradient(fza["theta"][nb_row])  # (B, k, d)
        nb_w = jnp.where(nb_valid, w_rank[None, :], 0.0)

        # -- 3. Cauchy-weighted init ----------------------------------------
        w_init = jnp.where(nb_valid, 1.0 / (1.0 + nb_d2), 0.0)
        theta0 = jnp.einsum(
            "bk,bkd->bd",
            w_init / jnp.maximum(jnp.sum(w_init, -1, keepdims=True), 1e-12),
            nb_theta,
        )

        # -- 4. frozen NOMAD steps ------------------------------------------
        counts_f = fza["counts"].astype(jnp.float32)
        p_cell = counts_f / n_total  # (K,)
        cell_w = n_noise * p_cell
        p_own = p_cell[own]  # (B,)
        cnt_own = jnp.maximum(fza["counts"][own], 1)
        n_valid = jnp.sum(valid)
        if sharded:
            n_valid = jax.lax.psum(n_valid, axis)
        # per-row RNG stream: batching/sharding-invariant by construction
        # (key(seed) then fold_in(row) — identical bits whether the key is
        # built host-side from one python int or traced from a seeds row)
        row_key = jax.vmap(
            lambda s, r: jax.random.fold_in(jax.random.key(s), r)
        )(seeds, rows)

        def step(theta, t):
            kt = jax.vmap(lambda kk: jax.random.fold_in(kk, t))(row_key)
            u = jax.vmap(lambda kk: jax.random.uniform(kk, (S,)))(kt)
            nslot = jnp.minimum(
                jnp.floor(u * cnt_own[:, None]).astype(jnp.int32),
                (cnt_own - 1)[:, None].astype(jnp.int32),
            )
            th_neg = jax.lax.stop_gradient(
                fza["theta"][own[:, None] * C + nslot]
            )  # (B, S, d)

            def loss_fn(th):
                m_tilde = losses.nomad_mean_term(
                    th, fza["means"], cell_w, own, impl
                )
                q_neg = cauchy(th[:, None, :], th_neg)  # (B, S)
                m_exact = (n_noise * p_own / S) * jnp.sum(q_neg, axis=-1)
                lb = registry.dispatch(
                    "frozen_attract", th, nb_theta, nb_w, m_tilde + m_exact,
                    impl=impl,
                )
                return jnp.sum(jnp.where(valid, lb, 0.0))

            loss_sum, g = jax.value_and_grad(loss_fn)(theta)
            if sharded:
                loss_sum = jax.lax.psum(loss_sum, axis)
            lr_t = lr0 * (1.0 - t.astype(jnp.float32) / max(T, 1))
            return theta - lr_t * g, loss_sum / jnp.maximum(n_valid, 1)

        theta, step_losses = jax.lax.scan(step, theta0, jnp.arange(T))

        if not with_neighbors:
            return theta, own, step_losses
        nb_ids = jnp.where(nb_valid, fza["inv_perm"][nb_row], -1)
        nb_dists = jnp.where(nb_valid, jnp.sqrt(nb_d2), jnp.inf)
        return theta, own, nb_ids, nb_dists, step_losses

    if not sharded:
        return jax.jit(body)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    fz_specs = jax.tree_util.tree_map(
        lambda a: P(*([None] * a.ndim)), frozen_arrays(fz)
    )
    if with_neighbors:
        out_specs = (P(axis, None), P(axis), P(axis, None), P(axis, None), P())
    else:
        out_specs = (P(axis, None), P(axis), P())
    sharded_body = shard_map(
        body,
        mesh=mesh,
        in_specs=(fz_specs, P(axis, None), P(axis), P(axis), P(axis)),
        out_specs=out_specs,
        check_rep=False,
    )
    return jax.jit(sharded_body)

"""The frozen state a fitted map is served from.

:class:`FrozenMap` is the device-resident bundle every transform touches:
the fitted positions θ (cluster-major, capacity-padded — the same layout
training used), the frozen §3.2 index geometry (cluster vectors,
centroids, counts), the per-cell position means the repulsive M̃ term
reads, and the row → original-id inverse permutation used to report
neighbor ids. It is built either

* from a finished fit (:meth:`from_fit` — the estimator does this
  automatically), or
* straight from a checkpoint directory (:meth:`from_checkpoint`): the θ
  row block comes from the latest ``step_*/`` checkpoint and the index
  from the ``index.npz`` cache written beside it — **no access to the raw
  training array**, which is the production serving story: the fleet that
  serves the map never holds the corpus that built it.

Everything in a FrozenMap is immutable by convention and by construction:
the transform path's gradients stop at the query positions (the
``frozen_attract`` kernel's VJP returns cotangents for θ_q and the
repulsive mass only), so serving can never perturb the map.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NomadConfig

if TYPE_CHECKING:
    from repro.core.nomad import FitResult
    from repro.index.ann import AnnIndex


@dataclasses.dataclass
class FrozenMap:
    """Device-resident frozen state of one fitted NOMAD map."""

    theta_rows: jax.Array  # (K·C, out_dim) fitted positions, cluster-major
    x_rows: jax.Array  # (K·C, D) frozen input vectors (padding rows = 0)
    centroids: jax.Array  # (K, D)
    counts: jax.Array  # (K,) int32 real points per cluster
    means: jax.Array  # (K, out_dim) per-cell position means (M̃ input)
    inv_perm: jax.Array  # (K·C,) int32 original point id per row (-1 = pad)
    capacity: int
    n_points: int
    cfg: NomadConfig

    @property
    def n_clusters(self) -> int:
        return int(self.counts.shape[0])

    @property
    def out_dim(self) -> int:
        return int(self.theta_rows.shape[1])

    @property
    def dim(self) -> int:
        return int(self.x_rows.shape[1])

    # -- public frozen-index kNN -----------------------------------------------

    def neighbors(self, vec, k: Optional[int] = None):
        """Corpus rows nearest to embedding vector(s) ``vec``, via the
        frozen §3.2 index: centroid assign → in-cell kNN → unpermute to
        original ids. This is the public "what lives near this vector?"
        query — the ``/explore`` endpoint and the examples use it instead
        of reaching into ``repro.index.knn`` internals.

        ``vec`` is ``(D,)`` or ``(B, D)``; returns ``(ids, dists)`` of
        shape ``(k,)``/``(B, k)`` — ``ids`` int32 original corpus ids
        (-1 padding when the cell holds fewer than ``k`` rows), ``dists``
        float32 Euclidean distances (inf on padding). ``k`` defaults to
        ``cfg.n_neighbors``. The jitted query is cached per ``k`` on the
        instance; results match the transform path's neighbor report
        bit-for-bit (same kernels, same order).
        """
        q = np.asarray(vec, np.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None, :]
        if q.ndim != 2 or q.shape[1] != self.dim:
            raise ValueError(
                f"neighbors: expected ({self.dim},) or (n, {self.dim}) "
                f"vectors, got shape {np.asarray(vec).shape}"
            )
        if not np.isfinite(q).all():
            raise ValueError("neighbors: query vectors contain NaN/Inf")
        kk = self.cfg.n_neighbors if k is None else int(k)
        if not 1 <= kk <= self.capacity:
            raise ValueError(
                f"neighbors: k={kk} outside [1, capacity={self.capacity}]"
            )
        cache = getattr(self, "_neighbors_jit", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_neighbors_jit", cache)
        fn = cache.get(kk)
        if fn is None:
            C = self.capacity
            impl = self.cfg.resolved_kernel_impl()
            block = self.cfg.serve_knn_block

            @jax.jit
            def fn(fza, qx):
                from repro.index.knn import query_cluster_knn
                from repro.kernels import registry

                own, _ = registry.dispatch(
                    "kmeans_assign", qx, fza["centroids"], impl=impl
                )
                slot, d2, valid = query_cluster_knn(
                    qx, own, fza["x_blocks"], fza["counts"], kk, block=block
                )
                nb_row = own[:, None] * C + slot
                ids = jnp.where(valid, fza["inv_perm"][nb_row], -1)
                dists = jnp.where(valid, jnp.sqrt(d2), jnp.inf)
                return ids, dists

            cache[kk] = fn
        from repro.serve.transform import frozen_arrays

        ids, dists = fn(frozen_arrays(self), jnp.asarray(q))
        ids, dists = np.asarray(ids), np.asarray(dists)
        return (ids[0], dists[0]) if squeeze else (ids, dists)

    # -- constructors ----------------------------------------------------------

    @classmethod
    def from_index_theta(
        cls, index: "AnnIndex", theta_rows: np.ndarray, cfg: NomadConfig
    ) -> "FrozenMap":
        """Freeze an (index, cluster-major θ) pair — the shared tail of both
        public constructors, so fit-resident and checkpoint-loaded frozen
        maps are bit-identical given the same inputs."""
        from repro.core.nomad import local_means

        K, C = index.n_clusters, index.capacity
        counts = jnp.asarray(index.counts, jnp.int32)
        theta = jnp.asarray(theta_rows, jnp.float32)
        if theta.shape != (K * C, theta.shape[1]):
            raise ValueError(
                f"theta_rows {theta.shape} does not match the index layout "
                f"({K} clusters × capacity {C})"
            )
        inv = np.full((K * C,), -1, np.int32)
        inv[index.perm] = np.arange(index.n_points, dtype=np.int32)
        from repro.data.store import is_store

        # a store-backed x_rows (out-of-core build) is materialised here,
        # explicitly: serving needs the frozen cluster vectors device-
        # resident; this is the one O(K·C·D) allocation of the serve path
        x_np = index.x_rows.materialize() if is_store(index.x_rows) else index.x_rows
        return cls(
            theta_rows=theta,
            x_rows=jnp.asarray(x_np, jnp.float32),
            centroids=jnp.asarray(index.centroids, jnp.float32),
            counts=counts,
            means=local_means(theta, counts, C),
            inv_perm=jnp.asarray(inv),
            capacity=C,
            n_points=index.n_points,
            cfg=cfg,
        )

    @classmethod
    def from_fit(cls, result: "FitResult", cfg: NomadConfig) -> "FrozenMap":
        """Freeze a finished :class:`FitResult` (embedding re-permuted into
        the cluster-major buffer; padding rows are zero, exactly as θ left
        training — sampling never touches them)."""
        index = result.index
        rows = np.zeros(
            (index.n_clusters * index.capacity, result.embedding.shape[1]),
            np.float32,
        )
        rows[index.perm] = result.embedding
        return cls.from_index_theta(index, rows, cfg)

    @classmethod
    def from_checkpoint(
        cls, checkpoint_dir: str, cfg: Optional[NomadConfig] = None
    ) -> "FrozenMap":
        """Freeze the latest checkpoint of ``checkpoint_dir`` — θ from
        ``step_*/``, geometry from the ``index.npz`` cache. Needs no
        training data and no estimator."""
        import os

        from repro.checkpoint import load_theta
        from repro.index.ann import index_cache_path, load_index

        cache = index_cache_path(checkpoint_dir)
        if not os.path.exists(cache):
            raise FileNotFoundError(
                f"no index cache at {cache} — serving from a checkpoint needs "
                "the index.npz written by a fit with cfg.checkpoint_dir set "
                "(or pass an AnnIndex through FrozenMap.from_index_theta)"
            )
        index = load_index(cache)
        theta, meta = load_theta(checkpoint_dir)
        if cfg is None:
            stored = meta.get("config")
            if stored is None:
                raise ValueError(
                    f"checkpoint under {checkpoint_dir} has no stored config — "
                    "pass cfg= explicitly to serve it"
                )
            cfg = NomadConfig(**dict(stored))
        return cls.from_index_theta(index, theta, cfg)

"""The frozen state a fitted map is served from.

:class:`FrozenMap` is the device-resident bundle every transform touches:
the fitted positions θ (cluster-major, capacity-padded — the same layout
training used), the frozen §3.2 index geometry (cluster vectors,
centroids, counts), the per-cell position means the repulsive M̃ term
reads, and the row → original-id inverse permutation used to report
neighbor ids. It is built either

* from a finished fit (:meth:`from_fit` — the estimator does this
  automatically), or
* straight from a checkpoint directory (:meth:`from_checkpoint`): the θ
  row block comes from the latest ``step_*/`` checkpoint and the index
  from the ``index.npz`` cache written beside it — **no access to the raw
  training array**, which is the production serving story: the fleet that
  serves the map never holds the corpus that built it.

Everything in a FrozenMap is immutable by convention and by construction:
the transform path's gradients stop at the query positions (the
``frozen_attract`` kernel's VJP returns cotangents for θ_q and the
repulsive mass only), so serving can never perturb the map.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NomadConfig

if TYPE_CHECKING:
    from repro.core.nomad import FitResult
    from repro.index.ann import AnnIndex


@dataclasses.dataclass
class FrozenMap:
    """Device-resident frozen state of one fitted NOMAD map."""

    theta_rows: jax.Array  # (K·C, out_dim) fitted positions, cluster-major
    x_rows: jax.Array  # (K·C, D) frozen input vectors (padding rows = 0)
    centroids: jax.Array  # (K, D)
    counts: jax.Array  # (K,) int32 real points per cluster
    means: jax.Array  # (K, out_dim) per-cell position means (M̃ input)
    inv_perm: jax.Array  # (K·C,) int32 original point id per row (-1 = pad)
    capacity: int
    n_points: int
    cfg: NomadConfig

    @property
    def n_clusters(self) -> int:
        return int(self.counts.shape[0])

    @property
    def out_dim(self) -> int:
        return int(self.theta_rows.shape[1])

    @property
    def dim(self) -> int:
        return int(self.x_rows.shape[1])

    # -- constructors ----------------------------------------------------------

    @classmethod
    def from_index_theta(
        cls, index: "AnnIndex", theta_rows: np.ndarray, cfg: NomadConfig
    ) -> "FrozenMap":
        """Freeze an (index, cluster-major θ) pair — the shared tail of both
        public constructors, so fit-resident and checkpoint-loaded frozen
        maps are bit-identical given the same inputs."""
        from repro.core.nomad import local_means

        K, C = index.n_clusters, index.capacity
        counts = jnp.asarray(index.counts, jnp.int32)
        theta = jnp.asarray(theta_rows, jnp.float32)
        if theta.shape != (K * C, theta.shape[1]):
            raise ValueError(
                f"theta_rows {theta.shape} does not match the index layout "
                f"({K} clusters × capacity {C})"
            )
        inv = np.full((K * C,), -1, np.int32)
        inv[index.perm] = np.arange(index.n_points, dtype=np.int32)
        from repro.data.store import is_store

        # a store-backed x_rows (out-of-core build) is materialised here,
        # explicitly: serving needs the frozen cluster vectors device-
        # resident; this is the one O(K·C·D) allocation of the serve path
        x_np = index.x_rows.materialize() if is_store(index.x_rows) else index.x_rows
        return cls(
            theta_rows=theta,
            x_rows=jnp.asarray(x_np, jnp.float32),
            centroids=jnp.asarray(index.centroids, jnp.float32),
            counts=counts,
            means=local_means(theta, counts, C),
            inv_perm=jnp.asarray(inv),
            capacity=C,
            n_points=index.n_points,
            cfg=cfg,
        )

    @classmethod
    def from_fit(cls, result: "FitResult", cfg: NomadConfig) -> "FrozenMap":
        """Freeze a finished :class:`FitResult` (embedding re-permuted into
        the cluster-major buffer; padding rows are zero, exactly as θ left
        training — sampling never touches them)."""
        index = result.index
        rows = np.zeros(
            (index.n_clusters * index.capacity, result.embedding.shape[1]),
            np.float32,
        )
        rows[index.perm] = result.embedding
        return cls.from_index_theta(index, rows, cfg)

    @classmethod
    def from_checkpoint(
        cls, checkpoint_dir: str, cfg: Optional[NomadConfig] = None
    ) -> "FrozenMap":
        """Freeze the latest checkpoint of ``checkpoint_dir`` — θ from
        ``step_*/``, geometry from the ``index.npz`` cache. Needs no
        training data and no estimator."""
        import os

        from repro.checkpoint import load_theta
        from repro.index.ann import index_cache_path, load_index

        cache = index_cache_path(checkpoint_dir)
        if not os.path.exists(cache):
            raise FileNotFoundError(
                f"no index cache at {cache} — serving from a checkpoint needs "
                "the index.npz written by a fit with cfg.checkpoint_dir set "
                "(or pass an AnnIndex through FrozenMap.from_index_theta)"
            )
        index = load_index(cache)
        theta, meta = load_theta(checkpoint_dir)
        if cfg is None:
            stored = meta.get("config")
            if stored is None:
                raise ValueError(
                    f"checkpoint under {checkpoint_dir} has no stored config — "
                    "pass cfg= explicitly to serve it"
                )
            cfg = NomadConfig(**dict(stored))
        return cls.from_index_theta(index, theta, cfg)

"""Out-of-sample projection & serving: ``transform()`` on a frozen map.

``FrozenMap`` freezes a fitted (or checkpoint-loaded) map's device state;
``MapServer`` batches queries against it; ``NomadProjection.transform``
is the estimator-level front door.
"""

from repro.serve.frozen import FrozenMap
from repro.serve.server import MapServer, TransformResult, resolve_serve_strategy
from repro.serve.transform import make_transform_fn

__all__ = [
    "FrozenMap",
    "MapServer",
    "TransformResult",
    "make_transform_fn",
    "resolve_serve_strategy",
]

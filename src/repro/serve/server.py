"""MapServer: batched query serving over a frozen map.

The serve twin of ``core/strategy.py``: the server owns microbatch
queueing, padding, latency accounting and result assembly; a *serve
strategy* owns where the jitted transform runs —

* ``"local"``   — one device, one ``serve_microbatch``-row jit;
* ``"sharded"`` — the same body under ``shard_map`` with query rows
  sharded over a flat device mesh (frozen state replicated); each device
  handles ``serve_microbatch`` rows per batch;
* ``"auto"``    — sharded exactly when more than one device is visible.

Because the transform is per-row math with per-row RNG, every strategy and
every microbatch size produces bit-identical placements — a 1-device
sharded mesh reproduces local exactly (tested), and the frozen state is
loaded once: ``MapServer(FrozenMap.from_checkpoint(dir))`` serves with no
access to the training array.

Two entry points:

* :meth:`MapServer.transform` — the library call: one query array in,
  one :class:`TransformResult` out, internally chunked into fixed
  ``batch_rows`` device batches.
* :meth:`MapServer.transform_batch` — the single-batch substrate the
  service layer's batching engine (``repro.service.batcher``) drives
  directly: exactly ``batch_rows`` pre-padded rows with *per-row* seeds
  and local row ids, so one device batch may coalesce rows from many
  concurrent requests and still return, row for row, the bits a
  dedicated ``transform`` call would have.

``transform`` is safe to call concurrently from multiple threads: it
touches only locals and jitted functions (JAX's compilation cache is
thread-safe), and results are bit-equal to sequential calls (tested).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.serve.frozen import FrozenMap
from repro.serve.transform import frozen_arrays, make_transform_fn

SERVE_AXIS = "serve"


@dataclasses.dataclass
class TransformResult:
    """What one ``MapServer.transform`` call returns (FitResult's twin).

    ``neighbor_ids``/``neighbor_dists`` are ``None`` when the call asked
    for the ``return_neighbors=False`` placement-only fast path.
    """

    embedding: np.ndarray  # (Nq, out_dim) placements, query order
    cells: np.ndarray  # (Nq,) assigned frozen cluster per query
    neighbor_ids: Optional[np.ndarray]  # (Nq, k) original-order ids (-1 = none)
    neighbor_dists: Optional[np.ndarray]  # (Nq, k) ascending distances (inf = none)
    # serving provenance
    n_queries: int = 0
    strategy: str = "local"
    n_shards: int = 1
    microbatch: int = 0
    steps: int = 0
    wall_time_s: float = 0.0
    batch_latency_s: List[float] = dataclasses.field(default_factory=list)
    batch_loss: List[float] = dataclasses.field(default_factory=list)

    @staticmethod
    def percentile(values: Sequence[float], pct: float) -> float:
        """Shared percentile helper (NaN on empty) — the one latency
        quantile implementation the benchmarks and the service metrics
        endpoint reuse instead of hand-rolling their own."""
        arr = np.asarray(list(values), np.float64)
        if arr.size == 0:
            return float("nan")
        return float(np.percentile(arr, pct))

    @property
    def p50_latency_s(self) -> float:
        """Median per-batch placement latency of this call."""
        return self.percentile(self.batch_latency_s, 50.0)

    @property
    def p99_latency_s(self) -> float:
        """Tail (p99) per-batch placement latency of this call."""
        return self.percentile(self.batch_latency_s, 99.0)


@dataclasses.dataclass
class BatchOutput:
    """One ``transform_batch`` device batch, already on host.

    Arrays keep the full padded ``batch_rows`` length — the caller owns
    the valid mask and slices out what it needs (the batching engine
    fans rows back out to several requests).
    """

    embedding: np.ndarray  # (B, out_dim)
    cells: np.ndarray  # (B,)
    neighbor_ids: Optional[np.ndarray]  # (B, k) | None on the fast path
    neighbor_dists: Optional[np.ndarray]  # (B, k) | None on the fast path
    loss: float  # final-step mean loss over valid rows (nan if steps == 0)
    latency_s: float  # dispatch → block_until_ready wall


def resolve_serve_strategy(spec: str, mesh: Optional[Mesh] = None):
    """``"auto"|"local"|"sharded"`` → ("local", None) | ("sharded", Mesh)."""
    spec = spec or "auto"
    if spec not in ("auto", "local", "sharded"):
        raise ValueError(
            f"unknown serve_strategy {spec!r} (want 'auto'|'local'|'sharded')"
        )
    from repro.core.strategy import flat_mesh

    devs = list(mesh.devices.reshape(-1)) if mesh is not None else jax.devices()
    if spec == "local" or (spec == "auto" and len(devs) == 1):
        return "local", None
    if mesh is not None and len(mesh.axis_names) == 1:
        return "sharded", mesh
    return "sharded", flat_mesh(devs, SERVE_AXIS)


class MapServer:
    """Turns a :class:`FrozenMap` into a batched query engine.

    Queries are cut into fixed ``microbatch × n_shards`` slices (the last
    one zero-padded), each placed by one jitted call — one compile total,
    per-batch wall clocks recorded in ``TransformResult.batch_latency_s``.
    """

    def __init__(
        self,
        frozen: FrozenMap,
        *,
        strategy: Optional[str] = None,
        microbatch: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        steps: Optional[int] = None,
        lr: Optional[float] = None,
    ):
        cfg = frozen.cfg
        self.frozen = frozen
        self.strategy, self.mesh = resolve_serve_strategy(
            strategy if strategy is not None else cfg.serve_strategy, mesh
        )
        self.n_shards = (
            1 if self.mesh is None else int(np.prod(list(self.mesh.shape.values())))
        )
        self.microbatch = microbatch or cfg.serve_microbatch
        self.steps = cfg.transform_steps if steps is None else steps
        self._lr = lr
        self._fz = frozen_arrays(frozen)
        self._fn = self._make_fn(with_neighbors=True)
        self._fn_fast = None  # built lazily on first return_neighbors=False call
        self._fn_lock = threading.Lock()

    def _make_fn(self, *, with_neighbors: bool):
        return make_transform_fn(
            self.frozen,
            steps=self.steps,
            lr=self._lr,
            mesh=self.mesh,
            # a caller-supplied 1-axis mesh keeps its own axis name
            axis=self.mesh.axis_names[0] if self.mesh is not None else SERVE_AXIS,
            with_neighbors=with_neighbors,
        )

    @property
    def batch_rows(self) -> int:
        """Query rows consumed per jitted call (all shards together)."""
        return self.microbatch * self.n_shards

    def transform_batch(
        self,
        qb: np.ndarray,
        rows: np.ndarray,
        seeds: np.ndarray,
        valid: np.ndarray,
        *,
        return_neighbors: bool = True,
    ) -> BatchOutput:
        """Place exactly one pre-assembled device batch.

        ``qb`` must be ``(batch_rows, dim)`` float32 (already padded),
        ``rows``/``seeds``/``valid`` per-row int32 / uint32 / bool. Row i
        is placed with the RNG stream ``fold_in(key(seeds[i]), rows[i])``
        — so a batch coalescing several requests (each contributing its
        own seed and its own 0-based row ids) returns bit-for-bit what a
        dedicated :meth:`transform` per request would have. Pad rows
        (``valid=False``) only affect the reported loss normalisation,
        never another row's placement (the loss is a sum of per-row
        terms, so gradients decouple row by row).
        """
        B = self.batch_rows
        if qb.shape != (B, self.frozen.dim):
            raise ValueError(
                f"transform_batch wants exactly ({B}, {self.frozen.dim}) rows "
                f"(pad the tail), got {qb.shape}"
            )
        if return_neighbors:
            fn = self._fn
        else:
            with self._fn_lock:
                if self._fn_fast is None:
                    self._fn_fast = self._make_fn(with_neighbors=False)
            fn = self._fn_fast
        args = (
            self._fz,
            jnp.asarray(qb),
            jnp.asarray(rows, jnp.int32),
            jnp.asarray(seeds, jnp.uint32),
            jnp.asarray(valid),
        )
        tb = time.time()
        if return_neighbors:
            th, own, ids, dist, sl = fn(*args)
        else:
            th, own, sl = fn(*args)
            ids = dist = None
        jax.block_until_ready(th)
        latency = time.time() - tb
        sl = np.asarray(sl)
        return BatchOutput(
            embedding=np.asarray(th),
            cells=np.asarray(own),
            neighbor_ids=None if ids is None else np.asarray(ids),
            neighbor_dists=None if dist is None else np.asarray(dist),
            loss=float(sl[-1]) if sl.size else float("nan"),
            latency_s=latency,
        )

    def transform(
        self, q, *, seed: int = 0, return_neighbors: bool = True
    ) -> TransformResult:
        """Place unseen rows on the frozen map. Deterministic per ``seed``
        (and independent of microbatch size / sharding — RNG is folded per
        query row). ``q`` may be an array or a disk-backed
        :class:`repro.data.store.EmbeddingStore` (or memmap / store path):
        store queries are validated per chunk and read one microbatch at a
        time, so serving a larger-than-RAM query log never materialises it.

        ``return_neighbors=False`` skips the neighbor-id/distance outputs
        (and their host transfers) entirely — the placement-only fast path
        for service calls; placements and cells are bit-identical to the
        default (tested).
        """
        from repro.core.nomad import prepare_inputs
        from repro.data.store import is_store

        q = prepare_inputs(
            q,
            dim=self.frozen.dim,
            caller="transform",
            chunk_rows=self.frozen.cfg.chunk_rows,
        )
        t0 = time.time()
        nq = q.shape[0]
        B = self.batch_rows
        embs, cells, nids, ndist = [], [], [], []
        lat, bloss = [], []
        for s in range(0, max(nq, 1), B):
            qb = q.read(s, min(s + B, nq)) if is_store(q) else q[s : s + B]
            pad = B - qb.shape[0]
            if pad:
                qb = np.concatenate([qb, np.zeros((pad, q.shape[1]), qb.dtype)])
            rows = np.arange(s, s + B, dtype=np.int32)
            out = self.transform_batch(
                qb,
                rows,
                np.full((B,), np.uint32(seed & 0xFFFFFFFF)),
                rows < nq,
                return_neighbors=return_neighbors,
            )
            lat.append(out.latency_s)
            take = B - pad
            embs.append(out.embedding[:take])
            cells.append(out.cells[:take])
            if return_neighbors:
                nids.append(out.neighbor_ids[:take])
                ndist.append(out.neighbor_dists[:take])
            bloss.append(out.loss)
        return TransformResult(
            embedding=np.concatenate(embs).astype(np.float32),
            cells=np.concatenate(cells).astype(np.int64),
            neighbor_ids=(
                np.concatenate(nids).astype(np.int64) if return_neighbors else None
            ),
            neighbor_dists=(
                np.concatenate(ndist).astype(np.float32) if return_neighbors else None
            ),
            n_queries=nq,
            strategy=self.strategy,
            n_shards=self.n_shards,
            microbatch=self.microbatch,
            steps=self.steps,
            wall_time_s=time.time() - t0,
            batch_latency_s=lat,
            batch_loss=bloss,
        )

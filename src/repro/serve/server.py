"""MapServer: batched query serving over a frozen map.

The serve twin of ``core/strategy.py``: the server owns microbatch
queueing, padding, latency accounting and result assembly; a *serve
strategy* owns where the jitted transform runs —

* ``"local"``   — one device, one ``serve_microbatch``-row jit;
* ``"sharded"`` — the same body under ``shard_map`` with query rows
  sharded over a flat device mesh (frozen state replicated); each device
  handles ``serve_microbatch`` rows per batch;
* ``"auto"``    — sharded exactly when more than one device is visible.

Because the transform is per-row math with per-row RNG, every strategy and
every microbatch size produces bit-identical placements — a 1-device
sharded mesh reproduces local exactly (tested), and the frozen state is
loaded once: ``MapServer(FrozenMap.from_checkpoint(dir))`` serves with no
access to the training array.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.serve.frozen import FrozenMap
from repro.serve.transform import frozen_arrays, make_transform_fn

SERVE_AXIS = "serve"


@dataclasses.dataclass
class TransformResult:
    """What one ``MapServer.transform`` call returns (FitResult's twin)."""

    embedding: np.ndarray  # (Nq, out_dim) placements, query order
    cells: np.ndarray  # (Nq,) assigned frozen cluster per query
    neighbor_ids: np.ndarray  # (Nq, k) original-order ids of frozen kNN (-1 = none)
    neighbor_dists: np.ndarray  # (Nq, k) ascending high-dim distances (inf = none)
    # serving provenance
    n_queries: int = 0
    strategy: str = "local"
    n_shards: int = 1
    microbatch: int = 0
    steps: int = 0
    wall_time_s: float = 0.0
    batch_latency_s: List[float] = dataclasses.field(default_factory=list)
    batch_loss: List[float] = dataclasses.field(default_factory=list)


def resolve_serve_strategy(spec: str, mesh: Optional[Mesh] = None):
    """``"auto"|"local"|"sharded"`` → ("local", None) | ("sharded", Mesh)."""
    spec = spec or "auto"
    if spec not in ("auto", "local", "sharded"):
        raise ValueError(
            f"unknown serve_strategy {spec!r} (want 'auto'|'local'|'sharded')"
        )
    from repro.core.strategy import flat_mesh

    devs = list(mesh.devices.reshape(-1)) if mesh is not None else jax.devices()
    if spec == "local" or (spec == "auto" and len(devs) == 1):
        return "local", None
    if mesh is not None and len(mesh.axis_names) == 1:
        return "sharded", mesh
    return "sharded", flat_mesh(devs, SERVE_AXIS)


class MapServer:
    """Turns a :class:`FrozenMap` into a batched query engine.

    Queries are cut into fixed ``microbatch × n_shards`` slices (the last
    one zero-padded), each placed by one jitted call — one compile total,
    per-batch wall clocks recorded in ``TransformResult.batch_latency_s``.
    """

    def __init__(
        self,
        frozen: FrozenMap,
        *,
        strategy: Optional[str] = None,
        microbatch: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        steps: Optional[int] = None,
        lr: Optional[float] = None,
    ):
        cfg = frozen.cfg
        self.frozen = frozen
        self.strategy, self.mesh = resolve_serve_strategy(
            strategy if strategy is not None else cfg.serve_strategy, mesh
        )
        self.n_shards = (
            1 if self.mesh is None else int(np.prod(list(self.mesh.shape.values())))
        )
        self.microbatch = microbatch or cfg.serve_microbatch
        self.steps = cfg.transform_steps if steps is None else steps
        self._fz = frozen_arrays(frozen)
        self._fn = make_transform_fn(
            frozen,
            steps=self.steps,
            lr=lr,
            mesh=self.mesh,
            # a caller-supplied 1-axis mesh keeps its own axis name
            axis=self.mesh.axis_names[0] if self.mesh is not None else SERVE_AXIS,
        )

    @property
    def batch_rows(self) -> int:
        """Query rows consumed per jitted call (all shards together)."""
        return self.microbatch * self.n_shards

    def transform(self, q, *, seed: int = 0) -> TransformResult:
        """Place unseen rows on the frozen map. Deterministic per ``seed``
        (and independent of microbatch size / sharding — RNG is folded per
        query row). ``q`` may be an array or a disk-backed
        :class:`repro.data.store.EmbeddingStore` (or memmap / store path):
        store queries are validated per chunk and read one microbatch at a
        time, so serving a larger-than-RAM query log never materialises it.
        """
        from repro.core.nomad import prepare_inputs
        from repro.data.store import is_store

        q = prepare_inputs(
            q,
            dim=self.frozen.dim,
            caller="transform",
            chunk_rows=self.frozen.cfg.chunk_rows,
        )
        t0 = time.time()
        nq = q.shape[0]
        B = self.batch_rows
        key = jax.random.key(seed)
        embs, cells, nids, ndist = [], [], [], []
        lat, bloss = [], []
        for s in range(0, max(nq, 1), B):
            qb = q.read(s, min(s + B, nq)) if is_store(q) else q[s : s + B]
            pad = B - qb.shape[0]
            if pad:
                qb = np.concatenate([qb, np.zeros((pad, q.shape[1]), qb.dtype)])
            rows = np.arange(s, s + B, dtype=np.int32)
            valid = rows < nq
            tb = time.time()
            th, own, ids, dist, sl = self._fn(
                self._fz, jnp.asarray(qb), jnp.asarray(rows), jnp.asarray(valid), key
            )
            jax.block_until_ready(th)
            lat.append(time.time() - tb)
            take = B - pad
            embs.append(np.asarray(th)[:take])
            cells.append(np.asarray(own)[:take])
            nids.append(np.asarray(ids)[:take])
            ndist.append(np.asarray(dist)[:take])
            sl = np.asarray(sl)
            bloss.append(float(sl[-1]) if sl.size else float("nan"))
        return TransformResult(
            embedding=np.concatenate(embs).astype(np.float32),
            cells=np.concatenate(cells).astype(np.int64),
            neighbor_ids=np.concatenate(nids).astype(np.int64),
            neighbor_dists=np.concatenate(ndist).astype(np.float32),
            n_queries=nq,
            strategy=self.strategy,
            n_shards=self.n_shards,
            microbatch=self.microbatch,
            steps=self.steps,
            wall_time_s=time.time() - t0,
            batch_latency_s=lat,
            batch_loss=bloss,
        )

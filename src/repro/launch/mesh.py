"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count`` *before* any jax initialisation.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """Pin the pre-0.9 Auto axis-type behaviour where the API exists.

    ``jax.sharding.AxisType`` only appears in jax >= 0.5; older releases
    have exactly that behaviour already, so the kwarg is simply omitted.
    """
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh with the pre-0.9 Auto axis-type behaviour pinned."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_type_kwargs(len(axes)))


def flat_mesh(axis: str = "data", devs=None):
    """One flat axis over ``devs`` — defaulting to the **global** device
    pool (``jax.devices()``), which under ``jax.distributed`` spans every
    process, never just the local one. Prefer this over hand-rolling
    ``Mesh(jax.local_devices(), ...)``: a process-local mesh silently
    excludes the rest of the fleet and breaks cross-process collectives.
    """
    from repro.core.strategy import flat_mesh as _flat

    return _flat(list(devs) if devs is not None else jax.devices(), axis)

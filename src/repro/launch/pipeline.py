"""GPipe-style pipeline parallelism over a mesh axis (DESIGN.md §6 "PP").

The multi-pod mesh's ``pod`` axis can act as a pipeline instead of pure DP:
layers are split into ``n_stages`` contiguous groups, stage s's parameters
live on pod s, and microbatches rotate through stages via
``collective_permute`` — the canonical SPMD GPipe schedule:

  step t ∈ [0, n_micro + n_stages − 1):
    every stage runs its layer group on the activation it holds (masked out
    during its fill/drain bubbles), then passes the result to stage s+1.

Generic over a ``stage_fn(stage_params, x)``; correctness is checked against
the sequential composition in the multi-device selftest.

Cost model: bubble fraction = (S−1)/(T+S−1); wire = activation bytes per
microbatch per hop, visible to the roofline parser as collective-permutes.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def stack_stage_params(params, n_stages: int):
    """Reshape scan-stacked (L, …) leaves to (n_stages, L/n_stages, …)."""

    def one(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])

    return jax.tree.map(one, params)


def gpipe(
    mesh: Mesh,
    axis: str,
    stage_fn: Callable,
    n_micro: int,
):
    """Build ``run(stage_params, x_micro) -> y_micro`` (both global-view).

    ``stage_params``: leaves (n_stages, …), sharded over ``axis`` dim 0.
    ``x_micro``: (n_micro, B_m, …) replicated; returns same shape, the
    result of all stages applied in order.
    """
    n_stages = mesh.shape[axis]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    def run(stage_params, x_micro):
        sp = jax.tree.map(lambda a: a[0], stage_params)  # this stage's params
        stage = jax.lax.axis_index(axis)
        T = n_micro + n_stages - 1
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        buf = jnp.zeros_like(x_micro[0])  # activation currently held here
        outs = jnp.zeros_like(x_micro)

        def step(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (while t < n_micro)
            take = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where((stage == 0) & (t < n_micro), x_micro[take], buf)
            active = ((t - stage) >= 0) & ((t - stage) < n_micro)
            y = stage_fn(sp, x_in)
            y = jnp.where(active, y, x_in)
            # last stage emits microbatch (t − stage) when active
            emit = jnp.clip(t - stage, 0, n_micro - 1)
            outs = jax.lax.cond(
                active & (stage == n_stages - 1),
                lambda o: o.at[emit].set(y),
                lambda o: o,
                outs,
            )
            # rotate activations one stage forward
            buf_next = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf_next, outs), None

        (buf, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(T))
        # outs is only valid on the last stage; replicate via masked psum
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return run

"""NOMAD Projection end-to-end training launcher (deliverable b's driver).

A thin CLI over the unified estimator — everything fault-tolerant lives in
``NomadProjection.fit`` now:

* index build (K-means + in-cluster kNN) is cached on disk next to the
  checkpoint dir — on restart the index is reloaded, not rebuilt;
* one checkpoint per ``--checkpoint-every`` epochs (atomic commit, async);
* ``--resume`` restores θ + epoch and continues bit-exactly (same
  ``fold_in`` schedule as the uninterrupted run);
* **elastic**: the checkpoint stores the global θ row-block, so a run
  started on N devices restores onto any other divisor count (node loss →
  restart smaller; scale-up → restart bigger). Cluster blocks re-shard
  because the layout is cluster-major (checkpoint/checkpointer.py).

Host-device simulation: ``--host-devices N`` forces N CPU devices (set
before jax imports — this is why main() parses argv first).

Examples:
  PYTHONPATH=src python -m repro.launch.train --workload nomad_quickstart \
      --host-devices 8 --mesh 2x4 --epochs 10 --checkpoint-dir /tmp/nomad_ckpt
  … kill it mid-run, then:
  PYTHONPATH=src python -m repro.launch.train --workload nomad_quickstart \
      --host-devices 4 --mesh 4 --resume --checkpoint-dir /tmp/nomad_ckpt
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="nomad_quickstart")
    ap.add_argument("--n-points", type=int, default=0, help="override workload size")
    ap.add_argument("--epochs", type=int, default=0, help="override epoch count")
    ap.add_argument("--mesh", default="", help="e.g. '2x4' (axes data,model) or '4'")
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--fail-at-epoch", type=int, default=-1, help="crash injection (tests)")
    ap.add_argument("--out", default="", help="write final embedding .npy here")
    ap.add_argument("--metrics", action="store_true", help="NP@10/triplet at the end")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}"
        )

    import jax
    import numpy as np

    from repro.checkpoint import latest_step, load_metadata
    from repro.configs import get_nomad
    from repro.core.nomad import NomadProjection
    from repro.core.strategy import FitCallbacks
    from repro.data.synthetic import hierarchical_mixture
    from repro.launch.mesh import make_mesh

    cfg = get_nomad(args.workload)
    if args.n_points:
        cfg = cfg.replace(n_points=args.n_points)
    if args.epochs:
        cfg = cfg.replace(n_epochs=args.epochs)
    if args.hierarchical:
        cfg = cfg.replace(hierarchical=True)
    if args.checkpoint_dir:
        cfg = cfg.replace(checkpoint_dir=args.checkpoint_dir)
    if args.checkpoint_every:
        cfg = cfg.replace(checkpoint_every_epochs=args.checkpoint_every)

    # ---- mesh ------------------------------------------------------------------
    if args.mesh:
        dims = tuple(int(d) for d in args.mesh.split("x"))
    else:
        dims = (len(jax.devices()),)
    axis_names = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    mesh = make_mesh(dims, axis_names)
    pod_axis = "pod" if "pod" in axis_names else None
    shard_axes = tuple(a for a in axis_names if a != "pod")
    n_shards = 1
    for d in dims:
        n_shards *= d
    print(f"mesh {dims} axes {axis_names}; {n_shards} shards")

    # ---- data ------------------------------------------------------------------
    # the index is owned by fit: argument > fingerprint-checked
    # checkpoint_dir/index.npz cache > IndexBuilder on the training mesh
    x, sup, sub = hierarchical_mixture(cfg.n_points, cfg.dim, seed=cfg.seed)
    ckdir = cfg.checkpoint_dir

    resume = bool(args.resume and ckdir and latest_step(ckdir) is not None)
    if resume:
        meta = load_metadata(ckdir)
        print(f"resume: epoch {int(meta['epoch']) + 1} (ckpt step {meta['epoch']})")

    class Progress(FitCallbacks):
        wants_embedding = False

        def on_epoch_start(self, ev):
            if ev.epoch == args.fail_at_epoch:
                print(f"CRASH INJECTION at epoch {ev.epoch}", flush=True)
                os._exit(17)

        def on_epoch_end(self, ev):
            print(
                f"epoch {ev.epoch:4d} loss {ev.loss:.5f} ({ev.time_s:.2f}s)",
                flush=True,
            )

        def on_checkpoint(self, ev):
            print(f"checkpoint: epoch {ev.epoch} → {ev.directory}", flush=True)

    strategy = "hierarchical" if (cfg.hierarchical and pod_axis) else "sharded"
    proj = NomadProjection(
        cfg, strategy=strategy, mesh=mesh, shard_axes=shard_axes, pod_axis=pod_axis
    )
    res = proj.fit(x, callbacks=Progress(), resume=resume)
    print(
        f"index: {res.index_build_strategy}"
        + (f" build in {res.index_build_s:.1f}s" if res.index_build_s else "")
    )

    emb = res.embedding
    if args.out:
        np.save(args.out, emb)
        print("embedding →", args.out)
    if args.metrics:
        from repro.metrics import neighborhood_preservation, random_triplet_accuracy

        np10 = neighborhood_preservation(x, emb, k=10, n_queries=min(1000, cfg.n_points))
        rta = random_triplet_accuracy(x, emb, 10_000)
        print(f"NP@10={np10:.4f} triplet={rta:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""NOMAD Projection end-to-end training launcher (deliverable b's driver).

Fault-tolerant distributed fit:

* index build (K-means + in-cluster kNN) is cached on disk next to the
  checkpoint dir — on restart the index is reloaded, not rebuilt;
* one checkpoint per ``--checkpoint-every`` epochs (atomic commit, async);
* ``--resume`` restores θ + epoch + RNG stream and continues bit-exactly;
* **elastic**: the checkpoint stores the global θ row-block, so a run
  started on N devices restores onto any other divisor count (node loss →
  restart smaller; scale-up → restart bigger). Cluster blocks re-shard
  because the layout is cluster-major (checkpoint/checkpointer.py).

Host-device simulation: ``--host-devices N`` forces N CPU devices (set
before jax imports — this is why main() parses argv first).

Examples:
  PYTHONPATH=src python -m repro.launch.train --workload nomad_quickstart \
      --host-devices 8 --mesh 2x4 --epochs 10 --checkpoint-dir /tmp/nomad_ckpt
  … kill it mid-run, then:
  PYTHONPATH=src python -m repro.launch.train --workload nomad_quickstart \
      --host-devices 4 --mesh 4 --resume --checkpoint-dir /tmp/nomad_ckpt
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="nomad_quickstart")
    ap.add_argument("--n-points", type=int, default=0, help="override workload size")
    ap.add_argument("--epochs", type=int, default=0, help="override epoch count")
    ap.add_argument("--mesh", default="", help="e.g. '2x4' (axes data,model) or '4'")
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--fail-at-epoch", type=int, default=-1, help="crash injection (tests)")
    ap.add_argument("--out", default="", help="write final embedding .npy here")
    ap.add_argument("--metrics", action="store_true", help="NP@10/triplet at the end")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}"
        )

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import Checkpointer, latest_step
    from repro.configs import get_nomad
    from repro.core.distributed import make_sharded_epoch_fn, shard_index_arrays
    from repro.core.nomad import NomadProjection
    from repro.data.synthetic import hierarchical_mixture
    from repro.index.ann import build_index
    from repro.launch.mesh import make_mesh

    cfg = get_nomad(args.workload)
    if args.n_points:
        cfg = cfg.replace(n_points=args.n_points)
    if args.epochs:
        cfg = cfg.replace(n_epochs=args.epochs)
    if args.hierarchical:
        cfg = cfg.replace(hierarchical=True)

    # ---- mesh ------------------------------------------------------------------
    if args.mesh:
        dims = tuple(int(d) for d in args.mesh.split("x"))
    else:
        dims = (len(jax.devices()),)
    axis_names = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    mesh = make_mesh(dims, axis_names)
    pod_axis = "pod" if "pod" in axis_names else None
    shard_axes = tuple(a for a in axis_names if a != "pod")
    n_shards = 1
    for d in dims:
        n_shards *= d
    print(f"mesh {dims} axes {axis_names}; {n_shards} shards")

    # ---- data + index (cached) ---------------------------------------------------
    x, sup, sub = hierarchical_mixture(cfg.n_points, cfg.dim, seed=cfg.seed)
    ckdir = args.checkpoint_dir
    index = None
    index_cache = os.path.join(ckdir, "index.npz") if ckdir else ""
    if index_cache and os.path.exists(index_cache):
        from repro.index.ann import AnnIndex

        z = np.load(index_cache)
        index = AnnIndex(
            x_rows=z["x_rows"], knn_idx=z["knn_idx"], knn_w=z["knn_w"],
            counts=z["counts"], centroids=z["centroids"], perm=z["perm"],
            capacity=int(z["capacity"]), n_points=int(z["n_points"]),
        )
        print("index: restored from cache")
    if index is None:
        t0 = time.time()
        index = build_index(x, cfg)
        print(f"index: built in {time.time() - t0:.1f}s")
        if index_cache:
            os.makedirs(ckdir, exist_ok=True)
            np.savez(
                index_cache, x_rows=index.x_rows, knn_idx=index.knn_idx,
                knn_w=index.knn_w, counts=index.counts, centroids=index.centroids,
                perm=index.perm, capacity=index.capacity, n_points=index.n_points,
            )

    idx = shard_index_arrays(index, n_shards)
    theta_np = np.asarray(NomadProjection(cfg)._init_theta(x, index))
    start_epoch = 0

    ckpt = None
    if ckdir:
        ckpt = Checkpointer(ckdir, n_shards=n_shards, keep=3, async_save=True)
        if args.resume and latest_step(ckdir) is not None:
            tree, meta = ckpt.restore({"theta": theta_np})
            theta_np = tree["theta"]
            start_epoch = int(meta["epoch"]) + 1
            print(f"resume: epoch {start_epoch} (ckpt step {meta['epoch']})")

    axes = ((pod_axis,) if pod_axis else ()) + shard_axes
    row_sh = NamedSharding(mesh, P(axes, None))
    vec_sh = NamedSharding(mesh, P(axes))
    theta = jax.device_put(jnp.asarray(theta_np), row_sh)
    idx = {
        "knn_idx": jax.device_put(idx["knn_idx"], row_sh),
        "knn_w": jax.device_put(idx["knn_w"], row_sh),
        "counts": jax.device_put(idx["counts"], vec_sh),
        "cum_counts": jax.device_put(idx["cum_counts"], vec_sh),
    }
    counts_global = jnp.asarray(index.counts, jnp.float32)

    steps = max(1, -(-cfg.resolved_steps_per_epoch() // n_shards))
    epoch_fn = jax.jit(
        make_sharded_epoch_fn(
            cfg, mesh, shard_axes=shard_axes, pod_axis=pod_axis,
            steps_per_epoch=steps, n_shards=n_shards,
        )
    )
    lr0 = cfg.resolved_lr0()
    key = jax.random.key(cfg.seed + 1)
    every = args.checkpoint_every or cfg.checkpoint_every_epochs

    for e in range(start_epoch, cfg.n_epochs):
        if e == args.fail_at_epoch:
            print(f"CRASH INJECTION at epoch {e}", flush=True)
            os._exit(17)
        t0 = time.time()
        f0 = 1.0 - e / cfg.n_epochs
        f1 = 1.0 - (e + 1) / cfg.n_epochs
        theta, ml = epoch_fn(
            theta, idx, counts_global, lr0 * f0, lr0 * f1, jax.random.fold_in(key, e)
        )
        print(f"epoch {e:4d} loss {float(ml):.5f} ({time.time() - t0:.2f}s)", flush=True)
        if ckpt and ((e + 1) % every == 0 or e == cfg.n_epochs - 1):
            ckpt.save(e, {"theta": np.asarray(theta)}, sharded_keys=("theta",), metadata={"epoch": e})
    if ckpt:
        ckpt.wait()

    emb = index.unpermute(np.asarray(theta))
    if args.out:
        np.save(args.out, emb)
        print("embedding →", args.out)
    if args.metrics:
        from repro.metrics import neighborhood_preservation, random_triplet_accuracy

        np10 = neighborhood_preservation(x, emb, k=10, n_queries=min(1000, cfg.n_points))
        rta = random_triplet_accuracy(x, emb, 10_000)
        print(f"NP@10={np10:.4f} triplet={rta:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × supported input shape × mesh) cell this driver
builds the step function of the cell's kind (train / prefill / decode),
``jit(...).lower(*ShapeDtypeStructs).compile()`` — nothing is allocated —
and records:

* ``compiled.memory_analysis()``  → per-device bytes (proves it fits),
* ``compiled.cost_analysis()``    → XLA's (loop-body-once) numbers,
* our trip-count-aware HLO cost   → FLOPs / HBM bytes / collective bytes,
* the three roofline terms + dominant bottleneck (§Roofline).

The NOMAD workloads (the paper's own contribution) run through the same
gate: ``--arch nomad_pubmed`` / ``nomad_wiki60m`` lower the *distributed
epoch step* (shard_map over the full mesh, means all-gather included).

Results land in ``results/dryrun/<mesh>/<arch>__<shape>.json`` (one file
per cell, written incrementally — safe to re-run with --skip-existing).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
"""

import argparse
import json
import time
import traceback

import numpy as np


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def run_lm_cell(arch_name: str, shape_name: str, multi_pod: bool, save_hlo: str | None):
    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import step_shardings
    from repro.models import steps as steps_lib
    from repro.optim import AdamW, warmup_cosine
    from repro.roofline.analysis import model_flops, roofline_terms
    from repro.roofline.hlo_cost import analyze_hlo

    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))

    # pin activation batch sharding (see models/lm.py set_activation_sharding)
    from repro.models import lm as lm_lib
    from repro.models import moe as moe_lib

    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))
    micro = shape.global_batch // (cfg.accum_steps if shape.kind == "train" else 1)
    if micro % dp_size == 0:
        token_axes = dp_axes
    elif micro % mesh.shape["data"] == 0:
        token_axes = ("data",)
    else:
        token_axes = None
    lm_lib.set_activation_sharding(token_axes)
    # expert-parallel shard_map MoE (§Perf iteration 4); for decode, expert
    # weights go TP-resident when they fit (§Perf iteration 6)
    if cfg.n_experts:
        from repro.launch.sharding import serving_weights_resident

        fsdp = ("data",)
        stationary = False
        if shape.kind == "decode":
            if cfg.n_experts % mesh.shape["model"] == 0:
                stationary = True  # move tokens, not weights (any batch)
            elif serving_weights_resident(cfg, mesh):
                fsdp = ()  # expert weights fully TP-resident
        moe_lib.set_ep_mesh(mesh, fsdp, token_axes, stationary=stationary)
    else:
        moe_lib.set_ep_mesh(None, None)

    optimizer = AdamW(
        schedule=warmup_cosine(3e-4, 2000, 100_000),
        moment_dtype=cfg.opt_moment_dtype,
    )
    from repro.models import attention as attn_lib
    from repro.launch.sharding import cache_pspecs as _cp

    attn_lib.set_decode_context(None, None, ())
    if shape.kind == "train":
        step = steps_lib.make_train_step(cfg, optimizer, microbatched=True)
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = steps_lib.make_prefill_step(cfg)
        donate = ()
    else:
        step = steps_lib.make_decode_step(cfg)
        donate = (1,)
        if cfg.n_heads:  # sharded flash-decode (§Perf iteration 7)
            b = shape.global_batch
            if b % dp_size == 0:
                baxes, saxes = dp_axes, ("model",)
            else:
                baxes, saxes = None, dp_axes + ("model",)
            attn_lib.set_decode_context(mesh, baxes, saxes)

    specs = steps_lib.input_specs(cfg, shape, optimizer)
    in_sh, out_sh = step_shardings(cfg, shape, mesh, specs)

    t0 = time.time()
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
    with mesh:
        lowered = jitted.lower(*specs)
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    rep = analyze_hlo(hlo)
    mf = model_flops(cfg, shape)
    # per-device useful flops → terms; model_flops is global
    terms = roofline_terms(rep, n_chips, mf)
    if save_hlo:
        os.makedirs(save_hlo, exist_ok=True)
        with open(os.path.join(save_hlo, f"{arch_name}__{shape_name}__{_mesh_tag(multi_pod)}.hlo"), "w") as f:
            f.write(hlo)

    return {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": _mesh_tag(multi_pod),
        "n_chips": n_chips,
        "kind": shape.kind,
        "ok": True,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_total": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "xla_cost": {"flops": ca.get("flops", 0.0), "bytes": ca.get("bytes accessed", 0.0)},
        "hlo_cost": {
            "flops": rep.flops,
            "bytes": rep.bytes,
            "collective_bytes": rep.collective_bytes,
            "coll_by_type": rep.coll_by_type,
            "coll_ops": rep.coll_ops,
            "dot_flops": rep.dot_flops,
            "unknown_trip_whiles": rep.unknown_trip_whiles,
        },
        "model_flops": mf,
        "terms": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "useful_ratio": terms.useful_ratio,
            "roofline_fraction": terms.roofline_fraction,
            "bound_s": terms.bound_s,
        },
    }


def run_nomad_cell(workload: str, multi_pod: bool, save_hlo: str | None):
    """Lower + compile the distributed NOMAD epoch step on the mesh."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_nomad
    from repro.core.distributed import make_sharded_epoch_fn
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import nomad_analytic_terms, nomad_model_flops, roofline_terms
    from repro.roofline.hlo_cost import analyze_hlo
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_nomad(workload)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    pod_axis = "pod" if multi_pod else None

    K, C = cfg.n_clusters, cfg.cluster_capacity
    steps = max(1, -(-cfg.resolved_steps_per_epoch() // n_chips))
    epoch_fn = make_sharded_epoch_fn(
        cfg,
        mesh,
        shard_axes=("data", "model"),
        pod_axis=pod_axis,
        steps_per_epoch=steps,
        n_shards=n_chips,
    )

    rows = K * C
    sds = jax.ShapeDtypeStruct
    theta = sds((rows, cfg.out_dim), jnp.float32)
    idx = {
        "knn_idx": sds((rows, cfg.n_neighbors), jnp.int32),
        "knn_w": sds((rows, cfg.n_neighbors), jnp.float32),
        "counts": sds((K,), jnp.int32),
        "cum_counts": sds((K,), jnp.int32),
    }
    counts_global = sds((K,), jnp.float32)
    lr = sds((), jnp.float32)
    key = jax.eval_shape(lambda: jax.random.key(0))

    row_sh = NamedSharding(mesh, P(axes, None))
    vec_sh = NamedSharding(mesh, P(axes))
    rep_sh = NamedSharding(mesh, P())
    in_sh = (
        row_sh,
        {"knn_idx": row_sh, "knn_w": row_sh, "counts": vec_sh, "cum_counts": vec_sh},
        rep_sh,
        rep_sh,
        rep_sh,
        rep_sh,
    )
    t0 = time.time()
    jitted = jax.jit(epoch_fn, in_shardings=in_sh, out_shardings=(row_sh, rep_sh), donate_argnums=(0,))
    with mesh:
        lowered = jitted.lower(theta, idx, counts_global, lr, lr, key)
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    rep = analyze_hlo(hlo)
    mf = nomad_model_flops(
        cfg.n_points, cfg.batch_size * n_chips, cfg.n_neighbors,
        cfg.n_exact_negatives, cfg.n_clusters, steps,
    )
    terms = roofline_terms(rep, n_chips, mf)
    if save_hlo:
        os.makedirs(save_hlo, exist_ok=True)
        with open(os.path.join(save_hlo, f"{workload}__epoch__{_mesh_tag(multi_pod)}.hlo"), "w") as f:
            f.write(hlo)
    return {
        "arch": workload,
        "shape": "epoch",
        "mesh": _mesh_tag(multi_pod),
        "n_chips": n_chips,
        "kind": "nomad-epoch",
        "ok": True,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "steps_per_epoch": steps,
        "hierarchical": bool(cfg.hierarchical and multi_pod),
        # kernel-true terms: the HLO memory term is inflated by the Pallas
        # interpret-mode tile boundaries (VMEM-resident on a real TPU)
        "analytic_terms": nomad_analytic_terms(cfg, n_chips, steps),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_total": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "xla_cost": {"flops": ca.get("flops", 0.0), "bytes": ca.get("bytes accessed", 0.0)},
        "hlo_cost": {
            "flops": rep.flops,
            "bytes": rep.bytes,
            "collective_bytes": rep.collective_bytes,
            "coll_by_type": rep.coll_by_type,
            "coll_ops": rep.coll_ops,
            "dot_flops": rep.dot_flops,
            "unknown_trip_whiles": rep.unknown_trip_whiles,
        },
        "model_flops": mf,
        "terms": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "useful_ratio": terms.useful_ratio,
            "roofline_fraction": terms.roofline_fraction,
            "bound_s": terms.bound_s,
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id | nomad workload | 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", default="", help="dir to dump compiled HLO text")
    args = ap.parse_args()

    from repro.configs import ARCHS, NOMAD_WORKLOADS, SHAPES

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = []
    arch_list = (
        list(ARCHS) + ["nomad_pubmed", "nomad_wiki60m"]
        if args.arch == "all"
        else [args.arch]
    )
    for a in arch_list:
        if a in NOMAD_WORKLOADS:
            for mp in meshes:
                cells.append((a, "epoch", mp))
            continue
        cfg = ARCHS[a]
        shapes = cfg.supported_shapes() if args.shape == "all" else [args.shape]
        for s in shapes:
            if s not in cfg.supported_shapes():
                print(f"SKIP {a} × {s}: unsupported (see DESIGN.md skip table)")
                continue
            for mp in meshes:
                cells.append((a, s, mp))

    results = []
    for arch, shape, mp in cells:
        tag = _mesh_tag(mp)
        out_dir = os.path.join(args.out, tag)
        os.makedirs(out_dir, exist_ok=True)
        out_path = os.path.join(out_dir, f"{arch}__{shape}.json")
        if args.skip_existing and os.path.exists(out_path):
            print(f"SKIP (exists) {arch} × {shape} × {tag}")
            continue
        print(f"=== {arch} × {shape} × {tag} ===", flush=True)
        try:
            if arch in NOMAD_WORKLOADS:
                rec = run_nomad_cell(arch, mp, args.save_hlo or None)
            else:
                rec = run_lm_cell(arch, shape, mp, args.save_hlo or None)
            t = rec["terms"]
            print(
                f"  ok: compile {rec['compile_s']}s | mem/dev "
                f"{rec['memory']['per_device_total']/2**30:.2f} GiB | "
                f"compute {t['compute_s']*1e3:.2f} ms, memory {t['memory_s']*1e3:.2f} ms, "
                f"collective {t['collective_s']*1e3:.2f} ms → {t['dominant']}-bound; "
                f"useful {t['useful_ratio']:.2f}, roofline {t['roofline_fraction']:.2f}",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            rec = {
                "arch": arch,
                "shape": shape,
                "mesh": tag,
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(f"  FAIL: {rec['error']}", flush=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        results.append(rec)

    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells compiled OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Multi-process distributed fit: coordinator bootstrap + per-process CLI.

The paper's flagship result (a map of Multilingual Wikipedia) exists
because NOMAD Projection runs across accelerators *and hosts*: clusters
shard over one global mesh, and the only optimisation-loop collective —
the per-refresh all-gather of cluster means — crosses process boundaries
exactly like it crosses devices. This module is the host-side glue:

* :func:`initialize_distributed` — ``jax.distributed.initialize`` against a
  coordinator address, with the CPU backend switched to its ``gloo``
  collectives implementation first (without it, XLA:CPU rejects any
  multi-process computation outright). After it returns,
  ``jax.devices()`` spans every process while ``jax.local_devices()``
  stays process-local — every mesh built from the global pool
  (``core/strategy.py:default_mesh``, ``launch/mesh.py:flat_mesh``,
  ``index/build.py:resolve_build_strategy``) then shards across hosts
  with no further changes: ``shard_map`` collectives reduce over mesh
  axes, not processes.

* ``python -m repro.launch.distributed`` — the per-process entrypoint.
  One invocation per process (``--process-id i``), all pointing at the
  same ``--coordinator host:port``; or ``--spawn K`` to launch K local
  worker processes against an automatically chosen local coordinator
  port (the CI/test harness, and the quickest way to try 2 processes on
  one machine). Every process must see the same data — pass ``--store``
  (a shared-filesystem embedding store) for anything big; each process
  then reads only its own row range of it (the ``"distributed"`` index
  build), so no process ever holds all N rows.

Determinism contract (pinned by tests/test_multiprocess.py): a K-process
fit is bit-for-bit equal to the 1-process sharded fit over the same
global device count — process layout changes *where* shards live, never
what they compute.
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time


def pick_free_port(host: str = "127.0.0.1") -> int:
    """An OS-assigned free TCP port (closed again — a tiny race the
    coordinator bind reports loudly if ever lost)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind((host, 0))
        return int(s.getsockname()[1])


def initialize_distributed(
    coordinator: str,
    num_processes: int,
    process_id: int,
    *,
    timeout_s: int = 60,
) -> None:
    """``jax.distributed.initialize`` with the CPU collectives prerequisite.

    Must run before any jax computation touches the backend. On CPU the
    collectives implementation is switched to ``gloo`` first — XLA:CPU's
    default implementation refuses cross-process computations with
    "Multiprocess computations aren't implemented on the CPU backend".
    GPU/TPU backends keep their native (NCCL/ICI) collectives.
    """
    import jax

    if num_processes < 2:
        return  # single process: nothing to coordinate
    if not coordinator:
        raise ValueError(
            "multi-process init needs a coordinator address "
            "(host:port of process 0)"
        )
    platforms = os.environ.get("JAX_PLATFORMS", "")
    if platforms in ("", "cpu"):
        # harmless when another backend wins; required when CPU does
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
        initialization_timeout=timeout_s,
    )


def barrier(tag: str = "barrier") -> None:
    """Block until every process reaches this point (no-op single-process)."""
    import jax

    if jax.process_count() > 1:
        from jax.experimental import multihost_utils

        multihost_utils.sync_global_devices(tag)


# ---------------------------------------------------------------------------
# The per-process entrypoint
# ---------------------------------------------------------------------------


def parse_args(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.distributed",
        description="Per-process NOMAD fit worker (jax.distributed).",
    )
    ap.add_argument("--coordinator", default="", help="host:port of process 0")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument(
        "--spawn", type=int, default=0,
        help="launch K local worker processes against a local coordinator",
    )
    ap.add_argument(
        "--host-devices", type=int, default=0,
        help="force N CPU devices per process (XLA host-platform simulation)",
    )
    ap.add_argument("--init-timeout", type=int, default=60)
    # workload
    ap.add_argument("--workload", default="nomad_quickstart")
    ap.add_argument("--store", default="", help="shared embedding store (dir or .npy)")
    ap.add_argument("--n-points", type=int, default=0)
    ap.add_argument("--dim", type=int, default=0)
    ap.add_argument("--epochs", type=int, default=0)
    ap.add_argument("--clusters", type=int, default=0)
    ap.add_argument("--neighbors", type=int, default=0)
    ap.add_argument("--chunk-rows", type=int, default=0)
    # fault tolerance
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-epoch", type=int, default=-1, help="crash injection (tests)")
    # outputs (process 0 writes; --stats is per-process)
    ap.add_argument("--out", default="", help="final embedding .npy (process 0)")
    ap.add_argument("--dump-index", default="", help="index arrays .npz (process 0)")
    ap.add_argument("--stats", default="", help="per-process stage walls + RSS JSON")
    return ap.parse_args(argv)


def _spawn_workers(args, argv) -> int:
    """``--spawn K``: run K local workers against a local coordinator."""
    port = pick_free_port()
    strip = {"--spawn": 1}
    child_common: list = []
    it = iter(argv)
    for a in it:
        if a in strip:
            next(it, None)
            continue
        child_common.append(a)
    procs = []
    for i in range(args.spawn):
        cmd = [
            sys.executable, "-m", "repro.launch.distributed",
            *child_common,
            "--coordinator", f"127.0.0.1:{port}",
            "--num-processes", str(args.spawn),
            "--process-id", str(i),
        ]
        procs.append(subprocess.Popen(cmd))
    rcs = [p.wait() for p in procs]
    bad = [rc for rc in rcs if rc != 0]
    return bad[0] if bad else 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    args = parse_args(argv)
    if args.spawn > 0:
        return _spawn_workers(args, argv)
    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}"
        )

    t_start = time.time()
    try:
        initialize_distributed(
            args.coordinator,
            args.num_processes,
            args.process_id,
            timeout_s=args.init_timeout,
        )
    except Exception as e:  # noqa: BLE001 — fail loud, fast and actionable
        print(
            f"distributed init failed (coordinator {args.coordinator!r}, "
            f"process {args.process_id}/{args.num_processes}): {e}",
            file=sys.stderr,
            flush=True,
        )
        return 3

    import jax
    import numpy as np

    from repro.checkpoint import latest_step, load_metadata
    from repro.configs import get_nomad
    from repro.core.nomad import NomadProjection
    from repro.core.strategy import FitCallbacks
    from repro.data.store import as_store
    from repro.index.build import IndexBuilder, _rss_mb

    pid, nproc = jax.process_index(), jax.process_count()
    print(
        f"process {pid}/{nproc}: {jax.local_device_count()} local / "
        f"{jax.device_count()} global devices",
        flush=True,
    )

    cfg = get_nomad(args.workload)
    # every process must run the cross-process collective build — the
    # "distributed" IndexBuilder path (per-process row ranges of the store)
    cfg = cfg.replace(build_strategy="distributed")
    if args.store:
        store = as_store(args.store)
        x = store
        cfg = cfg.replace(n_points=store.n_rows, dim=store.dim)
    else:
        if args.n_points:
            cfg = cfg.replace(n_points=args.n_points)
        if args.dim:
            cfg = cfg.replace(dim=args.dim)
        from repro.data.synthetic import hierarchical_mixture

        x, _sup, _sub = hierarchical_mixture(cfg.n_points, cfg.dim, seed=cfg.seed)
    if args.epochs:
        cfg = cfg.replace(n_epochs=args.epochs)
    if args.clusters:
        cfg = cfg.replace(n_clusters=args.clusters)
    if args.neighbors:
        cfg = cfg.replace(n_neighbors=args.neighbors)
    if args.chunk_rows:
        cfg = cfg.replace(chunk_rows=args.chunk_rows)
    if args.checkpoint_dir:
        cfg = cfg.replace(checkpoint_dir=args.checkpoint_dir)
    if args.checkpoint_every:
        cfg = cfg.replace(checkpoint_every_epochs=args.checkpoint_every)

    ckdir = cfg.checkpoint_dir
    resume = bool(args.resume and ckdir and latest_step(ckdir) is not None)
    if resume:
        meta = load_metadata(ckdir)
        print(f"resume: epoch {int(meta['epoch']) + 1} (ckpt step {meta['epoch']})")

    class Progress(FitCallbacks):
        wants_embedding = False

        def on_epoch_start(self, ev):
            if ev.epoch == args.fail_at_epoch:
                print(f"CRASH INJECTION at epoch {ev.epoch}", flush=True)
                os._exit(17)

        def on_epoch_end(self, ev):
            if pid == 0:
                print(
                    f"epoch {ev.epoch:4d} loss {ev.loss:.5f} ({ev.time_s:.2f}s)",
                    flush=True,
                )

        def on_checkpoint(self, ev):
            if pid == 0:
                print(f"checkpoint: epoch {ev.epoch} → {ev.directory}", flush=True)

    index = None
    build_stage_s: dict = {}
    if args.stats:
        # explicit build so per-stage walls land in the stats JSON
        builder = IndexBuilder(cfg)
        index = builder.build(x)
        build_stage_s = dict(builder.report.stage_s)
        print(
            f"index: {builder.report.strategy} "
            f"({builder.report.n_shards} shards, {builder.report.total_s:.1f}s)",
            flush=True,
        )

    proj = NomadProjection(cfg, strategy="auto")
    res = proj.fit(x, index=index, callbacks=Progress(), resume=resume)
    if pid == 0:
        print(
            f"index: {res.index_build_strategy}"
            + (f" build in {res.index_build_s:.1f}s" if res.index_build_s else "")
        )
        print(
            f"fit: strategy={res.strategy} shards={res.n_shards} "
            f"processes={res.process_count}",
            flush=True,
        )

    if args.out and pid == 0:
        np.save(args.out, res.embedding)
        print("embedding →", args.out)
    if args.dump_index and pid == 0:
        idx = res.index
        np.savez(
            args.dump_index,
            knn_idx=idx.knn_idx,
            knn_w=idx.knn_w,
            counts=idx.counts,
            centroids=idx.centroids,
            perm=idx.perm,
        )
        print("index arrays →", args.dump_index)
    if args.stats:
        # spawned workers share one argv — derive a per-process filename
        stats_path = args.stats
        if nproc > 1:
            root, ext = os.path.splitext(stats_path)
            stats_path = f"{root}.p{pid}{ext}"
        stats = {
            "process": pid,
            "n_processes": nproc,
            "local_devices": jax.local_device_count(),
            "global_devices": jax.device_count(),
            "peak_rss_mb": _rss_mb(),
            "stage_seconds": {
                **build_stage_s,
                "fit": float(sum(res.epoch_times)),
                "total": float(time.time() - t_start),
            },
            "epoch_seconds": [float(t) for t in res.epoch_times],
            "losses": [float(v) for v in res.losses],
        }
        with open(stats_path, "w") as f:
            json.dump(stats, f, indent=1)
        print("stats →", stats_path, flush=True)

    barrier("fit-done")  # no process exits while peers still need collectives
    print(f"process {pid}: DONE", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())

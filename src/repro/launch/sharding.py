"""Per-architecture sharding rules (DESIGN.md §6).

Conventions on the production mesh (pod?, data, model):

* FSDP (zero-3): every weight matrix shards its d_model-ish dim over
  ``data``; optimizer moments follow their parameter.
* TP over ``model``: attention H dim (wq/wo), MLP hidden F, vocab V.
  kv projections are replicated over ``model`` (KV=8 < 16; redundant
  compute is ~1% of FLOPs, zero comm — see DESIGN.md).
* EP over ``model`` for MoE when E % model == 0 (llama4 16e, jamba 16e);
  otherwise TP inside experts (mixtral 8e).
* batch shards over (pod, data); for decode cells whose batch is smaller
  than the axis, the cache length axis takes ``model`` (+ ``data`` for
  long_500k) — distributed flash-decode / SP.
* ``pod`` is pure DP for weights (replicated; grads all-reduce over pod).

Rules are expressed as trailing-dimension specs matched on the flattened
parameter path; leading (scan-stacked) dims are padded with None.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.optim.quantized import QTensor


def _expert_parallel(cfg: ArchConfig, mesh: Mesh) -> bool:
    return cfg.n_experts > 0 and cfg.n_experts % mesh.shape["model"] == 0


def serving_weights_resident(cfg: ArchConfig, mesh: Mesh, budget_gib: float = 12.0) -> bool:
    """Can bf16 weights live TP-only (no per-token FSDP gathers) on this mesh?"""
    total = cfg.param_counts()["total"] * 2 / mesh.shape["model"]
    return total <= budget_gib * 2**30


def _param_rules(cfg: ArchConfig, mesh: Mesh, serving: bool = False):
    """Ordered (substring(s), trailing-dims spec) rules.

    ``serving``: decode wants weights resident — FSDP ("data") sharding
    means an all-gather per generated token, which made every baseline
    decode cell collective-bound (§Perf iteration 6). When the TP-sharded
    weights fit the HBM budget we drop the data axis entirely; for the
    100B+ MoE archs (llama4, jamba) the experts keep their data shard (the
    gather cost is real and reported — serving them properly needs a wider
    EP domain, which the multi-pod mesh's pod axis provides).
    """
    ep = _expert_parallel(cfg, mesh)
    # dense (non-expert) weights: TP-only when serving (they always fit);
    # expert weights: TP-only when the whole model fits, else (serving)
    # weights-STATIONARY: E over model, F over data — tokens move, not
    # weights (models/moe.py set_ep_mesh(stationary=True))
    dd = None if serving else "data"
    if serving and not serving_weights_resident(cfg, mesh) and ep:
        moe_gu = ["model", None, "data"]
        moe_d = ["model", "data", None]
    else:
        ed = None if (serving and serving_weights_resident(cfg, mesh)) else "data"
        moe_gu = ["model", ed, None] if ep else [None, ed, "model"]
        moe_d = ["model", None, ed] if ep else [None, "model", ed]
    return [
        # --- MoE (before generic mlp rules; 'moe' appears in the path) ----
        (("moe", "router"), [dd, None]),
        (("moe", "w_gate"), moe_gu),
        (("moe", "w_up"), moe_gu),
        (("moe", "w_down"), moe_d),
        (("moe", "shared", "w_gate"), [dd, "model"]),
        (("moe", "shared", "w_up"), [dd, "model"]),
        (("moe", "shared", "w_down"), ["model", dd]),
        # --- attention ------------------------------------------------------
        (("wq",), [dd, "model", None]),
        (("wk",), [dd, None, None]),
        (("wv",), [dd, None, None]),
        (("wo",), ["model", None, dd]),
        # --- dense MLP ---------------------------------------------------------
        (("w_gate",), [dd, "model"]),
        (("w_up",), [dd, "model"]),
        (("w_down",), ["model", dd]),
        # --- SSM (split projections; see models/ssm.py sharding note) --------
        (("w_z",), [dd, "model"]),
        (("w_x",), [dd, "model"]),
        (("w_b",), [dd, None]),
        (("w_c",), [dd, None]),
        (("w_dt",), [dd, "model"]),
        (("w_out",), ["model", dd]),
        (("conv_x",), [None, "model"]),
        (("conv_b",), [None, None]),
        (("conv_c",), [None, None]),
        # --- embeddings / heads ---------------------------------------------------
        (("embed",), ["model", dd]),
        (("head",), [dd, "model"]),
    ]


def _match(path: str, keys) -> bool:
    return all(k in path for k in keys)


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def param_pspec_tree(cfg: ArchConfig, mesh: Mesh, params, serving: bool = False):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs).

    The shared-expert rule must win over the generic MoE w_gate rule, so
    rules are checked most-specific-first (more keys = more specific).
    """
    rules = sorted(_param_rules(cfg, mesh, serving), key=lambda r: -len(r[0]))

    def leaf_spec(path, leaf):
        p = _path_str(path)
        ndim = len(leaf.shape)
        for keys, trailing in rules:
            if _match(p, keys):
                spec = [None] * (ndim - len(trailing)) + list(trailing)
                # guard: drop axis sharding on dims it does not divide,
                # unless XLA padding is acceptable (model-TP dims only)
                return P(*spec)
        return P()  # norms, scalars, biases: replicated

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def opt_state_pspec_tree(cfg: ArchConfig, mesh: Mesh, opt_state):
    """Moments follow their parameter (the path still names it: …/wq/m/q).

    int8 payloads keep the parameter's shape → identical spec; per-row
    scales drop the last axis → the parameter's spec minus its last entry.
    This shape-transparency is what keeps the quantised optimizer sharded
    (see optim/quantized.py — §Perf iteration 3).
    """
    rules = sorted(_param_rules(cfg, mesh), key=lambda r: -len(r[0]))

    def leaf_spec(path, leaf):
        p = _path_str(path)
        if "count" in p:
            return P()
        is_scale = ".scale" in p
        for keys, trailing in rules:
            if _match(p, keys):
                t = list(trailing)
                if is_scale:  # shape = param.shape[:-1]
                    spec = [None] * (len(leaf.shape) - (len(t) - 1)) + t[:-1]
                else:
                    spec = [None] * (len(leaf.shape) - len(t)) + t
                return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, opt_state)


def batch_pspecs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    """Input-batch shardings. Train batches arrive pre-split into
    (accum, micro, …) so the microbatch scan never reshapes a sharded dim
    (sharded reshapes make XLA SPMD insert all-gathers)."""
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    micro = shape.global_batch // (cfg.accum_steps if shape.kind == "train" else 1)
    bdim = dp if micro % dp_size == 0 else (
        "data" if micro % mesh.shape["data"] == 0 else None
    )
    lead = (None,) if shape.kind == "train" and cfg.accum_steps > 1 else ()
    spec: dict = {}
    if cfg.family == "audio":
        spec["embeds"] = P(*lead, bdim, None, None)
    elif cfg.family == "vlm":
        spec["tokens"] = P(*lead, bdim, None)
        spec["patches"] = P(*lead, bdim, None, None)
    else:
        spec["tokens"] = P(*lead, bdim, None)
    if shape.kind == "train":
        spec["labels"] = P(*lead, bdim, None)
    return spec


def cache_pspecs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, cache) -> dict:
    """Decode-cache shardings. Batch takes (pod, data) when it divides;
    otherwise the cache length axis takes over (SP / flash-decode)."""
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    b = shape.global_batch
    if b % dp_size == 0:
        bspec, sspec = dp, "model"  # batch over DP axes, cache length over model
    else:
        bspec, sspec = None, (dp + ("model",))  # batch=1: length over everything

    specs: dict = {"idx": P()}
    if "k" in cache:
        # (L_or_M, B, Sc, KV, hd)
        specs["k"] = P(None, bspec, sspec, None, None)
        specs["v"] = P(None, bspec, sspec, None, None)
        specs["pos"] = P(sspec)
    if "ssm_h" in cache:
        nd = len(cache["ssm_h"].shape)
        # (L, B, H, P, N) or (M, 7, B, H, P, N): heads over model
        lead = [None] * (nd - 4)
        specs["ssm_h"] = P(*lead, bspec, "model", None, None)
        ndc = len(cache["ssm_tx"].shape)
        leadc = [None] * (ndc - 3)
        # x-tail channel dim = d_inner (model-divisible); B/C tails are N=128 wide
        specs["ssm_tx"] = P(*leadc, bspec, None, "model")
        specs["ssm_tb"] = P(*leadc, bspec, None, None)
        specs["ssm_tc"] = P(*leadc, bspec, None, None)
    return specs


def step_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, specs):
    """(in_shardings, out_shardings) for the step of this shape cell.

    ``specs`` is the positional input_specs tuple from models.steps.
    """

    def ns(spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    params = specs[0]
    p_specs = param_pspec_tree(cfg, mesh, params)
    if shape.kind == "train":
        opt_state = specs[1]
        o_specs = opt_state_pspec_tree(cfg, mesh, opt_state)
        b_specs = batch_pspecs(cfg, shape, mesh)
        in_sh = (ns(p_specs), ns(o_specs), ns(b_specs))
        out_sh = (ns(p_specs), ns(o_specs), NamedSharding(mesh, P()))
    elif shape.kind == "prefill":
        b_specs = batch_pspecs(cfg, shape, mesh)
        in_sh = (ns(p_specs), ns(b_specs))
        # logits (B,1,V): batch over dp, vocab over model; cache like decode
        cache_shape = ShapeConfig(shape.name, "decode", shape.seq_len, shape.global_batch)
        dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        bdim = dp if shape.global_batch % dp_size == 0 else None
        logits_sh = NamedSharding(mesh, P(bdim, None, "model"))
        out_sh = (logits_sh, None)  # prefill cache shardings: let XLA choose
    else:  # decode — serving layout (weights TP-resident where they fit).
        # batch=1 long-context decode keeps FSDP: with one token per step,
        # per-device HBM time scales with resident weight bytes, and 256-way
        # sharded weights + per-token gathers are cheaper than 16-way
        # resident reads (ICI 50 GB/s loses to HBM 819 GB/s only when the
        # batch amortises the gather — §Perf iteration 10).
        # big-MoE decode always uses the stationary expert layout; dense
        # batch-1 decode keeps FSDP (see note above); resident-class MoE at
        # batch-1 (mixtral long_500k) still prefers resident over per-token
        # expert gathers
        serving = (
            shape.global_batch >= mesh.shape["data"]
            or (cfg.n_experts > 0 and cfg.n_experts % mesh.shape["model"] == 0)
            or (cfg.n_experts > 0 and serving_weights_resident(cfg, mesh))
        )
        p_specs = param_pspec_tree(cfg, mesh, params, serving=serving)
        cache = specs[1]
        c_specs = cache_pspecs(cfg, shape, mesh, cache)
        tok_sh = NamedSharding(mesh, P())
        in_sh = (ns(p_specs), ns(c_specs), tok_sh)
        dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        bdim = dp if shape.global_batch % dp_size == 0 else None
        logits_sh = NamedSharding(mesh, P(bdim, None, "model"))
        out_sh = (logits_sh, ns(c_specs))
    return in_sh, out_sh

"""Pipeline-parallelism selftest (subprocess, 8 host devices).

Checks the GPipe schedule against sequential layer application:
  1. MLP stack, 4 stages × 2 layers, 6 microbatches → exact match;
  2. transformer layers (reduced qwen3 family) through the same harness;
  3. bubble accounting: the schedule runs T = n_micro + n_stages − 1 steps.
"""

import sys

import numpy as np


def main() -> int:
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_mesh
    from repro.launch.pipeline import gpipe, stack_stage_params

    assert len(jax.devices()) >= 4, "need ≥4 host devices"
    mesh = make_mesh((4,), ("stage",))

    # --- 1. MLP stack ---------------------------------------------------------
    L, D, n_micro, Bm = 8, 64, 6, 16
    ks = jax.random.split(jax.random.key(0), L)
    params = {"w": jnp.stack([jax.random.normal(k, (D, D)) / np.sqrt(D) for k in ks])}

    def stage_fn(sp, x):  # sp["w"]: (L/stages, D, D)
        def body(x, w):
            return jnp.tanh(x @ w), None

        return jax.lax.scan(body, x, sp["w"])[0]

    x = jax.random.normal(jax.random.key(1), (n_micro, Bm, D))
    run = gpipe(mesh, "stage", stage_fn, n_micro)
    got = run(stack_stage_params(params, 4), x)

    def seq(x):
        def body(x, w):
            return jnp.tanh(x @ w), None

        return jax.lax.scan(body, x, params["w"])[0]

    want = jax.vmap(seq)(x)
    err = float(jnp.max(jnp.abs(got - want)))
    print("MLP gpipe max err:", err)
    assert err < 1e-5, err

    # --- 2. transformer stages --------------------------------------------------
    from repro.configs import ARCHS, reduced
    from repro.models import lm
    from repro.models.layers import rms_norm

    cfg = reduced(ARCHS["qwen3-14b"], n_layers=8)
    mparams = lm.init_params(jax.random.key(2), cfg)

    def tf_stage(sp, x):
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        body = lm._homogeneous_body(cfg, pos, True, False)
        (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), sp)
        return x

    xh = jax.random.normal(jax.random.key(3), (n_micro, 2, 32, cfg.d_model))
    run_tf = gpipe(mesh, "stage", tf_stage, n_micro)
    got_tf = run_tf(stack_stage_params(mparams["layers"], 4), xh)
    want_tf = jax.vmap(lambda x: tf_stage(mparams["layers"], x))(xh)
    err = float(jnp.max(jnp.abs(got_tf - want_tf)))
    print("transformer gpipe max err:", err)
    assert err < 2e-4, err

    print("PIPELINE SELFTEST PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())

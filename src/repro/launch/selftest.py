"""Multi-device correctness selftest (run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; see
tests/test_distributed.py).

Checks, on real (simulated) multi-device SPMD:
  1. distributed NOMAD quality ≈ single-device reference quality
     (same index, same budget) — the paper's multi-GPU ≈ single-GPU claim;
  2. bitwise determinism of the distributed epoch (run twice → identical);
  3. the hierarchical (pod) variant runs and stays finite, and its flat
     counterpart on the same mesh matches the 2-axis run;
  4. distributed K-means EM (psum factorisation) ≡ single-device EM.
"""

import os
import sys

import numpy as np


def main() -> int:
    import jax

    assert len(jax.devices()) >= 8, f"need 8 host devices, got {len(jax.devices())}"
    import jax.numpy as jnp

    from repro.configs.base import NomadConfig
    from repro.core.nomad import NomadProjection
    from repro.data.synthetic import gaussian_mixture
    from repro.index.ann import build_index
    from repro.index.kmeans import kmeans_fit_sharded, lsh_init_centroids, assign_jnp, _m_step
    from repro.metrics import neighborhood_preservation, random_triplet_accuracy

    x, labels = gaussian_mixture(8000, 32, n_components=8, seed=0)
    cfg = NomadConfig(
        n_points=8000,
        dim=32,
        n_clusters=16,
        n_neighbors=10,
        n_noise=32,
        n_exact_negatives=8,
        batch_size=1024,
        n_epochs=15,
    )
    index = build_index(x, cfg)

    # --- 1. quality parity ---------------------------------------------------
    ref = NomadProjection(cfg, strategy="local").fit(x, index=index)
    np_ref = neighborhood_preservation(x, ref.embedding, k=10, n_queries=400)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    dist = NomadProjection(
        cfg, strategy="sharded", mesh=mesh, shard_axes=("data", "model")
    ).fit(x, index=index)
    emb = dist.embedding
    assert dist.strategy == "sharded" and dist.n_shards == 8, dist
    assert np.isfinite(emb).all(), "distributed embedding has NaNs"
    np_dist = neighborhood_preservation(x, emb, k=10, n_queries=400)
    rta_ref = random_triplet_accuracy(x, ref.embedding, 4000)
    rta_dist = random_triplet_accuracy(x, emb, 4000)
    print(f"NP@10 ref={np_ref:.4f} dist={np_dist:.4f}; RTA ref={rta_ref:.3f} dist={rta_dist:.3f}")
    assert np_dist > 0.5 * np_ref - 0.01, (np_ref, np_dist)
    assert rta_dist > 0.8 * rta_ref, (rta_ref, rta_dist)

    # --- 2. determinism --------------------------------------------------------
    emb2 = NomadProjection(
        cfg, strategy="sharded", mesh=mesh, shard_axes=("data", "model")
    ).fit_transform(x, index=index)
    assert np.array_equal(emb, emb2), "distributed run is not deterministic"
    print("determinism: OK")

    # --- 2b. the deprecation shim still serves the legacy tuple ----------------
    import warnings

    from repro.core.distributed import fit_distributed

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        emb_shim, _, _ = fit_distributed(
            cfg.replace(n_epochs=2), x, mesh, index=index
        )
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert np.isfinite(emb_shim).all()
    print("fit_distributed shim: OK (DeprecationWarning emitted)")

    # --- 3. hierarchical multi-pod ---------------------------------------------
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    hier = NomadProjection(
        cfg,
        strategy="hierarchical",
        mesh=mesh3,
        shard_axes=("data", "model"),
        pod_axis="pod",
    ).fit(x, index=index)
    emb_h = hier.embedding
    assert hier.strategy == "hierarchical" and hier.n_shards == 8, hier
    assert np.isfinite(emb_h).all()
    np_h = neighborhood_preservation(x, emb_h, k=10, n_queries=400)
    print(f"hierarchical NP@10={np_h:.4f} (flat dist={np_dist:.4f})")
    assert np_h > 0.4 * np_ref - 0.01, (np_ref, np_h)

    emb_f = NomadProjection(
        cfg, strategy="sharded", mesh=mesh3, shard_axes=("data", "model"), pod_axis="pod"
    ).fit_transform(x, index=index)
    assert np.isfinite(emb_f).all()

    # --- 4. distributed K-means ≡ reference EM ---------------------------------
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh1 = jax.make_mesh((8,), ("data",))
    xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh1, P("data", None)))
    cents_d = kmeans_fit_sharded(jax.random.key(0), xs, 16, mesh1, "data", n_iters=5)
    cents = lsh_init_centroids(jax.random.key(0), jnp.asarray(x), 16)
    for _ in range(5):
        a, _d = assign_jnp(jnp.asarray(x), cents)
        cents, _ = _m_step(jnp.asarray(x), a, 16, cents)
    err = float(jnp.max(jnp.abs(cents_d - cents)))
    print("distributed kmeans max err:", err)
    # psum partial-sum order ≠ single-device scatter-add order in fp32, and a
    # borderline point flipping assignment amplifies the drift over 5 EM
    # iterations — 1e-2 bounds that while still catching real factorisation bugs
    assert err < 1e-2, err

    print("SELFTEST PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())

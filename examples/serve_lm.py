"""Batched serving demo: prefill a batch of prompts, then decode tokens
autoregressively with the KV/SSM cache machinery — the ``serve_step`` path
the decode dry-run cells lower, exercised end to end on CPU.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b --new-tokens 24

Works for every decode-capable zoo family (dense / MoE / SSM / hybrid /
SWA ring buffer). With ``--map-lookup`` the demo closes the loop with the
embed→map pipeline: it streams a reference corpus through the same model
into a NOMAD map, then asks — via the **public** ``FrozenMap.neighbors``
frozen-index query, the same call ``POST /explore`` uses — which corpus
documents each decoded continuation lands next to.
"""

import sys

sys.path.insert(0, "src")

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument(
        "--map-lookup",
        action="store_true",
        help="fit a small map over a reference corpus embedded by this model "
        "and report each continuation's nearest corpus docs "
        "(public FrozenMap.neighbors)",
    )
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.models import lm, steps as steps_lib

    # ssm_chunk=1 lets SSD prefill any prompt length (demo-sized model)
    cfg = reduced(ARCHS[args.arch], n_layers=4, ssm_chunk=1)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only; pick a decoder arch")
    print(f"serving {cfg.name} ({cfg.family}); batch={args.batch}, "
          f"prompt={args.prompt_len}, new={args.new_tokens}")

    params = lm.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    total = args.prompt_len + args.new_tokens
    if cfg.family == "vlm":
        prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len - cfg.n_vision_patches))
        patches = rng.normal(0, 1, (args.batch, cfg.n_vision_patches, cfg.d_model)).astype(np.float32)
        batch = {"tokens": jnp.asarray(prompts[:, :-1]), "patches": jnp.asarray(patches)}
    else:
        prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
        batch = {"tokens": jnp.asarray(prompts[:, :-1])}

    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    decode = jax.jit(steps_lib.make_decode_step(cfg))

    t0 = time.time()
    logits, stacked = prefill(params, batch)
    print(f"prefill: {time.time()-t0:.2f}s (logits {logits.shape})")

    # load the prefill outputs into a decode cache sized for the full run
    cache = lm.init_cache(cfg, args.batch, total, filled=args.prompt_len - 1)
    cache = lm.load_cache_from_prefill(cfg, cache, stacked, args.prompt_len - 1)

    tok = jnp.asarray(prompts[:, -1:])
    generated = []
    t0 = time.time()
    for i in range(args.new_tokens):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        generated.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    gen = np.stack(generated, axis=1)
    print(f"decoded {args.new_tokens} tokens × {args.batch} seqs in {dt:.2f}s "
          f"({args.new_tokens*args.batch/dt:.1f} tok/s on CPU)")
    print("sampled continuations (greedy):")
    for b in range(args.batch):
        print(f"  seq{b}: …{prompts[b,-5:].tolist()} → {gen[b,:12].tolist()}…")
    assert np.isfinite(np.asarray(logits)).all()

    if args.map_lookup and cfg.family != "vlm":
        import tempfile

        from repro.configs.base import NomadConfig
        from repro.core.nomad import NomadProjection
        from repro.data.synthetic import class_token_corpus
        from repro.pipeline import embed_to_store, make_embed_fn
        from repro.serve.frozen import FrozenMap

        # a reference corpus embedded by the same model, streamed to disk
        docs, classes = class_token_corpus(512, args.prompt_len, cfg.vocab_size)
        with tempfile.TemporaryDirectory() as d:
            store = embed_to_store(params, cfg, docs, d, doc_batch=128)
            ncfg = NomadConfig(
                n_points=store.shape[0], dim=store.shape[1],
                n_clusters=8, n_epochs=4, batch_size=512, chunk_rows=1024,
            )
            fz = FrozenMap.from_fit(NomadProjection(ncfg).fit(store), ncfg)
        # embed prompt+continuation with the same pooled forward, then ask
        # the frozen index (public API) what corpus docs live nearest
        fwd = make_embed_fn(cfg)
        full = np.concatenate([prompts, gen], axis=1).astype(np.int32)
        vecs = np.asarray(fwd(params, jnp.asarray(full)))
        ids, dists = fz.neighbors(vecs, k=3)
        print("nearest corpus docs per continuation (id:class @ dist):")
        for b in range(args.batch):
            near = ", ".join(
                f"{i}:{classes[i]}@{d:.2f}"
                for i, d in zip(ids[b], dists[b]) if i >= 0
            )
            print(f"  seq{b}: {near}")

    print("OK")


if __name__ == "__main__":
    main()

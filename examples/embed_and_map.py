"""End-to-end driver: train a small LM from the model zoo, stream a corpus
through it into an on-disk store, map the embeddings with NOMAD
Projection, train the inverse head, and explore the result — the full
production pipeline of the paper (model → vectors → map → explore) in one
script.

    PYTHONPATH=src python examples/embed_and_map.py [--train-steps 300]

The embed stage is ``repro.pipeline``'s streaming path: pooled forwards
land directly in ``write_sharded()`` chunks and the fit consumes the
store, so the full ``(N, D)`` embedding matrix never materialises on host
— peak RSS stays O(doc_batch + shard), not O(N). ``--materialize``
switches back to the old collect-then-fit path (bit-identical map, much
bigger footprint); ``--rss-compare`` runs streamed-then-materialized in
one process and reports both ``ru_maxrss`` watermarks (the CI smoke
asserts the gap).
"""

import sys

sys.path.insert(0, "src")

import argparse
import json
import os
import time

import numpy as np


def _rss_mb() -> float:
    import resource

    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return ru / 1024.0 if sys.platform != "darwin" else ru / (1024.0 * 1024.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", help="zoo arch (reduced for CPU)")
    ap.add_argument("--train-steps", type=int, default=300, help="LM pre-training steps")
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--doc-batch", type=int, default=128)
    ap.add_argument("--epochs", type=int, default=30, help="map fit epochs")
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--inverse-steps", type=int, default=500)
    ap.add_argument("--workdir", default="", help="keep artifacts here (default: tmp)")
    ap.add_argument("--materialize", action="store_true",
                    help="old path: collect the (N, D) matrix, then fit")
    ap.add_argument("--rss-compare", action="store_true",
                    help="embed streamed then materialized, report both RSS "
                    "watermarks, skip the fit (the CI smoke)")
    ap.add_argument("--json", default="", help="write results to this file")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.configs.base import NomadConfig
    from repro.data.embeddings import embed_corpus
    from repro.data.loader import TokenStream
    from repro.data.synthetic import class_token_corpus
    from repro.models import lm, steps as steps_lib
    from repro.optim import AdamW, warmup_cosine
    from repro.pipeline import embed_to_store

    report = {"example": "embed_and_map", "config": vars(args)}

    # ---- 1. train a small LM of the chosen family on synthetic tokens --------
    cfg = reduced(
        ARCHS[args.arch], n_layers=args.n_layers, d_model=args.d_model,
        vocab_size=512,
    )
    print(f"training {cfg.name} ({cfg.family}) for {args.train_steps} steps …")
    params = lm.init_params(jax.random.key(0), cfg)
    if args.train_steps > 0:
        opt = AdamW(
            schedule=warmup_cosine(3e-3, min(50, args.train_steps), args.train_steps),
            moment_dtype="float32",
        )
        opt_state = opt.init(params)
        step_fn = jax.jit(steps_lib.make_train_step(cfg, opt))
        stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len)
        t0 = time.time()
        for s in range(args.train_steps):
            batch = {k: np.asarray(v) for k, v in stream.batch(s, 16).items()}
            params, opt_state, loss = step_fn(params, opt_state, batch)
            if s % 50 == 0:
                print(f"  step {s:4d}  loss {float(loss):.4f}")
        print(f"trained in {time.time()-t0:.1f}s; final loss {float(loss):.3f}")

    # ---- 2. a corpus with latent classes, embedded by the trained model ------
    tokens, classes = class_token_corpus(
        args.docs, args.seq_len, cfg.vocab_size, n_classes=8
    )
    workdir = args.workdir or os.path.join(
        os.environ.get("TMPDIR", "/tmp"), f"embed_and_map_{os.getpid()}"
    )
    store_dir = os.path.join(workdir, "embeddings")
    token_batches = [
        tokens[i : i + args.doc_batch] for i in range(0, args.docs, args.doc_batch)
    ]

    if args.rss_compare:
        # streamed FIRST: ru_maxrss is a monotone watermark, so the order
        # streamed → materialized is the only one that can show the gap
        t0 = time.time()
        store = embed_to_store(
            params, cfg, token_batches, store_dir, doc_batch=args.doc_batch
        )
        streamed_mb = _rss_mb()
        print(f"streamed embed: {store.shape} in {time.time()-t0:.1f}s, "
              f"peak RSS {streamed_mb:.0f} MB")
        t0 = time.time()
        vecs = embed_corpus(params, cfg, token_batches)
        mono_mb = _rss_mb()
        print(f"materialized embed: {vecs.shape} in {time.time()-t0:.1f}s, "
              f"peak RSS {mono_mb:.0f} MB")
        np.testing.assert_array_equal(store.materialize(), vecs)
        print("streamed store is bit-identical to the materialized matrix")
        report["rss_compare"] = {
            "streamed_peak_mb": streamed_mb,
            "monolithic_peak_mb": mono_mb,
        }
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
        print("OK — RSS comparison complete")
        return

    print(f"embedding {args.docs} documents "
          f"({'materialized' if args.materialize else 'streamed → ' + store_dir}) …")
    t0 = time.time()
    if args.materialize:
        x = embed_corpus(params, cfg, token_batches)
    else:
        x = embed_to_store(
            params, cfg, token_batches, store_dir, doc_batch=args.doc_batch
        )
    report["embed_s"] = time.time() - t0
    print(f"corpus embeddings: {x.shape} ({report['embed_s']:.1f}s)")

    # ---- 3. NOMAD-map the embeddings (the fit consumes the store) ------------
    from repro.core.nomad import NomadProjection
    from repro.metrics import neighborhood_preservation, random_triplet_accuracy
    from repro.serve.frozen import FrozenMap

    ckdir = os.path.join(workdir, "map")
    ncfg = NomadConfig(
        n_points=x.shape[0], dim=x.shape[1], n_clusters=args.clusters,
        n_neighbors=15, n_noise=32, n_exact_negatives=8, batch_size=512,
        n_epochs=args.epochs, chunk_rows=1024, checkpoint_dir=ckdir,
        kernel_impl="auto",  # registry picks pallas vs jnp per backend
    )
    t0 = time.time()
    fit = NomadProjection(ncfg).fit(x)
    report["fit_s"] = time.time() - t0
    emb = fit.embedding
    vecs = x.materialize() if hasattr(x, "materialize") else x
    np10 = neighborhood_preservation(vecs, emb, k=10, n_queries=500)
    rta = random_triplet_accuracy(vecs, emb, 10_000)
    # do documents of the same class land together?
    from repro.metrics.neighborhood import _topk_neighbors

    nb = np.asarray(_topk_neighbors(jnp.asarray(emb[:400]), jnp.asarray(emb), 10))
    purity = float(np.mean(classes[nb] == classes[:400, None]))
    print(f"map quality: NP@10={np10:.4f} triplet={rta:.4f} class-purity={purity:.3f}")
    report.update(np10=np10, triplet=rta, class_purity=purity)
    assert purity > 0.5, "document classes did not separate"

    # ---- 4. inverse head + explore: "what lives at this spot?" ---------------
    from repro.pipeline import inverse_from_frozen, roundtrip_score, save_inverse

    frozen = FrozenMap.from_fit(fit, ncfg)
    t0 = time.time()
    inv = inverse_from_frozen(frozen, hidden=(64, 64), steps=args.inverse_steps)
    report["inverse_train_s"] = time.time() - t0
    save_inverse(ckdir, inv)
    r2 = roundtrip_score(inv, emb, vecs)
    report["inverse_roundtrip_r2"] = r2
    print(f"inverse head: R²={r2:.3f} "
          f"({args.inverse_steps} steps, {report['inverse_train_s']:.1f}s) "
          f"→ {ckdir}/inverse.npz")
    spot = emb[0]
    ids, dists = frozen.neighbors(inv.decode(spot)[0], k=5)
    near = [int(i) for i in ids if i >= 0]
    same = float(np.mean(classes[near] == classes[0])) if near else 0.0
    print(f"explore({spot.round(2).tolist()}): docs {near} "
          f"(class match {same:.2f} vs doc 0)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
    print("OK — model → embeddings → map → explore pipeline complete")


if __name__ == "__main__":
    main()

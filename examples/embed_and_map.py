"""End-to-end driver (deliverable b): train a small LM from the model zoo
for a few hundred steps, embed a corpus with it, and map the embeddings
with NOMAD Projection — the full production pipeline of the paper
(model → vectors → map) in one script.

    PYTHONPATH=src python examples/embed_and_map.py [--steps 300]
"""

import sys

sys.path.insert(0, "src")

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen3-14b", help="zoo arch (reduced for CPU)")
    args = ap.parse_args()

    import jax

    from repro.configs import ARCHS, reduced
    from repro.configs.base import NomadConfig
    from repro.core.nomad import NomadProjection
    from repro.data.embeddings import embed_corpus
    from repro.data.loader import TokenStream
    from repro.metrics import neighborhood_preservation, random_triplet_accuracy
    from repro.models import lm, steps as steps_lib
    from repro.optim import AdamW, warmup_cosine

    # ---- 1. train a ~small LM of the chosen family on synthetic tokens -------
    cfg = reduced(ARCHS[args.arch], n_layers=4, d_model=128, vocab_size=512)
    print(f"training {cfg.name} ({cfg.family}) for {args.steps} steps …")
    params = lm.init_params(jax.random.key(0), cfg)
    opt = AdamW(schedule=warmup_cosine(3e-3, 50, args.steps), moment_dtype="float32")
    opt_state = opt.init(params)
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt))
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=64)
    t0 = time.time()
    first = last = None
    for s in range(args.steps):
        batch = {k: np.asarray(v) for k, v in stream.batch(s, 16).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if s == 0:
            first = float(loss)
        last = float(loss)
        if s % 50 == 0:
            print(f"  step {s:4d}  loss {float(loss):.4f}")
    print(f"trained in {time.time()-t0:.1f}s; loss {first:.3f} → {last:.3f}")

    # ---- 2. embed a corpus with the trained model ------------------------------
    # a corpus with latent structure: each "document class" biases tokens
    n_docs, seq = 4000, 64
    rng = np.random.default_rng(0)
    classes = rng.integers(0, 8, n_docs)
    base = rng.integers(0, cfg.vocab_size, (8, seq))
    noise = rng.integers(0, cfg.vocab_size, (n_docs, seq))
    keep = rng.random((n_docs, seq)) < 0.7
    tokens = np.where(keep, base[classes], noise).astype(np.int32)
    print(f"embedding {n_docs} documents …")
    vecs = embed_corpus(params, cfg, [tokens[i : i + 128] for i in range(0, n_docs, 128)])
    print("corpus embeddings:", vecs.shape)

    # ---- 3. NOMAD-map the embeddings ---------------------------------------------
    ncfg = NomadConfig(
        n_points=n_docs, dim=vecs.shape[1], n_clusters=8, n_neighbors=15,
        n_noise=32, n_exact_negatives=8, batch_size=512, n_epochs=30,
        kernel_impl="auto",  # registry picks pallas vs jnp per backend
    )
    emb = NomadProjection(ncfg).fit_transform(vecs)
    np10 = neighborhood_preservation(vecs, emb, k=10, n_queries=500)
    rta = random_triplet_accuracy(vecs, emb, 10_000)
    # do documents of the same class land together?
    import jax.numpy as jnp

    from repro.metrics.neighborhood import _topk_neighbors

    nb = np.asarray(_topk_neighbors(jnp.asarray(emb[:400]), jnp.asarray(emb), 10))
    purity = float(np.mean(classes[nb] == classes[:400, None]))
    print(f"map quality: NP@10={np10:.4f} triplet={rta:.4f} class-purity={purity:.3f}")
    assert purity > 0.5, "document classes did not separate"
    print("OK — model → embeddings → map pipeline complete")


if __name__ == "__main__":
    main()

"""Quickstart: map a synthetic embedding corpus with NOMAD Projection.

    PYTHONPATH=src python examples/quickstart.py

Builds the LSH-initialised K-means ANN index, runs the NOMAD optimisation
(PCA init, lr n/10 linearly annealed — the paper's §3.4 recipe), reports
NP@10 / triplet accuracy, and writes an ASCII density sketch of the map —
the terminal cousin of the paper's Figure 1.
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture
from repro.metrics import neighborhood_preservation, random_triplet_accuracy


def ascii_density(emb: np.ndarray, labels: np.ndarray, w: int = 72, h: int = 24) -> str:
    gx = np.clip(((emb[:, 0] - emb[:, 0].min()) / np.ptp(emb[:, 0]) * (w - 1)), 0, w - 1).astype(int)
    gy = np.clip(((emb[:, 1] - emb[:, 1].min()) / np.ptp(emb[:, 1]) * (h - 1)), 0, h - 1).astype(int)
    grid = np.full((h, w), " ", dtype="<U1")
    glyphs = "0123456789abcdefghijklmnop"
    for x, y, l in zip(gx, gy, labels):
        grid[y, x] = glyphs[l % len(glyphs)]
    return "\n".join("".join(row) for row in grid)


def main():
    n, dim, comps = 10_000, 64, 12
    print(f"generating {n} points, {dim}-d, {comps} clusters …")
    x, labels = gaussian_mixture(n, dim, n_components=comps, seed=0)

    cfg = NomadConfig(
        n_points=n, dim=dim,
        n_clusters=16, n_neighbors=15,            # §3.2 index
        n_noise=48, n_exact_negatives=8,          # §3.3 loss
        batch_size=1024, n_epochs=40,             # §3.4 schedule (lr0 = n/10)
        use_pallas=True,
    )
    print("fitting NOMAD Projection …")
    res = NomadProjection(cfg).fit(x)
    print(f"done in {res.wall_time_s:.1f}s "
          f"({np.mean(res.epoch_times[1:]):.2f}s/epoch after warmup)")
    print(f"loss {res.losses[0]:.4f} → {res.losses[-1]:.4f}")

    np10 = neighborhood_preservation(x, res.embedding, k=10, n_queries=1000)
    rta = random_triplet_accuracy(x, res.embedding, 20_000)
    print(f"NP@10 = {np10:.4f}   random-triplet accuracy = {rta:.4f}")
    print("\nmap (digits = cluster labels):")
    print(ascii_density(res.embedding, labels))


if __name__ == "__main__":
    main()

"""Quickstart: map a synthetic embedding corpus with NOMAD Projection.

    PYTHONPATH=src python examples/quickstart.py [--n 10000] [--epochs 40]

Builds the LSH-initialised K-means ANN index, runs the NOMAD optimisation
(PCA init, lr n/10 linearly annealed — the paper's §3.4 recipe) through the
unified ``NomadProjection`` estimator (``strategy="auto"`` picks local vs
sharded from ``jax.devices()``), streams progress via the event API,
reports NP@10 / triplet accuracy, and writes an ASCII density sketch of the
map — the terminal cousin of the paper's Figure 1.

The ``--n 1500 --epochs 4`` point is the CI smoke test: the full public API
path (index → strategy → events → FitResult) at tiny N on CPU.
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.core.strategy import FitCallbacks
from repro.data.synthetic import gaussian_mixture
from repro.metrics import neighborhood_preservation, random_triplet_accuracy


def ascii_density(emb: np.ndarray, labels: np.ndarray, w: int = 72, h: int = 24) -> str:
    gx = np.clip(((emb[:, 0] - emb[:, 0].min()) / np.ptp(emb[:, 0]) * (w - 1)), 0, w - 1).astype(int)
    gy = np.clip(((emb[:, 1] - emb[:, 1].min()) / np.ptp(emb[:, 1]) * (h - 1)), 0, h - 1).astype(int)
    grid = np.full((h, w), " ", dtype="<U1")
    glyphs = "0123456789abcdefghijklmnop"
    for x, y, l in zip(gx, gy, labels):
        grid[y, x] = glyphs[l % len(glyphs)]
    return "\n".join("".join(row) for row in grid)


class Progress(FitCallbacks):
    """Structured fit events: loss curve + checkpoint notices."""

    wants_embedding = False  # loss/time only — skip the per-epoch host copy

    def on_epoch_end(self, ev):
        if ev.epoch % 10 == 0 or ev.epoch == ev.n_epochs - 1:
            print(f"  epoch {ev.epoch:3d}/{ev.n_epochs}  loss {ev.loss:.4f}  "
                  f"({ev.time_s:.2f}s, {ev.strategy})")

    def on_checkpoint(self, ev):
        print(f"  checkpoint @ epoch {ev.epoch} → {ev.directory}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--clusters", type=int, default=16)
    ap.add_argument("--checkpoint-dir", default="", help="enable checkpoint/resume")
    ap.add_argument(
        "--store", action="store_true",
        help="round-trip the corpus through a sharded on-disk store and fit "
        "from disk (the larger-than-RAM ingestion path; same map bit-for-bit "
        "when cfg.chunk_rows matches)",
    )
    args = ap.parse_args()

    n, dim, comps = args.n, args.dim, 12
    print(f"generating {n} points, {dim}-d, {comps} clusters …")
    x, labels = gaussian_mixture(n, dim, n_components=comps, seed=0)

    cfg = NomadConfig(
        n_points=n, dim=dim,
        n_clusters=args.clusters, n_neighbors=15,    # §3.2 index
        n_noise=48, n_exact_negatives=8,             # §3.3 loss
        batch_size=min(1024, n), n_epochs=args.epochs,  # §3.4 schedule (lr0 = n/10)
        strategy="auto",                             # local vs sharded, from devices
        checkpoint_dir=args.checkpoint_dir,
    )
    fit_input = x
    if args.store:
        import tempfile

        from repro.data.store import write_sharded

        store_dir = tempfile.mkdtemp(prefix="quickstart-store-")
        fit_input = write_sharded(x, store_dir, rows_per_shard=4096)
        print(f"fitting from disk-backed store at {store_dir} "
              f"({len(fit_input._files)} shards) …")
    print("fitting NOMAD Projection …")
    res = NomadProjection(cfg).fit(fit_input, callbacks=Progress())
    if args.store:
        assert res.index_build_strategy == "streamed", res.index_build_strategy
    print(f"done in {res.wall_time_s:.1f}s "
          f"({np.mean(res.epoch_times[1:] or res.epoch_times):.2f}s/epoch after warmup) "
          f"[strategy={res.strategy}, shards={res.n_shards}]")
    print(f"loss {res.losses[0]:.4f} → {res.losses[-1]:.4f}")

    np10 = neighborhood_preservation(x, res.embedding, k=10, n_queries=min(1000, n))
    rta = random_triplet_accuracy(x, res.embedding, 20_000)
    print(f"NP@10 = {np10:.4f}   random-triplet accuracy = {rta:.4f}")
    chance = 10 / n
    assert np10 > 3 * chance, f"map no better than chance (NP@10={np10:.4f})"
    assert np.isfinite(res.embedding).all()
    print("\nmap (digits = cluster labels):")
    print(ascii_density(res.embedding, labels))
    print("OK")


if __name__ == "__main__":
    main()

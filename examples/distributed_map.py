"""Distributed NOMAD Projection on 8 simulated devices (paper Fig. 2).

    PYTHONPATH=src python examples/distributed_map.py [--hierarchical]

Demonstrates the paper's distribution strategy end to end: clusters sharded
across a (data=2, model=4) mesh — or a (pod=2, data=2, model=2) mesh with
the hierarchical super-mean exchange when --hierarchical is given — with
the per-epoch means all-gather as the only collective. Compares quality and
wall-time against the single-device reference on the same index.
"""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")

import time

import numpy as np


def main():
    import jax

    from repro.configs.base import NomadConfig
    from repro.core.nomad import NomadProjection
    from repro.data.synthetic import gaussian_mixture
    from repro.index.ann import build_index
    from repro.launch.mesh import make_mesh
    from repro.metrics import neighborhood_preservation, random_triplet_accuracy

    hier = "--hierarchical" in sys.argv
    n, dim = 12_000, 64
    x, labels = gaussian_mixture(n, dim, n_components=10, seed=0)
    cfg = NomadConfig(
        n_points=n, dim=dim, n_clusters=16, n_neighbors=15, n_noise=48,
        n_exact_negatives=8, batch_size=1024, n_epochs=30,
    )
    print("building index …")
    index = build_index(x, cfg)

    print("single-device reference …")
    t0 = time.time()
    ref = NomadProjection(cfg, strategy="local").fit(x, index=index)
    t_ref = time.time() - t0

    # same estimator, different execution strategy — the whole migration
    # from the old fit_distributed() free function is these two kwargs
    if hier:
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        proj = NomadProjection(cfg, strategy="hierarchical", mesh=mesh, pod_axis="pod")
        print("8 shards, hierarchical (pod super-means across the slow axis) …")
    else:
        mesh = make_mesh((2, 4), ("data", "model"))
        proj = NomadProjection(cfg, strategy="sharded", mesh=mesh)
        print("8 shards, flat mean exchange (the paper's strategy) …")
    t0 = time.time()
    dist = proj.fit(x, index=index)
    t_dist = time.time() - t0
    print(f"ran as strategy={dist.strategy} on mesh {dist.mesh_shape} "
          f"({dist.n_shards} shards)")

    for name, e, t in (("1-device", ref.embedding, t_ref), ("8-shard", dist.embedding, t_dist)):
        np10 = neighborhood_preservation(x, e, k=10, n_queries=800)
        rta = random_triplet_accuracy(x, e, 20_000)
        print(f"{name:9s}: {t:6.1f}s  NP@10={np10:.4f}  triplet={rta:.4f}")
    print(f"(simulated devices share one CPU — wall-clock parity is the "
          f"expectation here; on real chips the 8-shard fit is ~8× faster "
          f"per epoch, which is the paper's Table-1 claim)")


if __name__ == "__main__":
    main()

"""Serving a map over HTTP: fit → checkpoint → uvicorn → POST /project.

    PYTHONPATH=src python examples/serve_http.py [--n 5000] [--port 8787]

The full service stack end-to-end: fit once with a checkpoint dir, build
the service (registry + result cache + batching engine) from the
checkpoint alone, run the FastAPI app under uvicorn in a background
thread, and talk to it like any other client would — plain
``urllib.request`` POSTs, no SDK. Verifies the HTTP round trip returns
exactly the placements a direct in-process ``MapServer.transform`` gives,
demonstrates a warm cache hit, and dumps ``/metrics``.

Needs the ``[service]`` extra (``pip install -e '.[service]'``); prints a
pointer and exits 0 on bare installs so smoke harnesses can always run it.
"""

import argparse
import json
import os
import sys
import tempfile
import threading
import time
import urllib.request

sys.path.insert(0, "src")

import numpy as np


def http_json(method, url, body=None):
    data = None if body is None else json.dumps(body).encode()
    req = urllib.request.Request(
        url, data=data, method=method, headers={"Content-Type": "application/json"}
    )
    with urllib.request.urlopen(req, timeout=30) as r:
        return json.loads(r.read())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5_000)
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=128)
    ap.add_argument("--port", type=int, default=8787)
    args = ap.parse_args()

    try:
        import uvicorn  # noqa: F401
        from repro.service.app import create_app
    except ImportError:
        print("this example needs the HTTP extras: pip install -e '.[service]'")
        return 0

    from repro.configs.base import NomadConfig
    from repro.core.nomad import NomadProjection
    from repro.data.synthetic import gaussian_mixture
    from repro.serve import FrozenMap, MapServer
    from repro.service import MapService

    # -- 1. fit with a checkpoint dir ----------------------------------------
    ckdir = os.path.join(tempfile.mkdtemp(prefix="nomad_http_"), "ck")
    comps = 8
    x, _ = gaussian_mixture(args.n, args.dim, n_components=comps, seed=0)
    cfg = NomadConfig(
        n_points=args.n, dim=args.dim,
        n_clusters=args.clusters, n_neighbors=15,
        n_epochs=args.epochs, batch_size=min(1024, args.n),
        checkpoint_dir=ckdir,
        serve_microbatch=args.microbatch,
    )
    print(f"fitting {args.n} points … (checkpoints → {ckdir})")
    NomadProjection(cfg).fit(x)
    del x  # the service below never sees the training data

    # -- 2. service from the checkpoint alone, uvicorn in a thread -----------
    svc = MapService()
    svc.registry.load(ckdir, version="v1")
    server = uvicorn.Server(
        uvicorn.Config(
            create_app(svc), host="127.0.0.1", port=args.port, log_level="warning"
        )
    )
    threading.Thread(target=server.run, daemon=True).start()
    base = f"http://127.0.0.1:{args.port}"
    for _ in range(100):
        if server.started:
            break
        time.sleep(0.05)
    health = http_json("GET", f"{base}/health")
    print(f"serving {base}: {health}")

    # -- 3. clients: POST /project, verify against the in-process path -------
    q, _ = gaussian_mixture(args.queries, args.dim, n_components=comps, seed=99)
    t0 = time.time()
    body = http_json("POST", f"{base}/project", {"rows": q.tolist(), "seed": 7})
    wall = time.time() - t0
    got = np.asarray(body["embedding"], np.float32)
    want = MapServer(FrozenMap.from_checkpoint(ckdir)).transform(q, seed=7)
    np.testing.assert_array_equal(got, want.embedding)
    print(f"POST /project: {body['n_queries']} rows in {wall * 1e3:.0f}ms "
          f"({body['n_batches']} device batches) — bit-equal to in-process transform")

    t0 = time.time()
    again = http_json("POST", f"{base}/project", {"rows": q.tolist(), "seed": 7})
    print(f"again: cache_hit={again['cache_hit']} in {(time.time() - t0) * 1e3:.0f}ms")
    assert again["cache_hit"] and again["embedding"] == body["embedding"]

    m = http_json("GET", f"{base}/metrics")
    v1 = m["maps"]["v1"]
    print(f"/metrics: {m['counters']} "
          f"| batch_fill={v1['batch_fill']:.2f} n_batches={v1['n_batches']}")

    server.should_exit = True
    svc.close()
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

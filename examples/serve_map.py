"""Serving a map: fit → checkpoint → frozen MapServer → transform.

    PYTHONPATH=src python examples/serve_map.py [--n 10000] [--queries 2000]

The production loop the paper's Wikipedia map needs: fit once with a
checkpoint dir, then bring up a server from the checkpoint alone — no
training array in sight — and place unseen points on the frozen map with
``transform``. Prints per-batch placement latency and checks that queries
drawn from the training distribution land among their high-dim neighbors.
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture
from repro.serve import FrozenMap, MapServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--queries", type=int, default=2_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--clusters", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=512)
    ap.add_argument("--checkpoint-dir", default="", help="default: a temp dir")
    args = ap.parse_args()

    ckdir = args.checkpoint_dir or os.path.join(
        tempfile.mkdtemp(prefix="nomad_serve_"), "ck"
    )
    comps = 12
    x, _ = gaussian_mixture(args.n, args.dim, n_components=comps, seed=0)

    # -- 1. fit with a checkpoint dir (θ + index cache land beside it) -------
    cfg = NomadConfig(
        n_points=args.n, dim=args.dim,
        n_clusters=args.clusters, n_neighbors=15,
        n_epochs=args.epochs, batch_size=min(1024, args.n),
        checkpoint_dir=ckdir,
        serve_microbatch=args.microbatch,
    )
    print(f"fitting {args.n} points … (checkpoints → {ckdir})")
    res = NomadProjection(cfg).fit(x)
    print(f"fit done in {res.wall_time_s:.1f}s, loss {res.losses[-1]:.4f}")
    del x, res  # the server below never sees the training data

    # -- 2. bring up a server from the checkpoint alone ----------------------
    frozen = FrozenMap.from_checkpoint(ckdir)
    server = MapServer(frozen)
    print(f"serving: strategy={server.strategy}, shards={server.n_shards}, "
          f"microbatch={server.microbatch}, steps={server.steps}")

    # -- 3. place unseen points ----------------------------------------------
    q, _ = gaussian_mixture(args.queries, args.dim, n_components=comps, seed=99)
    out = server.transform(q, seed=0)
    lat = 1e3 * np.asarray(out.batch_latency_s)
    print(f"placed {out.n_queries} queries in {out.wall_time_s:.2f}s "
          f"({len(lat)} batches: p50 {np.percentile(lat, 50):.1f}ms, "
          f"max {lat.max():.1f}ms, "
          f"{out.n_queries / out.wall_time_s:.0f} pts/s)")

    # each query's placement should sit inside its frozen kNN's 2-D spread
    emb_rows = np.asarray(frozen.theta_rows)
    live = out.neighbor_ids >= 0
    inv = np.asarray(frozen.inv_perm)
    pos = {int(o): r for r, o in enumerate(inv) if o >= 0}
    ok = 0
    for b in range(out.n_queries):
        ids = out.neighbor_ids[b][live[b]]
        nb = emb_rows[[pos[int(i)] for i in ids]]
        radius = np.linalg.norm(nb - nb.mean(0), axis=1).max()
        ok += np.linalg.norm(out.embedding[b] - nb.mean(0)) <= 3 * radius + 1e-9
    frac = ok / out.n_queries
    print(f"{frac:.1%} of placements within 3× their neighborhood radius")
    assert frac > 0.9, "placements drifted off their frozen neighborhoods"
    assert np.isfinite(out.embedding).all()
    print("OK")


if __name__ == "__main__":
    main()

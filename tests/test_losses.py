"""Loss-layer tests, incl. the paper's two structural claims:

* **Reduction** (paper §3.3): with R̃ = ∅ the NOMAD loss *is* InfoNC-t-SNE.
* **Theorem 1** (paper §7): the mean-approximated loss upper-bounds the
  InfoNC-t-SNE loss — the Jensen step exactly, the Taylor step approximately
  (checked with tolerance on clustered data, and exactly in the tight-cluster
  limit where the Taylor remainder vanishes).

Property tests use hypothesis over positions/weights/partitions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import losses
from repro.core.cauchy import cauchy, cauchy_pairwise
from repro.core.rank_model import edge_weights, normalizer, rank_matrix


# ---------------------------------------------------------------------------
# Cauchy kernel properties
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_cauchy_range_symmetry_identity(seed, d):
    k1, k2 = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(k1, (7, d)) * 10
    b = jax.random.normal(k2, (7, d)) * 10
    q = cauchy(a, b)
    assert np.all(np.asarray(q) > 0) and np.all(np.asarray(q) <= 1.0)
    np.testing.assert_allclose(np.asarray(cauchy(b, a)), np.asarray(q), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cauchy(a, a)), 1.0, rtol=1e-6)
    # pairwise form agrees with broadcast form
    qp = cauchy_pairwise(a, b)
    np.testing.assert_allclose(
        np.asarray(qp), np.asarray(cauchy(a[:, None, :], b[None, :, :])), rtol=1e-4, atol=1e-6
    )


# ---------------------------------------------------------------------------
# Inverse-rank model (Eq. 6)
# ---------------------------------------------------------------------------


def test_rank_matrix_definition():
    x = jnp.asarray([[0.0], [1.0], [3.0], [3.5]])
    d2 = jnp.square(x - x.T)
    R = np.asarray(rank_matrix(d2))
    # rank of i w.r.t. column j; diagonal is 0 (j itself)
    assert (np.diag(R) == 0).all()
    # w.r.t. point 0 (x=0): order is [0, 1, 3, 3.5] → ranks 0,1,2,3
    np.testing.assert_array_equal(R[:, 0], [0, 1, 2, 3])
    # w.r.t. point 2 (x=3): nearest is 3.5 (rank1), then 1 (rank2), then 0
    np.testing.assert_array_equal(R[:, 2], [3, 2, 0, 1])


@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(8, 24))
@settings(max_examples=20, deadline=None)
def test_edge_weights_properties(seed, k, c):
    if k >= c:
        k = c - 1
    x = jax.random.normal(jax.random.key(seed), (c, 3))
    d2 = jnp.sum(jnp.square(x[:, None] - x[None, :]), -1)
    big = jnp.eye(c) * 1e30
    _, knn = jax.lax.top_k(-(d2 + big), k)
    valid = jnp.ones((c,), bool)
    w = np.asarray(edge_weights(d2, knn, k, valid))
    assert (w >= 0).all()
    assert (w <= np.exp(1.0) / normalizer(k) + 1e-6).all()
    # weight 0 ⟺ the tail ranks the head beyond k
    R = np.asarray(rank_matrix(d2))
    r_ji = np.take_along_axis(R, np.asarray(knn), axis=1)
    assert ((w > 0) == ((r_ji >= 1) & (r_ji <= k))).all()


def test_normalizer_matches_eq6():
    # Z = Σ_{j=0}^{k} e^{1/(j+1)}, k+1 terms
    k = 15
    want = sum(np.exp(1.0 / (j + 1)) for j in range(k + 1))
    assert abs(normalizer(k) - want) < 1e-9


# ---------------------------------------------------------------------------
# Reduction property: R̃ = ∅ ⇒ Eq. 3 ≡ Eq. 2
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_reduction_to_infonc(seed):
    key = jax.random.key(seed)
    ks = jax.random.split(key, 4)
    B, k, M, d = 6, 4, 8, 2
    ti = jax.random.normal(ks[0], (B, d))
    tp = jax.random.normal(ks[1], (B, k, d))
    pw = jax.random.uniform(ks[2], (B, k))
    tn = jax.random.normal(ks[3], (B, M, d))
    # NOMAD machinery with zero mean-mass and unit-weight exact samples
    l_nomad_form = losses.contrastive_loss(
        ti, tp, pw, jnp.zeros((B,)), tn, jnp.ones((B, M))
    )
    l_infonc = losses.infonc_tsne_loss(ti, tp, pw, tn)
    np.testing.assert_allclose(float(l_nomad_form), float(l_infonc), rtol=1e-6)


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------


def _exact_losses(theta, clusters, heads, tp, pw, n_noise=1):
    """Exact-expectation InfoNC (|M|=1, uniform ξ over all points) vs the
    NOMAD mean-approximated loss on the same configuration."""
    N = theta.shape[0]
    K = int(clusters.max()) + 1
    q_pos = cauchy(theta[heads][:, None, :], tp)  # (B, k)
    q_all = cauchy_pairwise(theta[heads], theta)  # (B, N)
    # Eq. 2, |M| = 1, expectation exact: E_m[log(q_pos + q(im))]
    inner = jnp.log(q_pos[:, :, None] + q_all[:, None, :])  # (B, k, N)
    l2 = -jnp.mean(jnp.sum(pw[:, :, None] * (jnp.log(q_pos)[:, :, None] - inner) / N, axis=(1, 2)))
    # Eq. 3: all cells approximated by their means (R̃ = R)
    means = jnp.stack([theta[clusters == r].mean(0) for r in range(K)])
    p_r = jnp.asarray([(clusters == r).mean() for r in range(K)])
    q_mu = cauchy(theta[heads][:, None, :], means[None, :, :])  # (B, K)
    m_tilde = jnp.sum(p_r[None, :] * q_mu, axis=1)  # |M| = 1
    l3 = -jnp.mean(jnp.sum(pw * (jnp.log(q_pos) - jnp.log(q_pos + m_tilde[:, None])), axis=1))
    return float(l2), float(l3)


def _mk_config(seed, spread):
    rng = np.random.default_rng(seed)
    K, per, d = 4, 12, 2
    centers = rng.normal(0, 5, (K, d))
    pts = (centers[:, None, :] + rng.normal(0, spread, (K, per, d))).reshape(-1, d)
    clusters = np.repeat(np.arange(K), per)
    theta = jnp.asarray(pts, jnp.float32)
    heads = jnp.asarray(rng.integers(0, K * per, 8))
    nbrs = jnp.asarray(rng.integers(0, K * per, (8, 3)))
    tp = theta[nbrs]
    pw = jnp.asarray(rng.uniform(0.1, 1.0, (8, 3)), jnp.float32)
    return theta, jnp.asarray(clusters), heads, tp, pw


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_theorem1_upper_bound_tight_clusters(seed):
    """Tight clusters ⇒ Taylor remainder →0 ⇒ the bound must hold cleanly."""
    theta, clusters, heads, tp, pw = _mk_config(seed, spread=1e-3)
    l2, l3 = _exact_losses(theta, clusters, heads, tp, pw)
    assert l3 >= l2 - 1e-5, (l2, l3)


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 0.8))
@settings(max_examples=25, deadline=None)
def test_theorem1_approx_upper_bound(seed, spread):
    """Moderate spread: '≳' with the second-order Taylor slack (paper §7:
    the approximation is accurate to second order; slack scales with the
    within-cell variance)."""
    theta, clusters, heads, tp, pw = _mk_config(seed, spread)
    l2, l3 = _exact_losses(theta, clusters, heads, tp, pw)
    slack = 0.5 * spread**2 + 1e-5
    assert l3 >= l2 - slack, (l2, l3, spread)


def test_jensen_step_exact():
    """The Jensen inequality step of the proof, exactly (|M| = 1):
    E_m[log(q + q(im))] ≤ log(q + E_m[q(im)])."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        theta = jnp.asarray(rng.normal(0, 3, (50, 2)), jnp.float32)
        i = int(rng.integers(0, 50))
        q = float(rng.uniform(0.01, 1.0))
        q_im = np.asarray(cauchy_pairwise(theta[i : i + 1], theta))[0]
        lhs = np.mean(np.log(q + q_im))
        rhs = np.log(q + np.mean(q_im))
        assert lhs <= rhs + 1e-7


def test_nomad_loss_gradient_structure():
    """Means are stop-gradded: ∂L/∂θ must not flow into the mean positions
    (the paper's design — means refresh only via the epoch all-gather)."""
    B, k, S, K, d = 4, 3, 5, 6, 2
    ks = jax.random.split(jax.random.key(0), 6)
    ti = jax.random.normal(ks[0], (B, d))
    tp = jax.random.normal(ks[1], (B, k, d))
    pw = jax.random.uniform(ks[2], (B, k))
    tn = jax.random.normal(ks[3], (B, S, d))
    means = jax.random.normal(ks[4], (K, d))
    counts = jnp.full((K,), 10.0)
    cells = jax.random.randint(ks[5], (B,), 0, K)

    def f(means):
        return losses.nomad_loss(ti, tp, pw, means, counts, cells, tn, 8, 60)

    g = jax.grad(f)(means)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-9)

    # …but gradients DO flow to heads, positives and exact negatives
    g_i = jax.grad(
        lambda t: losses.nomad_loss(t, tp, pw, means, counts, cells, tn, 8, 60)
    )(ti)
    assert float(jnp.max(jnp.abs(g_i))) > 0

"""Multi-device integration tests — run in subprocesses with
``--xla_force_host_platform_device_count=8`` so the main pytest process
keeps its single CPU device (assignment: only the dry-run gets 512).

Covers: distributed-vs-single-device quality parity, determinism,
hierarchical multi-pod, distributed K-means, pipeline parallelism, and the
checkpoint/restart + elastic-resharding path of the NOMAD launcher.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(mod_args, devices=8, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", *mod_args],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )


@pytest.mark.slow
def test_distributed_selftest():
    r = _run(["repro.launch.selftest"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "SELFTEST PASS" in r.stdout


@pytest.mark.slow
def test_pipeline_selftest():
    r = _run(["repro.launch.selftest_pipeline"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "PIPELINE SELFTEST PASS" in r.stdout


@pytest.mark.slow
def test_train_crash_restart_elastic(tmp_path):
    """Kill the launcher mid-run, restart on FEWER devices from the
    checkpoint, and verify it resumes at the right epoch and finishes."""
    ck = str(tmp_path / "ckpt")
    common = [
        "repro.launch.train",
        "--workload", "nomad_quickstart",
        "--n-points", "4000",
        "--epochs", "6",
        "--checkpoint-dir", ck,
        "--checkpoint-every", "2",
        "--out", str(tmp_path / "emb.npy"),
    ]
    r1 = _run(common + ["--mesh", "2x4", "--fail-at-epoch", "4"])
    assert r1.returncode == 17, r1.stdout[-2000:] + r1.stderr[-2000:]
    assert "CRASH INJECTION" in r1.stdout
    assert "epoch    3" in r1.stdout

    # elastic restart: 8 shards → 4 shards. Async-save durability semantics:
    # a hard crash may lose the single in-flight checkpoint (atomic commit
    # means never a corrupt one), so the resume point is epoch 4 (ckpt 3
    # committed) or epoch 2 (ckpt 3 was still in flight when we _exit'd).
    r2 = _run(common + ["--mesh", "4", "--resume", "--metrics"], devices=4)
    assert r2.returncode == 0, r2.stdout[-2000:] + r2.stderr[-2000:]
    assert "resume: epoch 4" in r2.stdout or "resume: epoch 2" in r2.stdout, r2.stdout
    # fit owns the index now: the resumed run must hit the on-disk cache
    # (fingerprint-checked) rather than rebuild
    assert "index: cache" in r2.stdout, r2.stdout
    emb = np.load(tmp_path / "emb.npy")
    assert emb.shape == (4000, 2) and np.isfinite(emb).all()

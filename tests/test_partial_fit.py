"""Incremental growth (`partial_fit`): the no-op is bit-identical, admission
keeps every index invariant, unaffected cells never move, quality matches a
joint refit, and the versioned-lineage / store-backed / registry paths all
serve the grown map."""

import os

import numpy as np
import pytest

from repro.checkpoint.lineage import VERSIONS_FILE, MapLineage
from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture
from repro.metrics import map_stability, neighborhood_preservation


def make_cfg(n, *, dim=8, clusters=4, ckdir="", epochs=4, refine=2, seed=0, **kw):
    return NomadConfig(
        n_points=n,
        dim=dim,
        n_clusters=clusters,
        n_neighbors=5,
        n_noise=8,
        n_exact_negatives=4,
        batch_size=256,
        n_epochs=epochs,
        partial_refine_epochs=refine,
        strategy="local",
        build_strategy="local",
        seed=seed,
        checkpoint_dir=ckdir,
        **kw,
    )


def separated(n_per, n_modes, dim, scale, seed=0, which=None):
    """Modes 50 units apart — appends aimed at ``which`` stay in its cells."""
    rng = np.random.default_rng(seed)
    centers = np.eye(n_modes, dim, dtype=np.float32) * 50.0
    modes = [which] * n_per if which is not None else list(range(n_modes)) * n_per
    labels = np.asarray(sorted(modes))
    x = centers[labels] + rng.normal(0, scale, (len(labels), dim)).astype(np.float32)
    return x.astype(np.float32)


@pytest.fixture(scope="module")
def grown():
    """One fit → partial_fit pair shared by the invariant tests."""
    x, _ = gaussian_mixture(600, 8, n_components=4, seed=0)
    y, _ = gaussian_mixture(150, 8, n_components=4, seed=1)
    est = NomadProjection(make_cfg(600))
    base = est.fit(x)
    pf = est.partial_fit(y)
    return x, y, base, pf


def test_append_invariants(grown):
    x, y, base, pf = grown
    idx = pf.index
    n = len(x) + len(y)
    assert pf.n_points == idx.n_points == n
    assert pf.embedding.shape[0] == n and np.isfinite(pf.embedding).all()
    # capacity is fixed forever; growth is new cells, never wider ones
    assert idx.capacity == base.index.capacity
    k2 = idx.counts.shape[0]
    assert int(idx.counts.sum()) == n == len(idx.perm)
    assert (idx.counts <= idx.capacity).all()
    # perm injects original ids into distinct live layout slots
    assert len(np.unique(idx.perm)) == n
    assert idx.perm.min() >= 0 and idx.perm.max() < k2 * idx.capacity
    np.testing.assert_array_equal(
        np.asarray(idx.x_rows)[idx.perm], np.vstack([x, y])
    )
    # kNN edges stay inside the grown layout
    assert idx.knn_idx.min() >= 0
    assert idx.knn_idx.max() < k2 * idx.capacity


def test_old_rows_keep_their_neighborhoods(grown):
    x, y, base, pf = grown
    stab = map_stability(base.embedding, pf.embedding[: len(x)], k=10, n_queries=600)
    assert stab > 0.5, stab


def test_noop_partial_fit_bit_identical(tmp_path):
    """Growing by zero rows changes no artifact bit and writes nothing."""
    ckdir = str(tmp_path / "ck")
    x, _ = gaussian_mixture(400, 8, n_components=4, seed=2)
    est = NomadProjection(make_cfg(400, ckdir=ckdir))
    base = est.fit(x)

    def snapshot():
        return sorted(
            os.path.join(r, f)
            for r, _d, fs in os.walk(ckdir)
            for f in fs
        )

    before = snapshot()
    pf = est.partial_fit(np.zeros((0, 8), np.float32))
    assert pf.n_new == 0
    np.testing.assert_array_equal(pf.embedding, base.embedding)
    np.testing.assert_array_equal(
        np.asarray(pf.index.x_rows), np.asarray(base.index.x_rows)
    )
    np.testing.assert_array_equal(pf.index.perm, base.index.perm)
    assert snapshot() == before
    assert not os.path.exists(os.path.join(ckdir, VERSIONS_FILE))


def test_unaffected_cells_bit_identical():
    """An append aimed at one mode must not move rows anywhere else."""
    x = separated(100, 8, 8, 0.5, seed=3)
    y = separated(40, 8, 8, 0.5, seed=4, which=0)
    est = NomadProjection(make_cfg(800, clusters=8, seed=3))
    base = est.fit(x)
    pf = est.partial_fit(y)

    cap = base.index.capacity
    k_old = base.index.counts.shape[0]
    affected = set(np.asarray(pf.affected_cells).tolist())
    unaffected = [c for c in range(k_old) if c not in affected]
    assert unaffected, "append touched every cell — test data not separated"

    old_x, new_x = np.asarray(base.index.x_rows), np.asarray(pf.index.x_rows)
    for c in unaffected:
        lo, hi = c * cap, (c + 1) * cap
        np.testing.assert_array_equal(new_x[lo:hi], old_x[lo:hi])
        np.testing.assert_array_equal(
            pf.index.knn_idx[lo:hi], base.index.knn_idx[lo:hi]
        )
    # original rows living in unaffected cells keep layout slot AND θ exactly
    in_unaff = ~np.isin(base.index.perm // cap, np.asarray(pf.affected_cells))
    ids = np.flatnonzero(in_unaff)
    assert ids.size > 0
    np.testing.assert_array_equal(pf.index.perm[ids], base.index.perm[ids])
    np.testing.assert_array_equal(pf.embedding[ids], base.embedding[ids])


def test_overflow_splits_and_stays_capacity_bounded():
    """Appending a whole mode's worth of rows must split, not overflow."""
    x = separated(80, 4, 8, 0.5, seed=5)
    y = separated(120, 4, 8, 0.5, seed=6, which=1)
    est = NomadProjection(make_cfg(320, clusters=4, seed=5))
    base = est.fit(x)
    pf = est.partial_fit(y)
    assert pf.n_split_cells >= 1
    assert pf.n_new_cells >= 1
    assert pf.index.counts.shape[0] > base.index.counts.shape[0]
    assert (pf.index.counts <= pf.index.capacity).all()
    n = 320 + 120
    assert len(np.unique(pf.index.perm)) == n
    np.testing.assert_array_equal(
        np.asarray(pf.index.x_rows)[pf.index.perm], np.vstack([x, y])
    )


def test_quality_matches_joint_refit():
    """fit(X) + partial_fit(Y) ≈ fit(X ∥ Y) on the old rows (the acceptance
    bar CI gates via benchmarks/partial_fit.py's np_old_score floor)."""
    x, _ = gaussian_mixture(1000, 16, n_components=8, seed=7)
    y, _ = gaussian_mixture(200, 16, n_components=8, seed=8)
    kw = dict(dim=16, clusters=8, epochs=8, refine=3, seed=7)
    est = NomadProjection(make_cfg(1000, **kw))
    est.fit(x)
    pf = est.partial_fit(y)
    joint = NomadProjection(make_cfg(1200, **kw)).fit(np.vstack([x, y]))
    np_partial = neighborhood_preservation(x, pf.embedding[:1000], k=10, n_queries=500)
    np_joint = neighborhood_preservation(x, joint.embedding[:1000], k=10, n_queries=500)
    assert np_partial >= np_joint - 0.05, (np_partial, np_joint)


def test_partial_fit_deterministic():
    x, _ = gaussian_mixture(400, 8, n_components=4, seed=9)
    y, _ = gaussian_mixture(100, 8, n_components=4, seed=10)
    runs = []
    for _ in range(2):
        est = NomadProjection(make_cfg(400, seed=9))
        est.fit(x)
        runs.append(est.partial_fit(y))
    np.testing.assert_array_equal(runs[0].embedding, runs[1].embedding)
    np.testing.assert_array_equal(runs[0].index.perm, runs[1].index.perm)


def test_partial_fit_before_fit_raises(tmp_path):
    est = NomadProjection(make_cfg(100, ckdir=str(tmp_path / "empty")))
    with pytest.raises((RuntimeError, ValueError, FileNotFoundError)):
        est.partial_fit(np.zeros((5, 8), np.float32))


def test_lineage_chain_across_processes(tmp_path):
    """fit → partial_fit → (new estimator from disk) → partial_fit: the
    versions.json chain records parentage and every version dir serves."""
    from repro.serve.frozen import FrozenMap

    ckdir = str(tmp_path / "ck")
    x, _ = gaussian_mixture(400, 8, n_components=4, seed=11)
    y1, _ = gaussian_mixture(100, 8, n_components=4, seed=12)
    y2, _ = gaussian_mixture(80, 8, n_components=4, seed=13)

    est = NomadProjection(make_cfg(400, ckdir=ckdir, seed=11))
    est.fit(x)
    pf1 = est.partial_fit(y1)
    assert pf1.version and pf1.checkpoint_dir

    est2 = NomadProjection.from_checkpoint(ckdir)  # fresh process analogue
    pf2 = est2.partial_fit(y2)
    assert pf2.parent_version == pf1.version
    assert pf2.n_points == 580

    lin = MapLineage(ckdir)
    versions = lin.load()
    assert [v.kind for v in versions] == ["fit", "partial_fit", "partial_fit"]
    assert versions[0].dirname == "."  # the base fit is v0, in the root
    assert versions[1].parent == versions[0].name
    assert versions[2].parent == versions[1].name
    assert len({v.fingerprint for v in versions}) == 3
    assert [v.n_points for v in versions] == [400, 500, 580]
    # every version dir is self-contained: serve any point in history
    for v, n in zip(versions, (400, 500, 580)):
        fz = FrozenMap.from_checkpoint(v.path)
        assert fz.n_points == n
    assert lin.resolve(None).name == versions[2].name


def test_registry_serves_lineage(tmp_path):
    from repro.service.registry import MapRegistry

    ckdir = str(tmp_path / "ck")
    x, _ = gaussian_mixture(300, 8, n_components=4, seed=14)
    y, _ = gaussian_mixture(90, 8, n_components=4, seed=15)
    est = NomadProjection(make_cfg(300, ckdir=ckdir, seed=14))
    est.fit(x)
    pf = est.partial_fit(y)

    reg = MapRegistry()
    try:
        newest = reg.load_lineage(ckdir)
        assert newest.version == pf.version
        assert newest.frozen.n_points == 390
        base = reg.load_lineage(ckdir, map_version="v0", version="base", activate=False)
        assert base.frozen.n_points == 300
        out = newest.server.transform(x[:8], seed=0)
        assert out.embedding.shape == (8, est.cfg.out_dim)
    finally:
        reg.close()


def test_store_backed_rows_patch(tmp_path):
    """A store-backed corpus grows by patching shards, never materializing."""
    from repro.data.store import ShardedStore, write_sharded

    store_dir = str(tmp_path / "corpus")
    ckdir = str(tmp_path / "ck")
    x, _ = gaussian_mixture(400, 8, n_components=4, seed=16)
    y, _ = gaussian_mixture(120, 8, n_components=4, seed=17)
    write_sharded(x, store_dir)

    est = NomadProjection(make_cfg(400, ckdir=ckdir, seed=16, chunk_rows=128))
    est.fit(store_dir)
    pf = est.partial_fit(y)

    assert isinstance(pf.index.x_rows, ShardedStore)
    rows = pf.index.x_rows.materialize()
    np.testing.assert_array_equal(rows[pf.index.perm], np.vstack([x, y]))
    # the version dir owns its grown store — deleting the original corpus
    # must not break serving the new version
    assert pf.checkpoint_dir and os.path.isdir(pf.checkpoint_dir)
    assert os.path.commonpath(
        [os.path.abspath(pf.index.x_rows.path), os.path.abspath(pf.checkpoint_dir)]
    ) == os.path.abspath(pf.checkpoint_dir)


def test_refine_zero_is_place_only():
    x, _ = gaussian_mixture(300, 8, n_components=4, seed=18)
    y, _ = gaussian_mixture(60, 8, n_components=4, seed=19)
    est = NomadProjection(make_cfg(300, seed=18))
    base = est.fit(x)
    pf = est.partial_fit(y, refine_epochs=0)
    assert pf.refine_epochs == 0 and pf.losses == []
    # admission reorders layout slots but never rewrites a row's θ value,
    # so with zero refinement every old coordinate is bit-identical
    np.testing.assert_array_equal(pf.embedding[:300], base.embedding)

"""MoE layer: dispatch-equivalence (einsum ≡ sort), capacity semantics,
shared experts, gradient flow, and routing determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import moe as M


def _setup(name="mixtral-8x7b", cf=8.0, seed=0, B=2, S=16):
    cfg = reduced(ARCHS[name], capacity_factor=cf)
    p = M.init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (B, S, cfg.d_model))
    return cfg, p, x


@pytest.mark.parametrize("name", ["mixtral-8x7b", "llama4-scout-17b-a16e", "jamba-1.5-large-398b"])
def test_sort_equals_einsum_dropfree(name):
    cfg, p, x = _setup(name)
    y1, a1 = M.moe_einsum(p, x, cfg)
    y2, a2 = M.moe_sort(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_capacity_drops_route_to_residual():
    """With capacity 0-ish, (almost) all tokens drop: y ≈ shared-expert-only
    (or ≈ 0 without a shared expert) — the GShard drop semantics."""
    cfg, p, x = _setup("mixtral-8x7b", cf=1e-9)  # capacity floor = 4 slots
    y, _ = M.moe_sort(p, x, cfg)
    cfg8, p8, _ = _setup("mixtral-8x7b", cf=8.0)
    y_full, _ = M.moe_sort(p, x, cfg8)
    # many rows must be exactly zero (dropped, no shared expert in mixtral);
    # the capacity floor (4 slots × E experts) lets some survive
    zero_rows = np.mean(np.all(np.asarray(y) == 0, axis=-1))
    assert zero_rows >= 0.3, zero_rows
    assert not np.allclose(np.asarray(y_full), 0)


def test_shared_expert_always_on():
    """llama4: the shared expert contributes even for dropped tokens."""
    cfg, p, x = _setup("llama4-scout-17b-a16e", cf=1e-9)
    assert p.shared is not None
    y, _ = M.moe_sort(p, x, cfg)
    zero_rows = np.mean(np.all(np.asarray(y) == 0, axis=-1))
    assert zero_rows == 0.0


def test_gradients_flow_to_router_and_experts():
    cfg, p, x = _setup("mixtral-8x7b")

    def loss(p):
        y, aux = M.moe_sort(p, x, cfg)
        return jnp.sum(jnp.square(y)) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.max(jnp.abs(g.router))) > 0, "router got no gradient"
    assert float(jnp.max(jnp.abs(g.w_gate))) > 0
    assert float(jnp.max(jnp.abs(g.w_down))) > 0


def test_aux_loss_prefers_balance():
    """Uniform routing probabilities minimise the Switch aux loss."""
    E, T = 4, 64
    probs_uniform = jnp.full((T, E), 1.0 / E)
    assign_uniform = jnp.tile(jnp.arange(E), T // E)[:, None]
    l_uni = M.load_balance_loss(probs_uniform, assign_uniform, E)
    probs_peaked = jnp.eye(E)[jnp.zeros(T, jnp.int32)]
    assign_peaked = jnp.zeros((T, 1), jnp.int32)
    l_peak = M.load_balance_loss(probs_peaked, assign_peaked, E)
    assert float(l_uni) < float(l_peak)
    np.testing.assert_allclose(float(l_uni), 1.0, rtol=1e-5)  # E·Σ(1/E·1/E)


def test_expert_capacity_formula():
    assert M.expert_capacity(1024, 8, 2, 1.25) == 320
    assert M.expert_capacity(8, 8, 1, 1.0) >= 4  # floor

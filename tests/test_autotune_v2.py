"""Autotuner v2: shape buckets, source-hash invalidation, cache robustness.

The property tests use ``hypothesis`` when it is installed (it is in the
``[test]`` extra, so CI runs them); without it, ``conftest.py``'s stub
turns each ``@given`` test into a clean skip.

The robustness block is the "hostile filesystem" contract: corrupt,
truncated or legacy-v1 cache files, winners recorded by an older kernel
source, and two processes racing on the store must all degrade to a
fresh sweep (or the defaults) — never a crash, never stale tiles.
"""

from __future__ import annotations

import json
import threading

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import autotune, registry


@pytest.fixture
def tune_env(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    autotune.clear_memory_cache()
    yield path
    autotune.clear_memory_cache()


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------


def test_bucket_examples():
    assert autotune.bucket_dim(64) == 64
    assert autotune.bucket_dim(128) == 128
    assert autotune.bucket_dim(129) == 256
    # the motivating case: N = 49k and N = 50k share one sweep
    assert autotune.bucket_dim(49_000) == autotune.bucket_dim(50_000) == 65_536


@given(n=st.integers(min_value=1, max_value=10_000_000))
@settings(max_examples=200, deadline=None)
def test_bucket_dim_is_idempotent_and_covers(n):
    b = autotune.bucket_dim(n)
    assert b >= n  # a sweep at the bucket shape covers the real shape
    assert autotune.bucket_dim(b) == b  # idempotent: buckets are fixpoints
    if n <= 128:
        assert b == n  # small dims key exactly (tile regimes differ there)
    else:
        assert b & (b - 1) == 0  # power of two
        assert b < 2 * n  # never over-pads by more than 2×


@given(n=st.integers(min_value=129, max_value=10_000_000))
@settings(max_examples=100, deadline=None)
def test_same_bucket_means_same_cache_key(n):
    b = autotune.bucket_dim(n)
    lo = max(b // 2 + 1, 129)  # smallest large-dim member of n's bucket
    key_n = autotune.cache_key("k", "cpu", (((n, 64), "float32"),))
    key_lo = autotune.cache_key("k", "cpu", (((lo, 64), "float32"),))
    assert key_n == key_lo


@given(
    entries=st.lists(
        st.tuples(
            st.lists(st.integers(min_value=1, max_value=100_000), min_size=1, max_size=3),
            st.sampled_from(["float32", "bfloat16", "int32"]),
        ),
        min_size=1,
        max_size=4,
    )
)
@settings(max_examples=100, deadline=None)
def test_cache_key_stable_under_bucketing(entries):
    """cache_key(sig) == cache_key(bucket_sig(sig)): the key is a pure
    function of the bucket, so every shape in a bucket shares an entry."""
    sig = tuple((tuple(shape), dt) for shape, dt in entries)
    assert autotune.cache_key("k", "cpu", sig) == autotune.cache_key(
        "k", "cpu", autotune.bucket_sig(sig)
    )
    # and it is deterministic across calls (no dict/set ordering leaks)
    assert autotune.cache_key("k", "cpu", sig) == autotune.cache_key("k", "cpu", sig)


def test_shapes_in_one_bucket_share_a_recorded_winner(tune_env, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")  # no sweeps: cache hits only
    spec = registry.get("pairwise")
    sig_a = (((49_000, 64), "float32"), ((256, 64), "float32"))
    sig_b = (((50_000, 64), "float32"), ((256, 64), "float32"))
    planted = {"tiles": {"block_n": 128, "block_m": 128, "block_d": 256}, "us": 5.0}
    autotune.record(spec, sig_a, planted)
    assert autotune.tiles_for(spec, sig_b) == planted["tiles"]
    # a fresh process (cleared memory) reloads the same winner from disk
    autotune.clear_memory_cache()
    assert autotune.tiles_for(spec, sig_b) == planted["tiles"]


# ---------------------------------------------------------------------------
# Source-hash invalidation
# ---------------------------------------------------------------------------


def _plant(path, key, tiles, src):
    blob = {
        "version": autotune.CACHE_VERSION,
        "entries": {key: {"tiles": tiles, "us": 1.0, "src": src}},
    }
    path.write_text(json.dumps(blob))


def test_matching_source_hash_serves_cached_tiles(tune_env, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    spec = registry.get("pairwise")
    sig = spec.check_shapes[0]
    key = autotune.cache_key(spec.name, registry.backend(), sig)
    planted = {"block_n": 128, "block_m": 128, "block_d": 256}
    _plant(tune_env, key, planted, autotune.source_hash(spec))
    autotune.clear_memory_cache()
    assert autotune.tiles_for(spec, sig) == planted


def test_stale_source_hash_is_ignored(tune_env, monkeypatch):
    """A winner timed against an older kernel source must not be served."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    spec = registry.get("pairwise")
    sig = spec.check_shapes[0]
    key = autotune.cache_key(spec.name, registry.backend(), sig)
    _plant(tune_env, key, {"block_n": 1, "block_m": 1, "block_d": 1}, "0000deadbeef0000")
    autotune.clear_memory_cache()
    assert autotune.tiles_for(spec, sig) == dict(
        spec.tiles_for_backend(registry.backend())
    )


def test_unknown_kernel_entries_are_skipped(tune_env, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    _plant(tune_env, "no_such_kernel|cpu|()", {"bb": 1}, "whatever")
    autotune.clear_memory_cache()
    spec = registry.get("pairwise")
    autotune.tiles_for(spec, spec.check_shapes[0])  # must not raise
    assert "no_such_kernel|cpu|()" not in autotune._memory_cache


# ---------------------------------------------------------------------------
# Hostile-filesystem robustness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "content",
    [
        "{definitely not json",  # corrupt
        '{"version": 2, "entries": {"k": {"til',  # truncated mid-write
        '{"pairwise|cpu|()": {"tiles": {"block_n": 1}}}',  # legacy v1 flat dict
        '{"version": 99, "entries": {}}',  # future version
        '[1, 2, 3]',  # wrong toplevel type
    ],
)
def test_unusable_cache_file_degrades_to_fresh_sweep(tune_env, content):
    tune_env.write_text(content)
    spec = registry.get("pairwise")
    sig = spec.check_shapes[0]
    tiles = autotune.tiles_for(spec, sig)  # sweeps: REPRO_AUTOTUNE=1
    assert tiles in [dict(t) for t in spec.tile_candidates]
    # ...and the rewritten file is a valid v2 envelope with the new winner
    blob = json.loads(tune_env.read_text())
    assert blob["version"] == autotune.CACHE_VERSION
    key = autotune.cache_key(spec.name, registry.backend(), sig)
    assert blob["entries"][key]["tiles"] == dict(tiles)


def test_concurrent_stores_leave_a_valid_cache(tune_env):
    """Two processes racing on _store_disk: atomic replace means the last
    writer wins wholesale — the file is never interleaved garbage."""
    threads = [
        threading.Thread(
            target=autotune._store_disk,
            args=(f"k{i}|cpu|()", {"tiles": {"bb": i}, "us": 1.0, "src": "x"}),
        )
        for i in range(16)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    blob = json.loads(tune_env.read_text())
    assert blob["version"] == autotune.CACHE_VERSION
    assert blob["entries"]  # at least the last writer's entry survived
    for entry in blob["entries"].values():
        assert "tiles" in entry


# ---------------------------------------------------------------------------
# sweep --report
# ---------------------------------------------------------------------------


def test_sweep_report_lists_candidates_and_disk_strips_them(tune_env):
    spec = registry.get("pairwise")
    sig = spec.check_shapes[0]
    entry = autotune.sweep(spec, sig, interpret=True, report=True)
    assert entry["src"] == autotune.source_hash(spec)
    assert len(entry["candidates"]) == entry["n_candidates"]
    for cand in entry["candidates"]:
        assert cand["us"] > 0 and cand["tiles"] in [dict(t) for t in spec.tile_candidates]
    assert min(c["us"] for c in entry["candidates"]) == entry["us"]
    autotune.record(spec, sig, entry)
    blob = json.loads(tune_env.read_text())
    key = autotune.cache_key(spec.name, registry.backend(), sig)
    assert "candidates" not in blob["entries"][key]  # winner only on disk

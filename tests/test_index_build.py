"""Device-resident index-build subsystem (repro.index.build).

Covers the PR-3 acceptance criteria: device-vs-host capacity-assignment
equivalence, capacity edge cases (zero slack, stragglers), sharded-vs-local
bit-equality on a 1-device mesh, build-strategy resolution, FitResult build
provenance, and the index-cache content fingerprint.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import NomadConfig
from repro.data.synthetic import gaussian_mixture
from repro.index.ann import (
    _np_dist2,
    build_index,
    data_fingerprint,
    load_index,
    save_index,
)
from repro.index.build import (
    BuildReport,
    IndexBuilder,
    capacity_assign_device,
    resolve_build_strategy,
)
from repro.index.kmeans import capacity_assign, kmeans_fit
from repro.index.knn import batched_cluster_knn, cluster_knn

CFG = NomadConfig(n_points=1500, dim=12, n_clusters=6, n_neighbors=5)


@pytest.fixture(scope="module")
def data():
    x, _ = gaussian_mixture(1500, 12, n_components=6, seed=5)
    return x


# ---------------------------------------------------------------------------
# Capacity-bounded assignment: device vs host, edge cases
# ---------------------------------------------------------------------------


def test_device_assign_matches_host_reference_fixed_seed():
    rng = np.random.default_rng(7)
    x = rng.normal(0, 1, (400, 8)).astype(np.float32)
    cents = rng.normal(0, 1, (7, 8)).astype(np.float32)
    cap = int(np.ceil(1.2 * 400 / 7))
    a_host = capacity_assign(_np_dist2, x, cents, cap)
    a_dev = capacity_assign_device(x, cents, cap, impl="jnp")
    # same round semantics; fp tie-breaks may differ between numpy and XLA
    assert float(np.mean(a_host == a_dev)) >= 0.99
    counts = np.bincount(a_dev, minlength=7)
    assert (counts <= cap).all() and counts.sum() == 400


def test_device_assign_zero_slack_exact_fill():
    """K·C == N: no slack at all — every cluster must fill exactly."""
    rng = np.random.default_rng(3)
    n, K = 96, 8
    cap = n // K  # 12, zero slack
    x = rng.normal(0, 1, (n, 4)).astype(np.float32)
    cents = rng.normal(0, 1, (K, 4)).astype(np.float32)
    a = capacity_assign_device(x, cents, cap, impl="jnp")
    counts = np.bincount(a, minlength=K)
    np.testing.assert_array_equal(counts, np.full(K, cap))


def test_device_assign_straggler_force_placement():
    """One centroid attracts everything: after max_rounds=1 the rejects are
    force-placed — all assigned, capacity never violated, and the round's
    admissions are the closest bidders."""
    rng = np.random.default_rng(0)
    n, K, cap = 50, 5, 13
    x = rng.normal(0, 0.1, (n, 3)).astype(np.float32)
    cents = np.full((K, 3), 50.0, np.float32)
    cents[0] = 0.0  # everyone's nearest
    a = capacity_assign_device(x, cents, cap, impl="jnp", max_rounds=1)
    counts = np.bincount(a, minlength=K)
    assert (a >= 0).all() and (counts <= cap).all() and counts.sum() == n
    # the 13 admitted to centroid 0 are the 13 closest points to it
    d0 = np.sum((x - cents[0]) ** 2, -1)
    want = set(np.argsort(d0)[:cap].tolist())
    assert set(np.flatnonzero(a == 0).tolist()) == want


def test_device_assign_prefers_nearest_when_room():
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (60, 3)).astype(np.float32)
    cents = rng.normal(0, 1, (10, 3)).astype(np.float32)
    a = capacity_assign_device(x, cents, capacity=60, impl="jnp")
    np.testing.assert_array_equal(a, _np_dist2(x, cents).argmin(1))


# ---------------------------------------------------------------------------
# Builder: resolution, local build, sharded ≡ local on a 1-device mesh
# ---------------------------------------------------------------------------


def test_resolve_build_strategy():
    name, mesh = resolve_build_strategy("local", CFG)
    assert name == "local" and mesh is None
    # the in-process test runner has one device → auto resolves local
    assert resolve_build_strategy("auto", CFG)[0] == "local"
    name, mesh = resolve_build_strategy("sharded", CFG)
    assert name == "sharded" and mesh.shape == {"build": 1}
    with pytest.raises(ValueError, match="build_strategy"):
        resolve_build_strategy("pmap", CFG)
    with pytest.raises(ValueError, match="build_strategy"):
        NomadConfig(build_strategy="pmap")


def test_local_build_report_stages(data):
    b = IndexBuilder(CFG, impl="jnp")
    idx = b.build(data)
    assert isinstance(b.report, BuildReport)
    assert b.report.strategy == "local" and b.report.n_shards == 1
    assert set(b.report.stage_s) == {"kmeans", "assign", "permute", "knn"}
    assert all(t >= 0 for t in b.report.stage_s.values())
    assert b.report.total_s >= sum(b.report.stage_s.values()) * 0.5
    assert idx.fingerprint == data_fingerprint(data)


def test_sharded_build_matches_local_bitwise_on_one_device_mesh(data):
    loc = IndexBuilder(CFG, strategy="local", impl="jnp").build(data)
    b = IndexBuilder(CFG, strategy="sharded", impl="jnp")
    sh = b.build(data)
    assert b.report.strategy == "sharded" and b.report.n_shards == 1
    for f in ("x_rows", "knn_idx", "knn_w", "counts", "centroids", "perm"):
        np.testing.assert_array_equal(
            getattr(loc, f), getattr(sh, f), err_msg=f
        )


def test_build_index_front_door_strategy_override(data):
    idx = build_index(data, CFG, impl="jnp", strategy="sharded")
    assert idx.n_points == 1500
    # perm is a bijection onto valid rows of the (K·C) cluster-major space
    assert len(set(idx.perm.tolist())) == 1500
    assert idx.valid_mask[idx.perm].all()


# ---------------------------------------------------------------------------
# kmeans_fit scan: returned assignment always matches returned centroids
# ---------------------------------------------------------------------------


def test_kmeans_fit_consistent_converged_and_not(data):
    x = jnp.asarray(data)
    for tol in (1e2, 0.0):  # converges in 1-2 iters / never converges
        cents, assign, counts = kmeans_fit(
            jax.random.key(0), x, 6, n_iters=5, tol=tol, impl="jnp"
        )
        d2 = _np_dist2(data, np.asarray(cents))
        np.testing.assert_array_equal(np.asarray(assign), d2.argmin(1))
        assert int(np.asarray(counts).sum()) == 1500


# ---------------------------------------------------------------------------
# fit provenance + index-cache fingerprint
# ---------------------------------------------------------------------------

FIT_CFG = NomadConfig(
    n_points=600,
    dim=8,
    n_clusters=4,
    n_neighbors=5,
    n_noise=8,
    n_exact_negatives=4,
    batch_size=128,
    n_epochs=1,
)


@pytest.fixture(scope="module")
def fit_data():
    x, _ = gaussian_mixture(600, 8, n_components=4, seed=2)
    return x


def test_fit_records_build_provenance(fit_data, tmp_path):
    from repro.core.nomad import NomadProjection

    cfg = FIT_CFG.replace(checkpoint_dir=str(tmp_path))
    res = NomadProjection(cfg).fit(fit_data)
    assert res.index_build_strategy == "local" and res.index_build_s > 0
    # second fit hits the on-disk cache
    res2 = NomadProjection(cfg).fit(fit_data, resume=False)
    assert res2.index_build_strategy == "cache" and res2.index_build_s == 0.0
    # an explicit index argument is recorded as provided
    res3 = NomadProjection(FIT_CFG).fit(fit_data, index=res.index)
    assert res3.index_build_strategy == "provided"


def test_index_cache_fingerprint_rejects_same_shape_different_data(
    fit_data, tmp_path
):
    from repro.core.nomad import NomadProjection

    cfg = FIT_CFG.replace(checkpoint_dir=str(tmp_path))
    NomadProjection(cfg).fit(fit_data)
    x2, _ = gaussian_mixture(600, 8, n_components=4, seed=99)  # same shape!
    with pytest.warns(UserWarning, match="fingerprint"):
        res = NomadProjection(cfg).fit(x2, resume=False)
    assert res.index_build_strategy == "local"  # rebuilt, not reused
    assert res.index.fingerprint == data_fingerprint(x2)


def test_save_load_roundtrips_fingerprint(data, tmp_path):
    idx = IndexBuilder(CFG, impl="jnp").build(data)
    path = str(tmp_path / "index.npz")
    save_index(idx, path)
    loaded = load_index(path)
    assert loaded.fingerprint == idx.fingerprint != ""
    # pre-fingerprint caches (no field in the npz) load as never-stale ""
    np.savez(
        str(tmp_path / "old.npz"),
        **{
            k: getattr(idx, k)
            for k in (
                "x_rows", "knn_idx", "knn_w", "counts", "centroids", "perm",
                "capacity", "n_points",
            )
        },
    )
    old = load_index(str(tmp_path / "old.npz"))
    assert old.fingerprint == ""


# ---------------------------------------------------------------------------
# Out-of-core: the streamed build ≡ the in-memory build, bit for bit
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def data_store(data, tmp_path_factory):
    from repro.data.store import write_sharded

    d = tmp_path_factory.mktemp("store")
    # shard size deliberately not a multiple of chunk_rows (ragged reads)
    return write_sharded(data, str(d / "corpus"), rows_per_shard=400)


@pytest.mark.parametrize("build_strategy", ["local", "sharded", "auto"])
def test_streamed_build_store_equals_ndarray_bitwise(
    data, data_store, build_strategy
):
    """build(store) ≡ build(ndarray) for every build_strategy: a store
    input (or an explicit chunk_rows) selects the streamed pipeline, whose
    chunk schedule depends only on (N, chunk_rows) — never the container
    or its shard layout."""
    cfg = CFG.replace(chunk_rows=512, build_strategy=build_strategy)
    ba = IndexBuilder(cfg, impl="jnp")
    a = ba.build(data)
    bb = IndexBuilder(cfg, impl="jnp")
    b = bb.build(data_store)
    assert ba.report.strategy == bb.report.strategy == "streamed"
    for f in ("knn_idx", "knn_w", "counts", "centroids", "perm"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    np.testing.assert_array_equal(np.asarray(a.x_rows), np.asarray(b.x_rows))
    assert a.fingerprint == b.fingerprint != ""


def test_streamed_build_spills_x_rows_to_disk(data_store):
    """A disk-backed input produces a disk-backed cluster-major x_rows —
    the O(N·D) permuted buffer never lands in host RAM."""
    from repro.data.store import ShardedStore, is_store

    idx = IndexBuilder(CFG.replace(chunk_rows=512), impl="jnp").build(data_store)
    assert is_store(idx.x_rows) and isinstance(idx.x_rows, ShardedStore)
    # the spill agrees with the in-memory scatter of the same permutation
    ref = IndexBuilder(CFG, impl="jnp").build(np.asarray(data_store))
    rows = np.zeros_like(ref.x_rows)
    rows[idx.perm] = np.asarray(data_store)
    np.testing.assert_array_equal(np.asarray(idx.x_rows), rows)


def test_streamed_build_chunk_invariance(data):
    """chunk_rows changes the accumulation order (different centroids are
    legitimate) but every chunk size must produce a valid index."""
    for chunk in (257, 1500):
        idx = IndexBuilder(CFG.replace(chunk_rows=chunk), impl="jnp").build(data)
        assert len(set(idx.perm.tolist())) == 1500
        assert idx.valid_mask[idx.perm].all()
        counts = np.bincount(idx.perm // idx.capacity, minlength=idx.n_clusters)
        assert (counts <= idx.capacity).all() and counts.sum() == 1500


def test_streamed_build_bf16_spill(data, data_store):
    """store_dtype='bfloat16' halves the x_rows spill on disk; reads upcast
    to f32, so the index stays valid and x_rows is bf16-close to the f32
    scatter. Only the stored mantissa is cut — kNN (computed from the f32
    upcast) remains a legal neighbor graph."""
    cfg = CFG.replace(chunk_rows=512, store_dtype="bfloat16")
    idx = IndexBuilder(cfg, impl="jnp").build(data_store)
    assert idx.x_rows.dtype_name == "bfloat16"
    rows = np.zeros((idx.n_clusters * idx.capacity, data.shape[1]), np.float32)
    rows[idx.perm] = data
    got = np.asarray(idx.x_rows)
    np.testing.assert_allclose(got, rows, rtol=2**-7, atol=2**-7)
    assert got.dtype == np.float32
    with pytest.raises(ValueError, match="store_dtype"):
        NomadConfig(store_dtype="int8")


def test_store_backed_index_save_load_roundtrip(data_store, tmp_path):
    """A store-backed x_rows is spilled to a .npy sidecar beside the npz
    cache and loads back as a memmap store — bit-equal, no O(N·D) RAM."""
    from repro.data.store import MemmapStore, is_store

    idx = IndexBuilder(CFG.replace(chunk_rows=512), impl="jnp").build(data_store)
    path = str(tmp_path / "index.npz")
    save_index(idx, path)
    assert os.path.exists(path + ".x_rows.npy")
    loaded = load_index(path)
    assert is_store(loaded.x_rows) and isinstance(loaded.x_rows, MemmapStore)
    np.testing.assert_array_equal(
        np.asarray(loaded.x_rows), np.asarray(idx.x_rows)
    )
    assert loaded.fingerprint == idx.fingerprint


# ---------------------------------------------------------------------------
# use_pallas= deprecation shims
# ---------------------------------------------------------------------------


def test_use_pallas_deprecated_on_index_entry_points(data):
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.normal(0, 1, (16, 4)), jnp.float32)
    valid = jnp.ones((16,), bool)
    with pytest.warns(DeprecationWarning, match="build_index"):
        build_index(data, CFG, use_pallas=False)
    with pytest.warns(DeprecationWarning, match="kmeans_fit"):
        kmeans_fit(jax.random.key(0), jnp.asarray(data), 6, n_iters=2, use_pallas=False)
    with pytest.warns(DeprecationWarning, match="cluster_knn"):
        cluster_knn(xb, valid, 3, use_pallas=False)
    with pytest.warns(DeprecationWarning, match="batched_cluster_knn"):
        batched_cluster_knn(xb[None], valid[None], 3, use_pallas=False)

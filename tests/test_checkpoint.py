"""Checkpointing: atomic commit, roundtrip, elastic resharding, pruning,
async writer."""

import os

import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "theta": rng.normal(0, 1, (64, 2)).astype(np.float32),
        "opt": {"count": np.asarray(7, np.int32), "vel": rng.normal(0, 1, (64, 2)).astype(np.float32)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), n_shards=4)
    t = _tree()
    ck.save(3, t, sharded_keys=("theta", "opt/vel"), metadata={"epoch": 3})
    got, meta = ck.restore(t)
    assert meta["epoch"] == 3
    np.testing.assert_array_equal(got["theta"], t["theta"])
    np.testing.assert_array_equal(got["opt"]["vel"], t["opt"]["vel"])
    assert got["opt"]["count"] == 7
    assert latest_step(str(tmp_path)) == 3


def test_elastic_reshard(tmp_path):
    """Written from 8 shards, restored for 2 — the elastic-scaling path."""
    ck8 = Checkpointer(str(tmp_path), n_shards=8)
    t = _tree(1)
    ck8.save(0, t, sharded_keys=("theta",))
    ck2 = Checkpointer(str(tmp_path), n_shards=2)
    got, _ = ck2.restore(t)
    np.testing.assert_array_equal(got["theta"], t["theta"])  # global view identical


def test_atomic_no_tmp_left_and_pruning(tmp_path):
    ck = Checkpointer(str(tmp_path), n_shards=2, keep=2)
    t = _tree(2)
    for step in range(5):
        ck.save(step, t, sharded_keys=("theta",))
    names = sorted(os.listdir(tmp_path))
    assert not any(n.endswith(".tmp") for n in names)
    steps = [n for n in names if n.startswith("step_")]
    assert len(steps) == 2 and steps[-1].endswith("4".zfill(9))


def test_async_save_joins(tmp_path):
    ck = Checkpointer(str(tmp_path), n_shards=2, async_save=True)
    t = _tree(3)
    ck.save(0, t, sharded_keys=("theta",))
    ck.save(1, t, sharded_keys=("theta",))  # implicitly joins save 0
    ck.wait()
    assert latest_step(str(tmp_path)) == 1
    got, _ = ck.restore(t)
    np.testing.assert_array_equal(got["theta"], t["theta"])


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore({"a": np.zeros(3)})

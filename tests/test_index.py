"""ANN-index substrate tests: K-means invariants, capacity assignment,
in-cluster kNN exactness, cluster-component property (paper §3.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import NomadConfig
from repro.data.synthetic import gaussian_mixture
from repro.index.ann import build_index, _np_dist2
from repro.index.kmeans import assign_jnp, capacity_assign, kmeans_fit, lsh_init_centroids
from repro.index.knn import cluster_knn


def test_kmeans_objective_nonincreasing():
    x, _ = gaussian_mixture(2000, 16, n_components=6, seed=1)
    x = jnp.asarray(x)
    cents = lsh_init_centroids(jax.random.key(0), x, 6)
    prev = np.inf
    for _ in range(8):
        a, d2 = assign_jnp(x, cents)
        obj = float(jnp.sum(d2))
        assert obj <= prev + 1e-3 * abs(prev), "EM objective increased"
        prev = obj
        sums = jnp.zeros((6, 16)).at[a].add(x)
        cnt = jnp.zeros((6,)).at[a].add(1.0)
        cents = jnp.where((cnt > 0)[:, None], sums / jnp.maximum(cnt, 1)[:, None], cents)


def test_kmeans_assignment_is_nearest():
    x, _ = gaussian_mixture(500, 8, seed=2)
    cents, assign, counts = kmeans_fit(jax.random.key(1), jnp.asarray(x), 5, n_iters=10)
    d2 = _np_dist2(x, np.asarray(cents))
    np.testing.assert_array_equal(np.asarray(assign), d2.argmin(1))
    assert int(counts.sum()) == 500


@given(st.integers(0, 2**31 - 1), st.integers(2, 10))
@settings(max_examples=15, deadline=None)
def test_capacity_assign_invariants(seed, K):
    rng = np.random.default_rng(seed)
    n = 200
    x = rng.normal(0, 1, (n, 4)).astype(np.float32)
    cents = rng.normal(0, 1, (K, 4)).astype(np.float32)
    cap = int(np.ceil(1.3 * n / K))
    a = capacity_assign(_np_dist2, x, cents, cap)
    assert (a >= 0).all() and (a < K).all()
    counts = np.bincount(a, minlength=K)
    assert (counts <= cap).all(), "capacity violated"


def test_capacity_assign_prefers_nearest_when_room():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (50, 3)).astype(np.float32)
    cents = rng.normal(0, 1, (10, 3)).astype(np.float32)
    a = capacity_assign(_np_dist2, x, cents, capacity=50)  # no pressure
    np.testing.assert_array_equal(a, _np_dist2(x, cents).argmin(1))


def test_cluster_knn_exactness():
    rng = np.random.default_rng(3)
    C, D, k = 40, 8, 5
    xb = jnp.asarray(rng.normal(0, 1, (C, D)), jnp.float32)
    valid = jnp.ones((C,), bool)
    knn, w = cluster_knn(xb, valid, k)
    d2 = np.array(jnp.sum(jnp.square(xb[:, None] - xb[None, :]), -1))  # writable copy
    np.fill_diagonal(d2, np.inf)
    want = np.argsort(d2, axis=1)[:, :k]
    got_d = np.take_along_axis(d2, np.asarray(knn), 1)
    want_d = np.take_along_axis(d2, want, 1)
    np.testing.assert_allclose(np.sort(got_d, 1), np.sort(want_d, 1), rtol=1e-4)


def test_cluster_knn_respects_padding():
    rng = np.random.default_rng(4)
    C, D, k, real = 32, 4, 4, 20
    xb = jnp.asarray(rng.normal(0, 1, (C, D)), jnp.float32)
    valid = jnp.arange(C) < real
    knn, w = cluster_knn(xb, valid, k)
    w = np.asarray(w)
    knn = np.asarray(knn)
    # padded heads carry no edges; no edge points at a padded tail
    assert (w[real:] == 0).all()
    assert (knn[:real][w[:real] > 0] < real).all()


def test_build_index_layout_and_component_property():
    cfg = NomadConfig(n_points=1500, dim=12, n_clusters=6, n_neighbors=5)
    x, _ = gaussian_mixture(1500, 12, n_components=6, seed=5)
    idx = build_index(x, cfg, impl="jnp")
    K, C = idx.n_clusters, idx.capacity
    # permutation is a bijection onto valid rows
    assert idx.perm.shape == (1500,)
    assert len(set(idx.perm.tolist())) == 1500
    valid = idx.valid_mask
    assert valid[idx.perm].all()
    assert int(valid.sum()) == 1500
    # x_rows really is the permuted input
    np.testing.assert_allclose(idx.x_rows[idx.perm], x, rtol=1e-6)
    # paper §3.2: every kNN edge stays inside its cluster block (component)
    cluster_of = np.arange(K * C) // C
    live = idx.knn_w > 0  # (K·C, k)
    head_cluster = np.broadcast_to(cluster_of[:, None], idx.knn_idx.shape)
    tail_cluster = cluster_of[idx.knn_idx]
    assert (head_cluster[live] == tail_cluster[live]).all()
    # counts consistent
    np.testing.assert_array_equal(
        idx.counts, valid.reshape(K, C).sum(1)
    )

"""Per-architecture smoke tests (assignment requirement f).

Each assigned architecture is instantiated at a REDUCED config of the same
family and runs one forward + one train step on CPU, asserting output shapes
and the absence of NaNs. Decode-capable archs also run a prefill→decode
consistency check.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, reduced
from repro.models import lm, steps
from repro.optim import AdamW, constant

ARCH_NAMES = sorted(ARCHS)


def small_batch(cfg, B=2, S=64, key=None):
    key = key or jax.random.key(1)
    kt, kl, kp = jax.random.split(key, 3)
    batch = {}
    if cfg.family == "audio":
        batch["embeds"] = jax.random.normal(kt, (B, S, cfg.d_model), jnp.float32)
    elif cfg.family == "vlm":
        P = cfg.n_vision_patches
        batch["tokens"] = jax.random.randint(kt, (B, S - P), 0, cfg.vocab_size)
        batch["patches"] = jax.random.normal(kp, (B, P, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    lab_len = S - cfg.n_vision_patches if cfg.family == "vlm" else S
    batch["labels"] = jax.random.randint(kl, (B, lab_len), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = reduced(ARCHS[name])
    params = lm.init_params(jax.random.key(0), cfg)
    batch = small_batch(cfg)
    logits, aux, _ = lm.forward(
        params,
        cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        patches=batch.get("patches"),
    )
    B = 2
    S = 64
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all(), "NaN/Inf in logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_decreases_loss(name):
    cfg = reduced(ARCHS[name])
    params = lm.init_params(jax.random.key(0), cfg)
    opt = AdamW(schedule=constant(3e-3), moment_dtype="float32", weight_decay=0.0)
    opt_state = opt.init(params)
    step = jax.jit(steps.make_train_step(cfg, opt))
    batch = small_batch(cfg)
    losses = []
    for _ in range(4):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"


@pytest.mark.parametrize(
    "name", [n for n in ARCH_NAMES if not ARCHS[n].encoder_only]
)
def test_prefill_decode_consistency(name):
    """decode(token_t | cache from prefill(x_<t)) ≡ forward(x_<=t) logits."""
    cfg = reduced(ARCHS[name])
    B, S = 2, 32
    params = lm.init_params(jax.random.key(0), cfg)
    batch = small_batch(cfg, B=B, S=S)
    # full-sequence logits (oracle)
    logits_full, _, _ = lm.forward(
        params, cfg, tokens=batch.get("tokens"), patches=batch.get("patches")
    )
    # prefill on the first S-1 positions, then decode position S-1
    if cfg.family == "vlm":
        toks = batch["tokens"]
        pre_batch = {"tokens": toks[:, :-1], "patches": batch["patches"]}
        last_tok = toks[:, -1:]
    else:
        toks = batch["tokens"]
        pre_batch = {"tokens": toks[:, :-1]}
        last_tok = toks[:, -1:]
    prefill = steps.make_prefill_step(cfg)
    _, caches = prefill(params, pre_batch)
    cache = lm.init_cache(cfg, B, S, filled=S - 1)
    cache = lm.load_cache_from_prefill(cfg, cache, caches, S - 1)
    decode = steps.make_decode_step(cfg)
    logits_dec, new_cache = decode(params, cache, last_tok)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]),
        np.asarray(logits_full[:, -1]),
        rtol=2e-3,
        atol=2e-3,
    )
    assert int(new_cache["idx"]) == S


def test_swa_masks_long_range():
    """Mixtral's sliding window: tokens beyond the window are invisible."""
    cfg = reduced(ARCHS["mixtral-8x7b"], sliding_window=8, n_layers=2)
    params = lm.init_params(jax.random.key(0), cfg)
    B, S = 1, 32
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    logits1, _, _ = lm.forward(params, cfg, tokens=toks)
    # perturbing a token far outside every later window must not change the
    # last position's logits
    toks2 = toks.at[:, 0].set((toks[:, 0] + 7) % cfg.vocab_size)
    logits2, _, _ = lm.forward(params, cfg, tokens=toks2)
    np.testing.assert_allclose(
        np.asarray(logits1[:, -1]), np.asarray(logits2[:, -1]), rtol=1e-5, atol=1e-5
    )
    # ... while a token inside the window does
    toks3 = toks.at[:, -2].set((toks[:, -2] + 7) % cfg.vocab_size)
    logits3, _, _ = lm.forward(params, cfg, tokens=toks3)
    assert not np.allclose(np.asarray(logits1[:, -1]), np.asarray(logits3[:, -1]))

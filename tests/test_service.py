"""The service layer without HTTP: batching engine, result cache, map
registry, hot swap, metrics.

The acceptance bar this file pins down:

* **coalesced ≡ direct** — any interleaving of concurrent ``project()``
  requests returns placements bit-identical to one dedicated
  ``MapServer.transform`` call per request;
* **cache hits skip device work entirely** — asserted via the batcher's
  batch counters;
* **hot map swap never drops or mixes in-flight requests** — every
  response under a concurrent swap matches a direct transform on the
  exact map version it reports.

Everything here runs on a bare install — fastapi is never imported (the
HTTP skin has its own guarded suite in test_service_http.py).
"""

import threading
import time

import numpy as np
import pytest

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection
from repro.data.synthetic import gaussian_mixture
from repro.serve import FrozenMap, MapServer, TransformResult
from repro.service import (
    Batcher,
    BatcherClosed,
    MapRegistry,
    MapService,
    ResultCache,
    make_key,
    map_fingerprint,
    query_fingerprint,
)

N, DIM, MICRO = 600, 8, 32

CFG = NomadConfig(
    n_points=N,
    dim=DIM,
    n_clusters=4,
    n_neighbors=5,
    n_noise=8,
    n_exact_negatives=4,
    batch_size=128,
    n_epochs=2,
    serve_microbatch=MICRO,
    transform_steps=4,
    service_max_delay_s=0.003,
)


def _fit(seed: int, ckdir: str = ""):
    x, _ = gaussian_mixture(N, DIM, n_components=4, seed=seed)
    est = NomadProjection(CFG.replace(seed=seed, checkpoint_dir=ckdir))
    est.fit(x)
    return est


@pytest.fixture(scope="module")
def fitted():
    return _fit(0)


@pytest.fixture(scope="module")
def fitted_b(tmp_path_factory):
    """A second, genuinely different map (different seed), checkpointed —
    the swap target."""
    ckdir = str(tmp_path_factory.mktemp("svc") / "ck_b")
    return _fit(1, ckdir), ckdir


def queries(n, seed):
    q, _ = gaussian_mixture(n, DIM, n_components=4, seed=seed)
    return q


# ---------------------------------------------------------------------------
# Batching engine: coalesced ≡ direct, bit for bit
# ---------------------------------------------------------------------------


def assert_result_equal(got: TransformResult, want: TransformResult):
    np.testing.assert_array_equal(got.embedding, want.embedding)
    np.testing.assert_array_equal(got.cells, want.cells)
    np.testing.assert_array_equal(got.neighbor_ids, want.neighbor_ids)
    np.testing.assert_array_equal(got.neighbor_dists, want.neighbor_dists)


def test_batcher_single_request_equals_direct(fitted):
    server = fitted.map_server()
    batcher = Batcher(server, max_delay_s=0.0)
    q = queries(50, 11)
    try:
        got = batcher.project(q, seed=3)
    finally:
        batcher.close()
    assert_result_equal(got, server.transform(q, seed=3))
    assert got.n_queries == 50 and np.isnan(got.batch_loss).all()


def test_batcher_concurrent_requests_bit_equal_direct(fitted):
    """The tentpole property: concurrent requests of ragged sizes and
    distinct seeds, interleaved however the worker coalesces them, each
    return exactly the bits of a dedicated transform call."""
    server = fitted.map_server()
    rng = np.random.RandomState(7)
    sizes = [int(rng.randint(1, 3 * server.batch_rows)) for _ in range(12)]
    reqs = [(queries(n, 100 + i), 1000 + i) for i, n in enumerate(sizes)]
    want = [server.transform(q, seed=s) for q, s in reqs]

    batcher = Batcher(server, max_delay_s=0.01)
    got = [None] * len(reqs)
    errs = []
    start = threading.Barrier(len(reqs))

    def go(i):
        try:
            start.wait()
            got[i] = batcher.project(reqs[i][0], seed=reqs[i][1])
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=go, args=(i,)) for i in range(len(reqs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    batcher.close()
    assert not errs
    for g, w in zip(got, want):
        assert_result_equal(g, w)


def test_batcher_coalesces_backlog_into_full_batches(fitted):
    """Deterministic coalescing: enqueue a backlog with the worker
    stopped, then start it — the whole backlog must pack into the minimal
    number of device batches."""
    server = fitted.map_server()
    B = server.batch_rows
    batcher = Batcher(server, max_delay_s=0.5, autostart=False)
    per_req = B // 4
    n_req = 8  # 8 × B/4 = 2 full batches
    reqs = [batcher.submit(queries(per_req, 30 + i), seed=i) for i in range(n_req)]
    batcher.start()
    for r in reqs:
        assert r.done.wait(30.0) and r.error is None
    batcher.close()
    assert batcher.stats.n_batches == (n_req * per_req) // B == 2
    assert batcher.stats.batch_fill == 1.0
    assert batcher.stats.n_requests == n_req


def test_batcher_splits_oversize_requests(fitted):
    server = fitted.map_server()
    B = server.batch_rows
    n = 2 * B + B // 2  # 2.5 batches
    q = queries(n, 41)
    batcher = Batcher(server, max_delay_s=0.0)
    try:
        got = batcher.project(q, seed=5)
    finally:
        batcher.close()
    assert_result_equal(got, server.transform(q, seed=5))
    assert len(got.batch_latency_s) >= 3


def test_batcher_closed_rejects_and_drains(fitted):
    server = fitted.map_server()
    batcher = Batcher(server, max_delay_s=0.2)
    req = batcher.submit(queries(8, 50), seed=0)
    batcher.close(drain=True)  # flushes the partial batch immediately
    assert req.done.is_set() and req.error is None
    with pytest.raises(BatcherClosed):
        batcher.submit(queries(4, 51))
    assert batcher.queue_depth() == 0


def test_batcher_return_neighbors_false_matches(fitted):
    server = fitted.map_server()
    q = queries(40, 60)
    batcher = Batcher(server, max_delay_s=0.0)
    try:
        got = batcher.project(q, seed=2, return_neighbors=False)
    finally:
        batcher.close()
    want = server.transform(q, seed=2)
    np.testing.assert_array_equal(got.embedding, want.embedding)
    np.testing.assert_array_equal(got.cells, want.cells)
    assert got.neighbor_ids is None and got.neighbor_dists is None


# ---------------------------------------------------------------------------
# Result cache
# ---------------------------------------------------------------------------


def test_cache_hit_skips_device_work_entirely(fitted):
    svc = MapService()
    handle = svc.registry.add(FrozenMap.from_fit(fitted._fit_result, fitted.cfg))
    q = queries(20, 70)
    first = svc.project(q, seed=1)
    assert not first.cache_hit
    batches_after_miss = handle.batcher.stats.n_batches
    second = svc.project(q, seed=1)
    assert second.cache_hit
    # the whole point: a hit never reaches the batcher, let alone the device
    assert handle.batcher.stats.n_batches == batches_after_miss
    assert second.result is first.result
    assert svc.metrics.count("project.cache_hits") == 1
    svc.close()


def test_cache_key_sensitivity(fitted):
    """seed, steps, neighbors flag, map content and query content each
    produce distinct keys; identical inputs collide (that's the hit)."""
    fz = FrozenMap.from_fit(fitted._fit_result, fitted.cfg)
    fp = map_fingerprint(fz)
    q = queries(10, 80)
    base = make_key(fp, q, 0, 4, True)
    assert make_key(fp, q, 0, 4, True) == base
    assert make_key(fp, q, 1, 4, True) != base
    assert make_key(fp, q, 0, 5, True) != base
    assert make_key(fp, q, 0, 4, False) != base
    assert make_key("other-map", q, 0, 4, True) != base
    q2 = q.copy()
    q2[3, 2] += 1e-3
    assert make_key(fp, q2, 0, 4, True) != base
    # container/layout-invariant: the fingerprint canonicalises to f32 C-order
    assert query_fingerprint(np.asfortranarray(q)) == query_fingerprint(q)


def test_cache_lru_eviction():
    cache = ResultCache(capacity=2)
    r = TransformResult(np.zeros((1, 2)), np.zeros(1), None, None)
    ka, kb, kc = ("m", "a", 0, 1, True), ("m", "b", 0, 1, True), ("m", "c", 0, 1, True)
    cache.put(ka, r)
    cache.put(kb, r)
    assert cache.get(ka) is r  # touch a → b is now LRU
    cache.put(kc, r)
    assert cache.get(kb) is None and cache.get(ka) is r and cache.get(kc) is r
    assert len(cache) == 2
    st = cache.stats()
    assert st["hits"] == 3 and st["misses"] == 1


def test_cache_capacity_zero_disables():
    cache = ResultCache(capacity=0)
    k = ("m", "q", 0, 1, True)
    cache.put(k, TransformResult(np.zeros((1, 2)), np.zeros(1), None, None))
    assert cache.get(k) is None and len(cache) == 0


# ---------------------------------------------------------------------------
# Registry + hot swap
# ---------------------------------------------------------------------------


def test_registry_versioning_and_activation(fitted):
    reg = MapRegistry()
    fz = FrozenMap.from_fit(fitted._fit_result, fitted.cfg)
    h1 = reg.add(fz, warm=False)
    h2 = reg.add(fz, warm=False, activate=False)
    assert (h1.version, h2.version) == ("v1", "v2")
    assert reg.active_version == "v1"
    assert [d["active"] for d in reg.versions()] == [True, False]
    reg.activate("v2")
    assert reg.get().version == "v2"
    with pytest.raises(KeyError, match="unknown map version"):
        reg.get("v9")
    with pytest.raises(ValueError, match="refusing to retire the active"):
        reg.retire("v2")
    reg.retire("v1")
    assert [d["version"] for d in reg.versions()] == ["v2"]
    with pytest.raises(ValueError, match="already registered"):
        reg.add(fz, version="v2", warm=False)
    reg.close()
    with pytest.raises(RuntimeError, match="no active map"):
        reg.get()


def test_map_fingerprint_is_content_derived(fitted, fitted_b):
    est_b, _ = fitted_b
    fz_a = FrozenMap.from_fit(fitted._fit_result, fitted.cfg)
    fz_b = FrozenMap.from_fit(est_b._fit_result, est_b.cfg)
    assert map_fingerprint(fz_a) == map_fingerprint(fz_a)
    assert map_fingerprint(fz_a) != map_fingerprint(fz_b)


def test_hot_swap_under_concurrent_load(fitted, fitted_b):
    """Clients hammer project() while the registry swaps v1 → v2 and
    retires v1. No request may be dropped, error, or mix maps: every
    response must be bit-identical to a direct transform on the exact
    version it claims to have been served by."""
    est_b, ckdir_b = fitted_b
    svc = MapService(cache_entries=0)  # every request must hit a device
    svc.registry.add(
        FrozenMap.from_fit(fitted._fit_result, fitted.cfg), version="v1"
    )
    servers = {"v1": fitted.map_server(), "v2": est_b.map_server()}

    n_threads = 4
    results = [[] for _ in range(n_threads)]
    errs = []
    start = threading.Barrier(n_threads + 1)
    stop = threading.Event()  # set only after the swap has completed

    def client(t):
        try:
            start.wait()
            i = 0
            # keep firing until the swap is done, then land two more
            # requests that must be served by v2
            tail_after_stop = 0
            while tail_after_stop < 2 and i < 5000:
                stopped = stop.is_set()
                seed = t * 1000 + i
                q = queries(11 + (7 * t + i) % 40, seed)
                out = svc.project(q, seed=seed)
                results[t].append((q, seed, out))
                i += 1
                if stopped:
                    tail_after_stop += 1
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=client, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    start.wait()
    handle = svc.registry.swap(ckdir_b, version="v2")  # load+warm+activate+retire v1
    assert handle.version == "v2" and svc.registry.active_version == "v2"
    stop.set()
    for t in threads:
        t.join()

    assert not errs
    versions_seen = set()
    for bucket in results:
        assert len(bucket) >= 2  # every client got all its responses back
        for q, seed, out in bucket:
            versions_seen.add(out.map_version)
            want = servers[out.map_version].transform(q, seed=seed)
            np.testing.assert_array_equal(out.result.embedding, want.embedding)
            np.testing.assert_array_equal(out.result.neighbor_ids, want.neighbor_ids)
        # requests issued after the swap completed were served by v2
        assert bucket[-1][2].map_version == "v2"
    assert "v2" in versions_seen
    assert [d["version"] for d in svc.registry.versions()] == ["v2"]
    svc.close()


def test_swap_retry_on_retired_handle(fitted, fitted_b):
    """A request that resolved a handle which gets retired before its rows
    are accepted must transparently fail over to the new active map."""
    est_b, ckdir_b = fitted_b
    svc = MapService(cache_entries=0)
    svc.registry.add(
        FrozenMap.from_fit(fitted._fit_result, fitted.cfg), version="v1"
    )
    h2 = svc.registry.load(ckdir_b, version="v2", activate=True)
    old = svc.registry.get("v1")
    svc.registry.retire("v1")
    # simulate the race: submitting straight to the retired batcher fails …
    with pytest.raises(BatcherClosed):
        old.batcher.project(queries(4, 90), seed=0)
    # … but the service path re-resolves and serves from v2
    out = svc.project(queries(4, 90), seed=0)
    assert out.map_version == "v2"
    # a request pinned to a retired version does not silently fail over
    with pytest.raises(KeyError, match="unknown map version"):
        svc.project(queries(4, 91), seed=0, map_version="v1")
    assert h2.batcher.stats.n_errors == 0
    svc.close()


# ---------------------------------------------------------------------------
# Service-level plumbing
# ---------------------------------------------------------------------------


def test_service_validation_gate(fitted):
    svc = MapService()
    svc.registry.add(FrozenMap.from_fit(fitted._fit_result, fitted.cfg))
    with pytest.raises(ValueError, match="dim"):
        svc.project(np.zeros((4, DIM + 1), np.float32))
    with pytest.raises(ValueError, match="non-finite"):
        svc.project(np.full((4, DIM), np.nan, np.float32))
    with pytest.raises(ValueError, match="float64"):
        svc.project(np.zeros((4, DIM), np.float64))
    with pytest.raises(ValueError, match="transform_steps"):
        svc.project(queries(4, 95), steps=CFG.transform_steps + 1)
    svc.close()


def test_metrics_snapshot_shape(fitted):
    svc = MapService()
    svc.registry.add(FrozenMap.from_fit(fitted._fit_result, fitted.cfg))
    q = queries(8, 96)
    svc.project(q, seed=0)
    svc.project(q, seed=0)  # hit
    snap = svc.metrics_snapshot()
    assert snap["counters"]["project.requests"] == 2
    assert snap["counters"]["project.cache_hits"] == 1
    assert snap["cache"]["hits"] == 1 and snap["cache"]["misses"] == 1
    lat = snap["latency"]["project"]
    assert lat["count"] == 2 and lat["p50_s"] > 0 and lat["p99_s"] >= lat["p50_s"]
    (version,) = snap["maps"]
    per_map = snap["maps"][version]
    assert per_map["active"] and per_map["queue_depth"] == 0
    assert per_map["n_batches"] >= 1 and 0 < per_map["batch_fill"] <= 1.0
    assert per_map["batch_p50_s"] > 0
    assert snap["active_map"] == version
    svc.close()


def test_service_config_validation():
    with pytest.raises(ValueError, match="service_max_delay_s"):
        NomadConfig(service_max_delay_s=-0.1)
    with pytest.raises(ValueError, match="service_cache_entries"):
        NomadConfig(service_cache_entries=-1)


def test_batcher_reads_config_delay(fitted):
    cfg_delay = fitted.cfg.service_max_delay_s
    batcher = Batcher(fitted.map_server())
    try:
        assert batcher.max_delay_s == cfg_delay
    finally:
        batcher.close()

"""Attention-path equivalence: full (oracle) vs chunked vs flash custom-VJP,
forward AND gradients, across causal/sliding-window/GQA variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    attend_chunked,
    attend_flash,
    attend_full,
    repeat_kv,
)


def _inputs(B=2, Sq=64, Sk=64, H=4, KV=2, hd=16, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32), (B, Sk))
    return q, k, v, qp, kp


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_forward_equivalence(causal, window, chunk):
    q, k, v, qp, kp = _inputs()
    want = attend_full(q, k, v, qp, kp, causal=causal, window=window)
    got_c = attend_chunked(q, k, v, qp, kp, causal=causal, window=window, chunk=chunk)
    got_f = attend_flash(q, k, v, qp, kp, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 24])
def test_flash_vjp_matches_full_grad(causal, window):
    q, k, v, qp, kp = _inputs(seed=3)
    tgt = jax.random.normal(jax.random.key(9), (2, 64, 4, 16))

    def loss_full(q, k, v):
        o = attend_full(q, k, v, qp, kp, causal=causal, window=window)
        return jnp.sum((o - tgt) ** 2)

    def loss_flash(q, k, v):
        o = attend_flash(q, k, v, qp, kp, causal=causal, window=window, chunk=16)
        return jnp.sum((o - tgt) ** 2)

    g_full = jax.grad(loss_full, argnums=(0, 1, 2))(q, k, v)
    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(g_flash, g_full, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4, err_msg=f"d{nm}"
        )


def test_flash_vjp_gqa_head_reduction():
    """GQA: dk/dv must sum over the q heads sharing each kv head."""
    q, k, v, qp, kp = _inputs(H=8, KV=2, seed=5)

    def loss(fn):
        def f(k):
            o = fn(q, k, v, qp, kp, causal=True, window=0)
            return jnp.sum(jnp.sin(o))

        return f

    g_flash = jax.grad(loss(lambda *a, **kw: attend_flash(*a, chunk=32, **kw)))(k)
    g_full = jax.grad(loss(attend_full))(k)
    assert g_flash.shape == k.shape
    np.testing.assert_allclose(np.asarray(g_flash), np.asarray(g_full), rtol=2e-4, atol=2e-4)


def test_uneven_chunks_and_long_kv():
    q, k, v, qp, kp = _inputs(Sq=32, Sk=128, seed=7)
    want = attend_full(q, k, v, qp, kp, causal=False, window=0)
    got = attend_flash(q, k, v, qp, kp, causal=False, window=0, chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

"""The HTTP skin (FastAPI app): endpoint contracts over a live service.

Skipped wholesale on bare installs — the CI ``service`` job installs the
``[service]`` extra and runs this for real. Everything the HTTP layer
adds (JSON marshalling, status codes, the swap endpoint) is covered here;
the batching/cache/swap *semantics* are pinned dependency-free in
test_service.py.
"""

import numpy as np
import pytest

fastapi = pytest.importorskip("fastapi")
pytest.importorskip("httpx")  # fastapi.testclient's transport

from fastapi.testclient import TestClient  # noqa: E402

from repro.configs.base import NomadConfig  # noqa: E402
from repro.core.nomad import NomadProjection  # noqa: E402
from repro.data.synthetic import gaussian_mixture  # noqa: E402
from repro.serve import FrozenMap  # noqa: E402
from repro.service import MapService  # noqa: E402
from repro.service.app import create_app  # noqa: E402

N, DIM = 600, 8

CFG = NomadConfig(
    n_points=N,
    dim=DIM,
    n_clusters=4,
    n_neighbors=5,
    n_noise=8,
    n_exact_negatives=4,
    batch_size=128,
    n_epochs=2,
    serve_microbatch=32,
    transform_steps=4,
)


@pytest.fixture(scope="module")
def fitted(tmp_path_factory):
    ckdir = str(tmp_path_factory.mktemp("http") / "ck")
    x, _ = gaussian_mixture(N, DIM, n_components=4, seed=0)
    est = NomadProjection(CFG.replace(checkpoint_dir=ckdir))
    est.fit(x)
    return est, ckdir


@pytest.fixture()
def service(fitted):
    est, _ = fitted
    svc = MapService()
    svc.registry.add(FrozenMap.from_fit(est._fit_result, est.cfg), version="v1")
    yield svc
    svc.close()


@pytest.fixture()
def client(service):
    with TestClient(create_app(service)) as c:
        yield c


def rows(n, seed):
    q, _ = gaussian_mixture(n, DIM, n_components=4, seed=seed)
    return q


def test_health_ok_and_empty(client):
    body = client.get("/health").json()
    assert body["status"] == "ok" and body["active_map"] == "v1"
    empty = TestClient(create_app(MapService()))
    r = empty.get("/health")
    assert r.status_code == 503 and r.json()["detail"]["status"] == "empty"


def test_project_roundtrip_equals_direct(client, fitted):
    est, _ = fitted
    q = rows(20, 5)
    r = client.post("/project", json={"rows": q.tolist(), "seed": 3})
    assert r.status_code == 200
    body = r.json()
    want = est.map_server().transform(q, seed=3)
    np.testing.assert_array_equal(
        np.asarray(body["embedding"], np.float32), want.embedding
    )
    np.testing.assert_array_equal(np.asarray(body["cells"]), want.cells)
    np.testing.assert_array_equal(np.asarray(body["neighbor_ids"]), want.neighbor_ids)
    # dead edges (-1 ids) marshal their inf distances as -1.0, live ones exact
    dists = np.asarray(body["neighbor_dists"], np.float32)
    ids = np.asarray(body["neighbor_ids"])
    np.testing.assert_array_equal(dists[ids >= 0], want.neighbor_dists[ids >= 0])
    assert (dists[ids < 0] == -1.0).all()
    assert body["map_version"] == "v1" and not body["cache_hit"]
    assert body["n_queries"] == 20 and body["n_batches"] >= 1


def test_project_cache_hit_and_placement_only(client):
    q = rows(10, 6)
    a = client.post("/project", json={"rows": q.tolist(), "seed": 0}).json()
    b = client.post("/project", json={"rows": q.tolist(), "seed": 0}).json()
    assert not a["cache_hit"] and b["cache_hit"]
    assert b["embedding"] == a["embedding"]
    c = client.post(
        "/project",
        json={"rows": q.tolist(), "seed": 0, "return_neighbors": False},
    ).json()
    assert "neighbor_ids" not in c and c["embedding"] == a["embedding"]


def test_project_error_codes(client):
    bad_dim = rows(4, 7)[:, :-1]
    r = client.post("/project", json={"rows": bad_dim.tolist()})
    assert r.status_code == 400 and "dim" in r.json()["detail"]
    r = client.post(
        "/project", json={"rows": rows(4, 7).tolist(), "map_version": "nope"}
    )
    assert r.status_code == 404
    r = client.post("/project", json={"rows": []})
    assert r.status_code == 400


def test_maps_listing_and_swap_endpoint(client, fitted):
    _, ckdir = fitted
    body = client.get("/maps").json()
    assert body["active"] == "v1" and len(body["maps"]) == 1
    assert body["maps"][0]["n_points"] == N

    r = client.post("/maps", json={"checkpoint_dir": ckdir, "version": "v2"})
    assert r.status_code == 200 and r.json()["activated"] == "v2"
    body = client.get("/maps").json()
    assert body["active"] == "v2"
    # retire_old drained and dropped v1
    assert [m["version"] for m in body["maps"]] == ["v2"]

    r = client.post("/maps", json={"checkpoint_dir": "/nonexistent/ck"})
    assert r.status_code == 400


def test_activate_endpoint(client, fitted):
    _, ckdir = fitted
    client.post(
        "/maps",
        json={"checkpoint_dir": ckdir, "version": "v2", "retire_old": False},
    )
    r = client.post("/maps/v1/activate")
    assert r.status_code == 200 and r.json()["activated"] == "v1"
    assert client.get("/maps").json()["active"] == "v1"
    assert client.post("/maps/v9/activate").status_code == 404


def test_metrics_endpoint_counts_and_latency(client):
    q = rows(6, 8)
    client.post("/project", json={"rows": q.tolist()})
    client.post("/project", json={"rows": q.tolist()})
    client.get("/health")
    m = client.get("/metrics").json()
    assert m["counters"]["http./project"] == 2
    assert m["counters"]["http./health"] == 1
    assert m["counters"]["project.cache_hits"] == 1
    assert m["cache"]["size"] == 1
    assert m["active_map"] == "v1"
    v1 = m["maps"]["v1"]
    assert v1["active"] and v1["n_batches"] >= 1 and 0 < v1["batch_fill"] <= 1
    assert m["latency"]["project"]["count"] == 2

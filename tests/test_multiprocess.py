"""Multi-process (jax.distributed) integration + unit tests.

The determinism contract under test: a 2-process CPU fit — per-process
store shards, cross-process collectives on one global mesh — is
**bit-for-bit equal** to the 1-process sharded fit over the same global
device count. Same for the distributed index build and for
checkpoint/resume from a killed 2-process run.

The slow tests spawn real worker processes via
``python -m repro.launch.distributed --spawn K`` (gloo CPU collectives,
local coordinator on an OS-assigned port); the fast tests cover the
process-aware store/​config plumbing in-process.
"""

import os
import socket
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the coordinator needs a loopback TCP port; sandboxes without one skip
# the whole module rather than failing on infrastructure
try:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as _s:
        _s.bind(("127.0.0.1", 0))
except OSError as e:  # pragma: no cover - environment-dependent
    pytest.skip(f"no loopback TCP available ({e})", allow_module_level=True)


def _run(args, devices=1, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.distributed", *args],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )


# ---------------------------------------------------------------------------
# fast: process-aware plumbing (no subprocesses, single device)
# ---------------------------------------------------------------------------


def test_process_row_range_partitions_exactly():
    from repro.data.store import ArrayStore

    st = ArrayStore(np.zeros((1001, 4), np.float32))
    spans = [st.process_row_range(i, 3) for i in range(3)]
    # contiguous, ordered, balanced (sizes differ by at most one), total N
    assert spans[0][0] == 0 and spans[-1][1] == 1001
    assert all(spans[i][1] == spans[i + 1][0] for i in range(2))
    sizes = [hi - lo for lo, hi in spans]
    assert max(sizes) - min(sizes) <= 1 and sum(sizes) == 1001
    with pytest.raises(ValueError):
        st.process_row_range(3, 3)


def test_assigned_shards_cover_all(tmp_path):
    from repro.data.store import ShardedStore, write_sharded

    x = np.arange(700 * 3, dtype=np.float32).reshape(700, 3)
    write_sharded(x, str(tmp_path / "st"), rows_per_shard=100)
    st = ShardedStore(str(tmp_path / "st"))
    a, b = st.assigned_shards(0, 2), st.assigned_shards(1, 2)
    # every shard is someone's; the boundary shard may appear in both
    assert sorted(set(a) | set(b)) == list(range(7))


def test_write_sharded_offset_validation(tmp_path):
    from repro.data.store import write_sharded

    x = np.zeros((10, 2), np.float32)
    with pytest.raises(ValueError, match="total_rows"):
        write_sharded(x, str(tmp_path / "a"), rows_per_shard=4, row_offset=4)
    with pytest.raises(ValueError, match="rows_per_shard"):
        write_sharded(
            x, str(tmp_path / "b"), rows_per_shard=4, row_offset=2, total_rows=20
        )
    with pytest.raises(ValueError, match="mid-shard"):
        # 10 rows from offset 4 end at 14 — inside the next writer's shard
        write_sharded(
            x, str(tmp_path / "c"), rows_per_shard=4, row_offset=4,
            total_rows=20, commit=False,
        )
    with pytest.raises(ValueError, match="commit"):
        # shard-aligned partial range, but commit=True would write meta
        # for rows no one has written yet
        write_sharded(
            np.zeros((8, 2), np.float32), str(tmp_path / "d"),
            rows_per_shard=4, row_offset=4, total_rows=20, commit=True,
        )


def test_cooperative_write_then_commit(tmp_path):
    """Two offset writers + a process-0-style commit ≡ one monolithic write."""
    from repro.data.store import (
        ShardedStore,
        commit_sharded_meta,
        write_sharded,
    )

    rng = np.random.default_rng(0)
    x = rng.normal(size=(600, 5)).astype(np.float32)
    mono = write_sharded(x, str(tmp_path / "mono"), rows_per_shard=100)

    coop = str(tmp_path / "coop")
    write_sharded(x[:300], coop, rows_per_shard=100, row_offset=0,
                  total_rows=600, commit=False)
    with pytest.raises(FileNotFoundError, match="missing"):
        commit_sharded_meta(coop, 600, 5, rows_per_shard=100)
    write_sharded(x[300:], coop, rows_per_shard=100, row_offset=300,
                  total_rows=600, commit=False)
    st = commit_sharded_meta(coop, 600, 5, rows_per_shard=100)
    assert st.shape == mono.shape
    np.testing.assert_array_equal(st.read(0, 600), mono.read(0, 600))
    # re-open from disk sees the same bytes
    np.testing.assert_array_equal(
        ShardedStore(coop).read(0, 600), x
    )


def test_config_distributed_and_shard_cap():
    from repro.configs.base import NomadConfig

    assert NomadConfig(build_strategy="distributed").store_max_shards == 256
    assert NomadConfig(store_max_shards=8).store_max_shards == 8
    with pytest.raises(ValueError, match="store_max_shards"):
        NomadConfig(store_max_shards=0)
    with pytest.raises(ValueError, match="build_strategy"):
        NomadConfig(build_strategy="bogus")


def test_fit_result_records_process_provenance():
    from repro.configs import get_nomad
    from repro.core.nomad import NomadProjection

    cfg = get_nomad("nomad_quickstart").replace(
        n_points=600, n_clusters=4, n_neighbors=4, n_epochs=1
    )
    rng = np.random.default_rng(0)
    x = rng.normal(size=(600, 8)).astype(np.float32)
    res = NomadProjection(cfg).fit(x)
    assert res.process_count == 1 and res.process_index == 0


def test_distributed_build_matches_sharded_single_process():
    """On one process the 'distributed' path IS the sharded program."""
    from repro.configs import get_nomad
    from repro.data.store import ArrayStore
    from repro.index.build import IndexBuilder

    cfg = get_nomad("nomad_quickstart").replace(
        n_points=1501, n_clusters=4, n_neighbors=4
    )
    rng = np.random.default_rng(1)
    x = rng.normal(size=(1501, 8)).astype(np.float32)
    ref = IndexBuilder(cfg, strategy="sharded").build(x)
    b = IndexBuilder(cfg, strategy="distributed")
    got = b.build(ArrayStore(x))
    assert b.report.strategy == "distributed"
    assert "place" in b.report.stage_s
    for name in ("knn_idx", "knn_w", "counts", "centroids", "perm"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, name)), np.asarray(getattr(got, name))
        )
    np.testing.assert_array_equal(np.asarray(ref.x_rows), np.asarray(got.x_rows))


def test_strategy_describe_reports_process_topology():
    from repro.configs import get_nomad
    from repro.core.strategy import resolve_strategy

    cfg = get_nomad("nomad_quickstart").replace(n_points=600, n_clusters=4)
    # single-process here: 'local' resolves fine — the multi-process guard
    # itself only trips under jax.distributed (slow 2-process tests)
    strat = resolve_strategy("local", cfg)
    desc = strat.describe()
    assert desc["process_count"] == 1 and desc["process_index"] == 0


# ---------------------------------------------------------------------------
# slow: real 2-process runs (gloo collectives over loopback)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    from repro.data.synthetic import gaussian_mixture_store

    d = str(tmp_path_factory.mktemp("mp") / "store")
    gaussian_mixture_store(d, 4000, 16, seed=3, rows_per_shard=1000)
    return d


@pytest.mark.slow
def test_two_process_fit_bit_equal_to_single(corpus, tmp_path):
    ref_out, ref_idx = str(tmp_path / "ref.npy"), str(tmp_path / "ref.npz")
    mp_out, mp_idx = str(tmp_path / "mp.npy"), str(tmp_path / "mp.npz")
    r1 = _run(
        ["--num-processes", "1", "--store", corpus, "--epochs", "3",
         "--out", ref_out, "--dump-index", ref_idx],
        devices=2,
    )
    assert r1.returncode == 0, r1.stdout[-2000:] + r1.stderr[-2000:]
    r2 = _run(
        ["--spawn", "2", "--store", corpus, "--epochs", "3",
         "--out", mp_out, "--dump-index", mp_idx],
        devices=1,
    )
    assert r2.returncode == 0, r2.stdout[-2000:] + r2.stderr[-2000:]
    assert "processes=2" in r2.stdout
    np.testing.assert_array_equal(np.load(ref_out), np.load(mp_out))
    ref, got = np.load(ref_idx), np.load(mp_idx)
    for k in ("knn_idx", "knn_w", "counts", "centroids", "perm"):
        np.testing.assert_array_equal(ref[k], got[k])


@pytest.mark.slow
def test_two_process_crash_then_resume_bit_equal(corpus, tmp_path):
    ck = str(tmp_path / "ck")
    resumed, straight = str(tmp_path / "resumed.npy"), str(tmp_path / "s4.npy")
    common = ["--spawn", "2", "--store", corpus, "--epochs", "4"]
    crash = _run(
        [*common, "--checkpoint-dir", ck, "--checkpoint-every", "1",
         "--fail-at-epoch", "2"],
    )
    assert crash.returncode == 17, crash.stdout[-2000:] + crash.stderr[-2000:]
    assert "CRASH INJECTION at epoch 2" in crash.stdout
    resume = _run(
        [*common, "--checkpoint-dir", ck, "--resume", "--out", resumed],
    )
    assert resume.returncode == 0, resume.stdout[-2000:] + resume.stderr[-2000:]
    assert "resume: epoch 2" in resume.stdout
    assert "index: cache" in resume.stdout  # p0's cached index was reused
    clean = _run([*common, "--out", straight])
    assert clean.returncode == 0, clean.stdout[-2000:] + clean.stderr[-2000:]
    np.testing.assert_array_equal(np.load(resumed), np.load(straight))


@pytest.mark.slow
def test_missing_coordinator_fails_fast_and_loud():
    # pre-flight validation is catchable: rc 3 + an actionable message
    r = _run(
        ["--num-processes", "2", "--process-id", "1", "--epochs", "1"],
        timeout=120,
    )
    assert r.returncode == 3, r.stdout[-2000:] + r.stderr[-2000:]
    assert "distributed init failed" in r.stderr


@pytest.mark.slow
def test_unreachable_coordinator_does_not_hang():
    from repro.launch.distributed import pick_free_port

    port = pick_free_port()  # nothing listens here
    r = _run(
        ["--num-processes", "2", "--process-id", "1",
         "--coordinator", f"127.0.0.1:{port}", "--init-timeout", "3",
         "--epochs", "1"],
        timeout=120,
    )
    # jaxlib's distributed client LOG(FATAL)s (SIGABRT) on rendezvous
    # deadline instead of raising — either way the worker must die within
    # the timeout, nonzero, with the deadline visible in stderr
    assert r.returncode != 0, r.stdout[-2000:]
    assert (
        "DEADLINE_EXCEEDED" in r.stderr or "distributed init failed" in r.stderr
    ), r.stderr[-2000:]

"""Metric sanity: identity embeddings score 1.0; random embeddings score at
chance; metrics are monotone in corruption."""

import numpy as np

from repro.data.synthetic import gaussian_mixture
from repro.metrics import neighborhood_preservation, random_triplet_accuracy


def test_identity_scores_one():
    x, _ = gaussian_mixture(400, 8, seed=0)
    assert neighborhood_preservation(x, x.copy(), k=10, n_queries=200) == 1.0
    assert random_triplet_accuracy(x, x.copy(), 5000) == 1.0


def test_isometry_scores_one():
    x, _ = gaussian_mixture(300, 4, seed=1)
    y = x * 3.0 + 7.0  # distance-order preserving
    assert neighborhood_preservation(x, y, k=10, n_queries=150) == 1.0
    assert random_triplet_accuracy(x, y, 4000) == 1.0


def test_random_embedding_at_chance():
    rng = np.random.default_rng(2)
    x, _ = gaussian_mixture(500, 16, seed=2)
    y = rng.normal(0, 1, (500, 2)).astype(np.float32)
    np10 = neighborhood_preservation(x, y, k=10, n_queries=300)
    assert np10 < 0.08  # chance ≈ k/N = 0.02, generous margin
    rta = random_triplet_accuracy(x, y, 10000)
    assert 0.4 < rta < 0.6


def test_corruption_monotonicity():
    x, _ = gaussian_mixture(400, 8, seed=3)
    rng = np.random.default_rng(3)
    scores = []
    for noise in (0.0, 0.5, 5.0):
        y = x[:, :2] + rng.normal(0, noise, (400, 2)).astype(np.float32)
        scores.append(random_triplet_accuracy(x, y, 8000))
    assert scores[0] >= scores[1] >= scores[2] - 0.02

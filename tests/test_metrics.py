"""Metric sanity: identity embeddings score 1.0; random embeddings score at
chance; metrics are monotone in corruption."""

import numpy as np
import pytest

from repro.data.synthetic import gaussian_mixture
from repro.metrics import (
    map_stability,
    neighborhood_preservation,
    random_triplet_accuracy,
)


def test_identity_scores_one():
    x, _ = gaussian_mixture(400, 8, seed=0)
    assert neighborhood_preservation(x, x.copy(), k=10, n_queries=200) == 1.0
    assert random_triplet_accuracy(x, x.copy(), 5000) == 1.0


def test_isometry_scores_one():
    x, _ = gaussian_mixture(300, 4, seed=1)
    y = x * 3.0 + 7.0  # distance-order preserving
    assert neighborhood_preservation(x, y, k=10, n_queries=150) == 1.0
    assert random_triplet_accuracy(x, y, 4000) == 1.0


def test_random_embedding_at_chance():
    rng = np.random.default_rng(2)
    x, _ = gaussian_mixture(500, 16, seed=2)
    y = rng.normal(0, 1, (500, 2)).astype(np.float32)
    np10 = neighborhood_preservation(x, y, k=10, n_queries=300)
    assert np10 < 0.08  # chance ≈ k/N = 0.02, generous margin
    rta = random_triplet_accuracy(x, y, 10000)
    assert 0.4 < rta < 0.6


def test_corruption_monotonicity():
    x, _ = gaussian_mixture(400, 8, seed=3)
    rng = np.random.default_rng(3)
    scores = []
    for noise in (0.0, 0.5, 5.0):
        y = x[:, :2] + rng.normal(0, noise, (400, 2)).astype(np.float32)
        scores.append(random_triplet_accuracy(x, y, 8000))
    assert scores[0] >= scores[1] >= scores[2] - 0.02


def test_map_stability_identity_is_one():
    emb = np.random.default_rng(4).normal(0, 1, (300, 2)).astype(np.float32)
    assert map_stability(emb, emb.copy(), k=10, n_queries=300) == 1.0


def test_map_stability_row_permutation_invariant():
    """The score compares maps, not row order: relabeling the rows of BOTH
    versions consistently cannot change it (exact at full query coverage)."""
    rng = np.random.default_rng(5)
    a = rng.normal(0, 1, (250, 2)).astype(np.float32)
    b = (a + rng.normal(0, 0.3, a.shape)).astype(np.float32)
    p = rng.permutation(250)
    s = map_stability(a, b, k=10, n_queries=250)
    s_perm = map_stability(a[p], b[p], k=10, n_queries=250)
    assert s == pytest.approx(s_perm, abs=1e-9)


def test_map_stability_degrades_monotonically_with_jitter():
    rng = np.random.default_rng(6)
    a = rng.normal(0, 1, (400, 2)).astype(np.float32)
    scores = []
    for noise in (0.0, 0.2, 1.0, 5.0):
        b = a + rng.normal(0, noise, a.shape).astype(np.float32)
        scores.append(map_stability(a, b, k=10, n_queries=400))
    assert scores[0] == 1.0
    assert scores[0] > scores[1] > scores[2] > scores[3]


def test_map_stability_rejects_row_count_mismatch():
    a = np.zeros((10, 2), np.float32)
    with pytest.raises(ValueError, match="same rows"):
        map_stability(a, np.zeros((12, 2), np.float32))

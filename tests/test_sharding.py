"""Sharding-rule tests: every parameter/optimizer/batch/cache spec must
divide the production mesh — cheap static checks that catch regressions
without compiling (the dry-run is the integration test)."""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.launch.sharding import (
    batch_pspecs,
    cache_pspecs,
    opt_state_pspec_tree,
    param_pspec_tree,
)
from repro.models import lm, steps
from repro.optim import AdamW, constant


class FakeMesh:
    shape = {"data": 16, "model": 16}


class FakeMeshPod:
    shape = {"pod": 2, "data": 16, "model": 16}


def _check_divisibility(tree, specs, mesh_shape, where):
    leaves = jax.tree.leaves(tree)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves), where
    for leaf, spec in zip(leaves, spec_leaves):
        assert isinstance(spec, P), (where, spec)
        assert len(spec) <= len(leaf.shape), (where, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([mesh_shape[a] for a in axes]))
            assert dim % total == 0, (where, leaf.shape, spec, dim, total)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_and_opt_specs_divide_mesh(name):
    cfg = ARCHS[name]
    params = lm.abstract_params(cfg)
    specs = param_pspec_tree(cfg, FakeMesh, params)
    _check_divisibility(params, specs, FakeMesh.shape, f"{name}/params")
    opt = AdamW(schedule=constant(1e-4), moment_dtype=cfg.opt_moment_dtype)
    opt_state = jax.eval_shape(opt.init, params)
    ospecs = opt_state_pspec_tree(cfg, FakeMesh, opt_state)
    _check_divisibility(opt_state, ospecs, FakeMesh.shape, f"{name}/opt")


@pytest.mark.parametrize("name", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [FakeMesh, FakeMeshPod])
def test_batch_and_cache_specs_divide_mesh(name, mesh):
    cfg = ARCHS[name]
    for sname in cfg.supported_shapes():
        shape = SHAPES[sname]
        b = batch_specs_tree = steps.batch_specs(
            cfg, shape, with_labels=shape.kind == "train", microbatched=True
        )
        specs = batch_pspecs(cfg, shape, mesh)
        _check_divisibility(b, specs, mesh.shape, f"{name}/{sname}/batch")
        if shape.kind == "decode":
            cache = steps.cache_specs(cfg, shape)
            cspecs = cache_pspecs(cfg, shape, mesh, cache)
            for k_ in cache:
                _check_divisibility(
                    cache[k_], cspecs[k_], mesh.shape, f"{name}/{sname}/cache[{k_}]"
                )


def test_tp_attention_heads_padded():
    cfg = ARCHS["phi4-mini-3.8b"]
    assert cfg.n_heads == 24 and cfg.n_heads_padded == 32
    cfg = ARCHS["yi-34b"]
    assert cfg.n_heads == 56 and cfg.n_heads_padded == 64
    cfg = ARCHS["jamba-1.5-large-398b"]
    assert cfg.n_heads_padded == cfg.n_heads == 64  # already divisible
    # padding preserves kv-group structure: Hp/KV ≥ H/KV, integer
    for c in ARCHS.values():
        if c.n_heads:
            assert c.n_heads_padded % max(c.n_kv_heads, 1) == 0
            assert c.n_heads_padded % 16 == 0


def test_vocab_padding():
    assert ARCHS["mamba2-2.7b"].vocab_padded % 256 == 0
    assert ARCHS["hubert-xlarge"].vocab_padded == 512
    assert ARCHS["mixtral-8x7b"].vocab_padded == 32000  # already divisible

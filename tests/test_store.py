"""Out-of-core embedding stores (repro.data.store).

Covers: bit-exact round-trips of sharded/memmap stores vs the source array
across chunk sizes (ragged final chunks, N not divisible by chunk_rows),
the 0-row shard rejection, bf16 storage, the convert CLI, the chunked
``prepare_inputs`` gate (no full-size temporary for memmap inputs), the
container-invariant data fingerprint, and — marked ``slow`` — the RSS
regression bound of the streamed index build vs the monolithic path.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.nomad import prepare_inputs
from repro.data.store import (
    ArrayStore,
    EmbeddingStore,
    MemmapStore,
    ShardedStore,
    as_store,
    is_store,
    stream_chunks,
    write_sharded,
)
from repro.data.synthetic import gaussian_mixture, gaussian_mixture_store

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N, DIM = 1500, 12


@pytest.fixture(scope="module")
def x():
    data, _ = gaussian_mixture(N, DIM, n_components=6, seed=5)
    return data


# ---------------------------------------------------------------------------
# Round-trips: every container must reproduce the source bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows_per_shard", [1, 7, 400, 1500, 4096])
def test_sharded_store_roundtrips_bitexact(x, tmp_path, rows_per_shard):
    st_ = write_sharded(x, str(tmp_path / "s"), rows_per_shard=rows_per_shard)
    assert st_.shape == (N, DIM) and len(st_) == N
    np.testing.assert_array_equal(st_.materialize(), x)


@pytest.mark.parametrize("chunk_rows", [1, 333, 512, 1499, 1500, 9999])
def test_chunked_reads_cover_ragged_chunks(x, tmp_path, chunk_rows):
    """Chunk boundaries straddle shard boundaries and N % chunk_rows != 0 —
    reassembly must still be bit-exact, via both read paths."""
    st_ = write_sharded(x, str(tmp_path / "s"), rows_per_shard=400)
    got = [c for s, c in st_.iter_chunks(chunk_rows)]
    np.testing.assert_array_equal(np.concatenate(got), x)
    streamed = [c for s, c in stream_chunks(st_, chunk_rows)]
    np.testing.assert_array_equal(np.concatenate(streamed), x)
    assert all(c.dtype == np.float32 for c in got)


def test_memmap_store_roundtrips_bitexact(x, tmp_path):
    path = str(tmp_path / "x.npy")
    np.save(path, x)
    mm = MemmapStore(path)
    np.testing.assert_array_equal(mm.materialize(), x)
    np.testing.assert_array_equal(mm.read(37, 1203), x[37:1203])


def test_read_rows_gather(x, tmp_path):
    st_ = write_sharded(x, str(tmp_path / "s"), rows_per_shard=256)
    rows = np.array([3, 4, 5, N - 1, 0, 777, 401])
    np.testing.assert_array_equal(st_.read_rows(rows), x[rows])


def test_read_range_validation(x, tmp_path):
    st_ = write_sharded(x, str(tmp_path / "s"), rows_per_shard=256)
    with pytest.raises(IndexError):
        st_.read(0, N + 1)
    with pytest.raises(IndexError):
        st_.read(-1, 5)
    with pytest.raises(ValueError, match="chunk_rows"):
        list(st_.iter_chunks(0))


@given(
    n=st.integers(min_value=1, max_value=257),
    rows_per_shard=st.integers(min_value=1, max_value=300),
    chunk_rows=st.integers(min_value=1, max_value=300),
)
@settings(max_examples=25, deadline=None)
def test_property_roundtrip_any_blocking(n, rows_per_shard, chunk_rows):
    """Property: any (N, rows_per_shard, chunk_rows) triple round-trips."""
    import tempfile

    rng = np.random.default_rng(n * 1000 + rows_per_shard)
    data = rng.normal(0, 1, (n, 5)).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        st_ = write_sharded(data, d + "/s", rows_per_shard=rows_per_shard)
        got = [c for _s, c in st_.iter_chunks(chunk_rows)]
        np.testing.assert_array_equal(np.concatenate(got), data)


# ---------------------------------------------------------------------------
# Malformed stores
# ---------------------------------------------------------------------------


def test_zero_row_shard_rejected(x, tmp_path):
    d = str(tmp_path / "s")
    write_sharded(x, d, rows_per_shard=400)
    meta_path = os.path.join(d, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    np.save(os.path.join(d, "shard-junk.npy"), np.zeros((0, DIM), np.float32))
    meta["shards"].append("shard-junk.npy")
    meta["shard_rows"].append(0)
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="at least one row"):
        ShardedStore(d)


def test_inconsistent_row_total_rejected(x, tmp_path):
    d = str(tmp_path / "s")
    write_sharded(x, d, rows_per_shard=400)
    meta_path = os.path.join(d, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["n_rows"] = N + 7
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="sum"):
        ShardedStore(d)


def test_write_sharded_rejects_empty_and_ragged_dims(tmp_path):
    with pytest.raises(ValueError, match="no rows"):
        write_sharded(np.zeros((0, 4), np.float32), str(tmp_path / "e"))
    bad = [np.zeros((3, 4), np.float32), np.zeros((3, 5), np.float32)]
    with pytest.raises(ValueError, match="dim"):
        write_sharded(iter(bad), str(tmp_path / "r"))


# ---------------------------------------------------------------------------
# Storage dtypes
# ---------------------------------------------------------------------------


def test_bfloat16_store_roundtrip_within_precision(x, tmp_path):
    st_ = write_sharded(x, str(tmp_path / "bf"), rows_per_shard=512, dtype="bfloat16")
    assert st_.dtype_name == "bfloat16"
    got = st_.materialize()
    assert got.dtype == np.float32
    # bf16 keeps 8 significand bits: relative error bounded by 2^-8
    np.testing.assert_allclose(got, x, rtol=2**-7, atol=2**-7)
    # on-disk footprint is half of f32
    raw = np.load(str(tmp_path / "bf" / "shard-00000.npy"))
    assert raw.dtype == np.uint16


def test_float16_store_roundtrip(x, tmp_path):
    st_ = write_sharded(x, str(tmp_path / "f16"), rows_per_shard=512, dtype="float16")
    np.testing.assert_array_equal(st_.materialize(), x.astype(np.float16).astype(np.float32))


def test_raw_void_npy_rejected_with_pointer_to_sharded(tmp_path):
    import ml_dtypes

    path = str(tmp_path / "bf.npy")
    np.save(path, np.zeros((4, 3), ml_dtypes.bfloat16))  # degrades to |V2
    with pytest.raises(ValueError, match="sharded store"):
        MemmapStore(path)


# ---------------------------------------------------------------------------
# Resolution + CLI
# ---------------------------------------------------------------------------


def test_as_store_dispatch(x, tmp_path):
    assert as_store(x)._x is x  # ndarray → ArrayStore, zero-copy
    np.save(str(tmp_path / "x.npy"), x)
    assert isinstance(as_store(str(tmp_path / "x.npy")), MemmapStore)
    d = str(tmp_path / "s")
    write_sharded(x, d, rows_per_shard=512)
    assert isinstance(as_store(d), ShardedStore)
    s = as_store(d)
    assert as_store(s) is s
    with pytest.raises(TypeError, match="EmbeddingStore"):
        as_store(42)
    with pytest.raises(FileNotFoundError, match="meta.json"):
        as_store(str(tmp_path))  # a directory without meta.json
    with pytest.raises(ValueError, match=".npy"):
        as_store(str(tmp_path / "s" / "meta.json"))  # a non-.npy file


def test_convert_cli_and_info(x, tmp_path):
    src = str(tmp_path / "x.npy")
    np.save(src, x)
    out = str(tmp_path / "converted")
    r = subprocess.run(
        [
            sys.executable, "-m", "repro.data.store", "convert", src, out,
            "--rows-per-shard", "300", "--dtype", "bfloat16",
        ],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO,
    )
    assert r.returncode == 0, r.stderr
    assert "1500 rows x 12 dims" in r.stdout and "5 shard(s)" in r.stdout
    st_ = ShardedStore(out)
    np.testing.assert_allclose(st_.materialize(), x, rtol=2**-7, atol=2**-7)
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.data.store", "info", out],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO,
    )
    assert r2.returncode == 0 and "bfloat16" in r2.stdout


# ---------------------------------------------------------------------------
# The prepare_inputs gate: per-chunk validation, no full-size temporaries
# ---------------------------------------------------------------------------


def test_prepare_inputs_store_passthrough_and_validation(x, tmp_path):
    st_ = write_sharded(x, str(tmp_path / "s"), rows_per_shard=400)
    out = prepare_inputs(st_, caller="fit")
    assert out is st_  # a clean store flows through unchanged

    bad = x.copy()
    bad[1234, 3] = np.nan
    stb = write_sharded(bad, str(tmp_path / "bad"), rows_per_shard=400)
    with pytest.raises(ValueError, match="non-finite"):
        prepare_inputs(stb, caller="fit")

    with pytest.raises(ValueError, match="float64"):
        prepare_inputs(ArrayStore(x.astype(np.float64)), caller="fit")

    with pytest.raises(ValueError, match="dim 12"):
        prepare_inputs(st_, dim=99, caller="transform")


def test_prepare_inputs_memmap_casts_per_chunk(x, tmp_path, monkeypatch):
    """The satellite fix: a memmap input must neither be upcast with a
    full-array astype nor NaN-scanned in one full-size temporary — the
    gate wraps it into a store and validates chunk_rows rows at a time."""
    path = str(tmp_path / "x16.npy")
    np.save(path, x.astype(np.float16))
    mm = np.load(path, mmap_mode="r")
    assert isinstance(mm, np.memmap)

    seen = []
    real_isfinite = np.isfinite

    def spy(a, *args, **kw):
        seen.append(np.shape(a))
        return real_isfinite(a, *args, **kw)

    monkeypatch.setattr(np, "isfinite", spy)
    out = prepare_inputs(mm, caller="fit", chunk_rows=256)
    assert is_store(out)
    # every validation temporary was a chunk, never the full (N, D) array
    assert seen and max(s[0] for s in seen) <= 256 < N
    # reads cast per chunk to float32
    chunk = out.read(0, 100)
    assert chunk.dtype == np.float32
    np.testing.assert_array_equal(chunk, x[:100].astype(np.float16).astype(np.float32))


def test_prepare_inputs_ndarray_unchanged(x):
    out = prepare_inputs(x, caller="fit")
    assert isinstance(out, np.ndarray) and not is_store(out)
    assert out is x  # f32 arrays flow through without a copy


# ---------------------------------------------------------------------------
# Fingerprints are container-invariant
# ---------------------------------------------------------------------------


def test_data_fingerprint_same_for_all_containers(x, tmp_path):
    from repro.index.ann import data_fingerprint

    st_ = write_sharded(x, str(tmp_path / "s"), rows_per_shard=333)
    np.save(str(tmp_path / "x.npy"), x)
    fp = data_fingerprint(x)
    assert fp == data_fingerprint(st_)
    assert fp == data_fingerprint(MemmapStore(str(tmp_path / "x.npy")))
    y = x.copy()
    y[7, 0] += 1e-3
    assert data_fingerprint(y) != fp


def test_gaussian_mixture_store_matches_monolithic(tmp_path):
    x, lab = gaussian_mixture(2000, 10, n_components=5, seed=11)
    st_, lab2 = gaussian_mixture_store(
        str(tmp_path / "g"), 2000, 10, n_components=5, seed=11,
        chunk_rows=301, rows_per_shard=512,
    )
    np.testing.assert_array_equal(lab, lab2)
    np.testing.assert_array_equal(st_.materialize(), x)


# ---------------------------------------------------------------------------
# RSS regression: the streamed build must stay under the monolithic path
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_streamed_build_rss_below_monolithic(tmp_path):
    """Runs benchmarks/index_build.py --store-dir at N=50k in a subprocess
    and asserts the streamed build's peak host RSS (ru_maxrss watermark,
    sampled before the monolithic build runs in the same process) stays
    measurably below the monolithic path's.

    The benchmark is launched through a tiny ``python -c`` interposer: on
    Linux a fork()ed child *inherits the parent's RSS as its initial
    ru_maxrss* (and the value survives exec), so spawning straight from a
    multi-GB pytest process would floor both phases at pytest's own RSS
    and void the comparison. The interposer forks the benchmark from a
    ~15 MB image instead."""
    out = str(tmp_path / "bench.json")
    interpose = (
        "import subprocess, sys; "
        "sys.exit(subprocess.run(sys.argv[1:]).returncode)"
    )
    r = subprocess.run(
        [
            sys.executable, "-c", interpose,
            sys.executable, "benchmarks/index_build.py",
            "--n", "50000", "--dim", "256", "--clusters", "128",
            "--neighbors", "15", "--repeat", "1",
            "--store-dir", str(tmp_path / "corpus"),
            "--json", out,
        ],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    with open(out) as f:
        res = json.load(f)
    rss = res["rss_compare"]
    assert rss["streamed_peak_mb"] > 0 and rss["monolithic_peak_mb"] > 0
    # "measurably below": the monolithic path allocates several full (N, D)
    # copies (~50 MB each at this size); demand a clear margin over jitter
    assert rss["monolithic_peak_mb"] - rss["streamed_peak_mb"] >= 24.0, rss

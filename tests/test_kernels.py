"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref.py oracles,
custom-VJP correctness vs jax.grad of the oracle (assignment requirement c).

All Pallas kernels run in interpret mode on CPU (the TPU lowering is the
same kernel body with ``REPRO_PALLAS_INTERPRET=0``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pairwise.ops import pairwise_dist2
from repro.kernels.pairwise.ref import pairwise_dist2_ref
from repro.kernels.cauchy_mean.ops import cauchy_weighted_sum
from repro.kernels.cauchy_mean.ref import (
    cauchy_weighted_sum_ref,
    cauchy_weighted_sum_vjp_ref,
)
from repro.kernels.kmeans_assign.ops import assign_nearest
from repro.kernels.kmeans_assign.ref import assign_nearest_ref


# ---------------------------------------------------------------------------
# pairwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m,d", [(256, 256, 64), (512, 256, 128), (100, 300, 33), (8, 1024, 512), (257, 129, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_matches_ref(n, m, d, dtype):
    kx, ky = jax.random.split(jax.random.key(n * m + d))
    x = jax.random.normal(kx, (n, d), dtype)
    y = jax.random.normal(ky, (m, d), dtype)
    got = pairwise_dist2(x, y)
    want = pairwise_dist2_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_pairwise_zero_distance_diagonal():
    x = jax.random.normal(jax.random.key(0), (64, 16), jnp.float32)
    d2 = np.asarray(pairwise_dist2(x, x))
    assert np.all(np.abs(np.diag(d2)) < 1e-4)
    assert np.all(d2 >= 0)


# ---------------------------------------------------------------------------
# cauchy_mean (forward + custom VJP)
# ---------------------------------------------------------------------------


def _cauchy_inputs(B, K, d, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed), 4)
    theta = jax.random.normal(k1, (B, d), jnp.float32) * 3.0
    means = jax.random.normal(k2, (K, d), jnp.float32) * 3.0
    w = jax.random.uniform(k3, (K,), jnp.float32)
    own = jax.random.randint(k4, (B,), 0, K)
    return theta, means, w, own


@pytest.mark.parametrize("B,K,d", [(512, 1024, 2), (100, 64, 2), (1024, 4096, 2), (64, 100, 3), (777, 333, 2)])
def test_cauchy_mean_forward_matches_ref(B, K, d):
    theta, means, w, own = _cauchy_inputs(B, K, d, seed=B + K)
    got = cauchy_weighted_sum(theta, means, w, own)
    want = cauchy_weighted_sum_ref(theta, means, w, own)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,K,d", [(256, 512, 2), (100, 64, 2), (64, 100, 3)])
def test_cauchy_mean_vjp_matches_autodiff_of_ref(B, K, d):
    theta, means, w, own = _cauchy_inputs(B, K, d, seed=7 * B + K)

    def f_kernel(th):
        return jnp.sum(jnp.sin(cauchy_weighted_sum(th, means, w, own)))

    def f_ref(th):
        return jnp.sum(jnp.sin(cauchy_weighted_sum_ref(th, means, w, own)))

    g_kernel = jax.grad(f_kernel)(theta)
    g_ref = jax.grad(f_ref)(theta)
    np.testing.assert_allclose(np.asarray(g_kernel), np.asarray(g_ref), rtol=1e-4, atol=1e-6)


def test_cauchy_mean_vjp_ref_matches_formula():
    theta, means, w, own = _cauchy_inputs(128, 64, 2, seed=3)
    gbar = jax.random.normal(jax.random.key(9), (128,), jnp.float32)
    want = jax.vjp(lambda th: cauchy_weighted_sum_ref(th, means, w, own), theta)[1](gbar)[0]
    got = cauchy_weighted_sum_vjp_ref(theta, means, w, own, gbar)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)


def test_cauchy_mean_excludes_own_cell():
    """Moving the own-cell mean must not change the output."""
    theta, means, w, own = _cauchy_inputs(32, 16, 2, seed=5)
    s1 = cauchy_weighted_sum(theta, means, w, own)
    means2 = means.at[own[0]].add(100.0)
    s2 = cauchy_weighted_sum(theta, means2, w, own)
    assert float(jnp.abs(s1[0] - s2[0])) < 1e-6
    assert float(jnp.max(jnp.abs(s1[1:] - s2[1:]))) > 0  # others do change


# ---------------------------------------------------------------------------
# kmeans_assign
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k,d", [(512, 256, 64), (1000, 17, 32), (64, 512, 128), (513, 255, 48)])
def test_kmeans_assign_matches_ref(n, k, d):
    kx, kc = jax.random.split(jax.random.key(n + k))
    x = jax.random.normal(kx, (n, d), jnp.float32)
    cents = jax.random.normal(kc, (k, d), jnp.float32)
    a_got, d_got = assign_nearest(x, cents)
    a_want, d_want = assign_nearest_ref(x, cents)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want), rtol=1e-4, atol=1e-4)
    # argmin ties can differ between tilings; assert distance-equivalence
    d_of_got = np.take_along_axis(
        np.asarray(pairwise_dist2_ref(x, cents)), np.asarray(a_got)[:, None], 1
    )[:, 0]
    np.testing.assert_allclose(d_of_got, np.asarray(d_want), rtol=1e-4, atol=1e-4)


def test_kmeans_assign_exact_on_centroids():
    cents = jax.random.normal(jax.random.key(1), (32, 16), jnp.float32) * 5
    a, d = assign_nearest(cents, cents)
    np.testing.assert_array_equal(np.asarray(a), np.arange(32))
    assert float(jnp.max(d)) < 1e-3

"""Out-of-sample serving: transform determinism, checkpoint-loaded serving,
sharded ≡ local bit-equality, frozen-θ immutability, and the shared
fit/transform input-validation gate.

Everything runs on the single in-process CPU device; the sharded serve
strategy is exercised on a 1-device mesh, where it must agree with the
local strategy bit-for-bit (per-row math, per-row RNG).
"""

import os

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.base import NomadConfig
from repro.core.nomad import NomadProjection, prepare_inputs
from repro.data.synthetic import gaussian_mixture
from repro.serve import FrozenMap, MapServer, TransformResult

N, DIM, NQ = 1500, 16, 300

CFG = NomadConfig(
    n_points=N,
    dim=DIM,
    n_clusters=4,
    n_neighbors=10,
    n_noise=16,
    n_exact_negatives=4,
    batch_size=256,
    n_epochs=4,
    serve_microbatch=128,
    transform_steps=6,
)


@pytest.fixture(scope="module")
def fitted(tmp_path_factory):
    """One fit with a checkpoint dir — shared by every serving test."""
    ckdir = str(tmp_path_factory.mktemp("serve") / "ck")
    x, labels = gaussian_mixture(N, DIM, n_components=4, seed=0)
    est = NomadProjection(CFG.replace(checkpoint_dir=ckdir))
    res = est.fit(x)
    return est, res, x, labels, ckdir


@pytest.fixture(scope="module")
def queries():
    return gaussian_mixture(NQ, DIM, n_components=4, seed=7)


@pytest.fixture(scope="module")
def one_device_mesh():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("serve",))


# ---------------------------------------------------------------------------
# Determinism + invariances
# ---------------------------------------------------------------------------


def test_transform_deterministic_under_fixed_key(fitted, queries):
    est, _, _, _, _ = fitted
    q, _ = queries
    a = est.transform(q, seed=0)
    b = est.transform(q, seed=0)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (NQ, CFG.out_dim) and np.isfinite(a).all()
    c = est.transform(q, seed=1)
    assert not np.array_equal(a, c)  # the key matters (in-cell negatives)


def test_transform_microbatch_invariant(fitted, queries):
    """RNG is folded per global query row, so placements cannot depend on
    how the queries are sliced into microbatches."""
    est, _, _, _, _ = fitted
    q, _ = queries
    a = est.map_server(microbatch=64).transform(q, seed=0)
    b = est.map_server(microbatch=256).transform(q, seed=0)
    np.testing.assert_array_equal(a.embedding, b.embedding)
    np.testing.assert_array_equal(a.neighbor_ids, b.neighbor_ids)
    assert len(a.batch_latency_s) == -(-NQ // 64)
    assert len(b.batch_latency_s) == -(-NQ // 256)


def test_sharded_serving_equals_local_on_one_device(fitted, queries, one_device_mesh):
    est, _, _, _, _ = fitted
    q, _ = queries
    loc = est.map_server(strategy="local").transform(q, seed=0)
    sh = est.map_server(strategy="sharded", mesh=one_device_mesh).transform(q, seed=0)
    assert loc.strategy == "local" and sh.strategy == "sharded" and sh.n_shards == 1
    np.testing.assert_array_equal(loc.embedding, sh.embedding)
    np.testing.assert_array_equal(loc.cells, sh.cells)
    np.testing.assert_array_equal(loc.neighbor_ids, sh.neighbor_ids)
    np.testing.assert_array_equal(loc.neighbor_dists, sh.neighbor_dists)
    assert loc.batch_loss == sh.batch_loss


def test_sharded_serving_accepts_caller_mesh_axis_name(fitted, queries):
    """A caller-supplied 1-axis mesh keeps its own axis name (e.g. the
    training mesh's 'data') — the serve axis must not be hard-coded."""
    est, _, _, _, _ = fitted
    q, _ = queries
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sh = est.map_server(strategy="sharded", mesh=mesh).transform(q, seed=0)
    loc = est.map_server(strategy="local").transform(q, seed=0)
    np.testing.assert_array_equal(loc.embedding, sh.embedding)


def test_map_server_overrides_do_not_stick(fitted, queries):
    """A one-off map_server(override) must not change what the estimator's
    public transform() does afterwards."""
    est, _, _, _, _ = fitted
    q, _ = queries
    a = est.transform(q, seed=0)
    est.map_server(steps=0)  # inspect-only server with overrides
    b = est.transform(q, seed=0)
    np.testing.assert_array_equal(a, b)


def test_concurrent_transform_threads_bit_equal_sequential(fitted, queries):
    """One MapServer hammered from many threads: no shared-state
    corruption, every result bit-equal to the sequential call — the
    correctness substrate the service layer's batching engine stands on."""
    import threading

    est, _, _, _, _ = fitted
    q, _ = queries
    server = est.map_server()
    seeds = list(range(8))
    want = {s: server.transform(q[: 64 + 8 * s], seed=s) for s in seeds}
    got = {}
    errs = []
    start = threading.Barrier(len(seeds))

    def go(s):
        try:
            start.wait()
            got[s] = server.transform(q[: 64 + 8 * s], seed=s)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=go, args=(s,)) for s in seeds]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    for s in seeds:
        np.testing.assert_array_equal(got[s].embedding, want[s].embedding)
        np.testing.assert_array_equal(got[s].cells, want[s].cells)
        np.testing.assert_array_equal(got[s].neighbor_ids, want[s].neighbor_ids)
        np.testing.assert_array_equal(got[s].neighbor_dists, want[s].neighbor_dists)


def test_return_neighbors_false_parity(fitted, queries):
    """The placement-only fast path skips the neighbor outputs (and their
    host transfers) but must place bit-identically."""
    est, _, _, _, _ = fitted
    q, _ = queries
    server = est.map_server()
    full = server.transform(q, seed=0)
    fast = server.transform(q, seed=0, return_neighbors=False)
    np.testing.assert_array_equal(fast.embedding, full.embedding)
    np.testing.assert_array_equal(fast.cells, full.cells)
    assert fast.neighbor_ids is None and fast.neighbor_dists is None
    assert full.neighbor_ids is not None  # the default is unchanged


def test_transform_result_percentile_helpers():
    r = TransformResult(
        embedding=np.zeros((1, 2), np.float32),
        cells=np.zeros((1,), np.int64),
        neighbor_ids=None,
        neighbor_dists=None,
        batch_latency_s=[0.1 * (i + 1) for i in range(100)],
    )
    assert r.p50_latency_s == pytest.approx(
        float(np.percentile(r.batch_latency_s, 50))
    )
    assert r.p99_latency_s == pytest.approx(
        float(np.percentile(r.batch_latency_s, 99))
    )
    assert r.p99_latency_s > r.p50_latency_s
    # the shared static helper the benchmarks pool latencies through
    assert TransformResult.percentile([1.0, 2.0, 3.0], 50.0) == 2.0
    assert np.isnan(TransformResult.percentile([], 50.0))
    empty = TransformResult(
        embedding=np.zeros((0, 2), np.float32),
        cells=np.zeros((0,), np.int64),
        neighbor_ids=None,
        neighbor_dists=None,
    )
    assert np.isnan(empty.p50_latency_s)


# ---------------------------------------------------------------------------
# Out-of-core queries: transform(store) ≡ transform(ndarray)
# ---------------------------------------------------------------------------


def test_transform_store_queries_equal_ndarray(fitted, queries, tmp_path):
    """Store-backed queries stream one microbatch at a time through the
    same jitted transform — placements are bit-identical to the in-memory
    call (per-row math, per-row RNG)."""
    from repro.data.store import write_sharded

    est, _, _, _, _ = fitted
    q, _ = queries
    # shard size not aligned with the microbatch: reads straddle shards
    qs = write_sharded(q, str(tmp_path / "q"), rows_per_shard=100)
    a = est.map_server().transform(q, seed=0)
    b = est.map_server().transform(qs, seed=0)
    np.testing.assert_array_equal(a.embedding, b.embedding)
    np.testing.assert_array_equal(a.cells, b.cells)
    np.testing.assert_array_equal(a.neighbor_ids, b.neighbor_ids)
    np.testing.assert_array_equal(a.neighbor_dists, b.neighbor_dists)
    assert b.n_queries == NQ


def test_transform_memmap_queries(fitted, queries, tmp_path):
    from repro.data.store import is_store

    est, _, _, _, _ = fitted
    q, _ = queries
    path = str(tmp_path / "q.npy")
    np.save(path, q)
    mm = np.load(path, mmap_mode="r")
    got = est.transform(mm, seed=0)
    np.testing.assert_array_equal(got, est.transform(q, seed=0))
    # the gate still validates store-backed queries
    bad = q.copy()
    bad[3, 2] = np.inf
    np.save(path, bad)
    with pytest.raises(ValueError, match="non-finite"):
        est.transform(np.load(path, mmap_mode="r"))


def test_serve_from_store_built_map(queries, tmp_path):
    """Fit from a disk-backed corpus (store-backed index), checkpoint it,
    and serve from the checkpoint — the store-backed x_rows sidecar feeds
    FrozenMap without the training array."""
    from repro.data.synthetic import gaussian_mixture_store

    q, _ = queries
    ckdir = str(tmp_path / "ck")
    store, _ = gaussian_mixture_store(
        str(tmp_path / "corpus"), N, DIM, n_components=4, seed=0,
        rows_per_shard=400,
    )
    cfg = CFG.replace(chunk_rows=512, checkpoint_dir=ckdir)
    est = NomadProjection(cfg)
    est.fit(store)
    want = est.transform(q, seed=0)
    cold = NomadProjection.from_checkpoint(ckdir)
    got = cold.transform(q, seed=0)  # never saw the corpus
    np.testing.assert_array_equal(want, got)


# ---------------------------------------------------------------------------
# Checkpoint-loaded serving (no training data)
# ---------------------------------------------------------------------------


def test_transform_after_from_checkpoint_matches_fit(fitted, queries):
    """`from_checkpoint(dir).transform(q)` — no fit call, no access to the
    training array — must equal transform on the just-fitted estimator
    bit-for-bit."""
    est, _, _, _, ckdir = fitted
    q, _ = queries
    want = est.transform(q, seed=0)
    cold = NomadProjection.from_checkpoint(ckdir)
    got = cold.transform(q, seed=0)  # never saw x
    np.testing.assert_array_equal(want, got)


def test_frozen_map_from_checkpoint_standalone(fitted, queries):
    est, _, _, _, ckdir = fitted
    q, _ = queries
    fz = FrozenMap.from_checkpoint(ckdir)
    assert fz.n_points == N and fz.dim == DIM
    res = MapServer(fz).transform(q, seed=0)
    assert isinstance(res, TransformResult)
    np.testing.assert_array_equal(res.embedding, est.transform(q, seed=0))


def test_frozen_map_from_checkpoint_needs_index_cache(tmp_path):
    from repro.checkpoint import Checkpointer

    ck = Checkpointer(str(tmp_path))
    ck.save(0, {"theta": np.zeros((8, 2), np.float32)}, metadata={"epoch": 0})
    with pytest.raises(FileNotFoundError, match="index"):
        FrozenMap.from_checkpoint(str(tmp_path))


def test_transform_without_fit_or_checkpoint_raises():
    with pytest.raises(RuntimeError, match="fit"):
        NomadProjection(CFG).transform(np.zeros((4, DIM), np.float32))


# ---------------------------------------------------------------------------
# The map is frozen
# ---------------------------------------------------------------------------


def test_transform_never_mutates_fitted_theta(fitted, queries):
    est, res, _, _, _ = fitted
    q, _ = queries
    before = res.embedding.copy()
    theta_before = np.asarray(est.map_server().frozen.theta_rows).copy()
    means_before = np.asarray(est.map_server().frozen.means).copy()
    est.transform(q, seed=3)
    est.transform(q, seed=4)
    np.testing.assert_array_equal(before, res.embedding)
    np.testing.assert_array_equal(
        theta_before, np.asarray(est.map_server().frozen.theta_rows)
    )
    np.testing.assert_array_equal(
        means_before, np.asarray(est.map_server().frozen.means)
    )


# ---------------------------------------------------------------------------
# Placement semantics
# ---------------------------------------------------------------------------


def test_transform_result_fields(fitted, queries):
    est, _, _, _, _ = fitted
    q, _ = queries
    r = est.map_server().transform(q, seed=0)
    assert r.n_queries == NQ and r.embedding.shape == (NQ, CFG.out_dim)
    assert r.cells.shape == (NQ,)
    assert (r.cells >= 0).all() and (r.cells < CFG.n_clusters).all()
    k = CFG.n_neighbors
    assert r.neighbor_ids.shape == (NQ, k) and r.neighbor_dists.shape == (NQ, k)
    live = r.neighbor_ids >= 0
    assert live.any()
    assert (r.neighbor_ids[live] < N).all()
    # distances ascend within each row (dead edges are +inf at the tail)
    d = np.where(live, r.neighbor_dists, np.inf)
    assert (np.diff(d, axis=1) >= -1e-6).all()
    assert len(r.batch_latency_s) == -(-NQ // CFG.serve_microbatch)
    assert all(t > 0 for t in r.batch_latency_s)


def test_queries_identical_to_training_points_land_nearby(fitted):
    """A query that IS a training row must be placed near that row's fitted
    position (its kNN contains itself at distance 0)."""
    est, res, x, _, _ = fitted
    take = np.arange(0, 50)
    r = est.map_server().transform(x[take], seed=0)
    # self is the nearest frozen neighbor, at distance ~0
    assert (r.neighbor_dists[:, 0] < 1e-3).all()
    assert (r.neighbor_ids[:, 0] == take).all()
    # the kNN init (steps=0) is a convex combination of fitted in-cell
    # positions: it must land within the local neighborhood of the true
    # position. (The optimised placement equals it only at equilibrium —
    # this 4-epoch toy map is still expanding, so we pin the init.)
    r0 = est.map_server(steps=0).transform(x[take], seed=0)
    gap = np.linalg.norm(r0.embedding - res.embedding[take], axis=1)
    nbr_radius = np.array(
        [
            np.linalg.norm(
                res.embedding[ids[ids >= 0]] - res.embedding[i], axis=1
            ).max()
            for i, ids in zip(take, r0.neighbor_ids)
        ]
    )
    assert (gap <= nbr_radius + 1e-12).all()


def test_transform_steps_zero_is_pure_knn_init(fitted, queries):
    est, _, _, _, _ = fitted
    q, _ = queries
    r = est.map_server(steps=0).transform(q, seed=0)
    r2 = est.map_server(steps=0).transform(q, seed=99)
    # no optimisation ⇒ no RNG consumption ⇒ seed-independent
    np.testing.assert_array_equal(r.embedding, r2.embedding)
    assert np.isnan(r.batch_loss).all()


# ---------------------------------------------------------------------------
# The shared fit/transform validation gate
# ---------------------------------------------------------------------------


def test_prepare_inputs_rejects_float64_everywhere(fitted):
    est, _, _, _, _ = fitted
    bad = np.zeros((4, DIM), np.float64)
    with pytest.raises(ValueError, match="float64"):
        est.transform(bad)
    with pytest.raises(ValueError, match="float64"):
        NomadProjection(CFG).fit(bad)
    with pytest.raises(ValueError, match="float64"):
        NomadProjection(CFG).fit_transform(bad)


def test_prepare_inputs_rejects_nan_everywhere(fitted):
    est, _, _, _, _ = fitted
    bad = np.zeros((4, DIM), np.float32)
    bad[1, 2] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        est.transform(bad)
    with pytest.raises(ValueError, match="non-finite"):
        NomadProjection(CFG).fit_transform(bad)


def test_prepare_inputs_shape_and_dim_checks(fitted):
    est, _, _, _, _ = fitted
    with pytest.raises(ValueError, match="2-D"):
        est.transform(np.zeros((DIM,), np.float32))
    with pytest.raises(ValueError, match="dim"):
        est.transform(np.zeros((4, DIM + 1), np.float32))


def test_prepare_inputs_coerces_integer_and_half():
    out = prepare_inputs(np.ones((3, 4), np.int64))
    assert out.dtype == np.float32
    out = prepare_inputs(np.ones((3, 4), np.float16))
    assert out.dtype == np.float32


def test_serve_config_validation():
    with pytest.raises(ValueError, match="serve_strategy"):
        NomadConfig(serve_strategy="pmap")
    with pytest.raises(ValueError, match="serve_microbatch"):
        NomadConfig(serve_microbatch=0)
    with pytest.raises(ValueError, match="transform_steps"):
        NomadConfig(transform_steps=-1)
